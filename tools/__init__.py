"""Repo tooling: the `check_bench` CI gate, the static-analysis suite
(`tools.analyze`, DESIGN.md §11), and the deprecated `check_docs` shim
(absorbed into the backend-parity pass)."""
