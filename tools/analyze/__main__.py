"""CLI of the static-analysis suite (DESIGN.md §11).

    PYTHONPATH=src python -m tools.analyze [--check|--baseline] [paths...]

Default paths: ``src tools benchmarks``.  Modes:

* (default) report non-baselined findings; exit 1 if any.
* ``--check``  CI gate: also fail on *stale* baseline entries, so the
  committed baseline can only shrink.
* ``--baseline``  rewrite ``tools/analyze/baseline.json`` from the
  current findings (deliberate re-grandfathering).
* ``--list-rules``  print the rule catalog.
"""
from __future__ import annotations

import argparse
import sys

from . import (ALL_PASSES, BASELINE_PATH, all_rules, collect_files,
               diff_baseline, load_baseline, run_passes, save_baseline)

DEFAULT_PATHS = ("src", "tools", "benchmarks")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="repo-invariant static-analysis suite")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS))
    ap.add_argument("--check", action="store_true",
                    help="CI gate: fail on new findings AND stale "
                         "baseline entries")
    ap.add_argument("--baseline", action="store_true",
                    help="rewrite the committed baseline from current "
                         "findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, doc in sorted(all_rules().items()):
            print(f"{rule}  {doc}")
        return 0

    files = collect_files(args.paths or list(DEFAULT_PATHS))
    findings = run_passes(ALL_PASSES, files)

    if args.baseline:
        save_baseline(findings)
        print(f"analyze: baseline rewritten with {len(findings)} "
              f"finding(s) -> {BASELINE_PATH}")
        return 0

    diff = diff_baseline(findings, load_baseline())
    n_base = len(findings) - len(diff.new)
    for f in diff.new:
        print(f.render())
    if diff.stale and args.check:
        for rule, path, snippet, n in diff.stale:
            print(f"{path}: STALE baseline entry {rule} x{n}: {snippet!r} "
                  f"(finding fixed? regenerate with --baseline)")
    ok = not diff.new and not (args.check and diff.stale)
    print(f"analyze: {len(files)} files, {len(findings)} finding(s) "
          f"({n_base} baselined, {len(diff.new)} new, "
          f"{len(diff.stale)} stale baseline entr"
          f"{'y' if len(diff.stale) == 1 else 'ies'}) -> "
          f"{'ok' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
