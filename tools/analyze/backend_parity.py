"""backend-parity pass: the pluggable surfaces stay complete (BE001-003).

The repo's contract (DESIGN.md §2, §9): every registered
`IntermediateFilter` implements the *full* protocol — batched verdicts,
the sequential per-pair oracle, and the §10 incremental-maintenance hooks —
and every ``*_backend`` knob on `JoinPlan` is threaded through the
pipeline shims, the launchers, and the docs.  A filter or knob that ships
half-wired silently degrades one execution path while the others keep
passing.  This pass generalizes (and absorbs) the old
``tools/check_docs.py`` CI gate:

* **BE001** — a registered filter misses part of the protocol: no
  ``verdicts`` / ``build`` / ``_verdict_one`` override, or no incremental
  maintenance path (neither ``_store_append``+``_store_delete`` nor
  overridden ``patch_insert``+``patch_delete``).
* **BE002** — a backend knob (JoinPlan ``*backend`` kwargs,
  ``build_backend``, launcher ``--*-backend`` flags) missing from
  README.md or DESIGN.md (the old check_docs rule).
* **BE003** — a JoinPlan backend knob not threaded through the pipeline
  shims (`spatial/pipeline.py`) or exposed by no launcher ``--*-backend``
  flag.

Unlike the AST passes this one imports ``repro`` (the registry is the
source of truth), so it needs ``src`` importable — the pass adds
``<root>/src`` to ``sys.path`` itself.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

from .core import AnalysisPass, Finding, SourceFile

_DOCS = ("README.md", "DESIGN.md")
#: build_backend travels through build_opts to every filter build, not as a
#: named JoinPlan kwarg; pipeline_mode is the staged/fused execution-mode
#: knob (DESIGN.md §12) — not a ``*backend`` name, same parity contract.
#: tile_budget / resume are the §14 tiled scale-out knobs (tile packing
#: budget + checkpoint-manifest resume): not JoinPlan kwargs either, but
#: they gate execution the same way, so BE002/BE003 hold them to the same
#: docs + pipeline-shim + launcher-flag threading.
_EXTRA_KNOBS = ("build_backend", "pipeline_mode", "plan_mode",
                "tile_budget", "resume")
_LAUNCHERS = ("src/repro/launch/spatial_join.py",
              "src/repro/launch/serve_join.py")
_PIPELINE = "src/repro/spatial/pipeline.py"


def _launcher_flag_knobs(root: Path) -> dict[str, list[str]]:
    """knob -> launchers exposing it as an argparse flag (the
    ``--*-backend`` / ``--*-mode`` family plus the §14 tiling flags
    ``--*-budget`` and ``--resume``)."""
    knobs: dict[str, list[str]] = {}
    for rel in _LAUNCHERS:
        text = (root / rel).read_text()
        for flag in re.findall(
                r'add_argument\(\s*'
                r'"(--[a-z][a-z-]*(?:backend|mode|budget)|--resume)"',
                text):
            knob = flag.lstrip("-").replace("-", "_")
            knobs.setdefault(knob, []).append(rel)
    return knobs


def collect_knobs(root: Path) -> list[str]:
    """Every backend knob: JoinPlan ``*backend`` kwargs + build_backend +
    launcher-only flags (the old check_docs surface)."""
    import inspect

    from repro.spatial import JoinPlan
    # the bare `backend` param is the deprecated filter_backend alias
    # (DP001, removed after 2026-12-01) — it needs no parity threading
    knobs = [p for p in inspect.signature(JoinPlan.__init__).parameters
             if p.endswith("backend") and p != "backend"]
    knobs += [k for k in _EXTRA_KNOBS if k not in knobs]
    knobs += [k for k in _launcher_flag_knobs(root) if k not in knobs]
    return knobs


class BackendParityPass(AnalysisPass):
    name = "backend-parity"
    rules = {
        "BE001": "registered IntermediateFilter does not implement the "
                 "full protocol (verdicts/build/_verdict_one/patch hooks)",
        "BE002": "backend knob undocumented in README.md or DESIGN.md "
                 "(absorbed tools/check_docs.py)",
        "BE003": "backend knob not threaded through the pipeline shims or "
                 "exposed by any launcher flag",
    }

    def scope(self, path: str) -> bool:
        # repo-level pass: runs once, not per scanned file
        return False

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        src_dir = str(root / "src")
        if src_dir not in sys.path:
            sys.path.insert(0, src_dir)
        out: list[Finding] = []
        out.extend(self._be001(root))
        out.extend(self._be002_003(root))
        return out

    # -- BE001: full filter protocol ---------------------------------------
    def _be001(self, root: Path) -> list[Finding]:
        import inspect

        from repro.spatial.filters import available_filters, get_filter
        from repro.spatial.filters.base import IntermediateFilter as Base

        out: list[Finding] = []
        for name in available_filters():
            cls = type(get_filter(name))
            try:
                path = Path(inspect.getsourcefile(cls)).resolve() \
                    .relative_to(root).as_posix()
                line = inspect.getsourcelines(cls)[1]
            except (TypeError, OSError, ValueError):
                path, line = "src/repro/spatial/filters/base.py", 1
            missing: list[str] = []
            for member in ("build", "verdicts", "_verdict_one"):
                if getattr(cls, member) is getattr(Base, member):
                    missing.append(member)
            has_store_hooks = (
                cls._store_append is not Base._store_append
                and cls._store_delete is not Base._store_delete)
            has_patch_override = (
                cls.patch_insert is not Base.patch_insert
                and cls.patch_delete is not Base.patch_delete)
            if not (has_store_hooks or has_patch_override):
                missing.append("patch_insert/patch_delete")
            if missing:
                out.append(Finding(
                    rule="BE001", path=path, line=line,
                    message=f"filter {name!r} ({cls.__name__}) misses "
                            f"protocol members: {', '.join(missing)}",
                    snippet=f"filter:{name}"))
        return out

    # -- BE002/BE003: knob threading ---------------------------------------
    def _be002_003(self, root: Path) -> list[Finding]:
        out: list[Finding] = []
        knobs = collect_knobs(root)
        texts = {doc: (root / doc).read_text() for doc in _DOCS}
        pipeline_text = (root / _PIPELINE).read_text()
        flag_knobs = _launcher_flag_knobs(root)
        for knob in knobs:
            for doc, text in texts.items():
                if not re.search(rf"\b{re.escape(knob)}\b", text):
                    out.append(Finding(
                        rule="BE002", path=doc, line=1,
                        message=f"backend knob `{knob}` undocumented in "
                                f"{doc} (add it to the stages/backends "
                                f"table and its DESIGN section)",
                        snippet=f"knob:{knob}"))
            if not re.search(rf"\b{re.escape(knob)}\b", pipeline_text):
                out.append(Finding(
                    rule="BE003", path=_PIPELINE, line=1,
                    message=f"backend knob `{knob}` not threaded through "
                            f"the pipeline shims",
                    snippet=f"knob:{knob}"))
            if knob not in flag_knobs:
                out.append(Finding(
                    rule="BE003", path=_LAUNCHERS[0], line=1,
                    message=f"backend knob `{knob}` exposed by no launcher "
                            f"--{knob.replace('_', '-')} flag",
                    snippet=f"knob:{knob}"))
        return out
