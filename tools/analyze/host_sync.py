"""host-sync pass: implicit device->host transfers (rules HS001/HS002).

3DPipe's fused-pipeline result (PAPERS.md) hinges on *not* syncing to host
between stages: every ``float()`` / ``bool()`` / ``.item()`` /
``np.asarray()`` on a jnp value is a blocking device->host transfer that
serializes dispatch.  Conversely, a benchmark that reads
``time.perf_counter()`` without draining the device first times dispatch,
not work (JAX is async).  Both directions are statically visible:

* **HS001** — ``float(x)`` / ``bool(x)`` / ``int(x)`` / ``np.asarray(x)``
  / ``np.array(x)`` / ``x.item()`` where ``x`` is a *device value*: a name
  assigned (anywhere in the enclosing function) from a ``jnp.*`` /
  ``jax.*`` expression or from calling a jit/shard_map/pallas-wrapped
  callable defined in the module.  Intended stage-boundary syncs are
  grandfathered in the baseline or carry an explaining suppression.
* **HS002** — in ``benchmarks/``, an elapsed-time read
  ``time.perf_counter() - t0`` whose timed region contains no
  ``block_until_ready`` / ``sync`` call: the number measures async
  dispatch, not device work.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .core import (AnalysisPass, Finding, SourceFile, assigned_names,
                   call_name, dotted, iter_functions)

#: host-converting callables (argument position 0)
_CONVERTERS = ("float", "bool", "int", "np.asarray", "np.array",
               "numpy.asarray", "numpy.array")
#: calls that wrap a function into a device-executing one
_DEVICE_WRAPPERS = ("jax.jit", "jit", "pl.pallas_call", "pallas_call",
                    "shard_map", "jax.experimental.shard_map.shard_map")
#: calls that force/await the transfer explicitly — the sanctioned idiom
_SYNC_CALLS = ("block_until_ready", "sync")


def _is_device_rooted(node: ast.AST, device_fns: set[str]) -> bool:
    """Expression rooted at jnp./jax. or at a known device callable."""
    if isinstance(node, ast.Call):
        name = call_name(node)
        root = name.split(".", 1)[0]
        if root in ("jnp", "jax") and not name.startswith("jax.config"):
            return True
        if name in device_fns:
            return True
        return False
    if isinstance(node, (ast.Subscript, ast.Attribute)):
        return _is_device_rooted(node.value, device_fns)
    if isinstance(node, ast.BinOp):
        return (_is_device_rooted(node.left, device_fns)
                or _is_device_rooted(node.right, device_fns))
    return False


def _module_device_fns(tree: ast.Module) -> set[str]:
    """Names bound (anywhere) to jit/shard_map/pallas_call results, plus
    functions decorated with them."""
    fns: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if call_name(node.value) in _DEVICE_WRAPPERS:
                for t in node.targets:
                    fns.update(assigned_names(t))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                name = (call_name(dec) if isinstance(dec, ast.Call)
                        else dotted(dec))
                if name in _DEVICE_WRAPPERS or (
                        isinstance(dec, ast.Call)
                        and call_name(dec) in ("partial", "functools.partial")
                        and dec.args
                        and dotted(dec.args[0]) in _DEVICE_WRAPPERS):
                    fns.add(node.name)
    return fns


def _device_names(fn: ast.AST, device_fns: set[str]) -> set[str]:
    """Local names assigned from device-rooted expressions in ``fn``."""
    names: set[str] = set()
    # two sweeps: a name assigned from a device fn may feed a later
    # assignment that appears earlier in the walk order
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                value_dev = _is_device_rooted(node.value, device_fns) or (
                    isinstance(node.value, ast.Name)
                    and node.value.id in names)
                if value_dev:
                    for t in node.targets:
                        names.update(assigned_names(t))
    return names


class HostSyncPass(AnalysisPass):
    name = "host-sync"
    rules = {
        "HS001": "implicit device->host transfer "
                 "(float/bool/int/np.asarray/.item on a jnp value)",
        "HS002": "benchmark elapsed-time read without a device sync "
                 "(block_until_ready) in the timed region",
    }

    _SCOPE = ("src/repro/spatial/", "src/repro/core/",
              "src/repro/kernels/", "benchmarks/")

    def scope(self, path: str) -> bool:
        return path.startswith(self._SCOPE)

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        out: list[Finding] = []
        for src in files:
            out.extend(self._hs001(src))
            if src.path.startswith("benchmarks/"):
                out.extend(self._hs002(src))
        return out

    # -- HS001 -------------------------------------------------------------
    def _hs001(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        device_fns = _module_device_fns(src.tree)
        # scope = one outermost function with everything nested inside it
        # (closures share the enclosing function's names), or the module
        # body outside any function
        parents = src.parents()
        scopes: list[tuple[ast.AST, list[ast.Call]]] = []
        claimed: set[int] = set()
        for fn in iter_functions(src.tree):
            anc, outer = parents.get(fn), True
            while anc is not None:
                if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    outer = False
                    break
                anc = parents.get(anc)
            if outer:
                calls = [n for n in ast.walk(fn)
                         if isinstance(n, ast.Call)]
                claimed.update(id(c) for c in calls)
                scopes.append((fn, calls))
        scopes.append((src.tree, [n for n in ast.walk(src.tree)
                                  if isinstance(n, ast.Call)
                                  and id(n) not in claimed]))
        for scope, calls in scopes:
            local = _device_names(scope, device_fns)
            for node in calls:
                name = call_name(node)
                what = None
                if name in _CONVERTERS and node.args:
                    arg = node.args[0]
                    if ((isinstance(arg, ast.Name) and arg.id in local)
                            or _is_device_rooted(arg, device_fns)):
                        what = f"{name}(...)"
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr == "item"):
                    base = node.func.value
                    if ((isinstance(base, ast.Name) and base.id in local)
                            or _is_device_rooted(base, device_fns)):
                        what = ".item()"
                if what is not None:
                    out.append(src.finding(
                        "HS001", node,
                        f"implicit device->host transfer: {what} on a jnp "
                        f"value blocks dispatch; keep the stage on device "
                        f"or sync explicitly with jax.block_until_ready"))
        return out

    # -- HS002 -------------------------------------------------------------
    @staticmethod
    def _is_perf_counter(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and call_name(node) in ("time.perf_counter", "perf_counter"))

    def _hs002(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        for fn in iter_functions(src.tree):
            starts: dict[str, list[int]] = {}  # name -> linenos of t0 = pc()
            reads: list[tuple[ast.AST, str]] = []
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and self._is_perf_counter(node.value)):
                    for t in node.targets:
                        for n in assigned_names(t):
                            starts.setdefault(n, []).append(node.lineno)
                if (isinstance(node, ast.BinOp)
                        and isinstance(node.op, ast.Sub)
                        and self._is_perf_counter(node.left)
                        and isinstance(node.right, ast.Name)):
                    reads.append((node, node.right.id))
            if not reads:
                continue
            sync_lines = sorted(
                node.lineno for node in ast.walk(fn)
                if isinstance(node, ast.Call)
                and (call_name(node).split(".")[-1] in _SYNC_CALLS))
            for node, t0 in reads:
                # the timed region opens at the closest preceding start
                cands = [ln for ln in starts.get(t0, ())
                         if ln <= node.lineno]
                if not cands:
                    continue
                lo = max(cands)
                if not any(lo <= s <= node.lineno for s in sync_lines):
                    out.append(src.finding(
                        "HS002", node,
                        f"timed region [{t0}={lo} .. {node.lineno}] has no "
                        f"block_until_ready/sync before the perf_counter "
                        f"read: measures async dispatch, not device work"))
        return out
