"""pallas-constraint pass: TPU kernel shape/trace rules (PL001-003).

The Pallas kernels (DESIGN.md §§7,9) assume: block shapes are powers of
two (the (8,128) VPU tile and the pow-2 bucketing contract of
``core.join``), kernel bodies are straight-line vector code (Python
branches on traced values either fail to trace or silently specialize),
and kernels close over nothing mutable on the host (captured state bakes
into the compiled executable and goes stale).  A *kernel function* is one
whose parameters are ``*_ref`` Refs.

* **PL001** — a ``block_*`` parameter default or ``block_*=`` call
  argument that is not a power of two, in a pallas-importing module.
* **PL002** — a Python ``if`` / ``while`` / ``assert`` in a kernel
  function whose test involves a traced value (a Ref load, a value
  derived from one, or ``pl.program_id``).  Use ``jnp.where`` /
  ``pl.when`` instead.
* **PL003** — a kernel function closing over host state: free names that
  are not module imports, module-level constants, module-level function
  defs, or builtins.
"""
from __future__ import annotations

import ast
import builtins
from pathlib import Path

from .core import (AnalysisPass, Finding, SourceFile, assigned_names,
                   call_name, is_pow2, iter_functions)


def _imports_pallas(src: SourceFile) -> bool:
    return "pallas" in src.text


def _is_kernel_fn(fn: ast.FunctionDef) -> bool:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args]
    return any(n.endswith("_ref") for n in names) and len(names) > 0


def _module_allowed_names(tree: ast.Module) -> set[str]:
    allowed: set[str] = set(dir(builtins))
    for node in tree.body:
        if isinstance(node, ast.Import):
            allowed.update(a.asname or a.name.split(".")[0]
                           for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            allowed.update(a.asname or a.name for a in node.names)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            allowed.add(node.name)
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            # module constants: literal scalars/tuples only
            value = node.value
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            if value is not None and _is_const_expr(value):
                for t in targets:
                    allowed.update(assigned_names(t))
    return allowed


def _is_const_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_const_expr(e) for e in node.elts)
    if isinstance(node, ast.BinOp):
        return _is_const_expr(node.left) and _is_const_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_const_expr(node.operand)
    return False


def _traced_names(fn: ast.FunctionDef) -> set[str]:
    """Locals derived from Ref loads or pl.program_id (fixpoint sweep)."""
    ref_params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                  if a.arg.endswith("_ref")}

    def rooted(node: ast.AST, traced: set[str]) -> bool:
        if isinstance(node, ast.Subscript):
            base = node.value
            return ((isinstance(base, ast.Name)
                     and (base.id in ref_params or base.id in traced))
                    or rooted(base, traced))
        if isinstance(node, ast.Call):
            if call_name(node) in ("pl.program_id", "program_id"):
                return True
            return any(rooted(a, traced) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in traced
        if isinstance(node, ast.BinOp):
            return rooted(node.left, traced) or rooted(node.right, traced)
        if isinstance(node, (ast.Attribute, ast.UnaryOp)):
            inner = (node.value if isinstance(node, ast.Attribute)
                     else node.operand)
            return rooted(inner, traced)
        if isinstance(node, ast.Compare):
            return rooted(node.left, traced) or any(
                rooted(c, traced) for c in node.comparators)
        return False

    traced: set[str] = set()
    for _ in range(3):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and rooted(node.value, traced):
                for t in node.targets:
                    traced.update(assigned_names(t))
    return traced


class PallasConstraintPass(AnalysisPass):
    name = "pallas-constraint"
    rules = {
        "PL001": "non-power-of-two block shape in a pallas module",
        "PL002": "Python branch on a traced value inside a kernel "
                 "function (use jnp.where / pl.when)",
        "PL003": "kernel function captures host state (free name that is "
                 "not an import, module constant, or module function)",
    }

    def scope(self, path: str) -> bool:
        return path.startswith("src/repro/kernels/")

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        out: list[Finding] = []
        for src in files:
            if not _imports_pallas(src):
                continue
            out.extend(self._pl001(src))
            allowed = _module_allowed_names(src.tree)
            for fn in iter_functions(src.tree):
                if not _is_kernel_fn(fn):
                    continue
                out.extend(self._pl002(src, fn))
                out.extend(self._pl003(src, fn, allowed))
        return out

    # -- PL001 -------------------------------------------------------------
    def _pl001(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        for fn in iter_functions(src.tree):
            args = fn.args
            pairs = list(zip(args.kwonlyargs, args.kw_defaults))
            n_def = len(args.defaults)
            if n_def:
                pairs += list(zip(args.args[-n_def:], args.defaults))
            for a, d in pairs:
                if a.arg.startswith("block_") and \
                        isinstance(d, ast.Constant) and \
                        isinstance(d.value, int) and not is_pow2(d.value):
                    out.append(src.finding(
                        "PL001", fn,
                        f"`{fn.name}` default {a.arg}={d.value} is not a "
                        f"power of two (breaks the pow-2 tiling contract)"))
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg and kw.arg.startswith("block_") and \
                            isinstance(kw.value, ast.Constant) and \
                            isinstance(kw.value.value, int) and \
                            not is_pow2(kw.value.value):
                        out.append(src.finding(
                            "PL001", node,
                            f"call passes {kw.arg}={kw.value.value}, not a "
                            f"power of two"))
        return out

    # -- PL002 -------------------------------------------------------------
    def _pl002(self, src: SourceFile,
               fn: ast.FunctionDef) -> list[Finding]:
        out: list[Finding] = []
        traced = _traced_names(fn)
        ref_params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                      if a.arg.endswith("_ref")}

        def mentions_traced(node: ast.AST) -> bool:
            for n in ast.walk(node):
                if isinstance(n, ast.Name) and (
                        n.id in traced or n.id in ref_params):
                    return True
                if isinstance(n, ast.Call) and \
                        call_name(n) in ("pl.program_id", "program_id"):
                    return True
            return False

        for node in ast.walk(fn):
            test = None
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            elif isinstance(node, ast.IfExp):
                test = node.test
            if test is not None and mentions_traced(test):
                kind = type(node).__name__.lower()
                out.append(src.finding(
                    "PL002", node,
                    f"kernel `{fn.name}`: Python `{kind}` on a traced "
                    f"value — use jnp.where / pl.when (branches do not "
                    f"trace)"))
        return out

    # -- PL003 -------------------------------------------------------------
    def _pl003(self, src: SourceFile, fn: ast.FunctionDef,
               allowed: set[str]) -> list[Finding]:
        out: list[Finding] = []
        bound: set[str] = set()
        a = fn.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            bound.add(arg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    bound.update(assigned_names(t))
            elif isinstance(node, (ast.For, ast.comprehension)):
                tgt = node.target
                bound.update(assigned_names(tgt))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                bound.add(node.name)
                for arg in (node.args.posonlyargs + node.args.args
                            + node.args.kwonlyargs):
                    bound.add(arg.arg)
            elif isinstance(node, ast.withitem) and node.optional_vars:
                bound.update(assigned_names(node.optional_vars))
        reported: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load):
                name = node.id
                if name in bound or name in allowed or name in reported:
                    continue
                reported.add(name)
                out.append(src.finding(
                    "PL003", node,
                    f"kernel `{fn.name}` captures host name `{name}` "
                    f"(not an import/constant/module function): captured "
                    f"state bakes into the compiled kernel"))
        return out
