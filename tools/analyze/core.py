"""Framework of the repo-invariant static-analysis suite (DESIGN.md §11).

The paper's pipeline only pays off while every stage stays vectorized,
device-resident, and verdict-identical to its sequential oracle.  Those are
*mechanical* invariants — an implicit device->host sync, an unguarded f32
sign test, a shared service field touched off-lock — and this module gives
them a linter so they fail in CI before they fail in a benchmark.

Building blocks:

* :class:`Finding` — one diagnostic: ``(rule, path, line, message)`` plus
  the stripped source ``snippet`` that keys baseline matching (line numbers
  shift; code lines rarely do).
* :class:`AnalysisPass` — subclass per invariant family; ``rules`` maps
  rule ids (``HS001`` ...) to one-line docs, :meth:`scope` selects files,
  :meth:`run` yields findings.  Registration is a module-level list in
  ``tools.analyze`` (:data:`tools.analyze.ALL_PASSES`).
* inline suppressions — ``# analyze: ignore[HS001]`` on the flagged line
  (or a standalone comment on the line above) silences that rule there;
  ``# analyze: ignore`` silences every rule.  Suppressions are for
  *explained* exceptions: the comment should say why the invariant does
  not apply.
* a committed baseline (``tools/analyze/baseline.json``) grandfathers
  pre-existing findings by ``(rule, path, snippet)`` multiset.  ``--check``
  fails on findings not in the baseline AND on stale baseline entries, so
  the baseline can only shrink unless deliberately regenerated with
  ``--baseline``.

Run from the repo root::

    PYTHONPATH=src python -m tools.analyze --check src tools benchmarks
"""
from __future__ import annotations

import ast
import json
import re
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent.parent
BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"

_SUPPRESS_RE = re.compile(
    r"#\s*analyze:\s*ignore(?:\[(?P<rules>[A-Z]{2}\d{3}"
    r"(?:\s*,\s*[A-Z]{2}\d{3})*)\])?")


@dataclass(frozen=True)
class Finding:
    """One diagnostic. ``snippet`` is the stripped source line — the
    line-number-independent identity used for baseline matching."""
    rule: str
    path: str          # repo-relative, posix separators
    line: int
    message: str
    snippet: str = ""

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """A parsed python file shared by every pass (one parse per file)."""

    def __init__(self, path: Path, root: Path = ROOT):
        self.abspath = path
        self.path = path.resolve().relative_to(root).as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self._parents: dict[ast.AST, ast.AST] | None = None

    # -- helpers shared by passes ------------------------------------------
    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def snippet(self, lineno: int) -> str:
        return self.line_at(lineno).strip()

    def parents(self) -> dict[ast.AST, ast.AST]:
        """child -> parent map over the module tree (built lazily)."""
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def suppressed(self, lineno: int, rule: str) -> bool:
        """``# analyze: ignore[RULE]`` on the line, or as a standalone
        comment on the line above."""
        for cand in (self.line_at(lineno), ):
            m = _SUPPRESS_RE.search(cand)
            if m and (m.group("rules") is None
                      or rule in re.split(r"\s*,\s*", m.group("rules"))):
                return True
        above = self.line_at(lineno - 1).strip()
        if above.startswith("#"):
            m = _SUPPRESS_RE.search(above)
            if m and (m.group("rules") is None
                      or rule in re.split(r"\s*,\s*", m.group("rules"))):
                return True
        return False

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        lineno = (node_or_line if isinstance(node_or_line, int)
                  else node_or_line.lineno)
        return Finding(rule=rule, path=self.path, line=lineno,
                       message=message, snippet=self.snippet(lineno))


class AnalysisPass:
    """One invariant family. Subclasses set ``name``/``rules`` and
    implement :meth:`run`; :meth:`scope` narrows which files are visited."""

    name: str = "?"
    rules: dict[str, str] = {}

    def scope(self, path: str) -> bool:
        """Repo-relative posix path filter; default: every scanned file."""
        return True

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# shared AST utilities
# ---------------------------------------------------------------------------

def call_name(node: ast.Call) -> str:
    """Dotted name of a call's callee ('' when not a name/attribute)."""
    return dotted(node.func)


def dotted(node: ast.AST) -> str:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def assigned_names(target: ast.AST) -> list[str]:
    """Flat simple names bound by an assignment target (tuples unpacked)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: list[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    return []


def iter_functions(tree: ast.AST):
    """Every FunctionDef/AsyncFunctionDef in the tree (nested included)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# file collection and the runner
# ---------------------------------------------------------------------------

def collect_files(paths: list[str], root: Path = ROOT) -> list[SourceFile]:
    seen: dict[str, SourceFile] = {}
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = root / p
        candidates = ([p] if p.is_file()
                      else sorted(p.rglob("*.py")) if p.is_dir() else [])
        if not candidates:
            raise FileNotFoundError(f"analyze: no such path {raw!r}")
        for f in candidates:
            if "__pycache__" in f.parts or f.suffix != ".py":
                continue
            sf = SourceFile(f, root)
            seen.setdefault(sf.path, sf)
    return list(seen.values())


def run_passes(passes, files: list[SourceFile],
               root: Path = ROOT) -> list[Finding]:
    """All non-suppressed findings, sorted by (path, line, rule)."""
    by_path = {f.path: f for f in files}
    findings: list[Finding] = []
    for p in passes:
        scoped = [f for f in files if p.scope(f.path)]
        for fnd in p.run(scoped, root):
            src = by_path.get(fnd.path)
            if src is not None and src.suppressed(fnd.line, fnd.rule):
                continue
            findings.append(fnd)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

@dataclass
class BaselineDiff:
    new: list[Finding] = field(default_factory=list)
    #: (rule, path, snippet, surplus count) entries no current finding matches
    stale: list[tuple[str, str, str, int]] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.new and not self.stale


def load_baseline(path: Path = BASELINE_PATH) -> Counter:
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    out: Counter = Counter()
    for e in data.get("findings", []):
        out[(e["rule"], e["path"], e["snippet"])] += int(e.get("count", 1))
    return out


def save_baseline(findings: list[Finding],
                  path: Path = BASELINE_PATH) -> None:
    counts = Counter(f.key for f in findings)
    entries = [{"rule": r, "path": p, "snippet": s, "count": c}
               for (r, p, s), c in sorted(counts.items())]
    path.write_text(json.dumps(
        {"comment": "grandfathered findings; regenerate with "
                    "`python -m tools.analyze --baseline <paths>` "
                    "(shrink-only under --check)",
         "findings": entries}, indent=2) + "\n")


def diff_baseline(findings: list[Finding],
                  baseline: Counter) -> BaselineDiff:
    diff = BaselineDiff()
    remaining = Counter(baseline)
    for f in findings:
        if remaining[f.key] > 0:
            remaining[f.key] -= 1
        else:
            diff.new.append(f)
    for (rule, path, snippet), n in sorted(remaining.items()):
        if n > 0:
            diff.stale.append((rule, path, snippet, n))
    return diff
