"""lock-discipline pass: guarded access to service state (LD001/LD002).

`JoinService` runs a background micro-batch worker thread next to caller
threads that submit, mutate datasets, and read stats; `StoreCache` is the
shared warm-store table between them.  Every *shared mutable* field of a
lock-owning class must be touched under one of the class's locks, and lock
acquisition order must be consistent — the two invariants this pass checks
lexically, per class:

* a class participates when it assigns a ``threading.Lock()`` /
  ``threading.RLock()`` to ``self.<attr>`` in ``__init__``;
* *thread-entry* methods are those passed as ``target=`` to
  ``threading.Thread`` (closures count as part of their defining method);
  the worker-reachable set is their transitive ``self.f()`` call closure;
* a field is *shared mutable* when it is mutated outside ``__init__`` and
  either (a) it is accessed by a worker-reachable method, or (b) it is
  mutated in two or more distinct methods.  Fields holding inherently
  thread-safe primitives (``threading.Event`` / ``Condition`` / locks /
  queues) are exempt.

* **LD001** — a read or mutation of a shared mutable field lexically
  outside every ``with self.<lock>:`` block (``__init__`` exempt).
* **LD002** — lock-order inversion: ``with self.B:`` nested inside
  ``with self.A:`` in one method and the opposite nesting elsewhere.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .core import AnalysisPass, Finding, SourceFile, call_name

_LOCK_CTORS = ("threading.Lock", "threading.RLock", "Lock", "RLock")
_SAFE_CTORS = ("threading.Event", "threading.Condition", "Event",
               "Condition", "queue.Queue", "Queue") + _LOCK_CTORS
#: method names that mutate their receiver in place
_MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "update", "setdefault", "add", "discard", "put", "move_to_end",
    "resize", "sort", "reverse", "appendleft", "popleft",
})


def _self_attr(node: ast.AST) -> str | None:
    """'x' for an expression rooted at ``self.x``; None otherwise."""
    while isinstance(node, (ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class _MethodInfo:
    def __init__(self, node: ast.FunctionDef):
        self.node = node
        self.name = node.name
        self.reads: list[ast.Attribute] = []      # self.f loads
        self.mutations: list[ast.AST] = []        # nodes mutating self.f
        self.mutated_fields: set[str] = set()
        self.accessed_fields: set[str] = set()
        self.calls: set[str] = set()              # self.f() call targets
        self.thread_targets: set[str] = set()     # local defs passed to Thread


def _scan_method(m: _MethodInfo) -> None:
    fn = m.node
    local_defs = {n.name for n in ast.walk(fn)
                  if isinstance(n, ast.FunctionDef) and n is not fn}
    for node in ast.walk(fn):
        # Thread(target=...) — the entry point of a worker thread
        if isinstance(node, ast.Call) and \
                call_name(node).split(".")[-1] == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    tgt = kw.value
                    if isinstance(tgt, ast.Name) and tgt.id in local_defs:
                        m.thread_targets.add(m.name)     # closure: this method
                    elif (attr := _self_attr(tgt)) is not None:
                        m.thread_targets.add(attr)
        # self.f(...) call graph edges
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "self":
            m.calls.add(node.func.attr)
        # mutations: self.f = / self.f op= / self.f[k] = / del self.f[k]
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                f = _self_attr(t)
                if f is not None:
                    m.mutations.append(t)
                    m.mutated_fields.add(f)
        if isinstance(node, ast.Delete):
            for t in node.targets:
                f = _self_attr(t)
                if f is not None:
                    m.mutations.append(t)
                    m.mutated_fields.add(f)
        # mutator method calls: self.f.append(...)
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            f = _self_attr(node.func.value)
            if f is not None:
                m.mutations.append(node)
                m.mutated_fields.add(f)
        # every self.f access
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            m.reads.append(node)
            m.accessed_fields.add(node.attr)


def _init_field_ctors(cls: ast.ClassDef) -> dict[str, str]:
    """field -> ctor dotted name for ``self.x = <ctor>()`` in __init__."""
    out: dict[str, str] = {}
    for fn in cls.body:
        if isinstance(fn, ast.FunctionDef) and fn.name == "__init__":
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call):
                    for t in node.targets:
                        f = _self_attr(t)
                        if f is not None:
                            out[f] = call_name(node.value)
    return out


def _with_lock_stack(node: ast.AST, parents: dict, locks: set[str]
                     ) -> list[str]:
    """Lock attrs held (innermost last) at ``node`` by lexical With blocks."""
    stack: list[str] = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, ast.With):
            for item in cur.items:
                f = _self_attr(item.context_expr)
                if f in locks:
                    stack.append(f)
        cur = parents.get(cur)
    return list(reversed(stack))


class LockDisciplinePass(AnalysisPass):
    name = "lock-discipline"
    rules = {
        "LD001": "shared mutable field of a lock-owning class accessed "
                 "outside every `with self.<lock>:` block",
        "LD002": "lock-order inversion between two locks of one class",
    }

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        out: list[Finding] = []
        for src in files:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.ClassDef):
                    out.extend(self._check_class(src, node))
        return out

    def _check_class(self, src: SourceFile,
                     cls: ast.ClassDef) -> list[Finding]:
        ctors = _init_field_ctors(cls)
        locks = {f for f, c in ctors.items() if c in _LOCK_CTORS}
        if not locks:
            return []
        safe = {f for f, c in ctors.items() if c in _SAFE_CTORS}

        methods: dict[str, _MethodInfo] = {}
        for fn in cls.body:
            if isinstance(fn, ast.FunctionDef):
                m = _MethodInfo(fn)
                _scan_method(m)
                methods[fn.name] = m

        # worker-reachable set: transitive self-call closure of thread entries
        entries: set[str] = set()
        for m in methods.values():
            entries |= m.thread_targets & set(methods)
        worker: set[str] = set(entries)
        frontier = list(entries)
        while frontier:
            for callee in methods[frontier.pop()].calls:
                if callee in methods and callee not in worker:
                    worker.add(callee)
                    frontier.append(callee)

        mutated_by: dict[str, set[str]] = {}
        accessed_in_worker: set[str] = set()
        for m in methods.values():
            if m.name == "__init__":
                continue
            for f in m.mutated_fields:
                mutated_by.setdefault(f, set()).add(m.name)
            if m.name in worker:
                accessed_in_worker |= m.accessed_fields

        # exclude method names: `self._handle(k).insert(...)` mutates the
        # *returned* object, not a field named `_handle`
        shared = {
            f for f, muts in mutated_by.items()
            if f not in safe and f not in locks and f not in methods
            and (f in accessed_in_worker or len(muts) >= 2)
        }
        if not shared:
            return []

        parents = src.parents()
        out: list[Finding] = []
        seen_lines: set[tuple[str, int]] = set()
        for m in methods.values():
            if m.name == "__init__":
                continue
            for node in m.reads:
                f = node.attr
                if f not in shared:
                    continue
                if _with_lock_stack(node, parents, locks):
                    continue
                key = (f, node.lineno)
                if key in seen_lines:
                    continue
                seen_lines.add(key)
                role = ("worker-reachable " if m.name in worker else "")
                out.append(src.finding(
                    "LD001", node,
                    f"{cls.name}.{m.name}: access to shared field "
                    f"`{f}` outside every lock of "
                    f"{sorted(locks)} ({role}method; field is mutated in "
                    f"{sorted(mutated_by.get(f, ()))})"))

        # LD002: lock-order inversion over lexical nesting
        order_sites: dict[tuple[str, str], ast.AST] = {}
        for m in methods.values():
            for node in ast.walk(m.node):
                if not isinstance(node, ast.With):
                    continue
                inner = {f for item in node.items
                         if (f := _self_attr(item.context_expr)) in locks}
                if not inner:
                    continue
                outer = _with_lock_stack(node, parents, locks)
                for o in outer:
                    for i in inner:
                        if o != i:
                            order_sites.setdefault((o, i), node)
        for (a, b), node in sorted(order_sites.items()):
            if (b, a) in order_sites and a < b:
                other = order_sites[(b, a)]
                out.append(src.finding(
                    "LD002", node,
                    f"{cls.name}: lock order inversion — `{a}` then `{b}` "
                    f"here, but `{b}` then `{a}` at line {other.lineno}"))
        return out
