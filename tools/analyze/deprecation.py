"""deprecation pass: no new uses of deprecated kwargs in-repo (DP001).

`JoinPlan(backend=...)` has been a `DeprecationWarning`-emitting alias of
``filter_backend`` since PR 6 and is scheduled for removal after
**2026-12-01** (see ``spatial/plan.py``); ``use_jnp=`` on the pipeline
shims is the same vintage.  Warnings only fire at runtime on exercised
paths — this rule keeps new *in-repo* call sites from accumulating while
the alias ages out.

* **DP001** — a call passes a deprecated kwarg listed in
  :data:`DEPRECATED_KWARGS` (callee matched by trailing name, so
  ``spatial.JoinPlan(...)`` and ``JoinPlan(...)`` both match).
"""
from __future__ import annotations

import ast
from pathlib import Path

from .core import AnalysisPass, Finding, SourceFile, call_name

#: (callee trailing name, kwarg) -> replacement note.  Removal dates live
#: in the deprecation warnings at the definition sites.
DEPRECATED_KWARGS: dict[tuple[str, str], str] = {
    ("JoinPlan", "backend"):
        "pass filter_backend= (alias removed after 2026-12-01)",
    ("spatial_intersection_join", "use_jnp"):
        "pass filter_backend='jnp' (legacy switch, removed with the shims)",
}


class DeprecationPass(AnalysisPass):
    name = "deprecation"
    rules = {
        "DP001": "call site uses a deprecated kwarg",
    }

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        out: list[Finding] = []
        for src in files:
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.Call):
                    continue
                callee = call_name(node).split(".")[-1]
                for kw in node.keywords:
                    note = DEPRECATED_KWARGS.get((callee, kw.arg))
                    if note is not None:
                        out.append(src.finding(
                            "DP001", node,
                            f"deprecated kwarg `{kw.arg}=` on "
                            f"`{callee}(...)`: {note}"))
        return out
