"""`tools.analyze`: the repo-invariant static-analysis suite.

See DESIGN.md §11 for the rule catalog and suppression policy.  CLI::

    PYTHONPATH=src python -m tools.analyze --check src tools benchmarks

Passes (rule prefixes): host-sync (HS), precision (FP), lock-discipline
(LD), backend-parity (BE), pallas-constraint (PL), deprecation (DP).
"""
from __future__ import annotations

from .backend_parity import BackendParityPass
from .core import (BASELINE_PATH, ROOT, AnalysisPass, BaselineDiff, Finding,
                   SourceFile, collect_files, diff_baseline, load_baseline,
                   run_passes, save_baseline)
from .deprecation import DeprecationPass
from .host_sync import HostSyncPass
from .lock_discipline import LockDisciplinePass
from .pallas_constraint import PallasConstraintPass
from .precision import PrecisionPass

__all__ = [
    "ALL_PASSES", "AnalysisPass", "BaselineDiff", "Finding", "SourceFile",
    "BASELINE_PATH", "ROOT", "collect_files", "diff_baseline",
    "load_baseline", "run_passes", "save_baseline", "all_rules",
]

#: registration order == report order; add new passes here
ALL_PASSES: tuple[AnalysisPass, ...] = (
    HostSyncPass(),
    PrecisionPass(),
    LockDisciplinePass(),
    BackendParityPass(),
    PallasConstraintPass(),
    DeprecationPass(),
)


def all_rules() -> dict[str, str]:
    out: dict[str, str] = {}
    for p in ALL_PASSES:
        out.update(p.rules)
    return out
