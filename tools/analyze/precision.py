"""precision pass: f32/FMA sign-safety invariants (rules FP001/FP002).

PR 3's fuzz-found regression: XLA contracts mul+add chains into FMAs below
the HLO level, so a near-zero orientation sign computed on device can
disagree with strict-IEEE numpy — and ``optimization_barrier`` cannot stop
it.  The repo-wide idiom is a *guard band*: every device sign test carries
an eps/tol band and borderline pairs escalate to the host oracle
(``spatial/refine.py``, ``kernels/refine``).  This pass flags device sign
tests that skip the idiom:

* **FP001** — in a jnp-using function, a sign comparison (``> 0`` /
  ``< 0`` / ``>= 0`` / ``<= 0``) of an orientation-style value (a local
  assigned from the cross-product idiom ``a*b - c*d``, directly or through
  a local helper returning one) in a function with no guard-band
  machinery (no ``eps`` / ``tol`` / ``guard`` / ``unc`` name in scope).
* **FP002** — ``jax.config.update("jax_enable_x64", ...)`` in library
  code: a process-global precision flip reachable from f32 paths (the
  pallas kernels run f32 by contract).  Use the scoped
  ``jax.experimental.enable_x64`` context manager instead.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .core import (AnalysisPass, Finding, SourceFile, assigned_names,
                   call_name, iter_functions)

_GUARD_HINTS = ("eps", "tol", "guard", "unc", "borderline")


def _is_mul_sub(node: ast.AST) -> bool:
    """The cross-product / orientation idiom: ``<mult> - <mult>``."""
    return (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub)
            and isinstance(node.left, ast.BinOp)
            and isinstance(node.left.op, ast.Mult)
            and isinstance(node.right, ast.BinOp)
            and isinstance(node.right.op, ast.Mult))


def _uses_jnp(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id == "jnp":
            return True
    return False


def _has_guard(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        elif isinstance(node, ast.arg):
            name = node.arg
        if name and any(h in name.lower() for h in _GUARD_HINTS):
            return True
    return False


def _orientation_names(fn: ast.AST) -> set[str]:
    """Locals assigned from a mul-sub expression, or from a call to a
    local helper whose body returns a mul-sub (the ``orient()`` idiom)."""
    helpers: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.FunctionDef):
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Return) and stmt.value is not None \
                        and _is_mul_sub(stmt.value):
                    helpers.add(node.name)
    names: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            v = node.value
            if _is_mul_sub(v) or (isinstance(v, ast.Call)
                                  and call_name(v) in helpers):
                for t in node.targets:
                    names.update(assigned_names(t))
    return names


class PrecisionPass(AnalysisPass):
    name = "precision"
    rules = {
        "FP001": "device sign test on an orientation value without the "
                 "guard-band idiom (FMA contraction can flip near-zero "
                 "signs vs strict IEEE)",
        "FP002": "process-global jax_enable_x64 flip in library code; use "
                 "the scoped enable_x64() context manager",
    }

    _SCOPE = ("src/repro/spatial/", "src/repro/core/", "src/repro/kernels/")

    def scope(self, path: str) -> bool:
        return path.startswith(self._SCOPE)

    def run(self, files: list[SourceFile], root: Path) -> list[Finding]:
        out: list[Finding] = []
        for src in files:
            out.extend(self._fp001(src))
            out.extend(self._fp002(src))
        return out

    def _fp001(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        for fn in iter_functions(src.tree):
            if not _uses_jnp(fn) or _has_guard(fn):
                continue
            orient = _orientation_names(fn)
            if not orient:
                continue
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Compare)
                        and len(node.ops) == 1
                        and isinstance(node.ops[0],
                                       (ast.Gt, ast.Lt, ast.GtE, ast.LtE))):
                    continue
                left, right = node.left, node.comparators[0]
                zero_cmp = (isinstance(right, ast.Constant)
                            and right.value == 0)
                if zero_cmp and isinstance(left, ast.Name) \
                        and left.id in orient:
                    out.append(src.finding(
                        "FP001", node,
                        f"sign test on orientation value `{left.id}` with "
                        f"no guard band in `{fn.name}`: FMA contraction "
                        f"can flip near-zero signs; use the eps-band + "
                        f"host-escalation idiom (spatial/refine.py)"))
        return out

    def _fp002(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not name.endswith("config.update"):
                continue
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and node.args[0].value == "jax_enable_x64":
                out.append(src.finding(
                    "FP002", node,
                    "process-global jax_enable_x64 update in library code "
                    "changes precision for every caller (including f32 "
                    "pallas paths); scope it with "
                    "`with jax.experimental.enable_x64():`"))
        return out
