"""CI bench-regression gate: committed BENCH_*.json artifacts must be sane.

Every ``BENCH_*.json`` at the repo root is a benchmark acceptance artifact
(filter / construction / refinement / MBR join). This gate keeps a PR from
committing one that records a regression or a broken backend:

* the file must parse as a JSON object and contain at least one ``speedup``
  leaf (schema presence — an empty or truncated artifact fails);
* every identity flag (``verdicts_equal`` / ``pair_sets_equal`` /
  ``stores_equal``) must be ``true`` — a backend that diverges from its
  sequential reference cannot ship behind a green bench file;
* every ``speedup*`` leaf must be >= 1.0 — "batched" may never be slower
  than the sequential reference it replaced.

Run from the repo root: ``python tools/check_bench.py`` (no repo imports —
the gate also runs before the package installs).
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
IDENTITY_FLAGS = ("verdicts_equal", "pair_sets_equal", "stores_equal")
MIN_SPEEDUP = 1.0


def _walk(node, path=""):
    """Yield (dotted-path, key, value) for every leaf of a JSON tree."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, f"{path}.{k}" if path else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, f"{path}[{i}]")
    else:
        key = path.rsplit(".", 1)[-1]
        yield path, key, node


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable bench artifact ({e})"]
    if not isinstance(data, dict):
        return [f"{path.name}: top level must be a JSON object"]
    n_speedups = 0
    for dotted, key, value in _walk(data):
        if key in IDENTITY_FLAGS:
            if value is not True:
                errors.append(f"{path.name}: {dotted} is {value!r}, "
                              "expected true")
        elif key.startswith("speedup"):
            n_speedups += 1
            if not isinstance(value, (int, float)) or value < MIN_SPEEDUP:
                errors.append(f"{path.name}: {dotted} = {value!r} "
                              f"(regression: every speedup must be "
                              f">= {MIN_SPEEDUP})")
    if n_speedups == 0:
        errors.append(f"{path.name}: no speedup field found — schema "
                      "missing or artifact truncated")
    return errors


def main(argv: list[str] | None = None) -> int:
    paths = [pathlib.Path(p) for p in (argv or [])] \
        or sorted(ROOT.glob("BENCH_*.json"))
    if not paths:
        print("bench gate FAILED: no BENCH_*.json artifacts found")
        return 1
    errors = []
    for p in paths:
        errors += check_file(p)
    if errors:
        print("bench gate FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"bench gate ok: {len(paths)} artifacts "
          f"({', '.join(p.name for p in paths)}) — all identity flags true, "
          f"all speedups >= {MIN_SPEEDUP}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
