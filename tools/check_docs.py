"""DEPRECATED shim: the docs-consistency check now lives in the
backend-parity pass of the static-analysis suite (rules BE002/BE003 —
see ``tools/analyze/backend_parity.py`` and DESIGN.md §11).

This entry point is kept so existing invocations keep working; it runs
only the absorbed knob checks.  Prefer the full gate::

    PYTHONPATH=src python -m tools.analyze --check src tools benchmarks
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from tools.analyze.backend_parity import (  # noqa: E402
    BackendParityPass, collect_knobs)


def main() -> int:
    findings = BackendParityPass()._be002_003(ROOT)
    if findings:
        print("docs-consistency check FAILED "
              "(run `python -m tools.analyze` for the full gate):")
        for f in findings:
            print(f"  {f.render()}")
        return 1
    print(f"docs-consistency ok: {collect_knobs(ROOT)} documented and "
          f"threaded (absorbed into tools.analyze rules BE002/BE003)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
