"""CI docs-consistency check: the backend-knob surface must be documented.

Two knob sources are scanned:

* every ``*backend`` kwarg accepted by ``JoinPlan.__init__`` (plus
  ``build_backend``, which travels through ``build_opts`` to every
  filter's ``build``);
* every ``--*-backend`` flag exposed by the launchers
  (``repro.launch.spatial_join`` and ``repro.launch.serve_join``) — flags
  normalize to knob names (``--filter-backend`` -> ``filter_backend``), so
  a launcher-only surface cannot ship undocumented either.

Each knob must appear, as a whole word, in both README.md and DESIGN.md —
so a new stage backend cannot ship without landing in the "Pipeline stages
& backends" table and its DESIGN section.

Run from the repo root: ``PYTHONPATH=src python tools/check_docs.py``
"""
from __future__ import annotations

import inspect
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.spatial import JoinPlan  # noqa: E402
DOCS = ("README.md", "DESIGN.md")
# build_backend is accepted by every IntermediateFilter.build (via the
# JoinPlan build_opts dict), not as a named JoinPlan kwarg
EXTRA_KNOBS = ("build_backend",)
LAUNCHERS = (
    ROOT / "src" / "repro" / "launch" / "spatial_join.py",
    ROOT / "src" / "repro" / "launch" / "serve_join.py",
)


def plan_knobs() -> list[str]:
    params = inspect.signature(JoinPlan.__init__).parameters
    return [p for p in params if p.endswith("backend")]


def launcher_knobs() -> list[str]:
    """Knob names behind the launchers' ``--*-backend`` argparse flags."""
    knobs: list[str] = []
    for launcher in LAUNCHERS:
        text = launcher.read_text()
        flags = re.findall(
            r'add_argument\(\s*"(--[a-z][a-z-]*backend)"', text)
        for f in flags:
            knob = f.lstrip("-").replace("-", "_")
            if knob not in knobs:
                knobs.append(knob)
    return knobs


def backend_knobs() -> list[str]:
    knobs = plan_knobs() + list(EXTRA_KNOBS)
    knobs += [k for k in launcher_knobs() if k not in knobs]
    return knobs


def main() -> int:
    missing = []
    texts = {doc: (ROOT / doc).read_text() for doc in DOCS}
    for knob in backend_knobs():
        for doc, text in texts.items():
            if not re.search(rf"\b{re.escape(knob)}\b", text):
                missing.append(f"{doc}: missing `{knob}`")
    if missing:
        print("docs-consistency check FAILED:")
        for m in missing:
            print(f"  {m}")
        return 1
    print(f"docs-consistency ok: {backend_knobs()} documented in "
          f"{' and '.join(DOCS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
