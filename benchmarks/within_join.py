"""Table 16: spatial within joins (r within s) — every registered filter,
through `JoinPlan` (the within predicate is no longer APRIL-only)."""
from __future__ import annotations

from repro.spatial import JoinPlan

from .common import ds, row


def run():
    out = []
    for pair in (("T2", "T10"), ("T1", "T3"), ("T2", "T3")):
        R, S = ds(pair[0]), ds(pair[1])
        for m in ("none", "april", "ri"):
            plan = JoinPlan(R, S, filter=m, n_order=9)
            _, st = plan.build().execute("within")
            h, g, i = st.rates()
            out.append(row(
                f"table16_{pair[0]}in{pair[1]}_{m}", st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                f"refine_s={st.t_refine:.3f};total_s={st.t_total:.3f}"))
    return out
