"""Table 17: polygon x linestring intersection joins."""
from __future__ import annotations

from repro.spatial import polygon_linestring_join

from .common import ds, lines, row


def run():
    out = []
    L = lines()
    for name in ("T1", "T2", "T3"):
        S = ds(name)
        for m in ("none", "april"):
            _, st = polygon_linestring_join(S, L, method=m, n_order=9)
            h, g, i = st.rates()
            out.append(row(
                f"table17_{name}xT8_{m}", st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                f"refine_s={st.t_refine:.3f};total_s={st.t_total:.3f}"))
    return out
