"""Table 7: AA/AF/FA join-order effect on the APRIL filter."""
from __future__ import annotations

from repro.core.april import build_april
from repro.spatial import spatial_intersection_join

from .common import ds, row


def run():
    out = []
    for pair in (("T1", "T2"), ("T1", "T3")):
        R, S = ds(pair[0]), ds(pair[1])
        pre = (build_april(R, 9), build_april(S, 9))
        for order in (("AA", "AF", "FA"), ("AA", "FA", "AF"),
                      ("AF", "FA", "AA"), ("FA", "AF", "AA")):
            _, st = spatial_intersection_join(
                R, S, method="april", n_order=9, order=order, prebuilt=pre)
            h, g, i = st.rates()
            out.append(row(
                f"table7_{pair[0]}x{pair[1]}_{'-'.join(order)}",
                st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f}"))
    return out
