"""Table 7: AA/AF/FA join-order effect on the APRIL filter.

Uses one `JoinPlan` per dataset pair: the approximations are built once and
reused across the four join orders (the session API's build/execute split).
"""
from __future__ import annotations

from repro.spatial import JoinPlan

from .common import ds, row


def run():
    out = []
    for pair in (("T1", "T2"), ("T1", "T3")):
        R, S = ds(pair[0]), ds(pair[1])
        plan = JoinPlan(R, S, filter="april", n_order=9)
        plan.build()
        for order in (("AA", "AF", "FA"), ("AA", "FA", "AF"),
                      ("AF", "FA", "AA"), ("FA", "AF", "AA")):
            plan.filter_opts["order"] = order
            _, st = plan.execute("intersects")
            h, g, i = st.rates()
            out.append(row(
                f"table7_{pair[0]}x{pair[1]}_{'-'.join(order)}",
                st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f}"))
    return out
