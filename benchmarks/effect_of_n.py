"""Table 5/6: effect of grid order N on RI and APRIL (T1 x T2)."""
from __future__ import annotations

from repro.core.april import build_april
from repro.core.ri import build_ri
from repro.spatial import spatial_intersection_join

from .common import ds, row, timeit


def run():
    R, S = ds("T1"), ds("T2")
    out = []
    for n in (6, 7, 8, 9, 10):
        april_r, tb_a = timeit(build_april, R, n)
        april_s, _ = timeit(build_april, S, n)
        _, st = spatial_intersection_join(R, S, method="april", n_order=n,
                                          prebuilt=(april_r, april_s))
        h, g, i = st.rates()
        out.append(row(
            f"table5_april_N{n}", st.t_filter * 1e6,
            f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
            f"refine_s={st.t_refine:.3f};total_s={st.t_total:.3f};"
            f"build_s={tb_a:.2f};size_B={april_r.size_bytes() + april_s.size_bytes()}"))
    # RI at the reference order (construction is the expensive path)
    for n in (6, 7, 8):
        ri_r, tb_r = timeit(build_ri, R, n, encoding="R")
        ri_s, _ = timeit(build_ri, S, n, encoding="S")
        _, st = spatial_intersection_join(R, S, method="ri", n_order=n,
                                          prebuilt=(ri_r, ri_s))
        h, g, i = st.rates()
        out.append(row(
            f"table5_ri_N{n}", st.t_filter * 1e6,
            f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
            f"refine_s={st.t_refine:.3f};build_s={tb_r:.2f};"
            f"size_B={ri_r.size_bytes() + ri_s.size_bytes()}"))
    return out
