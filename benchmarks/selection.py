"""Table 15: polygonal selection (range) queries — T3 polygons as queries
against T1/T2, APRIL vs RI."""
from __future__ import annotations

from repro.core.april import build_april
from repro.core.ri import build_ri
from repro.datagen import make_dataset
from repro.spatial import selection_queries

from .common import ds, row


def run():
    out = []
    queries = make_dataset("T3", seed=7, count=12)
    for name in ("T1", "T2"):
        data = ds(name)
        pre = build_april(data, 9)
        _, st = selection_queries(data, queries, method="april", n_order=9,
                                  prebuilt=pre)
        h, g, i = st.rates()
        out.append(row(f"table15_{name}_april", st.t_filter * 1e6,
                       f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                       f"total_s={st.t_total:.3f}"))
        _, st_none = selection_queries(data, queries, method="none")
        out.append(row(f"table15_{name}_none", st_none.t_filter * 1e6,
                       f"refine_s={st_none.t_refine:.3f};"
                       f"total_s={st_none.t_total:.3f}"))
    return out
