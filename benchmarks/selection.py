"""Table 15: polygonal selection (range) queries — T3 polygons as queries
against T1/T2 through `JoinPlan`, APRIL vs RI vs none. Approximations are
built once per dataset and reused (the session API's build/execute split)."""
from __future__ import annotations

from repro.datagen import make_dataset
from repro.spatial import JoinPlan

from .common import ds, row


def run():
    out = []
    queries = make_dataset("T3", seed=7, count=12)
    for name in ("T1", "T2"):
        data = ds(name)
        for m in ("april", "ri", "none"):
            plan = JoinPlan(data, queries, filter=m, n_order=9)
            _, st = plan.build().execute("selection")
            h, g, i = st.rates()
            out.append(row(f"table15_{name}_{m}", st.t_filter * 1e6,
                           f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                           f"total_s={st.t_total:.3f}"))
    return out
