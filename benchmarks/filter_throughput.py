"""BENCH_filter: per-method intermediate-filter throughput (pairs/s),
sequential per-pair reference vs batched `verdicts`, on one >=10k-candidate
MBR batch. Seeds the perf trajectory for the batched filter redesign;
`benchmarks/run.py` persists the result as BENCH_filter.json.
"""
from __future__ import annotations

import json
import time

from repro.datagen import make_dataset
from repro.spatial import get_filter
from repro.spatial.mbr_join import mbr_join

from .common import row

N_ORDER = 10
METHODS = ("none", "april", "april-c", "ri", "ra", "5cch")


def bench_filters(min_pairs: int = 10_000):
    R = make_dataset("T1", seed=1, count=800)
    S = make_dataset("T2", seed=2, count=1600)
    pairs = mbr_join(R.mbrs, S.mbrs)
    assert len(pairs) >= min_pairs, len(pairs)
    out = {"dataset": "T1 x T2", "n_pairs": int(len(pairs)),
           "n_order": N_ORDER, "methods": {}}
    for m in METHODS:
        filt = get_filter(m)
        build_opts = {"max_cells": 256} if m == "ra" else {}
        t0 = time.perf_counter()
        ar = filt.build(R, n_order=N_ORDER, side="r", **build_opts)
        as_ = filt.build(S, n_order=N_ORDER, side="s", **build_opts)
        t_build = time.perf_counter() - t0

        t0 = time.perf_counter()
        v_seq = filt.verdicts_seq(ar, as_, pairs)
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        v_bat = filt.verdicts(ar, as_, pairs)
        t_bat = time.perf_counter() - t0
        assert (v_seq == v_bat).all(), f"{m}: batched verdicts diverged"

        out["methods"][m] = {
            "t_build_s": round(t_build, 4),
            "t_seq_s": round(t_seq, 4),
            "t_batch_s": round(t_bat, 6),
            "seq_pairs_per_s": round(len(pairs) / max(t_seq, 1e-9), 1),
            "batch_pairs_per_s": round(len(pairs) / max(t_bat, 1e-9), 1),
            "speedup": round(t_seq / max(t_bat, 1e-9), 2),
        }
    return out


def run():
    res = bench_filters()
    with open("BENCH_filter.json", "w") as f:
        json.dump(res, f, indent=2)
    out = []
    for m, r in res["methods"].items():
        out.append(row(
            f"filter_throughput_{m}",
            1e6 * r["t_batch_s"] / max(res["n_pairs"], 1),
            f"pairs={res['n_pairs']};seq_pairs_per_s={r['seq_pairs_per_s']};"
            f"batch_pairs_per_s={r['batch_pairs_per_s']};"
            f"speedup={r['speedup']}"))
    return out
