"""BENCH_filter: per-method intermediate-filter throughput (pairs/s),
sequential per-pair reference (``filter_backend='sequential'``) vs the
bucketed batched ``verdicts`` path (DESIGN.md §9), on one >=10k-candidate
MBR batch. The ISSUE-5 acceptance gate: >= 5x batched-over-sequential for
APRIL, APRIL-C and RA with ``verdicts_equal`` true for every method;
`benchmarks/run.py` persists the result as BENCH_filter.json and
``tools/check_bench.py`` guards the committed artifact in CI.

Batched timing is *warm*: the first call per method (untimed) populates the
Approximation's device-resident interval-list / pyramid caches, which by
design survive across ``JoinPlan`` executions; the cold first-call time is
reported alongside.

``python -m benchmarks.filter_throughput --smoke`` runs a tiny
verdict-identity sweep — every method x every filter backend against the
sequential trichotomy — as the CI quick-lane smoke.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.datagen import make_dataset
from repro.spatial import get_filter
from repro.spatial.mbr_join import mbr_join

from .common import row, sync

N_ORDER = 10
METHODS = ("none", "april", "april-c", "ri", "ra", "5cch")
#: batched backends exercised by the smoke lane (pallas runs in interpret
#: mode off-TPU: correctness-faithful, so the slice stays small)
SMOKE_BACKENDS = ("numpy", "jnp", "pallas")
SMOKE_PAIR_CAP = 200


def _built(filt, R, S, n_order):
    build_opts = {"max_cells": 256} if filt.name == "ra" else {}
    ar = filt.build(R, n_order=n_order, side="r", **build_opts)
    as_ = filt.build(S, n_order=n_order, side="s", **build_opts)
    return ar, as_


def bench_filters(min_pairs: int = 10_000):
    R = make_dataset("T1", seed=1, count=800)
    S = make_dataset("T2", seed=2, count=1600)
    pairs = mbr_join(R.mbrs, S.mbrs)
    assert len(pairs) >= min_pairs, len(pairs)
    out = {"dataset": "T1 x T2", "n_pairs": int(len(pairs)),
           "n_order": N_ORDER, "methods": {}}
    for m in METHODS:
        filt = get_filter(m)
        t0 = time.perf_counter()
        ar, as_ = _built(filt, R, S, N_ORDER)
        sync((ar.store, as_.store))
        t_build = time.perf_counter() - t0

        t0 = time.perf_counter()
        v_seq = sync(filt.verdicts(ar, as_, pairs, backend="sequential"))
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        v_cold = sync(filt.verdicts(ar, as_, pairs))  # populates caches
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        v_bat = sync(filt.verdicts(ar, as_, pairs))
        t_bat = time.perf_counter() - t0
        equal = bool((v_seq == v_bat).all() and (v_seq == v_cold).all())
        assert equal, f"{m}: batched verdicts diverged"

        out["methods"][m] = {
            "t_build_s": round(t_build, 4),
            "t_seq_s": round(t_seq, 4),
            "t_batch_s": round(t_bat, 6),
            "t_batch_cold_s": round(t_cold, 6),
            "seq_pairs_per_s": round(len(pairs) / max(t_seq, 1e-9), 1),
            "batch_pairs_per_s": round(len(pairs) / max(t_bat, 1e-9), 1),
            "speedup": round(t_seq / max(t_bat, 1e-9), 2),
            "verdicts_equal": equal,
        }
    return out


def smoke() -> None:
    """CI quick-lane smoke: every method x every backend must equal the
    sequential trichotomy on a small T1 x T2 slice, for every predicate
    with a polygon x polygon reading (intersects / within / selection)."""
    R = make_dataset("T1", seed=91, count=40)
    S = make_dataset("T2", seed=92, count=60)
    pairs = mbr_join(R.mbrs, S.mbrs)[:SMOKE_PAIR_CAP]
    assert len(pairs) > 10, "smoke fixture must produce candidates"
    for m in METHODS:
        filt = get_filter(m)
        ar, as_ = _built(filt, R, S, 6)
        for predicate in ("intersects", "within", "selection"):
            ref = filt.verdicts(ar, as_, pairs, predicate=predicate,
                                backend="sequential")
            for backend in SMOKE_BACKENDS:
                got = filt.verdicts(ar, as_, pairs, predicate=predicate,
                                    backend=backend)
                assert np.array_equal(ref, got), (m, predicate, backend)
        print(f"filter smoke ok: {m}")


def run():
    res = bench_filters()
    with open("BENCH_filter.json", "w") as f:
        json.dump(res, f, indent=2)
    out = []
    for m, r in res["methods"].items():
        out.append(row(
            f"filter_throughput_{m}",
            1e6 * r["t_batch_s"] / max(res["n_pairs"], 1),
            f"pairs={res['n_pairs']};seq_pairs_per_s={r['seq_pairs_per_s']};"
            f"batch_pairs_per_s={r['batch_pairs_per_s']};"
            f"speedup={r['speedup']}"))
    return out


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
