"""Fig. 13: comparative study — None / 5C+CH / RA / RI / APRIL / APRIL-C
filter effectiveness, filter cost and end-to-end join cost, all through the
`JoinPlan` session API (one batched verdicts pass per method).

Grid order 10 keeps the polygon-diameter / cell-size ratio close to the
paper's N=16 regime (see benchmarks/common.py): at coarser grids Strong-
Strong cells dominate and RI's extra hit detection is overstated."""
from __future__ import annotations

from repro.spatial import JoinPlan

from .common import ds, row


def run():
    out = []
    for pair in (("T1", "T2"), ("O5", "O6")):
        R, S = ds(pair[0]), ds(pair[1])
        for m in ("none", "5cch", "ra", "ri", "april", "april-c"):
            plan = JoinPlan(R, S, filter=m, n_order=10,
                            build_opts={"max_cells": 256} if m == "ra" else None)
            _, st = plan.build().execute("intersects")
            h, g, i = st.rates()
            out.append(row(
                f"fig13_{pair[0]}x{pair[1]}_{m}", st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                f"filter_s={st.t_filter:.4f};refine_s={st.t_refine:.3f};"
                f"total_s={st.t_total:.3f};approx_B={st.approx_bytes}"))
    return out
