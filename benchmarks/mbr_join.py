"""BENCH_mbr: candidate-generation throughput, sequential vs batched.

The MBR filter (paper §2) was the pipeline's last per-object interpreted
hot path — DESIGN.md §8 makes it a batched partitioned grid-hash join.
This benchmark times the per-object/per-bucket ``sequential`` reference
against the batched ``numpy`` / ``jnp`` backends on T1 x T2-scale MBR sets
(both the adaptive grid and the legacy fixed grid=32), asserts the
backends emit identical pair sets, and persists ``BENCH_mbr.json``. The
ISSUE-4 acceptance gate: >= 5x batched-over-sequential at T1 x T2 scale.

``python -m benchmarks.mbr_join --smoke`` runs a tiny all-backends
pair-set identity check, including the translated/scaled extent
regression (the CI quick-lane smoke).
"""
from __future__ import annotations

import json

import numpy as np

from repro.datagen import make_dataset
from repro.spatial.distributed import distributed_mbr_join
from repro.spatial.mbr_join import (MBR_BACKENDS, adaptive_grid,
                                    mbr_intersect_mask, mbr_join)

from .common import ds, row, timeit

REPEATS = 5


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).tolist()))


def bench_mbr_join() -> dict:
    R, S = ds("T1"), ds("T2")
    mr, ms = R.mbrs, S.mbrs
    out = {"datasets": "T1xT2 (bench scale)", "n_r": len(mr), "n_s": len(ms),
           "adaptive_grid": adaptive_grid(mr, ms), "grids": {}}
    oracle = _pairs_set(np.stack(np.nonzero(mbr_intersect_mask(mr, ms)),
                                 axis=1))
    for label, grid in (("adaptive", None), ("fixed32", 32)):
        res = {}
        sets = {}
        for backend in MBR_BACKENDS:
            if backend == "jnp":   # warm the jit cache on the timed shapes
                mbr_join(mr, ms, grid=grid, backend=backend)
            pairs, t = timeit(mbr_join, mr, ms, grid=grid, backend=backend,
                              repeats=REPEATS)
            sets[backend] = _pairs_set(pairs)
            res[f"t_{backend}_s"] = round(t, 5)
        assert all(s == oracle for s in sets.values()), "pair-set mismatch"
        n = len(oracle)
        rate_seq = n / max(res["t_sequential_s"], 1e-9)
        rate_np = n / max(res["t_numpy_s"], 1e-9)
        res.update({
            "n_pairs": n,
            "pairs_per_s_seq": round(rate_seq, 1),
            "pairs_per_s_numpy": round(rate_np, 1),
            "speedup_numpy": round(res["t_sequential_s"]
                                   / max(res["t_numpy_s"], 1e-9), 2),
            "speedup_jnp": round(res["t_sequential_s"]
                                 / max(res["t_jnp_s"], 1e-9), 2),
            "pair_sets_equal": True,
        })
        out["grids"][label] = res
    return out


def smoke() -> None:
    """CI quick lane: tiny pair-set identity sweep + extent regression."""
    R = make_dataset("T1", seed=81, count=40)
    S = make_dataset("T2", seed=82, count=60)
    for scale, shift in ((1.0, 0.0), (50.0, 300.0), (1e-3, 2.0)):
        mr = R.mbrs * scale + shift
        ms = S.mbrs * scale + shift
        want = _pairs_set(np.stack(np.nonzero(mbr_intersect_mask(mr, ms)),
                                   axis=1))
        for backend in MBR_BACKENDS:
            got = _pairs_set(mbr_join(mr, ms, backend=backend))
            assert got == want, (backend, scale, shift)
        got, counts = distributed_mbr_join(mr, ms)
        assert _pairs_set(got) == want and counts["mbr_pairs"] == len(want)
        print(f"mbr smoke ok: scale={scale} shift={shift} "
              f"({len(want)} pairs, all backends + distributed)")


def run():
    res = bench_mbr_join()
    with open("BENCH_mbr.json", "w") as f:
        json.dump(res, f, indent=2)
    out = []
    for label, r in res["grids"].items():
        out.append(row(
            f"mbr_join_{label}", 1e6 * r["t_numpy_s"],
            f"t_seq_s={r['t_sequential_s']};t_numpy_s={r['t_numpy_s']};"
            f"t_jnp_s={r['t_jnp_s']};speedup={r['speedup_numpy']}"))
    return out


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
