"""Tables 4/12: approximation space requirements — MBR vs APRIL vs APRIL-C
vs RI vs RA vs 5C+CH.

NOTE: RI here is built at order 8 (its Weak/Strong coverage labeling is the
expensive path) while APRIL uses order 9, so this table under-states RI's
size; the same-order size comparison (both at N=10) is in the fig13 rows
(approx_B column) and EXPERIMENTS.md quotes those."""
from __future__ import annotations

from repro.baselines import build_5cch, build_ra
from repro.core.april import build_april
from repro.core.compress import compress_intervals
from repro.core.ri import build_ri

from .common import ds, row


def run():
    out = []
    for name in ("T1", "T2", "T3"):
        D = ds(name)
        geom = sum(int(n) * 16 for n in D.nverts)
        mbr = 32 * len(D)
        april = build_april(D, 9)
        aprilc = sum(
            len(compress_intervals(april.a_list(i))[0])
            + len(compress_intervals(april.f_list(i))[0])
            for i in range(len(D)))
        ri = build_ri(D, 8)
        ra = build_ra(D, max_cells=256)
        cch = build_5cch(D)
        out.append(row(
            f"table4_{name}", 0.0,
            f"geom_B={geom};mbr_B={mbr};april_B={april.size_bytes()};"
            f"aprilc_B={aprilc};ri_B={ri.size_bytes()};ra_B={ra.size_bytes()};"
            f"5cch_B={cch.size_bytes()}"))
    return out
