"""BENCH_service: warm micro-batched serving vs per-request cold joins.

The ISSUE-6 acceptance gate: a :class:`~repro.spatial.service.JoinService`
with warm device-resident stores (LRU store cache + warm MBR bucket index)
serving a micro-batched request trace must sustain >= 1.0x the throughput
of per-request cold ``JoinPlan`` runs (each rebuilding its approximations,
the pre-service behavior), with ``verdicts_equal`` true — batching and
warm-store reuse are execution details that never change results.
``benchmarks/run.py`` persists the result as BENCH_service.json and
``tools/check_bench.py`` guards the committed artifact in CI.

``python -m benchmarks.service_throughput --smoke`` is the CI quick-lane
check: micro-batched verdicts == per-request sequential verdicts for every
service predicate, plus the incremental-maintenance identity (mutated warm
stores == fresh rebuild) on the serving path.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.datagen import make_dataset
from repro.spatial import JoinPlan, JoinService

from .common import sync

N_ORDER = 8
N_REQUESTS = 48


def _queries(Q):
    return [(Q.verts[i, : Q.nverts[i]],) for i in range(len(Q))]


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).reshape(-1, 2).tolist()))


def _cold_requests(D, Q, predicate: str, method: str, n_order: int):
    """Per-request cold runs: every request pays its own store build (the
    pre-service behavior of every JoinPlan caller)."""
    out = []
    for i in range(len(Q)):
        one = make_one(Q, i)
        plan = JoinPlan(D, one, filter=method, n_order=n_order)
        pairs, _ = plan.execute(predicate)
        out.append(pairs)
    return out


def make_one(Q, i: int):
    from repro.datagen.synthetic import PolygonDataset
    nv = int(Q.nverts[i])
    return PolygonDataset(name=f"q{i}", verts=Q.verts[i: i + 1, :nv],
                          nverts=Q.nverts[i: i + 1])


def bench_service(method: str = "april"):
    D = make_dataset("T1", seed=5, count=400)
    Q = make_dataset("T2", seed=6, count=N_REQUESTS)

    # -- cold: one JoinPlan per request, stores rebuilt every time ----------
    t0 = time.perf_counter()
    cold = sync(_cold_requests(D, Q, "selection", method, N_ORDER))
    t_cold = time.perf_counter() - t0

    # -- warm: micro-batched service over cached stores ---------------------
    svc = JoinService(method=method, n_order=N_ORDER)
    svc.register_dataset("T1", D)
    svc.warm_store("T1")            # preprocessing, amortized (paper §1)
    t0 = time.perf_counter()
    tickets = [svc.submit("T1", "selection", Q.verts[i, : Q.nverts[i]])
               for i in range(len(Q))]
    svc.drain()
    sync([t.pairs for t in tickets])
    t_warm = time.perf_counter() - t0

    # each cold run has a single query, so both sides carry query index 0
    equal = all(_pairs_set(t.wait(10.0).pairs) == _pairs_set(cold[i])
                for i, t in enumerate(tickets))
    assert equal, "micro-batched verdicts diverged from cold per-request"

    lat = svc.latency_stats()
    return {
        "dataset": "T1 x T2", "method": method, "n_order": N_ORDER,
        "n_requests": N_REQUESTS,
        "t_cold_per_request_s": round(t_cold, 4),
        "t_warm_microbatched_s": round(t_warm, 4),
        "cold_queries_per_s": round(N_REQUESTS / max(t_cold, 1e-9), 1),
        "warm_queries_per_s": round(N_REQUESTS / max(t_warm, 1e-9), 1),
        "speedup_warm_over_cold": round(t_cold / max(t_warm, 1e-9), 2),
        "latency_p50_s": round(lat["p50_s"], 6),
        "latency_p99_s": round(lat["p99_s"], 6),
        "cache": dict(svc.cache.stats),
        "verdicts_equal": bool(equal),
    }


def smoke() -> None:
    """CI quick lane: micro-batched == per-request sequential for every
    service predicate, and warm stores patched by insert/delete answer
    identically to a fresh rebuild, for every filter method."""
    from repro.spatial.filters import available_filters

    D = make_dataset("T1", seed=21, count=90)
    Q = make_dataset("T2", seed=22, count=8)

    for method in ("april", "ri"):
        svc = JoinService(method=method, n_order=6)
        svc.register_dataset("d", D)
        for predicate in ("selection", "intersects", "within"):
            tickets = [svc.submit("d", predicate,
                                  Q.verts[i, : Q.nverts[i]])
                       for i in range(len(Q))]
            assert svc.drain() == len(Q)
            for i, t in enumerate(tickets):
                ref, _ = JoinPlan(D, make_one(Q, i), filter=method,
                                  n_order=6).execute(predicate)
                assert _pairs_set(t.pairs) == _pairs_set(ref), \
                    (method, predicate, i)
        # window == selection with the rectangle's corner polygon
        t = svc.submit("d", "window", (0.25, 0.25, 0.7, 0.7))
        svc.drain()
        rect = np.array([[0.25, 0.25], [0.7, 0.25], [0.7, 0.7], [0.25, 0.7]])
        from repro.datagen.synthetic import PolygonDataset
        ref, _ = JoinPlan(D, PolygonDataset(name="w", verts=rect[None],
                                            nverts=np.array([4])),
                          filter=method, n_order=6).execute("selection")
        assert _pairs_set(t.wait(10.0).pairs) == _pairs_set(ref)
        print(f"service smoke ok: {method} micro-batch == per-request")

    # incremental identity on the serving path, every filter method
    ins = Q.verts[0, : Q.nverts[0]] * 0.8 + 0.1
    for method in available_filters():
        svc = JoinService(method=method, n_order=6)
        svc.register_dataset("d", D)
        svc.warm_store("d")                      # build BEFORE mutating
        svc.insert("d", ins)
        svc.delete("d", 7)
        t = svc.submit("d", "selection", Q.verts[1, : Q.nverts[1]])
        svc.drain()
        ref, _ = JoinPlan(svc.dataset("d"), make_one(Q, 1), filter=method,
                          n_order=6).execute("selection")
        assert _pairs_set(t.wait(10.0).pairs) == _pairs_set(ref), method
        print(f"service smoke ok: {method} patched store == fresh rebuild")


def run():
    res = bench_service()
    with open("BENCH_service.json", "w") as f:
        json.dump(res, f, indent=2)
    from .common import row
    return [row("service_throughput",
                1e6 * res["t_warm_microbatched_s"] / res["n_requests"],
                f"warm_qps={res['warm_queries_per_s']};"
                f"cold_qps={res['cold_queries_per_s']};"
                f"speedup={res['speedup_warm_over_cold']}")]


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
