"""Table 11: APRIL construction methods — RI-style full rasterization vs
scanline vs flood fill vs one-step intervalization (PiPs / Neighbors /
TPU-batched)."""
from __future__ import annotations

from repro.core.april import build_april
from repro.core.intervalize import PIP_COUNTER
from repro.core.ri import build_ri

from .common import ds, row, timeit


def run():
    out = []
    for name in ("T1", "T2", "T3"):
        D = ds(name)
        for method in ("scanline", "floodfill", "pips", "neighbors",
                       "batched"):
            PIP_COUNTER["count"] = 0
            _, dt = timeit(build_april, D, 9, method=method)
            pips = PIP_COUNTER["count"]
            out.append(row(f"table11_{name}_{method}",
                           dt / max(1, len(D)) * 1e6,
                           f"total_s={dt:.3f};pip_tests={pips}"))
        # RI needs Strong/Weak labels => coverage clipping (the costly path)
        if name != "T3":  # T3 at order 9 is large; keep the bench bounded
            _, dt = timeit(build_ri, D, 8)
            out.append(row(f"table11_{name}_ri_full", dt / len(D) * 1e6,
                           f"total_s={dt:.3f}"))
    return out
