"""Table 11 + BENCH_build: approximation construction cost.

Two parts:

* ``table11_*`` rows — the paper's construction-method comparison (scanline
  vs flood fill vs one-step PiPs/Neighbors/batched) with PiP-test counts.
* :func:`bench_builds` — sequential (per-polygon reference) vs batched
  (dataset-level, DESIGN.md §6) build times for every registered filter,
  persisted as ``BENCH_build.json``. The ISSUE-2 acceptance gate: >=10x on
  ``build_ri`` / ``build_ra`` at order 9 on T1/T2, batched store-identical
  to sequential.

``python -m benchmarks.construction --smoke`` runs a tiny
batched-vs-sequential equality check (the CI quick-lane smoke).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import ri
from repro.core.april import build_april
from repro.core.intervalize import PIP_COUNTER
from repro.datagen import make_dataset
from repro.spatial import get_filter

from .common import ds, row, timeit

BENCH_ORDER = 9
BENCH_DATASETS = ("T1", "T2")
RA_MAX_CELLS = 256


def _store_equal(name: str, s, b) -> bool:
    try:
        if name == "april":
            return all(np.array_equal(getattr(s, f), getattr(b, f))
                       for f in ("a_off", "a_ints", "f_off", "f_ints"))
        if name == "april-c":
            return s.a_bufs == b.a_bufs and s.f_bufs == b.f_bufs
        if name == "ri":
            return all(np.array_equal(getattr(s, f), getattr(b, f))
                       for f in ("off", "ints", "bit_off", "bits"))
        if name == "ra":
            return (np.array_equal(s.k, b.k)
                    and all(np.array_equal(x, y)
                            for x, y in zip(s.cells, b.cells)))
        if name == "5cch":
            return all(np.array_equal(getattr(s, f), getattr(b, f))
                       for f in ("pent", "hull_off", "hull_pts"))
    except AttributeError:
        return False
    return False


def bench_builds(n_order: int = BENCH_ORDER, names=BENCH_DATASETS) -> dict:
    """Sequential vs batched builds for all five filters; BENCH_build dict."""
    out = {"n_order": n_order, "ra_max_cells": RA_MAX_CELLS, "datasets": {}}
    for name in names:
        D = ds(name)
        per = {}
        for m in ("april", "april-c", "ri", "ra", "5cch"):
            filt = get_filter(m)
            opts = {"max_cells": RA_MAX_CELLS} if m == "ra" else {}
            seq, t_seq = timeit(filt.build, D, n_order=n_order,
                                build_backend="sequential", **opts)
            bat, t_bat = timeit(filt.build, D, n_order=n_order,
                                build_backend="numpy", **opts)
            assert _store_equal(m, seq.store, bat.store), \
                f"{m}/{name}: batched store diverged from sequential"
            per[m] = {
                "t_seq_s": round(t_seq, 4),
                "t_batch_s": round(t_bat, 4),
                "polys_per_s_seq": round(len(D) / max(t_seq, 1e-9), 1),
                "polys_per_s_batch": round(len(D) / max(t_bat, 1e-9), 1),
                "speedup": round(t_seq / max(t_bat, 1e-9), 2),
                "stores_equal": True,   # asserted above; checked in CI by
                                        # tools/check_bench.py
            }
        out["datasets"][name] = per
    return out


def smoke() -> None:
    """CI quick-lane smoke: tiny dataset, batched == sequential stores."""
    D = make_dataset("T1", seed=77, count=10)
    for m in ("april", "april-c", "ri", "ra", "5cch"):
        filt = get_filter(m)
        opts = {"max_cells": 64} if m == "ra" else {}
        seq = filt.build(D, n_order=6, build_backend="sequential", **opts)
        bat = filt.build(D, n_order=6, build_backend="numpy", **opts)
        assert _store_equal(m, seq.store, bat.store), m
        print(f"construction smoke ok: {m}")


def run():
    out = []
    for name in ("T1", "T2", "T3"):
        D = ds(name)
        for method in ("scanline", "floodfill", "pips", "neighbors",
                       "batched"):
            PIP_COUNTER["count"] = 0
            _, dt = timeit(build_april, D, 9, method=method)
            pips = PIP_COUNTER["count"]
            out.append(row(f"table11_{name}_{method}",
                           dt / max(1, len(D)) * 1e6,
                           f"total_s={dt:.3f};pip_tests={pips}"))
        # RI needs Strong/Weak labels => coverage clipping (the costly path)
        if name != "T3":  # T3 at order 9 is large; keep the bench bounded
            _, dt = timeit(ri.build_ri, D, 8)
            out.append(row(f"table11_{name}_ri_full", dt / len(D) * 1e6,
                           f"total_s={dt:.3f}"))

    # sequential vs batched builds -> BENCH_build.json
    res = bench_builds()
    with open("BENCH_build.json", "w") as f:
        json.dump(res, f, indent=2)
    for name, per in res["datasets"].items():
        for m, r in per.items():
            out.append(row(
                f"build_{m}_{name}", 1e6 * r["t_batch_s"] / max(1, len(ds(name))),
                f"t_seq_s={r['t_seq_s']};t_batch_s={r['t_batch_s']};"
                f"speedup={r['speedup']}"))
    return out


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
