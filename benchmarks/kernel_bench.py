"""Kernel-layer benchmark: vectorized/batched joins vs the paper's
sequential merge join, plus refinement batching. (The Pallas kernels
themselves run interpret=True on CPU — their latency here is NOT indicative;
their roofline terms are derived analytically in EXPERIMENTS.md §Perf.)"""
from __future__ import annotations

import numpy as np

from repro.core.april import build_april
from repro.core.join import (april_filter_batch, april_verdict_pair,
                             pack_lists)
from repro.core.join import batch_overlap_np
from repro.spatial.mbr_join import mbr_join
from repro.spatial.distributed import distributed_april_filter, pack_pair_batch

from .common import ds, row, timeit


def run():
    out = []
    R, S = ds("T1"), ds("T2")
    ar, as_ = build_april(R, 9), build_april(S, 9)
    pairs = mbr_join(R.mbrs, S.mbrs)
    n = max(1, len(pairs))

    def sequential():
        return [april_verdict_pair(ar.a_list(int(i)), ar.f_list(int(i)),
                                   as_.a_list(int(j)), as_.f_list(int(j)))
                for i, j in pairs]

    _, t_seq = timeit(sequential)
    out.append(row("kernel_seq_merge_join", t_seq / n * 1e6,
                   f"pairs={len(pairs)}"))

    _, t_np = timeit(april_filter_batch, ar, as_, pairs)
    out.append(row("kernel_batch_numpy", t_np / n * 1e6,
                   f"speedup={t_seq / t_np:.2f}x"))

    packed = pack_pair_batch(ar, as_, pairs)
    _, t_j0 = timeit(distributed_april_filter, packed)   # includes jit
    _, t_j = timeit(distributed_april_filter, packed, repeats=3)
    out.append(row("kernel_batch_jax_sharded", t_j / n * 1e6,
                   f"speedup={t_seq / t_j:.2f}x;first_call_s={t_j0:.2f}"))
    return out
