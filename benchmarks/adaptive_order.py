"""Beyond-paper: per-pair adaptive join order (the paper's §9 future work).

Compares fixed AA-AF-FA against the MBR-statistics heuristic on a
hit-heavy workload (T1 x T3, 70% true hits in the paper) and a
negative-heavy one (T1 x T2). Metric: total interval comparisons executed
by the sequential filter (machine-independent work counter)."""
from __future__ import annotations

from repro.core.april import build_april
from repro.core.join import adaptive_order, interval_join_pair
from repro.spatial.mbr_join import mbr_join

from .common import ds, row


def _count_join(X, Y) -> int:
    """Interval comparisons a two-pointer merge join performs."""
    i = j = n = 0
    while i < len(X) and j < len(Y):
        n += 1
        if X[i][0] < Y[j][1] and Y[j][0] < X[i][1]:
            return n
        if X[i][1] <= Y[j][1]:
            i += 1
        else:
            j += 1
    return n


def _filter_work(ar, as_, R, S, pairs, order_fn) -> int:
    total = 0
    for i, j in pairs:
        order = order_fn(i, j)
        lists = {"AA": (ar.a_list(i), as_.a_list(j)),
                 "AF": (ar.a_list(i), as_.f_list(j)),
                 "FA": (ar.f_list(i), as_.a_list(j))}
        for step in order:
            X, Y = lists[step]
            total += _count_join(X, Y)
            hit = interval_join_pair(X, Y)
            if step == "AA" and not hit:
                break
            if step != "AA" and hit:
                break
    return total


def run():
    out = []
    for pair in (("T1", "T2"), ("T1", "T3")):
        R, S = ds(pair[0]), ds(pair[1])
        ar, as_ = build_april(R, 9), build_april(S, 9)
        pairs = mbr_join(R.mbrs, S.mbrs)
        fixed = _filter_work(ar, as_, R, S, pairs,
                             lambda i, j: ("AA", "AF", "FA"))
        adapt = _filter_work(
            ar, as_, R, S, pairs,
            lambda i, j: adaptive_order(
                R.mbrs[i], S.mbrs[j],
                int(ar.f_off[i + 1] - ar.f_off[i]),
                int(as_.f_off[j + 1] - as_.f_off[j])))
        out.append(row(
            f"adaptive_order_{pair[0]}x{pair[1]}", 0.0,
            f"fixed_cmps={fixed};adaptive_cmps={adapt};"
            f"saving={1 - adapt / max(1, fixed):.3f}"))
    return out
