"""BENCH_planner: the adaptive query planner vs the static config sweep.

The ISSUE-9 acceptance gate (DESIGN.md §13): ``choose_plan`` samples a
slice of the MBR candidates and picks filter method / ``n_order`` /
AA-AF-FA order / pipeline mode; its pick must land within ``MARGIN`` of
the best static configuration — and far above the worst — on both the
negative-heavy (T1 x T2) and hit-heavy (T1 x T3) workloads, with the
executed adaptive plan's result pairs identical to the refine-everything
reference. The metric is the planner's own machine-independent work unit
(interval comparisons + build/refine/decode work,
:func:`repro.spatial.planner.measured_work` on the FULL candidate set),
so the gate is immune to CI wall-clock noise; the adaptive total includes
the sampling/probe work the planner itself spent (``plan_work``).

This suite also carries the paper's Table-7 join-order sweep (§7.2.2,
formerly ``benchmarks/join_order.py``): per-order wall-clock filter time
over one reused :class:`~repro.spatial.plan.JoinPlan` — the static sweep
the planner's order choice is judged against.

``--smoke`` is the CI quick-lane check: seeded planning is deterministic,
the chosen estimate is never worse than the best static estimate, the
executed adaptive plan matches the refine-everything reference on
intersects/within, and tiny candidate sets take the skip-filter fast
path.
"""
from __future__ import annotations

import json

import numpy as np

from repro.spatial import JoinPlan
from repro.spatial.mbr_join import mbr_join
from repro.spatial.planner import (PLAN_DEFAULTS, choose_plan,
                                   measured_work, static_configs)

from .common import bench_main, ds, row

N_ORDER = 9
#: negative-heavy (many small objects, AA kills most pairs) and hit-heavy
#: (few large complex objects, ~70% true hits in the paper's Table 7)
WORKLOADS = (("T1", "T2"), ("T1", "T3"))
MARGIN = 1.1


def _pair_set(pairs) -> set:
    return set(map(tuple, np.asarray(pairs).tolist()))


def _sweep_orders() -> list[int]:
    return sorted({max(4, N_ORDER - 2), N_ORDER, min(14, N_ORDER + 2)})


def bench_planner() -> dict:
    out: dict = {"n_order_requested": N_ORDER, "margin": MARGIN,
                 "work_unit": "interval comparisons (planner cost model)"}
    for rn, sn in WORKLOADS:
        R, S = ds(rn), ds(sn)
        pairs = mbr_join(R.mbrs, S.mbrs)
        choice = choose_plan(R, S, pairs, predicate="intersects",
                             n_order=N_ORDER)

        bank: dict = {}
        sweep = {
            cfg.key(): measured_work(R, S, pairs, cfg, store_bank=bank)
            for cfg in static_configs("intersects",
                                      PLAN_DEFAULTS["methods"],
                                      _sweep_orders(),
                                      PLAN_DEFAULTS["orders"], N_ORDER)
        }
        totals = {k: v["total"] for k, v in sweep.items()}
        best = min(totals, key=lambda k: (totals[k], k))
        worst = max(totals, key=lambda k: (totals[k], k))
        w_adapt = (measured_work(R, S, pairs, choice, store_bank=bank)
                   ["total"] + choice.est["plan_work"])
        ratio = w_adapt / totals[best]
        assert ratio <= MARGIN, (
            f"{rn}x{sn}: adaptive plan {choice.key()} costs {w_adapt:.0f} "
            f"work units vs best static {best} at {totals[best]:.0f} "
            f"({ratio:.3f}x > {MARGIN}x margin)")

        plan = JoinPlan(R, S, filter="april", n_order=N_ORDER,
                        plan_mode="adaptive")
        res, _ = plan.execute("intersects")
        ref, _ = JoinPlan(R, S, filter="none").execute("intersects")
        identical = _pair_set(res) == _pair_set(ref)
        assert identical, f"{rn}x{sn}: adaptive verdicts diverged"

        out[f"{rn}x{sn}"] = {
            "n_candidates": int(len(pairs)),
            "plan": choice.key(),
            "plan_pipeline_mode": choice.pipeline_mode,
            "work_adaptive": round(w_adapt, 1),
            "plan_work": round(choice.est["plan_work"], 1),
            "best_static": best,
            "work_best_static": round(totals[best], 1),
            "worst_static": worst,
            "work_worst_static": round(totals[worst], 1),
            "ratio_adaptive_vs_best_static": round(ratio, 4),
            "speedup_adaptive_over_worst_static":
                round(totals[worst] / w_adapt, 2),
            "n_results": int(len(res)),
            "verdicts_equal": bool(identical),
        }
    return out


def _table7_rows() -> list[str]:
    """Table 7 (§7.2.2): wall-clock filter time per AA/AF/FA order, one
    reused JoinPlan per dataset pair (the build/execute split)."""
    out = []
    for rn, sn in WORKLOADS:
        R, S = ds(rn), ds(sn)
        plan = JoinPlan(R, S, filter="april", n_order=N_ORDER)
        plan.build()
        for order in PLAN_DEFAULTS["orders"]:
            plan.filter_opts["order"] = order
            _, st = plan.execute("intersects")
            h, g, i = st.rates()
            out.append(row(
                f"table7_{rn}x{sn}_{'-'.join(order)}", st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f}"))
    return out


def run() -> list[str]:
    res = bench_planner()
    with open("BENCH_planner.json", "w") as f:
        json.dump(res, f, indent=2)
    rows = []
    for key, v in res.items():
        if isinstance(v, dict):
            rows.append(row(
                f"planner_{key}", 0.0,
                f"plan={v['plan']};best={v['best_static']};"
                f"ratio_vs_best={v['ratio_adaptive_vs_best_static']};"
                f"speedup_vs_worst={v['speedup_adaptive_over_worst_static']}"
            ))
    return rows + _table7_rows()


def smoke() -> None:
    """CI quick lane: determinism, never-worse-than-best-static estimate,
    verdict identity of the executed adaptive plan, skip-filter fast
    path."""
    from repro.datagen import make_dataset

    R = make_dataset("T1", seed=41, count=70)
    S = make_dataset("T2", seed=42, count=110)
    pairs = mbr_join(R.mbrs, S.mbrs)
    c1 = choose_plan(R, S, pairs, n_order=7)
    c2 = choose_plan(R, S, pairs, n_order=7)
    assert c1.to_dict() == c2.to_dict(), "seeded planning must be " \
        "deterministic (same inputs -> same chosen plan)"
    if c1.est["costs"]:
        # est["costs"] entries are rounded to 3 decimals; total is exact
        assert c1.est["total"] <= min(c1.est["costs"].values()) + 1e-3, \
            "chosen estimate must equal the best static estimate"
    print(f"planner smoke ok: deterministic choice {c1.key()} "
          f"over {len(c1.est['costs'])} static configs")

    for predicate in ("intersects", "within"):
        plan = JoinPlan(R, S, filter="april", n_order=7,
                        plan_mode="adaptive")
        res, st = plan.execute(predicate)
        ref, _ = JoinPlan(R, S, filter="none").execute(predicate)
        assert _pair_set(res) == _pair_set(ref), predicate
        assert st.plan_mode == "adaptive" and "plan" in st.extra
        print(f"planner smoke ok: {predicate} adaptive "
              f"plan={st.extra['plan']['method']} == refine-all reference")

    tiny_r = make_dataset("T1", seed=43, count=4)
    tiny_s = make_dataset("T2", seed=44, count=4)
    tiny = choose_plan(tiny_r, tiny_s,
                       mbr_join(tiny_r.mbrs, tiny_s.mbrs), n_order=7)
    assert tiny.skip_filter and tiny.method == "none"
    print("planner smoke ok: tiny candidate set skips the filter")


if __name__ == "__main__":
    bench_main(run, smoke)
