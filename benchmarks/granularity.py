"""Table 10: mixed-granularity joins — T1 at N=10 vs T3 at L<=10."""
from __future__ import annotations

from repro.core.april import build_april
from repro.core.granularity import mixed_order_verdict_pair
from repro.core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from repro.spatial.mbr_join import mbr_join

from .common import ds, row, timeit


def run():
    R, S = ds("T1"), ds("T3")
    n_fine = 10
    ar = build_april(R, n_fine)
    pairs = mbr_join(R.mbrs, S.mbrs)
    out = []
    for L in (10, 9, 8, 7):
        as_ = build_april(S, L)

        def filter_all():
            cnt = [0, 0, 0]
            for i, j in pairs:
                v = mixed_order_verdict_pair(
                    ar.a_list(int(i)), ar.f_list(int(i)), n_fine,
                    as_.a_list(int(j)), as_.f_list(int(j)), L)
                cnt[v] += 1
            return cnt

        cnt, tf = timeit(filter_all)
        n = max(1, len(pairs))
        out.append(row(
            f"table10_T3_order{L}", tf * 1e6,
            f"hits={cnt[TRUE_HIT] / n:.3f};negs={cnt[TRUE_NEG] / n:.3f};"
            f"indec={cnt[INDECISIVE] / n:.3f};t3_size_B={as_.size_bytes()}"))
    return out
