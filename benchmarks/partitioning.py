"""Tables 8/9: partitions-per-dimension vs filter effectiveness, time, size."""
from __future__ import annotations

import numpy as np

from repro.core import partition as pm
from repro.core.join import INDECISIVE, april_verdict_pair
from repro.spatial.mbr_join import mbr_join

from .common import ds, row, timeit


def run():
    out = []
    for pair in (("T1", "T2"), ("O5", "O6")):
        R, S = ds(pair[0]), ds(pair[1])
        pairs = mbr_join(R.mbrs, S.mbrs)
        for parts in (1, 2, 3, 4):
            parting = pm.partition_space([R, S], parts_per_dim=parts)
            (sr, ss), tb = timeit(
                lambda: (parting.build_april(R, 9), parting.build_april(S, 9)))
            size = sum(s.size_bytes() for s in sr if s) \
                + sum(s.size_bytes() for s in ss if s)

            def filter_all():
                ind = 0
                for i, j in pairs:
                    p = pm.reference_partition(parts, R.mbrs[i], S.mbrs[j])
                    part = parting.partitions[p]
                    li = np.nonzero(part.obj_idx[pair[0]] == i)[0][0]
                    lj = np.nonzero(part.obj_idx[pair[1]] == j)[0][0]
                    v = april_verdict_pair(
                        sr[p].a_list(int(li)), sr[p].f_list(int(li)),
                        ss[p].a_list(int(lj)), ss[p].f_list(int(lj)))
                    ind += int(v == INDECISIVE)
                return ind

            ind, tf = timeit(filter_all)
            out.append(row(
                f"table8_{pair[0]}x{pair[1]}_parts{parts}", tf * 1e6,
                f"indec={ind / max(1, len(pairs)):.3f};size_B={size};"
                f"build_s={tb:.2f}"))
    return out
