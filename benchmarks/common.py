"""Shared benchmark helpers: cached datasets, timing, CSV rows.

Benchmarks mirror the paper's tables on seeded synthetic datasets whose
statistics track the TIGER/OSM collections (see datagen/synthetic.py). Grid
orders are scaled to the synthetic map density (the paper's N=16 on a
continent-sized map corresponds to N≈10 on our unit-square workloads —
polygon/cell-size ratios are kept comparable).
"""
from __future__ import annotations

import sys
import time
from functools import lru_cache

from repro.datagen import make_dataset, make_linestrings

# benchmark-scale dataset sizes (seconds-scale on one CPU core)
SIZES = {"T1": 320, "T2": 520, "T3": 24, "T9": 6, "T10": 80,
         "O5": 300, "O6": 380}


@lru_cache(maxsize=None)
def ds(name: str, seed: int = 0):
    return make_dataset(name, seed=seed, count=SIZES.get(name))


@lru_cache(maxsize=None)
def lines(seed: int = 0, count: int = 400):
    return make_linestrings(seed=seed, count=count)


def sync(x):
    """Barrier before reading a benchmark timer: block until any device
    work backing ``x`` is done (JAX dispatch is async — without this the
    timer measures dispatch, not execution). No-op on host values."""
    import jax
    jax.block_until_ready(x)
    return x


def timeit(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    sync(out)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def smoke_requested(argv: list[str] | None = None) -> bool:
    """The ONE place that interprets the ``--smoke`` flag — every
    benchmark entry point (module ``__main__`` and ``benchmarks.run``)
    routes through here, so the flag means the same thing everywhere."""
    return "--smoke" in (sys.argv[1:] if argv is None else argv)


def bench_main(run_fn, smoke_fn=None, argv: list[str] | None = None) -> None:
    """Uniform benchmark-module entry point: ``--smoke`` runs the CI
    quick-lane identity check, anything else prints the CSV rows."""
    if smoke_fn is not None and smoke_requested(argv):
        smoke_fn()
        return
    print("name,us_per_call,derived")
    for line in run_fn():
        print(line)
