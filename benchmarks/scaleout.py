"""BENCH_scaleout: cost-balanced tiled partitioning vs the static grid.

The ISSUE-10 acceptance gate: the §14 out-of-core driver
(:func:`~repro.spatial.scaleout.tiled_join`) with cost-aware partitioning
— per-partition work estimated in the §13 planner's units, hot partitions
skew-split into quadrants, partitions FFD-packed into byte-budgeted tiles
— must complete a clustered multi-chunk workload at >= 1.0x the uniform
static grid (``balance="static"``: no splitting, order-preserving
packing), with ``verdicts_equal`` true. The honest speedup lever is
precision: skew-split children get their own smaller raster extents, so
their interval grids are effectively finer — fewer INDECISIVE pairs on
the dense clusters and less exact-refinement work, which dominates on
skewed data. ``benchmarks/run.py`` persists the result as
BENCH_scaleout.json and ``tools/check_bench.py`` guards the committed
artifact in CI.

``python -m benchmarks.scaleout --smoke`` is the CI quick-lane check:
tiled verdicts (both balance modes, several tiles, skew splits firing)
== the in-memory ``JoinPlan`` reference pair set.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.datagen import iter_dataset_chunks, make_chunked_dataset
from repro.spatial import JoinPlan
from repro.spatial.scaleout import tiled_join

from .common import sync

N_ORDER = 8
COUNT_R, COUNT_S, CHUNK = 2400, 3400, 600
TILE_BUDGET = 1_200_000          # several tiles on this workload


def _chunks(name: str, seed: int, count: int):
    return iter_dataset_chunks(name, seed=seed, count=count,
                               chunk_size=CHUNK)


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).reshape(-1, 2).tolist()))


def _tiled(balance: str, **opts):
    t0 = time.perf_counter()
    pairs, stats = tiled_join(
        _chunks("T1", 5, COUNT_R), _chunks("T2", 6, COUNT_S),
        predicate="intersects", method="april", n_order=N_ORDER,
        tile_budget=TILE_BUDGET, balance=balance, **opts)
    sync(pairs)
    return pairs, stats, time.perf_counter() - t0


def bench_scaleout():
    # cost-balanced: skew splits on (threshold at the median partition
    # cost — the hot quadrants of the 16-cluster map split into finer
    # extents), FFD packing by estimated resident bytes
    pairs_c, st_c, t_cost = _tiled("cost", split_factor=1.0)
    # static baseline: the uniform grid, packed in partition order
    pairs_s, st_s, t_static = _tiled("static")

    equal = _pairs_set(pairs_c) == _pairs_set(pairs_s)
    assert equal, "cost-balanced verdicts diverged from the static grid"
    assert st_c.extra["tile_plan"]["n_splits"] > 0, \
        "skew split must fire on this clustered workload"

    return {
        "dataset": "T1 x T2 (streamed chunks)", "method": "april",
        "n_order": N_ORDER, "count_r": COUNT_R, "count_s": COUNT_S,
        "chunk_size": CHUNK, "tile_budget": TILE_BUDGET,
        "t_cost_balanced_s": round(t_cost, 4),
        "t_static_grid_s": round(t_static, 4),
        "speedup_cost_balanced": round(t_static / max(t_cost, 1e-9), 2),
        "tiles_cost": st_c.tiles, "tiles_static": st_s.tiles,
        "n_splits": st_c.extra["tile_plan"]["n_splits"],
        "indecisive_cost": st_c.n_indecisive,
        "indecisive_static": st_s.n_indecisive,
        "n_results": st_c.n_results,
        "verdicts_equal": bool(equal),
    }


def smoke() -> None:
    """CI quick lane: tiled == in-memory verdict set, both balance modes,
    with the workload genuinely tiling and skew splits firing."""
    kw = dict(seed=5, count=260, chunk_size=90)
    R = make_chunked_dataset("T1", **kw)
    S = make_chunked_dataset("T2", seed=6, count=380, chunk_size=90)
    ref, _ = JoinPlan(R, S, filter="april", n_order=7).execute("intersects")
    ref = _pairs_set(ref)
    for balance, opts in (("cost", dict(split_factor=1.0,
                                        min_split_objs=32)),
                          ("static", {})):
        pairs, stats = tiled_join(
            iter_dataset_chunks("T1", **kw),
            iter_dataset_chunks("T2", seed=6, count=380, chunk_size=90),
            predicate="intersects", method="april", n_order=7,
            tile_budget=150_000, balance=balance, **opts)
        assert _pairs_set(pairs) == ref, balance
        assert stats.tiles > 1, balance
        if balance == "cost":
            assert stats.extra["tile_plan"]["n_splits"] > 0
        print(f"scaleout smoke ok: {balance} tiled == in-memory "
              f"({stats.tiles} tiles, {stats.n_results} results)")


def run():
    res = bench_scaleout()
    with open("BENCH_scaleout.json", "w") as f:
        json.dump(res, f, indent=2)
    from .common import row
    return [row("scaleout_tiled",
                1e6 * res["t_cost_balanced_s"],
                f"tiles={res['tiles_cost']};"
                f"n_splits={res['n_splits']};"
                f"speedup={res['speedup_cost_balanced']}")]


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
