"""Tables 13/14: joins between T2 and datasets of increasing object size
(T1 -> T10 -> T3 -> T9); APRIL's advantage grows with size skew."""
from __future__ import annotations

from repro.spatial import spatial_intersection_join

from .common import ds, row


def run():
    out = []
    R = ds("T2")
    for other, methods in (("T1", ("none", "5cch", "ra", "april")),
                           ("T10", ("none", "5cch", "april")),
                           ("T3", ("none", "5cch", "april")),
                           ("T9", ("none", "april"))):
        S = ds(other)
        for m in methods:
            _, st = spatial_intersection_join(R, S, method=m, n_order=9,
                                              max_ra_cells=256)
            h, g, i = st.rates()
            out.append(row(
                f"table13_T2x{other}_{m}", st.t_filter * 1e6,
                f"hits={h:.3f};negs={g:.3f};indec={i:.3f};"
                f"refine_s={st.t_refine:.3f};total_s={st.t_total:.3f}"))
    return out
