"""BENCH_refine: refinement throughput, sequential vs batched vs pallas.

The refinement stage dominates end-to-end join cost (paper §2) — PR 3 makes
it batched (DESIGN.md §7). This benchmark times the per-pair sequential
reference against the batched numpy / jnp / pallas backends for every
predicate on T1 x T2-scale candidate sets, asserts the backends are
verdict-identical on a common sample, and persists ``BENCH_refine.json``.
The ISSUE-3 acceptance gate: >= 5x batched-over-sequential throughput on the
within and linestring predicates.

The sequential loop is timed on a capped sample (its per-pair cost is rate-
constant); batched backends run the full candidate set. The pallas backend
on a non-TPU host runs the kernel in interpret mode — correctness-faithful,
not performance-faithful — so its pair cap is small and its time is reported
for completeness only.

``python -m benchmarks.refinement --smoke`` runs a tiny all-backends
verdict-identity check plus the two boundary-touch regressions (the CI
quick-lane smoke).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import geometry
from repro.datagen import make_dataset, make_linestrings
from repro.spatial import JoinPlan, refine

from .common import ds, lines, row, timeit

SEQ_CAP = 2000      # pairs timed through the per-pair reference loop
PALLAS_CAP = 256    # pairs through the (interpret-mode) pallas sweep


def _sides(predicate):
    if predicate == "linestring":
        # enough chains that the candidate set reaches T1xT2 scale
        return lines(count=1600), ds("T2"), "line"
    if predicate == "selection":
        return ds("T2"), ds("T1"), "polygon"   # data x queries
    return ds("T1"), ds("T2"), "polygon"


def _candidates(predicate, R, S, r_kind):
    plan = JoinPlan(R, S, filter="none", r_kind=r_kind)
    # MBR-containment candidates are scarce on T1xT2; within throughput is
    # measured over the full MBR-intersect candidate set instead (refinement
    # verdicts are defined for any pair batch)
    pred = "intersects" if predicate == "within" else predicate
    return plan.candidates(pred)


def bench_refinement() -> dict:
    out = {"datasets": "T1xT2 (bench scale)", "seq_cap": SEQ_CAP,
           "pallas_cap": PALLAS_CAP, "predicates": {}}
    for pred in ("intersects", "within", "linestring", "selection"):
        R, S, r_kind = _sides(pred)
        pairs = _candidates(pred, R, S, r_kind)
        n = len(pairs)
        n_seq = min(n, SEQ_CAP)
        n_pal = min(n, PALLAS_CAP)

        def run(backend, p):
            return refine.refine(R, S, p, predicate=pred, backend=backend)

        seq, t_seq = timeit(run, "sequential", pairs[:n_seq])
        bat, t_np = timeit(run, "numpy", pairs)
        jn = run("jnp", pairs)     # warm the jit cache on the timed shapes
        _, t_jnp = timeit(run, "jnp", pairs)
        pal, t_pal = timeit(run, "pallas", pairs[:n_pal])
        assert np.array_equal(seq, bat[:n_seq]), f"{pred}: numpy != seq"
        assert np.array_equal(bat, jn), f"{pred}: jnp != numpy"
        assert np.array_equal(seq[:n_pal], pal), f"{pred}: pallas != seq"

        rate_seq = n_seq / max(t_seq, 1e-9)
        rate_np = n / max(t_np, 1e-9)
        out["predicates"][pred] = {
            "n_pairs": int(n), "n_seq": int(n_seq), "n_pallas": int(n_pal),
            "n_hits": int(bat.sum()),
            "t_seq_s": round(t_seq, 4), "t_numpy_s": round(t_np, 4),
            "t_jnp_s": round(t_jnp, 4), "t_pallas_s": round(t_pal, 4),
            "pairs_per_s_seq": round(rate_seq, 1),
            "pairs_per_s_numpy": round(rate_np, 1),
            "speedup_numpy": round(rate_np / max(rate_seq, 1e-9), 2),
            "verdicts_equal": True,
        }
    return out


def smoke() -> None:
    """CI quick lane: tiny verdict-identity sweep + boundary regressions."""
    R = make_dataset("T1", seed=91, count=30)
    S = make_dataset("T10", seed=92, count=20)
    L = make_linestrings(seed=93, count=30)
    for pred in ("intersects", "within", "linestring", "selection"):
        A = L if pred == "linestring" else R
        pairs = _candidates(pred, A, S, "line" if pred == "linestring"
                            else "polygon")
        want = refine.refine(A, S, pairs, predicate=pred,
                             backend="sequential")
        for backend in ("numpy", "jnp", "pallas"):
            got = refine.refine(A, S, pairs, predicate=pred, backend=backend)
            assert np.array_equal(want, got), (pred, backend)
        print(f"refinement smoke ok: {pred} ({len(pairs)} pairs)")
    # boundary-touch regressions (ISSUE 3): touching containment + concave
    # within-container — both were false negatives before the fix
    from repro.datagen.fixtures import (CSHAPE, CSHAPE_INNER, SNAPPED_HOST,
                                        SNAPPED_TRI)
    assert geometry.polygons_intersect(SNAPPED_TRI, 3, SNAPPED_HOST, 8)
    assert geometry.polygon_within(CSHAPE_INNER, 3, CSHAPE, 8)
    print("refinement smoke ok: boundary-touch regressions")


def run():
    res = bench_refinement()
    with open("BENCH_refine.json", "w") as f:
        json.dump(res, f, indent=2)
    out = []
    for pred, r in res["predicates"].items():
        out.append(row(
            f"refine_{pred}", 1e6 * r["t_numpy_s"] / max(1, r["n_pairs"]),
            f"t_seq_s={r['t_seq_s']};t_numpy_s={r['t_numpy_s']};"
            f"t_jnp_s={r['t_jnp_s']};speedup={r['speedup_numpy']}"))
    return out


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
