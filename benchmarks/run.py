"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Select subsets with
``python -m benchmarks.run table5 fig13 ...``; no args runs everything.
``--smoke`` routes uniformly to every selected suite's CI quick-lane
smoke check (``common.smoke_requested`` is the single interpretation of
the flag) — suites without one are skipped with a comment line.
"""
from __future__ import annotations

import sys
import time

from . import (adaptive_order, comparative, construction, effect_of_n,
               filter_throughput, granularity, kernel_bench, linestring,
               mbr_join, partitioning, pipeline_e2e, refinement, scaleout,
               selection, service_throughput, size_variance, space,
               within_join)
from .common import smoke_requested

SUITES = {
    "table4_space": space,
    "table5_effect_of_n": effect_of_n,
    "table8_partitioning": partitioning,
    "table10_granularity": granularity,
    "table11_construction": construction,
    "table13_size_variance": size_variance,
    "table15_selection": selection,
    "table16_within": within_join,
    "table17_linestring": linestring,
    "fig13_comparative": comparative,
    # emits BENCH_planner.json: adaptive planner vs the static config
    # sweep; also carries the Table-7 join-order rows (paper §7.2.2)
    "planner_table7_join_order": adaptive_order,
    "kernels": kernel_bench,
    # emits BENCH_filter.json: sequential vs batched verdict throughput
    "filter_throughput": filter_throughput,
    # emits BENCH_refine.json: sequential vs batched refinement throughput
    "refinement": refinement,
    # emits BENCH_mbr.json: sequential vs batched candidate generation
    "mbr_join": mbr_join,
    # emits BENCH_service.json: warm micro-batched serving vs cold joins
    "service_throughput": service_throughput,
    # emits BENCH_pipeline.json: fused single-dispatch chain vs staged
    "pipeline_e2e": pipeline_e2e,
    # emits BENCH_scaleout.json: cost-balanced tiling vs the static grid
    "scaleout": scaleout,
}


def main() -> None:
    smoke = smoke_requested()
    want = [a for a in sys.argv[1:] if a != "--smoke"]
    print("name,us_per_call,derived")
    for name, mod in SUITES.items():
        if want and not any(w in name for w in want):
            continue
        t0 = time.time()
        try:
            if smoke:
                if hasattr(mod, "smoke"):
                    mod.smoke()
                else:
                    print(f"# suite {name} has no smoke mode, skipped")
                    continue
            else:
                for line in mod.run():
                    print(line)
        except Exception as e:  # keep the suite going; surface the failure
            print(f"{name}_FAILED,0,{e!r}")
        print(f"# suite {name} took {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
