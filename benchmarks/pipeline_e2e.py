"""BENCH_pipeline: fused single-dispatch chain vs staged stage boundaries.

The ISSUE-8 acceptance gate: ``JoinPlan(pipeline_mode="fused")`` runs the
whole MBR -> filter -> refine chain device-resident (DESIGN.md §12) —
on-device compaction between stages, one sanctioned host sync at the end —
and must sustain >= 1.0x the end-to-end wall-clock of the staged chain
with ``verdicts_equal`` true: fusing the boundaries is an execution
detail that never changes results (same pairs, same ORDER).
``benchmarks/run.py`` persists the result as BENCH_pipeline.json and
``tools/check_bench.py`` guards the committed artifact in CI.

``python -m benchmarks.pipeline_e2e --smoke`` is the CI quick-lane check:
fused results are bitwise identical to staged for every filter method on
intersects/within, plus empty and degenerate candidate frames through the
compaction kernels.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.datagen import make_dataset
from repro.spatial import JoinPlan

from .common import row, sync

N_ORDER = 8
REPEATS = 5


def _plan(R, S, mode: str, method: str = "april") -> JoinPlan:
    plan = JoinPlan(R, S, filter=method, n_order=N_ORDER,
                    pipeline_mode=mode)
    plan.build()
    return plan


def _time_mode(plan: JoinPlan, predicate: str) -> tuple[np.ndarray, float]:
    pairs, _ = plan.execute(predicate)      # warm-up: jit compile + caches
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        pairs, _ = sync(plan.execute(predicate))
    return pairs, (time.perf_counter() - t0) / REPEATS


def bench_pipeline(method: str = "april"):
    R = make_dataset("T1", seed=11, count=420)
    S = make_dataset("T2", seed=12, count=560)

    staged = _plan(R, S, "staged", method)
    fused = _plan(R, S, "fused", method)

    # the gated headline: the paper's core intersection join, where the
    # candidate frame is large enough that the staged chain's three host
    # round-trips dominate. Tiny frames (this workload's `within` sees ~34
    # candidates) stay faster staged — which is why staged is the default —
    # so within contributes identity, not a gated speedup.
    p_staged, t_staged = _time_mode(staged, "intersects")
    p_fused, t_fused = _time_mode(fused, "intersects")
    equal = np.array_equal(p_staged, p_fused)
    stages = fused.last_stats.stage_times()

    w_staged, tw_staged = _time_mode(staged, "within")
    w_fused, tw_fused = _time_mode(fused, "within")
    equal &= np.array_equal(w_staged, w_fused)
    assert equal, "fused verdicts diverged from staged"

    return {
        "dataset": "T1 x T2", "method": method, "n_order": N_ORDER,
        "repeats": REPEATS,
        "t_staged_s": round(t_staged, 5),
        "t_fused_s": round(t_fused, 5),
        "n_results": int(len(p_staged)),
        "speedup_fused_over_staged": round(t_staged / max(t_fused, 1e-9), 2),
        "within_identity": {
            "t_staged_s": round(tw_staged, 5),
            "t_fused_s": round(tw_fused, 5),
            "n_results": int(len(w_staged)),
        },
        "fused_stage_times_s": {k: round(v, 5) for k, v in stages.items()},
        "verdicts_equal": bool(equal),
    }


def smoke() -> None:
    """CI quick lane: fused == staged bitwise (pairs AND order) for every
    filter method on intersects/within, and the degenerate frames — empty
    candidate set, single-object datasets — survive the compaction
    kernels."""
    from repro.spatial.filters import available_filters

    R = make_dataset("T1", seed=31, count=70)
    S = make_dataset("T2", seed=32, count=90)
    for method in available_filters():
        for predicate in ("intersects", "within"):
            ref, _ = _plan(R, S, "staged", method).execute(predicate)
            got, stats = _plan(R, S, "fused", method).execute(predicate)
            assert np.array_equal(ref, got), (method, predicate)
            assert stats.pipeline_mode == "fused"
        print(f"pipeline smoke ok: {method} fused == staged")

    # degenerate frames: far-apart single polygons -> empty candidate set;
    # identical single polygons -> every lane survives to refinement
    from repro.datagen.synthetic import PolygonDataset
    sq = np.array([[0.1, 0.1], [0.2, 0.1], [0.2, 0.2], [0.1, 0.2]])
    one = PolygonDataset(name="a", verts=sq[None], nverts=np.array([4]))
    far = PolygonDataset(name="b", verts=sq[None] + 0.6, nverts=np.array([4]))
    for other, n_exp in ((far, 0), (one, 1)):
        ref, _ = _plan(one, other, "staged").execute("intersects")
        got, _ = _plan(one, other, "fused").execute("intersects")
        assert np.array_equal(ref, got) and len(got) == n_exp
    print("pipeline smoke ok: empty + degenerate candidate frames")


def run():
    res = bench_pipeline()
    with open("BENCH_pipeline.json", "w") as f:
        json.dump(res, f, indent=2)
    return [row("pipeline_e2e_intersects", 1e6 * res["t_fused_s"],
                f"staged_us={1e6 * res['t_staged_s']:.1f};"
                f"results={res['n_results']};"
                f"speedup={res['speedup_fused_over_staged']}")]


if __name__ == "__main__":
    from .common import bench_main
    bench_main(run, smoke)
