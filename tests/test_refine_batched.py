"""Batched refinement subsystem (DESIGN.md §7): every backend must be
verdict-identical to the per-pair f64 sequential reference on every
predicate, including boundary-touching, collinear-edge and shared-vertex
geometry; plus the ISSUE-3 boundary-touch regressions and the sharded
(distributed) refinement path."""
import numpy as np
import pytest

from repro.core import geometry
from repro.datagen import make_dataset, make_linestrings
from repro.datagen.synthetic import PolygonDataset
from repro.spatial import JoinPlan, refine
from repro.spatial.distributed import distributed_refine

BATCHED = ("numpy", "jnp", "pallas")


@pytest.fixture(scope="module")
def rs():
    return (make_dataset("T1", seed=31, count=80),
            make_dataset("T10", seed=32, count=50))


@pytest.fixture(scope="module")
def poly_pairs(rs):
    R, S = rs
    return JoinPlan(R, S, filter="none").candidates("intersects")


# ---------------------------------------------------------------- identity

@pytest.mark.parametrize("backend", BATCHED)
def test_intersects_verdict_identical(rs, poly_pairs, backend):
    R, S = rs
    pairs = poly_pairs if backend != "pallas" else poly_pairs[:64]
    want = refine.refine_pairs_seq(R, S, pairs)
    got = refine.refine_pairs(R, S, pairs, backend=backend)
    assert want.sum() > 0 and (~want).sum() > 0
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BATCHED)
def test_within_verdict_identical(rs, poly_pairs, backend):
    R, S = rs
    pairs = poly_pairs if backend != "pallas" else poly_pairs[:64]
    want = refine.refine_within_pairs_seq(R, S, pairs)
    got = refine.refine_within_pairs(R, S, pairs, backend=backend)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", BATCHED)
def test_linestring_verdict_identical(rs, backend):
    _, S = rs
    L = make_linestrings(seed=33, count=120)
    pairs = JoinPlan(L, S, filter="none",
                     r_kind="line").candidates("linestring")
    if backend == "pallas":
        pairs = pairs[:64]
    want = refine.refine_line_poly_pairs_seq(L, S, pairs)
    got = refine.refine_line_poly_pairs(L, S, pairs, backend=backend)
    assert want.sum() > 0
    np.testing.assert_array_equal(got, want)


def test_selection_dispatch_matches_intersects(rs, poly_pairs):
    R, S = rs
    np.testing.assert_array_equal(
        refine.refine(R, S, poly_pairs, predicate="selection"),
        refine.refine_pairs_seq(R, S, poly_pairs))


def test_unknown_backend_rejected(rs):
    R, S = rs
    with pytest.raises(ValueError, match="refine backend"):
        refine.refine_pairs(R, S, np.zeros((1, 2), np.int64), backend="tpu")


# ----------------------------------------------- boundary-touch geometry

def _ds(verts_list):
    V = max(len(v) for v in verts_list)
    verts = np.zeros((len(verts_list), V, 2))
    nv = np.zeros(len(verts_list), np.int64)
    for i, v in enumerate(verts_list):
        verts[i, : len(v)] = v
        nv[i] = len(v)
    return PolygonDataset(name="fixture", verts=verts, nverts=nv)


def test_touchy_geometry_all_backends():
    """Shared-vertex, collinear-shared-edge, exact-on-edge and containment
    contacts: batched backends agree with the sequential oracle."""
    sq = np.array([[0., 0.], [4., 0.], [4., 4.], [0., 4.]])
    R = _ds([
        sq + np.array([4.0, 0.0]),          # shares the x=4 edge
        sq + np.array([4.0, 4.0]),          # shares only the corner (4,4)
        np.array([[2., 4.], [3., 3.], [1., 3.]]),    # vertex on top edge
        np.array([[1., 1.], [3., 1.], [2., 3.]]),    # strictly inside
        sq,                                  # identical polygon
        sq + np.array([10., 10.]),           # disjoint
        np.array([[-1., -1.], [5., -1.], [5., 5.], [-1., 5.]]),  # contains
    ])
    S = _ds([sq] * len(R))
    pairs = np.stack([np.arange(len(R)), np.arange(len(R))], axis=1)
    want = refine.refine_pairs_seq(R, S, pairs)
    np.testing.assert_array_equal(
        want, [True, True, True, True, True, False, True])
    for backend in BATCHED:
        got = refine.refine_pairs(R, S, pairs, backend=backend)
        np.testing.assert_array_equal(got, want, err_msg=backend)
    # within with boundary contact: inner triangle touching the top edge
    w_want = refine.refine_within_pairs_seq(R, S, pairs)
    assert bool(w_want[2]) and bool(w_want[4])   # touching + identical
    for backend in BATCHED:
        got = refine.refine_within_pairs(R, S, pairs, backend=backend)
        np.testing.assert_array_equal(got, w_want, err_msg=backend)


# ------------------------------------------------- ISSUE-3 regressions

def test_regression_touching_containment_first_vertex():
    """A polygon whose first vertex is snapped onto the other's (diagonal)
    boundary used to refine False: the sweep sees no crossing and the old
    first-vertex crossing-parity fallback misclassified the snapped vertex
    outside. The exact-rational truth on the stored floats is True."""
    from repro.datagen.fixtures import SNAPPED_HOST, SNAPPED_TRI
    assert geometry.polygons_intersect(SNAPPED_TRI, 3, SNAPPED_HOST, 8)
    R, S = _ds([SNAPPED_TRI]), _ds([SNAPPED_HOST])
    pairs = np.asarray([[0, 0]], np.int64)
    for backend in ("sequential",) + BATCHED:
        assert refine.refine_pairs(R, S, pairs, backend=backend)[0], backend


def test_regression_within_concave_container():
    """'r within s' with a concave container: the old on-boundary fallback
    nudged vertices toward the container centroid, which lies OUTSIDE a
    C-shaped container — a false negative for a touching inner polygon."""
    from repro.datagen.fixtures import CSHAPE, CSHAPE_INNER
    cshape, inner = CSHAPE, CSHAPE_INNER               # vertex on y=2 edge
    assert geometry.polygon_within(inner, 3, cshape, 8)
    # convex containers must keep working
    sq = np.array([[0., 0.], [10., 0.], [10., 10.], [0., 10.]])
    top = np.array([[6., 10.], [7., 8.5], [5., 8.5]])
    assert geometry.polygon_within(top, 3, sq, 4)
    # and a genuinely outside polygon must not be 'within'
    out = inner + np.array([0.0, 2.5])                 # pokes into the cavity
    assert not geometry.polygon_within(out, 3, cshape, 8)
    R, S = _ds([inner]), _ds([cshape])
    pairs = np.asarray([[0, 0]], np.int64)
    for backend in ("sequential",) + BATCHED:
        assert refine.refine_within_pairs(R, S, pairs,
                                          backend=backend)[0], backend


def test_pallas_short_edge_guard_band():
    """f64 -> f32 casting perturbs coordinates by ~eps32 * |coord| — an
    absolute error the old edge-length-relative guard band missed for
    short edges away from the origin. Tiny near-touching polygons at
    O(1) coordinates must still be verdict-identical (borderline pairs
    escalate to host)."""
    rng = np.random.default_rng(19)
    polys_r, polys_s = [], []
    for i in range(24):
        c = rng.uniform(0.3, 0.7, 2)
        r1, r2 = rng.uniform(2e-5, 8e-5, 2)

        def star(cc, r, nv):
            ang = np.sort(rng.uniform(0, 2 * np.pi, nv))
            return np.stack([cc[0] + r * np.cos(ang),
                             cc[1] + r * np.sin(ang)], axis=1)

        ps = star(c, r1, 8)
        pr = star(c + rng.uniform(-1, 1, 2) * (r1 + r2) * 0.8, r2, 7)
        if i % 2 == 0:      # snap a vertex onto an edge: exact touching
            t = rng.uniform(0, 1)
            pr[0] = ps[0] + t * (ps[1] - ps[0])
        polys_r.append(pr)
        polys_s.append(ps)
    R, S = _ds(polys_r), _ds(polys_s)
    pairs = np.stack([np.arange(len(R)), np.arange(len(R))], axis=1)
    want = refine.refine_pairs_seq(R, S, pairs)
    got = refine.refine_pairs(R, S, pairs, backend="pallas")
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        refine.refine_within_pairs(R, S, pairs, backend="pallas"),
        refine.refine_within_pairs_seq(R, S, pairs))


def test_regression_jnp_fma_guard_band():
    """XLA contracts mul+add into FMAs below HLO (optimization_barrier does
    not survive to LLVM), flipping a near-zero orientation sign on this
    fuzz-found snapped-vertex pair: the jitted jnp within-verdict disagreed
    with the sequential oracle. The guard band must escalate it to host."""
    va = np.array([
        [0.46821126201099456, 0.33001897689418036],
        [0.4595537937791133, 0.3350787644582686],
        [0.4592356227004228, 0.3329649341949457],
        [0.4596606610281497, 0.33099007529253766],
        [0.45616671890794774, 0.33252371036844647],
        [0.45623553878792783, 0.33048644467627664],
        [0.45969407452675615, 0.32471573049690555],
        [0.4609399563810834, 0.3250079025220754],
        [0.4717620978321982, 0.3274392233419345],
        [0.4626992907961244, 0.324031668283713],
        [0.46705223951997354, 0.32491571012657894],
        [0.46662147259952713, 0.3273967831499829]])
    vb = np.array([
        [0.4752340142333326, 0.3327771686923501],
        [0.47062455687358307, 0.33128636458924227],
        [0.468976987931185, 0.3401287235421079],
        [0.4621100503439218, 0.33613973562982113],
        [0.458980197448991, 0.3379977083450747],
        [0.45152906086282973, 0.33208269891216996],
        [0.4627947747182639, 0.3206307916141646],
        [0.4686857145345563, 0.32272521209315136],
        [0.46794202990619516, 0.325202662712839],
        [0.46984918890693217, 0.32449819535518454]])
    R, S = _ds([va]), _ds([vb])
    pairs = np.asarray([[0, 0]], np.int64)
    want = refine.refine_within_pairs_seq(R, S, pairs)
    for backend in BATCHED:
        got = refine.refine_within_pairs(R, S, pairs, backend=backend)
        np.testing.assert_array_equal(got, want, err_msg=backend)
    got, _ = distributed_refine(R, S, pairs, predicate="within")
    np.testing.assert_array_equal(got, want, err_msg="distributed")


# ------------------------------------------------------ plan + sharded

def test_joinplan_refine_backend_wiring(rs):
    R, S = rs
    ref = None
    for rb in ("sequential", "numpy", "jnp"):
        plan = JoinPlan(R, S, filter="april", n_order=7, refine_backend=rb)
        res, stats = plan.build().execute("intersects")
        assert stats.refine_backend == rb
        assert rb in stats.row()
        key = set(map(tuple, res.tolist()))
        ref = key if ref is None else ref
        assert key == ref, rb
    with pytest.raises(ValueError, match="refine backend"):
        JoinPlan(R, S, refine_backend="bogus")


def test_distributed_refine_matches_host(rs, poly_pairs):
    R, S = rs
    want = refine.refine_pairs(R, S, poly_pairs)
    got, counts = distributed_refine(R, S, poly_pairs)
    np.testing.assert_array_equal(got, want)
    assert counts["refined_true"] == int(want.sum())
    w_want = refine.refine_within_pairs(R, S, poly_pairs)
    w_got, _ = distributed_refine(R, S, poly_pairs, predicate="within")
    np.testing.assert_array_equal(w_got, w_want)


def test_distributed_refine_linestring(rs):
    _, S = rs
    L = make_linestrings(seed=34, count=60)
    pairs = JoinPlan(L, S, filter="none",
                     r_kind="line").candidates("linestring")
    want = refine.refine_line_poly_pairs(L, S, pairs)
    got, _ = distributed_refine(L, S, pairs, predicate="linestring")
    np.testing.assert_array_equal(got, want)


def test_launcher_sharded_refine_matches_host_refine():
    from repro.launch.spatial_join import run_join
    res_a, _ = run_join("T1", "T2", n_order=7, parts=2, seed=3,
                        count_r=40, count_s=60, refine_backend="numpy")
    res_b, _ = run_join("T1", "T2", n_order=7, parts=2, seed=3,
                        count_r=40, count_s=60, refine_backend="jnp")
    assert (set(map(tuple, np.asarray(res_a).tolist()))
            == set(map(tuple, np.asarray(res_b).tolist())))
