"""Out-of-core tiled scale-out driver (DESIGN.md §14).

The §14 contract: tiling, cost-balanced packing, skew splitting, and
checkpoint-resume are *execution* details — the verdict set is identical
to the in-memory `JoinPlan` reference for every filter method and every
predicate, a kill mid-run resumes to the same results, and planning is
deterministic.
"""
import shutil

import numpy as np
import pytest

from repro.datagen import (iter_dataset_chunks, make_chunked_dataset,
                           make_linestrings)
from repro.spatial.filters import available_filters
from repro.spatial.plan import JoinPlan
from repro.spatial.planner import ProfileCache
from repro.spatial.scaleout import (SCALEOUT_DEFAULTS, plan_scaleout,
                                    tiled_join)

COUNT_R, COUNT_S, CHUNK = 280, 400, 100
N_ORDER = 7
# small budget + low split threshold: several tiles AND skew splits fire;
# total estimated resident bytes exceed 4x this budget (asserted below)
TILED = dict(tile_budget=150_000, split_factor=1.0, min_split_objs=32)


def _chunks_r():
    return iter_dataset_chunks("T1", seed=5, count=COUNT_R, chunk_size=CHUNK)


def _chunks_s():
    return iter_dataset_chunks("T2", seed=6, count=COUNT_S, chunk_size=CHUNK)


def _mem_r():
    return make_chunked_dataset("T1", seed=5, count=COUNT_R, chunk_size=CHUNK)


def _mem_s():
    return make_chunked_dataset("T2", seed=6, count=COUNT_S, chunk_size=CHUNK)


def _pairs_set(pairs):
    return set(map(tuple, np.asarray(pairs).tolist()))


def _reference(predicate, method, **kw):
    plan = JoinPlan(_mem_r(), _mem_s(), filter=method, n_order=N_ORDER, **kw)
    pairs, _ = plan.execute(predicate)
    return _pairs_set(pairs)


# ---------------------------------------------------------------------------
# Verdict identity: every filter method x predicate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(available_filters()))
@pytest.mark.parametrize("predicate", ["intersects", "within", "selection"])
def test_tiled_identity_every_method_predicate(method, predicate):
    ref = _reference(predicate, method)
    pairs, stats = tiled_join(_chunks_r(), _chunks_s(), predicate=predicate,
                              method=method, n_order=N_ORDER, **TILED)
    assert _pairs_set(pairs) == ref
    assert stats.tiles > 1, "workload must actually tile"
    assert stats.n_results == len(pairs)


@pytest.mark.parametrize("method", sorted(available_filters()))
def test_tiled_identity_linestring(method):
    L = make_linestrings(seed=7, count=150)
    S = _mem_s()
    ref, _ = JoinPlan(L, S, filter=method, n_order=N_ORDER,
                      r_kind="line").execute("linestring")
    # in-memory datasets auto-chunk through the same streaming spill path
    pairs, stats = tiled_join(L, S, predicate="linestring", method=method,
                              n_order=N_ORDER, r_kind="line", **TILED)
    assert _pairs_set(pairs) == _pairs_set(ref)
    assert stats.tiles >= 1


def test_tiled_identity_static_balance():
    ref = _reference("intersects", "april")
    pairs, stats = tiled_join(_chunks_r(), _chunks_s(), method="april",
                              n_order=N_ORDER, balance="static",
                              tile_budget=TILED["tile_budget"])
    assert _pairs_set(pairs) == ref
    assert stats.extra["tile_plan"]["n_splits"] == 0


def test_tiled_identity_adaptive_with_profile_cache():
    ref = _reference("intersects", "april")
    cache = ProfileCache()
    pairs, stats = tiled_join(_chunks_r(), _chunks_s(), method="april",
                              n_order=N_ORDER, plan_mode="adaptive",
                              profile_cache=cache, **TILED)
    assert _pairs_set(pairs) == ref
    cs = stats.extra["profile_cache"]
    assert cs["hits"] + cs["misses"] >= stats.tiles - 1
    assert len(cache) == cs["misses"]


def test_tiled_workload_exceeds_budget_4x(tmp_path):
    """The acceptance-criteria shape: total resident bytes >= 4x the tile
    budget, so the driver genuinely spills and streams."""
    plan, _, _ = plan_scaleout(_chunks_r(), _chunks_s(),
                               spill_dir=str(tmp_path), n_order=N_ORDER,
                               **TILED)
    total = sum(p.est["bytes"] for p in plan.parts)
    assert total >= 4 * TILED["tile_budget"]
    assert len(plan.tiles) >= 4


# ---------------------------------------------------------------------------
# Skew split determinism
# ---------------------------------------------------------------------------

def test_plan_scaleout_deterministic(tmp_path):
    p1, _, tot1 = plan_scaleout(_chunks_r(), _chunks_s(),
                                spill_dir=str(tmp_path / "a"),
                                n_order=N_ORDER, **TILED)
    p2, _, tot2 = plan_scaleout(_chunks_r(), _chunks_s(),
                                spill_dir=str(tmp_path / "b"),
                                n_order=N_ORDER, **TILED)
    assert tot1 == tot2 == (COUNT_R, COUNT_S)
    assert p1.to_dict() == p2.to_dict()
    assert p1.est["n_splits"] > 0, "skew split must fire on this workload"
    # children of a split carry depth > 0 and strictly smaller tiles
    deep = [p for p in p1.parts if p.depth > 0]
    assert deep
    for p in deep:
        assert (p.tile[2] - p.tile[0]) <= 0.5 / SCALEOUT_DEFAULTS[
            "parts_per_dim"] + 1e-12


def test_tile_packing_respects_budget(tmp_path):
    plan, _, _ = plan_scaleout(_chunks_r(), _chunks_s(),
                               spill_dir=str(tmp_path), n_order=N_ORDER,
                               **TILED)
    for tile in plan.tiles:
        load = sum(plan.parts[i].est["bytes"] for i in tile)
        # single oversized partitions may ride alone above budget;
        # multi-partition tiles must fit
        if len(tile) > 1:
            assert load <= TILED["tile_budget"]
    covered = sorted(i for t in plan.tiles for i in t)
    assert covered == list(range(len(plan.parts)))


# ---------------------------------------------------------------------------
# Kill-and-resume: interrupted run + resume == uninterrupted verdict set
# ---------------------------------------------------------------------------

def test_kill_and_resume_identical_verdicts(tmp_path):
    ref = _reference("intersects", "april")
    ck = str(tmp_path / "ck")

    partial, st_part = tiled_join(_chunks_r(), _chunks_s(), method="april",
                                  n_order=N_ORDER, ckpt_dir=ck,
                                  stop_after_tiles=2, **TILED)
    assert st_part.extra["interrupted"] is True
    assert _pairs_set(partial) < ref, "partial run must be a strict subset"

    resumed, st_res = tiled_join(_chunks_r(), _chunks_s(), method="april",
                                 n_order=N_ORDER, ckpt_dir=ck, **TILED)
    assert st_res.extra["resumed_tiles"] == 2
    assert "interrupted" not in st_res.extra
    assert _pairs_set(resumed) == ref
    # resumed counters equal a clean run's (restored from the manifest)
    clean, st_clean = tiled_join(_chunks_r(), _chunks_s(), method="april",
                                 n_order=N_ORDER, **TILED)
    assert st_res.n_candidates == st_clean.n_candidates
    assert st_res.n_indecisive == st_clean.n_indecisive


def test_resume_fingerprint_guard(tmp_path):
    """A manifest from a different configuration must NOT be resumed."""
    ck = str(tmp_path / "ck")
    tiled_join(_chunks_r(), _chunks_s(), method="april", n_order=N_ORDER,
               ckpt_dir=ck, stop_after_tiles=1, **TILED)
    pairs, stats = tiled_join(_chunks_r(), _chunks_s(), method="ri",
                              n_order=N_ORDER, ckpt_dir=ck, **TILED)
    assert stats.extra["resumed_tiles"] == 0
    assert _pairs_set(pairs) == _reference("intersects", "ri")


def test_resume_false_starts_fresh(tmp_path):
    ck = str(tmp_path / "ck")
    tiled_join(_chunks_r(), _chunks_s(), method="april", n_order=N_ORDER,
               ckpt_dir=ck, stop_after_tiles=1, **TILED)
    pairs, stats = tiled_join(_chunks_r(), _chunks_s(), method="april",
                              n_order=N_ORDER, ckpt_dir=ck, resume=False,
                              **TILED)
    assert stats.extra["resumed_tiles"] == 0
    assert _pairs_set(pairs) == _reference("intersects", "april")


# ---------------------------------------------------------------------------
# Streamed datagen
# ---------------------------------------------------------------------------

def test_chunk_determinism_and_concat_identity():
    a = list(iter_dataset_chunks("T1", seed=9, count=330, chunk_size=128))
    b = list(iter_dataset_chunks("T1", seed=9, count=330, chunk_size=128))
    assert len(a) == 3 and sum(len(c) for c in a) == 330
    for ca, cb in zip(a, b):
        np.testing.assert_array_equal(ca.verts, cb.verts)
        np.testing.assert_array_equal(ca.nverts, cb.nverts)
    ds = make_chunked_dataset("T1", seed=9, count=330, chunk_size=128)
    off = 0
    for c in a:
        np.testing.assert_array_equal(ds.nverts[off:off + len(c)], c.nverts)
        np.testing.assert_allclose(
            ds.verts[off:off + len(c), : c.verts.shape[1]], c.verts)
        off += len(c)


def test_chunks_are_valid_polygons():
    for c in iter_dataset_chunks("T2", seed=1, count=200, chunk_size=64):
        assert (c.nverts >= 4).all()
        assert np.isfinite(c.verts).all()
        # padding rows zeroed (the batched-pipeline contract)
        mask = np.arange(c.verts.shape[1])[None, :] >= c.nverts[:, None]
        assert (c.verts[mask] == 0).all()
        assert (c.mbrs[:, 2] > c.mbrs[:, 0]).all()


# ---------------------------------------------------------------------------
# Stats plumbing (JoinStats §14 additions)
# ---------------------------------------------------------------------------

def test_stats_roundtrip_and_row():
    from repro.spatial.plan import JoinStats
    pairs, st = tiled_join(_chunks_r(), _chunks_s(), method="april",
                           n_order=N_ORDER, **TILED)
    assert st.tiles > 1 and st.t_partition > 0
    d = st.to_dict()
    back = JoinStats.from_dict(d)
    assert back.tiles == st.tiles
    assert back.t_partition == st.t_partition
    assert "t_partition" in st.stage_times()
    assert f"tiles={st.tiles}" in st.row()
    # non-tiled stats keep the old row shape and round-trip the defaults
    st0 = JoinStats(method="april")
    assert "tiles=" not in st0.row()
    assert JoinStats.from_dict(st0.to_dict()).tiles == 0


def test_profile_cache_buckets():
    c = ProfileCache()
    k1 = c.key("intersects", 1000, 1000, 5000)
    k2 = c.key("intersects", 1100, 950, 5400)   # same octave
    k3 = c.key("intersects", 1000, 1000, 90000)
    assert k1 == k2 and k1 != k3
    assert c.get(k1) is None
    from repro.spatial.planner import PlanChoice
    c.put(k1, PlanChoice())
    assert c.get(k2) is not None
    assert c.stats == {"hits": 1, "misses": 1}
