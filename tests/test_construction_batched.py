"""Batched dataset-level construction (DESIGN.md §6).

Two contracts:

* the out-of-extent bugfix — `dda_partial_cells` must match the brute-force
  `classify_window_oracle` for geometry straddling (or missing, or covering)
  a partition extent, instead of clamping out-of-extent traversal into the
  border row/column;
* batched == sequential — for all five filters the `build_backend="numpy"`
  (and 'jnp') stores must be *store-identical* (intervals / bits / grids /
  hulls, not just verdicts) to the per-polygon `build_backend="sequential"`
  reference.
"""
import numpy as np
import pytest

from repro.baselines import fivec_ch
from repro.baselines import ra as ra_mod
from repro.core import intervalize, rasterize, ri
from repro.core.partition import partition_space
from repro.core.rasterize import Extent
from repro.datagen import make_dataset, make_linestrings
from repro.spatial import get_filter

N_ORDER = 6
FILTERS = ("april", "april-c", "ri", "ra", "5cch")
BUILD_OPTS = {"ra": {"max_cells": 96}}

# the ISSUE's regression triangle: crosses the left extent boundary
TRI = np.array([[-0.5, 0.2], [0.3, 0.2], [0.3, 0.6]])


# ---------------------------------------------------------------------------
# out-of-extent rasterization (the clamping bugfix)
# ---------------------------------------------------------------------------

def test_dda_out_of_extent_no_clamped_column():
    """Clamping used to smear the out-of-extent hypotenuse into column 0
    (partials {3..7}); only the true crossings {3, 7} may remain."""
    cells = rasterize.dda_partial_cells(TRI, 3, 4)
    col0 = sorted(int(cy) for cx, cy in cells if cx == 0)
    assert col0 == [3, 7]


def _straddling_cases():
    ext = Extent(0.25, 0.25, 0.5)
    ds = make_dataset("T1", seed=21, count=10)
    cases = [(TRI, 3, rasterize.GLOBAL_EXTENT)]
    for i in range(len(ds)):
        # shift polygons toward the extent corners so many straddle it
        v = ds.polygon(i).copy()
        v[:, 0] += 0.22 * (i % 3 - 1)
        v[:, 1] += 0.22 * (i % 5 - 2) / 2
        cases.append((v, len(v), ext))
    # fully outside / fully covering
    cases.append((np.array([[1.2, 1.2], [1.4, 1.2], [1.3, 1.4]]), 3, ext))
    cases.append((np.array([[0., 0.], [1., 0.], [1., 1.], [0., 1.]]), 4,
                  Extent(0.4, 0.4, 0.1)))
    return cases


def test_dda_matches_oracle_straddling_extent():
    for v, n, ext in _straddling_cases():
        got = set(map(tuple, rasterize.dda_partial_cells(v, n, 5, ext)))
        want = set(map(tuple,
                       rasterize.classify_window_oracle(v, n, 5, ext)["partial"]))
        assert got == want, (v[:2], got ^ want)


def test_scanline_matches_oracle_straddling_extent():
    for v, n, ext in _straddling_cases():
        partial = rasterize.dda_partial_cells(v, n, 5, ext)
        full = rasterize.scanline_full_cells(v, n, partial, 5, ext)
        oracle = rasterize.classify_window_oracle(v, n, 5, ext)
        assert set(map(tuple, full)) == set(map(tuple, oracle["full"]))


def test_onestep_covering_polygon_is_whole_grid():
    """A polygon enclosing the entire raster area has no Partial cells; the
    virtual gap [0, 4^N) must classify Full, not drop the object."""
    ext = Extent(0.4, 0.4, 0.1)
    big = np.array([[0., 0.], [1., 0.], [1., 1.], [0., 1.]])
    for method in ("batched", "pips", "neighbors"):
        a, f = intervalize.onestep(big, 4, 5, ext, method=method)
        assert a.tolist() == [[0, 4 ** 5]] and f.tolist() == [[0, 4 ** 5]]
    far = np.array([[1.2, 1.2], [1.4, 1.2], [1.3, 1.4]])
    a, f = intervalize.onestep(far, 3, 5, ext)
    assert len(a) == 0 and len(f) == 0


# ---------------------------------------------------------------------------
# batched == sequential, store-level, all five filters
# ---------------------------------------------------------------------------

def _assert_store_equal(name, s, b):
    if name in ("april", "april-c") and hasattr(s, "a_bufs"):
        assert s.a_bufs == b.a_bufs and s.f_bufs == b.f_bufs
        return
    if name in ("april", "april-c") and hasattr(s, "a_off"):
        for f in ("a_off", "a_ints", "f_off", "f_ints"):
            np.testing.assert_array_equal(getattr(s, f), getattr(b, f), f)
        return
    if hasattr(s, "ids"):                      # LineCellStore
        np.testing.assert_array_equal(s.off, b.off)
        np.testing.assert_array_equal(s.ids, b.ids)
        return
    if name == "ri":
        for f in ("off", "ints", "bit_off", "bits"):
            np.testing.assert_array_equal(getattr(s, f), getattr(b, f), f)
        return
    if name == "ra":
        for f in ("k", "origin", "shape"):
            np.testing.assert_array_equal(getattr(s, f), getattr(b, f), f)
        assert len(s.cells) == len(b.cells)
        for i, (x, y) in enumerate(zip(s.cells, b.cells)):
            np.testing.assert_array_equal(x, y, f"grid {i}")
        return
    if name == "5cch":
        for f in ("pent", "hull_off", "hull_pts"):
            np.testing.assert_array_equal(getattr(s, f), getattr(b, f), f)
        return
    raise AssertionError(f"unknown store for {name}: {type(s)}")


@pytest.fixture(scope="module")
def poly_data():
    return make_dataset("T1", seed=31, count=50)


@pytest.fixture(scope="module")
def line_data():
    return make_linestrings(seed=32, count=40)


@pytest.mark.parametrize("name", FILTERS)
def test_batched_build_matches_sequential(poly_data, name):
    filt = get_filter(name)
    opts = BUILD_OPTS.get(name, {})
    seq = filt.build(poly_data, n_order=N_ORDER,
                     build_backend="sequential", **opts)
    bat = filt.build(poly_data, n_order=N_ORDER,
                     build_backend="numpy", **opts)
    _assert_store_equal(name, seq.store, bat.store)


@pytest.mark.parametrize("name", FILTERS)
def test_batched_line_build_matches_sequential(line_data, name):
    filt = get_filter(name)
    opts = BUILD_OPTS.get(name, {})
    seq = filt.build(line_data, n_order=N_ORDER, kind="line",
                     build_backend="sequential", **opts)
    bat = filt.build(line_data, n_order=N_ORDER, kind="line",
                     build_backend="numpy", **opts)
    _assert_store_equal(name, seq.store, bat.store)


@pytest.mark.parametrize("name", ("april", "ri", "ra"))
def test_jnp_build_backend_matches_sequential(poly_data, name):
    pytest.importorskip("jax")
    filt = get_filter(name)
    opts = BUILD_OPTS.get(name, {})
    seq = filt.build(poly_data, n_order=N_ORDER,
                     build_backend="sequential", **opts)
    bat = filt.build(poly_data, n_order=N_ORDER, build_backend="jnp", **opts)
    _assert_store_equal(name, seq.store, bat.store)


def test_unknown_build_backend_raises(poly_data):
    with pytest.raises(ValueError, match="unknown build_backend"):
        get_filter("april").build(poly_data, n_order=N_ORDER,
                                  build_backend="cuda")


def test_batched_build_on_straddling_dataset():
    """Per-partition semantics: geometry crossing the raster-area boundary
    must build identically (and per the oracle) in both paths."""
    ext = Extent(0.25, 0.25, 0.5)
    cases = _straddling_cases()
    V = max(n for _, n, _ in cases)
    verts = np.zeros((len(cases), V, 2))
    nv = np.zeros(len(cases), np.int64)
    for i, (v, n, _) in enumerate(cases):
        verts[i, :n] = v[:n]
        nv[i] = n
    from repro.datagen.synthetic import PolygonDataset
    ds = PolygonDataset(name="straddle", verts=verts, nverts=nv)
    seq = ri.build_ri(ds, 5, ext, backend="sequential")
    bat = ri.build_ri(ds, 5, ext, backend="numpy")
    for f in ("off", "ints", "bit_off", "bits"):
        np.testing.assert_array_equal(getattr(seq, f), getattr(bat, f), f)


def test_ri_size_bytes_matches_python_loop(poly_data):
    store = ri.build_ri(poly_data, N_ORDER)
    code_bytes = 0
    for g in range(len(store.ints)):
        nbits = int(store.bit_off[g + 1] - store.bit_off[g])
        code_bytes += (nbits + 7) // 8
    want = 4 * 2 * len(store.ints) + code_bytes + 8 * len(store.off)
    assert store.size_bytes() == want


def test_partition_parallel_build_matches_serial():
    R = make_dataset("T1", seed=41, count=50)
    S = make_dataset("T2", seed=42, count=60)
    parting = partition_space([R, S], 2)
    filt = get_filter("april")
    serial = parting.build_approx(filt, R, N_ORDER, parallel=False)
    threaded = parting.build_approx(filt, R, N_ORDER, parallel=True)
    assert len(serial) == len(threaded)
    for a, b in zip(serial, threaded):
        assert (a is None) == (b is None)
        if a is not None:
            _assert_store_equal("april", a.store, b.store)


def test_ra_fit_grid_multi_matches_scalar():
    ds = make_dataset("T3", seed=43, count=12)
    k, side, ox, oy, nx, ny = ra_mod._fit_grid_multi(ds.mbrs, 96,
                                                     1.0 / (1 << 16))
    for i in range(len(ds)):
        ref = ra_mod._fit_grid(ds.mbrs[i], 96, 1.0 / (1 << 16))
        assert (int(k[i]), float(side[i]), float(ox[i]), float(oy[i]),
                int(nx[i]), int(ny[i])) == ref


def test_box_clip_areas_matches_sequential_clip(poly_data):
    """The one-shot padded clip (public reference kernel) and the banded
    row driver must both equal clip_polygon_to_box + polygon_area per row."""
    from repro.core import geometry
    rng = np.random.default_rng(7)
    K = 200
    pid = rng.integers(0, len(poly_data), K)
    lo = rng.uniform(-0.01, 1.0, (K, 2))
    h = rng.uniform(0.001, 0.05, (K, 1))
    boxes = np.concatenate([lo, lo + h], axis=1)
    ref = np.zeros(K)
    for i in range(K):
        ring = geometry.clip_polygon_to_box(poly_data.polygon(pid[i]),
                                            tuple(boxes[i]))
        if len(ring) >= 3:
            ref[i] = geometry.polygon_area(ring)
    got = geometry.box_clip_areas(poly_data.verts[pid], poly_data.nverts[pid],
                                  boxes)
    np.testing.assert_array_equal(got, ref)
    got_rows = geometry.box_clip_areas_rows(poly_data.verts,
                                            poly_data.nverts, pid, boxes)
    np.testing.assert_array_equal(got_rows, ref)


def test_5cch_pentagon_batch_matches_scalar(poly_data):
    pent = fivec_ch._pentagons_multi(poly_data.verts, poly_data.nverts)
    for i in range(len(poly_data)):
        np.testing.assert_array_equal(pent[i],
                                      fivec_ch._pentagon(poly_data.polygon(i)))
