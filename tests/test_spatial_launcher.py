"""End-to-end distributed spatial-join launcher: correctness vs the
single-process pipeline + partition-checkpoint resume."""
import numpy as np

from repro.datagen import make_dataset
from repro.launch.spatial_join import run_join
from repro.spatial import spatial_intersection_join


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).tolist()))


def test_launcher_matches_pipeline(tmp_path):
    res, totals = run_join("T1", "T2", n_order=7, parts=2, seed=0,
                           count_r=60, count_s=90,
                           ckpt_dir=str(tmp_path / "ck"))
    R = make_dataset("T1", seed=0, count=60)
    S = make_dataset("T2", seed=1, count=90)
    ref, _ = spatial_intersection_join(R, S, method="none")
    assert _pairs_set(res) == _pairs_set(ref)
    assert totals["true_neg"] > 0

    # resume from checkpoint: all partitions done -> same results, no rework
    res2, _ = run_join("T1", "T2", n_order=7, parts=2, seed=0,
                       count_r=60, count_s=90,
                       ckpt_dir=str(tmp_path / "ck"))
    assert _pairs_set(res2) == _pairs_set(ref)


def test_launcher_adaptive_plan_matches_pipeline():
    # per-partition planning (DESIGN.md §13): no global prebuilt stores,
    # each partition picks its own config, results identical to the
    # refine-everything reference
    res, totals = run_join("T1", "T2", n_order=7, parts=2, seed=0,
                           count_r=60, count_s=90, plan_mode="adaptive")
    R = make_dataset("T1", seed=0, count=60)
    S = make_dataset("T2", seed=1, count=90)
    ref, _ = spatial_intersection_join(R, S, method="none")
    assert _pairs_set(res) == _pairs_set(ref)
