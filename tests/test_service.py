"""JoinService (DESIGN.md §10): micro-batched serving == per-request runs,
LRU store cache accounting, incremental mutations through the serving
path, JoinStats JSON envelope, and CheckpointManager restoring spatial
stores via the extra-dict path."""
import json

import numpy as np
import pytest

from repro.datagen import make_dataset
from repro.datagen.synthetic import PolygonDataset
from repro.runtime.checkpoint import CheckpointManager
from repro.spatial import (JoinPlan, JoinService, JoinStats, StoreCache,
                           get_filter)

N_ORDER = 6


def _one(Q, i):
    nv = int(Q.nverts[i])
    return PolygonDataset(name=f"q{i}", verts=Q.verts[i: i + 1, :nv],
                          nverts=Q.nverts[i: i + 1])


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).reshape(-1, 2).tolist()))


@pytest.fixture(scope="module")
def data():
    return (make_dataset("T1", seed=51, count=80),
            make_dataset("T2", seed=52, count=10))


# ---------------------------------------------------------------------------
# Micro-batching
# ---------------------------------------------------------------------------

def test_microbatch_matches_per_request(data):
    D, Q = data
    svc = JoinService(method="april", n_order=N_ORDER)
    svc.register_dataset("d", D)
    tickets = {}
    for predicate in ("selection", "intersects", "within"):
        tickets[predicate] = [
            svc.submit("d", predicate, Q.verts[i, : Q.nverts[i]])
            for i in range(len(Q))]
    # all predicates pending at once: one drain, one batched pass per group
    assert svc.drain() == 3 * len(Q)
    assert svc.stats["batches"] == 3
    for predicate, ts in tickets.items():
        for i, t in enumerate(ts):
            ref, _ = JoinPlan(D, _one(Q, i), filter="april",
                              n_order=N_ORDER).execute(predicate)
            assert _pairs_set(t.wait(5.0).pairs) == _pairs_set(ref), \
                (predicate, i)
            assert t.stats["predicate"] == predicate
            assert t.stats["extra"]["batched_requests"] == len(Q)
            assert t.latency is not None and t.latency >= 0
    lat = svc.latency_stats()
    assert lat["n"] == 3 * len(Q)
    assert lat["p99_s"] >= lat["p50_s"] >= 0


def test_window_is_selection_on_rect_polygon(data):
    D, _ = data
    svc = JoinService(method="ri", n_order=N_ORDER)
    svc.register_dataset("d", D)
    t = svc.submit("d", "window", (0.2, 0.3, 0.7, 0.8))
    svc.drain()
    rect = np.array([[0.2, 0.3], [0.7, 0.3], [0.7, 0.8], [0.2, 0.8]])
    ref, _ = JoinPlan(D, PolygonDataset(name="w", verts=rect[None],
                                        nverts=np.array([4])),
                      filter="ri", n_order=N_ORDER).execute("selection")
    assert _pairs_set(t.wait(5.0).pairs) == _pairs_set(ref)


def test_background_worker_resolves_tickets(data):
    D, Q = data
    svc = JoinService(method="april", n_order=N_ORDER, window_s=0.01)
    svc.register_dataset("d", D)
    svc.start()
    try:
        tickets = [svc.submit("d", "selection", Q.verts[i, : Q.nverts[i]])
                   for i in range(4)]
        for t in tickets:
            t.wait(10.0)
        assert all(t.pairs is not None for t in tickets)
    finally:
        svc.stop()


def test_submit_validation(data):
    D, Q = data
    svc = JoinService()
    svc.register_dataset("d", D)
    with pytest.raises(ValueError, match="unknown predicate"):
        svc.submit("d", "crosses", Q.verts[0, : Q.nverts[0]])
    with pytest.raises(KeyError, match="unknown dataset"):
        svc.submit("nope", "selection", Q.verts[0, : Q.nverts[0]])


# ---------------------------------------------------------------------------
# Store cache
# ---------------------------------------------------------------------------

def test_store_cache_hits_and_reuse(data):
    D, Q = data
    svc = JoinService(method="april", n_order=N_ORDER)
    svc.register_dataset("d", D)
    for i in range(3):
        svc.submit("d", "selection", Q.verts[i, : Q.nverts[i]])
        svc.drain()
    # one miss (the cold build), then warm hits
    assert svc.cache.stats["misses"] == 1
    assert svc.cache.stats["hits"] == 2
    assert svc.cache.stats["resident_bytes"] > 0


def test_store_cache_lru_eviction():
    cache = StoreCache(budget_bytes=1)   # everything evicts everything
    D = make_dataset("T1", seed=53, count=10)
    filt = get_filter("april")
    a = filt.build(D, n_order=N_ORDER)
    b = filt.build(D, n_order=N_ORDER + 1)
    cache.put(("d", "april", N_ORDER), a)
    cache.put(("d", "april", N_ORDER + 1), b)
    assert cache.stats["evictions"] == 1
    assert cache.get(("d", "april", N_ORDER)) is None
    assert cache.get(("d", "april", N_ORDER + 1)) is b
    assert len(cache) == 1


def test_store_cache_rejects_bad_budget():
    with pytest.raises(ValueError, match="budget_bytes"):
        StoreCache(budget_bytes=0)


# ---------------------------------------------------------------------------
# Incremental mutations through the serving path
# ---------------------------------------------------------------------------

def test_mutations_replay_into_warm_store(data):
    D, Q = data
    svc = JoinService(method="april", n_order=N_ORDER)
    svc.register_dataset("d", D)
    svc.warm_store("d")                      # cold build BEFORE mutations
    new_poly = Q.verts[0, : Q.nverts[0]] * 0.7 + 0.15
    new_id = svc.insert("d", new_poly)
    assert new_id == len(D)
    svc.delete("d", 2)
    t = svc.submit("d", "selection", Q.verts[1, : Q.nverts[1]])
    svc.drain()
    # warm patched store answers like a fresh plan over the mutated dataset
    ref, _ = JoinPlan(svc.dataset("d"), _one(Q, 1), filter="april",
                      n_order=N_ORDER).execute("selection")
    assert _pairs_set(t.wait(5.0).pairs) == _pairs_set(ref)
    assert svc.cache.stats["misses"] == 1    # never rebuilt


# ---------------------------------------------------------------------------
# JoinStats envelope (the service response format)
# ---------------------------------------------------------------------------

def test_join_stats_json_round_trip(data):
    D, Q = data
    _, stats = JoinPlan(D, Q, filter="april", n_order=N_ORDER).execute()
    d = stats.to_dict()
    assert d["t_build"] == stats.t_build     # headline serving metric
    assert d["t_total"] == stats.t_total
    back = JoinStats.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    assert back.n_results == stats.n_results
    assert back.filter_backend == stats.filter_backend


def test_join_plan_backend_alias_warns(data):
    D, Q = data
    with pytest.warns(DeprecationWarning, match="deprecated alias"):
        plan = JoinPlan(D, Q, filter="none", backend="numpy")
    assert plan.filter_backend == "numpy"


# ---------------------------------------------------------------------------
# CheckpointManager: spatial stores through the extra-dict path
# ---------------------------------------------------------------------------

def test_checkpoint_extra_dict_round_trip(tmp_path):
    """The extra dict rides the JSON manifest: store metadata and the
    mutation log must survive save -> restore verbatim."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    extra = {"stores": [{"dataset_id": "d", "method": "april",
                         "n_order": 6, "seq": 2}],
             "datasets": {"d": {"log": [["delete", 3]]}}}
    mgr.save(1, {"x": np.arange(4)}, extra=extra)
    step, flat, got = mgr.restore()
    assert step == 1
    assert got == extra
    assert np.array_equal(flat["x"], np.arange(4))


def test_service_checkpoint_restore_verdict_identity(data, tmp_path):
    """save -> restore -> identical verdicts, including mutations that
    postdate the persisted store (replayed from the extra-dict log)."""
    D, Q = data
    for method in ("april", "ri"):           # the persisted-array stores
        svc = JoinService(method=method, n_order=N_ORDER)
        svc.register_dataset("d", D)
        svc.warm_store("d")
        svc.insert("d", Q.verts[0, : Q.nverts[0]] * 0.8 + 0.1)
        svc.delete("d", 5)
        # checkpoint AFTER the mutations but with the store synced earlier:
        # warm_store above synced to seq 0; mutations are pending replay
        mgr = CheckpointManager(str(tmp_path / method), async_save=False)
        svc.save_checkpoint(mgr, step=7)

        restored = JoinService.restore_checkpoint(mgr)
        assert restored is not None
        key = ("d", method, N_ORDER)
        assert key in restored.cache         # store came back warm
        assert restored.cache.get(key).meta["mutation_seq"] == 0
        t = restored.submit("d", "selection", Q.verts[1, : Q.nverts[1]])
        restored.drain()
        ref, _ = JoinPlan(svc.dataset("d"), _one(Q, 1), filter=method,
                          n_order=N_ORDER).execute("selection")
        assert _pairs_set(t.wait(5.0).pairs) == _pairs_set(ref), method
        # the replay brought the restored store current
        assert restored.cache.get(key).meta["mutation_seq"] == 2


def test_service_checkpoint_restore_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    assert JoinService.restore_checkpoint(mgr) is None


# ---------------------------------------------------------------------------
# Adaptive planning through the serving path (DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_adaptive_service_replans_after_drift(data):
    D, Q = data
    svc = JoinService(method="april", n_order=N_ORDER,
                      plan_mode="adaptive", replan_after=2)
    svc.register_dataset("d", D)

    def _batch():
        ts = [svc.submit("d", "intersects", Q.verts[i, : Q.nverts[i]])
              for i in range(len(Q))]
        svc.drain()
        return ts

    ts = _batch()
    assert svc.stats["replans"] == 1         # planned once for the group
    _batch()
    assert svc.stats["replans"] == 1         # cached: no drift, no replan

    # two mutations reach replan_after -> next group plans again
    new_poly = Q.verts[0, : Q.nverts[0]] * 0.7 + 0.15
    svc.insert("d", new_poly)
    svc.delete("d", 2)
    ts = _batch()
    assert svc.stats["replans"] == 2
    for i, t in enumerate(ts):
        ref, _ = JoinPlan(svc.dataset("d"), _one(Q, i), filter="april",
                          n_order=N_ORDER).execute("intersects")
        assert _pairs_set(t.wait(5.0).pairs) == _pairs_set(ref), i
        assert t.stats["plan_mode"] == "adaptive"
