"""Sharded model execution on 8 virtual devices (subprocess): the sharded
train step must match the single-device step numerically, and grad
compression must integrate with the DP axis."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_sharded_train_matches_single():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_dev_mesh
        from repro.models.model import init_model
        from repro.models.sharding import (make_activation_hook,
                                           named_sharding_tree,
                                           opt_state_specs, param_specs)
        from repro.models.train import make_train_step
        from repro.optim.adamw import adamw_init
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_config("gemma2-2b", smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}

        # single-device reference
        step0 = jax.jit(make_train_step(cfg, lr=1e-3))
        p0, o0, m0 = step0(params, opt, batch)

        mesh = make_dev_mesh(4, 2)
        hook = make_activation_hook(mesh, sequence_parallel=False)
        ns_p = named_sharding_tree(mesh, param_specs(params, mesh))
        ns_o = named_sharding_tree(mesh, opt_state_specs(params, mesh))
        ps = jax.device_put(params, ns_p)
        os_ = jax.device_put(opt, ns_o)
        bs = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
              for k, v in batch.items()}
        with mesh:
            step1 = jax.jit(make_train_step(cfg, lr=1e-3,
                                            activation_hook=hook))
            p1, o1, m1 = step1(ps, os_, bs)
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, \
            (float(m0["loss"]), float(m1["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p0, p1)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, worst
        print("SHARDED_OK", float(m1["loss"]))
    """))
    assert "SHARDED_OK" in out


@pytest.mark.slow
def test_grad_compression_shard_map():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.grad_compression import compressed_psum_ef
        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        rng = np.random.default_rng(0)
        local = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)

        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P())
        def reduce_compressed(g):
            g = g[0]
            out, _ = compressed_psum_ef(
                {"g": g}, {"g": jnp.zeros_like(g)}, "data")
            return out["g"] / 8.0
        got = reduce_compressed(local)
        want = np.mean(np.asarray(local), axis=0)
        err = np.abs(np.asarray(got) - want).max()
        rel = err / (np.abs(want).max() + 1e-9)
        assert rel < 0.05, rel     # int8 quantization error bound
        print("COMPRESS_OK", rel)
    """))
    assert "COMPRESS_OK" in out
