import numpy as np
import pytest

from repro.core import hilbert


@pytest.mark.parametrize("n_order", [1, 2, 3, 5, 8, 16])
def test_roundtrip(n_order):
    rng = np.random.default_rng(0)
    G = 1 << n_order
    x = rng.integers(0, G, size=512)
    y = rng.integers(0, G, size=512)
    d = hilbert.xy2d(n_order, x, y)
    assert d.max() < (1 << (2 * n_order))
    x2, y2 = hilbert.d2xy(n_order, d)
    np.testing.assert_array_equal(x, x2.astype(np.int64))
    np.testing.assert_array_equal(y, y2.astype(np.int64))


def test_bijection_small():
    n_order = 4
    G = 1 << n_order
    X, Y = np.meshgrid(np.arange(G), np.arange(G), indexing="ij")
    d = hilbert.xy2d(n_order, X.ravel(), Y.ravel())
    assert len(np.unique(d)) == G * G
    assert d.min() == 0 and d.max() == G * G - 1


def test_adjacency():
    """Consecutive Hilbert ids are spatially adjacent cells (the property the
    one-step intervalization proof relies on)."""
    n_order = 6
    G = 1 << n_order
    d = np.arange(G * G, dtype=np.uint64)
    x, y = hilbert.d2xy(n_order, d)
    dx = np.abs(np.diff(x.astype(np.int64)))
    dy = np.abs(np.diff(y.astype(np.int64)))
    assert np.all(dx + dy == 1)


def test_jnp_matches_numpy():
    import jax.numpy as jnp
    n_order = 16
    rng = np.random.default_rng(1)
    G = 1 << n_order
    x = rng.integers(0, G, size=256)
    y = rng.integers(0, G, size=256)
    d_np = hilbert.xy2d(n_order, x, y)
    d_j = np.asarray(hilbert.xy2d_jnp(n_order, jnp.asarray(x, jnp.uint32),
                                      jnp.asarray(y, jnp.uint32)))
    np.testing.assert_array_equal(d_np.astype(np.uint32), d_j)
    x2, y2 = hilbert.d2xy_jnp(n_order, jnp.asarray(d_j))
    np.testing.assert_array_equal(np.asarray(x2), x.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(y2), y.astype(np.uint32))


def test_biased_i32_order_preserving():
    rng = np.random.default_rng(2)
    u = rng.integers(0, 2**32, size=1000, dtype=np.uint64).astype(np.uint32)
    b = hilbert.u32_to_biased_i32(u)
    assert b.dtype == np.int32
    order_u = np.argsort(u, kind="stable")
    order_b = np.argsort(b, kind="stable")
    np.testing.assert_array_equal(u[order_u], u[order_b])
    np.testing.assert_array_equal(hilbert.biased_i32_to_u32(b), u)
