"""tools/analyze: fixture-verified true positives AND true negatives for
every rule, the suppression syntax, baseline round-trips, and the repo
gate itself (current tree must be analyze-clean with a minimal baseline).

Fixtures are written under ``tmp_path`` mirroring the repo layout (the
passes scope by repo-relative path), parsed with :class:`SourceFile`
rooted at ``tmp_path``, and run through one pass at a time.
"""
import textwrap

import pytest

from tools.analyze import (ALL_PASSES, all_rules, collect_files,
                           diff_baseline, load_baseline, run_passes,
                           save_baseline)
from tools.analyze.backend_parity import BackendParityPass
from tools.analyze.core import ROOT, Finding, SourceFile
from tools.analyze.deprecation import DeprecationPass
from tools.analyze.host_sync import HostSyncPass
from tools.analyze.lock_discipline import LockDisciplinePass
from tools.analyze.pallas_constraint import PallasConstraintPass
from tools.analyze.precision import PrecisionPass


def _run(tmp_path, rel, code, pass_):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    src = SourceFile(p, root=tmp_path)
    return run_passes([pass_], [src], root=tmp_path)


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# host-sync (HS001/HS002)
# ---------------------------------------------------------------------------

HS001_TP = """
    import jax.numpy as jnp

    def stage(a):
        x = jnp.sum(a)
        return float(x)
"""

HS001_TN = """
    import numpy as np

    def stage(a):
        y = np.sum(a)
        return float(y)
"""


def test_hs001_true_positive(tmp_path):
    out = _run(tmp_path, "src/repro/spatial/mod.py", HS001_TP,
               HostSyncPass())
    assert _rules(out) == ["HS001"]


def test_hs001_true_negative(tmp_path):
    out = _run(tmp_path, "src/repro/spatial/mod.py", HS001_TN,
               HostSyncPass())
    assert out == []


def test_hs001_out_of_scope_path_ignored(tmp_path):
    out = _run(tmp_path, "src/repro/datagen/mod.py", HS001_TP,
               HostSyncPass())
    assert out == []


HS002_TP = """
    import time

    def bench(fn, x):
        t0 = time.perf_counter()
        out = fn(x)
        dt = time.perf_counter() - t0
        return out, dt
"""

HS002_TN = """
    import time
    import jax

    def bench(fn, x):
        t0 = time.perf_counter()
        out = fn(x)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        return out, dt
"""


def test_hs002_true_positive(tmp_path):
    out = _run(tmp_path, "benchmarks/bench_mod.py", HS002_TP,
               HostSyncPass())
    assert _rules(out) == ["HS002"]


def test_hs002_true_negative(tmp_path):
    out = _run(tmp_path, "benchmarks/bench_mod.py", HS002_TN,
               HostSyncPass())
    assert out == []


def test_hs002_pairs_read_with_closest_preceding_start(tmp_path):
    # two regions reusing t0: the synced first region must stay clean and
    # only the unsynced second region is flagged (regression: "latest
    # start wins" misattributed the region bounds)
    code = """
        import time
        import jax

        def bench(fn, x):
            t0 = time.perf_counter()
            a = fn(x)
            jax.block_until_ready(a)
            d1 = time.perf_counter() - t0
            t0 = time.perf_counter()
            b = fn(x)
            d2 = time.perf_counter() - t0
            return d1, d2
    """
    out = _run(tmp_path, "benchmarks/bench_mod.py", code, HostSyncPass())
    assert _rules(out) == ["HS002"]
    assert "d2" in (tmp_path / "benchmarks/bench_mod.py").read_text() \
        .splitlines()[out[0].line - 1]


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_same_line(tmp_path):
    code = """
        import jax.numpy as jnp

        def stage(a):
            x = jnp.sum(a)
            return float(x)  # analyze: ignore[HS001] stage-boundary sync
    """
    assert _run(tmp_path, "src/repro/spatial/mod.py", code,
                HostSyncPass()) == []


def test_suppression_standalone_comment_above(tmp_path):
    code = """
        import jax.numpy as jnp

        def stage(a):
            x = jnp.sum(a)
            # analyze: ignore[HS001] intended host hand-off
            return float(x)
    """
    assert _run(tmp_path, "src/repro/spatial/mod.py", code,
                HostSyncPass()) == []


def test_suppression_other_rule_does_not_silence(tmp_path):
    code = """
        import jax.numpy as jnp

        def stage(a):
            x = jnp.sum(a)
            return float(x)  # analyze: ignore[HS002]
    """
    assert _rules(_run(tmp_path, "src/repro/spatial/mod.py", code,
                       HostSyncPass())) == ["HS001"]


def test_suppression_bare_ignore_silences_all(tmp_path):
    code = """
        import jax.numpy as jnp

        def stage(a):
            x = jnp.sum(a)
            return float(x)  # analyze: ignore
    """
    assert _run(tmp_path, "src/repro/spatial/mod.py", code,
                HostSyncPass()) == []


# ---------------------------------------------------------------------------
# precision (FP001/FP002)
# ---------------------------------------------------------------------------

FP001_TP = """
    import jax.numpy as jnp

    def classify(ax, ay, bx, by):
        d = ax * by - ay * bx
        return jnp.where(d > 0, 1, -1)
"""

FP001_TN = """
    import jax.numpy as jnp

    _EPS_GUARD = 2.0 ** -44

    def classify(ax, ay, bx, by):
        d = ax * by - ay * bx
        sure = jnp.abs(d) > _EPS_GUARD
        return jnp.where(d > 0, 1, -1), sure
"""


def test_fp001_true_positive(tmp_path):
    out = _run(tmp_path, "src/repro/core/geo.py", FP001_TP,
               PrecisionPass())
    assert _rules(out) == ["FP001"]


def test_fp001_true_negative_guard_band(tmp_path):
    out = _run(tmp_path, "src/repro/core/geo.py", FP001_TN,
               PrecisionPass())
    assert out == []


def test_fp002_true_positive(tmp_path):
    code = """
        import jax

        def setup():
            jax.config.update("jax_enable_x64", True)
    """
    out = _run(tmp_path, "src/repro/core/setup.py", code, PrecisionPass())
    assert _rules(out) == ["FP002"]


def test_fp002_true_negative_scoped_context(tmp_path):
    code = """
        import numpy as np
        from jax.experimental import enable_x64

        def compute(x):
            with enable_x64():
                return np.asarray(x)
    """
    assert _run(tmp_path, "src/repro/core/setup.py", code,
                PrecisionPass()) == []


# ---------------------------------------------------------------------------
# lock-discipline (LD001/LD002)
# ---------------------------------------------------------------------------

LD001_TP = """
    import threading

    class Svc:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []

        def start(self):
            def loop():
                self.drain()
            threading.Thread(target=loop, daemon=True).start()

        def drain(self):
            self.items.append(1)
"""

LD001_TN = """
    import threading

    class Svc:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []

        def start(self):
            def loop():
                self.drain()
            threading.Thread(target=loop, daemon=True).start()

        def drain(self):
            with self._lock:
                self.items.append(1)
"""


def test_ld001_true_positive(tmp_path):
    out = _run(tmp_path, "src/repro/spatial/svc.py", LD001_TP,
               LockDisciplinePass())
    assert "LD001" in _rules(out)


def test_ld001_true_negative(tmp_path):
    assert _run(tmp_path, "src/repro/spatial/svc.py", LD001_TN,
                LockDisciplinePass()) == []


def test_ld001_method_call_is_not_a_field(tmp_path):
    # `self._handle(k).append(...)` mutates the returned object, not a
    # field named `_handle` (regression: methods misclassified as fields)
    code = """
        import threading

        class Svc:
            def __init__(self):
                self._lock = threading.Lock()

            def _handle(self, k):
                return []

            def start(self):
                def loop():
                    self.work()
                threading.Thread(target=loop, daemon=True).start()

            def work(self):
                self._handle(1).append(2)
    """
    assert _run(tmp_path, "src/repro/spatial/svc.py", code,
                LockDisciplinePass()) == []


def test_ld001_thread_safe_fields_exempt(tmp_path):
    code = """
        import threading

        class Svc:
            def __init__(self):
                self._lock = threading.Lock()
                self._stop = threading.Event()

            def start(self):
                def loop():
                    self.work()
                threading.Thread(target=loop, daemon=True).start()

            def work(self):
                self._stop.set()

            def stop(self):
                self._stop.set()
    """
    assert _run(tmp_path, "src/repro/spatial/svc.py", code,
                LockDisciplinePass()) == []


LD002_TP = """
    import threading

    class Two:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()
            self.x = 0

        def m1(self):
            with self._a:
                with self._b:
                    self.x = 1

        def m2(self):
            with self._b:
                with self._a:
                    self.x = 2
"""

LD002_TN = """
    import threading

    class Two:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()
            self.x = 0

        def m1(self):
            with self._a:
                with self._b:
                    self.x = 1

        def m2(self):
            with self._a:
                with self._b:
                    self.x = 2
"""


def test_ld002_true_positive(tmp_path):
    out = _run(tmp_path, "src/repro/spatial/two.py", LD002_TP,
               LockDisciplinePass())
    assert "LD002" in _rules(out)


def test_ld002_true_negative_consistent_order(tmp_path):
    out = _run(tmp_path, "src/repro/spatial/two.py", LD002_TN,
               LockDisciplinePass())
    assert "LD002" not in _rules(out)


# ---------------------------------------------------------------------------
# pallas-constraint (PL001/PL002/PL003)
# ---------------------------------------------------------------------------

def test_pl001_true_positive_default_and_call(tmp_path):
    code = """
        from jax.experimental import pallas as pl

        def launch(x, block_m: int = 100):
            return run(x, block_n=96)
    """
    out = _run(tmp_path, "src/repro/kernels/k.py", code,
               PallasConstraintPass())
    assert _rules(out) == ["PL001", "PL001"]


def test_pl001_true_negative_pow2(tmp_path):
    code = """
        from jax.experimental import pallas as pl

        def launch(x, block_m: int = 128):
            return run(x, block_n=64)
    """
    assert _run(tmp_path, "src/repro/kernels/k.py", code,
                PallasConstraintPass()) == []


def test_pl002_true_positive(tmp_path):
    code = """
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            v = x_ref[0]
            if v > 0:
                o_ref[0] = v
    """
    out = _run(tmp_path, "src/repro/kernels/k.py", code,
               PallasConstraintPass())
    assert _rules(out) == ["PL002"]


def test_pl002_true_negative_pl_when(tmp_path):
    code = """
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            v = x_ref[0]
            o_ref[0] = jnp.where(v > 0, v, 0.0)
    """
    assert _run(tmp_path, "src/repro/kernels/k.py", code,
                PallasConstraintPass()) == []


def test_pl003_true_positive_captured_host_state(tmp_path):
    code = """
        from jax.experimental import pallas as pl

        state = dict(scale=2.0)

        def kernel(x_ref, o_ref):
            o_ref[0] = x_ref[0] * state["scale"]
    """
    out = _run(tmp_path, "src/repro/kernels/k.py", code,
               PallasConstraintPass())
    assert _rules(out) == ["PL003"]


def test_pl003_true_negative_module_constant(tmp_path):
    code = """
        from jax.experimental import pallas as pl

        SCALE = 2.0
        NEG, HIT, MAYBE = 0, 1, 2

        def kernel(x_ref, o_ref):
            o_ref[0] = x_ref[0] * SCALE + MAYBE
    """
    assert _run(tmp_path, "src/repro/kernels/k.py", code,
                PallasConstraintPass()) == []


# ---------------------------------------------------------------------------
# deprecation (DP001)
# ---------------------------------------------------------------------------

def test_dp001_true_positive(tmp_path):
    code = """
        from repro.spatial import JoinPlan

        def make(R, S):
            return JoinPlan(R, S, backend="jnp")
    """
    out = _run(tmp_path, "src/repro/spatial/user.py", code,
               DeprecationPass())
    assert _rules(out) == ["DP001"]


def test_dp001_true_negative(tmp_path):
    code = """
        from repro.spatial import JoinPlan

        def make(R, S):
            return JoinPlan(R, S, filter_backend="jnp")
    """
    assert _run(tmp_path, "src/repro/spatial/user.py", code,
                DeprecationPass()) == []


# ---------------------------------------------------------------------------
# backend-parity (BE001/BE002/BE003)
# ---------------------------------------------------------------------------

def test_be001_true_positive_incomplete_filter():
    from repro.spatial.filters import register_filter, unregister_filter
    from repro.spatial.filters.base import IntermediateFilter

    class StubFilter(IntermediateFilter):
        # overrides only the abstract pair; no sequential oracle, no
        # incremental-maintenance hooks -> protocol incomplete
        def build(self, *a, **kw):
            raise NotImplementedError

        def verdicts(self, *a, **kw):
            raise NotImplementedError

    register_filter("zz-stub", StubFilter)
    try:
        out = BackendParityPass()._be001(ROOT)
    finally:
        unregister_filter("zz-stub")
    stub = [f for f in out if f.snippet == "filter:zz-stub"]
    assert len(stub) == 1 and stub[0].rule == "BE001"
    assert "_verdict_one" in stub[0].message
    assert "patch_insert/patch_delete" in stub[0].message


def test_be001_true_negative_builtin_registry():
    assert BackendParityPass()._be001(ROOT) == []


def _fake_repo(tmp_path, *, readme, design, pipeline, flags):
    (tmp_path / "README.md").write_text(" ".join(readme))
    (tmp_path / "DESIGN.md").write_text(" ".join(design))
    pp = tmp_path / "src/repro/spatial/pipeline.py"
    pp.parent.mkdir(parents=True, exist_ok=True)
    pp.write_text("# " + " ".join(pipeline) + "\n")
    lp = tmp_path / "src/repro/launch"
    lp.mkdir(parents=True, exist_ok=True)
    body = "\n".join(
        f'ap.add_argument("--{k.replace("_", "-")}")' for k in flags)
    (lp / "spatial_join.py").write_text(body + "\n")
    (lp / "serve_join.py").write_text("\n")
    return tmp_path


ALL_KNOBS = ("filter_backend", "refine_backend", "mbr_backend",
             "build_backend", "pipeline_mode", "plan_mode",
             "tile_budget", "resume")


def test_be002_003_true_negative_fully_threaded(tmp_path):
    root = _fake_repo(tmp_path, readme=ALL_KNOBS, design=ALL_KNOBS,
                      pipeline=ALL_KNOBS, flags=ALL_KNOBS)
    assert BackendParityPass()._be002_003(root) == []


def test_be002_true_positive_undocumented_knob(tmp_path):
    readme = tuple(k for k in ALL_KNOBS if k != "mbr_backend")
    root = _fake_repo(tmp_path, readme=readme, design=ALL_KNOBS,
                      pipeline=ALL_KNOBS, flags=ALL_KNOBS)
    out = BackendParityPass()._be002_003(root)
    assert [(f.rule, f.path, f.snippet) for f in out] == \
        [("BE002", "README.md", "knob:mbr_backend")]


def test_be003_true_positive_missing_flag_and_pipeline(tmp_path):
    pipeline = tuple(k for k in ALL_KNOBS if k != "refine_backend")
    flags = tuple(k for k in ALL_KNOBS if k != "build_backend")
    root = _fake_repo(tmp_path, readme=ALL_KNOBS, design=ALL_KNOBS,
                      pipeline=pipeline, flags=flags)
    out = BackendParityPass()._be002_003(root)
    assert sorted((f.rule, f.snippet) for f in out) == [
        ("BE003", "knob:build_backend"), ("BE003", "knob:refine_backend")]


def test_deprecated_backend_alias_is_not_a_parity_knob():
    from tools.analyze.backend_parity import collect_knobs
    knobs = collect_knobs(ROOT)
    assert "backend" not in knobs
    assert set(ALL_KNOBS) <= set(knobs)


# ---------------------------------------------------------------------------
# baseline mechanics and the repo gate
# ---------------------------------------------------------------------------

def _f(rule, path, snippet):
    return Finding(rule=rule, path=path, line=1, message="m",
                   snippet=snippet)


def test_baseline_round_trip(tmp_path):
    p = tmp_path / "baseline.json"
    found = [_f("HS001", "a.py", "x = 1"), _f("HS001", "a.py", "x = 1"),
             _f("LD001", "b.py", "y = 2")]
    save_baseline(found, p)
    diff = diff_baseline(found, load_baseline(p))
    assert diff.clean


def test_baseline_is_line_number_independent(tmp_path):
    p = tmp_path / "baseline.json"
    save_baseline([_f("HS001", "a.py", "x = 1")], p)
    moved = [Finding(rule="HS001", path="a.py", line=99, message="m",
                     snippet="x = 1")]
    assert diff_baseline(moved, load_baseline(p)).clean


def test_baseline_flags_new_and_stale(tmp_path):
    p = tmp_path / "baseline.json"
    save_baseline([_f("HS001", "a.py", "x = 1"),
                   _f("LD001", "b.py", "y = 2")], p)
    current = [_f("HS001", "a.py", "x = 1"),
               _f("FP001", "c.py", "z = 3")]
    diff = diff_baseline(current, load_baseline(p))
    assert [f.key for f in diff.new] == [("FP001", "c.py", "z = 3")]
    assert diff.stale == [("LD001", "b.py", "y = 2", 1)]


def test_repo_is_analyze_clean_with_minimal_baseline():
    """The committed tree passes the gate AND the committed baseline has
    no stale (already-fixed) entries — it can only shrink."""
    files = collect_files(["src", "tools", "benchmarks"])
    findings = run_passes(ALL_PASSES, files)
    diff = diff_baseline(findings, load_baseline())
    assert not diff.new, "\n" + "\n".join(f.render() for f in diff.new)
    assert not diff.stale, diff.stale


def test_rule_catalog_is_complete_and_unique():
    rules = all_rules()
    assert set(rules) == {"HS001", "HS002", "FP001", "FP002", "LD001",
                          "LD002", "BE001", "BE002", "BE003", "PL001",
                          "PL002", "PL003", "DP001"}
    assert len(ALL_PASSES) == 6
