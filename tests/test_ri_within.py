"""RI within-join filter (§3.4): soundness vs the exact predicate and
consistency with the APRIL within filter."""
import numpy as np
import pytest

from repro.core import geometry, join, ri
from repro.core.april import build_april
from repro.core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from repro.datagen import make_dataset

N_ORDER = 7


@pytest.fixture(scope="module")
def data():
    R = make_dataset("T1", seed=91, count=50)
    S = make_dataset("T10", seed=92, count=30)
    rir = ri.build_ri(R, N_ORDER, encoding="R")
    ris = ri.build_ri(S, N_ORDER, encoding="S")
    ar = build_april(R, N_ORDER)
    as_ = build_april(S, N_ORDER)
    pairs = []
    for i in range(len(R)):
        for j in range(len(S)):
            mr, ms = R.mbrs[i], S.mbrs[j]
            if (mr[0] >= ms[0] and mr[1] >= ms[1]
                    and mr[2] <= ms[2] and mr[3] <= ms[3]):
                pairs.append((i, j))
    return R, S, rir, ris, ar, as_, pairs


def test_ri_within_soundness(data):
    R, S, rir, ris, ar, as_, pairs = data
    assert len(pairs) > 5
    n_hit = 0
    for i, j in pairs:
        v = ri.ri_within_verdict_pair(rir, i, ris, j)
        truth = geometry.polygon_within(R.verts[i], R.nverts[i],
                                        S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth, (i, j)
            n_hit += 1
        elif v == TRUE_NEG:
            assert not truth, (i, j)
    assert n_hit > 0


def test_ri_within_vs_april_within(data):
    """RI's 3-class codes give it strictly MORE pruning information than
    APRIL's 2-class lists: wherever APRIL decides, RI must agree; RI may
    additionally decide pairs APRIL leaves indecisive (strong/weak info)."""
    R, S, rir, ris, ar, as_, pairs = data
    for i, j in pairs:
        v_ri = ri.ri_within_verdict_pair(rir, i, ris, j)
        v_ap = join.within_verdict_pair(ar.a_list(i), ar.f_list(i),
                                        as_.a_list(j), as_.f_list(j))
        if v_ap == TRUE_HIT:
            assert v_ri == TRUE_HIT, (i, j)
        if v_ri == INDECISIVE:
            assert v_ap == INDECISIVE, (i, j)
