"""Serving pool: continuous batching must produce the same tokens as
isolated single-request decoding (slot reuse cannot leak state)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import Request, ServePool
from repro.models.model import init_model
from repro.models.serve import greedy_generate

ARCHS = ["smollm-135m", "recurrentgemma-2b"]   # attention + recurrent-state


@pytest.mark.parametrize("arch", ARCHS)
def test_pool_matches_isolated_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 6) for _ in range(5)]
    max_new = 6

    # isolated reference decodes
    refs = []
    for p in prompts:
        out = greedy_generate(params, cfg, jnp.asarray(p[None], jnp.int32),
                              steps=max_new, ctx_capacity=32)
        refs.append(np.asarray(out)[0].tolist())

    # pooled: 2 slots serving 5 requests forces slot reuse
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    pool = ServePool(cfg, params, batch_slots=2, ctx_len=32)
    done = pool.run(reqs)
    assert len(done) == len(prompts)
    for req in done:
        assert req.out == refs[req.rid], (arch, req.rid)
