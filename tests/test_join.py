"""APRIL/RI filter correctness: soundness vs the exact geometry oracle and
equivalence of sequential, batched-numpy and batched-jnp paths."""
import itertools

import numpy as np
import pytest

from repro.core import compress, geometry, join, ri
from repro.core.april import build_april
from repro.core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from repro.datagen import make_dataset

N_ORDER = 7


@pytest.fixture(scope="module")
def setup():
    R = make_dataset("T1", seed=21, count=80)
    S = make_dataset("T2", seed=22, count=120)
    ar = build_april(R, N_ORDER)
    as_ = build_april(S, N_ORDER)
    # candidate pairs: MBR overlap
    pairs = []
    for i in range(len(R)):
        for j in range(len(S)):
            mr, ms = R.mbrs[i], S.mbrs[j]
            if mr[0] <= ms[2] and ms[0] <= mr[2] and mr[1] <= ms[3] and ms[1] <= mr[3]:
                pairs.append((i, j))
    return R, S, ar, as_, np.asarray(pairs, np.int64)


def test_candidates_exist(setup):
    *_, pairs = setup
    assert len(pairs) >= 20, "fixture should generate a meaningful workload"


def test_april_soundness(setup):
    R, S, ar, as_, pairs = setup
    n_hit = n_neg = 0
    for i, j in pairs:
        v = join.april_verdict_pair(ar.a_list(i), ar.f_list(i),
                                    as_.a_list(j), as_.f_list(j))
        truth = geometry.polygons_intersect(
            R.verts[i], R.nverts[i], S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth, f"false TRUE_HIT for pair {(i, j)}"
            n_hit += 1
        elif v == TRUE_NEG:
            assert not truth, f"false TRUE_NEG for pair {(i, j)}"
            n_neg += 1
    # the filter must actually decide a good share of pairs (paper Fig. 13)
    assert n_hit > 0 and n_neg > 0


def test_join_order_invariance(setup):
    R, S, ar, as_, pairs = setup
    orders = list(itertools.permutations(("AA", "AF", "FA")))
    for i, j in pairs[:50]:
        views = (ar.a_list(i), ar.f_list(i), as_.a_list(j), as_.f_list(j))
        verdicts = {o: join.april_verdict_pair(*views, order=o) for o in orders}
        assert len(set(verdicts.values())) == 1, verdicts


def test_batch_matches_pairwise(setup):
    R, S, ar, as_, pairs = setup
    ref = np.asarray([
        join.april_verdict_pair(ar.a_list(i), ar.f_list(i),
                                as_.a_list(j), as_.f_list(j))
        for i, j in pairs], np.int8)
    got_np = join.april_filter_batch(ar, as_, pairs, use_jnp=False)
    np.testing.assert_array_equal(got_np, ref)
    got_j = join.april_filter_batch(ar, as_, pairs, use_jnp=True)
    np.testing.assert_array_equal(got_j, ref)


def test_ri_soundness_and_vs_april(setup):
    R, S, ar, as_, pairs = setup
    rir = ri.build_ri(_small(R, 30), N_ORDER, encoding="R")
    ris = ri.build_ri(_small(S, 40), N_ORDER, encoding="S")
    npairs = [(i, j) for (i, j) in pairs if i < 30 and j < 40]
    for i, j in npairs:
        v = ri.ri_verdict_pair(rir, i, ris, j)
        truth = geometry.polygons_intersect(
            R.verts[i], R.nverts[i], S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth
        elif v == TRUE_NEG:
            assert not truth
        # APRIL may miss only Strong-Strong-exclusive hits vs RI (§4.1 fn 1)
        va = join.april_verdict_pair(ar.a_list(i), ar.f_list(i),
                                     as_.a_list(j), as_.f_list(j))
        if va == TRUE_HIT:
            assert v in (TRUE_HIT, INDECISIVE)
        if va == TRUE_NEG:
            assert v == TRUE_NEG
        if v == TRUE_NEG:
            assert va == TRUE_NEG


def test_ri_same_encoding_xor(setup):
    """Two R-encoded stores joined => on-the-fly XOR conversion (§3.1)."""
    R, S, ar, as_, pairs = setup
    rir = ri.build_ri(_small(R, 25), N_ORDER, encoding="R")
    ris_r = ri.build_ri(_small(S, 25), N_ORDER, encoding="R")
    ris_s = ri.build_ri(_small(S, 25), N_ORDER, encoding="S")
    for i, j in [(i, j) for (i, j) in pairs if i < 25 and j < 25]:
        assert (ri.ri_verdict_pair(rir, i, ris_r, j)
                == ri.ri_verdict_pair(rir, i, ris_s, j))


def _small(ds, k):
    from repro.datagen.synthetic import PolygonDataset
    return PolygonDataset(name=ds.name, verts=ds.verts[:k], nverts=ds.nverts[:k])


def test_compressed_filter_matches(setup):
    R, S, ar, as_, pairs = setup
    for i, j in pairs[:40]:
        ref = join.april_verdict_pair(ar.a_list(i), ar.f_list(i),
                                      as_.a_list(j), as_.f_list(j))
        got = compress.april_verdict_compressed(
            compress.compress_intervals(ar.a_list(i)),
            compress.compress_intervals(ar.f_list(i)),
            compress.compress_intervals(as_.a_list(j)),
            compress.compress_intervals(as_.f_list(j)))
        assert got == ref


def test_compression_roundtrip_and_ratio(setup):
    _, _, ar, as_, _ = setup
    total_raw = total_c = 0
    for store in (ar, as_):
        for i in range(len(store)):
            for ints in (store.a_list(i), store.f_list(i)):
                buf, cnt = compress.compress_intervals(ints)
                back = compress.decompress_intervals(buf, cnt)
                np.testing.assert_array_equal(back, ints)
                total_raw += ints.size * 4
                total_c += len(buf)
    assert total_c < total_raw  # APRIL-C must actually compress (Table 4)
