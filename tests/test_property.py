"""Property-based tests (hypothesis) over the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compress, geometry, granularity, hilbert, join
from repro.core.april import build_april_polygon
from repro.core.intervalize import ids_in_intervals, intervals_from_ids
from repro.core.join import INDECISIVE, TRUE_HIT, TRUE_NEG


# --- strategies -----------------------------------------------------------

@st.composite
def sorted_unique_ids(draw, max_id=2**20, max_len=64):
    vals = draw(st.lists(st.integers(0, max_id), min_size=0, max_size=max_len,
                         unique=True))
    return np.asarray(sorted(vals), np.uint64)


@st.composite
def interval_list(draw, max_id=2**20, max_len=32):
    """Sorted disjoint half-open intervals."""
    pts = draw(st.lists(st.integers(0, max_id), min_size=0, max_size=2 * max_len,
                        unique=True))
    pts = sorted(pts)
    if len(pts) % 2:
        pts = pts[:-1]
    arr = np.asarray(pts, np.uint64).reshape(-1, 2)
    return arr


@st.composite
def polygon(draw):
    """Random star polygon in [0.05, 0.95]^2."""
    nv = draw(st.integers(4, 24))
    cx = draw(st.floats(0.2, 0.8))
    cy = draw(st.floats(0.2, 0.8))
    r = draw(st.floats(0.01, 0.15))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    ang = np.sort(rng.uniform(0, 2 * np.pi, nv)) + np.linspace(0, 1e-4, nv)
    rad = r * (1 + 0.5 * rng.uniform(-1, 1, nv))
    pts = np.stack([cx + rad * np.cos(ang), cy + rad * np.sin(ang)], axis=1)
    return np.clip(pts, 0.01, 0.99)


# --- invariants -----------------------------------------------------------

@given(sorted_unique_ids())
@settings(max_examples=60, deadline=None)
def test_intervalize_roundtrip(ids):
    ints = intervals_from_ids(ids)
    np.testing.assert_array_equal(ids_in_intervals(ints), ids)
    if len(ints):
        # disjoint + sorted + non-empty
        flat = ints.reshape(-1).astype(np.int64)
        assert np.all(ints[:, 1] > ints[:, 0])
        assert np.all(flat[2::2] > flat[1:-1:2])


@given(interval_list(), interval_list())
@settings(max_examples=60, deadline=None)
def test_merge_join_equals_bruteforce(X, Y):
    got = join.interval_join_pair(X, Y)
    xs = set(ids_in_intervals(X).tolist())
    ys = set(ids_in_intervals(Y).tolist())
    assert got == bool(xs & ys)


@given(interval_list(), interval_list())
@settings(max_examples=60, deadline=None)
def test_batched_join_equals_sequential(X, Y):
    class FakeStore:
        """CSR-convention store with a single polygon (see AprilStore)."""
        def __init__(self, ints):
            self.a_ints = ints
            self.a_off = np.asarray([0, len(ints)], np.int64)
            self.f_ints = ints
            self.f_off = self.a_off
        def a_list(self, i):
            return self.a_ints
        def f_list(self, i):
            return self.f_ints
    sx, sy = FakeStore(X), FakeStore(Y)
    from repro.core.join import pack_lists, batch_overlap_np
    xs, xl, nx = pack_lists(sx, [0], "A")
    ys, yl, ny = pack_lists(sy, [0], "A")
    got = batch_overlap_np(xs, xl, nx, ys, yl, ny)[0]
    assert bool(got) == join.interval_join_pair(X, Y)


@given(interval_list())
@settings(max_examples=40, deadline=None)
def test_vbyte_roundtrip(ints):
    buf, cnt = compress.compress_intervals(ints)
    back = compress.decompress_intervals(buf, cnt)
    np.testing.assert_array_equal(back, ints.reshape(-1, 2))


@given(interval_list(), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_scaling_covers(ints, dn):
    n_from, n_to = 12, 12 - dn
    out = granularity.scale_intervals(ints, n_from, n_to)
    orig = set((ids_in_intervals(ints) >> np.uint64(2 * dn)).tolist())
    cover = set(ids_in_intervals(out).tolist())
    assert orig <= cover


@given(st.integers(1, 8), st.data())
@settings(max_examples=40, deadline=None)
def test_hilbert_roundtrip_property(n_order, data):
    G = 1 << n_order
    x = np.asarray(data.draw(st.lists(st.integers(0, G - 1), min_size=1,
                                      max_size=32)), np.int64)
    y = np.asarray(data.draw(st.lists(st.integers(0, G - 1), min_size=len(x),
                                      max_size=len(x))), np.int64)
    d = hilbert.xy2d(n_order, x, y)
    x2, y2 = hilbert.d2xy(n_order, d)
    np.testing.assert_array_equal(x, x2.astype(np.int64))
    np.testing.assert_array_equal(y, y2.astype(np.int64))


@given(polygon(), polygon())
@settings(max_examples=25, deadline=None)
def test_filter_soundness_property(pa, pb):
    """For ANY pair of random polygons, the APRIL verdict never contradicts
    the exact geometry predicate."""
    n_order = 6
    aa, fa = build_april_polygon(pa, len(pa), n_order)
    ab, fb = build_april_polygon(pb, len(pb), n_order)
    v = join.april_verdict_pair(aa, fa, ab, fb)
    truth = geometry.polygons_intersect(pa, len(pa), pb, len(pb))
    if v == TRUE_HIT:
        assert truth
    elif v == TRUE_NEG:
        assert not truth


# --- batched refinement (DESIGN.md §7) ------------------------------------

def _pair_datasets(pa, pb):
    from repro.datagen.synthetic import PolygonDataset
    V = max(len(pa), len(pb))
    def one(p):
        verts = np.zeros((1, V, 2))
        verts[0, : len(p)] = p
        return PolygonDataset(name="h", verts=verts,
                              nverts=np.asarray([len(p)], np.int64))
    return one(pa), one(pb)


@given(polygon(), polygon(), st.booleans(), st.integers(0, 63))
@settings(max_examples=40, deadline=None)
def test_batched_refine_equals_sequential_property(pa, pb, snap, k):
    """Batched refinement is verdict-identical to the per-pair f64 oracle
    for ANY polygon pair — including pairs with a vertex of one snapped
    onto a boundary edge of the other (the touching regime)."""
    from repro.spatial import refine
    if snap:
        e = k % len(pb)
        t = (k / 64.0) or 0.5
        p0, p1 = pb[e], pb[(e + 1) % len(pb)]
        pa = pa.copy()
        pa[k % len(pa)] = p0 + t * (p1 - p0)
    R, S = _pair_datasets(pa, pb)
    pairs = np.asarray([[0, 0]], np.int64)
    want_i = refine.refine_pairs_seq(R, S, pairs)
    got_i = refine.refine_pairs(R, S, pairs)
    np.testing.assert_array_equal(got_i, want_i)
    want_w = refine.refine_within_pairs_seq(R, S, pairs)
    got_w = refine.refine_within_pairs(R, S, pairs)
    np.testing.assert_array_equal(got_w, want_w)


@given(polygon())
@settings(max_examples=40, deadline=None)
def test_representative_point_interior_property(p):
    rep = geometry.representative_points(p[None], np.asarray([len(p)]))[0]
    assert (geometry.points_in_polygon(rep[None], p)[0]
            or geometry.points_on_polygon_boundary(rep[None], p)[0])
