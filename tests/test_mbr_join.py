"""Candidate generation (DESIGN.md §8): every mbr_backend must emit exactly
the brute-force oracle's pair set, duplicate-free, on any data extent."""
import numpy as np
import pytest

from repro.datagen import make_dataset
from repro.spatial import JoinPlan
from repro.spatial.distributed import distributed_mbr_join
from repro.spatial.mbr_join import (
    MBR_BACKENDS, adaptive_grid, bucket_ranges, expand_buckets,
    joint_extent, mbr_intersect_mask, mbr_join)

BACKENDS = MBR_BACKENDS


def oracle_set(mr, ms):
    return set(map(tuple, np.stack(
        np.nonzero(mbr_intersect_mask(mr, ms)), axis=1).tolist()))


def pairs_set(p):
    return set(map(tuple, np.asarray(p).tolist()))


@pytest.fixture(scope="module")
def sides():
    R = make_dataset("T1", seed=61, count=110)
    S = make_dataset("T2", seed=62, count=160)
    return R.mbrs, S.mbrs


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("grid", [None, 1, 7, 64])
def test_backends_match_oracle(sides, backend, grid):
    mr, ms = sides
    pairs = mbr_join(mr, ms, grid=grid, backend=backend)
    got = pairs_set(pairs)
    assert got == oracle_set(mr, ms)
    assert len(pairs) == len(got), "duplicate pairs emitted"


@pytest.mark.parametrize("backend", BACKENDS)
def test_translated_scaled_extent_regression(sides, backend):
    """MBRs far outside the unit square must bucket over the joint extent
    (pre-§8, ``mbrs * k`` clamped everything into the border buckets)."""
    mr, ms = sides
    for scale, shift in ((3.7, (1000.0, -55.0)), (1e-3, (2.0, 2.0)),
                         (1e6, (-3e5, 4e4))):
        tr = mr * scale + np.array([shift[0], shift[1]] * 2)
        ts = ms * scale + np.array([shift[0], shift[1]] * 2)
        pairs = mbr_join(tr, ts, backend=backend)
        got = pairs_set(pairs)
        assert got == oracle_set(tr, ts), (scale, shift)
        assert len(pairs) == len(got)


def test_translated_bucketing_not_degenerate(sides):
    """The extent-normalization fix: translated data must spread over the
    grid instead of collapsing into one border bucket."""
    mr, ms = sides
    tr = mr * 50.0 + 300.0
    ts = ms * 50.0 + 300.0
    k = adaptive_grid(tr, ts)
    assert k > 1
    lo, hi = bucket_ranges(tr, k, joint_extent(tr, ts))
    _, buckets = expand_buckets(lo, hi, k)
    # far more occupied buckets than the 1-2 border cells of the old clamp
    assert len(np.unique(buckets)) > 10


def test_bucket_straddling_dedup():
    """MBRs covering many buckets appear once per qualifying pair."""
    # big overlapping boxes straddling every bucket at any grid
    mr = np.array([[0.0, 0.0, 1.0, 1.0], [0.1, 0.1, 0.9, 0.9]])
    ms = np.array([[0.2, 0.2, 0.8, 0.8], [0.0, 0.5, 1.0, 0.6]])
    for backend in BACKENDS:
        for grid in (None, 2, 16, 64):
            pairs = mbr_join(mr, ms, grid=grid, backend=backend)
            got = pairs_set(pairs)
            assert len(pairs) == len(got) == 4, (backend, grid)


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_and_degenerate_inputs(backend):
    z = np.zeros((0, 4))
    box = np.array([[0.2, 0.2, 0.6, 0.6]])
    assert mbr_join(z, box, backend=backend).shape == (0, 2)
    assert mbr_join(box, z, backend=backend).shape == (0, 2)
    assert mbr_join(z, z, backend=backend).shape == (0, 2)
    # point MBRs (zero width/height), including coincident ones
    rng = np.random.default_rng(7)
    pr = np.repeat(rng.random((25, 2)), 2, axis=1)[:, [0, 2, 1, 3]]
    ps = np.concatenate([pr[:5], np.repeat(rng.random((15, 2)), 2,
                                           axis=1)[:, [0, 2, 1, 3]]])
    assert pairs_set(mbr_join(pr, ps, backend=backend)) == oracle_set(pr, ps)
    # all MBRs identical -> single bucket, full cross product
    same = np.tile(box, (6, 1))
    pairs = mbr_join(same, same[:4], backend=backend)
    assert pairs_set(pairs) == oracle_set(same, same[:4])
    assert len(pairs) == 24


def test_invalid_grid_rejected(sides):
    """Non-positive explicit grids must raise, not silently drop pairs."""
    mr, ms = sides
    for bad in (-2, 0):
        with pytest.raises(ValueError):
            mbr_join(mr, ms, grid=bad)
        with pytest.raises(ValueError):
            distributed_mbr_join(mr, ms, grid=bad)
        with pytest.raises(ValueError):   # even when one side is empty
            mbr_join(np.zeros((0, 4)), ms, grid=bad)


def test_adaptive_grid_statistics(sides):
    mr, ms = sides
    k = adaptive_grid(mr, ms)
    assert 1 <= k <= 1024 and (k & (k - 1)) == 0
    # giant MBRs force a coarse grid; empty input falls back to 1
    huge = np.tile([[0.0, 0.0, 1.0, 1.0]], (50, 1))
    assert adaptive_grid(huge, huge) == 1
    assert adaptive_grid(np.zeros((0, 4)), np.zeros((0, 4))) == 1
    # pair set is grid-invariant by construction; spot-check the adaptive one
    assert pairs_set(mbr_join(mr, ms)) == pairs_set(mbr_join(mr, ms, grid=3))


def test_plan_threads_mbr_backend(sides):
    R = make_dataset("T1", seed=63, count=50)
    S = make_dataset("T2", seed=64, count=70)
    want = None
    for mb in BACKENDS:
        plan = JoinPlan(R, S, filter="april", n_order=7, mbr_backend=mb)
        pairs, stats = plan.build().execute("intersects")
        assert stats.mbr_backend == mb
        assert mb in stats.row()
        got = pairs_set(pairs)
        want = want or got
        assert got == want
    with pytest.raises(ValueError):
        JoinPlan(R, S, mbr_backend="cuda")


@pytest.mark.slow
def test_distributed_mbr_join_matches_host(sides):
    mr, ms = sides
    pairs, counts = distributed_mbr_join(mr, ms)
    assert pairs_set(pairs) == oracle_set(mr, ms)
    assert counts["mbr_pairs"] == len(pairs)
    assert counts["mbr_candidates"] >= counts["mbr_pairs"]
    empty, c0 = distributed_mbr_join(np.zeros((0, 4)), ms)
    assert empty.shape == (0, 2) and c0["mbr_pairs"] == 0


def test_property_random_mbrs():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    coord = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                      width=64)
    box = st.tuples(coord, coord, coord, coord).map(
        lambda t: (min(t[0], t[2]), min(t[1], t[3]),
                   max(t[0], t[2]), max(t[1], t[3])))
    boxes = st.lists(box, min_size=0, max_size=24).map(
        lambda bs: np.asarray(bs, np.float64).reshape(-1, 4))

    @settings(max_examples=60, deadline=None)
    @given(mr=boxes, ms=boxes)
    def check(mr, ms):
        want = oracle_set(mr, ms)
        for backend in ("numpy", "sequential"):
            pairs = mbr_join(mr, ms, backend=backend)
            got = pairs_set(pairs)
            assert got == want and len(pairs) == len(got)

    check()
