import numpy as np

from repro.core import geometry


SQUARE = np.array([[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]])


def test_points_in_polygon_square():
    pts = np.array([[0.5, 0.5], [0.1, 0.1], [0.79, 0.79], [0.9, 0.5]])
    got = geometry.points_in_polygon(pts, SQUARE)
    np.testing.assert_array_equal(got, [True, False, True, False])


def test_points_in_polygons_batch():
    tri = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0], [0.0, 0.0]])
    verts = np.stack([np.pad(SQUARE, ((0, 0), (0, 0))), tri])
    nverts = np.array([4, 3])
    pts = np.array([[[0.5, 0.5], [0.9, 0.9]], [[0.1, 0.1], [0.9, 0.9]]])
    got = geometry.points_in_polygons_batch(pts, verts, nverts)
    np.testing.assert_array_equal(got, [[True, False], [True, False]])


def test_segments_intersect():
    a0 = np.array([0.0, 0.0]); a1 = np.array([1.0, 1.0])
    b0 = np.array([0.0, 1.0]); b1 = np.array([1.0, 0.0])
    assert geometry.segments_intersect(a0, a1, b0, b1)
    assert not geometry.segments_intersect(a0, a1, b0 + 2, b1 + 2)
    # touching at endpoint
    assert geometry.segments_intersect(a0, a1, a1, np.array([2.0, 0.0]))
    # collinear overlap
    assert geometry.segments_intersect(
        np.array([0.0, 0.0]), np.array([1.0, 0.0]),
        np.array([0.5, 0.0]), np.array([2.0, 0.0]))


def test_polygons_intersect_cases():
    sq2 = SQUARE + 0.5   # overlaps corner
    assert geometry.polygons_intersect(SQUARE, 4, sq2, 4)
    sq3 = SQUARE + 2.0   # disjoint
    assert not geometry.polygons_intersect(SQUARE, 4, sq3, 4)
    inner = np.array([[0.4, 0.4], [0.6, 0.4], [0.6, 0.6], [0.4, 0.6]])
    # containment (no boundary crossing)
    assert geometry.polygons_intersect(SQUARE, 4, inner, 4)
    assert geometry.polygons_intersect(inner, 4, SQUARE, 4)


def test_polygon_within():
    inner = np.array([[0.4, 0.4], [0.6, 0.4], [0.6, 0.6], [0.4, 0.6]])
    assert geometry.polygon_within(inner, 4, SQUARE, 4)
    assert not geometry.polygon_within(SQUARE, 4, inner, 4)
    shifted = inner + 0.5
    assert not geometry.polygon_within(shifted, 4, SQUARE, 4)


def test_area_and_mbr():
    assert np.isclose(geometry.polygon_area(SQUARE), 0.36)
    mbrs = geometry.polygon_mbrs(SQUARE[None], np.array([4]))
    np.testing.assert_allclose(mbrs[0], [0.2, 0.2, 0.8, 0.8])


def test_clip_polygon_to_box():
    clipped = geometry.clip_polygon_to_box(SQUARE, (0.5, 0.5, 1.0, 1.0))
    assert np.isclose(geometry.polygon_area(clipped), 0.09)
    empty = geometry.clip_polygon_to_box(SQUARE, (0.9, 0.9, 1.0, 1.0))
    assert len(empty) == 0
