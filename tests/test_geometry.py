import numpy as np

from repro.core import geometry


SQUARE = np.array([[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]])


def test_points_in_polygon_square():
    pts = np.array([[0.5, 0.5], [0.1, 0.1], [0.79, 0.79], [0.9, 0.5]])
    got = geometry.points_in_polygon(pts, SQUARE)
    np.testing.assert_array_equal(got, [True, False, True, False])


def test_points_in_polygons_batch():
    tri = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0], [0.0, 0.0]])
    verts = np.stack([np.pad(SQUARE, ((0, 0), (0, 0))), tri])
    nverts = np.array([4, 3])
    pts = np.array([[[0.5, 0.5], [0.9, 0.9]], [[0.1, 0.1], [0.9, 0.9]]])
    got = geometry.points_in_polygons_batch(pts, verts, nverts)
    np.testing.assert_array_equal(got, [[True, False], [True, False]])


def test_segments_intersect():
    a0 = np.array([0.0, 0.0]); a1 = np.array([1.0, 1.0])
    b0 = np.array([0.0, 1.0]); b1 = np.array([1.0, 0.0])
    assert geometry.segments_intersect(a0, a1, b0, b1)
    assert not geometry.segments_intersect(a0, a1, b0 + 2, b1 + 2)
    # touching at endpoint
    assert geometry.segments_intersect(a0, a1, a1, np.array([2.0, 0.0]))
    # collinear overlap
    assert geometry.segments_intersect(
        np.array([0.0, 0.0]), np.array([1.0, 0.0]),
        np.array([0.5, 0.0]), np.array([2.0, 0.0]))


def test_polygons_intersect_cases():
    sq2 = SQUARE + 0.5   # overlaps corner
    assert geometry.polygons_intersect(SQUARE, 4, sq2, 4)
    sq3 = SQUARE + 2.0   # disjoint
    assert not geometry.polygons_intersect(SQUARE, 4, sq3, 4)
    inner = np.array([[0.4, 0.4], [0.6, 0.4], [0.6, 0.6], [0.4, 0.6]])
    # containment (no boundary crossing)
    assert geometry.polygons_intersect(SQUARE, 4, inner, 4)
    assert geometry.polygons_intersect(inner, 4, SQUARE, 4)


def test_polygon_within():
    inner = np.array([[0.4, 0.4], [0.6, 0.4], [0.6, 0.6], [0.4, 0.6]])
    assert geometry.polygon_within(inner, 4, SQUARE, 4)
    assert not geometry.polygon_within(SQUARE, 4, inner, 4)
    shifted = inner + 0.5
    assert not geometry.polygon_within(shifted, 4, SQUARE, 4)


def test_area_and_mbr():
    assert np.isclose(geometry.polygon_area(SQUARE), 0.36)
    mbrs = geometry.polygon_mbrs(SQUARE[None], np.array([4]))
    np.testing.assert_allclose(mbrs[0], [0.2, 0.2, 0.8, 0.8])


def test_clip_polygon_to_box():
    clipped = geometry.clip_polygon_to_box(SQUARE, (0.5, 0.5, 1.0, 1.0))
    assert np.isclose(geometry.polygon_area(clipped), 0.09)
    empty = geometry.clip_polygon_to_box(SQUARE, (0.9, 0.9, 1.0, 1.0))
    assert len(empty) == 0


def test_points_on_polygon_boundary():
    pts = np.array([[0.5, 0.2], [0.8, 0.5], [0.2, 0.2], [0.5, 0.5],
                    [0.5, 0.19999]])
    got = geometry.points_on_polygon_boundary(pts, SQUARE)
    np.testing.assert_array_equal(got, [True, True, True, False, False])


def test_points_in_polygon_closed_on_all_edges():
    # the open crossing-parity test lands on-boundary points on either side
    # (here: the top edge classifies outside); the closed test never does
    on_edges = np.array([[0.5, 0.2], [0.8, 0.5], [0.5, 0.8], [0.2, 0.5]])
    assert not geometry.points_in_polygon(on_edges, SQUARE).all()
    assert geometry.points_in_polygon_closed(on_edges, SQUARE).all()


def test_representative_points_interior():
    from repro.datagen import make_dataset
    for name in ("T1", "T3", "T10"):
        D = make_dataset(name, seed=5, count=40)
        reps = geometry.representative_points(D.verts, D.nverts)
        for i in range(len(D)):
            assert (geometry.points_in_polygon(
                        reps[i: i + 1], D.verts[i], D.nverts[i])[0]
                    or geometry.points_on_polygon_boundary(
                        reps[i: i + 1], D.verts[i], D.nverts[i])[0]), \
                (name, i)
    # concave U-shape: the vertex centroid is outside, the rep must not be
    U = np.array([[0., 0.], [10., 0.], [10., 10.], [8., 10.],
                  [8., 2.], [2., 2.], [2., 10.], [0., 10.]])
    rep = geometry.representative_points(U[None], np.array([8]))[0]
    assert geometry.points_in_polygon_closed(rep[None], U)[0]


def test_regression_polygons_intersect_snapped_vertex():
    """ISSUE 3: first vertex snapped onto a diagonal edge of the container
    refined False (sweep misses, parity misclassifies); exact-rational truth
    on the stored floats is True."""
    from repro.datagen.fixtures import SNAPPED_HOST, SNAPPED_TRI
    assert geometry.polygons_intersect(SNAPPED_TRI, 3, SNAPPED_HOST, 8)
    assert geometry.polygons_intersect(SNAPPED_HOST, 8, SNAPPED_TRI, 3)


def test_regression_polygon_within_concave_container():
    """ISSUE 3: the centroid-nudge on-boundary fallback was unsound for
    concave containers (centroid in the cavity -> nudge direction exits)."""
    from repro.datagen.fixtures import CSHAPE, CSHAPE_INNER
    cshape, inner = CSHAPE, CSHAPE_INNER
    assert geometry.polygon_within(inner, 3, cshape, 8)
    assert not geometry.polygon_within(inner + np.array([0., 2.5]), 3,
                                       cshape, 8)
    # touching containment against a convex container, one per edge
    sq = np.array([[0., 0.], [10., 0.], [10., 10.], [0., 10.]])
    touching = (
        np.array([[6., 1.5], [7., 0.], [5., 0.]]),      # bottom edge
        np.array([[6., 10.], [7., 8.5], [5., 8.5]]),    # top edge
        np.array([[1.5, 6.], [0., 7.], [0., 5.]]),      # left edge
        np.array([[8.5, 6.], [10., 7.], [10., 5.]]),    # right edge
    )
    for t in touching:
        assert geometry.polygon_within(t, 3, sq, 4), t
