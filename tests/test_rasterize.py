import numpy as np
import pytest

from repro.core import rasterize
from repro.core.rasterize import GLOBAL_EXTENT
from repro.datagen import make_dataset


SQUARE = np.array([[0.21, 0.21], [0.79, 0.21], [0.79, 0.79], [0.21, 0.79]])


def test_dda_square_boundary():
    n_order = 4  # 16x16 grid, cells of 1/16
    cells = rasterize.dda_partial_cells(SQUARE, 4, n_order)
    # boundary must form a ring covering rows/cols 3..12 approx
    assert len(cells) > 0
    cs = set(map(tuple, cells))
    # corners of the square are at cell (3,3) and (12,12)
    assert (3, 3) in cs and (12, 12) in cs
    # interior cell must NOT be partial
    assert (8, 8) not in cs


def test_dda_matches_oracle_random():
    ds = make_dataset("T1", seed=3, count=12)
    n_order = 7
    for i in range(len(ds)):
        v, n = ds.verts[i], ds.nverts[i]
        got = set(map(tuple, rasterize.dda_partial_cells(v, n, n_order)))
        oracle = rasterize.classify_window_oracle(v, n, n_order)
        want = set(map(tuple, oracle["partial"]))
        # DDA detects cells crossed by edges; the oracle may additionally
        # label never-crossed cells partial only in degenerate touch cases.
        missing = want - got
        extra = got - want
        assert not missing, f"poly {i}: DDA missed boundary cells {missing}"
        assert not extra, f"poly {i}: DDA found non-boundary cells {extra}"


def test_scanline_matches_oracle():
    ds = make_dataset("T1", seed=4, count=10)
    n_order = 7
    for i in range(len(ds)):
        v, n = ds.verts[i], ds.nverts[i]
        partial = rasterize.dda_partial_cells(v, n, n_order)
        full = rasterize.scanline_full_cells(v, n, partial, n_order)
        oracle = rasterize.classify_window_oracle(v, n, n_order)
        assert set(map(tuple, full)) == set(map(tuple, oracle["full"]))


def test_floodfill_matches_scanline():
    ds = make_dataset("T2", seed=5, count=10)
    n_order = 7
    for i in range(len(ds)):
        v, n = ds.verts[i], ds.nverts[i]
        partial = rasterize.dda_partial_cells(v, n, n_order)
        sl = rasterize.scanline_full_cells(v, n, partial, n_order)
        ff = rasterize.floodfill_classify(v, n, partial, n_order)
        assert set(map(tuple, sl)) == set(map(tuple, ff))


def test_coverage_fractions_square():
    n_order = 4
    # cell (8,8) fully inside square => fraction 1; cell (0,0) outside => 0
    fr = rasterize.coverage_fractions(
        SQUARE, 4, np.array([[8, 8], [0, 0]]), n_order)
    assert fr[0] == pytest.approx(1.0)
    assert fr[1] == pytest.approx(0.0)


def test_extent_scaling():
    ext = rasterize.Extent(0.2, 0.2, 0.6)
    cells = rasterize.cells_of_points(np.array([[0.2, 0.2], [0.79, 0.79]]), 4, ext)
    np.testing.assert_array_equal(cells[0], [0, 0])
    np.testing.assert_array_equal(cells[1], [15, 15])
