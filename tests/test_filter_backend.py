"""The bucketed filter-join subsystem (DESIGN.md §9), deterministic tests.

Covers: the `filter_backend` knob threading (JoinPlan / stats / pipeline /
distributed / launcher flag), device-resident IntervalLists reuse across
calls, staged trichotomy drivers against the per-pair references on seeded
random interval lists (empty and single-interval rows included), APRIL-C's
bounded staged decode, the fused Pallas trichotomy kernel, and the
`tools/check_bench.py` CI gate. The hypothesis variants live in
``test_filter_backend_property.py``.
"""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import join
from repro.core.april import AprilStore
from repro.core.join import (IntervalLists, april_trichotomy_rows,
                             within_trichotomy_rows)
from repro.core.rasterize import GLOBAL_EXTENT
from repro.datagen import make_dataset
from repro.spatial import FILTER_BACKENDS, JoinPlan

N_ORDER = 6
ROOT = pathlib.Path(__file__).resolve().parent.parent


def _random_store(rng, n_rows, p_empty=0.3, max_len=10, max_id=2**12):
    """AprilStore over random sorted disjoint lists; rows are empty with
    probability ``p_empty`` and single-interval with fair odds."""
    def lists():
        out = []
        for _ in range(n_rows):
            if rng.random() < p_empty:
                out.append(np.zeros((0, 2), np.uint64))
                continue
            n = int(rng.integers(1, max_len))
            pts = np.unique(rng.integers(0, max_id, 2 * n).astype(np.uint64))
            if len(pts) % 2:
                pts = pts[:-1]
            out.append(pts.reshape(-1, 2))
        off = np.zeros(n_rows + 1, np.int64)
        off[1:] = np.cumsum([len(l) for l in out])
        ints = (np.concatenate(out, axis=0) if any(len(l) for l in out)
                else np.zeros((0, 2), np.uint64))
        return off, ints
    a_off, a_ints = lists()
    f_off, f_ints = lists()
    return AprilStore(n_order=N_ORDER, extent=GLOBAL_EXTENT, a_off=a_off,
                      a_ints=a_ints, f_off=f_off, f_ints=f_ints)


def _all_pairs(nr, ns):
    return np.stack(np.meshgrid(np.arange(nr), np.arange(ns),
                                indexing="ij"), axis=-1).reshape(-1, 2)


@pytest.mark.parametrize("backend", ["numpy", "jnp"])
def test_trichotomy_random_lists(backend):
    """Staged trichotomy == per-pair references on random CSR lists with
    empty and single-interval rows (seeded mirror of the hypothesis test)."""
    rng = np.random.default_rng(7)
    for trial in range(6):
        sr = _random_store(rng, 5)
        ss = _random_store(rng, 6)
        pairs = _all_pairs(len(sr), len(ss))
        want = np.asarray([
            join.april_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                    ss.f_list(j))
            for i, j in pairs], np.int8)
        got = april_trichotomy_rows(
            IntervalLists.from_intervals(sr.a_off, sr.a_ints),
            IntervalLists.from_intervals(sr.f_off, sr.f_ints),
            IntervalLists.from_intervals(ss.a_off, ss.a_ints),
            IntervalLists.from_intervals(ss.f_off, ss.f_ints),
            pairs[:, 0], pairs[:, 1], backend=backend)
        np.testing.assert_array_equal(got, want, err_msg=f"trial {trial}")
        want_w = np.asarray([
            join.within_verdict_pair(sr.a_list(i), sr.f_list(i),
                                     ss.a_list(j), ss.f_list(j))
            for i, j in pairs], np.int8)
        got_w = within_trichotomy_rows(
            IntervalLists.from_intervals(sr.a_off, sr.a_ints),
            IntervalLists.from_intervals(ss.a_off, ss.a_ints),
            IntervalLists.from_intervals(ss.f_off, ss.f_ints),
            pairs[:, 0], pairs[:, 1], backend=backend)
        np.testing.assert_array_equal(got_w, want_w, err_msg=f"trial {trial}")


@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_degenerate_order_matches_reference(backend):
    """order=("AA",) leaves AA survivors INDECISIVE (like the sequential
    reference); an order missing AA raises, like the reference."""
    rng = np.random.default_rng(11)
    sr = _random_store(rng, 4)
    ss = _random_store(rng, 4)
    pairs = _all_pairs(len(sr), len(ss))
    want = np.asarray([
        join.april_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                ss.f_list(j), order=("AA",))
        for i, j in pairs], np.int8)
    got = join.april_filter_batch(sr, ss, pairs, order=("AA",),
                                  backend=backend)
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="order must include 'AA'"):
        join.april_filter_batch(sr, ss, pairs, order=("AF", "FA"),
                                backend=backend)


def test_pallas_trichotomy_matches_reference():
    rng = np.random.default_rng(9)
    sr = _random_store(rng, 4)
    ss = _random_store(rng, 4)
    pairs = _all_pairs(len(sr), len(ss))
    want = np.asarray([
        join.april_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                ss.f_list(j))
        for i, j in pairs], np.int8)
    got = join.april_filter_batch(sr, ss, pairs, backend="pallas")
    np.testing.assert_array_equal(got, want)


def test_compressed_store_bounded_decode_matches():
    """APRIL-C staged bounded decode == sequential streaming reference on
    every predicate (polygon reading), on every batched backend."""
    R = make_dataset("T1", seed=3, count=40)
    S = make_dataset("T2", seed=4, count=60)
    plan = JoinPlan(R, S, filter="april-c", n_order=N_ORDER)
    plan.build()
    for predicate in ("intersects", "within", "selection"):
        # within-containment candidates are scarce on T1xT2; verdicts are
        # defined for any pair batch, so test over the intersect candidates
        pairs = plan.candidates("intersects" if predicate == "within"
                                else predicate)
        assert len(pairs) > 5
        want = plan.filter.verdicts_seq(plan.approx_r, plan.approx_s, pairs,
                                        predicate=predicate)
        for backend in ("numpy", "jnp", "pallas"):
            got = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                       predicate=predicate, backend=backend)
            np.testing.assert_array_equal(got, want,
                                          err_msg=(predicate, backend))


# ---------------------------------------------------------------------------
# knob threading + device-store reuse
# ---------------------------------------------------------------------------

def test_filter_backend_knob_and_stats():
    R = make_dataset("T1", seed=11, count=20)
    S = make_dataset("T2", seed=12, count=30)
    ref = None
    for backend in FILTER_BACKENDS:
        plan = JoinPlan(R, S, filter="april", n_order=N_ORDER,
                        filter_backend=backend)
        res, st_ = plan.build().execute("intersects")
        assert st_.filter_backend == backend
        assert st_.backend == backend        # historical alias mirrors
        assert backend in st_.row()
        if ref is None:
            ref = np.sort(res, axis=0)
        else:
            np.testing.assert_array_equal(np.sort(res, axis=0), ref)


def test_filter_backend_alias_and_validation():
    R = make_dataset("T1", seed=11, count=5)
    S = make_dataset("T2", seed=12, count=5)
    with pytest.warns(DeprecationWarning, match="deprecated alias"):
        plan = JoinPlan(R, S, filter="none", backend="jnp")
    assert plan.filter_backend == "jnp"
    assert plan.backend == "jnp"
    with pytest.raises(ValueError, match="not both"):
        JoinPlan(R, S, filter="none", filter_backend="numpy", backend="jnp")
    with pytest.raises(ValueError, match="unknown filter backend"):
        JoinPlan(R, S, filter="none", filter_backend="cuda")


def test_pipeline_shim_threads_filter_backend():
    from repro.spatial.pipeline import spatial_intersection_join
    R = make_dataset("T1", seed=17, count=15)
    S = make_dataset("T2", seed=18, count=20)
    res_a, st_a = spatial_intersection_join(R, S, method="april",
                                            n_order=N_ORDER,
                                            filter_backend="sequential")
    assert st_a.filter_backend == "sequential"
    res_b, st_b = spatial_intersection_join(R, S, method="april",
                                            n_order=N_ORDER)
    np.testing.assert_array_equal(np.sort(res_a, axis=0),
                                  np.sort(res_b, axis=0))


def test_interval_lists_cached_across_calls():
    """The device-ready lists build once per Approximation and are reused
    across verdicts calls (DESIGN.md §9 device-store reuse)."""
    R = make_dataset("T1", seed=13, count=20)
    S = make_dataset("T2", seed=14, count=30)
    plan = JoinPlan(R, S, filter="april", n_order=N_ORDER)
    plan.build()
    pairs = plan.candidates("intersects")
    plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs)
    cached = plan.approx_r.meta["interval_lists"]["A"]
    assert isinstance(cached, IntervalLists)
    plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs)
    assert plan.approx_r.meta["interval_lists"]["A"] is cached


def test_distributed_filter_backend_alias():
    from repro.spatial.distributed import distributed_filter
    R = make_dataset("T1", seed=15, count=10)
    S = make_dataset("T2", seed=16, count=12)
    plan = JoinPlan(R, S, filter="ri", n_order=N_ORDER)
    plan.build()
    pairs = plan.candidates("intersects")
    v1, c1 = distributed_filter("ri", plan.approx_r, plan.approx_s, pairs,
                                filter_backend="numpy")
    v2, c2 = distributed_filter("ri", plan.approx_r, plan.approx_s, pairs,
                                backend="sequential")
    np.testing.assert_array_equal(v1, v2)
    assert c1 == c2


def test_launcher_exposes_filter_backend_flag():
    src = (ROOT / "src" / "repro" / "launch" / "spatial_join.py").read_text()
    assert '"--filter-backend"' in src


# ---------------------------------------------------------------------------
# the check_bench CI gate
# ---------------------------------------------------------------------------

def _run_gate(*paths):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_bench.py"),
         *map(str, paths)], capture_output=True, text=True)


def test_check_bench_gate_committed_artifacts_green():
    r = _run_gate()
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_gate_rejects_regressions(tmp_path):
    ok = tmp_path / "BENCH_ok.json"
    ok.write_text(json.dumps(
        {"methods": {"m": {"speedup": 2.0, "verdicts_equal": True}}}))
    assert _run_gate(ok).returncode == 0
    for bad in ({"methods": {"m": {"speedup": 0.4, "verdicts_equal": True}}},
                {"methods": {"m": {"speedup": 3.0, "verdicts_equal": False}}},
                {"methods": {"m": {"pair_sets_equal": False, "speedup": 2.0}}},
                {"no": "speedup at all"}):
        p = tmp_path / "BENCH_bad.json"
        p.write_text(json.dumps(bad))
        assert _run_gate(p).returncode == 1, bad
    p = tmp_path / "BENCH_trunc.json"
    p.write_text('{"methods": ')
    assert _run_gate(p).returncode == 1
