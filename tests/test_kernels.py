"""Pallas kernel validation (interpret=True): shape/dtype sweeps vs the
pure-jnp oracles, plus integration against the core implementation."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import geometry
from repro.core.april import build_april
from repro.core.join import interval_join_pair, pack_lists
from repro.datagen import make_dataset
from repro.kernels.april_attention.ops import april_attention, build_block_intervals
from repro.kernels.april_attention.ref import april_attention_ref, dense_mask
from repro.kernels.interval_join.ops import batch_interval_overlap
from repro.kernels.interval_join.ref import interval_overlap_ref
from repro.kernels.refine.ops import batch_edges_intersect
from repro.kernels.refine.ref import edges_intersect_ref
from repro.kernels.ri_and.ops import (batch_aligned_and, pack_bits_u32,
                                      xor_mask_words)
from repro.kernels.ri_and.ref import aligned_and_ref


# ---------------------------------------------------------------- interval_join

def _random_interval_batch(rng, B, I, J, spread=10_000):
    I32_MAX = np.iinfo(np.int32).max
    xs = np.full((B, I), I32_MAX, np.int32); xl = xs.copy()
    ys = np.full((B, J), I32_MAX, np.int32); yl = ys.copy()
    nx = rng.integers(0, I + 1, B).astype(np.int32)
    ny = rng.integers(0, J + 1, B).astype(np.int32)
    for b in range(B):
        if nx[b]:
            p = np.sort(rng.choice(spread, size=2 * nx[b], replace=False))
            xs[b, :nx[b]] = p[0::2]; xl[b, :nx[b]] = p[1::2] - 1
        if ny[b]:
            p = np.sort(rng.choice(spread, size=2 * ny[b], replace=False))
            ys[b, :ny[b]] = p[0::2]; yl[b, :ny[b]] = p[1::2] - 1
    return xs, xl, nx, ys, yl, ny


@pytest.mark.parametrize("B,I,J", [(5, 3, 4), (16, 64, 64), (9, 17, 130),
                                   (8, 128, 256), (3, 1, 1)])
def test_interval_join_kernel_sweep(B, I, J):
    rng = np.random.default_rng(B * 1000 + I + J)
    xs, xl, nx, ys, yl, ny = _random_interval_batch(rng, B, I, J)
    got = np.asarray(batch_interval_overlap(xs, xl, nx, ys, yl, ny,
                                            interpret=True))
    want = np.asarray(interval_overlap_ref(
        jnp.asarray(xs), jnp.asarray(xl), jnp.asarray(nx),
        jnp.asarray(ys), jnp.asarray(yl), jnp.asarray(ny)))
    np.testing.assert_array_equal(got, want)


def test_interval_join_kernel_vs_merge_join():
    """Kernel verdict == the paper's sequential merge join on real APRIL data."""
    R = make_dataset("T1", seed=71, count=40)
    S = make_dataset("T2", seed=72, count=40)
    ar, as_ = build_april(R, 7), build_april(S, 7)
    idx_r = np.arange(40); idx_s = np.arange(40)
    xs, xl, nx = pack_lists(ar, idx_r, "A")
    ys, yl, ny = pack_lists(as_, idx_s, "A")
    got = np.asarray(batch_interval_overlap(xs, xl, nx, ys, yl, ny,
                                            interpret=True))
    want = np.asarray([
        interval_join_pair(ar.a_list(i), as_.a_list(j))
        for i, j in zip(idx_r, idx_s)])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- ri_and

@pytest.mark.parametrize("B,W,density", [(8, 2, 0.05), (24, 6, 0.08),
                                         (5, 16, 0.02), (12, 4, 0.5)])
def test_ri_and_kernel_sweep(B, W, density):
    rng = np.random.default_rng(B + W)
    xw = np.zeros((B, W), np.uint32); yw = np.zeros((B, W), np.uint32)
    meta = np.zeros((B, 4), np.int32)
    for b in range(B):
        xw[b] = pack_bits_u32((rng.random(32 * W) < density).astype(np.uint8), W)
        yw[b] = pack_bits_u32((rng.random(32 * W) < density).astype(np.uint8), W)
        max_off = max(1, 32 * (W - 2))
        meta[b] = (int(rng.integers(0, max_off)), int(rng.integers(0, max_off)),
                   int(rng.integers(1, 64)), int(rng.integers(0, 2)))
    mask = xor_mask_words(W)
    got = np.asarray(batch_aligned_and(xw, yw, meta, mask, interpret=True))
    want = np.asarray(aligned_and_ref(jnp.asarray(xw), jnp.asarray(yw),
                                      meta, jnp.asarray(mask)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- refine

@pytest.mark.parametrize("seed,count", [(81, 16), (82, 24)])
def test_refine_kernel_sweep(seed, count):
    R = make_dataset("T1", seed=seed, count=count)
    S = make_dataset("T2", seed=seed + 1, count=count)
    idx = np.arange(count)
    sa, ea, ma = geometry.polygon_edges(R.verts[idx], R.nverts[idx])
    sb, eb, mb = geometry.polygon_edges(S.verts[idx], S.nverts[idx])
    hit, unc = batch_edges_intersect(sa, ea, ma, sb, eb, mb, interpret=True)
    rh, ru = edges_intersect_ref(jnp.asarray(sa, jnp.float32),
                                 jnp.asarray(ea, jnp.float32), jnp.asarray(ma),
                                 jnp.asarray(sb, jnp.float32),
                                 jnp.asarray(eb, jnp.float32), jnp.asarray(mb))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(rh))
    np.testing.assert_array_equal(np.asarray(unc), np.asarray(ru))
    # soundness: definite kernel hits must be true intersections (f64 oracle)
    for b in range(count):
        if bool(hit[b]) and not bool(unc[b]):
            assert geometry.polygons_intersect(
                R.verts[b], R.nverts[b], S.verts[b], S.nverts[b])


def test_refine_kernel_overlapping_pairs():
    """Force intersecting pairs (shifted copies) — kernel must find them."""
    R = make_dataset("T1", seed=83, count=12)
    verts2 = R.verts + 1e-4  # tiny shift => guaranteed overlap
    from repro.datagen.synthetic import PolygonDataset
    S = PolygonDataset(name="shift", verts=verts2, nverts=R.nverts)
    idx = np.arange(12)
    sa, ea, ma = geometry.polygon_edges(R.verts[idx], R.nverts[idx])
    sb, eb, mb = geometry.polygon_edges(S.verts[idx], S.nverts[idx])
    hit, unc = batch_edges_intersect(sa, ea, ma, sb, eb, mb, interpret=True)
    assert bool(np.all(np.asarray(hit) | np.asarray(unc)))


# ---------------------------------------------------------------- april_attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind,window,softcap", [
    ("causal", 0, None), ("local", 96, None), ("local", 64, 30.0),
    ("full", 0, None)])
def test_april_attention_sweep(dtype, kind, window, softcap):
    rng = np.random.default_rng(11)
    BH, S, D = 2, 256, 64
    q = jnp.asarray(rng.normal(size=(BH, S, D)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, S, D)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, S, D)), dtype)
    got = april_attention(q, k, v, block_q=64, block_kv=64, mask_kind=kind,
                          window=window, softcap=softcap, interpret=True)
    want = april_attention_ref(q, k, v, mask_kind=kind, window=window,
                               softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("S,bq,bkv", [(256, 128, 64), (512, 64, 128)])
def test_april_attention_blocks(S, bq, bkv):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.normal(size=(1, S, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, 32)), jnp.float32)
    got = april_attention(q, k, v, block_q=bq, block_kv=bkv,
                          mask_kind="causal", interpret=True)
    want = april_attention_ref(q, k, v, mask_kind="causal")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_block_intervals_classification():
    """The interval table must be the exact APRIL A/F classification of the
    (q_block x kv_block) raster of the mask."""
    for kind, window in [("causal", 0), ("local", 96), ("full", 0)]:
        Sq = Skv = 512; bq = bkv = 64
        iv = build_block_intervals(Sq, Skv, bq, bkv, kind, window)
        mask = np.asarray(dense_mask(Sq, Skv, kind, window))
        for qi in range(Sq // bq):
            rows = mask[qi * bq: (qi + 1) * bq]
            for ki in range(Skv // bkv):
                blk = rows[:, ki * bkv: (ki + 1) * bkv]
                a_lo, f_lo, f_hi, a_hi = iv[qi]
                in_a = a_lo <= ki < a_hi
                in_f = f_lo <= ki < f_hi
                if blk.all():
                    assert in_a, (kind, qi, ki)
                    # a Full block must never be treated as maskable-out
                elif blk.any():
                    assert in_a and not in_f, (kind, qi, ki)
                else:
                    assert not in_a or not in_f, (kind, qi, ki)
