"""Fault tolerance: checkpoint/restore roundtrip + integrity, crash-resume
equivalence, elastic re-mesh, straggler detection, work-stealing queue,
gradient compression."""
import os

import numpy as np
import pytest

pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.launch.train import SyntheticCorpus, train_loop
from repro.optim.grad_compression import (dequantize_int8, ef_compress_tree,
                                          quantize_int8)
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import StragglerMonitor, WorkQueue


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(3, tree)
    mgr.save(7, jax.tree.map(lambda x: x * 2, tree))
    assert mgr.latest_step() == 7
    step, restored, _ = mgr.restore_tree(tree)
    assert step == 7
    np.testing.assert_array_equal(restored["a"], np.arange(12.0).reshape(3, 4) * 2)


def test_checkpoint_gc_and_corruption(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    # corrupt latest -> checksum failure
    d = os.path.join(str(tmp_path), "step_4")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))
    arr[0, 0] += 1
    np.save(os.path.join(d, fn), arr)
    with pytest.raises(IOError):
        mgr.restore(4)
    # older checkpoint still valid
    step, _, _ = mgr.restore(3)
    assert step == 3


def test_crash_resume_equivalence(tmp_path):
    """Training with an injected crash + resume must produce the same final
    loss trajectory as an uninterrupted run (data cursor checkpointing)."""
    kw = dict(smoke=True, steps=12, batch=2, seq=32, ckpt_every=4, lr=1e-3)
    _, _, ref = train_loop("smollm-135m", ckpt_dir=None, **kw)

    ck = str(tmp_path / "run")
    with pytest.raises(RuntimeError):
        train_loop("smollm-135m", ckpt_dir=ck, fail_at_step=9, **kw)
    _, _, resumed = train_loop("smollm-135m", ckpt_dir=ck, **kw)
    # resumed run re-executes steps 8..11 (last ckpt at 8)
    np.testing.assert_allclose(resumed, ref[8:], rtol=1e-4, atol=1e-5)


def test_data_cursor_restart():
    d1 = SyntheticCorpus(100, 2, 8)
    batches = [d1.next_batch() for _ in range(5)]
    st = d1.state()
    d2 = SyntheticCorpus(100, 2, 8)
    d2.load_state(st)
    nxt1 = d1.next_batch()
    nxt2 = d2.next_batch()
    np.testing.assert_array_equal(np.asarray(nxt1["tokens"]),
                                  np.asarray(nxt2["tokens"]))


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=5.0)
    import time
    for _ in range(3):
        mon.start(); time.sleep(0.002); mon.stop()
    mon.start(); time.sleep(0.08)
    assert mon.stop() is True
    assert len(mon.flagged) == 1


def test_work_queue_lease_expiry():
    q = WorkQueue([1, 2, 3], lease_seconds=0.01)
    a = q.acquire(); b = q.acquire()
    q.complete(a)
    import time
    time.sleep(0.02)          # b's lease expires
    c = q.acquire()           # 3
    d = q.acquire()           # recovered b
    assert {c, d} == {3, b}
    q.complete(c); q.complete(d)
    assert q.finished


def test_int8_quantization_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased():
    """With error feedback, the cumulative compressed sum tracks the true
    cumulative gradient (residual never grows)."""
    rng = np.random.default_rng(1)
    g_tree = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    resid = {"w": jnp.zeros((64,), jnp.float32)}
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for t in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        q, s, resid = ef_compress_tree(g, resid)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(dequantize_int8(q["w"], s["w"]))
    drift = np.abs(total_comp - total_true).max()
    assert drift <= float(np.abs(np.asarray(resid["w"])).max()) + 1e-4


def test_elastic_remesh_subprocess():
    """Restore a checkpoint under a DIFFERENT mesh size (8 -> 4 devices)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.runtime.elastic import make_mesh_from_devices, remesh_tree
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
        spec = {"w": P("data", "model")}
        mesh8 = make_mesh_from_devices(jax.devices(), n_model=2)
        t8 = remesh_tree(tree, mesh8, spec)
        # node failure: only 4 devices survive
        mesh4 = make_mesh_from_devices(jax.devices()[:4], n_model=2)
        t4 = remesh_tree({"w": np.asarray(t8["w"])}, mesh4, spec)
        np.testing.assert_array_equal(np.asarray(t4["w"]), tree["w"])
        print("ELASTIC_OK", mesh4.shape)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
