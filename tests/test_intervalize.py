import numpy as np
import pytest

from repro.core import intervalize, rasterize
from repro.core.intervalize import ids_in_intervals, intervals_from_ids
from repro.datagen import make_dataset


def test_intervals_from_ids_roundtrip():
    ids = np.array([1, 2, 3, 7, 9, 10, 25], dtype=np.uint64)
    ints = intervals_from_ids(ids)
    np.testing.assert_array_equal(
        ints, np.array([[1, 4], [7, 8], [9, 11], [25, 26]], np.uint64))
    np.testing.assert_array_equal(ids_in_intervals(ints), ids)


@pytest.mark.parametrize("method", ["batched", "pips", "neighbors"])
def test_onestep_matches_full_raster(method):
    """One-step intervalization (all variants) must equal the §6.1
    full-rasterization path exactly — the paper's central construction claim."""
    ds = make_dataset("T1", seed=11, count=14)
    n_order = 7
    for i in range(len(ds)):
        v, n = ds.verts[i], int(ds.nverts[i])
        partial = rasterize.dda_partial_cells(v, n, n_order)
        full = rasterize.scanline_full_cells(v, n, partial, n_order)
        a_ref, f_ref = intervalize.april_from_cells(partial, full, n_order)
        a_got, f_got = intervalize.onestep(v, n, n_order, method=method)
        np.testing.assert_array_equal(a_got, a_ref, err_msg=f"A poly {i}")
        np.testing.assert_array_equal(f_got, f_ref, err_msg=f"F poly {i}")


def test_onestep_f_subset_a():
    ds = make_dataset("T2", seed=12, count=10)
    for i in range(len(ds)):
        a, f = intervalize.onestep(ds.verts[i], int(ds.nverts[i]), 7)
        a_ids = set(ids_in_intervals(a).tolist())
        f_ids = set(ids_in_intervals(f).tolist())
        assert f_ids <= a_ids
        # A/F lists are sorted + disjoint
        for ints in (a, f):
            flat = ints.reshape(-1)
            assert np.all(flat[1:] >= flat[:-1])
            assert np.all(ints[:, 1] > ints[:, 0])


def test_corner_covering_polygon():
    """Polygon covering the Hilbert-curve origin cell (robustness fix).

    The virtual *leading* gap [0, first_partial) has zero length here; it
    must not split or shift the A-intervals.
    """
    v = np.array([[0.0, 0.0], [0.4, 0.0], [0.4, 0.4], [0.0, 0.4]]) + 1e-9
    n_order = 5
    partial = rasterize.dda_partial_cells(v, 4, n_order)
    full = rasterize.scanline_full_cells(v, 4, partial, n_order)
    a_ref, f_ref = intervalize.april_from_cells(partial, full, n_order)
    a_got, f_got = intervalize.onestep(v, 4, n_order, method="batched")
    np.testing.assert_array_equal(a_got, a_ref)
    np.testing.assert_array_equal(f_got, f_ref)
    # id 0 must be covered (corner is inside the polygon)
    assert a_got[0, 0] == 0


@pytest.mark.parametrize("method", ["batched", "pips", "neighbors"])
def test_corner_covering_polygon_trailing(method):
    """Polygon covering the Hilbert curve's LAST cell: the virtual
    *trailing* gap [last_partial+1, 4^N) has zero length — audit that it
    cannot split A-intervals either (the `_assemble` zero-length-block
    exclusion)."""
    n_order = 5
    # the curve ends at cell (G-1, 0): cover the bottom-right map corner
    v = np.array([[0.6, 0.0], [1.0, 0.0], [1.0, 0.4], [0.6, 0.4]])
    v = np.clip(v, 1e-9, 1 - 1e-9)
    partial = rasterize.dda_partial_cells(v, 4, n_order)
    full = rasterize.scanline_full_cells(v, 4, partial, n_order)
    a_ref, f_ref = intervalize.april_from_cells(partial, full, n_order)
    a_got, f_got = intervalize.onestep(v, 4, n_order, method=method)
    np.testing.assert_array_equal(a_got, a_ref)
    np.testing.assert_array_equal(f_got, f_ref)
    # the last id 4^N - 1 must be covered (corner cell is inside)
    assert int(a_got[-1, 1]) == 4 ** n_order


def test_both_corners_covered_multi():
    """Zero-length lead AND trail gaps at once, through the batched
    dataset-level path (onestep_multi) and the sequential reference."""
    n_order = 4
    eps = 1e-9
    band = np.clip(np.array(
        [[0.0, 0.0], [1.0, 0.0], [1.0, 0.3], [0.0, 0.3]]), eps, 1 - eps)
    verts = band[None, :, :]
    nv = np.array([4])
    a_off, a_ints, f_off, f_ints = intervalize.onestep_multi(
        verts, nv, n_order)
    a_ref, f_ref = intervalize.onestep(band, 4, n_order)
    np.testing.assert_array_equal(a_ints[a_off[0]:a_off[1]], a_ref)
    np.testing.assert_array_equal(f_ints[f_off[0]:f_off[1]], f_ref)
    assert int(a_ref[0, 0]) == 0 and int(a_ref[-1, 1]) == 4 ** n_order
