import numpy as np
import pytest

from repro.core import intervalize, rasterize
from repro.core.intervalize import ids_in_intervals, intervals_from_ids
from repro.datagen import make_dataset


def test_intervals_from_ids_roundtrip():
    ids = np.array([1, 2, 3, 7, 9, 10, 25], dtype=np.uint64)
    ints = intervals_from_ids(ids)
    np.testing.assert_array_equal(
        ints, np.array([[1, 4], [7, 8], [9, 11], [25, 26]], np.uint64))
    np.testing.assert_array_equal(ids_in_intervals(ints), ids)


@pytest.mark.parametrize("method", ["batched", "pips", "neighbors"])
def test_onestep_matches_full_raster(method):
    """One-step intervalization (all variants) must equal the §6.1
    full-rasterization path exactly — the paper's central construction claim."""
    ds = make_dataset("T1", seed=11, count=14)
    n_order = 7
    for i in range(len(ds)):
        v, n = ds.verts[i], int(ds.nverts[i])
        partial = rasterize.dda_partial_cells(v, n, n_order)
        full = rasterize.scanline_full_cells(v, n, partial, n_order)
        a_ref, f_ref = intervalize.april_from_cells(partial, full, n_order)
        a_got, f_got = intervalize.onestep(v, n, n_order, method=method)
        np.testing.assert_array_equal(a_got, a_ref, err_msg=f"A poly {i}")
        np.testing.assert_array_equal(f_got, f_ref, err_msg=f"F poly {i}")


def test_onestep_f_subset_a():
    ds = make_dataset("T2", seed=12, count=10)
    for i in range(len(ds)):
        a, f = intervalize.onestep(ds.verts[i], int(ds.nverts[i]), 7)
        a_ids = set(ids_in_intervals(a).tolist())
        f_ids = set(ids_in_intervals(f).tolist())
        assert f_ids <= a_ids
        # A/F lists are sorted + disjoint
        for ints in (a, f):
            flat = ints.reshape(-1)
            assert np.all(flat[1:] >= flat[:-1])
            assert np.all(ints[:, 1] > ints[:, 0])


def test_corner_covering_polygon():
    """Polygon covering the Hilbert-curve origin cell (robustness fix)."""
    v = np.array([[0.0, 0.0], [0.4, 0.0], [0.4, 0.4], [0.0, 0.4]]) + 1e-9
    n_order = 5
    partial = rasterize.dda_partial_cells(v, 4, n_order)
    full = rasterize.scanline_full_cells(v, 4, partial, n_order)
    a_ref, f_ref = intervalize.april_from_cells(partial, full, n_order)
    a_got, f_got = intervalize.onestep(v, 4, n_order, method="batched")
    np.testing.assert_array_equal(a_got, a_ref)
    np.testing.assert_array_equal(f_got, f_ref)
    # id 0 must be covered (corner is inside the polygon)
    assert a_got[0, 0] == 0
