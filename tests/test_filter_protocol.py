"""The IntermediateFilter protocol + JoinPlan session API: registry
round-trip, approximation reuse across predicates, and — the core contract —
batched `verdicts` must be verdict-identical to the sequential per-pair
reference for every registered filter on every predicate."""
import numpy as np
import pytest

from repro.datagen import make_dataset, make_linestrings
from repro.spatial import (Approximation, JoinPlan, available_filters,
                           get_filter, register_filter)
from repro.spatial.filters import IntermediateFilter, unregister_filter
from repro.spatial.filters.base import PREDICATES

N_ORDER = 7
METHODS = ("none", "april", "april-c", "ri", "ra", "5cch")
BUILD_OPTS = {"ra": {"max_cells": 128}}


@pytest.fixture(scope="module")
def data():
    R = make_dataset("T1", seed=51, count=60)
    S = make_dataset("T2", seed=52, count=90)
    W = make_dataset("T10", seed=53, count=30)   # large: within-hits vs R
    L = make_linestrings(seed=54, count=60)
    return R, S, W, L


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contains_all_methods():
    assert set(METHODS) <= set(available_filters())


def test_registry_roundtrip():
    for m in METHODS:
        filt = get_filter(m)
        assert isinstance(filt, IntermediateFilter)
        assert filt.name == m
    # instances pass through unchanged
    inst = get_filter("april")
    assert get_filter(inst) is inst
    with pytest.raises(ValueError, match="unknown intermediate filter"):
        get_filter("nope")


def test_registry_register_custom():
    @register_filter("always-indecisive")
    class Custom(IntermediateFilter):
        def build(self, dataset, **opts):
            return Approximation(filter=self.name, store=None)

        def verdicts(self, ar, as_, pairs, **opts):
            return self._all_indecisive(pairs)

    try:
        filt = get_filter("always-indecisive")
        assert filt.name == "always-indecisive"
        assert "always-indecisive" in available_filters()
    finally:
        unregister_filter("always-indecisive")
    assert "always-indecisive" not in available_filters()


# ---------------------------------------------------------------------------
# JoinPlan session reuse
# ---------------------------------------------------------------------------

def test_joinplan_build_execute_reuse(data):
    R, S, W, L = data
    plan = JoinPlan(R, W, filter="april", n_order=N_ORDER)
    plan.build()
    ar, as_ = plan.approx_r, plan.approx_s
    assert isinstance(ar, Approximation) and ar.size_bytes() > 0
    res1, st1 = plan.execute("intersects")
    # built approximations survive across predicates and executions
    res2, st2 = plan.execute("within")
    res3, st3 = plan.execute("intersects")
    assert plan.approx_r is ar and plan.approx_s is as_
    assert st2.t_build == st1.t_build  # build cost paid once
    assert np.array_equal(np.sort(res1, axis=0), np.sort(res3, axis=0))
    assert st1.predicate == "intersects" and st2.predicate == "within"


def test_joinplan_adopts_prebuilt_stores(data):
    R, S, _, _ = data
    from repro.core.april import build_april
    store_r = build_april(R, N_ORDER)
    store_s = build_april(S, N_ORDER)
    plan = JoinPlan(R, S, filter="april", n_order=N_ORDER)
    plan.build(prebuilt=(store_r, store_s))
    assert plan.approx_r.store is store_r
    res, st = plan.execute("intersects")
    ref, _ = JoinPlan(R, S, filter="april",
                      n_order=N_ORDER).build().execute("intersects")
    assert np.array_equal(np.sort(res, axis=0), np.sort(ref, axis=0))


def test_joinplan_linestring_requires_line_kind(data):
    R, S, _, L = data
    plan = JoinPlan(R, S, filter="april", n_order=N_ORDER)
    with pytest.raises(ValueError, match="r_kind"):
        plan.execute("linestring")


def test_none_filter_builds_nothing(data):
    R, _, W, _ = data
    plan = JoinPlan(R, W, filter="none")
    plan.build()
    assert plan.approx_r.store is None and plan.approx_s.store is None
    _, st = plan.execute("within")
    assert st.n_indecisive == st.n_candidates
    assert st.approx_bytes == 0


# ---------------------------------------------------------------------------
# batched verdicts == sequential per-pair reference, all filters x predicates
# ---------------------------------------------------------------------------

def _plan_for(data, method, predicate):
    R, S, W, L = data
    build_opts = BUILD_OPTS.get(method, {})
    if predicate == "linestring":
        return JoinPlan(L, S, filter=method, n_order=N_ORDER, r_kind="line",
                        build_opts=build_opts)
    if predicate == "within":
        return JoinPlan(R, W, filter=method, n_order=N_ORDER,
                        build_opts=build_opts)
    if predicate == "selection":
        queries = make_dataset("T3", seed=55, count=5)
        return JoinPlan(R, queries, filter=method, n_order=N_ORDER,
                        build_opts=build_opts)
    return JoinPlan(R, S, filter=method, n_order=N_ORDER,
                    build_opts=build_opts)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("predicate", PREDICATES)
def test_batched_matches_sequential(data, method, predicate):
    plan = _plan_for(data, method, predicate)
    plan.build()
    pairs = plan.candidates(predicate)
    assert len(pairs) > 5, "fixture must produce candidates"
    v_seq = plan.filter.verdicts_seq(plan.approx_r, plan.approx_s, pairs,
                                     predicate=predicate)
    v_bat = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                 predicate=predicate)
    assert v_bat.dtype == np.int8
    assert np.array_equal(v_seq, v_bat), (
        f"{method}/{predicate}: batched verdicts diverged "
        f"(seq {np.bincount(v_seq, minlength=3)}, "
        f"bat {np.bincount(v_bat, minlength=3)})")


def test_backend_choice_never_changes_verdicts(data):
    """jnp/pallas backends must agree with numpy (small batch, APRIL + RI)."""
    R, S, _, _ = data
    pairs = None
    for method in ("april", "ri"):
        plan = JoinPlan(R, S, filter=method, n_order=N_ORDER)
        plan.build()
        pairs = plan.candidates("intersects")[:64]
        base = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                    predicate="intersects", backend="numpy")
        for backend in ("jnp", "pallas"):
            got = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                       predicate="intersects",
                                       backend=backend)
            assert np.array_equal(base, got), (method, backend)


def test_unknown_predicate_and_backend_raise(data):
    R, S, _, _ = data
    plan = JoinPlan(R, S, filter="none")
    plan.build()
    pairs = np.zeros((1, 2), np.int64)
    with pytest.raises(ValueError, match="unknown predicate"):
        plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                             predicate="overlaps")
    with pytest.raises(ValueError, match="unknown backend"):
        plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                             backend="cuda")
