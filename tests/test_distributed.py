"""Distributed join: single-device shard_map correctness + an 8-device
subprocess test (the main test process must keep the default 1-CPU backend)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core.april import build_april
from repro.core.join import april_verdict_pair
from repro.datagen import make_dataset
from repro.spatial.distributed import (
    bucket_pairs, distributed_april_filter, pack_pair_batch)
from repro.spatial.mbr_join import mbr_join

N_ORDER = 7


@pytest.fixture(scope="module")
def setup():
    R = make_dataset("T1", seed=51, count=60)
    S = make_dataset("T2", seed=52, count=90)
    ar, as_ = build_april(R, N_ORDER), build_april(S, N_ORDER)
    pairs = mbr_join(R.mbrs, S.mbrs)
    return R, S, ar, as_, pairs


def test_sharded_filter_matches_reference(setup):
    R, S, ar, as_, pairs = setup
    assert len(pairs) > 10
    packed = pack_pair_batch(ar, as_, pairs, pad_batch_to=1)
    verd, counts = distributed_april_filter(packed)
    ref = np.asarray([
        april_verdict_pair(ar.a_list(int(i)), ar.f_list(int(i)),
                           as_.a_list(int(j)), as_.f_list(int(j)))
        for i, j in pairs], np.int8)
    np.testing.assert_array_equal(verd[packed.valid], ref)
    assert counts["true_hit"] == int(np.sum(ref == 1))
    assert counts["true_neg"] == int(np.sum(ref == 0))


def test_bucketing_covers_all_pairs(setup):
    R, S, ar, as_, pairs = setup
    buckets = bucket_pairs(ar, as_, pairs, n_devices=4)
    seen = set()
    for b in buckets:
        assert len(b) % 4 == 0
        for (i, j), v in zip(b.pair_idx, b.valid):
            if v:
                seen.add((int(i), int(j)))
    assert seen == set(map(tuple, pairs.tolist()))


MULTI_DEV_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core.april import build_april
    from repro.core.join import april_verdict_pair
    from repro.datagen import make_dataset
    from repro.spatial.distributed import (
        distributed_april_filter, make_join_mesh, pack_pair_batch)
    from repro.spatial.mbr_join import mbr_join

    assert jax.device_count() == 8
    R = make_dataset("T1", seed=51, count=60)
    S = make_dataset("T2", seed=52, count=90)
    ar, as_ = build_april(R, 7), build_april(S, 7)
    pairs = mbr_join(R.mbrs, S.mbrs)
    packed = pack_pair_batch(ar, as_, pairs, pad_batch_to=8)
    mesh = make_join_mesh(8)
    verd, counts = distributed_april_filter(packed, mesh)
    ref = np.asarray([
        april_verdict_pair(ar.a_list(int(i)), ar.f_list(int(i)),
                           as_.a_list(int(j)), as_.f_list(int(j)))
        for i, j in pairs], np.int8)
    np.testing.assert_array_equal(verd[packed.valid], ref)
    print("MULTIDEV_OK", counts)
""")


def test_multi_device_subprocess(setup):
    r = subprocess.run([sys.executable, "-c", MULTI_DEV_SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MULTIDEV_OK" in r.stdout
