"""Incremental store maintenance (DESIGN.md §10): insert/delete patches
must leave every filter's store identical to a fresh rebuild — arrays and
verdicts — and the warm MBR index identical to a rebuilt bucket table."""
import numpy as np
import pytest

from repro.core.join import IntervalLists, csr_append_row, csr_delete_row
from repro.datagen import make_dataset
from repro.datagen.synthetic import PolygonDataset
from repro.spatial import JoinPlan, available_filters, get_filter
from repro.spatial.mbr_join import MBRIndex, mbr_intersect_mask

N_ORDER = 6


def _subset(ds, ids):
    return PolygonDataset(name=ds.name, verts=ds.verts[ids],
                          nverts=ds.nverts[ids])


def _stores_equal(a, b):
    if a is None or b is None:
        return a is b
    for k, v in vars(a).items():
        w = getattr(b, k)
        if isinstance(v, np.ndarray):
            if v.shape != w.shape or not np.array_equal(v, w):
                return False
        elif isinstance(v, list):
            if len(v) != len(w):
                return False
            for x, y in zip(v, w):
                if isinstance(x, np.ndarray):
                    if not np.array_equal(x, y):
                        return False
                elif x != y:
                    return False
        elif v != w:
            return False
    return True


# ---------------------------------------------------------------------------
# CSR splice primitives
# ---------------------------------------------------------------------------

def test_csr_row_splices():
    rng = np.random.default_rng(0)
    rows = [rng.integers(0, 50, size=n).astype(np.int32)
            for n in (3, 0, 4, 2)]
    off = np.cumsum([0] + [len(r) for r in rows]).astype(np.int64)
    data = np.concatenate(rows)

    off2, data2 = csr_delete_row(off, data, 2)
    ref = rows[:2] + rows[3:]
    assert np.array_equal(off2, np.cumsum([0] + [len(r) for r in ref]))
    assert np.array_equal(data2, np.concatenate(ref))

    new = np.array([9, 9, 9], np.int32)
    off3, data3 = csr_append_row(off2, data2, new)
    ref.append(new)
    assert np.array_equal(off3, np.cumsum([0] + [len(r) for r in ref]))
    assert np.array_equal(data3, np.concatenate(ref))


def test_interval_lists_patch_matches_rebuild():
    rng = np.random.default_rng(1)
    rows = [np.sort(rng.integers(0, 99, size=rng.integers(0, 6)))
            .astype(np.int32) for _ in range(5)]
    off = np.cumsum([0] + [len(r) for r in rows]).astype(np.int64)
    starts = np.concatenate(rows)
    il = IntervalLists(off=off, starts=starts.copy(),
                       lasts=(starts + 2).copy())
    il.delete_row(1)
    new = np.array([4, 40], np.int32)
    il.append_row(new, new + 2)
    ref_rows = rows[:1] + rows[2:] + [new]
    ref_off = np.cumsum([0] + [len(r) for r in ref_rows]).astype(np.int64)
    ref_starts = np.concatenate(ref_rows)
    assert np.array_equal(il.off, ref_off)
    assert np.array_equal(il.starts, ref_starts)
    assert np.array_equal(il.lasts, ref_starts + 2)
    assert il._device is None   # patch drops the stale device upload


# ---------------------------------------------------------------------------
# Warm MBR index
# ---------------------------------------------------------------------------

def test_mbr_index_probe_matches_oracle_all_backends():
    R = make_dataset("T1", seed=31, count=70)
    Q = make_dataset("T2", seed=32, count=40)
    index = MBRIndex(R.mbrs)
    ref = set(map(tuple, np.stack(
        np.nonzero(mbr_intersect_mask(R.mbrs, Q.mbrs)), axis=1).tolist()))
    for backend in ("numpy", "jnp", "sequential"):
        got = set(map(tuple, index.probe(Q.mbrs, backend=backend).tolist()))
        assert got == ref, backend
    # queries far outside the index extent still produce the oracle set
    far = Q.mbrs + 50.0
    ref_far = set(map(tuple, np.stack(
        np.nonzero(mbr_intersect_mask(R.mbrs, far)), axis=1).tolist()))
    assert set(map(tuple, index.probe(far).tolist())) == ref_far


def test_mbr_index_patch_equals_rebuild():
    R = make_dataset("T1", seed=33, count=50)
    extra = make_dataset("T2", seed=34, count=1)
    index = MBRIndex(R.mbrs)
    new_id = index.insert(extra.mbrs[0])
    assert new_id == 50
    index.delete(4)
    patched_mbrs = np.delete(
        np.concatenate([R.mbrs, extra.mbrs[:1]]), 4, axis=0)
    fresh = MBRIndex(patched_mbrs, grid=index.k, extent=index.extent)
    assert np.array_equal(index._obj, fresh._obj)
    assert np.array_equal(index._buck, fresh._buck)
    assert np.array_equal(index.mbrs, fresh.mbrs)
    assert index.stats["inserts"] == 1 and index.stats["deletes"] == 1
    assert index.stats["entries_touched"] > 0


def test_join_plan_mbr_index_hook_identical_results():
    R = make_dataset("T1", seed=35, count=60)
    S = make_dataset("T2", seed=36, count=45)
    base, _ = JoinPlan(R, S, filter="april", n_order=N_ORDER).execute()
    warm, _ = JoinPlan(R, S, filter="april", n_order=N_ORDER,
                       mbr_index=MBRIndex(R.mbrs)).execute()
    assert set(map(tuple, base.tolist())) == set(map(tuple, warm.tolist()))


# ---------------------------------------------------------------------------
# The identity property, every filter method
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", available_filters())
def test_patched_store_equals_rebuild(method):
    """insert + delete patches == fresh rebuild: store arrays AND verdicts
    (the ISSUE-6 acceptance property)."""
    D = make_dataset("T1", seed=41, count=30)
    Q = make_dataset("T2", seed=42, count=10)
    filt = get_filter(method)
    ids = np.arange(30)

    # build on 29 objects, patch in the 30th, delete object 5
    approx = filt.build(_subset(D, ids[:29]), n_order=N_ORDER)
    filt.patch_insert(approx, _subset(D, ids[29:30]))
    filt.patch_delete(approx, 5)

    patched_D = _subset(D, np.delete(ids, 5))
    fresh = filt.build(patched_D, n_order=N_ORDER)
    assert _stores_equal(approx.store, fresh.store), method

    # verdict identity through the full pipeline on the patched store
    plan = JoinPlan(patched_D, Q, filter=method, n_order=N_ORDER)
    plan.build(prebuilt=(approx, None))
    got, _ = plan.execute("intersects")
    ref, _ = JoinPlan(patched_D, Q, filter=method,
                      n_order=N_ORDER).execute("intersects")
    assert set(map(tuple, got.tolist())) == set(map(tuple, ref.tolist()))


@pytest.mark.parametrize("method", ["april", "ri"])
def test_patch_preserves_warm_interval_caches(method):
    """Patching must not poison warm device-ready caches: verdicts after a
    patch equal a cold plan's, even when the IntervalLists cache was
    populated (and for APRIL spliced in place) before the mutation."""
    D = make_dataset("T1", seed=43, count=25)
    Q = make_dataset("T2", seed=44, count=8)
    filt = get_filter(method)
    approx = filt.build(D, n_order=N_ORDER)
    # populate warm caches with one execution
    plan = JoinPlan(D, Q, filter=method, n_order=N_ORDER)
    plan.build(prebuilt=(approx, None))
    plan.execute("intersects")

    filt.patch_delete(approx, 3)
    patched_D = _subset(D, np.delete(np.arange(25), 3))
    warm = JoinPlan(patched_D, Q, filter=method, n_order=N_ORDER)
    warm.build(prebuilt=(approx, None))
    got, _ = warm.execute("intersects")
    ref, _ = JoinPlan(patched_D, Q, filter=method,
                      n_order=N_ORDER).execute("intersects")
    assert set(map(tuple, got.tolist())) == set(map(tuple, ref.tolist()))


def test_patch_validation():
    D = make_dataset("T1", seed=45, count=10)
    filt = get_filter("april")
    approx = filt.build(D, n_order=N_ORDER)
    with pytest.raises(ValueError, match="1-object"):
        filt.patch_insert(approx, D)
    with pytest.raises(IndexError, match="out of range"):
        filt.patch_delete(approx, 10)
