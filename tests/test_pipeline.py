"""End-to-end pipeline: every intermediate filter must return the EXACT same
result set (they differ only in how much refinement they avoid)."""
import numpy as np
import pytest

from repro.datagen import make_dataset, make_linestrings
from repro.spatial import (polygon_linestring_join, selection_queries,
                           spatial_intersection_join, spatial_within_join)

N_ORDER = 7


@pytest.fixture(scope="module")
def rs():
    return (make_dataset("T1", seed=41, count=70),
            make_dataset("T2", seed=42, count=100))


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).tolist()))


def test_all_methods_same_results(rs):
    R, S = rs
    ref, stats_none = spatial_intersection_join(R, S, method="none")
    ref_set = _pairs_set(ref)
    assert len(ref_set) > 5
    for method in ("april", "april-c", "ri", "ra", "5cch"):
        got, stats = spatial_intersection_join(R, S, method=method,
                                               n_order=N_ORDER)
        assert _pairs_set(got) == ref_set, f"{method} changed join results"
        assert stats.n_candidates == stats_none.n_candidates


def test_april_beats_none_on_refinement(rs):
    R, S = rs
    _, st_none = spatial_intersection_join(R, S, method="none")
    _, st_april = spatial_intersection_join(R, S, method="april", n_order=N_ORDER)
    assert st_april.n_indecisive < st_none.n_indecisive
    h, g, i = st_april.rates()
    assert h > 0 and g > 0


def test_april_jnp_path_matches(rs):
    R, S = rs
    a, _ = spatial_intersection_join(R, S, method="april", n_order=N_ORDER)
    b, _ = spatial_intersection_join(R, S, method="april", n_order=N_ORDER,
                                     use_jnp=True)
    assert _pairs_set(a) == _pairs_set(b)


def test_within_join(rs):
    R, _ = rs
    S = make_dataset("T10", seed=43, count=40)
    ref, _ = spatial_within_join(R, S, method="none")
    got, stats = spatial_within_join(R, S, method="april", n_order=N_ORDER)
    assert _pairs_set(got) == _pairs_set(ref)


def test_linestring_join(rs):
    _, S = rs
    L = make_linestrings(seed=44, count=120)
    ref, _ = polygon_linestring_join(S, L, method="none")
    got, stats = polygon_linestring_join(S, L, method="april", n_order=N_ORDER)
    assert _pairs_set(got) == _pairs_set(ref)
    assert stats.n_indecisive < stats.n_candidates


def test_selection_queries(rs):
    R, _ = rs
    Q = make_dataset("T3", seed=45, count=6)
    ref, _ = selection_queries(R, Q, method="none")
    got, stats = selection_queries(R, Q, method="april", n_order=N_ORDER)
    for a, b in zip(ref, got):
        assert set(a.tolist()) == set(b.tolist())
    assert stats.n_true_hits > 0 or stats.n_true_negs > 0
