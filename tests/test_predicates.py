"""Within joins (§4.3.2), linestring joins (§4.3.3), mixed granularity (§5.3),
and partitioning (§5.2)."""
import numpy as np
import pytest

from repro.core import geometry, granularity, join, partition, rasterize
from repro.core.april import build_april, build_april_polygon
from repro.core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from repro.datagen import make_dataset, make_linestrings

N_ORDER = 7


@pytest.fixture(scope="module")
def data():
    R = make_dataset("T1", seed=31, count=60)
    S = make_dataset("T10", seed=32, count=40)   # larger objects: within-hits
    ar = build_april(R, N_ORDER)
    as_ = build_april(S, N_ORDER)
    pairs = []
    for i in range(len(R)):
        for j in range(len(S)):
            mr, ms = R.mbrs[i], S.mbrs[j]
            if mr[0] <= ms[2] and ms[0] <= mr[2] and mr[1] <= ms[3] and ms[1] <= mr[3]:
                pairs.append((i, j))
    return R, S, ar, as_, pairs


def test_within_soundness(data):
    R, S, ar, as_, pairs = data
    n_hit = 0
    for i, j in pairs:
        v = join.within_verdict_pair(ar.a_list(i), ar.f_list(i),
                                     as_.a_list(j), as_.f_list(j))
        truth = geometry.polygon_within(R.verts[i], R.nverts[i],
                                        S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth, (i, j)
            n_hit += 1
        elif v == TRUE_NEG:
            # AA-disjoint => r cannot be within s
            assert not truth, (i, j)
    assert n_hit > 0, "fixture should contain some definite within-pairs"


def test_linestring_soundness(data):
    R, S, ar, as_, _ = data
    L = make_linestrings(seed=33, count=150)
    n_hit = n_neg = 0
    for j in range(len(S)):
        for k in range(len(L)):
            ml, ms = (geometry.polygon_mbrs(L.verts[k: k + 1], L.nverts[k: k + 1])[0],
                      S.mbrs[j])
            if not (ml[0] <= ms[2] and ms[0] <= ml[2]
                    and ml[1] <= ms[3] and ms[1] <= ml[3]):
                continue
            cells = rasterize.dda_partial_cells(
                L.verts[k], int(L.nverts[k]), N_ORDER, closed=False)
            ids = rasterize.cells_to_hilbert(cells, N_ORDER)
            v = join.linestring_verdict_pair(as_.a_list(j), as_.f_list(j), ids)
            truth = _line_poly_intersect(L.verts[k], int(L.nverts[k]),
                                         S.verts[j], int(S.nverts[j]))
            if v == TRUE_HIT:
                assert truth, (j, k)
                n_hit += 1
            elif v == TRUE_NEG:
                assert not truth, (j, k)
                n_neg += 1
    assert n_hit > 0 and n_neg > 0


def _line_poly_intersect(lv, ln, pv, pn):
    line = np.asarray(lv, np.float64)[:ln]
    poly = np.asarray(pv, np.float64)[:pn]
    a0, a1 = line[:-1], line[1:]
    b0 = poly; b1 = np.roll(poly, -1, axis=0)
    if bool(geometry.segments_intersect(
            a0[:, None, :], a1[:, None, :], b0[None, :, :], b1[None, :, :]).any()):
        return True
    return bool(geometry.points_in_polygon(line[:1], poly)[0])


def test_mixed_granularity(data):
    R, S, ar, as_, pairs = data
    n_coarse = N_ORDER - 2
    as_c = build_april(S, n_coarse)
    n_checked = 0
    for i, j in pairs:
        v = granularity.mixed_order_verdict_pair(
            ar.a_list(i), ar.f_list(i), N_ORDER,
            as_c.a_list(j), as_c.f_list(j), n_coarse)
        truth = geometry.polygons_intersect(
            R.verts[i], R.nverts[i], S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth, (i, j)
        elif v == TRUE_NEG:
            assert not truth, (i, j)
        n_checked += 1
    assert n_checked > 0


def test_scale_intervals_superset():
    ints = np.array([[5, 9], [12, 13], [40, 44]], np.uint64)
    out = granularity.scale_intervals(ints, 4, 2)
    # every original cell's scaled id must be covered
    from repro.core.intervalize import ids_in_intervals
    orig = ids_in_intervals(ints) >> np.uint64(4)
    cover = set(ids_in_intervals(out).tolist())
    assert set(orig.tolist()) <= cover
    flat = out.reshape(-1).astype(np.int64)
    assert np.all(np.diff(flat.reshape(-1, 2), axis=1) > 0)


def test_partitioning_verdicts_consistent(data):
    """Partitioned APRIL (own grid per partition) must stay sound, and the
    reference-point rule must assign each candidate pair exactly one owner."""
    R, S, ar, as_, pairs = data
    parting = partition.partition_space([R, S], parts_per_dim=2)
    stores_r = parting.build_april(R, N_ORDER)
    stores_s = parting.build_april(S, N_ORDER)
    n_checked = 0
    for i, j in pairs[:80]:
        p = partition.reference_partition(2, R.mbrs[i], S.mbrs[j])
        part = parting.partitions[p]
        ridx = part.obj_idx["T1"]; sidx = part.obj_idx["T10"]
        li = np.nonzero(ridx == i)[0]
        lj = np.nonzero(sidx == j)[0]
        assert len(li) == 1 and len(lj) == 1, "owner partition must contain both"
        sr, ss = stores_r[p], stores_s[p]
        v = join.april_verdict_pair(
            sr.a_list(int(li[0])), sr.f_list(int(li[0])),
            ss.a_list(int(lj[0])), ss.f_list(int(lj[0])))
        truth = geometry.polygons_intersect(
            R.verts[i], R.nverts[i], S.verts[j], S.nverts[j])
        if v == TRUE_HIT:
            assert truth
        elif v == TRUE_NEG:
            assert not truth
        n_checked += 1
    assert n_checked > 0


def test_partition_improves_resolution(data):
    """Per-partition grids refine the approximation: indecisive rate must not
    increase with partitioning (paper Tables 8-9 trend)."""
    R, S, ar, as_, pairs = data
    base = [join.april_verdict_pair(ar.a_list(i), ar.f_list(i),
                                    as_.a_list(j), as_.f_list(j))
            for i, j in pairs]
    parting = partition.partition_space([R, S], parts_per_dim=3)
    stores_r = parting.build_april(R, N_ORDER)
    stores_s = parting.build_april(S, N_ORDER)
    part_v = []
    for i, j in pairs:
        p = partition.reference_partition(3, R.mbrs[i], S.mbrs[j])
        part = parting.partitions[p]
        li = np.nonzero(part.obj_idx["T1"] == i)[0]
        lj = np.nonzero(part.obj_idx["T10"] == j)[0]
        sr, ss = stores_r[p], stores_s[p]
        part_v.append(join.april_verdict_pair(
            sr.a_list(int(li[0])), sr.f_list(int(li[0])),
            ss.a_list(int(lj[0])), ss.f_list(int(lj[0]))))
    ind_base = sum(1 for v in base if v == INDECISIVE)
    ind_part = sum(1 for v in part_v if v == INDECISIVE)
    assert ind_part <= ind_base
