"""Adaptive query planner (DESIGN.md §13): deterministic seeded choice,
never-worse-than-best-static estimate, skip-filter fast path, PlanChoice /
JoinStats round trips, verdict identity of the executed adaptive plan, and
the per-shard plan hook of the fused distributed step."""
import json

import numpy as np
import pytest

from repro.datagen import make_dataset
from repro.spatial import JoinPlan, JoinStats, PlanChoice, check_plan_mode
from repro.spatial.mbr_join import mbr_join
from repro.spatial.planner import ORDER_CHOICES, choose_plan


def _pairs_set(p):
    return set(map(tuple, np.asarray(p).reshape(-1, 2).tolist()))


@pytest.fixture(scope="module")
def data():
    return (make_dataset("T1", seed=61, count=70),
            make_dataset("T2", seed=62, count=110))


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_plan_mode_validation(data):
    R, S = data
    with pytest.raises(ValueError, match="plan_mode"):
        JoinPlan(R, S, plan_mode="bogus")
    with pytest.raises(ValueError, match="plan_mode"):
        check_plan_mode("bogus")
    with pytest.raises(ValueError, match="plan_choice"):
        JoinPlan(R, S, plan_mode="static", plan_choice=PlanChoice())
    with pytest.raises(ValueError, match="adaptive"):
        JoinPlan(R, S, plan_mode="static").plan()


def test_choose_plan_rejects_bad_options(data):
    R, S = data
    pairs = mbr_join(R.mbrs, S.mbrs)
    with pytest.raises(TypeError, match="unknown plan option"):
        choose_plan(R, S, pairs, not_an_option=1)
    with pytest.raises(ValueError, match="cannot cost"):
        choose_plan(R, S, pairs, methods=("april", "5cch"))


# ---------------------------------------------------------------------------
# Choice properties
# ---------------------------------------------------------------------------

def test_planning_is_deterministic(data):
    R, S = data
    pairs = mbr_join(R.mbrs, S.mbrs)
    c1 = choose_plan(R, S, pairs, n_order=7)
    c2 = choose_plan(R, S, pairs, n_order=7)
    assert c1.to_dict() == c2.to_dict()


def test_estimate_never_worse_than_best_static(data):
    R, S = data
    pairs = mbr_join(R.mbrs, S.mbrs)
    c = choose_plan(R, S, pairs, n_order=7)
    assert c.est["costs"], "full sample path must produce a cost table"
    # est["costs"] entries are rounded to 3 decimals; total is exact
    assert c.est["total"] <= min(c.est["costs"].values()) + 1e-3
    assert c.key() in c.est["costs"] or c.method == "none"
    assert c.est["plan_work"] >= 0.0


def test_tiny_candidate_set_skips_filter():
    R = make_dataset("T1", seed=63, count=4)
    S = make_dataset("T2", seed=64, count=4)
    c = choose_plan(R, S, mbr_join(R.mbrs, S.mbrs), n_order=7)
    assert c.method == "none" and c.skip_filter
    assert c.est.get("skip_rule") and c.est["plan_work"] == 0.0


def test_plan_choice_json_round_trip():
    c = PlanChoice(method="april-c", n_order=11,
                   order=ORDER_CHOICES[2], pipeline_mode="fused",
                   skip_filter=False, predicate="within",
                   est={"total": 12.5, "costs": {"none": 40.0}})
    back = PlanChoice.from_dict(json.loads(json.dumps(c.to_dict())))
    assert back.to_dict() == c.to_dict()
    assert back.order == c.order and back.key() == c.key()


# ---------------------------------------------------------------------------
# Execution: adaptive == refine-everything reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("predicate", ("intersects", "within"))
def test_adaptive_execute_matches_oracle(data, predicate):
    R, S = data
    plan = JoinPlan(R, S, filter="april", n_order=7, plan_mode="adaptive")
    res, st = plan.execute(predicate)
    ref, _ = JoinPlan(R, S, filter="none").execute(predicate)
    assert _pairs_set(res) == _pairs_set(ref)
    assert st.plan_mode == "adaptive"
    assert "plan" in st.extra and st.extra["t_plan"] >= 0.0
    choice = PlanChoice.from_dict(st.extra["plan"])
    assert plan.filter.name == choice.method
    assert plan.n_order == choice.n_order
    if choice.method in ("april", "april-c") and predicate == "intersects":
        assert tuple(plan.filter_opts["order"]) == choice.order


def test_join_stats_round_trip_preserves_plan(data):
    R, S = data
    _, st = JoinPlan(R, S, filter="april", n_order=7,
                     plan_mode="adaptive").execute("intersects")
    back = JoinStats.from_dict(json.loads(json.dumps(st.to_dict())))
    assert back.plan_mode == "adaptive"
    assert back.extra["plan"] == st.extra["plan"]
    _, st2 = JoinPlan(R, S, filter="april", n_order=7).execute("intersects")
    assert st2.plan_mode == "static" and "plan" not in st2.extra


# ---------------------------------------------------------------------------
# Distributed: per-shard plan hook (skip-filter goes straight to refine)
# ---------------------------------------------------------------------------

def test_distributed_fused_join_honors_skip_filter_plan(data):
    from repro.spatial import get_filter
    from repro.spatial.distributed import distributed_fused_join

    R, S = data
    ar = get_filter("april").build(R, n_order=6, side="R")
    as_ = get_filter("april").build(S, n_order=6, side="S")
    ref, refc = distributed_fused_join(R, S, ar, as_)
    skip = PlanChoice(method="none", skip_filter=True)
    got, gotc = distributed_fused_join(R, S, None, None, plan=skip)
    assert _pairs_set(ref) == _pairs_set(got)
    # without the filter every candidate is refined
    assert gotc["indecisive"] == refc["true_neg"] + refc["true_hit"] \
        + refc["indecisive"]


# ---------------------------------------------------------------------------
# Property: the three §13 guarantees on random workloads
# ---------------------------------------------------------------------------

def _assert_planner_properties(seed_r, seed_s, count_r, count_s, predicate):
    R = make_dataset("T1", seed=seed_r, count=count_r)
    S = make_dataset("T2", seed=seed_s, count=count_s)
    pairs = mbr_join(R.mbrs, S.mbrs)
    c1 = choose_plan(R, S, pairs, predicate=predicate, n_order=7)
    c2 = choose_plan(R, S, pairs, predicate=predicate, n_order=7)
    assert c1.to_dict() == c2.to_dict()
    if c1.est["costs"]:
        assert c1.est["total"] <= min(c1.est["costs"].values()) + 1e-3
    res, _ = JoinPlan(R, S, filter="april", n_order=7,
                      plan_mode="adaptive").execute(predicate)
    ref, _ = JoinPlan(R, S, filter="none").execute(predicate)
    assert _pairs_set(res) == _pairs_set(ref)


@pytest.mark.parametrize("seed", range(4))
def test_planner_properties_random(seed):
    """Seeded fallback of the hypothesis property below — always runs."""
    rng = np.random.default_rng(500 + seed)
    _assert_planner_properties(
        int(rng.integers(0, 1000)), int(rng.integers(1000, 2000)),
        int(rng.integers(3, 60)), int(rng.integers(3, 60)),
        ("intersects", "within")[seed % 2])


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @given(st.integers(0, 2**20), st.integers(0, 2**20),
           st.integers(3, 60), st.integers(3, 60),
           st.sampled_from(("intersects", "within")))
    @settings(max_examples=8, deadline=None)
    def test_planner_properties_hypothesis(sr, ss, cr, cs, predicate):
        _assert_planner_properties(sr, ss, cr, cs, predicate)
