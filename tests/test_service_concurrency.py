"""Concurrency regression for JoinService/StoreCache (DESIGN.md §10/§11).

The lock-discipline analyze pass (LD001/LD002) proved every shared field
is *lexically* guarded; this test proves the guarded implementation is
actually safe under load: caller threads hammer submit / insert / delete /
warm_store / latency_stats / checkpoint-style cache iteration while the
background micro-batch worker drains, with a cache budget small enough to
force eviction traffic.  The assertions are exactness ones — every ticket
resolves, stats counters add up, and the cache's resident-byte accounting
matches a from-scratch recount — so a lost update or torn read fails the
test rather than merely racing.
"""
import threading

import numpy as np
import pytest

from repro.datagen import make_dataset
from repro.spatial import JoinService, StoreCache

N_ORDER = 5
N_THREADS = 4
N_ROUNDS = 12


@pytest.fixture(scope="module")
def data():
    return (make_dataset("T1", seed=71, count=60),
            make_dataset("T2", seed=72, count=12))


def _square(cx, cy, r=0.01):
    return np.array([[cx - r, cy - r], [cx + r, cy - r],
                     [cx + r, cy + r], [cx - r, cy + r]], np.float64)


def test_hammer_submit_patch_evict(data):
    D, Q = data
    # tiny budget: every (method, n_order) store rotation forces evictions
    svc = JoinService(cache_bytes=64 << 10, window_s=0.001,
                      n_order=N_ORDER)
    svc.register_dataset("T1", D)
    svc.start()
    errors: list[BaseException] = []
    tickets_lock = threading.Lock()
    tickets = []
    inserted = []

    def caller(tid: int):
        rng = np.random.default_rng(100 + tid)
        try:
            for r in range(N_ROUNDS):
                i = int(rng.integers(len(Q)))
                t = svc.submit("T1", "selection",
                               Q.verts[i, : Q.nverts[i]])
                with tickets_lock:
                    tickets.append(t)
                if r % 3 == 0:
                    new_id = svc.insert(
                        "T1", _square(rng.random(), rng.random()))
                    with tickets_lock:
                        inserted.append(new_id)
                if r % 4 == 1:
                    # rotate n_order so warm stores churn through the LRU
                    svc.warm_store("T1", n_order=N_ORDER + (r % 3))
                if r % 5 == 2:
                    svc.delete("T1", int(rng.integers(len(D))))
                svc.latency_stats()
                for key, approx in svc.cache.items():
                    assert approx.size_bytes() >= 0
        except BaseException as exc:  # noqa: BLE001 - reraised below
            errors.append(exc)

    threads = [threading.Thread(target=caller, args=(tid,))
               for tid in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    svc.stop()

    assert not errors, errors
    # every ticket resolved (worker or final drain), none torn
    for t in tickets:
        t.wait(10.0)
        assert t.pairs is not None and t.pairs.shape[1] == 2
        assert t.latency is not None and t.latency >= 0.0
    # stats counters: no lost updates
    assert svc.stats["requests"] == N_THREADS * N_ROUNDS
    assert svc.stats["batched_requests"] == svc.stats["requests"]
    assert svc.stats["inserts"] == len(inserted)
    assert svc.stats["deletes"] == N_THREADS * len(
        [r for r in range(N_ROUNDS) if r % 5 == 2])
    assert set(svc.latency_stats()) == {"n", "p50_s", "p99_s", "mean_s",
                                        "stage_times"}
    assert svc.latency_stats()["n"] == svc.stats["requests"]


def test_store_cache_byte_accounting_under_contention():
    cache = StoreCache(48 << 10)
    D = make_dataset("T3", seed=73, count=12)
    from repro.spatial import get_filter
    filt = get_filter("april")
    protos = [filt.build(D, n_order=n, side="r") for n in (4, 5, 6)]
    errors: list[BaseException] = []

    def worker(tid: int):
        rng = np.random.default_rng(tid)
        try:
            for i in range(200):
                key = (f"d{int(rng.integers(6))}", "april",
                       int(rng.integers(3)))
                op = int(rng.integers(4))
                if op == 0:
                    cache.put(key, protos[key[2]])
                elif op == 1:
                    cache.get(key)
                elif op == 2:
                    cache.pop(key)
                else:
                    cache.resize(key)
                assert cache.stats["resident_bytes"] >= 0
        except BaseException as exc:  # noqa: BLE001 - reraised below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    assert not errors, errors
    # quiescent recount: resident_bytes equals the sum over live entries
    expect = sum(a.size_bytes() for _, a in cache.items())
    assert cache.stats["resident_bytes"] == expect
    assert len(cache) == len(cache.items())


def test_stop_is_idempotent_and_joins(data):
    D, _ = data
    svc = JoinService(n_order=N_ORDER)
    svc.register_dataset("T1", D)
    svc.start()
    svc.start()                  # second start is a no-op, not a second worker
    t = svc.submit("T1", "selection", _square(0.5, 0.5))
    svc.stop()
    svc.stop()                   # second stop is a no-op
    assert t.done.is_set()
