"""Hypothesis property tests for the bucketed filter-join subsystem
(DESIGN.md §9): staged trichotomy drivers == per-pair references on
arbitrary interval lists (empty and single-interval lists included), every
registered method x every predicate == its sequential reference, the
vectorized VByte batch decoder, and the fused Pallas trichotomy kernel."""
import numpy as np
import pytest

from repro.core import compress, join
from repro.core.april import AprilStore
from repro.core.join import (IntervalLists, april_trichotomy_rows,
                             linestring_trichotomy_rows,
                             within_trichotomy_rows)
from repro.core.rasterize import GLOBAL_EXTENT
from repro.datagen import make_dataset, make_linestrings
from repro.spatial import JoinPlan

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

N_ORDER = 6


# ---------------------------------------------------------------------------
# strategies: CSR interval stores with empty and single-interval rows
# ---------------------------------------------------------------------------

@st.composite
def interval_list(draw, max_id=2**12, max_len=12):
    """Sorted disjoint half-open intervals (possibly empty or single)."""
    pts = draw(st.lists(st.integers(0, max_id), min_size=0,
                        max_size=2 * max_len, unique=True))
    pts = sorted(pts)
    if len(pts) % 2:
        pts = pts[:-1]
    return np.asarray(pts, np.uint64).reshape(-1, 2)


@st.composite
def april_store(draw, n_rows):
    """An AprilStore over hypothesis-drawn A/F lists (empty rows allowed)."""
    def pack(lists):
        off = np.zeros(len(lists) + 1, np.int64)
        off[1:] = np.cumsum([len(l) for l in lists])
        ints = (np.concatenate(lists, axis=0) if any(len(l) for l in lists)
                else np.zeros((0, 2), np.uint64))
        return off, ints
    a = [draw(interval_list()) for _ in range(n_rows)]
    f = [draw(interval_list()) for _ in range(n_rows)]
    a_off, a_ints = pack(a)
    f_off, f_ints = pack(f)
    return AprilStore(n_order=N_ORDER, extent=GLOBAL_EXTENT, a_off=a_off,
                      a_ints=a_ints, f_off=f_off, f_ints=f_ints)


def _all_pairs(nr, ns):
    return np.stack(np.meshgrid(np.arange(nr), np.arange(ns),
                                indexing="ij"), axis=-1).reshape(-1, 2)


# ---------------------------------------------------------------------------
# trichotomy drivers == per-pair references on arbitrary lists
# ---------------------------------------------------------------------------

@given(april_store(3), april_store(3), st.sampled_from(["numpy", "jnp"]),
       st.permutations(["AA", "AF", "FA"]))
@settings(max_examples=40, deadline=None)
def test_trichotomy_property(sr, ss, backend, order):
    """Bucketed batched verdicts == april_verdict_pair / within_verdict_pair
    for ANY interval lists — empties and single-interval rows included."""
    pairs = _all_pairs(len(sr), len(ss))
    want = np.asarray([
        join.april_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                ss.f_list(j), order=tuple(order))
        for i, j in pairs], np.int8)
    got = april_trichotomy_rows(
        IntervalLists.from_intervals(sr.a_off, sr.a_ints),
        IntervalLists.from_intervals(sr.f_off, sr.f_ints),
        IntervalLists.from_intervals(ss.a_off, ss.a_ints),
        IntervalLists.from_intervals(ss.f_off, ss.f_ints),
        pairs[:, 0], pairs[:, 1], backend=backend, order=tuple(order))
    np.testing.assert_array_equal(got, want)

    want_w = np.asarray([
        join.within_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                 ss.f_list(j))
        for i, j in pairs], np.int8)
    got_w = within_trichotomy_rows(
        IntervalLists.from_intervals(sr.a_off, sr.a_ints),
        IntervalLists.from_intervals(ss.a_off, ss.a_ints),
        IntervalLists.from_intervals(ss.f_off, ss.f_ints),
        pairs[:, 0], pairs[:, 1], backend=backend)
    np.testing.assert_array_equal(got_w, want_w)


@given(st.lists(st.lists(st.integers(0, 2**12), min_size=0, max_size=10,
                         unique=True), min_size=1, max_size=3),
       april_store(2), st.sampled_from(["numpy", "jnp"]))
@settings(max_examples=30, deadline=None)
def test_linestring_trichotomy_property(cells, ss, backend):
    """Line unit-interval joins == linestring_verdict_pair, incl. empty
    cell sets."""
    ids = [np.asarray(sorted(c), np.uint64) for c in cells]
    off = np.zeros(len(ids) + 1, np.int64)
    off[1:] = np.cumsum([len(i) for i in ids])
    flat = (np.concatenate(ids) if any(len(i) for i in ids)
            else np.zeros(0, np.uint64))
    pairs = _all_pairs(len(ids), len(ss))
    want = np.asarray([
        join.linestring_verdict_pair(ss.a_list(j), ss.f_list(j), ids[i])
        for i, j in pairs], np.int8)
    got = linestring_trichotomy_rows(
        IntervalLists.from_unit_cells(off, flat),
        IntervalLists.from_intervals(ss.a_off, ss.a_ints),
        IntervalLists.from_intervals(ss.f_off, ss.f_ints),
        pairs[:, 0], pairs[:, 1], backend=backend)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# every registered method x every predicate == its reference
# ---------------------------------------------------------------------------

@given(st.sampled_from(["none", "april", "april-c", "ri", "ra", "5cch"]),
       st.sampled_from(["intersects", "within", "linestring", "selection"]),
       st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_every_method_every_predicate_property(method, predicate, seed):
    """Bucketed batched filter verdicts == the sequential per-pair reference
    for all five methods across all four predicates, on arbitrary seeded
    workloads."""
    if predicate == "linestring":
        R = make_linestrings(seed=seed, count=10)
        kind = "line"
    else:
        R = make_dataset("T1", seed=seed, count=10)
        kind = "polygon"
    S = make_dataset("T2", seed=seed + 1, count=14)
    plan = JoinPlan(R, S, filter=method, n_order=N_ORDER, r_kind=kind,
                    build_opts={"max_cells": 64} if method == "ra" else {})
    plan.build()
    pairs = plan.candidates(predicate)
    want = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                predicate=predicate, backend="sequential")
    for backend in ("numpy", "jnp"):
        got = plan.filter.verdicts(plan.approx_r, plan.approx_s, pairs,
                                   predicate=predicate, backend=backend)
        np.testing.assert_array_equal(got, want, err_msg=(method, predicate,
                                                          backend))


# ---------------------------------------------------------------------------
# vectorized VByte batch decode
# ---------------------------------------------------------------------------

@given(st.lists(st.lists(st.integers(0, 2**40), min_size=0, max_size=40,
                         unique=True), min_size=0, max_size=8))
@settings(max_examples=40, deadline=None)
def test_vbyte_decode_many_property(seqs):
    vals = [np.asarray(sorted(s), np.uint64) for s in seqs]
    bufs = [(compress.vbyte_encode(v), len(v)) for v in vals]
    got, off = compress.vbyte_decode_many(bufs)
    assert len(off) == len(bufs) + 1
    for i, v in enumerate(vals):
        np.testing.assert_array_equal(got[off[i]: off[i + 1]], v)


# ---------------------------------------------------------------------------
# fused Pallas trichotomy kernel
# ---------------------------------------------------------------------------

@given(april_store(4), april_store(4))
@settings(max_examples=10, deadline=None)
def test_pallas_trichotomy_matches_reference(sr, ss):
    pairs = _all_pairs(len(sr), len(ss))
    want = np.asarray([
        join.april_verdict_pair(sr.a_list(i), sr.f_list(i), ss.a_list(j),
                                ss.f_list(j))
        for i, j in pairs], np.int8)
    got = join.april_filter_batch(sr, ss, pairs, backend="pallas")
    np.testing.assert_array_equal(got, want)
