"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and absence of NaNs; plus a decode-step
consistency check (decode must reproduce full-forward logits)."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models.model import build_caches, forward_logits, init_model, \
    run_encoder, set_cache_pos
from repro.models.train import make_train_step
from repro.optim.adamw import adamw_init

ARCH_IDS = list(ARCHS.keys())


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.n_frames, cfg.d_model)) * 0.02,
            jnp.float32)
    elif cfg.n_patch_tokens:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patch_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


def _ctx(cfg, params, batch):
    if cfg.encoder is not None:
        return run_encoder(params, batch["frames"], cfg)
    if cfg.n_patch_tokens:
        return batch.get("patches")
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = _batch(cfg)
    logits, _, aux = forward_logits(params, batch["tokens"], cfg,
                                    ctx=_ctx(cfg, params, batch))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3, remat_policy="dots"))
    batch = _batch(cfg, seed=1)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss NaN"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: grad NaN"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(d)) > 0
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Greedy per-token decode with caches must reproduce the full-sequence
    forward logits (KV cache / recurrent state correctness).

    MoE: capacity-based routing drops tokens under contention in full-seq
    passes but never in single-token decode — the two are only equivalent
    when capacity is drop-free, so raise capacity_factor for this test."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = init_model(jax.random.PRNGKey(2), cfg, dtype=jnp.float32)
    B, S = 2, 12
    batch = _batch(cfg, B=B, S=S, seed=2)
    ctx = _ctx(cfg, params, batch)
    full, _, _ = forward_logits(params, batch["tokens"], cfg, ctx=ctx)

    caches = build_caches(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        caches = set_cache_pos(caches, t)
        logits, caches, _ = forward_logits(
            params, batch["tokens"][:, t: t + 1], cfg, ctx=ctx,
            caches=caches, pos_offset=jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)
