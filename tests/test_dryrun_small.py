"""Dry-run machinery regression: lower+compile+analyze a smoke-scale cell on
an 8-device mesh in a subprocess (the real 512-device sweep runs offline via
repro.launch.dryrun; this guards the plumbing)."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow


def test_dryrun_cell_smoke():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod

        # shrink the production mesh for the test
        mesh_mod.make_production_mesh = \\
            lambda multi_pod=False: jax.make_mesh((4, 2), ("data", "model"))
        dr.make_production_mesh = mesh_mod.make_production_mesh

        from repro.configs import get_config
        cfg = dataclasses.replace(get_config("gemma2-2b", smoke=True))

        lowered, cfg2, mesh, mode = dr.lower_model_cell(
            "gemma2-2b", "train_4k", False, cfg=dataclasses.replace(
                cfg, vocab=512))
        res = dr.analyze(lowered, arch="gemma2-2b", shape_name="train_4k",
                         mesh=mesh, cfg=cfg2)
        assert res["flops_per_chip"] > 0
        assert res["bytes_per_chip"] > 0
        assert res["bottleneck"] in ("compute", "memory", "collective")
        assert res["memory_per_chip_bytes"] > 0
        corrected = dr.probe_metrics("gemma2-2b", "train_4k", False, cfg=cfg)
        assert corrected["flops"] > 0
        # collective parser must see the mesh collectives
        assert sum(res["coll_breakdown"].values()) > 0
        print("DRYRUN_SMOKE_OK", res["bottleneck"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DRYRUN_SMOKE_OK" in r.stdout


def test_collective_parser():
    from repro.launch.roofline import collective_bytes, shape_bytes
    hlo = '''
      %ag = bf16[8,128]{1,0} all-gather(bf16[1,128] %x), dimensions={0}
      %ar.1 = f32[256]{0} all-reduce(f32[256] %y), to_apply=%sum
      %t = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(f32[16,16] %a, f32[16,16] %b)
      %cp = u32[64]{0} collective-permute(u32[64] %z), source_target_pairs={{0,1}}
      %rs = bf16[2,128]{1,0} reduce-scatter(bf16[16,128] %w), dimensions={0}
      %dot = f32[128,128]{1,0} dot(f32[128,8] %p, f32[8,128] %q)
    '''
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["all-to-all"] == 2 * 16 * 16 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["reduce-scatter"] == 2 * 128 * 2
    assert "dot" not in out
    assert shape_bytes("bf16[2,3]") == 12
