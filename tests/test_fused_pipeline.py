"""Fused-pipeline identity tests (DESIGN.md §12).

``JoinPlan(pipeline_mode="fused")`` must be *bitwise* result-identical to
the staged chain — same pairs, same ORDER — for every registered filter
method on every predicate, including empty and degenerate candidate
frames; the on-device compaction kernels must match their oracle; and the
new ``JoinStats`` stage-time fields must round-trip through the service
envelope.
"""
import numpy as np
import pytest

from repro.datagen import make_dataset, make_linestrings
from repro.datagen.synthetic import PolygonDataset
from repro.spatial import PIPELINE_MODES, JoinPlan
from repro.spatial.filters import available_filters
from repro.spatial.fused import check_pipeline_mode
from repro.spatial.plan import JoinStats

N_ORDER = 6
METHODS = tuple(available_filters())
PREDICATES = ("intersects", "within", "selection", "linestring")


@pytest.fixture(scope="module")
def rs():
    return (make_dataset("T1", seed=71, count=80),
            make_dataset("T2", seed=72, count=100))


@pytest.fixture(scope="module")
def lines():
    return make_linestrings(seed=73, count=90)


def _run(R, S, mode, method, predicate, **kw):
    plan = JoinPlan(R, S, filter=method, n_order=N_ORDER,
                    pipeline_mode=mode, **kw)
    plan.build()
    return plan.execute(predicate)


# --- fused == staged, every method x every predicate ----------------------

@pytest.mark.parametrize("predicate", PREDICATES)
@pytest.mark.parametrize("method", METHODS)
def test_fused_identical_to_staged(rs, lines, method, predicate):
    """Bitwise identity (pairs AND order); where the staged chain rejects a
    method x predicate combination, fused must reject it identically."""
    R, S = rs
    kw = {}
    if predicate == "linestring":
        R, S, kw = lines, rs[1], {"r_kind": "line"}
    try:
        ref, ref_stats = _run(R, S, "staged", method, predicate, **kw)
    except Exception as e:
        with pytest.raises(type(e)):
            _run(R, S, "fused", method, predicate, **kw)
        return
    got, stats = _run(R, S, "fused", method, predicate, **kw)
    assert np.array_equal(ref, got), (method, predicate)
    assert stats.pipeline_mode == "fused"
    assert ref_stats.pipeline_mode == "staged"
    assert stats.n_candidates == ref_stats.n_candidates
    assert stats.n_true_hits == ref_stats.n_true_hits
    assert stats.n_indecisive == ref_stats.n_indecisive


@pytest.mark.parametrize("mbr_backend", ("numpy", "jnp"))
def test_fused_identity_across_mbr_backends(rs, mbr_backend):
    """The fused MBR stage keeps the candidate lane on device only for
    mbr_backend='jnp'; both routes are staged-identical."""
    R, S = rs
    ref, _ = _run(R, S, "staged", "april", "intersects",
                  mbr_backend=mbr_backend)
    got, _ = _run(R, S, "fused", "april", "intersects",
                  mbr_backend=mbr_backend)
    assert np.array_equal(ref, got)


def test_pipeline_mode_validation():
    assert set(PIPELINE_MODES) == {"staged", "fused"}
    check_pipeline_mode("fused")
    with pytest.raises(ValueError):
        check_pipeline_mode("streamed")
    with pytest.raises(ValueError):
        JoinPlan(make_dataset("T9", seed=1, count=4),
                 make_dataset("T9", seed=2, count=4),
                 pipeline_mode="streamed")


# --- property: random polygon batches -------------------------------------

def _star(rng):
    """Random star polygon in [0.01, 0.99]^2 (possibly sliver-thin)."""
    nv = int(rng.integers(4, 17))
    cx, cy = rng.uniform(0.2, 0.8, 2)
    r = rng.uniform(0.01, 0.2)
    ang = np.sort(rng.uniform(0, 2 * np.pi, nv)) + np.linspace(0, 1e-4, nv)
    rad = r * (1 + 0.5 * rng.uniform(-1, 1, nv))
    pts = np.stack([cx + rad * np.cos(ang), cy + rad * np.sin(ang)], axis=1)
    return np.clip(pts, 0.01, 0.99)


def _batch(polys, name):
    V = max(len(p) for p in polys)
    verts = np.zeros((len(polys), V, 2))
    for i, p in enumerate(polys):
        verts[i, : len(p)] = p
    return PolygonDataset(name=name, verts=verts,
                          nverts=np.asarray([len(p) for p in polys],
                                            np.int64))


def _assert_property(pr, ps, method, predicate):
    """Fused == staged bitwise for ANY random polygon batch — frames where
    every pair is decided, none survive to refinement, or the candidate
    set is empty all arise from these draws."""
    R, S = _batch(pr, "hr"), _batch(ps, "hs")
    ref, _ = _run(R, S, "staged", method, predicate)
    got, _ = _run(R, S, "fused", method, predicate)
    assert np.array_equal(ref, got), (method, predicate)


@pytest.mark.parametrize("seed", range(10))
def test_fused_identity_random_batches(seed):
    """Seeded fallback of the hypothesis property below — always runs."""
    rng = np.random.default_rng(1000 + seed)
    pr = [_star(rng) for _ in range(int(rng.integers(1, 7)))]
    ps = [_star(rng) for _ in range(int(rng.integers(1, 7)))]
    method = ("april", "ri", "none")[seed % 3]
    _assert_property(pr, ps, method, ("intersects", "within")[seed % 2])


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @st.composite
    def polygon(draw):
        seed = draw(st.integers(0, 2**31 - 1))
        return _star(np.random.default_rng(seed))

    @given(st.lists(polygon(), min_size=1, max_size=6),
           st.lists(polygon(), min_size=1, max_size=6),
           st.sampled_from(("april", "ri", "none")),
           st.sampled_from(("intersects", "within")))
    @settings(max_examples=25, deadline=None)
    def test_fused_identity_property(pr, ps, method, predicate):
        _assert_property(pr, ps, method, predicate)


# --- compaction kernels ---------------------------------------------------

def _masks():
    rng = np.random.default_rng(9)
    yield np.zeros(0, bool)
    yield np.zeros(1, bool)
    yield np.ones(1, bool)
    yield np.zeros(257, bool)
    yield np.ones(257, bool)
    yield rng.random(1) < 0.5
    yield rng.random(513) < 0.3
    yield rng.random(4096) < 0.7
    yield rng.random(5000) < 0.01


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_compact_mask_matches_oracle(backend):
    """Both compaction backends are bit-identical to the argsort oracle on
    empty, all-true, all-false, and random lanes of awkward lengths."""
    import jax.numpy as jnp

    from repro.kernels.compact import compact_mask
    from repro.kernels.compact.ref import compact_mask_ref

    for mask in _masks():
        m = jnp.asarray(mask)
        perm, count = compact_mask(m, backend=backend, interpret=True)
        perm_ref, count_ref = compact_mask_ref(m)
        assert int(count) == int(count_ref) == int(mask.sum()), len(mask)
        assert np.array_equal(np.asarray(perm), np.asarray(perm_ref)), \
            (backend, len(mask))
        # the contract downstream gathers rely on: a permutation with the
        # True indices front-packed ascending, False indices after, ascending
        k = int(count)
        assert np.array_equal(np.sort(np.asarray(perm)),
                              np.arange(len(mask)))
        assert np.array_equal(np.asarray(perm[:k]), np.flatnonzero(mask))
        assert np.array_equal(np.asarray(perm[k:]), np.flatnonzero(~mask))


# --- degenerate candidate frames through the fused chain ------------------

def _one(square, name):
    return PolygonDataset(name=name, verts=square[None],
                          nverts=np.asarray([4], np.int64))


@pytest.mark.parametrize("method", METHODS)
def test_fused_empty_and_degenerate_frames(method):
    """Empty candidate sets and single-pair frames survive the compaction
    kernels and the end-of-chain sync identically to staged."""
    sq = np.array([[0.1, 0.1], [0.2, 0.1], [0.2, 0.2], [0.1, 0.2]])
    near = _one(sq + 0.05, "b")          # overlapping -> one live pair
    far = _one(sq + 0.6, "c")            # disjoint MBRs -> empty frame
    for other in (near, far):
        ref, ref_st = _run(_one(sq, "a"), other, "staged", method,
                           "intersects")
        got, st = _run(_one(sq, "a"), other, "fused", method, "intersects")
        assert np.array_equal(ref, got), (method, other.name)
        assert st.n_results == ref_st.n_results
    assert _run(_one(sq, "a"), far, "fused", method, "intersects")[1] \
        .n_candidates == 0


# --- JoinStats stage-time envelope ----------------------------------------

def test_stats_stage_times_roundtrip(rs):
    R, S = rs
    plan = JoinPlan(R, S, filter="april", n_order=N_ORDER,
                    pipeline_mode="fused")
    plan.build()
    _, stats = plan.execute("intersects")
    times = stats.stage_times()
    assert set(times) == {"t_mbr", "t_filter", "t_refine", "t_sync",
                          "t_partition", "t_total"}
    assert times["t_partition"] == 0.0   # non-tiled run (§14)
    assert times["t_total"] == pytest.approx(
        times["t_mbr"] + times["t_filter"] + times["t_refine"]
        + times["t_sync"])
    d = stats.to_dict()
    back = JoinStats.from_dict(d)
    assert back.pipeline_mode == "fused"
    assert back.stage_times() == times
    assert d["t_sync"] == stats.t_sync


def test_service_reports_stage_times(rs):
    from repro.spatial import JoinService
    R, _ = rs
    svc = JoinService(method="april", n_order=N_ORDER,
                      pipeline_mode="fused")
    svc.register_dataset("d", R)
    q = R.verts[0, : R.nverts[0]]
    t = svc.submit("d", "selection", q)
    svc.drain()
    t.wait(10.0)
    lat = svc.latency_stats()
    assert set(lat["stage_times"]) >= {"t_mbr", "t_filter", "t_refine",
                                       "t_sync"}
    assert lat["stage_times"]["t_total"] > 0.0
