"""Distributed spatial join across all local devices (shard_map), with
partition-level checkpointing. The launcher accepts any registered
intermediate filter; APRIL ships packed batches through the device mesh,
the others run their batched verdicts per partition. Run with more virtual
devices via:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_join.py
"""
import jax

from repro.launch.spatial_join import run_join


def main():
    print(f"devices: {jax.device_count()}")
    results, totals = run_join("T1", "T2", n_order=9, parts=2,
                               count_r=400, count_s=600, method="april",
                               backend="jnp",
                               ckpt_dir="/tmp/april_join_ckpt")
    print(f"join results: {len(results)} pairs")
    print(f"filter verdict counts: {totals}")
    print("re-running resumes from the partition checkpoint:")
    run_join("T1", "T2", n_order=9, parts=2, count_r=400, count_s=600,
             ckpt_dir="/tmp/april_join_ckpt")
    print("the same launcher with the RI filter on the host backend:")
    run_join("T1", "T2", n_order=9, parts=2, count_r=400, count_s=600,
             method="ri", backend="numpy")


if __name__ == "__main__":
    main()
