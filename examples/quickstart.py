"""Quickstart: build APRIL approximations and run a spatial intersection
join end-to-end with the `JoinPlan` session API, comparing intermediate
filters.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.april import build_april_polygon
from repro.core.join import april_verdict_pair, INDECISIVE, TRUE_HIT, TRUE_NEG
from repro.datagen import make_dataset
from repro.spatial import JoinPlan, available_filters


def main():
    # --- one pair, by hand -------------------------------------------------
    sq1 = np.array([[0.20, 0.20], [0.60, 0.20], [0.60, 0.60], [0.20, 0.60]])
    sq2 = sq1 + 0.25
    a1, f1 = build_april_polygon(sq1, 4, n_order=8)
    a2, f2 = build_april_polygon(sq2, 4, n_order=8)
    verdict = april_verdict_pair(a1, f1, a2, f2)
    names = {TRUE_NEG: "true negative", TRUE_HIT: "TRUE HIT",
             INDECISIVE: "indecisive"}
    print(f"squares overlap -> APRIL verdict: {names[verdict]}")
    print(f"A-list has {len(a1)} intervals, F-list {len(f1)} "
          f"(8x8..256x256 Hilbert grid)")

    # --- full pipeline on synthetic landmark/water layers ------------------
    print(f"registered intermediate filters: {available_filters()}")
    R = make_dataset("T1", count=300)
    S = make_dataset("T2", count=500)
    for method in ("none", "april", "ri"):
        plan = JoinPlan(R, S, filter=method, n_order=9)
        plan.build()                       # preprocessing, reusable
        results, stats = plan.execute("intersects")
        print(stats.row())
    print("all methods return the SAME join result; the filters just "
          "refine far fewer pairs.")


if __name__ == "__main__":
    main()
