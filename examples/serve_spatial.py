"""Online spatial-join serving example: warm device-resident stores behind
an LRU cache, micro-batched selection/window/intersects/within queries,
incremental inserts/deletes patching the CSR interval stores in place.

    PYTHONPATH=src python -m repro.launch.serve_join --queries 200
    PYTHONPATH=src python examples/serve_spatial.py
"""
from repro.launch.serve_join import main

if __name__ == "__main__":
    main()
