"""APRIL beyond intersection joins (§4.3): polygonal selection queries,
within joins, and polygon x linestring joins.

    PYTHONPATH=src python examples/selection_and_within.py
"""
from repro.datagen import make_dataset, make_linestrings
from repro.spatial import (polygon_linestring_join, selection_queries,
                           spatial_within_join)


def main():
    data = make_dataset("T1", count=400)
    counties = make_dataset("T3", count=10)

    results, st = selection_queries(data, counties, method="april", n_order=9)
    print("selection:", st.row())
    print(f"  e.g. query 0 returned {len(results[0])} landmark polygons")

    small = make_dataset("T2", count=400)
    res, st = spatial_within_join(small, counties, method="april", n_order=9)
    print("within:   ", st.row())

    roads = make_linestrings(count=300)
    res, st = polygon_linestring_join(counties, roads, method="april",
                                      n_order=9)
    print("linestring:", st.row())


if __name__ == "__main__":
    main()
