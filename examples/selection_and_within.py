"""Beyond intersection joins (§4.3) with the `JoinPlan` session API:
polygonal selection queries, within joins, and polygon x linestring joins —
for any registered intermediate filter, with approximations built once and
reused across predicates.

    PYTHONPATH=src python examples/selection_and_within.py
"""
from repro.datagen import make_dataset, make_linestrings
from repro.spatial import JoinPlan, selection_queries


def main():
    data = make_dataset("T1", count=400)
    counties = make_dataset("T3", count=10)

    # selection via the grouping wrapper (returns one array per query)
    results, st = selection_queries(data, counties, method="april", n_order=9)
    print("selection:", st.row())
    print(f"  e.g. query 0 returned {len(results[0])} landmark polygons")

    small = make_dataset("T2", count=400)
    plan = JoinPlan(small, counties, filter="ri", n_order=9)
    plan.build()
    res, st = plan.execute("within")
    print("within:   ", st.row())
    # the same built approximations serve another predicate for free
    res, st = plan.execute("intersects")
    print("intersect:", st.row())

    roads = make_linestrings(count=300)
    lplan = JoinPlan(roads, counties, filter="april", n_order=9,
                     r_kind="line")
    res, st = lplan.build().execute("linestring")
    print("linestring:", st.row())


if __name__ == "__main__":
    main()
