"""Continuous-batching serving example: a fixed pool of decode slots serves
a queue of requests, each at its own position (per-slot KV positions).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --requests 12
    PYTHONPATH=src python examples/serve_pool.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
