"""Serve a small model with batched greedy decoding (KV caches / recurrent
states), demonstrating the serve_step used by the decode dry-run shapes.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.models.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    extra = {}
    if cfg.encoder is not None:
        extra["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder.n_frames, cfg.d_model)) * 0.02,
            jnp.float32)
    elif cfg.n_patch_tokens:
        extra["patches"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.n_patch_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    out = greedy_generate(params, cfg, prompt, steps=args.steps,
                          batch_extra=extra or None)
    print(f"{args.arch} (smoke config) generated {out.shape[1]} tokens "
          f"for {args.batch} sequences:")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
