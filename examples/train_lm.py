"""Train a ~135M-param assigned architecture (smollm-135m, FULL config) for a
few hundred steps on synthetic data with checkpoint auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container the default runs the reduced smoke config; pass
--full on real hardware for the complete 135M model.
"""
import argparse

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()
    _, _, losses = train_loop(
        args.arch, smoke=not args.full, steps=args.steps, batch=8, seq=128,
        ckpt_dir="/tmp/lm_ckpt", ckpt_every=25, lr=3e-3, log_every=10)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'check lr'})")


if __name__ == "__main__":
    main()
