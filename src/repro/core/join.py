"""Interval joins: the APRIL intermediate filter (paper §4.2, Algorithm 2).

Two execution styles:

* **Faithful sequential merge joins** (`interval_join_pair`,
  `april_verdict_pair`) — the paper's two-pointer O(n+m) loops with early
  exit. Host/NumPy; used as the reference and for CPU-baseline benchmarks.
* **Vectorized batched joins** (`batch_overlap_np`, `batch_overlap_jnp`,
  `april_filter_batch`) — the TPU adaptation: each interval of X binary-
  searches Y (both lists are sorted and disjoint), giving a fully
  data-parallel O(n log m) test, batched over thousands of candidate pairs.
  Device arrays use *biased int32* with inclusive-last endpoints (see
  ``april.py``). `kernels/interval_join` provides the Pallas version.

Verdicts follow the paper's trichotomy: a pair is a sure non-result
(TRUE_NEG, AA-join empty), a sure result (TRUE_HIT, AF- or FA-join finds an
overlap), or INDECISIVE (forwarded to refinement).
"""
from __future__ import annotations

import numpy as np

from .hilbert import u32_to_biased_i32

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

__all__ = [
    "TRUE_NEG", "TRUE_HIT", "INDECISIVE",
    "interval_join_pair", "april_verdict_pair", "within_verdict_pair",
    "linestring_verdict_pair", "pack_lists", "pack_csr_intervals",
    "batch_overlap_np", "batch_overlap_jnp", "april_filter_batch",
    "within_filter_batch", "linestring_filter_batch",
    "containment_join_pair", "adaptive_order",
]

TRUE_NEG, TRUE_HIT, INDECISIVE = 0, 1, 2
I32_MAX = np.int32(np.iinfo(np.int32).max)


# ---------------------------------------------------------------------------
# Faithful sequential joins (paper Algorithm 2, host reference)
# ---------------------------------------------------------------------------

def interval_join_pair(X: np.ndarray, Y: np.ndarray) -> bool:
    """Two-pointer merge join over sorted disjoint half-open intervals.
    Returns True iff any pair overlaps (paper Alg. 2 `IntervalJoin`)."""
    i = j = 0
    nx, ny = len(X), len(Y)
    while i < nx and j < ny:
        xs, xe = X[i]
        ys, ye = Y[j]
        if xs < ye and ys < xe:
            return True
        if xe <= ye:
            i += 1
        else:
            j += 1
    return False


def containment_join_pair(X: np.ndarray, F: np.ndarray) -> bool:
    """True iff EVERY interval of X is contained in some interval of F
    (within-join variant of the AF-join, §4.3.2)."""
    j = 0
    nf = len(F)
    for xs, xe in X:
        while j < nf and F[j][1] < xe:
            j += 1
        if j >= nf or not (F[j][0] <= xs and xe <= F[j][1]):
            return False
    return True


def april_verdict_pair(
    Ar: np.ndarray, Fr: np.ndarray, As: np.ndarray, Fs: np.ndarray,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
) -> int:
    """APRIL intermediate filter for one candidate pair (Algorithm 2).

    ``order`` permutes the three joins (§7.2.2 join-order study). Semantics
    are order-invariant; early exits differ.
    """
    lists = {"AA": (Ar, As), "AF": (Ar, Fs), "FA": (Fr, As)}
    aa_overlap = None
    for step in order:
        X, Y = lists[step]
        hit = interval_join_pair(X, Y)
        if step == "AA":
            aa_overlap = hit
            if not hit:
                return TRUE_NEG
        elif hit:
            return TRUE_HIT
    if aa_overlap is None:   # AA ran last and was True (else returned above)
        raise AssertionError("order must include 'AA'")
    return INDECISIVE


def adaptive_order(mbr_r, mbr_s, nf_r: int, nf_s: int) -> tuple[str, ...]:
    """Per-pair join-order selection (the paper's §9 future-work item).

    Heuristic from object statistics available before any interval work:
    the MBR-overlap fraction of the smaller object predicts hit likelihood.
    Pairs whose common MBR covers most of one object are likely TRUE HITS
    -> run the cheap hit-detecting join (AF/FA, picking the side with the
    larger F-list) first; barely-touching pairs are likely TRUE NEGATIVES
    -> keep AA first (the paper's default).
    """
    ix = max(0.0, min(mbr_r[2], mbr_s[2]) - max(mbr_r[0], mbr_s[0]))
    iy = max(0.0, min(mbr_r[3], mbr_s[3]) - max(mbr_r[1], mbr_s[1]))
    inter = ix * iy
    area_r = max(1e-30, (mbr_r[2] - mbr_r[0]) * (mbr_r[3] - mbr_r[1]))
    area_s = max(1e-30, (mbr_s[2] - mbr_s[0]) * (mbr_s[3] - mbr_s[1]))
    cover = inter / min(area_r, area_s)
    if cover > 0.6 and (nf_r or nf_s):
        return ("AF", "FA", "AA") if nf_s >= nf_r else ("FA", "AF", "AA")
    return ("AA", "AF", "FA")


def within_verdict_pair(Ar, Fr, As, Fs) -> int:
    """Within-join filter (§4.3.2): r within s?  AA disjoint => TRUE_NEG;
    every A(r) interval inside an F(s) interval => TRUE_HIT; else indecisive."""
    if not interval_join_pair(Ar, As):
        return TRUE_NEG
    if len(Ar) and containment_join_pair(Ar, Fs):
        return TRUE_HIT
    return INDECISIVE


def linestring_verdict_pair(Ap, Fp, cell_ids: np.ndarray) -> int:
    """Polygon x linestring filter (§4.3.3). The linestring is a sorted
    Partial cell-id array, treated as unit intervals."""
    cells = np.stack([cell_ids, cell_ids + np.uint64(1)], axis=1) \
        if len(cell_ids) else np.zeros((0, 2), np.uint64)
    if not interval_join_pair(Ap, cells):
        return TRUE_NEG
    if interval_join_pair(Fp, cells):
        return TRUE_HIT
    return INDECISIVE


# ---------------------------------------------------------------------------
# Vectorized batched joins (TPU-adapted; numpy reference + jnp device)
# ---------------------------------------------------------------------------

def pack_csr_intervals(off: np.ndarray, ints: np.ndarray, idx: np.ndarray,
                       pad_to: int | None = None):
    """Pack CSR interval lists ``ints[off[i]:off[i+1]]`` for rows ``idx`` into
    padded biased-int32 arrays.

    Returns (starts [B, I], lasts [B, I], counts [B]) where I is the max (or
    ``pad_to``) interval count; padding slots hold I32_MAX. Endpoints are
    inclusive-last (end-1) in biased-int32 space. Fully vectorized CSR->
    padded gather (no per-pair Python loop — this packing is on the host hot
    path of every device batch).
    """
    idx = np.asarray(idx, np.int64)
    lo = off[idx]
    counts = (off[idx + 1] - lo).astype(np.int32)
    B = len(idx)
    width = int(max(1, counts.max() if B else 1))
    if pad_to is not None:
        width = max(width, pad_to)
    starts = np.full((B, width), I32_MAX, np.int32)
    lasts = np.full((B, width), I32_MAX, np.int32)
    if len(ints) and B:
        col = np.arange(width)[None, :]
        mask = col < counts[:, None]                       # [B, width]
        src = (lo[:, None] + col)[mask]                    # flat gather idx
        starts[mask] = u32_to_biased_i32(ints[src, 0])
        lasts[mask] = u32_to_biased_i32(ints[src, 1] - np.uint64(1))
    return starts, lasts, counts


def pack_lists(store, idx: np.ndarray, kind: str, pad_to: int | None = None):
    """Pack interval lists store[kind][idx]; see :func:`pack_csr_intervals`."""
    off = store.a_off if kind == "A" else store.f_off
    ints = store.a_ints if kind == "A" else store.f_ints
    return pack_csr_intervals(off, ints, idx, pad_to=pad_to)


def batch_overlap_np(xs, xl, nx, ys, yl, ny) -> np.ndarray:
    """NumPy vectorized overlap test per batch row (inclusive-last ints).

    Overlap iff exists (i, j): ys[j] <= xl[i] and xs[i] <= yl[j]. Per x-
    interval, binary-search y-lasts for the first j with yl[j] >= xs[i].
    """
    B, I = xs.shape
    out = np.zeros(B, dtype=bool)
    for b in range(B):  # host reference — device path is the jnp/Pallas one
        nyb = int(ny[b])
        nxb = int(nx[b])
        if nyb == 0 or nxb == 0:
            continue
        j = np.searchsorted(yl[b, :nyb], xs[b, :nxb], side="left")
        ok = j < nyb
        jj = np.minimum(j, nyb - 1)
        out[b] = bool(np.any(ok & (ys[b, jj] <= xl[b, :nxb])))
    return out


def batch_overlap_jnp(xs, xl, nx, ys, yl, ny):
    """jnp device version of :func:`batch_overlap_np` (vmapped searchsorted)."""
    assert jnp is not None

    def one(xs_r, xl_r, nx_r, ys_r, yl_r, ny_r):
        I = xs_r.shape[0]
        j = jnp.searchsorted(yl_r, xs_r, side="left")
        ok = j < ny_r
        jj = jnp.minimum(j, jnp.maximum(ny_r - 1, 0))
        ys_at = jnp.take(ys_r, jj)
        valid_x = jnp.arange(I, dtype=jnp.int32) < nx_r
        return jnp.any(valid_x & ok & (ys_at <= xl_r))

    return jax.vmap(one)(xs, xl, nx, ys, yl, ny)


def _containment_batch_np(xs, xl, nx, fs, fl, nf) -> np.ndarray:
    """Every x interval contained in some f interval? (within-join, batched)"""
    B, I = xs.shape
    out = np.zeros(B, dtype=bool)
    for b in range(B):
        nxb, nfb = int(nx[b]), int(nf[b])
        if nxb == 0:
            continue
        if nfb == 0:
            out[b] = False
            continue
        j = np.searchsorted(fl[b, :nfb], xl[b, :nxb], side="left")
        ok = j < nfb
        jj = np.minimum(j, nfb - 1)
        out[b] = bool(np.all(ok & (fs[b, jj] <= xs[b, :nxb])
                             & (xl[b, :nxb] <= fl[b, jj])))
    return out


def batch_containment_jnp(xs, xl, nx, fs, fl, nf):
    """jnp device version of :func:`_containment_batch_np`."""
    assert jnp is not None

    def one(xs_r, xl_r, nx_r, fs_r, fl_r, nf_r):
        I = xs_r.shape[0]
        j = jnp.searchsorted(fl_r, xl_r, side="left")
        ok = j < nf_r
        jj = jnp.minimum(j, jnp.maximum(nf_r - 1, 0))
        fs_at = jnp.take(fs_r, jj)
        fl_at = jnp.take(fl_r, jj)
        valid_x = jnp.arange(I, dtype=jnp.int32) < nx_r
        inside = ok & (fs_at <= xs_r) & (xl_r <= fl_at)
        return jnp.all(jnp.where(valid_x, inside, True)) & (nx_r > 0) & (nf_r > 0)

    return jax.vmap(one)(xs, xl, nx, fs, fl, nf)


def within_filter_batch(store_r, store_s, pairs: np.ndarray,
                        use_jnp: bool = False) -> np.ndarray:
    """Vectorized APRIL within filter (§4.3.2) over candidate pairs [N,2].

    Verdict-identical to :func:`within_verdict_pair` applied per pair:
    AA disjoint -> TRUE_NEG; every A(r) interval inside an F(s) interval ->
    TRUE_HIT; else INDECISIVE.
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    overlap = batch_overlap_jnp if (use_jnp and jnp is not None) else batch_overlap_np
    contain = batch_containment_jnp if (use_jnp and jnp is not None) \
        else _containment_batch_np
    xs, xl, nx = pack_lists(store_r, pairs[:, 0], "A")
    ys, yl, ny = pack_lists(store_s, pairs[:, 1], "A")
    aa = np.asarray(overlap(xs, xl, nx, ys, yl, ny))
    fs, fl, nf = pack_lists(store_s, pairs[:, 1], "F")
    cont = np.asarray(contain(xs, xl, nx, fs, fl, nf))
    return np.where(~aa, TRUE_NEG,
                    np.where((nx > 0) & cont, TRUE_HIT,
                             INDECISIVE)).astype(np.int8)


def linestring_filter_batch(store_s, line_off: np.ndarray,
                            line_ids: np.ndarray, pairs: np.ndarray,
                            use_jnp: bool = False) -> np.ndarray:
    """Vectorized polygon x linestring filter (§4.3.3).

    ``pairs`` rows are (line_idx, poly_idx); the linestring side is a CSR
    array of sorted Partial cell ids treated as unit intervals (start = last
    = id in inclusive-last space). Verdict-identical to
    :func:`linestring_verdict_pair`.
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    overlap = batch_overlap_jnp if (use_jnp and jnp is not None) else batch_overlap_np
    # pack the line side as unit intervals (inclusive-last == start)
    cells = np.stack([line_ids, line_ids + np.uint64(1)], axis=1) \
        if len(line_ids) else np.zeros((0, 2), np.uint64)
    cs, cl, counts = pack_csr_intervals(line_off, cells, pairs[:, 0])
    as_, al, na = pack_lists(store_s, pairs[:, 1], "A")
    aa = np.asarray(overlap(as_, al, na, cs, cl, counts))
    fs_, fl, nf = pack_lists(store_s, pairs[:, 1], "F")
    fhit = np.asarray(overlap(fs_, fl, nf, cs, cl, counts))
    return np.where(~aa, TRUE_NEG,
                    np.where(fhit, TRUE_HIT, INDECISIVE)).astype(np.int8)


def april_filter_batch(
    store_r, store_s, pairs: np.ndarray,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
    use_jnp: bool = False,
) -> np.ndarray:
    """Vectorized APRIL filter over candidate pairs [[r_idx, s_idx], ...].

    Returns verdicts [N] int8. The three joins run as masked batch passes in
    ``order``; pairs decided by an earlier pass are excluded from later ones
    (batch-level short-circuit — see DESIGN.md §3).
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    verdicts = np.full(N, INDECISIVE, np.int8)
    if N == 0:
        return verdicts
    overlap = batch_overlap_jnp if (use_jnp and jnp is not None) else batch_overlap_np

    undecided = np.arange(N)
    aa_seen = np.zeros(N, dtype=bool)
    for step in order:
        if len(undecided) == 0:
            break
        r_idx = pairs[undecided, 0]
        s_idx = pairs[undecided, 1]
        xk, yk = ("A", "A") if step == "AA" else (("A", "F") if step == "AF" else ("F", "A"))
        xs, xl, nx = pack_lists(store_r, r_idx, xk)
        ys, yl, ny = pack_lists(store_s, s_idx, yk)
        hit = np.asarray(overlap(xs, xl, nx, ys, yl, ny))
        if step == "AA":
            aa_seen[undecided] = True
            verdicts[undecided[~hit]] = TRUE_NEG
            undecided = undecided[hit]
        else:
            verdicts[undecided[hit]] = TRUE_HIT
            undecided = undecided[~hit]
    # pairs never killed by AA (when AA ran last) keep INDECISIVE; pairs with
    # empty A-overlap already got TRUE_NEG above.
    return verdicts
