"""Interval joins: the APRIL intermediate filter (paper §4.2, Algorithm 2).

Three execution styles:

* **Faithful sequential merge joins** (`interval_join_pair`,
  `april_verdict_pair`) — the paper's two-pointer O(n+m) loops with early
  exit. Host/NumPy; used as the reference and for CPU-baseline benchmarks.
* **The bucketed filter-join subsystem** (DESIGN.md §9) —
  :class:`IntervalLists` holds a dataset's interval lists CSR-packed in
  biased int32 with inclusive-last endpoints (see ``april.py``), uploaded
  to the device once and reused across ``JoinPlan`` calls. The staged
  trichotomy drivers (:func:`april_trichotomy_rows`,
  :func:`within_trichotomy_rows`, :func:`linestring_trichotomy_rows`) run
  the cheap AA-join over the whole batch first and forward only the AA
  survivors — compacted, like refinement's CMBR sweep — into the expensive
  full-cell joins. Backends: ``numpy`` evaluates the overlap as one flat
  row-keyed searchsorted pass (no padding, no per-pair loop); ``jnp``
  gathers padded power-of-two width buckets on device; ``pallas`` ships
  bucketed batches through ``kernels/interval_join`` (the fused kernel
  computes the whole three-join verdict in one pass).
* **Legacy padded batch joins** (`batch_overlap_np`, `batch_overlap_jnp`,
  `pack_lists`) — pad-to-max layouts kept for the mesh-sharded
  ``PackedPairs`` path (spatial/distributed.py) and the kernel tests.

Verdicts follow the paper's trichotomy: a pair is a sure non-result
(TRUE_NEG, AA-join empty), a sure result (TRUE_HIT, AF- or FA-join finds an
overlap), or INDECISIVE (forwarded to refinement).
"""
from __future__ import annotations

import numpy as np

from .hilbert import u32_to_biased_i32
from .rasterize import size_buckets

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

__all__ = [
    "TRUE_NEG", "TRUE_HIT", "INDECISIVE", "FILTER_BACKENDS",
    "check_filter_backend", "IntervalLists",
    "csr_delete_row", "csr_append_row",
    "interval_join_pair", "april_verdict_pair", "within_verdict_pair",
    "linestring_verdict_pair", "pack_lists", "pack_csr_intervals",
    "overlap_rows_np", "contain_rows_np",
    "april_trichotomy_rows", "within_trichotomy_rows",
    "linestring_trichotomy_rows",
    "batch_overlap_np", "batch_overlap_jnp", "april_filter_batch",
    "within_filter_batch", "linestring_filter_batch",
    "containment_join_pair", "adaptive_order", "fused_status_rows",
]

TRUE_NEG, TRUE_HIT, INDECISIVE = 0, 1, 2
I32_MAX = np.int32(np.iinfo(np.int32).max)

#: execution paths of the intermediate-filter stage (``filter_backend`` on
#: :class:`~repro.spatial.plan.JoinPlan`, DESIGN.md §9): 'numpy' is the flat
#: vectorized host pass, 'jnp' the bucketed device pass, 'pallas' the fused
#: TPU kernel, 'sequential' the faithful per-pair reference loop every
#: batched backend must be verdict-identical to.
FILTER_BACKENDS = ("numpy", "jnp", "pallas", "sequential")


def check_filter_backend(backend: str) -> None:
    if backend not in FILTER_BACKENDS:
        raise ValueError(f"unknown filter backend {backend!r}; "
                         f"expected one of {FILTER_BACKENDS}")


# ---------------------------------------------------------------------------
# CSR row splices (incremental store maintenance, DESIGN.md §10)
# ---------------------------------------------------------------------------

def csr_delete_row(off: np.ndarray, data: np.ndarray, i: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Splice row ``i`` out of a CSR (offsets [P+1], flat data) pair.

    The flat segment ``data[off[i]:off[i+1]]`` is removed and later offsets
    shift down — no other row's payload is recomputed. Works for any flat
    axis-0 layout (interval tables [T, 2], cell-id vectors [T], ...).
    """
    off = np.asarray(off, np.int64)
    lo, hi = int(off[i]), int(off[i + 1])
    new_off = np.concatenate([off[:i + 1], off[i + 2:] - (hi - lo)])
    new_data = np.concatenate([data[:lo], data[hi:]], axis=0)
    return new_off, new_data


def csr_append_row(off: np.ndarray, data: np.ndarray, row: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Append one row (flat payload ``row``) to a CSR pair; existing rows
    are untouched."""
    off = np.asarray(off, np.int64)
    new_off = np.append(off, off[-1] + len(row))
    new_data = np.concatenate([data, row], axis=0)
    return new_off, new_data


# ---------------------------------------------------------------------------
# Faithful sequential joins (paper Algorithm 2, host reference)
# ---------------------------------------------------------------------------

def interval_join_pair(X: np.ndarray, Y: np.ndarray) -> bool:
    """Two-pointer merge join over sorted disjoint half-open intervals.
    Returns True iff any pair overlaps (paper Alg. 2 `IntervalJoin`)."""
    i = j = 0
    nx, ny = len(X), len(Y)
    while i < nx and j < ny:
        xs, xe = X[i]
        ys, ye = Y[j]
        if xs < ye and ys < xe:
            return True
        if xe <= ye:
            i += 1
        else:
            j += 1
    return False


def containment_join_pair(X: np.ndarray, F: np.ndarray) -> bool:
    """True iff EVERY interval of X is contained in some interval of F
    (within-join variant of the AF-join, §4.3.2)."""
    j = 0
    nf = len(F)
    for xs, xe in X:
        while j < nf and F[j][1] < xe:
            j += 1
        if j >= nf or not (F[j][0] <= xs and xe <= F[j][1]):
            return False
    return True


def april_verdict_pair(
    Ar: np.ndarray, Fr: np.ndarray, As: np.ndarray, Fs: np.ndarray,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
) -> int:
    """APRIL intermediate filter for one candidate pair (Algorithm 2).

    ``order`` permutes the three joins (§7.2.2 join-order study). Semantics
    are order-invariant; early exits differ.
    """
    lists = {"AA": (Ar, As), "AF": (Ar, Fs), "FA": (Fr, As)}
    aa_overlap = None
    for step in order:
        X, Y = lists[step]
        hit = interval_join_pair(X, Y)
        if step == "AA":
            aa_overlap = hit
            if not hit:
                return TRUE_NEG
        elif hit:
            return TRUE_HIT
    if aa_overlap is None:   # AA ran last and was True (else returned above)
        raise AssertionError("order must include 'AA'")
    return INDECISIVE


def adaptive_order(mbr_r, mbr_s, nf_r: int, nf_s: int) -> tuple[str, ...]:
    """Per-pair join-order selection (the paper's §9 future-work item).

    Heuristic from object statistics available before any interval work:
    the MBR-overlap fraction of the smaller object predicts hit likelihood.
    Pairs whose common MBR covers most of one object are likely TRUE HITS
    -> run the cheap hit-detecting join (AF/FA, picking the side with the
    larger F-list) first; barely-touching pairs are likely TRUE NEGATIVES
    -> keep AA first (the paper's default).
    """
    ix = max(0.0, min(mbr_r[2], mbr_s[2]) - max(mbr_r[0], mbr_s[0]))
    iy = max(0.0, min(mbr_r[3], mbr_s[3]) - max(mbr_r[1], mbr_s[1]))
    inter = ix * iy
    area_r = max(1e-30, (mbr_r[2] - mbr_r[0]) * (mbr_r[3] - mbr_r[1]))
    area_s = max(1e-30, (mbr_s[2] - mbr_s[0]) * (mbr_s[3] - mbr_s[1]))
    cover = inter / min(area_r, area_s)
    if cover > 0.6 and (nf_r or nf_s):
        return ("AF", "FA", "AA") if nf_s >= nf_r else ("FA", "AF", "AA")
    return ("AA", "AF", "FA")


def within_verdict_pair(Ar, Fr, As, Fs) -> int:
    """Within-join filter (§4.3.2): r within s?  AA disjoint => TRUE_NEG;
    every A(r) interval inside an F(s) interval => TRUE_HIT; else indecisive."""
    if not interval_join_pair(Ar, As):
        return TRUE_NEG
    if len(Ar) and containment_join_pair(Ar, Fs):
        return TRUE_HIT
    return INDECISIVE


def linestring_verdict_pair(Ap, Fp, cell_ids: np.ndarray) -> int:
    """Polygon x linestring filter (§4.3.3). The linestring is a sorted
    Partial cell-id array, treated as unit intervals."""
    cells = np.stack([cell_ids, cell_ids + np.uint64(1)], axis=1) \
        if len(cell_ids) else np.zeros((0, 2), np.uint64)
    if not interval_join_pair(Ap, cells):
        return TRUE_NEG
    if interval_join_pair(Fp, cells):
        return TRUE_HIT
    return INDECISIVE


# ---------------------------------------------------------------------------
# Vectorized batched joins (TPU-adapted; numpy reference + jnp device)
# ---------------------------------------------------------------------------

def pack_csr_intervals(off: np.ndarray, ints: np.ndarray, idx: np.ndarray,
                       pad_to: int | None = None):
    """Pack CSR interval lists ``ints[off[i]:off[i+1]]`` for rows ``idx`` into
    padded biased-int32 arrays.

    Returns (starts [B, I], lasts [B, I], counts [B]) where I is the max (or
    ``pad_to``) interval count; padding slots hold I32_MAX. Endpoints are
    inclusive-last (end-1) in biased-int32 space. Fully vectorized CSR->
    padded gather (no per-pair Python loop — this packing is on the host hot
    path of every device batch).
    """
    idx = np.asarray(idx, np.int64)
    lo = off[idx]
    counts = (off[idx + 1] - lo).astype(np.int32)
    B = len(idx)
    width = int(max(1, counts.max() if B else 1))
    if pad_to is not None:
        width = max(width, pad_to)
    starts = np.full((B, width), I32_MAX, np.int32)
    lasts = np.full((B, width), I32_MAX, np.int32)
    if len(ints) and B:
        col = np.arange(width)[None, :]
        mask = col < counts[:, None]                       # [B, width]
        src = (lo[:, None] + col)[mask]                    # flat gather idx
        starts[mask] = u32_to_biased_i32(ints[src, 0])
        lasts[mask] = u32_to_biased_i32(ints[src, 1] - np.uint64(1))
    return starts, lasts, counts


def pack_lists(store, idx: np.ndarray, kind: str, pad_to: int | None = None):
    """Pack interval lists store[kind][idx]; see :func:`pack_csr_intervals`."""
    off = store.a_off if kind == "A" else store.f_off
    ints = store.a_ints if kind == "A" else store.f_ints
    return pack_csr_intervals(off, ints, idx, pad_to=pad_to)


def batch_overlap_np(xs, xl, nx, ys, yl, ny) -> np.ndarray:
    """NumPy vectorized overlap test per batch row (inclusive-last ints).

    Overlap iff exists (i, j): ys[j] <= xl[i] and xs[i] <= yl[j]. Per x-
    interval, binary-search y-lasts for the first j with yl[j] >= xs[i].
    """
    B, I = xs.shape
    out = np.zeros(B, dtype=bool)
    for b in range(B):  # host reference — device path is the jnp/Pallas one
        nyb = int(ny[b])
        nxb = int(nx[b])
        if nyb == 0 or nxb == 0:
            continue
        j = np.searchsorted(yl[b, :nyb], xs[b, :nxb], side="left")
        ok = j < nyb
        jj = np.minimum(j, nyb - 1)
        out[b] = bool(np.any(ok & (ys[b, jj] <= xl[b, :nxb])))
    return out


def batch_overlap_jnp(xs, xl, nx, ys, yl, ny):
    """jnp device version of :func:`batch_overlap_np` (vmapped searchsorted)."""
    assert jnp is not None

    def one(xs_r, xl_r, nx_r, ys_r, yl_r, ny_r):
        I = xs_r.shape[0]
        j = jnp.searchsorted(yl_r, xs_r, side="left")
        ok = j < ny_r
        jj = jnp.minimum(j, jnp.maximum(ny_r - 1, 0))
        ys_at = jnp.take(ys_r, jj)
        valid_x = jnp.arange(I, dtype=jnp.int32) < nx_r
        return jnp.any(valid_x & ok & (ys_at <= xl_r))

    return jax.vmap(one)(xs, xl, nx, ys, yl, ny)


def _containment_batch_np(xs, xl, nx, fs, fl, nf) -> np.ndarray:
    """Every x interval contained in some f interval? (within-join, batched)"""
    B, I = xs.shape
    out = np.zeros(B, dtype=bool)
    for b in range(B):
        nxb, nfb = int(nx[b]), int(nf[b])
        if nxb == 0:
            continue
        if nfb == 0:
            out[b] = False
            continue
        j = np.searchsorted(fl[b, :nfb], xl[b, :nxb], side="left")
        ok = j < nfb
        jj = np.minimum(j, nfb - 1)
        out[b] = bool(np.all(ok & (fs[b, jj] <= xs[b, :nxb])
                             & (xl[b, :nxb] <= fl[b, jj])))
    return out


def batch_containment_jnp(xs, xl, nx, fs, fl, nf):
    """jnp device version of :func:`_containment_batch_np`."""
    assert jnp is not None

    def one(xs_r, xl_r, nx_r, fs_r, fl_r, nf_r):
        I = xs_r.shape[0]
        j = jnp.searchsorted(fl_r, xl_r, side="left")
        ok = j < nf_r
        jj = jnp.minimum(j, jnp.maximum(nf_r - 1, 0))
        fs_at = jnp.take(fs_r, jj)
        fl_at = jnp.take(fl_r, jj)
        valid_x = jnp.arange(I, dtype=jnp.int32) < nx_r
        inside = ok & (fs_at <= xs_r) & (xl_r <= fl_at)
        return jnp.all(jnp.where(valid_x, inside, True)) & (nx_r > 0) & (nf_r > 0)

    return jax.vmap(one)(xs, xl, nx, fs, fl, nf)


def _store_lists(store, kind: str) -> "IntervalLists":
    """Wrap one list kind of an AprilStore into an :class:`IntervalLists`,
    cached on the store so repeated wrapper calls pay the biased-int32
    conversion once, not O(store) per batch (the filter classes cache in
    ``Approximation.meta`` instead)."""
    try:
        cache = store._interval_lists_cache
    except AttributeError:
        cache = store._interval_lists_cache = {}
    if kind not in cache:
        if kind == "A":
            cache[kind] = IntervalLists.from_intervals(store.a_off,
                                                       store.a_ints)
        else:
            cache[kind] = IntervalLists.from_intervals(store.f_off,
                                                       store.f_ints)
    return cache[kind]


def within_filter_batch(store_r, store_s, pairs: np.ndarray,
                        use_jnp: bool = False,
                        backend: str | None = None) -> np.ndarray:
    """Vectorized APRIL within filter (§4.3.2) over candidate pairs [N,2].

    Verdict-identical to :func:`within_verdict_pair` applied per pair:
    AA disjoint -> TRUE_NEG; every A(r) interval inside an F(s) interval ->
    TRUE_HIT; else INDECISIVE. Thin wrapper over
    :func:`within_trichotomy_rows` for raw stores.
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, np.int8)
    backend = backend or ("jnp" if (use_jnp and jnp is not None) else "numpy")
    return within_trichotomy_rows(
        _store_lists(store_r, "A"), _store_lists(store_s, "A"),
        _store_lists(store_s, "F"), pairs[:, 0], pairs[:, 1],
        backend=backend)


def linestring_filter_batch(store_s, line_off: np.ndarray,
                            line_ids: np.ndarray, pairs: np.ndarray,
                            use_jnp: bool = False,
                            backend: str | None = None) -> np.ndarray:
    """Vectorized polygon x linestring filter (§4.3.3).

    ``pairs`` rows are (line_idx, poly_idx); the linestring side is a CSR
    array of sorted Partial cell ids treated as unit intervals (start = last
    = id in inclusive-last space). Verdict-identical to
    :func:`linestring_verdict_pair`; thin wrapper over
    :func:`linestring_trichotomy_rows` for raw stores.
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, np.int8)
    backend = backend or ("jnp" if (use_jnp and jnp is not None) else "numpy")
    return linestring_trichotomy_rows(
        IntervalLists.from_unit_cells(line_off, line_ids),
        _store_lists(store_s, "A"), _store_lists(store_s, "F"),
        pairs[:, 0], pairs[:, 1], backend=backend)


def april_filter_batch(
    store_r, store_s, pairs: np.ndarray,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
    use_jnp: bool = False, backend: str | None = None,
) -> np.ndarray:
    """Vectorized APRIL filter over candidate pairs [[r_idx, s_idx], ...].

    Returns verdicts [N] int8; thin wrapper over
    :func:`april_trichotomy_rows` for raw stores (the staged AA ->
    compacted AF/FA evaluation, DESIGN.md §9).
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, np.int8)
    backend = backend or ("jnp" if (use_jnp and jnp is not None) else "numpy")
    return april_trichotomy_rows(
        _store_lists(store_r, "A"), _store_lists(store_r, "F"),
        _store_lists(store_s, "A"), _store_lists(store_s, "F"),
        pairs[:, 0], pairs[:, 1], backend=backend, order=order)


# ---------------------------------------------------------------------------
# The bucketed filter-join subsystem (DESIGN.md §9)
# ---------------------------------------------------------------------------

_KEY_SHIFT = np.uint64(33)
_KEY_BIAS = np.int64(1) << np.int64(31)

#: per-backend padded working-set bound for one bucket chunk
_BUCKET_CHUNK = 1 << 22
#: pallas buckets cap list width so the [BB, I, J] predicate tile fits VMEM
_PALLAS_MAX_WIDTH = 256


class IntervalLists:
    """One dataset side's interval lists, CSR-packed for the filter join.

    Endpoints are biased int32 with inclusive lasts (``end - 1``), the
    device-native layout of every batched backend. Built once per
    :class:`~repro.spatial.filters.base.Approximation` (cached in its
    ``meta``) and — for the jnp/pallas backends — uploaded to the device
    once and reused across ``JoinPlan`` calls; per-batch work is a gather,
    never a host re-pack.
    """

    __slots__ = ("off", "starts", "lasts", "_device")

    def __init__(self, off: np.ndarray, starts: np.ndarray,
                 lasts: np.ndarray):
        self.off = np.ascontiguousarray(off, np.int64)
        self.starts = np.ascontiguousarray(starts, np.int32)
        self.lasts = np.ascontiguousarray(lasts, np.int32)
        self._device = None

    @classmethod
    def from_intervals(cls, off: np.ndarray, ints: np.ndarray):
        """From a CSR uint64 half-open interval table (AprilStore layout)."""
        if len(ints):
            starts = u32_to_biased_i32(ints[:, 0])
            lasts = u32_to_biased_i32(ints[:, 1] - np.uint64(1))
        else:
            starts = np.zeros(0, np.int32)
            lasts = np.zeros(0, np.int32)
        return cls(off, starts, lasts)

    @classmethod
    def from_unit_cells(cls, off: np.ndarray, ids: np.ndarray):
        """From sorted cell ids treated as unit intervals (start == last)."""
        b = u32_to_biased_i32(ids) if len(ids) else np.zeros(0, np.int32)
        return cls(off, b, b)

    def __len__(self) -> int:
        return len(self.off) - 1

    def counts(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, np.int64)
        return (self.off[idx + 1] - self.off[idx]).astype(np.int64)

    def pack(self, idx: np.ndarray, width: int):
        """Padded host gather: (starts [B, width], lasts, counts [B])."""
        idx = np.asarray(idx, np.int64)
        lo = self.off[idx]
        cnt = (self.off[idx + 1] - lo).astype(np.int32)
        B = len(idx)
        xs = np.full((B, width), I32_MAX, np.int32)
        xl = np.full((B, width), I32_MAX, np.int32)
        if len(self.starts) and B:
            col = np.arange(width)[None, :]
            mask = col < cnt[:, None]
            src = (lo[:, None] + col)[mask]
            xs[mask] = self.starts[src]
            xl[mask] = self.lasts[src]
        return xs, xl, cnt

    def device(self):
        """Lazily uploaded device copies of the flat endpoint arrays."""
        if self._device is None:
            assert jnp is not None, "jax unavailable"
            # a sentinel slot lets empty stores still index safely on device
            s = self.starts if len(self.starts) else np.full(1, I32_MAX,
                                                             np.int32)
            l = self.lasts if len(self.lasts) else np.full(1, I32_MAX,
                                                           np.int32)
            self._device = (jnp.asarray(s), jnp.asarray(l))
        return self._device

    # -- incremental maintenance (row splices, DESIGN.md §10) ---------------

    def delete_row(self, i: int) -> None:
        """Splice row ``i`` out in place; only this row's endpoints move.
        Drops the device copy — the next device batch re-uploads the
        patched flat arrays."""
        old_off = self.off
        _, self.lasts = csr_delete_row(old_off, self.lasts, i)
        self.off, self.starts = csr_delete_row(old_off, self.starts, i)
        self._device = None

    def append_row(self, starts: np.ndarray, lasts: np.ndarray) -> None:
        """Append one row's biased-int32 endpoints in place."""
        old_off = self.off
        _, self.lasts = csr_append_row(old_off, self.lasts,
                                       np.asarray(lasts, np.int32))
        self.off, self.starts = csr_append_row(old_off, self.starts,
                                               np.asarray(starts, np.int32))
        self._device = None


def _flat_rows(L: IntervalLists, idx: np.ndarray):
    """Expand rows ``idx`` of ``L`` into flat (row-of-entry [T],
    global-interval [T], counts [B]) arrays."""
    idx = np.asarray(idx, np.int64)
    lo = L.off[idx]
    cnt = (L.off[idx + 1] - lo).astype(np.int64)
    b_of = np.repeat(np.arange(len(idx)), cnt)
    pos = np.arange(len(b_of)) - np.repeat(np.cumsum(cnt) - cnt, cnt)
    return b_of, lo[b_of] + pos, cnt


def _rowkey(b_of: np.ndarray, vals_i32: np.ndarray) -> np.ndarray:
    """Row-keyed sort keys: row index in the high bits, the (order-
    preserving) unbiased endpoint in the low 32."""
    return ((b_of.astype(np.uint64) << _KEY_SHIFT)
            + (vals_i32.astype(np.int64) + _KEY_BIAS).astype(np.uint64))


def overlap_rows_np(X: IntervalLists, xi: np.ndarray,
                    Y: IntervalLists, yi: np.ndarray) -> np.ndarray:
    """[N] bool: does X[xi[n]] overlap Y[yi[n]]? One flat vectorized pass.

    Per x interval, binary-search the row-keyed flat y-lasts for the first
    y with ``yl >= xs`` (row keys keep each pair's segment separate), then
    test ``ys <= xl`` — no padding, no per-pair Python loop.
    """
    xi = np.asarray(xi, np.int64)
    N = len(xi)
    out = np.zeros(N, bool)
    if N == 0:
        return out
    bx, gx, _ = _flat_rows(X, xi)
    by, gy, cy = _flat_rows(Y, yi)
    if len(bx) == 0 or len(by) == 0:
        return out
    ykeys = _rowkey(by, Y.lasts[gy])
    yend = np.cumsum(cy)
    j = np.searchsorted(ykeys, _rowkey(bx, X.starts[gx]), side="left")
    ok = j < yend[bx]
    jj = np.minimum(j, len(gy) - 1)
    hit = ok & (Y.starts[gy[jj]] <= X.lasts[gx])
    out[bx[hit]] = True
    return out


def contain_rows_np(X: IntervalLists, xi: np.ndarray,
                    F: IntervalLists, fi: np.ndarray) -> np.ndarray:
    """[N] bool: is every interval of X[xi[n]] contained in some interval of
    F[fi[n]]? (within-join AF test, §4.3.2). False for empty X or F lists
    — the trichotomy drivers only consult it on AA survivors."""
    xi = np.asarray(xi, np.int64)
    N = len(xi)
    out = (X.counts(xi) > 0) & (F.counts(fi) > 0)
    if N == 0:
        return out
    bx, gx, _ = _flat_rows(X, xi)
    bf, gf, cf = _flat_rows(F, fi)
    if len(bx) == 0 or len(bf) == 0:
        return out      # some side is empty on every row
    fkeys = _rowkey(bf, F.lasts[gf])
    fend = np.cumsum(cf)
    j = np.searchsorted(fkeys, _rowkey(bx, X.lasts[gx]), side="left")
    ok = j < fend[bx]
    jj = np.minimum(j, len(gf) - 1)
    inside = ok & (F.starts[gf[jj]] <= X.starts[gx]) \
        & (X.lasts[gx] <= F.lasts[gf[jj]])
    out[bx[~inside]] = False
    return out


# -- jnp bucketed device paths ----------------------------------------------

def _pow2(n: int) -> int:
    return 1 << max(0, int(np.ceil(np.log2(max(1, int(n))))))


def _device_gather(flat_s, flat_l, lo, cnt, W: int):
    """Padded [B, W] device gather out of the resident flat arrays."""
    col = jnp.arange(W, dtype=jnp.int32)[None, :]
    idx = jnp.clip(lo[:, None] + col, 0, flat_s.shape[0] - 1)
    mask = col < cnt[:, None]
    return (jnp.where(mask, flat_s[idx], I32_MAX),
            jnp.where(mask, flat_l[idx], I32_MAX))


def _overlap_bucket_jnp(xs_f, xl_f, xlo, xcnt, ys_f, yl_f, ylo, ycnt,
                        Wx: int, Wy: int):
    xs, xl = _device_gather(xs_f, xl_f, xlo, xcnt, Wx)
    ys, yl = _device_gather(ys_f, yl_f, ylo, ycnt, Wy)
    return batch_overlap_jnp(xs, xl, xcnt, ys, yl, ycnt)


def _contain_bucket_jnp(xs_f, xl_f, xlo, xcnt, fs_f, fl_f, flo, fcnt,
                        Wx: int, Wf: int):
    xs, xl = _device_gather(xs_f, xl_f, xlo, xcnt, Wx)
    fs, fl = _device_gather(fs_f, fl_f, flo, fcnt, Wf)
    return batch_containment_jnp(xs, xl, xcnt, fs, fl, fcnt)


_JNP_BUCKET_FNS: dict = {}


def _jitted_bucket_fn(kind: str):
    if jax is None:  # pragma: no cover
        raise RuntimeError("jax unavailable for the jnp filter backend")
    if kind not in _JNP_BUCKET_FNS:
        fn = _overlap_bucket_jnp if kind == "overlap" else _contain_bucket_jnp
        _JNP_BUCKET_FNS[kind] = jax.jit(fn, static_argnames=("Wx", "Wy")
                                        if kind == "overlap"
                                        else ("Wx", "Wf"))
    return _JNP_BUCKET_FNS[kind]


def _bucketed_rows_jnp(kind: str, X: IntervalLists, xi, Y: IntervalLists,
                       yi) -> np.ndarray:
    """Bucketed device evaluation of overlap/containment rows.

    Rows group by the power-of-two class of their wider list (padding waste
    <= 2x); each bucket pads its batch to a power of two so the jitted
    gather+searchsorted step compiles O(log^2) times, not per shape. The
    flat endpoint arrays live on device (:meth:`IntervalLists.device`);
    only the [B] row offsets/counts travel per call.
    """
    xi = np.asarray(xi, np.int64)
    yi = np.asarray(yi, np.int64)
    N = len(xi)
    out = np.zeros(N, bool)
    if N == 0:
        return out
    cx = X.counts(xi)
    cy = Y.counts(yi)
    # rows with an empty list on either side are False for both overlap and
    # (survivor-only) containment; size_buckets skips the zeroed rows
    widths = np.where((cx > 0) & (cy > 0), np.maximum(np.maximum(cx, cy), 1),
                      0)
    fn = _jitted_bucket_fn(kind)
    xs_f, xl_f = X.device()
    ys_f, yl_f = Y.device()
    for sel in size_buckets(widths, _BUCKET_CHUNK):
        Wx = _pow2(cx[sel].max())
        Wy = _pow2(cy[sel].max())
        Bp = _pow2(len(sel))
        xlo = np.zeros(Bp, np.int64)
        xct = np.zeros(Bp, np.int32)
        ylo = np.zeros(Bp, np.int64)
        yct = np.zeros(Bp, np.int32)
        xlo[:len(sel)] = X.off[xi[sel]]
        xct[:len(sel)] = cx[sel]
        ylo[:len(sel)] = Y.off[yi[sel]]
        yct[:len(sel)] = cy[sel]
        kw = {"Wx": Wx, "Wy": Wy} if kind == "overlap" else \
            {"Wx": Wx, "Wf": Wy}
        got = np.asarray(fn(xs_f, xl_f, jnp.asarray(xlo), jnp.asarray(xct),
                            ys_f, yl_f, jnp.asarray(ylo), jnp.asarray(yct),
                            **kw))
        out[sel] = got[:len(sel)]
    return out


def overlap_rows_jnp(X, xi, Y, yi) -> np.ndarray:
    return _bucketed_rows_jnp("overlap", X, xi, Y, yi)


def contain_rows_jnp(X, xi, F, fi) -> np.ndarray:
    return _bucketed_rows_jnp("contain", X, xi, F, fi)


def _overlap_rows_pallas(X, xi, Y, yi, interpret=None) -> np.ndarray:
    """Bucketed overlap through the Pallas ``kernels/interval_join`` kernel
    (interpret mode off-TPU). Used by predicates without a fused kernel.

    Rows whose lists exceed ``_PALLAS_MAX_WIDTH`` would blow the kernel's
    padded [BB, I, J] VMEM tile; they take the flat host pass instead
    (verdict-identical by construction)."""
    from ..kernels.interval_join.ops import batch_interval_overlap
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    xi = np.asarray(xi, np.int64)
    yi = np.asarray(yi, np.int64)
    N = len(xi)
    out = np.zeros(N, bool)
    cx = X.counts(xi)
    cy = Y.counts(yi)
    widths = np.maximum(np.maximum(cx, cy), 1)
    live = (cx > 0) & (cy > 0)
    wide = live & (widths > _PALLAS_MAX_WIDTH)
    if wide.any():
        w = np.nonzero(wide)[0]
        out[w] = overlap_rows_np(X, xi[w], Y, yi[w])
    for sel in size_buckets(np.where(live & ~wide, widths, 0), _BUCKET_CHUNK):
        xs, xl, nx = X.pack(xi[sel], _pow2(cx[sel].max()))
        ys, yl, ny = Y.pack(yi[sel], _pow2(cy[sel].max()))
        out[sel] = np.asarray(batch_interval_overlap(
            xs, xl, nx, ys, yl, ny, interpret=interpret))
    return out


def _overlap_fn(backend: str):
    if backend == "numpy":
        return overlap_rows_np
    if backend == "jnp":
        return overlap_rows_jnp
    if backend == "pallas":
        return _overlap_rows_pallas
    raise ValueError(f"no batched overlap path for backend {backend!r}")


# -- staged trichotomy drivers ----------------------------------------------

def april_trichotomy_rows(
    Xa: IntervalLists, Xf: IntervalLists, Ya: IntervalLists,
    Yf: IntervalLists, ri: np.ndarray, si: np.ndarray, *,
    backend: str = "numpy", order: tuple[str, ...] = ("AA", "AF", "FA"),
) -> np.ndarray:
    """Staged APRIL trichotomy (Algorithm 2) over rows (ri[n], si[n]).

    The AA-join runs over the whole batch; AF/FA evaluate only the
    compacted AA survivors (the batch analogue of the sequential early
    exit — ``order`` picks which hit-join runs first, semantics are
    order-invariant). The pallas backend instead ships each bucket through
    the fused three-join kernel (one pass, one verdict).
    """
    if "AA" not in order:
        raise ValueError("order must include 'AA'")
    ri = np.asarray(ri, np.int64)
    si = np.asarray(si, np.int64)
    N = len(ri)
    if N == 0:
        return np.zeros(0, np.int8)
    # the fused kernel evaluates all three joins, which is verdict-identical
    # for any permutation; degenerate orders (hit joins omitted) stage
    if backend == "pallas" and set(order) == {"AA", "AF", "FA"}:
        return _april_trichotomy_pallas(Xa, Xf, Ya, Yf, ri, si)
    overlap = _overlap_fn(backend)
    aa = overlap(Xa, ri, Ya, si)
    verdicts = np.where(aa, INDECISIVE, TRUE_NEG).astype(np.int8)
    sel = np.nonzero(aa)[0]
    # hit joins run in `order`; a degenerate order without them leaves AA
    # survivors INDECISIVE, exactly like the sequential reference
    for step in [s for s in order if s != "AA"]:
        if len(sel) == 0:
            break
        if step == "AF":
            hit = overlap(Xa, ri[sel], Yf, si[sel])
        else:
            hit = overlap(Xf, ri[sel], Ya, si[sel])
        verdicts[sel[hit]] = TRUE_HIT
        sel = sel[~hit]
    return verdicts


def _april_trichotomy_pallas(Xa, Xf, Ya, Yf, ri, si,
                             interpret=None) -> np.ndarray:
    """Bucketed batches through the fused three-join Pallas kernel.

    Rows whose widest list exceeds ``_PALLAS_MAX_WIDTH`` take the flat host
    staged pass instead of blowing the kernel's VMEM tile."""
    from ..kernels.interval_join.ops import batch_april_trichotomy
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N = len(ri)
    verdicts = np.full(N, TRUE_NEG, np.int8)
    counts = [L.counts(idx) for L, idx in
              ((Xa, ri), (Xf, ri), (Ya, si), (Yf, si))]
    widths = np.maximum(np.maximum.reduce(counts), 1)
    # rows with an empty A list on either side are decided without a kernel
    live = (counts[0] > 0) & (counts[2] > 0)
    wide = live & (widths > _PALLAS_MAX_WIDTH)
    if wide.any():
        w = np.nonzero(wide)[0]
        verdicts[w] = april_trichotomy_rows(Xa, Xf, Ya, Yf, ri[w], si[w],
                                            backend="numpy")
    for sel in size_buckets(np.where(live & ~wide, widths, 0), _BUCKET_CHUNK):
        ra = Xa.pack(ri[sel], _pow2(counts[0][sel].max()))
        rf = Xf.pack(ri[sel], _pow2(max(1, counts[1][sel].max())))
        sa = Ya.pack(si[sel], _pow2(counts[2][sel].max()))
        sf = Yf.pack(si[sel], _pow2(max(1, counts[3][sel].max())))
        verdicts[sel] = np.asarray(batch_april_trichotomy(
            *ra, *rf, *sa, *sf, interpret=interpret))
    return verdicts


# -- fused device status lanes (DESIGN.md §12) -------------------------------

_FUSED_STATUS_FNS: dict = {}


def _fused_tri_bucket_jnp(xa_s, xa_l, xalo, xacnt, xf_s, xf_l, xflo, xfcnt,
                          ya_s, ya_l, yalo, yacnt, yf_s, yf_l, yflo, yfcnt,
                          Wxa: int, Wxf: int, Wya: int, Wyf: int):
    """One bucket of the fused APRIL trichotomy: AA + AF + FA evaluated
    branch-free over every row (no host compaction of AA survivors) and the
    verdict select, all in one traced program."""
    xas, xal = _device_gather(xa_s, xa_l, xalo, xacnt, Wxa)
    xfs, xfl = _device_gather(xf_s, xf_l, xflo, xfcnt, Wxf)
    yas, yal = _device_gather(ya_s, ya_l, yalo, yacnt, Wya)
    yfs, yfl = _device_gather(yf_s, yf_l, yflo, yfcnt, Wyf)
    aa = batch_overlap_jnp(xas, xal, xacnt, yas, yal, yacnt)
    af = batch_overlap_jnp(xas, xal, xacnt, yfs, yfl, yfcnt)
    fa = batch_overlap_jnp(xfs, xfl, xfcnt, yas, yal, yacnt)
    return jnp.where(~aa, TRUE_NEG,
                     jnp.where(af | fa, TRUE_HIT, INDECISIVE)).astype(jnp.int8)


def _fused_within_bucket_jnp(xa_s, xa_l, xalo, xacnt, ya_s, ya_l, yalo, yacnt,
                             yf_s, yf_l, yflo, yfcnt,
                             Wxa: int, Wya: int, Wyf: int):
    """One bucket of the fused within trichotomy: AA overlap + A(r)-in-F(s)
    containment, verdict select in one traced program."""
    xas, xal = _device_gather(xa_s, xa_l, xalo, xacnt, Wxa)
    yas, yal = _device_gather(ya_s, ya_l, yalo, yacnt, Wya)
    yfs, yfl = _device_gather(yf_s, yf_l, yflo, yfcnt, Wyf)
    aa = batch_overlap_jnp(xas, xal, xacnt, yas, yal, yacnt)
    cont = batch_containment_jnp(xas, xal, xacnt, yfs, yfl, yfcnt)
    return jnp.where(~aa, TRUE_NEG,
                     jnp.where(cont, TRUE_HIT, INDECISIVE)).astype(jnp.int8)


def _fused_line_bucket_jnp(c_s, c_l, clo, ccnt, ya_s, ya_l, yalo, yacnt,
                           yf_s, yf_l, yflo, yfcnt,
                           Wc: int, Wya: int, Wyf: int):
    """One bucket of the fused linestring trichotomy: chain cells against
    A(s) and F(s), verdict select in one traced program."""
    cs, cl = _device_gather(c_s, c_l, clo, ccnt, Wc)
    yas, yal = _device_gather(ya_s, ya_l, yalo, yacnt, Wya)
    yfs, yfl = _device_gather(yf_s, yf_l, yflo, yfcnt, Wyf)
    aa = batch_overlap_jnp(cs, cl, ccnt, yas, yal, yacnt)
    fhit = batch_overlap_jnp(cs, cl, ccnt, yfs, yfl, yfcnt)
    return jnp.where(~aa, TRUE_NEG,
                     jnp.where(fhit, TRUE_HIT, INDECISIVE)).astype(jnp.int8)


def _fused_status_fn(kind: str):
    if jax is None:  # pragma: no cover
        raise RuntimeError("jax unavailable for the fused filter stage")
    if kind not in _FUSED_STATUS_FNS:
        fn, widths = {
            "intersects": (_fused_tri_bucket_jnp,
                           ("Wxa", "Wxf", "Wya", "Wyf")),
            "within": (_fused_within_bucket_jnp, ("Wxa", "Wya", "Wyf")),
            "linestring": (_fused_line_bucket_jnp, ("Wc", "Wya", "Wyf")),
        }[kind]
        _FUSED_STATUS_FNS[kind] = jax.jit(fn, static_argnames=widths)
    return _FUSED_STATUS_FNS[kind]


def _bucket_args(L: IntervalLists, idx, cnt, sel, Bp: int):
    """Per-bucket device args for one list side: the resident flat endpoint
    arrays plus padded [Bp] row offsets/counts (padding rows count 0)."""
    lo = np.zeros(Bp, np.int64)
    ct = np.zeros(Bp, np.int32)
    lo[:len(sel)] = L.off[idx[sel]]
    ct[:len(sel)] = cnt[sel]
    fs, fl = L.device()
    return fs, fl, jnp.asarray(lo), jnp.asarray(ct)


def fused_status_rows(predicate: str, Xa: IntervalLists,
                      Xf: "IntervalLists | None", Ya: IntervalLists,
                      Yf: IntervalLists, ri: np.ndarray, si: np.ndarray):
    """Device int8 status lane over ALL rows — the fused chain's filter
    stage (DESIGN.md §12).

    Unlike the staged drivers above, nothing returns to host: every live
    row's full trichotomy evaluates branch-free per power-of-two width
    bucket and scatters into the [N] device lane (rows with an empty A list
    on either side stay TRUE_NEG, like the staged paths). ``predicate`` is
    'intersects' (Xf required), 'within' (Xf unused) or 'linestring' (Xa is
    the chain's unit-cell lists). Verdict-identical to the staged drivers.
    """
    ri = np.asarray(ri, np.int64)
    si = np.asarray(si, np.int64)
    N = len(ri)
    lane = jnp.zeros(N, jnp.int8)               # TRUE_NEG
    if N == 0:
        return lane
    ca_r = Xa.counts(ri)
    ca_s = Ya.counts(si)
    cf_s = Yf.counts(si)
    live = (ca_r > 0) & (ca_s > 0)
    if predicate == "intersects":
        cf_r = Xf.counts(ri)
        widths = np.maximum.reduce([ca_r, cf_r, ca_s, cf_s])
    else:
        widths = np.maximum.reduce([ca_r, ca_s, cf_s])
    fn = _fused_status_fn(predicate)
    for sel in size_buckets(np.where(live, np.maximum(widths, 1), 0),
                            _BUCKET_CHUNK):
        Bp = _pow2(len(sel))
        args = _bucket_args(Xa, ri, ca_r, sel, Bp)
        kw = {}
        if predicate == "intersects":
            args += _bucket_args(Xf, ri, cf_r, sel, Bp)
            kw["Wxa"] = _pow2(ca_r[sel].max())
            kw["Wxf"] = _pow2(max(1, cf_r[sel].max()))
        else:
            key = "Wc" if predicate == "linestring" else "Wxa"
            kw[key] = _pow2(ca_r[sel].max())
        args += _bucket_args(Ya, si, ca_s, sel, Bp)
        args += _bucket_args(Yf, si, cf_s, sel, Bp)
        kw["Wya"] = _pow2(ca_s[sel].max())
        kw["Wyf"] = _pow2(max(1, cf_s[sel].max()))
        st = fn(*args, **kw)
        lane = lane.at[jnp.asarray(sel)].set(st[:len(sel)])
    return lane


def within_trichotomy_rows(
    Xa: IntervalLists, Ya: IntervalLists, Yf: IntervalLists,
    ri: np.ndarray, si: np.ndarray, *, backend: str = "numpy",
) -> np.ndarray:
    """Staged within trichotomy (§4.3.2): AA over the batch, containment of
    A(r) in F(s) only on the compacted AA survivors."""
    ri = np.asarray(ri, np.int64)
    si = np.asarray(si, np.int64)
    N = len(ri)
    if N == 0:
        return np.zeros(0, np.int8)
    # containment has no pallas kernel; the pallas backend runs AA through
    # the kernel and falls back to the device containment pass
    overlap = _overlap_fn(backend)
    contain = contain_rows_jnp if backend in ("jnp", "pallas") \
        else contain_rows_np
    aa = overlap(Xa, ri, Ya, si)
    verdicts = np.where(aa, INDECISIVE, TRUE_NEG).astype(np.int8)
    sel = np.nonzero(aa)[0]
    if len(sel):
        cont = contain(Xa, ri[sel], Yf, si[sel])
        verdicts[sel[cont]] = TRUE_HIT
    return verdicts


def linestring_trichotomy_rows(
    C: IntervalLists, Ya: IntervalLists, Yf: IntervalLists,
    li: np.ndarray, si: np.ndarray, *, backend: str = "numpy",
) -> np.ndarray:
    """Staged polygon x linestring trichotomy (§4.3.3): the chain's unit
    intervals against A(s) over the batch, against F(s) on survivors."""
    li = np.asarray(li, np.int64)
    si = np.asarray(si, np.int64)
    N = len(li)
    if N == 0:
        return np.zeros(0, np.int8)
    overlap = _overlap_fn(backend)
    aa = overlap(C, li, Ya, si)
    verdicts = np.where(aa, INDECISIVE, TRUE_NEG).astype(np.int8)
    sel = np.nonzero(aa)[0]
    if len(sel):
        fhit = overlap(C, li[sel], Yf, si[sel])
        verdicts[sel[fhit]] = TRUE_HIT
    return verdicts
