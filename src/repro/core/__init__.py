"""Core library: the paper's contribution (RI + APRIL raster-interval
approximations and interval-join intermediate filters) in JAX/NumPy."""
from . import (  # noqa: F401
    april, compress, geometry, granularity, hilbert, intervalize, join,
    partition, rasterize, ri,
)
from .april import AprilStore, build_april, build_april_polygon  # noqa: F401
from .join import (  # noqa: F401
    INDECISIVE, TRUE_HIT, TRUE_NEG, april_filter_batch, april_verdict_pair,
)
from .rasterize import Extent, GLOBAL_EXTENT  # noqa: F401
