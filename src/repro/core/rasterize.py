"""Polygon rasterization onto the global 2^N x 2^N grid.

Implements the construction paths of the paper:

* :func:`dda_partial_cells` — Amanatides-Woo grid traversal of every polygon
  edge (the DDA variant of [4] used in §6), fully vectorized over edges.
  Detects *all* cells crossed by the boundary (the Partial cells).
* :func:`scanline_full_cells` — §6.1 scanline rendering: per-row parity fill
  at cell-center height, vectorized over rows x edges.
* :func:`floodfill_classify` — §6.1 flood-fill variant (host BFS, faithful to
  the paper; used for Table-11 style construction benchmarks and as oracle).
* :func:`coverage_fractions` — exact polygon∩cell area fractions via
  Sutherland–Hodgman clipping; needed only by RA/RI (Weak/Strong/Full labels).
* :func:`classify_window_oracle` — brute-force Partial/Full/Empty classifier
  (slow, exact) used as the test oracle for every faster path.

A raster ``extent`` is the square (x0, y0, side) covered by the grid: the
whole data space for the global grid, or a partition's *raster area* (§5.2).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from . import geometry
from .hilbert import xy2d

__all__ = [
    "Extent", "GLOBAL_EXTENT", "cells_of_points",
    "dda_partial_cells", "scanline_full_cells", "floodfill_classify",
    "coverage_fractions", "classify_window_oracle", "cell_centers",
]


@dataclass(frozen=True)
class Extent:
    """Square raster area: origin (x0, y0) and side length."""
    x0: float
    y0: float
    side: float

    def cell_size(self, n_order: int) -> float:
        return self.side / (1 << n_order)


GLOBAL_EXTENT = Extent(0.0, 0.0, 1.0)


def _grid_coords(points: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    """Continuous coords -> grid coords in [0, 2^n_order)."""
    g = (np.asarray(points, np.float64) - np.array([extent.x0, extent.y0])) \
        / extent.cell_size(n_order)
    return g


def cells_of_points(points: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    """Cell (cx, cy) of each point, clipped into the grid. [..., 2] int64."""
    g = np.floor(_grid_coords(points, n_order, extent)).astype(np.int64)
    return np.clip(g, 0, (1 << n_order) - 1)


def cell_centers(cx: np.ndarray, cy: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    h = extent.cell_size(n_order)
    return np.stack([extent.x0 + (np.asarray(cx, np.float64) + 0.5) * h,
                     extent.y0 + (np.asarray(cy, np.float64) + 0.5) * h], axis=-1)


def dda_partial_cells(
    verts: np.ndarray, n: int, n_order: int, extent: Extent = GLOBAL_EXTENT,
    closed: bool = True,
) -> np.ndarray:
    """All boundary (Partial) cells of one polygon, vectorized over edges.

    Returns unique cell coordinates [K, 2] int64 (cx, cy), unsorted.
    ``closed=False`` treats the vertices as an open chain (linestrings §4.3.3).

    For each edge we enumerate its vertical and horizontal grid-line
    crossings, order them by line parameter t, and accumulate cell steps —
    the Amanatides-Woo traversal, executed for all edges at once with
    padding to the max crossing count.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    G = 1 << n_order
    if closed:
        a = _grid_coords(v, n_order, extent)                 # [E,2]
        b = np.roll(a, -1, axis=0)
    else:
        g = _grid_coords(v, n_order, extent)
        a, b = g[:-1], g[1:]
    ca = np.clip(np.floor(a).astype(np.int64), 0, G - 1)     # [E,2]
    cb = np.clip(np.floor(b).astype(np.int64), 0, G - 1)

    dx = b[:, 0] - a[:, 0]
    dy = b[:, 1] - a[:, 1]
    sx = np.sign(cb[:, 0] - ca[:, 0]).astype(np.int64)
    sy = np.sign(cb[:, 1] - ca[:, 1]).astype(np.int64)
    nx = np.abs(cb[:, 0] - ca[:, 0])                         # [E]
    ny = np.abs(cb[:, 1] - ca[:, 1])
    E = len(a)
    Kx = int(nx.max()) if E else 0
    Ky = int(ny.max()) if E else 0

    # t-parameters of successive x-line crossings, in traversal order.
    kx = np.arange(1, Kx + 1)[None, :]                       # [1,Kx]
    xlines = ca[:, 0][:, None] + np.where(sx[:, None] >= 0, kx, -kx) \
        + np.where(sx[:, None] >= 0, 0, 1)                   # crossing coordinate
    with np.errstate(divide="ignore", invalid="ignore"):
        tx = (xlines - a[:, 0][:, None]) / np.where(dx[:, None] == 0, 1.0, dx[:, None])
    tx = np.where(kx <= nx[:, None], tx, np.inf)

    ky = np.arange(1, Ky + 1)[None, :]
    ylines = ca[:, 1][:, None] + np.where(sy[:, None] >= 0, ky, -ky) \
        + np.where(sy[:, None] >= 0, 0, 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        ty = (ylines - a[:, 1][:, None]) / np.where(dy[:, None] == 0, 1.0, dy[:, None])
    ty = np.where(ky <= ny[:, None], ty, np.inf)

    # Merge crossings by t; steps in x get label 0, steps in y label 1.
    t_all = np.concatenate([tx, ty], axis=1)                 # [E, Kx+Ky]
    step_is_y = np.concatenate(
        [np.zeros_like(tx, dtype=bool), np.ones_like(ty, dtype=bool)], axis=1)
    order = np.argsort(t_all, axis=1, kind="stable")
    t_sorted = np.take_along_axis(t_all, order, axis=1)
    isy = np.take_along_axis(step_is_y, order, axis=1)
    valid = np.isfinite(t_sorted)

    stepx = np.where(valid & ~isy, sx[:, None], 0)
    stepy = np.where(valid & isy, sy[:, None], 0)
    cx = ca[:, 0][:, None] + np.cumsum(stepx, axis=1)        # cells after each step
    cy = ca[:, 1][:, None] + np.cumsum(stepy, axis=1)

    # First cell of each edge + all stepped cells.
    all_cx = np.concatenate([ca[:, 0][:, None], cx], axis=1).ravel()
    all_cy = np.concatenate([ca[:, 1][:, None], cy], axis=1).ravel()
    all_valid = np.concatenate(
        [np.ones((E, 1), dtype=bool), valid], axis=1).ravel()
    cxv = np.clip(all_cx[all_valid], 0, G - 1)
    cyv = np.clip(all_cy[all_valid], 0, G - 1)
    cells = np.unique(np.stack([cxv, cyv], axis=1), axis=0)
    return cells


def scanline_full_cells(
    verts: np.ndarray, n: int, partial: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Full cells via per-row parity fill at cell-center height (§6.1).

    ``partial``: [K,2] boundary cells from :func:`dda_partial_cells`.
    Returns [F,2] int64 Full cells. Vectorized over (rows x edges).
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    if len(partial) == 0:
        return np.zeros((0, 2), dtype=np.int64)
    G = 1 << n_order
    h = extent.cell_size(n_order)
    y_lo, y_hi = int(partial[:, 1].min()), int(partial[:, 1].max())
    x_lo, x_hi = int(partial[:, 0].min()), int(partial[:, 0].max())
    rows = np.arange(y_lo, y_hi + 1)
    ycent = extent.y0 + (rows + 0.5) * h                     # [R]

    x0, y0 = v[:, 0][None, :], v[:, 1][None, :]              # [1,E]
    x1 = np.roll(v[:, 0], -1)[None, :]
    y1 = np.roll(v[:, 1], -1)[None, :]
    yc = ycent[:, None]                                       # [R,1]
    cond = (y0 <= yc) != (y1 <= yc)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (yc - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = np.where(cond, x0 + t * (x1 - x0), np.inf)        # [R,E]
    xint_sorted = np.sort(xint, axis=1)

    # Parity of crossings left of each cell center => inside/outside.
    cols = np.arange(x_lo, x_hi + 1)
    xcent = extent.x0 + (cols + 0.5) * h                     # [C]
    # counts[r, c] = # crossings with xint < xcent[c]  (broadcast [R,C,E])
    counts = np.sum(xint_sorted[:, None, :] < xcent[None, :, None], axis=2)
    inside = (counts % 2) == 1                               # [R,C]

    pmask = np.zeros((y_hi - y_lo + 1, x_hi - x_lo + 1), dtype=bool)
    pmask[partial[:, 1] - y_lo, partial[:, 0] - x_lo] = True
    fullmask = inside & ~pmask
    ry, cx = np.nonzero(fullmask)
    return np.stack([cx + x_lo, ry + y_lo], axis=1).astype(np.int64)


def floodfill_classify(
    verts: np.ndarray, n: int, partial: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Flood-fill Full-cell detection (§6.1, host BFS; oracle/benchmark path).

    Iterates the MBR window; each unlabeled region costs ONE PiP test, then a
    BFS labels the region Full or Empty, stopping at Partial cells.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    if len(partial) == 0:
        return np.zeros((0, 2), dtype=np.int64)
    y_lo, y_hi = int(partial[:, 1].min()), int(partial[:, 1].max())
    x_lo, x_hi = int(partial[:, 0].min()), int(partial[:, 0].max())
    H, W = y_hi - y_lo + 1, x_hi - x_lo + 1
    # 0 unknown, 1 partial, 2 full, 3 empty
    lab = np.zeros((H, W), dtype=np.int8)
    lab[partial[:, 1] - y_lo, partial[:, 0] - x_lo] = 1

    def pip(cx, cy) -> bool:
        c = cell_centers(np.array([cx]), np.array([cy]), n_order, extent)
        return bool(geometry.points_in_polygon(c, v)[0])

    for yy in range(H):
        for xx in range(W):
            if lab[yy, xx] != 0:
                continue
            mark = 2 if pip(xx + x_lo, yy + y_lo) else 3
            q = deque([(yy, xx)])
            lab[yy, xx] = mark
            while q:
                cy_, cx_ = q.popleft()
                for ny_, nx_ in ((cy_ + 1, cx_), (cy_ - 1, cx_), (cy_, cx_ + 1), (cy_, cx_ - 1)):
                    if 0 <= ny_ < H and 0 <= nx_ < W and lab[ny_, nx_] == 0:
                        lab[ny_, nx_] = mark
                        q.append((ny_, nx_))
    ry, cx = np.nonzero(lab == 2)
    return np.stack([cx + x_lo, ry + y_lo], axis=1).astype(np.int64)


def coverage_fractions(
    verts: np.ndarray, n: int, cells: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Exact coverage fraction of each cell by the polygon (RA/RI labeling).

    cells: [K,2]. Returns [K] float64 in [0,1]. Host-side, per-cell clipping —
    deliberately the expensive path the paper attributes to RA/RI.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    h = extent.cell_size(n_order)
    out = np.zeros(len(cells), dtype=np.float64)
    cell_area = h * h
    for i, (cx, cy) in enumerate(np.asarray(cells, np.int64)):
        box = (extent.x0 + cx * h, extent.y0 + cy * h,
               extent.x0 + (cx + 1) * h, extent.y0 + (cy + 1) * h)
        clipped = geometry.clip_polygon_to_box(v, box)
        if len(clipped) >= 3:
            out[i] = geometry.polygon_area(clipped) / cell_area
    return np.clip(out, 0.0, 1.0)


def classify_window_oracle(
    verts: np.ndarray, n: int, n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> dict[str, np.ndarray]:
    """Brute-force oracle: classify every MBR-window cell as partial/full.

    partial := boundary crosses the cell (any edge intersects the cell box or
    a polygon vertex lies inside it); full := not partial and center inside.
    Returns {'partial': [Kp,2], 'full': [Kf,2]} int64 cell coords.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    G = 1 << n_order
    h = extent.cell_size(n_order)
    mbr_lo = cells_of_points(v.min(axis=0)[None, :], n_order, extent)[0]
    mbr_hi = cells_of_points(v.max(axis=0)[None, :], n_order, extent)[0]
    xs = np.arange(mbr_lo[0], mbr_hi[0] + 1)
    ys = np.arange(mbr_lo[1], mbr_hi[1] + 1)
    CX, CY = np.meshgrid(xs, ys, indexing="ij")
    cx, cy = CX.ravel(), CY.ravel()
    # cell boxes
    bx0 = extent.x0 + cx * h; by0 = extent.y0 + cy * h
    bx1 = bx0 + h; by1 = by0 + h
    # vertex-in-cell
    vin = np.zeros(len(cx), dtype=bool)
    for p in v:
        vin |= (bx0 <= p[0]) & (p[0] < bx1) & (by0 <= p[1]) & (p[1] < by1)
    # edge-box intersection: any of the 4 box sides intersects the edge, or
    # edge endpoint inside box (covered by vin since endpoints are vertices).
    a0 = v; a1 = np.roll(v, -1, axis=0)
    partial = vin.copy()
    corners = np.stack([
        np.stack([bx0, by0], axis=1), np.stack([bx1, by0], axis=1),
        np.stack([bx1, by1], axis=1), np.stack([bx0, by1], axis=1),
    ], axis=1)  # [K,4,2]
    sides = np.stack([
        np.stack([corners[:, 0], corners[:, 1]], axis=1),
        np.stack([corners[:, 1], corners[:, 2]], axis=1),
        np.stack([corners[:, 2], corners[:, 3]], axis=1),
        np.stack([corners[:, 3], corners[:, 0]], axis=1),
    ], axis=1)  # [K,4,2,2]
    for e in range(len(v)):
        hit = geometry.segments_intersect(
            a0[e][None, None, :], a1[e][None, None, :],
            sides[:, :, 0, :], sides[:, :, 1, :])
        partial |= hit.any(axis=1)
    centers = cell_centers(cx, cy, n_order, extent)
    inside = geometry.points_in_polygon(centers, v)
    full = inside & ~partial
    sel_p = np.stack([cx[partial], cy[partial]], axis=1).astype(np.int64)
    sel_f = np.stack([cx[full], cy[full]], axis=1).astype(np.int64)
    return {"partial": sel_p, "full": sel_f}


def cells_to_hilbert(cells: np.ndarray, n_order: int) -> np.ndarray:
    """Sorted unique Hilbert ids (uint64) of cell coords [K,2]."""
    if len(cells) == 0:
        return np.zeros((0,), dtype=np.uint64)
    d = xy2d(n_order, cells[:, 0], cells[:, 1])
    return np.unique(d)
