"""Polygon rasterization onto the global 2^N x 2^N grid.

Implements the construction paths of the paper:

* :func:`dda_partial_cells` — Amanatides-Woo grid traversal of every polygon
  edge (the DDA variant of [4] used in §6), fully vectorized over edges.
  Detects *all* cells crossed by the boundary (the Partial cells).
* :func:`scanline_full_cells` — §6.1 scanline rendering: per-row parity fill
  at cell-center height, vectorized over rows x edges.
* :func:`floodfill_classify` — §6.1 flood-fill variant (host BFS, faithful to
  the paper; used for Table-11 style construction benchmarks and as oracle).
* :func:`coverage_fractions` — exact polygon∩cell area fractions via
  Sutherland–Hodgman clipping; needed only by RA/RI (Weak/Strong/Full labels).
* :func:`classify_window_oracle` — brute-force Partial/Full/Empty classifier
  (slow, exact) used as the test oracle for every faster path.

A raster ``extent`` is the square (x0, y0, side) covered by the grid: the
whole data space for the global grid, or a partition's *raster area* (§5.2).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from . import geometry
from .hilbert import xy2d

__all__ = [
    "Extent", "GLOBAL_EXTENT", "cells_of_points",
    "clip_segments_to_grid", "dda_traverse",
    "dda_partial_cells", "dda_partial_cells_multi",
    "scanline_full_cells", "scanline_full_cells_multi", "floodfill_classify",
    "coverage_fractions", "coverage_fractions_multi",
    "classify_window_oracle", "cell_centers", "size_buckets",
]


@dataclass(frozen=True)
class Extent:
    """Square raster area: origin (x0, y0) and side length."""
    x0: float
    y0: float
    side: float

    def cell_size(self, n_order: int) -> float:
        return self.side / (1 << n_order)


GLOBAL_EXTENT = Extent(0.0, 0.0, 1.0)


def _grid_coords(points: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    """Continuous coords -> grid coords in [0, 2^n_order)."""
    g = (np.asarray(points, np.float64) - np.array([extent.x0, extent.y0])) \
        / extent.cell_size(n_order)
    return g


def cells_of_points(points: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    """Cell (cx, cy) of each point, clipped into the grid. [..., 2] int64."""
    g = np.floor(_grid_coords(points, n_order, extent)).astype(np.int64)
    return np.clip(g, 0, (1 << n_order) - 1)


def cell_centers(cx: np.ndarray, cy: np.ndarray, n_order: int, extent: Extent) -> np.ndarray:
    h = extent.cell_size(n_order)
    return np.stack([extent.x0 + (np.asarray(cx, np.float64) + 0.5) * h,
                     extent.y0 + (np.asarray(cy, np.float64) + 0.5) * h], axis=-1)


# canonical bucketing helper lives in geometry (imported above); re-exported
# here for the join-side callers (core.ri aliases it)
size_buckets = geometry.size_buckets


def clip_segments_to_grid(a: np.ndarray, b: np.ndarray, G) -> tuple:
    """Liang–Barsky clip of segments a->b (grid coords) to the square
    [0, G]^2. ``G`` is a scalar or per-segment array. Returns
    (a_c [E,2], b_c [E,2], keep [E]); segments fully outside are dropped —
    clamping them into the border row/column emits spurious Partial cells
    when geometry crosses the raster-area boundary (§5.2 partition builds).
    Fully-inside segments pass through bit-unchanged.
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    E = len(a)
    d = b - a
    Gf = np.broadcast_to(np.asarray(G, np.float64), (E,))
    t0 = np.zeros(E)
    t1 = np.ones(E)
    keep = np.ones(E, bool)
    for axis in (0, 1):
        da = d[:, axis]
        pa = a[:, axis]
        for p, q in ((-da, pa), (da, Gf - pa)):
            par = p == 0
            keep &= ~(par & (q < 0))
            with np.errstate(divide="ignore", invalid="ignore"):
                r = q / np.where(par, 1.0, p)
            t0 = np.where(~par & (p < 0), np.maximum(t0, r), t0)
            t1 = np.where(~par & (p > 0), np.minimum(t1, r), t1)
    keep &= t0 <= t1
    a_c = np.where((t0 > 0)[:, None], a + t0[:, None] * d, a)
    b_c = np.where((t1 < 1)[:, None], a + t1[:, None] * d, b)
    return a_c, b_c, keep


def dda_traverse(a: np.ndarray, b: np.ndarray, G,
                 chunk_elems: int = 1 << 22) -> tuple:
    """Amanatides-Woo traversal of in-grid segments, vectorized over edges.

    a, b: [E,2] grid coords already clipped into [0, G]^2; ``G`` scalar or
    per-edge. Returns (edge_of_cell [T], cells [T,2] int64) — the start cell
    of every edge plus one cell per grid-line crossing, in traversal order.
    Edges are bucketed by crossing count to bound padding waste.
    """
    E = len(a)
    if E == 0:
        return np.zeros(0, np.int64), np.zeros((0, 2), np.int64)
    Gi = np.broadcast_to(np.asarray(G, np.int64), (E,))
    hi = (Gi - 1)[:, None]
    ca = np.clip(np.floor(a).astype(np.int64), 0, hi)        # [E,2]
    cb = np.clip(np.floor(b).astype(np.int64), 0, hi)
    sx = np.sign(cb[:, 0] - ca[:, 0]).astype(np.int64)
    sy = np.sign(cb[:, 1] - ca[:, 1]).astype(np.int64)
    nx = np.abs(cb[:, 0] - ca[:, 0])                         # [E]
    ny = np.abs(cb[:, 1] - ca[:, 1])

    eids = [np.arange(E)]
    cxs = [ca[:, 0]]
    cys = [ca[:, 1]]
    work = np.nonzero(nx + ny > 0)[0]
    for sub in size_buckets(nx[work] + ny[work], chunk_elems):
        e = work[sub]
        Kx = int(nx[e].max())
        Ky = int(ny[e].max())
        dx = b[e, 0] - a[e, 0]
        dy = b[e, 1] - a[e, 1]

        # t-parameters of successive x-line crossings, in traversal order.
        kx = np.arange(1, Kx + 1)[None, :]                   # [1,Kx]
        xlines = ca[e, 0][:, None] + np.where(sx[e, None] >= 0, kx, -kx) \
            + np.where(sx[e, None] >= 0, 0, 1)               # crossing coordinate
        with np.errstate(divide="ignore", invalid="ignore"):
            tx = (xlines - a[e, 0][:, None]) \
                / np.where(dx[:, None] == 0, 1.0, dx[:, None])
        tx = np.where(kx <= nx[e, None], tx, np.inf)

        ky = np.arange(1, Ky + 1)[None, :]
        ylines = ca[e, 1][:, None] + np.where(sy[e, None] >= 0, ky, -ky) \
            + np.where(sy[e, None] >= 0, 0, 1)
        with np.errstate(divide="ignore", invalid="ignore"):
            ty = (ylines - a[e, 1][:, None]) \
                / np.where(dy[:, None] == 0, 1.0, dy[:, None])
        ty = np.where(ky <= ny[e, None], ty, np.inf)

        # Merge crossings by t; steps in x get label 0, steps in y label 1.
        t_all = np.concatenate([tx, ty], axis=1)             # [e, Kx+Ky]
        step_is_y = np.concatenate(
            [np.zeros_like(tx, dtype=bool), np.ones_like(ty, dtype=bool)],
            axis=1)
        order = np.argsort(t_all, axis=1, kind="stable")
        t_sorted = np.take_along_axis(t_all, order, axis=1)
        isy = np.take_along_axis(step_is_y, order, axis=1)
        valid = np.isfinite(t_sorted)

        stepx = np.where(valid & ~isy, sx[e, None], 0)
        stepy = np.where(valid & isy, sy[e, None], 0)
        cx = ca[e, 0][:, None] + np.cumsum(stepx, axis=1)    # cells after steps
        cy = ca[e, 1][:, None] + np.cumsum(stepy, axis=1)
        erep = np.broadcast_to(e[:, None], valid.shape)[valid]
        eids.append(erep)
        cxs.append(np.clip(cx[valid], 0, Gi[erep] - 1))
        cys.append(np.clip(cy[valid], 0, Gi[erep] - 1))
    eid = np.concatenate(eids)
    cells = np.stack([np.concatenate(cxs), np.concatenate(cys)], axis=1)
    return eid, cells.astype(np.int64)


def dda_partial_cells(
    verts: np.ndarray, n: int, n_order: int, extent: Extent = GLOBAL_EXTENT,
    closed: bool = True,
) -> np.ndarray:
    """All boundary (Partial) cells of one polygon, vectorized over edges.

    Returns unique cell coordinates [K, 2] int64 (cx, cy), sorted lexico-
    graphically. ``closed=False`` treats the vertices as an open chain
    (linestrings §4.3.3). Edges are clipped to the extent before traversal
    (dropped when fully outside — NOT clamped into the border row/column),
    so geometry crossing the raster-area boundary yields exactly the cells
    its in-extent boundary touches.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    G = 1 << n_order
    if closed:
        a = _grid_coords(v, n_order, extent)                 # [E,2]
        b = np.roll(a, -1, axis=0)
    else:
        g = _grid_coords(v, n_order, extent)
        a, b = g[:-1], g[1:]
    a_c, b_c, keep = clip_segments_to_grid(a, b, float(G))
    _, cells = dda_traverse(a_c[keep], b_c[keep], G)
    if len(cells) == 0:
        return np.zeros((0, 2), dtype=np.int64)
    return np.unique(cells, axis=0)


def dda_partial_cells_multi(
    verts: np.ndarray, nverts: np.ndarray, n_order: int,
    extent: Extent = GLOBAL_EXTENT, closed: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Partial cells of MANY polygons in one traversal (DESIGN.md §6).

    verts: padded [P,V,2]; nverts: [P]. Returns CSR ``(off [P+1],
    cells [T,2])`` with each polygon's unique cells sorted by (cx, cy) —
    cell-identical to per-polygon :func:`dda_partial_cells` calls. All edges
    of all polygons form one flat edge array; buckets by crossing count keep
    the padded traversal dense.
    """
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    P, V, _ = verts.shape
    G = 1 << n_order
    g = _grid_coords(verts.reshape(-1, 2), n_order, extent).reshape(P, V, 2)
    idx = np.arange(V)[None, :]
    if closed:
        edge_valid = idx < nverts[:, None]
        nxt = np.where(edge_valid, (idx + 1) % np.maximum(nverts[:, None], 1), 0)
    else:
        edge_valid = idx < nverts[:, None] - 1
        nxt = np.where(edge_valid, np.minimum(idx + 1, V - 1), 0)
    pe, ve = np.nonzero(edge_valid)
    a = g[pe, ve]
    b = g[pe, nxt[pe, ve]]
    a_c, b_c, keep = clip_segments_to_grid(a, b, float(G))
    pe = pe[keep]
    eid, cells = dda_traverse(a_c[keep], b_c[keep], G)
    if len(cells) == 0:
        return np.zeros(P + 1, np.int64), np.zeros((0, 2), np.int64)
    pid = pe[eid]
    G2 = np.uint64(G) * np.uint64(G)
    key = (pid.astype(np.uint64) * G2
           + cells[:, 0].astype(np.uint64) * np.uint64(G)
           + cells[:, 1].astype(np.uint64))
    uk = np.unique(key)
    pid_u = (uk // G2).astype(np.int64)
    rem = uk % G2
    out = np.stack([(rem // np.uint64(G)).astype(np.int64),
                    (rem % np.uint64(G)).astype(np.int64)], axis=1)
    off = np.zeros(P + 1, np.int64)
    off[1:] = np.cumsum(np.bincount(pid_u, minlength=P))
    return off, out


def _all_grid_cells(n_order: int) -> np.ndarray:
    """Every cell of the grid, sorted by (cx, cy) — the Full set of a
    polygon that covers the whole extent without touching it."""
    G = 1 << n_order
    xs = np.arange(G)
    CX, CY = np.meshgrid(xs, xs, indexing="ij")
    return np.stack([CX.ravel(), CY.ravel()], axis=1).astype(np.int64)


def _grid_covered(verts: np.ndarray, n_order: int, extent: Extent) -> bool:
    """With no Partial cells the grid is entirely inside or entirely outside
    the polygon; one PiP at the (0,0) cell center decides (§5.2 partitions
    fully covered by a large polygon)."""
    v = np.asarray(verts, np.float64)
    if len(v) < 3:
        return False
    c = cell_centers(np.array([0]), np.array([0]), n_order, extent)
    return bool(geometry.points_in_polygon(c, v)[0])


def _window(verts: np.ndarray, n_order: int, extent: Extent) -> tuple:
    """MBR window clipped into the grid: (x_lo, y_lo, x_hi, y_hi) cells.
    For in-extent polygons this equals the Partial-cell bounding box; for
    geometry crossing the extent it covers the whole in-grid part (whose
    Full cells may lie outside the Partial bbox)."""
    v = np.asarray(verts, np.float64)
    lo = cells_of_points(v.min(axis=0)[None, :], n_order, extent)[0]
    hi = cells_of_points(v.max(axis=0)[None, :], n_order, extent)[0]
    return int(lo[0]), int(lo[1]), int(hi[0]), int(hi[1])


def scanline_full_cells(
    verts: np.ndarray, n: int, partial: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Full cells via per-row parity fill at cell-center height (§6.1).

    ``partial``: [K,2] boundary cells from :func:`dda_partial_cells`.
    Returns [F,2] int64 Full cells. Vectorized over (rows x edges).
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    if len(partial) == 0:
        if _grid_covered(v, n_order, extent):
            return _all_grid_cells(n_order)
        return np.zeros((0, 2), dtype=np.int64)
    h = extent.cell_size(n_order)
    x_lo, y_lo, x_hi, y_hi = _window(v, n_order, extent)
    rows = np.arange(y_lo, y_hi + 1)
    ycent = extent.y0 + (rows + 0.5) * h                     # [R]

    x0, y0 = v[:, 0][None, :], v[:, 1][None, :]              # [1,E]
    x1 = np.roll(v[:, 0], -1)[None, :]
    y1 = np.roll(v[:, 1], -1)[None, :]
    yc = ycent[:, None]                                       # [R,1]
    cond = (y0 <= yc) != (y1 <= yc)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (yc - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = np.where(cond, x0 + t * (x1 - x0), np.inf)        # [R,E]
    xint_sorted = np.sort(xint, axis=1)

    # Parity of crossings left of each cell center => inside/outside.
    cols = np.arange(x_lo, x_hi + 1)
    xcent = extent.x0 + (cols + 0.5) * h                     # [C]
    # counts[r, c] = # crossings with xint < xcent[c]  (broadcast [R,C,E])
    counts = np.sum(xint_sorted[:, None, :] < xcent[None, :, None], axis=2)
    inside = (counts % 2) == 1                               # [R,C]

    pmask = np.zeros((y_hi - y_lo + 1, x_hi - x_lo + 1), dtype=bool)
    pmask[partial[:, 1] - y_lo, partial[:, 0] - x_lo] = True
    fullmask = inside & ~pmask
    ry, cx = np.nonzero(fullmask)
    return np.stack([cx + x_lo, ry + y_lo], axis=1).astype(np.int64)


def floodfill_classify(
    verts: np.ndarray, n: int, partial: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Flood-fill Full-cell detection (§6.1, host BFS; oracle/benchmark path).

    Iterates the MBR window; each unlabeled region costs ONE PiP test, then a
    BFS labels the region Full or Empty, stopping at Partial cells.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    if len(partial) == 0:
        if _grid_covered(v, n_order, extent):
            return _all_grid_cells(n_order)
        return np.zeros((0, 2), dtype=np.int64)
    x_lo, y_lo, x_hi, y_hi = _window(v, n_order, extent)
    H, W = y_hi - y_lo + 1, x_hi - x_lo + 1
    # 0 unknown, 1 partial, 2 full, 3 empty
    lab = np.zeros((H, W), dtype=np.int8)
    lab[partial[:, 1] - y_lo, partial[:, 0] - x_lo] = 1

    def pip(cx, cy) -> bool:
        c = cell_centers(np.array([cx]), np.array([cy]), n_order, extent)
        return bool(geometry.points_in_polygon(c, v)[0])

    for yy in range(H):
        for xx in range(W):
            if lab[yy, xx] != 0:
                continue
            mark = 2 if pip(xx + x_lo, yy + y_lo) else 3
            q = deque([(yy, xx)])
            lab[yy, xx] = mark
            while q:
                cy_, cx_ = q.popleft()
                for ny_, nx_ in ((cy_ + 1, cx_), (cy_ - 1, cx_), (cy_, cx_ + 1), (cy_, cx_ - 1)):
                    if 0 <= ny_ < H and 0 <= nx_ < W and lab[ny_, nx_] == 0:
                        lab[ny_, nx_] = mark
                        q.append((ny_, nx_))
    ry, cx = np.nonzero(lab == 2)
    return np.stack([cx + x_lo, ry + y_lo], axis=1).astype(np.int64)


def coverage_fractions(
    verts: np.ndarray, n: int, cells: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> np.ndarray:
    """Exact coverage fraction of each cell by the polygon (RA/RI labeling).

    cells: [K,2]. Returns [K] float64 in [0,1]. Host-side, per-cell clipping —
    deliberately the expensive path the paper attributes to RA/RI.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    h = extent.cell_size(n_order)
    out = np.zeros(len(cells), dtype=np.float64)
    cell_area = h * h
    for i, (cx, cy) in enumerate(np.asarray(cells, np.int64)):
        box = (extent.x0 + cx * h, extent.y0 + cy * h,
               extent.x0 + (cx + 1) * h, extent.y0 + (cy + 1) * h)
        clipped = geometry.clip_polygon_to_box(v, box)
        if len(clipped) >= 3:
            out[i] = geometry.polygon_area(clipped) / cell_area
    return np.clip(out, 0.0, 1.0)


def coverage_fractions_multi(
    verts: np.ndarray, nverts: np.ndarray, poly_of_cell: np.ndarray,
    cells: np.ndarray, n_order: int, extent: Extent = GLOBAL_EXTENT,
    backend: str = "numpy",
) -> np.ndarray:
    """Coverage fraction of each (cell, own-polygon) row in one padded
    Sutherland–Hodgman pass (DESIGN.md §6). Row-identical to
    :func:`coverage_fractions` over the same polygon.

    verts [P,V,2] padded, nverts [P]; poly_of_cell [K]; cells [K,2].
    ``backend``: 'numpy' (host) or 'jnp' (device clip pass).
    """
    cells = np.asarray(cells, np.int64)
    h = extent.cell_size(n_order)
    boxes = np.stack([
        extent.x0 + cells[:, 0] * h, extent.y0 + cells[:, 1] * h,
        extent.x0 + (cells[:, 0] + 1) * h, extent.y0 + (cells[:, 1] + 1) * h,
    ], axis=1)
    areas = geometry.box_clip_areas_rows(verts, nverts, poly_of_cell, boxes,
                                         backend=backend)
    return np.clip(areas / (h * h), 0.0, 1.0)


def scanline_full_cells_multi(
    verts: np.ndarray, nverts: np.ndarray,
    p_off: np.ndarray, p_cells: np.ndarray,
    n_order: int, extent: Extent = GLOBAL_EXTENT,
    chunk_elems: int = 1 << 22,
) -> tuple[np.ndarray, np.ndarray]:
    """Full cells of MANY polygons: parity fill over flat (polygon-row x
    edge) pairs, bucketed by (vertex, column) count classes (DESIGN.md §6).

    ``p_off``/``p_cells``: Partial-cell CSR from
    :func:`dda_partial_cells_multi`. Returns CSR ``(off [P+1], cells [T,2])``
    sorted by (cx, cy) per polygon; cell-identical to per-polygon
    :func:`scanline_full_cells` calls.
    """
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    P = len(nverts)
    G = 1 << n_order
    h = extent.cell_size(n_order)
    G2 = np.uint64(G) * np.uint64(G)
    n_partial = np.diff(p_off)
    pkeys = (np.repeat(np.arange(P), n_partial).astype(np.uint64) * G2
             + p_cells[:, 0].astype(np.uint64) * np.uint64(G)
             + p_cells[:, 1].astype(np.uint64))    # sorted by CSR convention

    out_pid = []
    out_cx = []
    out_cy = []

    # polygons whose boundary misses the grid entirely: covered or empty
    no_part = np.nonzero((n_partial == 0) & (nverts >= 3))[0]
    if len(no_part):
        centers = cell_centers(np.zeros(len(no_part)), np.zeros(len(no_part)),
                               n_order, extent)
        inside = geometry.points_in_polygon_rows(centers, no_part, verts,
                                                 nverts)
        if inside.any():
            allc = _all_grid_cells(n_order)
            for p in no_part[inside]:
                out_pid.append(np.full(len(allc), p, np.int64))
                out_cx.append(allc[:, 0])
                out_cy.append(allc[:, 1])

    # windows (clipped MBR) of the polygons that do have partial cells
    mbrs = geometry.polygon_mbrs(verts, nverts)
    has = np.nonzero(n_partial > 0)[0]
    if len(has):
        lo = cells_of_points(mbrs[has, :2], n_order, extent)
        hi = cells_of_points(mbrs[has, 2:], n_order, extent)
        wx0, wy0 = lo[:, 0], lo[:, 1]
        ncols = hi[:, 0] - lo[:, 0] + 1
        nrows = hi[:, 1] - lo[:, 1] + 1
        starts, ends, emask = geometry.polygon_edges(verts, nverts)

        # flat rows: (polygon, grid row) pairs
        row_poly = np.repeat(has, nrows)                       # [Rtot]
        roff = np.concatenate([[0], np.cumsum(nrows)])
        row_y = (np.arange(roff[-1]) - np.repeat(roff[:-1], nrows)
                 + np.repeat(wy0, nrows))
        row_ncols = np.repeat(ncols, nrows)
        row_wx0 = np.repeat(wx0, nrows)
        nv_row = nverts[row_poly]

        # bucket rows by (vertex class, column class), chunk by working set
        clsv = np.ceil(np.log2(np.maximum(nv_row, 1).astype(np.float64)))
        clsc = np.ceil(np.log2(np.maximum(row_ncols, 1).astype(np.float64)))
        bkey = (clsv * 64 + clsc).astype(np.int64)
        for kb in np.unique(bkey):
            sel_all = np.nonzero(bkey == kb)[0]
            Vb = int(nv_row[sel_all].max())
            Cb = int(row_ncols[sel_all].max())
            step = max(1, int(chunk_elems // max(1, Vb * Cb)))
            for i0 in range(0, len(sel_all), step):
                sel = sel_all[i0: i0 + step]
                p = row_poly[sel]
                yc = (extent.y0 + (row_y[sel] + 0.5) * h)[:, None]   # [m,1]
                x0e, y0e = starts[p, :Vb, 0], starts[p, :Vb, 1]
                x1e, y1e = ends[p, :Vb, 0], ends[p, :Vb, 1]
                cond = ((y0e <= yc) != (y1e <= yc)) & emask[p, :Vb]
                with np.errstate(divide="ignore", invalid="ignore"):
                    t = (yc - y0e) / np.where(y1e == y0e, 1.0, y1e - y0e)
                xint = np.where(cond, x0e + t * (x1e - x0e), np.inf)  # [m,Vb]
                cols = np.arange(Cb)[None, :]
                xcent = extent.x0 + (row_wx0[sel][:, None] + cols + 0.5) * h
                counts = np.sum(xint[:, None, :] < xcent[:, :, None], axis=2)
                inside = ((counts % 2) == 1) \
                    & (cols < row_ncols[sel][:, None])                # [m,Cb]
                m_idx, c_idx = np.nonzero(inside)
                pid = p[m_idx]
                cx = row_wx0[sel][m_idx] + c_idx
                cy = row_y[sel][m_idx]
                key = (pid.astype(np.uint64) * G2
                       + cx.astype(np.uint64) * np.uint64(G)
                       + cy.astype(np.uint64))
                # drop Partial cells: in-polygon but boundary-crossed
                j = np.searchsorted(pkeys, key)
                is_part = (j < len(pkeys)) & (pkeys[np.minimum(
                    j, max(len(pkeys) - 1, 0))] == key)
                keep = ~is_part
                out_pid.append(pid[keep])
                out_cx.append(cx[keep])
                out_cy.append(cy[keep])

    if not out_pid:
        return np.zeros(P + 1, np.int64), np.zeros((0, 2), np.int64)
    pid = np.concatenate(out_pid)
    cx = np.concatenate(out_cx)
    cy = np.concatenate(out_cy)
    key = (pid.astype(np.uint64) * G2 + cx.astype(np.uint64) * np.uint64(G)
           + cy.astype(np.uint64))
    order = np.argsort(key)
    pid = pid[order]
    cells = np.stack([cx[order], cy[order]], axis=1).astype(np.int64)
    off = np.zeros(P + 1, np.int64)
    off[1:] = np.cumsum(np.bincount(pid, minlength=P))
    return off, cells


def classify_window_oracle(
    verts: np.ndarray, n: int, n_order: int, extent: Extent = GLOBAL_EXTENT,
) -> dict[str, np.ndarray]:
    """Brute-force oracle: classify every MBR-window cell as partial/full.

    partial := boundary crosses the cell (any edge intersects the cell box or
    a polygon vertex lies inside it); full := not partial and center inside.
    Returns {'partial': [Kp,2], 'full': [Kf,2]} int64 cell coords.
    """
    v = np.asarray(verts, np.float64)[: int(n)]
    G = 1 << n_order
    h = extent.cell_size(n_order)
    mbr_lo = cells_of_points(v.min(axis=0)[None, :], n_order, extent)[0]
    mbr_hi = cells_of_points(v.max(axis=0)[None, :], n_order, extent)[0]
    xs = np.arange(mbr_lo[0], mbr_hi[0] + 1)
    ys = np.arange(mbr_lo[1], mbr_hi[1] + 1)
    CX, CY = np.meshgrid(xs, ys, indexing="ij")
    cx, cy = CX.ravel(), CY.ravel()
    # cell boxes
    bx0 = extent.x0 + cx * h; by0 = extent.y0 + cy * h
    bx1 = bx0 + h; by1 = by0 + h
    # vertex-in-cell
    vin = np.zeros(len(cx), dtype=bool)
    for p in v:
        vin |= (bx0 <= p[0]) & (p[0] < bx1) & (by0 <= p[1]) & (p[1] < by1)
    # edge-box intersection: any of the 4 box sides intersects the edge, or
    # edge endpoint inside box (covered by vin since endpoints are vertices).
    a0 = v; a1 = np.roll(v, -1, axis=0)
    partial = vin.copy()
    corners = np.stack([
        np.stack([bx0, by0], axis=1), np.stack([bx1, by0], axis=1),
        np.stack([bx1, by1], axis=1), np.stack([bx0, by1], axis=1),
    ], axis=1)  # [K,4,2]
    sides = np.stack([
        np.stack([corners[:, 0], corners[:, 1]], axis=1),
        np.stack([corners[:, 1], corners[:, 2]], axis=1),
        np.stack([corners[:, 2], corners[:, 3]], axis=1),
        np.stack([corners[:, 3], corners[:, 0]], axis=1),
    ], axis=1)  # [K,4,2,2]
    for e in range(len(v)):
        hit = geometry.segments_intersect(
            a0[e][None, None, :], a1[e][None, None, :],
            sides[:, :, 0, :], sides[:, :, 1, :])
        partial |= hit.any(axis=1)
    centers = cell_centers(cx, cy, n_order, extent)
    inside = geometry.points_in_polygon(centers, v)
    full = inside & ~partial
    sel_p = np.stack([cx[partial], cy[partial]], axis=1).astype(np.int64)
    sel_f = np.stack([cx[full], cy[full]], axis=1).astype(np.int64)
    return {"partial": sel_p, "full": sel_f}


def cells_to_hilbert(cells: np.ndarray, n_order: int) -> np.ndarray:
    """Sorted unique Hilbert ids (uint64) of cell coords [K,2]."""
    if len(cells) == 0:
        return np.zeros((0,), dtype=np.uint64)
    d = xy2d(n_order, cells[:, 0], cells[:, 1])
    return np.unique(d)
