"""Intervalization: from raster cells to A- and F-interval lists.

Three construction families, mirroring the paper:

* :func:`april_from_cells` — full-rasterization path (§6.1): take labeled
  Partial/Full cell sets (from scanline or flood fill) and merge consecutive
  Hilbert ids into intervals.
* :func:`onestep` with ``method='pips'`` / ``'neighbors'`` — the paper's
  one-step intervalization (Algorithm 3), faithful sequential host versions,
  with and without the neighbor-inheritance shortcut.
* :func:`onestep` with ``method='batched'`` — the TPU-adapted variant: gaps in
  the sorted Partial-cell sequence are classified Full/Empty by ONE vectorized
  PiP pass over all gap-head cells (see DESIGN.md §3). Identical output; on
  accelerators the batched PiP replaces the serial neighbor-inheritance.

Robustness note (beyond the paper): Algorithm 3 implicitly assumes the Hilbert
curve's origin cell lies *outside* every polygon — a polygon covering the
curve's first/last cells would otherwise get its leading/trailing interior
cells dropped. We additionally classify the *virtual* leading gap
``[0, first_partial)`` and trailing gap ``[last_partial+1, 4^N)`` (two extra
PiP tests), which makes all methods exact for corner-covering polygons too.

Intervals are half-open ``[start, end)`` over Hilbert ids, stored uint64 on
host (ids themselves fit uint32 for N <= 16).
"""
from __future__ import annotations

import bisect
import threading

import numpy as np

from . import geometry, rasterize
from .hilbert import d2xy, xy2d
from .rasterize import Extent, GLOBAL_EXTENT

__all__ = [
    "intervals_from_ids", "april_from_cells", "onestep", "onestep_multi",
    "ids_in_intervals", "runs_from_sorted", "PIP_COUNTER",
]

# PiP-test counter (validates the paper's OneStep(Neighbors) claim of
# 40-70% fewer PiP tests; reset/read by benchmarks/construction.py).
# Builds may run on partition threads (Partitioning.build_approx), so the
# increment must not lose updates.
PIP_COUNTER = {"count": 0}
_PIP_LOCK = threading.Lock()


def _count_pips(n: int) -> None:
    with _PIP_LOCK:
        PIP_COUNTER["count"] += n


def intervals_from_ids(ids: np.ndarray) -> np.ndarray:
    """Merge a sorted unique id array into [I,2] half-open intervals."""
    ids = np.asarray(ids, dtype=np.uint64)
    if len(ids) == 0:
        return np.zeros((0, 2), dtype=np.uint64)
    brk = np.nonzero(np.diff(ids) != 1)[0]
    starts = np.concatenate([ids[:1], ids[brk + 1]])
    ends = np.concatenate([ids[brk], ids[-1:]]) + np.uint64(1)
    return np.stack([starts, ends], axis=1)


def runs_from_sorted(pid: np.ndarray, ids: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Maximal consecutive-id runs of a flat (polygon, id) sequence sorted
    by (pid, id): returns (run_start, run_end, run_poly) with half-open
    ends. Shared by one-step intervalization and RI store packing."""
    if len(ids) == 0:
        z = np.zeros(0, np.uint64)
        return z, z.copy(), np.zeros(0, np.int64)
    newpoly = np.r_[True, pid[1:] != pid[:-1]]
    brk = newpoly | np.r_[True, ids[1:] != ids[:-1] + np.uint64(1)]
    run_start = ids[brk]
    run_end = ids[np.r_[brk[1:], True]] + np.uint64(1)
    return run_start, run_end, pid[brk]


def ids_in_intervals(intervals: np.ndarray) -> np.ndarray:
    """Expand [I,2] intervals back to a sorted id array (test helper)."""
    if len(intervals) == 0:
        return np.zeros((0,), dtype=np.uint64)
    out = [np.arange(s, e, dtype=np.uint64) for s, e in intervals]
    return np.concatenate(out) if out else np.zeros((0,), dtype=np.uint64)


def april_from_cells(partial_cells: np.ndarray, full_cells: np.ndarray,
                     n_order: int) -> tuple[np.ndarray, np.ndarray]:
    """(A-list, F-list) from labeled cell-coordinate sets (full-raster path)."""
    p_ids = rasterize.cells_to_hilbert(np.asarray(partial_cells, np.int64), n_order)
    f_ids = rasterize.cells_to_hilbert(np.asarray(full_cells, np.int64), n_order)
    a_ids = np.union1d(p_ids, f_ids)
    return intervals_from_ids(a_ids), intervals_from_ids(f_ids)


def onestep(
    verts: np.ndarray, n: int, n_order: int,
    extent: Extent = GLOBAL_EXTENT, method: str = "batched",
) -> tuple[np.ndarray, np.ndarray]:
    """One-step intervalization (paper Alg. 3 + TPU-adapted batched variant).

    Returns (A-list [Ia,2], F-list [If,2]) uint64 half-open intervals.
    """
    v = np.asarray(verts, np.float64)
    cells = rasterize.dda_partial_cells(v, n, n_order, extent)
    p = rasterize.cells_to_hilbert(cells, n_order)
    if len(p) == 0:
        # The boundary misses the grid entirely: the single virtual gap
        # [0, 4^N) is the whole raster area — one PiP decides Full/Empty
        # (a §5.2 partition fully covered by a large polygon).
        n_cells_total = np.uint64(1) << np.uint64(2 * n_order)
        if int(n) >= 3 and bool(_classify_gaps_batched(
                v, n, n_order, extent, np.array([0], np.uint64))[0]):
            whole = np.array([[0, n_cells_total]], np.uint64)
            return whole, whole.copy()
        return np.zeros((0, 2), np.uint64), np.zeros((0, 2), np.uint64)

    # Partial runs and the R+1 gaps around them (incl. virtual lead/trail).
    brk = np.nonzero(np.diff(p) != 1)[0]
    run_start = np.concatenate([p[:1], p[brk + 1]])            # [R]
    run_end = np.concatenate([p[brk], p[-1:]]) + np.uint64(1)  # [R]
    n_cells_total = np.uint64(1) << np.uint64(2 * n_order)
    gap_start = np.concatenate([[np.uint64(0)], run_end])      # [R+1]
    gap_end = np.concatenate([run_start, [n_cells_total]])     # [R+1]
    nonzero = gap_end > gap_start                              # [R+1]

    gap_full = np.zeros(len(gap_start), dtype=bool)
    idx = np.nonzero(nonzero)[0]
    if len(idx):
        if method == "batched":
            gap_full[idx] = _classify_gaps_batched(
                v, n, n_order, extent, gap_start[idx])
        elif method == "pips":
            gap_full[idx] = _classify_gaps_pips(
                v, n, n_order, extent, gap_start[idx])
        elif method == "neighbors":
            gap_full[idx] = _classify_gaps_neighbors(
                v, n, n_order, extent, p, gap_start[idx], gap_end[idx])
        else:
            raise ValueError(f"unknown method {method!r}")

    return _assemble(run_start, run_end, gap_start, gap_end, gap_full)


def _assemble(run_start, run_end, gap_start, gap_end, gap_full):
    """Interleave gap/run blocks: G0 R0 G1 R1 ... R_{R-1} G_R; A-intervals
    break exactly at non-Full gaps; F-intervals are the Full gaps."""
    R = len(run_start)
    f_sel = gap_full & (gap_end > gap_start)
    f_list = np.stack([gap_start[f_sel], gap_end[f_sel]], axis=1).astype(np.uint64)

    # Block sequence starts/ends + A-membership flags, interleaved.
    n_blocks = 2 * R + 1
    b_start = np.empty(n_blocks, dtype=np.uint64)
    b_end = np.empty(n_blocks, dtype=np.uint64)
    b_in_a = np.empty(n_blocks, dtype=bool)
    b_start[0::2] = gap_start; b_end[0::2] = gap_end; b_in_a[0::2] = f_sel
    b_start[1::2] = run_start; b_end[1::2] = run_end; b_in_a[1::2] = True

    # Merge maximal runs of consecutive in-A blocks (zero-length gaps that are
    # not Full break nothing only if marked in_a; they are not, but they are
    # also zero-length — exclude them so they don't split runs).
    zero_len = b_end == b_start
    keep = ~zero_len
    bs, be, ba = b_start[keep], b_end[keep], b_in_a[keep]
    if len(bs) == 0:
        return np.zeros((0, 2), np.uint64), f_list
    # contiguity: next block starts where previous ends AND both in A
    joined = (bs[1:] == be[:-1]) & ba[1:] & ba[:-1]
    seg_break = ~joined
    # A-interval starts: in-A block whose predecessor isn't joined-in-A
    starts_mask = ba & np.concatenate([[True], seg_break])
    ends_mask = ba & np.concatenate([seg_break, [True]])
    a_list = np.stack([bs[starts_mask], be[ends_mask]], axis=1).astype(np.uint64)
    return a_list, f_list


def onestep_multi(
    verts: np.ndarray, nverts: np.ndarray, n_order: int,
    extent: Extent = GLOBAL_EXTENT, backend: str = "numpy",
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One-step intervalization of MANY polygons in one pass (DESIGN.md §6).

    The dataset-level analogue of :func:`onestep`: one multi-polygon DDA
    traversal, then ONE vectorized PiP pass over the gap heads of *all*
    polygons (including each polygon's virtual lead/trail gaps). Returns CSR
    ``(a_off [P+1], a_ints [sum_Ia,2], f_off [P+1], f_ints [sum_If,2])``
    interval-identical to per-polygon ``onestep(method='batched')`` calls.
    ``backend``: 'numpy' or 'jnp' (device PiP pass).
    """
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    P = len(nverts)
    n_cells_total = np.uint64(1) << np.uint64(2 * n_order)

    p_off, cells = rasterize.dda_partial_cells_multi(
        verts, nverts, n_order, extent)
    n_partial = np.diff(p_off)
    pid = np.repeat(np.arange(P), n_partial)
    ids = xy2d(n_order, cells[:, 0], cells[:, 1])
    order = np.argsort(pid.astype(np.uint64) * n_cells_total + ids)
    ids = ids[order]                       # sorted Hilbert ids per polygon

    # Partial runs: breaks at id jumps or polygon boundaries.
    run_start, run_end, run_poly = runs_from_sorted(pid, ids)
    roff = np.zeros(P + 1, np.int64)
    roff[1:] = np.cumsum(np.bincount(run_poly, minlength=P))

    # R_p + 1 gaps per polygon, interleaved with its runs (virtual lead and
    # trail gaps included — a polygon with no Partial cells keeps its single
    # whole-grid gap, which handles extent-covering polygons).
    goff = roff + np.arange(P + 1)
    total_g = goff[-1]
    gp = np.repeat(np.arange(P), np.diff(goff))
    gs = np.empty(total_g, np.uint64)
    ge = np.empty(total_g, np.uint64)
    first = np.zeros(total_g, bool)
    first[goff[:-1]] = True
    last = np.zeros(total_g, bool)
    last[goff[1:] - 1] = True
    gs[first] = np.uint64(0)
    gs[~first] = run_end
    ge[last] = n_cells_total
    ge[~last] = run_start

    gap_full = np.zeros(total_g, bool)
    idx = np.nonzero((ge > gs) & (nverts[gp] >= 3))[0]
    if len(idx):
        hx, hy = d2xy(n_order, gs[idx])
        centers = rasterize.cell_centers(hx, hy, n_order, extent)
        _count_pips(len(idx))
        pip = (geometry.points_in_polygon_rows_jnp if backend == "jnp"
               else geometry.points_in_polygon_rows)
        gap_full[idx] = pip(centers, gp[idx], verts, nverts)

    a_chunks, f_chunks = [], []
    a_off = np.zeros(P + 1, np.int64)
    f_off = np.zeros(P + 1, np.int64)
    for p in range(P):
        r0, r1 = roff[p], roff[p + 1]
        g0, g1 = goff[p], goff[p + 1]
        a, f = _assemble(run_start[r0:r1], run_end[r0:r1],
                         gs[g0:g1], ge[g0:g1], gap_full[g0:g1])
        a_chunks.append(a)
        f_chunks.append(f)
        a_off[p + 1] = a_off[p] + len(a)
        f_off[p + 1] = f_off[p] + len(f)
    cat = lambda ch: (np.concatenate(ch, axis=0) if ch
                      else np.zeros((0, 2), np.uint64))
    return a_off, cat(a_chunks), f_off, cat(f_chunks)


def _gap_head_centers(gap_start, n_order, extent):
    hx, hy = d2xy(n_order, np.asarray(gap_start, np.uint64))
    return rasterize.cell_centers(hx, hy, n_order, extent)


def _classify_gaps_batched(v, n, n_order, extent, gap_start) -> np.ndarray:
    """ALL gap heads tested in one vectorized PiP pass (TPU-adapted)."""
    centers = _gap_head_centers(gap_start, n_order, extent)
    _count_pips(len(gap_start))
    return geometry.points_in_polygon(centers, v[: int(n)])


def _classify_gaps_pips(v, n, n_order, extent, gap_start) -> np.ndarray:
    """One PiP per gap, sequential — OneStep (PiPs) of Table 11."""
    centers = _gap_head_centers(gap_start, n_order, extent)
    out = np.zeros(len(gap_start), dtype=bool)
    poly = v[: int(n)]
    _count_pips(len(gap_start))
    for i in range(len(gap_start)):          # deliberate sequential loop
        out[i] = bool(geometry.points_in_polygon(centers[i: i + 1], poly)[0])
    return out


def _classify_gaps_neighbors(v, n, n_order, extent, p, gap_start, gap_end) -> np.ndarray:
    """Faithful Alg. 3 CheckNeighbors: inspect 4-adjacent cells of the gap
    head with SMALLER Hilbert id; inherit Full/Empty from a resolved gap, else
    fall back to one PiP test. Sequential by construction."""
    poly = v[: int(n)]
    G = 1 << n_order
    n_gaps = len(gap_start)
    out = np.zeros(n_gaps, dtype=bool)
    f_starts: list[int] = []; f_ends: list[int] = []
    e_starts: list[int] = []; e_ends: list[int] = []
    p_list = p.tolist()

    def in_intervals(idv: int, starts: list[int], ends: list[int]) -> bool:
        k = bisect.bisect_right(starts, idv) - 1
        return k >= 0 and idv < ends[k]

    for g in range(n_gaps):
        head = int(gap_start[g])
        hx, hy = d2xy(n_order, np.array([head], dtype=np.uint64))
        hx, hy = int(hx[0]), int(hy[0])
        decided = None
        for nx_, ny_ in ((hx + 1, hy), (hx - 1, hy), (hx, hy + 1), (hx, hy - 1)):
            if not (0 <= nx_ < G and 0 <= ny_ < G):
                continue
            nid = int(xy2d(n_order, np.array([nx_]), np.array([ny_]))[0])
            if nid >= head:
                continue  # not yet visited in Hilbert order
            k = bisect.bisect_left(p_list, nid)
            if k < len(p_list) and p_list[k] == nid:
                continue  # partial neighbor is uninformative
            if in_intervals(nid, f_starts, f_ends):
                decided = True
                break
            if in_intervals(nid, e_starts, e_ends):
                decided = False
                break
        if decided is None:
            c = rasterize.cell_centers(np.array([hx]), np.array([hy]), n_order, extent)
            _count_pips(1)
            decided = bool(geometry.points_in_polygon(c, poly)[0])
        out[g] = decided
        if decided:
            f_starts.append(int(gap_start[g])); f_ends.append(int(gap_end[g]))
        else:
            e_starts.append(int(gap_start[g])); e_ends.append(int(gap_end[g]))
    return out
