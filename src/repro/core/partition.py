"""Space partitioning for APRIL (§5.2; tiled scale-out in DESIGN.md §14).

The map is divided into ``parts_per_dim ** 2`` disjoint tiles. Every dataset
(layer) shares the same partitioning. A partition's *raster area* is the
square hull of the MBRs of all objects intersecting the tile (it may exceed
the tile). Each partition gets its own order-N grid + Hilbert curve, raising
the effective global resolution without widening interval integers.

Duplicate-result avoidance follows [13, 49]: a candidate pair is processed
only in the partition containing the *reference point* — the bottom-left
corner of the intersection of the two MBRs. For the uniform grid that is
:func:`reference_partitions` (closed-form cell arithmetic); the §14
skew-split partitioner produces a *non-uniform* disjoint rect cover, whose
batched ownership rule is :func:`owner_tiles`.

Partitions are the distribution unit for the multi-device join
(``spatial/distributed.py``) and the packing unit of the out-of-core tiled
driver (``spatial/scaleout.py``): :func:`quadrants` splits a hot
partition's tile 2x2, :func:`tile_hits` re-assigns object MBRs to the
children, and :func:`square_extent` recomputes each child's raster area.

Batching contract: every public function here is MBR-array-batched — it
takes ``[N, 4]`` float64 boxes (or a list of tile rects) and returns
vectorized masks/indices; per-object Python loops appear nowhere on the
assignment path.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from .april import AprilStore, build_april
from .rasterize import Extent

__all__ = ["Partitioning", "partition_space", "reference_partition",
           "reference_partitions", "quadrants", "tile_hits", "owner_tiles",
           "square_extent"]


def _parallel_map(fn, items, parallel: bool, max_workers: int | None = None):
    """Order-preserving map, threaded when ``parallel``. Builds are pure
    numpy (no shared mutable state), so threads are safe and the heavy
    vectorized passes release the GIL."""
    if not parallel or len(items) <= 1:
        return [fn(x) for x in items]
    workers = max_workers or min(len(items), os.cpu_count() or 1)
    with ThreadPoolExecutor(max_workers=workers) as ex:
        return list(ex.map(fn, items))


@dataclass
class Partition:
    tile: tuple[float, float, float, float]   # xmin, ymin, xmax, ymax
    extent: Extent                            # square raster area
    obj_idx: dict[str, np.ndarray]            # dataset name -> object indices


@dataclass
class Partitioning:
    parts_per_dim: int
    partitions: list[Partition]

    def __len__(self) -> int:
        return len(self.partitions)

    def build_april(self, dataset, n_order: int, method: str = "batched",
                    parallel: bool = True, max_workers: int | None = None,
                    ) -> list[AprilStore | None]:
        """Per-partition APRIL stores for ``dataset`` (None if empty there).
        Partitions build in parallel (threads) unless ``parallel=False``."""
        def one(part):
            idx = part.obj_idx.get(dataset.name, np.zeros(0, np.int64))
            if len(idx) == 0:
                return None
            return build_april(_subset(dataset, idx), n_order, part.extent,
                               method)
        return _parallel_map(one, self.partitions, parallel, max_workers)

    def build_approx(self, filt, dataset, n_order: int, side: str = "r",
                     parallel: bool = True, max_workers: int | None = None,
                     **build_opts) -> list:
        """Per-partition approximations through an
        :class:`~repro.spatial.filters.IntermediateFilter` (None where the
        dataset has no objects). Generalizes :meth:`build_april` to every
        registered filter — each partition gets its own raster extent, and
        partitions build in parallel (threads) unless ``parallel=False``.
        The 'jnp' build backend forces sequential execution (JAX tracing is
        not thread-safe)."""
        if build_opts.get("build_backend") == "jnp":
            parallel = False
        def one(part):
            idx = part.obj_idx.get(dataset.name, np.zeros(0, np.int64))
            if len(idx) == 0:
                return None
            return filt.build(_subset(dataset, idx), n_order=n_order,
                              extent=part.extent, side=side, **build_opts)
        return _parallel_map(one, self.partitions, parallel, max_workers)


def _subset(dataset, idx):
    from ..datagen.synthetic import PolygonDataset
    return PolygonDataset(
        name=dataset.name, verts=dataset.verts[idx], nverts=dataset.nverts[idx])


def partition_space(datasets, parts_per_dim: int) -> Partitioning:
    """Partition [0,1]^2 into a parts_per_dim x parts_per_dim tiling and
    assign every object of every dataset to each tile its MBR intersects."""
    k = parts_per_dim
    tiles = []
    for ty in range(k):
        for tx in range(k):
            tiles.append((tx / k, ty / k, (tx + 1) / k, (ty + 1) / k))

    parts = []
    for tile in tiles:
        xmin, ymin, xmax, ymax = tile
        obj_idx = {}
        lo_x, lo_y, hi_x, hi_y = np.inf, np.inf, -np.inf, -np.inf
        any_obj = False
        for ds in datasets:
            m = ds.mbrs
            hit = ((m[:, 0] < xmax) & (m[:, 2] > xmin)
                   & (m[:, 1] < ymax) & (m[:, 3] > ymin))
            idx = np.nonzero(hit)[0].astype(np.int64)
            obj_idx[ds.name] = idx
            if len(idx):
                any_obj = True
                lo_x = min(lo_x, float(m[idx, 0].min()))
                lo_y = min(lo_y, float(m[idx, 1].min()))
                hi_x = max(hi_x, float(m[idx, 2].max()))
                hi_y = max(hi_y, float(m[idx, 3].max()))
        if not any_obj:
            lo_x, lo_y, hi_x, hi_y = tile
        side = max(hi_x - lo_x, hi_y - lo_y) * (1 + 1e-9)
        parts.append(Partition(
            tile=tile, extent=Extent(lo_x, lo_y, side), obj_idx=obj_idx))
    return Partitioning(parts_per_dim=k, partitions=parts)


def quadrants(tile: tuple[float, float, float, float]
              ) -> list[tuple[float, float, float, float]]:
    """Split a tile rect into its 2x2 quadrant rects (the §14 skew-split
    step). Children are listed bottom-left, bottom-right, top-left,
    top-right — a fixed order, so repeated splits are deterministic."""
    xmin, ymin, xmax, ymax = tile
    xm, ym = (xmin + xmax) / 2.0, (ymin + ymax) / 2.0
    return [(xmin, ymin, xm, ym), (xm, ymin, xmax, ym),
            (xmin, ym, xm, ymax), (xm, ym, xmax, ymax)]


def tile_hits(mbrs: np.ndarray,
              tile: tuple[float, float, float, float]) -> np.ndarray:
    """Batched open-interval intersection mask of ``[N, 4]`` MBRs against a
    tile rect — the assignment rule of :func:`partition_space`, exposed for
    the streaming partitioner (objects replicate into every tile their MBR
    intersects; the reference-point rule dedups results)."""
    m = np.asarray(mbrs, np.float64).reshape(-1, 4)
    xmin, ymin, xmax, ymax = tile
    return ((m[:, 0] < xmax) & (m[:, 2] > xmin)
            & (m[:, 1] < ymax) & (m[:, 3] > ymin))


def square_extent(mbrs: np.ndarray,
                  tile: tuple[float, float, float, float]) -> Extent:
    """Square raster hull of a partition's member MBRs (§5.2 raster area;
    the empty partition falls back to its tile rect). Batched: one
    min/max reduction over the ``[N, 4]`` boxes."""
    m = np.asarray(mbrs, np.float64).reshape(-1, 4)
    if len(m) == 0:
        lo_x, lo_y, hi_x, hi_y = tile
    else:
        lo_x, lo_y = float(m[:, 0].min()), float(m[:, 1].min())
        hi_x, hi_y = float(m[:, 2].max()), float(m[:, 3].max())
    side = max(hi_x - lo_x, hi_y - lo_y) * (1 + 1e-9)
    return Extent(lo_x, lo_y, side)


def owner_tiles(tiles: np.ndarray, mbrs_r: np.ndarray,
                mbrs_s: np.ndarray) -> np.ndarray:
    """Batched reference-point ownership over an arbitrary *disjoint* rect
    cover (the §14 generalization of :func:`reference_partitions` to
    skew-split tilings).

    ``tiles`` is ``[T, 4]`` (xmin, ymin, xmax, ymax) rects that tile the
    map disjointly; a pair belongs to the tile containing its reference
    point — half-open ``[min, max)`` membership, with the tiles touching
    the map's top/right edge closed there so boundary points stay owned.
    Returns the owning tile index per pair (``-1`` if the cover has a
    hole, which the tiled driver treats as a hard error).
    """
    tiles = np.asarray(tiles, np.float64).reshape(-1, 4)
    mbrs_r = np.asarray(mbrs_r, np.float64).reshape(-1, 4)
    mbrs_s = np.asarray(mbrs_s, np.float64).reshape(-1, 4)
    rx = np.maximum(mbrs_r[:, 0], mbrs_s[:, 0])
    ry = np.maximum(mbrs_r[:, 1], mbrs_s[:, 1])
    hi_x = tiles[:, 2].max()
    hi_y = tiles[:, 3].max()
    own = np.full(len(rx), -1, np.int64)
    for t in range(len(tiles)):
        xmin, ymin, xmax, ymax = tiles[t]
        in_x = (rx >= xmin) & ((rx < xmax) | (xmax >= hi_x) & (rx <= xmax))
        in_y = (ry >= ymin) & ((ry < ymax) | (ymax >= hi_y) & (ry <= ymax))
        own[in_x & in_y & (own < 0)] = t
    return own


def reference_partition(parts_per_dim: int, mbr_r: np.ndarray, mbr_s: np.ndarray) -> int:
    """Index of the partition owning the candidate pair (reference-point rule
    on the common MBR's bottom-left corner)."""
    return int(reference_partitions(
        parts_per_dim, np.asarray(mbr_r, np.float64)[None],
        np.asarray(mbr_s, np.float64)[None])[0])


def reference_partitions(parts_per_dim: int, mbrs_r: np.ndarray,
                         mbrs_s: np.ndarray) -> np.ndarray:
    """Batched reference-point ownership for paired [N,4] MBR arrays."""
    k = parts_per_dim
    rx = np.maximum(mbrs_r[:, 0], mbrs_s[:, 0])
    ry = np.maximum(mbrs_r[:, 1], mbrs_s[:, 1])
    tx = np.minimum((rx * k).astype(np.int64), k - 1)
    ty = np.minimum((ry * k).astype(np.int64), k - 1)
    return ty * k + tx
