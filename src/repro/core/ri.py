"""RI — Raster Intervals with 3-bit cell-type codes (paper §3).

Each object is a sorted list of Hilbert intervals; each interval carries a
bitstring concatenating the 3-bit codes (Table 2) of its cells:

              input R    input S
    full       011        101
    strong     101        011
    weak       100        010

Properties used by the join: (i) non-zero AND of two cell codes (one R-coded,
one S-coded) certifies intersection in that cell; (ii) XOR with mask 110
converts an R code into the S code of the same class, allowing one
precomputed dataset to take either side of a join.

Host representation: per-polygon flat *bit* arrays (np.uint8 0/1) plus
per-interval bit offsets; :func:`packed_codes` yields the byte-packed form
for storage accounting and the Pallas `ri_and` kernel operates on uint32
words. Construction requires Weak/Strong labeling, i.e. exact coverage
fractions — the expensive path the paper measures in Table 11.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import rasterize
from .intervalize import intervals_from_ids, runs_from_sorted
from .join import INDECISIVE, TRUE_HIT, TRUE_NEG
from .rasterize import Extent, GLOBAL_EXTENT

__all__ = [
    "RIStore", "build_ri", "build_ri_lines", "ri_verdict_pair",
    "ri_within_verdict_pair", "ri_filter_batch", "ri_within_batch",
    "CODE_R", "CODE_S", "XOR_MASK", "FULL", "STRONG", "WEAK",
]

FULL, STRONG, WEAK = 0, 1, 2
CODE_R = {FULL: (0, 1, 1), STRONG: (1, 0, 1), WEAK: (1, 0, 0)}
CODE_S = {FULL: (1, 0, 1), STRONG: (0, 1, 1), WEAK: (0, 1, 0)}
XOR_MASK = (1, 1, 0)


@dataclass
class RIStore:
    """RI approximations for one dataset (single encoding, R or S)."""
    n_order: int
    extent: Extent
    encoding: str              # 'R' or 'S'
    off: np.ndarray            # [P+1] interval offsets
    ints: np.ndarray           # [sum_I, 2] uint64
    bit_off: np.ndarray        # [sum_I + 1] int64: bit offset of each interval
    bits: np.ndarray           # [total_bits] uint8 in {0,1}

    def __len__(self) -> int:
        return len(self.off) - 1

    def intervals(self, i: int) -> np.ndarray:
        return self.ints[self.off[i]: self.off[i + 1]]

    def interval_bits(self, i: int, k: int) -> np.ndarray:
        """Bit code of the k-th interval of polygon i."""
        g = self.off[i] + k
        return self.bits[self.bit_off[g]: self.bit_off[g + 1]]

    def size_bytes(self) -> int:
        """Endpoints as uint32 pairs + ceil(bits/8) code bytes (paper §3.2).
        Vectorized — called per build for stats, so it must not walk every
        interval in Python."""
        code_bytes = int(((np.diff(self.bit_off) + 7) // 8).sum())
        return 4 * 2 * len(self.ints) + code_bytes + 8 * len(self.off)

    def packed_codes(self, i: int, k: int) -> np.ndarray:
        return np.packbits(self.interval_bits(i, k))


def _classify_cells(verts, n, n_order, extent):
    """Cell ids + classes for one polygon: DDA partials get Weak/Strong via
    coverage fraction; interior cells are Full."""
    partial = rasterize.dda_partial_cells(verts, n, n_order, extent)
    full = rasterize.scanline_full_cells(verts, n, partial, n_order, extent)
    p_ids = rasterize.cells_to_hilbert(partial, n_order)
    f_ids = rasterize.cells_to_hilbert(full, n_order)
    # coverage only for partial cells (full are 1.0 by construction)
    # recover cell coords in id order for fraction computation
    if len(partial):
        order = np.argsort(rasterize.xy2d(n_order, partial[:, 0], partial[:, 1]))
        pcells = partial[order]
        frac = rasterize.coverage_fractions(verts, n, pcells, n_order, extent)
        p_cls = np.where(frac > 0.5, STRONG, WEAK).astype(np.int8)
    else:
        p_cls = np.zeros((0,), np.int8)
    ids = np.concatenate([p_ids, f_ids])
    cls = np.concatenate([p_cls, np.full(len(f_ids), FULL, np.int8)])
    order = np.argsort(ids)
    return ids[order], cls[order]


# class id -> 3-bit code row, per encoding (vectorized bit generation)
_CODE_LUT = {
    enc: np.asarray([tab[FULL], tab[STRONG], tab[WEAK]], np.uint8)
    for enc, tab in (("R", CODE_R), ("S", CODE_S))
}


def _pack_store(objects, n_order: int, extent: Extent, encoding: str) -> RIStore:
    """Assemble an RIStore from per-object (sorted ids, classes) pairs."""
    lut = _CODE_LUT[encoding]
    off = [0]
    bit_off_chunks = [np.zeros(1, np.int64)]
    int_chunks = []; bit_chunks = []
    base = 0
    for ids, cls in objects:
        ints = intervals_from_ids(ids)
        int_chunks.append(ints)
        off.append(off[-1] + len(ints))
        # concatenated 3-bit codes in Hilbert order; per-interval offsets are
        # the running 3x cell counts (cells tile the intervals consecutively)
        lens = 3 * (ints[:, 1] - ints[:, 0]).astype(np.int64)
        bit_off_chunks.append(base + np.cumsum(lens))
        base += int(lens.sum())
        bit_chunks.append(lut[cls].reshape(-1))
    ints = (np.concatenate(int_chunks, axis=0)
            if int_chunks else np.zeros((0, 2), np.uint64))
    bits = (np.concatenate(bit_chunks) if bit_chunks
            else np.zeros((0,), np.uint8))
    return RIStore(
        n_order=n_order, extent=extent, encoding=encoding,
        off=np.asarray(off, np.int64), ints=ints,
        bit_off=np.concatenate(bit_off_chunks), bits=bits,
    )


def _sort_ids_by_poly(pid, ids, cls, n_order, P):
    """Sort flat (polygon, id) cell rows into per-polygon Hilbert order;
    returns (off [P+1], ids, cls)."""
    n_cells_total = np.uint64(1) << np.uint64(2 * n_order)
    order = np.argsort(pid.astype(np.uint64) * n_cells_total + ids)
    off = np.zeros(P + 1, np.int64)
    off[1:] = np.cumsum(np.bincount(pid, minlength=P))
    return off, ids[order], cls[order]


def _classify_cells_multi(verts, nverts, n_order, extent, backend="numpy"):
    """Dataset-level :func:`_classify_cells`: one multi-polygon DDA + one
    scanline pass + one padded coverage pass (DESIGN.md §6). Returns
    (off [P+1], ids, cls) flat and per-polygon Hilbert-sorted."""
    P = len(nverts)
    p_off, p_cells = rasterize.dda_partial_cells_multi(
        verts, nverts, n_order, extent)
    f_off, f_cells = rasterize.scanline_full_cells_multi(
        verts, nverts, p_off, p_cells, n_order, extent)
    pid_p = np.repeat(np.arange(P), np.diff(p_off))
    pid_f = np.repeat(np.arange(P), np.diff(f_off))
    frac = rasterize.coverage_fractions_multi(
        verts, nverts, pid_p, p_cells, n_order, extent, backend=backend)
    p_cls = np.where(frac > 0.5, STRONG, WEAK).astype(np.int8)
    ids = np.concatenate([
        rasterize.xy2d(n_order, p_cells[:, 0], p_cells[:, 1]),
        rasterize.xy2d(n_order, f_cells[:, 0], f_cells[:, 1])])
    cls = np.concatenate([p_cls, np.full(len(pid_f), FULL, np.int8)])
    pid = np.concatenate([pid_p, pid_f])
    return _sort_ids_by_poly(pid, ids, cls, n_order, P)


def _pack_store_flat(off, ids, cls, n_order, extent, encoding) -> RIStore:
    """Vectorized :func:`_pack_store` over flat per-polygon-sorted cells."""
    P = len(off) - 1
    pid = np.repeat(np.arange(P), np.diff(off))
    starts, ends, int_poly = runs_from_sorted(pid, ids)
    store_off = np.zeros(P + 1, np.int64)
    store_off[1:] = np.cumsum(np.bincount(int_poly, minlength=P))
    lens = 3 * (ends - starts).astype(np.int64)
    bit_off = np.zeros(len(starts) + 1, np.int64)
    bit_off[1:] = np.cumsum(lens)
    return RIStore(
        n_order=n_order, extent=extent, encoding=encoding,
        off=store_off, ints=np.stack([starts, ends], axis=1).astype(np.uint64),
        bit_off=bit_off, bits=_CODE_LUT[encoding][cls].reshape(-1),
    )


def build_ri(
    dataset, n_order: int, extent: Extent = GLOBAL_EXTENT, encoding: str = "R",
    backend: str = "numpy",
) -> RIStore:
    """Build the RI store. ``backend``: 'numpy' | 'jnp' run the batched
    dataset-level construction (DESIGN.md §6); 'sequential' is the faithful
    per-polygon reference the batched path is store-identical to."""
    if backend == "sequential":
        return _pack_store(
            (_classify_cells(dataset.verts[i], int(dataset.nverts[i]), n_order,
                             extent)
             for i in range(len(dataset))),
            n_order, extent, encoding)
    off, ids, cls = _classify_cells_multi(
        dataset.verts, dataset.nverts, n_order, extent, backend=backend)
    return _pack_store_flat(off, ids, cls, n_order, extent, encoding)


def build_ri_lines(
    dataset, n_order: int, extent: Extent = GLOBAL_EXTENT, encoding: str = "R",
    backend: str = "numpy",
) -> RIStore:
    """RI store for open linestrings: every touched cell is Weak (a line has
    no interior, so it can never certify a hit from its own side — but Weak
    against a Full polygon cell still ANDs non-zero, §3.3)."""
    if backend == "sequential":
        def gen():
            for i in range(len(dataset)):
                cells = rasterize.dda_partial_cells(
                    dataset.verts[i], int(dataset.nverts[i]), n_order, extent,
                    closed=False)
                ids = np.sort(rasterize.cells_to_hilbert(cells, n_order))
                yield ids, np.full(len(ids), WEAK, np.int8)
        return _pack_store(gen(), n_order, extent, encoding)
    off, cells = rasterize.dda_partial_cells_multi(
        dataset.verts, dataset.nverts, n_order, extent, closed=False)
    pid = np.repeat(np.arange(len(dataset)), np.diff(off))
    ids = rasterize.xy2d(n_order, cells[:, 0], cells[:, 1])
    cls = np.full(len(ids), WEAK, np.int8)
    return _pack_store_flat(
        *_sort_ids_by_poly(pid, ids, cls, n_order, len(dataset)),
        n_order, extent, encoding)


def _aligned_and(xbits, xs, ybits, ys, lo, hi, xor_y: bool) -> bool:
    """ALIGNEDAND: AND the 3-bit codes of cells [lo, hi) taken from both
    intervals' bitstrings; optionally XOR-converts y's encoding first."""
    xo = 3 * int(lo - xs)
    yo = 3 * int(lo - ys)
    ln = 3 * int(hi - lo)
    xf = xbits[xo: xo + ln]
    yf = ybits[yo: yo + ln].copy()
    if xor_y:
        yf ^= np.tile(np.asarray(XOR_MASK, np.uint8), int(hi - lo))
    return bool(np.any(xf & yf))


def ri_within_verdict_pair(store_x: RIStore, i: int, store_y: RIStore,
                           j: int) -> int:
    """RI within-join filter (§3.4): is x within y?

    TRUE_NEG as soon as (i) an interval of x is not fully covered by y's
    intervals (an x-cell is empty in y), or (ii) some shared cell is Full in
    x but not Full in y, or Strong in x and Weak in y (x's area in that cell
    must exceed y's). TRUE_HIT iff every x-cell is Full in y. Else
    indecisive. Operates on the decoded 3-bit classes.
    """
    X = store_x.intervals(i)
    Y = store_y.intervals(j)
    if len(X) == 0:
        return TRUE_HIT
    dec_x = _DECODE[store_x.encoding]
    dec_y = _DECODE[store_y.encoding]
    all_full_in_y = True
    b = 0
    for a in range(len(X)):
        xs, xe = X[a]
        cell = xs
        while cell < xe:
            # advance y's cursor to the interval that could contain `cell`
            while b < len(Y) and Y[b][1] <= cell:
                b += 1
            if b >= len(Y) or cell < Y[b][0]:
                return TRUE_NEG          # x-cell empty in y
            ys, ye = Y[b]
            hi = min(xe, ye)
            # classes over the shared run [cell, hi)
            for c in range(int(cell), int(hi)):
                cx = _cell_class(store_x, i, a, c - int(xs), dec_x)
                cy = _cell_class_at(store_y, j, b, c - int(ys), dec_y)
                if (cx == FULL and cy != FULL) or (cx == STRONG and cy == WEAK):
                    return TRUE_NEG
                if cy != FULL:
                    all_full_in_y = False
            cell = hi
    return TRUE_HIT if all_full_in_y else INDECISIVE


# class decoding tables: 3-bit tuple -> class id, per encoding
_DECODE = {
    "R": {v: k for k, v in CODE_R.items()},
    "S": {v: k for k, v in CODE_S.items()},
}


def _cell_class(store: RIStore, i: int, k: int, off: int, table) -> int:
    bits = store.interval_bits(i, k)[3 * off: 3 * off + 3]
    return table[tuple(int(b) for b in bits)]


def _cell_class_at(store: RIStore, j: int, k: int, off: int, table) -> int:
    return _cell_class(store, j, k, off, table)


# ---------------------------------------------------------------------------
# Batched RI filtering (DESIGN.md §3): fragment extraction is a vectorized
# CSR sweep; the ALIGNEDAND over all fragments runs either as a numpy bit
# pass or through the Pallas `kernels/ri_and` word kernel.
# ---------------------------------------------------------------------------

_U64_MAX = np.uint64(np.iinfo(np.uint64).max)

# 3-bit code (b0*4 + b1*2 + b2) -> class id, per encoding; -1 = invalid
_DECODE_ARR = {}
for _enc, _tab in (("R", CODE_R), ("S", CODE_S)):
    _arr = np.full(8, -1, np.int8)
    for _cls, (_b0, _b1, _b2) in _tab.items():
        _arr[4 * _b0 + 2 * _b1 + _b2] = _cls
    _DECODE_ARR[_enc] = _arr

_MASK3 = np.asarray(XOR_MASK, np.uint8)


def _pad_intervals(store: RIStore, idx: np.ndarray):
    """Padded per-pair interval endpoints: (starts [B,W], ends [B,W],
    counts [B], first_global [B]). Padding slots hold uint64 max."""
    idx = np.asarray(idx, np.int64)
    lo = store.off[idx]
    counts = (store.off[idx + 1] - lo).astype(np.int64)
    B = len(idx)
    W = int(max(1, counts.max() if B else 1))
    starts = np.full((B, W), _U64_MAX, np.uint64)
    ends = np.full((B, W), _U64_MAX, np.uint64)
    if len(store.ints) and B:
        col = np.arange(W)[None, :]
        mask = col < counts[:, None]
        src = (lo[:, None] + col)[mask]
        starts[mask] = store.ints[src, 0]
        ends[mask] = store.ints[src, 1]
    return starts, ends, counts, lo


def _flat_intervals(store: RIStore, idx: np.ndarray):
    """Per-pair flattened interval lists: (row-of-slot [T], local-pos [T],
    global-interval [T], segment offsets [B+1])."""
    idx = np.asarray(idx, np.int64)
    lo = store.off[idx]
    counts = (store.off[idx + 1] - lo).astype(np.int64)
    T = int(counts.sum())
    b_of = np.repeat(np.arange(len(idx)), counts)
    seg = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(T) - np.repeat(seg[:-1], counts)
    return b_of, pos, lo[b_of] + pos, seg


def _pair_fragments(store_x: RIStore, store_y: RIStore, pairs: np.ndarray):
    """All overlapping interval pairs ("fragments") of the candidate batch.

    Returns (b, ax, gx, gy, lo, hi): pair row, local x-interval index, global
    interval ids into each store, and the shared cell run [lo, hi). Fully
    vectorized: per x-interval, the overlapping y-intervals form a contiguous
    run (Y lists are sorted + disjoint) found with two flat searchsorted
    passes over row-keyed endpoints (row index in the high bits keeps each
    pair's segment separate; Hilbert ids use at most 2*N <= 32 bits).
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    bx_of, posx, gx_flat, _ = _flat_intervals(store_x, pairs[:, 0])
    by_of, posy, gy_flat, yseg = _flat_intervals(store_y, pairs[:, 1])
    if len(gx_flat) == 0 or len(gy_flat) == 0:
        z = np.zeros(0, np.int64)
        return z, z, z, z, z.astype(np.uint64), z.astype(np.uint64)
    SHIFT = np.uint64(33)
    xkey_b = bx_of.astype(np.uint64) << SHIFT
    ykey = (by_of.astype(np.uint64) << SHIFT)
    ys_keys = ykey + store_y.ints[gy_flat, 0]
    ye_keys = ykey + store_y.ints[gy_flat, 1]
    xs_flat = store_x.ints[gx_flat, 0]
    xe_flat = store_x.ints[gx_flat, 1]
    seg0 = yseg[:-1][bx_of]
    # first y with ye > xs ; one past last y with ys < xe
    lo_idx = np.searchsorted(ye_keys, xkey_b + xs_flat, side="right") - seg0
    hi_idx = np.searchsorted(ys_keys, xkey_b + xe_flat, side="left") - seg0
    n_frag = np.maximum(hi_idx - lo_idx, 0)
    total = int(n_frag.sum())
    if total == 0:
        z = np.zeros(0, np.int64)
        return z, z, z, z, z.astype(np.uint64), z.astype(np.uint64)
    rep = np.repeat(np.arange(len(n_frag)), n_frag)
    k = np.arange(total) - np.repeat(np.cumsum(n_frag) - n_frag, n_frag)
    b = bx_of[rep]
    ax = posx[rep]
    gx = gx_flat[rep]
    gy = store_y.off[pairs[b, 1]] + np.repeat(lo_idx, n_frag) + k
    lo = np.maximum(store_x.ints[gx, 0], store_y.ints[gy, 0])
    hi = np.minimum(store_x.ints[gx, 1], store_y.ints[gy, 1])
    return b, ax, gx, gy, lo, hi


def _fragment_hits_np(store_x: RIStore, store_y: RIStore, gx, gy, lo, hi,
                      xor_y: bool, chunk_elems: int = 1 << 24) -> np.ndarray:
    """ALIGNEDAND over all fragments, numpy bit-level path -> [F] bool."""
    F = len(gx)
    nbits = (3 * (hi - lo)).astype(np.int64)
    xo = store_x.bit_off[gx] + 3 * (lo - store_x.ints[gx, 0]).astype(np.int64)
    yo = store_y.bit_off[gy] + 3 * (lo - store_y.ints[gy, 0]).astype(np.int64)
    hits = np.zeros(F, bool)
    bx = store_x.bits; by = store_y.bits
    # power-of-two size buckets bound padding waste to 2x; rows per chunk
    # bound the padded working set
    for sel in _size_buckets(nbits, chunk_elems):
        L = int(nbits[sel].max())
        pos = np.arange(L)
        keep = pos[None, :] < nbits[sel, None]
        xi = np.clip(xo[sel, None] + pos[None, :], 0, max(len(bx) - 1, 0))
        yi = np.clip(yo[sel, None] + pos[None, :], 0, max(len(by) - 1, 0))
        xv = bx[xi]
        yv = by[yi]
        if xor_y:
            yv = yv ^ _MASK3[pos % 3][None, :]
        hits[sel] = np.any((xv & yv) & keep, axis=1)
    return hits


# power-of-two size-class bucketing shared with the construction paths
_size_buckets = rasterize.size_buckets


def _interval_words(store: RIStore, g: np.ndarray, W: int) -> np.ndarray:
    """Pack the full bitcodes of intervals ``g`` into [F, W] uint32 words,
    LSB-first (the layout `kernels/ri_and` consumes)."""
    F = len(g)
    nb = (store.bit_off[g + 1] - store.bit_off[g]).astype(np.int64)
    pos = np.arange(32 * W)
    bi = store.bit_off[g][:, None] + pos[None, :]
    valid = pos[None, :] < nb[:, None]
    src = np.clip(bi, 0, max(len(store.bits) - 1, 0))
    vals = np.where(valid, store.bits[src], 0).astype(np.uint32)
    sh = vals.reshape(F, W, 32) << np.arange(32, dtype=np.uint32)[None, None, :]
    return np.bitwise_or.reduce(sh, axis=-1)


def _fragment_hits_pallas(store_x: RIStore, store_y: RIStore, gx, gy, lo, hi,
                          xor_y: bool, interpret: bool | None = None,
                          chunk_elems: int = 1 << 22) -> np.ndarray:
    """ALIGNEDAND over fragments through the Pallas `ri_and` word kernel."""
    import jax
    from ..kernels.ri_and.ops import batch_aligned_and, xor_mask_words
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    F = len(gx)
    nbits = (3 * (hi - lo)).astype(np.int64)
    xo = (3 * (lo - store_x.ints[gx, 0])).astype(np.int64)
    yo = (3 * (lo - store_y.ints[gy, 0])).astype(np.int64)
    ibits = np.maximum(store_x.bit_off[gx + 1] - store_x.bit_off[gx],
                       store_y.bit_off[gy + 1] - store_y.bit_off[gy])
    hits = np.zeros(F, bool)
    for sel in _size_buckets(ibits, chunk_elems):
        W = max(1, (int(ibits[sel].max()) + 31) // 32)
        xw = _interval_words(store_x, gx[sel], W)
        yw = _interval_words(store_y, gy[sel], W)
        meta = np.stack([xo[sel], yo[sel], nbits[sel],
                         np.full(len(sel), int(xor_y))], axis=1).astype(np.int32)
        hits[sel] = np.asarray(batch_aligned_and(
            xw, yw, meta, xor_mask_words(W), interpret=interpret))
    return hits


def ri_filter_batch(store_x: RIStore, store_y: RIStore, pairs: np.ndarray,
                    backend: str = "numpy") -> np.ndarray:
    """Vectorized RI intersection filter (Algorithm 1) over pairs [N,2].

    Verdict-identical to :func:`ri_verdict_pair` per pair: TRUE_HIT if any
    shared cell run ANDs non-zero, INDECISIVE if interval ranges overlap
    without a code hit, TRUE_NEG otherwise. ``backend``: 'numpy' (host bit
    pass) or 'pallas'/'jnp' (packed uint32 words through kernels/ri_and).
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    xor_y = store_x.encoding == store_y.encoding
    b, ax, gx, gy, lo, hi = _pair_fragments(store_x, store_y, pairs)
    hit_fn = (_fragment_hits_pallas if backend in ("pallas", "jnp")
              else _fragment_hits_np)
    ovl_pair = np.zeros(N, bool)
    ovl_pair[b] = True
    hit_pair = np.zeros(N, bool)
    # batch-level short-circuit (DESIGN.md §3): AND the k-th fragment of
    # every undecided pair per round — a pair decided by an early fragment
    # never pays for its remaining ones (the vectorized analogue of the
    # sequential early exit). After a few rounds the survivors are flushed.
    if len(b):
        first = np.r_[True, b[1:] != b[:-1]]
        seg = np.nonzero(first)[0]
        rank = np.arange(len(b)) - np.repeat(seg, np.diff(np.r_[seg, len(b)]))
        todo = np.arange(len(b))
        r = 0
        while len(todo):
            todo = todo[~hit_pair[b[todo]]]
            if len(todo) == 0:
                break
            if r < 4:
                m = rank[todo] == r
                cur = todo[m]
                todo = todo[~m]
            else:               # flush the tail in one pass
                cur = todo
                todo = todo[:0]
            if len(cur):
                hits = hit_fn(store_x, store_y, gx[cur], gy[cur], lo[cur],
                              hi[cur], xor_y)
                np.logical_or.at(hit_pair, b[cur], hits)
            r += 1
    return np.where(hit_pair, TRUE_HIT,
                    np.where(ovl_pair, INDECISIVE, TRUE_NEG)).astype(np.int8)


def ri_within_batch(store_x: RIStore, store_y: RIStore,
                    pairs: np.ndarray) -> np.ndarray:
    """Vectorized RI within filter (§3.4) over pairs [N,2]; verdict-identical
    to :func:`ri_within_verdict_pair` per pair."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    cx = store_x.off[pairs[:, 0] + 1] - store_x.off[pairs[:, 0]]
    b, ax, gx, gy, lo, hi = _pair_fragments(store_x, store_y, pairs)

    # coverage: every x interval fully covered by (disjoint) y intervals
    Wx = int(ax.max()) + 1 if len(ax) else 1
    covered = np.zeros(N * Wx, np.int64)
    np.add.at(covered, b * Wx + ax, (hi - lo).astype(np.int64))
    xs_p, xe_p, cx_p, _ = _pad_intervals(store_x, pairs[:, 0])
    Wpad = xs_p.shape[1]           # >= Wx: ax < interval count <= Wpad
    xlen = np.where(np.arange(Wpad)[None, :] < cx_p[:, None],
                    (xe_p - xs_p).astype(np.int64), 0)
    uncovered = np.any(xlen[:, :Wx] > covered.reshape(N, Wx), axis=1)
    # x intervals with no fragments at all (columns beyond Wx) are uncovered
    uncovered |= np.any(xlen[:, Wx:] > 0, axis=1)

    # per-cell class comparison over the shared runs
    ncell = (hi - lo).astype(np.int64)
    C = int(ncell.sum())
    viol_pair = np.zeros(N, bool)
    notfull_pair = np.zeros(N, bool)
    if C:
        f_of_c = np.repeat(np.arange(len(ncell)), ncell)
        coff = np.arange(C) - np.repeat(np.cumsum(ncell) - ncell, ncell)
        cell_x = (lo[f_of_c] - store_x.ints[gx[f_of_c], 0]).astype(np.int64) + coff
        cell_y = (lo[f_of_c] - store_y.ints[gy[f_of_c], 0]).astype(np.int64) + coff

        def classes(store, g, celloff):
            o = store.bit_off[g[f_of_c]] + 3 * celloff
            code = (store.bits[o].astype(np.int8) * 4
                    + store.bits[o + 1].astype(np.int8) * 2
                    + store.bits[o + 2].astype(np.int8))
            return _DECODE_ARR[store.encoding][code]

        cls_x = classes(store_x, gx, cell_x)
        cls_y = classes(store_y, gy, cell_y)
        viol = ((cls_x == FULL) & (cls_y != FULL)) \
            | ((cls_x == STRONG) & (cls_y == WEAK))
        bc = b[f_of_c]
        np.logical_or.at(viol_pair, bc, viol)
        np.logical_or.at(notfull_pair, bc, cls_y != FULL)

    neg = uncovered | viol_pair
    out = np.where(neg, TRUE_NEG,
                   np.where(notfull_pair, INDECISIVE, TRUE_HIT)).astype(np.int8)
    out[cx == 0] = TRUE_HIT
    return out


def ri_verdict_pair(store_x: RIStore, i: int, store_y: RIStore, j: int) -> int:
    """RI-join (paper Algorithm 1) for one candidate pair."""
    X = store_x.intervals(i)
    Y = store_y.intervals(j)
    xor_y = store_x.encoding == store_y.encoding
    ovl = False
    a = b = 0
    while a < len(X) and b < len(Y):
        xs, xe = X[a]
        ys, ye = Y[b]
        if xs < ye and ys < xe:
            lo, hi = max(xs, ys), min(xe, ye)
            if _aligned_and(store_x.interval_bits(i, a), xs,
                            store_y.interval_bits(j, b), ys, lo, hi, xor_y):
                return TRUE_HIT
            ovl = True
        if xe <= ye:
            a += 1
        else:
            b += 1
    return INDECISIVE if ovl else TRUE_NEG
