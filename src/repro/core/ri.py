"""RI — Raster Intervals with 3-bit cell-type codes (paper §3).

Each object is a sorted list of Hilbert intervals; each interval carries a
bitstring concatenating the 3-bit codes (Table 2) of its cells:

              input R    input S
    full       011        101
    strong     101        011
    weak       100        010

Properties used by the join: (i) non-zero AND of two cell codes (one R-coded,
one S-coded) certifies intersection in that cell; (ii) XOR with mask 110
converts an R code into the S code of the same class, allowing one
precomputed dataset to take either side of a join.

Host representation: per-polygon flat *bit* arrays (np.uint8 0/1) plus
per-interval bit offsets; :func:`packed_codes` yields the byte-packed form
for storage accounting and the Pallas `ri_and` kernel operates on uint32
words. Construction requires Weak/Strong labeling, i.e. exact coverage
fractions — the expensive path the paper measures in Table 11.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import rasterize
from .intervalize import intervals_from_ids
from .join import INDECISIVE, TRUE_HIT, TRUE_NEG
from .rasterize import Extent, GLOBAL_EXTENT

__all__ = [
    "RIStore", "build_ri", "ri_verdict_pair", "ri_within_verdict_pair",
    "CODE_R", "CODE_S", "XOR_MASK", "FULL", "STRONG", "WEAK",
]

FULL, STRONG, WEAK = 0, 1, 2
CODE_R = {FULL: (0, 1, 1), STRONG: (1, 0, 1), WEAK: (1, 0, 0)}
CODE_S = {FULL: (1, 0, 1), STRONG: (0, 1, 1), WEAK: (0, 1, 0)}
XOR_MASK = (1, 1, 0)


@dataclass
class RIStore:
    """RI approximations for one dataset (single encoding, R or S)."""
    n_order: int
    extent: Extent
    encoding: str              # 'R' or 'S'
    off: np.ndarray            # [P+1] interval offsets
    ints: np.ndarray           # [sum_I, 2] uint64
    bit_off: np.ndarray        # [sum_I + 1] int64: bit offset of each interval
    bits: np.ndarray           # [total_bits] uint8 in {0,1}

    def __len__(self) -> int:
        return len(self.off) - 1

    def intervals(self, i: int) -> np.ndarray:
        return self.ints[self.off[i]: self.off[i + 1]]

    def interval_bits(self, i: int, k: int) -> np.ndarray:
        """Bit code of the k-th interval of polygon i."""
        g = self.off[i] + k
        return self.bits[self.bit_off[g]: self.bit_off[g + 1]]

    def size_bytes(self) -> int:
        """Endpoints as uint32 pairs + ceil(bits/8) code bytes (paper §3.2)."""
        code_bytes = 0
        for g in range(len(self.ints)):
            nbits = int(self.bit_off[g + 1] - self.bit_off[g])
            code_bytes += (nbits + 7) // 8
        return 4 * 2 * len(self.ints) + code_bytes + 8 * len(self.off)

    def packed_codes(self, i: int, k: int) -> np.ndarray:
        return np.packbits(self.interval_bits(i, k))


def _classify_cells(verts, n, n_order, extent):
    """Cell ids + classes for one polygon: DDA partials get Weak/Strong via
    coverage fraction; interior cells are Full."""
    partial = rasterize.dda_partial_cells(verts, n, n_order, extent)
    full = rasterize.scanline_full_cells(verts, n, partial, n_order, extent)
    p_ids = rasterize.cells_to_hilbert(partial, n_order)
    f_ids = rasterize.cells_to_hilbert(full, n_order)
    # coverage only for partial cells (full are 1.0 by construction)
    # recover cell coords in id order for fraction computation
    if len(partial):
        order = np.argsort(rasterize.xy2d(n_order, partial[:, 0], partial[:, 1]))
        pcells = partial[order]
        frac = rasterize.coverage_fractions(verts, n, pcells, n_order, extent)
        p_cls = np.where(frac > 0.5, STRONG, WEAK).astype(np.int8)
    else:
        p_cls = np.zeros((0,), np.int8)
    ids = np.concatenate([p_ids, f_ids])
    cls = np.concatenate([p_cls, np.full(len(f_ids), FULL, np.int8)])
    order = np.argsort(ids)
    return ids[order], cls[order]


def build_ri(
    dataset, n_order: int, extent: Extent = GLOBAL_EXTENT, encoding: str = "R",
) -> RIStore:
    code_tab = CODE_R if encoding == "R" else CODE_S
    off = [0]; bit_off = [0]
    int_chunks = []; bit_chunks = []
    for i in range(len(dataset)):
        ids, cls = _classify_cells(
            dataset.verts[i], int(dataset.nverts[i]), n_order, extent)
        ints = intervals_from_ids(ids)
        int_chunks.append(ints)
        off.append(off[-1] + len(ints))
        # per-interval concatenated 3-bit codes, in Hilbert order
        pos = 0
        for s, e in ints:
            ln = int(e - s)
            seg = cls[pos: pos + ln]
            pos += ln
            bits = np.asarray([code_tab[int(c)] for c in seg], np.uint8).ravel()
            bit_chunks.append(bits)
            bit_off.append(bit_off[-1] + 3 * ln)
    ints = (np.concatenate(int_chunks, axis=0)
            if int_chunks else np.zeros((0, 2), np.uint64))
    bits = (np.concatenate(bit_chunks) if bit_chunks
            else np.zeros((0,), np.uint8))
    return RIStore(
        n_order=n_order, extent=extent, encoding=encoding,
        off=np.asarray(off, np.int64), ints=ints,
        bit_off=np.asarray(bit_off, np.int64), bits=bits,
    )


def _aligned_and(xbits, xs, ybits, ys, lo, hi, xor_y: bool) -> bool:
    """ALIGNEDAND: AND the 3-bit codes of cells [lo, hi) taken from both
    intervals' bitstrings; optionally XOR-converts y's encoding first."""
    xo = 3 * int(lo - xs)
    yo = 3 * int(lo - ys)
    ln = 3 * int(hi - lo)
    xf = xbits[xo: xo + ln]
    yf = ybits[yo: yo + ln].copy()
    if xor_y:
        yf ^= np.tile(np.asarray(XOR_MASK, np.uint8), int(hi - lo))
    return bool(np.any(xf & yf))


def ri_within_verdict_pair(store_x: RIStore, i: int, store_y: RIStore,
                           j: int) -> int:
    """RI within-join filter (§3.4): is x within y?

    TRUE_NEG as soon as (i) an interval of x is not fully covered by y's
    intervals (an x-cell is empty in y), or (ii) some shared cell is Full in
    x but not Full in y, or Strong in x and Weak in y (x's area in that cell
    must exceed y's). TRUE_HIT iff every x-cell is Full in y. Else
    indecisive. Operates on the decoded 3-bit classes.
    """
    X = store_x.intervals(i)
    Y = store_y.intervals(j)
    if len(X) == 0:
        return TRUE_HIT
    dec_x = _DECODE[store_x.encoding]
    dec_y = _DECODE[store_y.encoding]
    all_full_in_y = True
    b = 0
    for a in range(len(X)):
        xs, xe = X[a]
        cell = xs
        while cell < xe:
            # advance y's cursor to the interval that could contain `cell`
            while b < len(Y) and Y[b][1] <= cell:
                b += 1
            if b >= len(Y) or cell < Y[b][0]:
                return TRUE_NEG          # x-cell empty in y
            ys, ye = Y[b]
            hi = min(xe, ye)
            # classes over the shared run [cell, hi)
            for c in range(int(cell), int(hi)):
                cx = _cell_class(store_x, i, a, c - int(xs), dec_x)
                cy = _cell_class_at(store_y, j, b, c - int(ys), dec_y)
                if (cx == FULL and cy != FULL) or (cx == STRONG and cy == WEAK):
                    return TRUE_NEG
                if cy != FULL:
                    all_full_in_y = False
            cell = hi
    return TRUE_HIT if all_full_in_y else INDECISIVE


# class decoding tables: 3-bit tuple -> class id, per encoding
_DECODE = {
    "R": {v: k for k, v in CODE_R.items()},
    "S": {v: k for k, v in CODE_S.items()},
}


def _cell_class(store: RIStore, i: int, k: int, off: int, table) -> int:
    bits = store.interval_bits(i, k)[3 * off: 3 * off + 3]
    return table[tuple(int(b) for b in bits)]


def _cell_class_at(store: RIStore, j: int, k: int, off: int, table) -> int:
    return _cell_class(store, j, k, off, table)


def ri_verdict_pair(store_x: RIStore, i: int, store_y: RIStore, j: int) -> int:
    """RI-join (paper Algorithm 1) for one candidate pair."""
    X = store_x.intervals(i)
    Y = store_y.intervals(j)
    xor_y = store_x.encoding == store_y.encoding
    ovl = False
    a = b = 0
    while a < len(X) and b < len(Y):
        xs, xe = X[a]
        ys, ye = Y[b]
        if xs < ye and ys < xe:
            lo, hi = max(xs, ys), min(xe, ye)
            if _aligned_and(store_x.interval_bits(i, a), xs,
                            store_y.interval_bits(j, b), ys, lo, hi, xor_y):
                return TRUE_HIT
            ovl = True
        if xe <= ye:
            a += 1
        else:
            b += 1
    return INDECISIVE if ovl else TRUE_NEG
