"""Mixed-granularity APRIL joins (§5.3).

Large-polygon datasets may be approximated at a lower Hilbert order L < N to
cut interval counts. Joining an order-N list with an order-L list scales the
finer list down (paper Eq. 1):

    a' = [a_start >> 2(N-L),  ((a_end - 1) >> 2(N-L)) + 1)      (half-open)

Scaling is only sound for A-lists (a Full interval at order N need not be
Full at order L), so the filter runs just TWO joins: AA (scaled) and the
AF/FA join that uses the *coarse* side's F-list.
"""
from __future__ import annotations

import numpy as np

from .join import (INDECISIVE, TRUE_HIT, TRUE_NEG, interval_join_pair)

__all__ = ["scale_intervals", "mixed_order_verdict_pair"]


def scale_intervals(ints: np.ndarray, n_from: int, n_to: int) -> np.ndarray:
    """Scale half-open intervals from order n_from down to n_to (Eq. 1) and
    re-merge any now-overlapping/adjacent intervals."""
    assert n_from >= n_to
    if n_from == n_to or len(ints) == 0:
        return np.asarray(ints, np.uint64)
    sh = np.uint64(2 * (n_from - n_to))
    one = np.uint64(1)
    starts = ints[:, 0] >> sh
    ends = ((ints[:, 1] - one) >> sh) + one
    # merge: scaled intervals can touch/overlap
    merged_s = [starts[0]]
    merged_e = [ends[0]]
    for s, e in zip(starts[1:], ends[1:]):
        if s <= merged_e[-1]:
            merged_e[-1] = max(merged_e[-1], e)
        else:
            merged_s.append(s); merged_e.append(e)
    return np.stack([np.asarray(merged_s, np.uint64),
                     np.asarray(merged_e, np.uint64)], axis=1)


def mixed_order_verdict_pair(
    a_fine: np.ndarray, f_fine: np.ndarray, n_fine: int,
    a_coarse: np.ndarray, f_coarse: np.ndarray, n_coarse: int,
) -> int:
    """APRIL filter across orders: fine side scaled down; only the coarse
    side's F-list participates (§5.3)."""
    a_scaled = scale_intervals(a_fine, n_fine, n_coarse)
    if not interval_join_pair(a_scaled, a_coarse):
        return TRUE_NEG
    if interval_join_pair(a_scaled, f_coarse):
        return TRUE_HIT
    return INDECISIVE
