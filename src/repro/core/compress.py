"""APRIL-C: delta + Variable-Byte compression of interval lists (§5.1).

An interval list is a strictly-increasing flat integer sequence
``s0, e0, s1, e1, ...`` (disjoint sorted intervals), so gaps are positive and
delta + VByte compresses well. The decoder supports *streaming* consumption —
`DecompressingCursor` yields one value at a time, so a merge join can stop
after the first overlap without decompressing the rest (join-while-decompress,
as the paper does with libvbyte).

Device note: byte-granular varint decode is scalar poison on TPU; the device
path decompresses per *partition shard* on host before upload (DESIGN.md §3),
while this codec provides the storage sizes reported in Table-4-style
benchmarks and the streaming host join.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .join import INDECISIVE, TRUE_HIT, TRUE_NEG

__all__ = [
    "vbyte_encode", "vbyte_decode", "vbyte_decode_many",
    "compress_intervals",
    "decompress_intervals", "DecompressingCursor", "interval_join_compressed",
    "april_verdict_compressed", "CompressedAprilStore", "compress_april",
]


def vbyte_encode(values: np.ndarray) -> bytes:
    """Delta + VByte encode a strictly increasing uint64 sequence."""
    v = np.asarray(values, np.uint64)
    if len(v) == 0:
        return b""
    deltas = np.empty_like(v)
    deltas[0] = v[0]
    deltas[1:] = v[1:] - v[:-1]
    out = bytearray()
    for d in deltas.tolist():
        while True:
            b = d & 0x7F
            d >>= 7
            if d:
                out.append(b | 0x80)
            else:
                out.append(b)
                break
    return bytes(out)


def vbyte_decode(buf: bytes, count: int) -> np.ndarray:
    """Inverse of :func:`vbyte_encode`."""
    out = np.empty(count, np.uint64)
    acc = 0
    pos = 0
    for i in range(count):
        val = 0
        shift = 0
        while True:
            b = buf[pos]; pos += 1
            val |= (b & 0x7F) << shift
            if not (b & 0x80):
                break
            shift += 7
        acc += val
        out[i] = acc
    return out


def vbyte_decode_many(bufs: list[tuple[bytes, int]]
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Decode many delta+VByte buffers in one vectorized pass.

    ``bufs`` is a list of (buffer, count) pairs (the
    :class:`CompressedAprilStore` per-object entries). Returns
    (values [sum_counts] uint64, offsets [len(bufs)+1] int64). The decode is
    flat numpy end to end — continuation-bit grouping, 7-bit shifts, one
    ``add.reduceat`` per varint, and a segmented prefix sum to undo the
    deltas — so decoding B objects costs O(total bytes), not B Python loops
    (the bound the batched APRIL-C path relies on, DESIGN.md §9).
    """
    counts = np.fromiter((c for _, c in bufs), np.int64, len(bufs))
    off = np.zeros(len(bufs) + 1, np.int64)
    np.cumsum(counts, out=off[1:])
    total = int(off[-1])
    if total == 0:
        return np.zeros(0, np.uint64), off
    raw = np.frombuffer(b"".join(b for b, _ in bufs), np.uint8)
    payload = (raw & 0x7F).astype(np.uint64)
    cont = raw >= 0x80
    # byte-group boundaries: a varint ends at every byte with a clear
    # continuation bit (varints never span buffers — each buffer is whole)
    ends = np.nonzero(~cont)[0]
    starts = np.empty_like(ends)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    shift = (np.arange(len(raw), dtype=np.uint64)
             - np.repeat(starts, ends - starts + 1).astype(np.uint64))
    deltas = np.add.reduceat(payload << (np.uint64(7) * shift), starts)
    # segmented prefix sum: absolute values restart at each buffer boundary
    cs = np.cumsum(deltas)
    seg0 = cs[off[:-1].clip(0, total - 1)] - deltas[off[:-1].clip(0, total - 1)]
    return cs - np.repeat(seg0, counts), off


def compress_intervals(ints: np.ndarray) -> tuple[bytes, int]:
    """Compress an [I,2] interval list; returns (buffer, count=2I)."""
    flat = np.asarray(ints, np.uint64).reshape(-1)
    return vbyte_encode(flat), len(flat)


def decompress_intervals(buf: bytes, count: int) -> np.ndarray:
    return vbyte_decode(buf, count).reshape(-1, 2)


class DecompressingCursor:
    """Streams intervals out of a compressed buffer one at a time."""

    def __init__(self, buf: bytes, count: int):
        self.buf = buf
        self.count = count          # number of flat values (2 * intervals)
        self.pos = 0
        self.emitted = 0
        self.acc = 0

    def _next_value(self) -> int:
        val = 0
        shift = 0
        while True:
            b = self.buf[self.pos]; self.pos += 1
            val |= (b & 0x7F) << shift
            if not (b & 0x80):
                break
            shift += 7
        self.acc += val
        self.emitted += 1
        return self.acc

    def next_interval(self):
        """Next (start, end) or None when exhausted."""
        if self.emitted >= self.count:
            return None
        return self._next_value(), self._next_value()


def interval_join_compressed(bx: tuple[bytes, int], by: tuple[bytes, int]) -> bool:
    """Merge join directly over two compressed lists; decompresses only as far
    as needed to find the first overlap (§5.1)."""
    cx = DecompressingCursor(*bx)
    cy = DecompressingCursor(*by)
    x = cx.next_interval()
    y = cy.next_interval()
    while x is not None and y is not None:
        if x[0] < y[1] and y[0] < x[1]:
            return True
        if x[1] <= y[1]:
            x = cx.next_interval()
        else:
            y = cy.next_interval()
    return False


def april_verdict_compressed(ar, fr, as_, fs) -> int:
    """APRIL filter over compressed (buf, count) lists — APRIL-C."""
    if not interval_join_compressed(ar, as_):
        return TRUE_NEG
    if interval_join_compressed(ar, fs):
        return TRUE_HIT
    if interval_join_compressed(fr, as_):
        return TRUE_HIT
    return INDECISIVE


@dataclass
class CompressedAprilStore:
    """APRIL-C approximations for one dataset: per-object VByte buffers.

    The streaming per-pair join (:func:`april_verdict_compressed`) consumes
    the buffers directly; the batched/device path decompresses the objects of
    a candidate batch on host first (DESIGN.md §3) via :meth:`decompress`.
    """
    n_order: int
    extent: object
    a_bufs: list          # per object: (bytes, count)
    f_bufs: list

    def __len__(self) -> int:
        return len(self.a_bufs)

    def a_list(self, i: int) -> np.ndarray:
        return decompress_intervals(*self.a_bufs[i])

    def f_list(self, i: int) -> np.ndarray:
        return decompress_intervals(*self.f_bufs[i])

    def size_bytes(self) -> int:
        return (sum(len(b) for b, _ in self.a_bufs)
                + sum(len(b) for b, _ in self.f_bufs))

    def decompress_lists(self, idx: np.ndarray, kind: str = "A"
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Decode one list kind of objects ``idx`` into CSR form
        (offsets [B+1] int64, intervals [T, 2] uint64), rows renumbered
        0..B-1 — one vectorized :func:`vbyte_decode_many` pass. This is the
        batched path's *bounded* decode: the APRIL-C filter calls it for
        exactly the objects a batch stage touches (A lists for the batch,
        F lists for the AA survivors only)."""
        bufs = self.a_bufs if kind == "A" else self.f_bufs
        idx = np.asarray(idx, np.int64)
        vals, voff = vbyte_decode_many([bufs[int(i)] for i in idx])
        return voff // 2, vals.reshape(-1, 2)

    def decompress(self, idx: np.ndarray | None = None):
        """Decompress objects ``idx`` (all when None) into an
        :class:`~repro.core.april.AprilStore` with rows renumbered 0..B-1."""
        from .april import AprilStore
        idx = np.arange(len(self)) if idx is None else np.asarray(idx, np.int64)
        a_off, a_ints = self.decompress_lists(idx, "A")
        f_off, f_ints = self.decompress_lists(idx, "F")
        return AprilStore(
            n_order=self.n_order, extent=self.extent,
            a_off=a_off, a_ints=a_ints, f_off=f_off, f_ints=f_ints)


def compress_april(store) -> CompressedAprilStore:
    """Compress an AprilStore into per-object VByte buffers (§5.1)."""
    a_bufs = [compress_intervals(store.a_list(i)) for i in range(len(store))]
    f_bufs = [compress_intervals(store.f_list(i)) for i in range(len(store))]
    return CompressedAprilStore(n_order=store.n_order, extent=store.extent,
                                a_bufs=a_bufs, f_bufs=f_bufs)
