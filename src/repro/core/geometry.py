"""Exact geometry predicates.

Host path (numpy, float64): used for offline approximation *construction*
(rasterization, PiP labeling) and as the correctness oracle for refinement —
mirrors the paper, where approximations are precomputed before the join.

Device path (jnp, float32): used for the *online* batched refinement step.
float32 is safe for the filter decisions (interval arithmetic is exact int32);
refinement results near the epsilon guard band are flagged indecisive so they
can be re-checked at f64 (conservative, never wrong).

Polygons are stored padded: ``verts`` has shape [P, V, 2] and ``nverts`` [P];
vertices at index >= nverts[p] are ignored. Rings are implicitly closed
(edge from vertex nverts-1 back to vertex 0). Vertex order may be CW or CCW.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "polygon_edges",
    "polygon_mbrs",
    "points_in_polygon",
    "points_in_polygons_batch",
    "segments_intersect",
    "polygons_intersect",
    "polygon_within",
    "polygon_area",
    "clip_polygon_to_box",
]


def polygon_edges(verts: np.ndarray, nverts: np.ndarray):
    """Return (starts [P,V,2], ends [P,V,2], mask [P,V]) of polygon edges.

    Edge i runs from vertex i to vertex (i+1) mod nverts. Padded slots are
    masked out and their coordinates degenerate to the first vertex (harmless
    zero-length segments, additionally excluded by ``mask``).
    """
    verts = np.asarray(verts, dtype=np.float64)
    nverts = np.asarray(nverts, dtype=np.int64)
    P, V, _ = verts.shape
    idx = np.arange(V)[None, :]                       # [1,V]
    valid = idx < nverts[:, None]                     # [P,V]
    nxt = (idx + 1) % np.maximum(nverts[:, None], 1)  # wrap within ring
    nxt = np.where(valid, nxt, 0)
    starts = np.where(valid[..., None], verts, verts[:, :1, :])
    ends = np.take_along_axis(verts, nxt[..., None].repeat(2, axis=-1), axis=1)
    ends = np.where(valid[..., None], ends, verts[:, :1, :])
    return starts, ends, valid


def polygon_mbrs(verts: np.ndarray, nverts: np.ndarray) -> np.ndarray:
    """[P,4] = (xmin, ymin, xmax, ymax) per polygon, ignoring padding."""
    verts = np.asarray(verts, dtype=np.float64)
    nverts = np.asarray(nverts, dtype=np.int64)
    P, V, _ = verts.shape
    valid = (np.arange(V)[None, :] < nverts[:, None])[..., None]
    lo = np.where(valid, verts, np.inf).min(axis=1)
    hi = np.where(valid, verts, -np.inf).max(axis=1)
    return np.concatenate([lo, hi], axis=1)


def points_in_polygon(points: np.ndarray, verts: np.ndarray, n: int | None = None) -> np.ndarray:
    """Crossing-number test for many points against ONE polygon.

    points: [M,2]; verts: [V,2] (optionally padded, pass n). Returns [M] bool.
    Points exactly on the boundary may land on either side (general-position
    data); construction snaps test points to cell centers which are off-grid.
    """
    points = np.asarray(points, dtype=np.float64)
    verts = np.asarray(verts, dtype=np.float64)
    if n is not None:
        verts = verts[: int(n)]
    x, y = points[:, 0][:, None], points[:, 1][:, None]       # [M,1]
    x0, y0 = verts[:, 0][None, :], verts[:, 1][None, :]       # [1,V]
    x1, y1 = np.roll(verts[:, 0], -1)[None, :], np.roll(verts[:, 1], -1)[None, :]
    # Edge straddles the horizontal ray at height y
    cond = (y0 <= y) != (y1 <= y)                             # [M,V]
    # x-coordinate of the edge at height y
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    crossings = np.sum(cond & (xint > x), axis=1)
    return (crossings % 2) == 1


def points_in_polygons_batch(
    points: np.ndarray, verts: np.ndarray, nverts: np.ndarray
) -> np.ndarray:
    """PiP for per-polygon points. points: [P,M,2]; polygons padded [P,V,2].

    Returns [P,M] bool. Fully vectorized (one pass, no Python loop) — this is
    the TPU-adapted "batched PiP" used by one-step intervalization.
    """
    points = np.asarray(points, dtype=np.float64)
    starts, ends, mask = polygon_edges(verts, nverts)
    x, y = points[..., 0][:, :, None], points[..., 1][:, :, None]   # [P,M,1]
    x0, y0 = starts[..., 0][:, None, :], starts[..., 1][:, None, :]  # [P,1,V]
    x1, y1 = ends[..., 0][:, None, :], ends[..., 1][:, None, :]
    cond = (y0 <= y) != (y1 <= y)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    cross = cond & (xint > x) & mask[:, None, :]
    return (np.sum(cross, axis=2) % 2) == 1


def _orient(ax, ay, bx, by, cx, cy):
    """Signed orientation of triangle (a,b,c): >0 ccw, <0 cw, 0 collinear."""
    return (bx - ax) * (cy - ay) - (by - ay) * (cx - ax)


def segments_intersect(a0, a1, b0, b1) -> np.ndarray:
    """Proper/improper segment intersection test, broadcastable.

    a0,a1,b0,b1: [...,2]. Returns bool array of the broadcast shape.
    Handles collinear-overlap via on-segment checks.
    """
    a0 = np.asarray(a0, np.float64); a1 = np.asarray(a1, np.float64)
    b0 = np.asarray(b0, np.float64); b1 = np.asarray(b1, np.float64)
    d1 = _orient(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a0[..., 0], a0[..., 1])
    d2 = _orient(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a1[..., 0], a1[..., 1])
    d3 = _orient(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b0[..., 0], b0[..., 1])
    d4 = _orient(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b1[..., 0], b1[..., 1])
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0)) \
        & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0)

    def on_seg(px, py, qx, qy, rx, ry):
        # r collinear with pq assumed; is r within the pq bounding box?
        return (
            (np.minimum(px, qx) <= rx) & (rx <= np.maximum(px, qx))
            & (np.minimum(py, qy) <= ry) & (ry <= np.maximum(py, qy))
        )

    touch = (
        ((d1 == 0) & on_seg(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a0[..., 0], a0[..., 1]))
        | ((d2 == 0) & on_seg(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a1[..., 0], a1[..., 1]))
        | ((d3 == 0) & on_seg(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b0[..., 0], b0[..., 1]))
        | ((d4 == 0) & on_seg(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b1[..., 0], b1[..., 1]))
    )
    return proper | touch


def polygons_intersect(
    verts_a: np.ndarray, na: int, verts_b: np.ndarray, nb: int
) -> bool:
    """Exact polygon-polygon intersection (the refinement oracle).

    True iff boundaries cross, or one polygon contains the other.
    """
    va = np.asarray(verts_a, np.float64)[: int(na)]
    vb = np.asarray(verts_b, np.float64)[: int(nb)]
    a0 = va; a1 = np.roll(va, -1, axis=0)
    b0 = vb; b1 = np.roll(vb, -1, axis=0)
    hit = segments_intersect(
        a0[:, None, :], a1[:, None, :], b0[None, :, :], b1[None, :, :]
    )
    if bool(hit.any()):
        return True
    # containment: any vertex of one inside the other
    if bool(points_in_polygon(va[:1], vb)[0]):
        return True
    if bool(points_in_polygon(vb[:1], va)[0]):
        return True
    return False


def polygon_within(verts_a: np.ndarray, na: int, verts_b: np.ndarray, nb: int) -> bool:
    """Exact 'a within b' (a's area subset of b's). Boundary-touching counts
    as within (closed-region semantics), matching the paper's within joins."""
    va = np.asarray(verts_a, np.float64)[: int(na)]
    vb = np.asarray(verts_b, np.float64)[: int(nb)]
    # every vertex of a inside (or on) b ...
    if not points_in_polygon(va, vb).all():
        # allow on-boundary vertices: nudge test — reject only clear outsiders
        eps = 1e-12
        c = vb.mean(axis=0)
        nudged = va + (c - va) * eps
        if not points_in_polygon(nudged, vb).all():
            return False
    # ... and no proper boundary crossing
    a0 = va; a1 = np.roll(va, -1, axis=0)
    b0 = vb; b1 = np.roll(vb, -1, axis=0)
    d1 = _orient(b0[None, :, 0], b0[None, :, 1], b1[None, :, 0], b1[None, :, 1], a0[:, None, 0], a0[:, None, 1])
    d2 = _orient(b0[None, :, 0], b0[None, :, 1], b1[None, :, 0], b1[None, :, 1], a1[:, None, 0], a1[:, None, 1])
    d3 = _orient(a0[:, None, 0], a0[:, None, 1], a1[:, None, 0], a1[:, None, 1], b0[None, :, 0], b0[None, :, 1])
    d4 = _orient(a0[:, None, 0], a0[:, None, 1], a1[:, None, 0], a1[:, None, 1], b1[None, :, 0], b1[None, :, 1])
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0)) \
        & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0)
    return not bool(proper.any())


def polygon_area(verts: np.ndarray, n: int | None = None) -> float:
    """Shoelace area (absolute)."""
    v = np.asarray(verts, np.float64)
    if n is not None:
        v = v[: int(n)]
    x, y = v[:, 0], v[:, 1]
    return float(abs(np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y)) / 2.0)


def clip_polygon_to_box(verts: np.ndarray, box: tuple[float, float, float, float]) -> np.ndarray:
    """Sutherland–Hodgman clip of a polygon to an axis-aligned box.

    Host-side helper for RA/RI construction (coverage-fraction labeling).
    Returns the clipped ring [K,2] (possibly empty).
    """
    xmin, ymin, xmax, ymax = box

    def clip_half(poly, inside, intersect):
        out = []
        k = len(poly)
        for i in range(k):
            cur, nxt = poly[i], poly[(i + 1) % k]
            cin, nin = inside(cur), inside(nxt)
            if cin:
                out.append(cur)
                if not nin:
                    out.append(intersect(cur, nxt))
            elif nin:
                out.append(intersect(cur, nxt))
        return out

    def ix_x(c, n, x):
        t = (x - c[0]) / (n[0] - c[0])
        return (x, c[1] + t * (n[1] - c[1]))

    def ix_y(c, n, y):
        t = (y - c[1]) / (n[1] - c[1])
        return (c[0] + t * (n[0] - c[0]), y)

    poly = [tuple(p) for p in np.asarray(verts, np.float64)]
    poly = clip_half(poly, lambda p: p[0] >= xmin, lambda c, n: ix_x(c, n, xmin))
    if poly:
        poly = clip_half(poly, lambda p: p[0] <= xmax, lambda c, n: ix_x(c, n, xmax))
    if poly:
        poly = clip_half(poly, lambda p: p[1] >= ymin, lambda c, n: ix_y(c, n, ymin))
    if poly:
        poly = clip_half(poly, lambda p: p[1] <= ymax, lambda c, n: ix_y(c, n, ymax))
    return np.asarray(poly, np.float64).reshape(-1, 2)
