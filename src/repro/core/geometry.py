"""Exact geometry predicates.

Host path (numpy, float64): used for offline approximation *construction*
(rasterization, PiP labeling) and as the correctness oracle for refinement —
mirrors the paper, where approximations are precomputed before the join.

Device path (jnp, float32): used for the *online* batched refinement step.
float32 is safe for the filter decisions (interval arithmetic is exact int32);
refinement results near the epsilon guard band are flagged indecisive so they
can be re-checked at f64 (conservative, never wrong).

Polygons are stored padded: ``verts`` has shape [P, V, 2] and ``nverts`` [P];
vertices at index >= nverts[p] are ignored. Rings are implicitly closed
(edge from vertex nverts-1 back to vertex 0). Vertex order may be CW or CCW.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "polygon_edges",
    "polygon_mbrs",
    "points_in_polygon",
    "points_on_polygon_boundary",
    "points_in_polygon_closed",
    "points_in_polygons_batch",
    "points_in_polygon_rows",
    "representative_points",
    "segments_intersect",
    "polygons_intersect",
    "polygon_within",
    "polygon_area",
    "clip_polygon_to_box",
    "box_clip_areas",
    "box_clip_areas_rows",
    "size_buckets",
]


def size_buckets(sizes: np.ndarray, chunk_elems: int = 1 << 22):
    """Yield index chunks grouped by power-of-two size class (padding waste
    <= 2x), each chunk's padded element count bounded by ``chunk_elems``.
    Zero-size rows are skipped. The shared bucketing lever of every batched
    pass (construction and joins alike, DESIGN.md §4/§6)."""
    sizes = np.asarray(sizes, np.int64)
    nz = np.nonzero(sizes > 0)[0]
    if len(nz) == 0:
        return
    cls = np.ceil(np.log2(sizes[nz].astype(np.float64))).astype(np.int64)
    for c in np.unique(cls):
        sel = nz[cls == c]
        L = int(sizes[sel].max())
        rows = max(1, int(chunk_elems // max(1, L)))
        for r0 in range(0, len(sel), rows):
            yield sel[r0: r0 + rows]


def polygon_edges(verts: np.ndarray, nverts: np.ndarray):
    """Return (starts [P,V,2], ends [P,V,2], mask [P,V]) of polygon edges.

    Edge i runs from vertex i to vertex (i+1) mod nverts. Padded slots are
    masked out and their coordinates degenerate to the first vertex (harmless
    zero-length segments, additionally excluded by ``mask``).
    """
    verts = np.asarray(verts, dtype=np.float64)
    nverts = np.asarray(nverts, dtype=np.int64)
    P, V, _ = verts.shape
    idx = np.arange(V)[None, :]                       # [1,V]
    valid = idx < nverts[:, None]                     # [P,V]
    nxt = (idx + 1) % np.maximum(nverts[:, None], 1)  # wrap within ring
    nxt = np.where(valid, nxt, 0)
    starts = np.where(valid[..., None], verts, verts[:, :1, :])
    ends = np.take_along_axis(verts, nxt[..., None].repeat(2, axis=-1), axis=1)
    ends = np.where(valid[..., None], ends, verts[:, :1, :])
    return starts, ends, valid


def polygon_mbrs(verts: np.ndarray, nverts: np.ndarray) -> np.ndarray:
    """[P,4] = (xmin, ymin, xmax, ymax) per polygon, ignoring padding."""
    verts = np.asarray(verts, dtype=np.float64)
    nverts = np.asarray(nverts, dtype=np.int64)
    P, V, _ = verts.shape
    valid = (np.arange(V)[None, :] < nverts[:, None])[..., None]
    lo = np.where(valid, verts, np.inf).min(axis=1)
    hi = np.where(valid, verts, -np.inf).max(axis=1)
    return np.concatenate([lo, hi], axis=1)


def points_in_polygon(points: np.ndarray, verts: np.ndarray, n: int | None = None) -> np.ndarray:
    """Crossing-number test for many points against ONE polygon.

    points: [M,2]; verts: [V,2] (optionally padded, pass n). Returns [M] bool.
    Points exactly on the boundary may land on either side (general-position
    data); construction snaps test points to cell centers which are off-grid.
    """
    points = np.asarray(points, dtype=np.float64)
    verts = np.asarray(verts, dtype=np.float64)
    if n is not None:
        verts = verts[: int(n)]
    x, y = points[:, 0][:, None], points[:, 1][:, None]       # [M,1]
    x0, y0 = verts[:, 0][None, :], verts[:, 1][None, :]       # [1,V]
    x1, y1 = np.roll(verts[:, 0], -1)[None, :], np.roll(verts[:, 1], -1)[None, :]
    # Edge straddles the horizontal ray at height y
    cond = (y0 <= y) != (y1 <= y)                             # [M,V]
    # x-coordinate of the edge at height y
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    crossings = np.sum(cond & (xint > x), axis=1)
    return (crossings % 2) == 1


def points_on_polygon_boundary(
    points: np.ndarray, verts: np.ndarray, n: int | None = None
) -> np.ndarray:
    """Exact on-boundary test: point collinear with an edge and inside its
    bounding box. points: [M,2]; verts: [V,2] (optionally padded, pass n).
    Returns [M] bool."""
    points = np.asarray(points, dtype=np.float64)
    verts = np.asarray(verts, dtype=np.float64)
    if n is not None:
        verts = verts[: int(n)]
    x, y = points[:, 0][:, None], points[:, 1][:, None]       # [M,1]
    x0, y0 = verts[:, 0][None, :], verts[:, 1][None, :]       # [1,V]
    x1, y1 = np.roll(verts[:, 0], -1)[None, :], np.roll(verts[:, 1], -1)[None, :]
    d = _orient(x0, y0, x1, y1, x, y)
    on = ((d == 0)
          & (np.minimum(x0, x1) <= x) & (x <= np.maximum(x0, x1))
          & (np.minimum(y0, y1) <= y) & (y <= np.maximum(y0, y1)))
    return on.any(axis=1)


def points_in_polygon_closed(
    points: np.ndarray, verts: np.ndarray, n: int | None = None
) -> np.ndarray:
    """Closed-region PiP: inside by crossing parity OR exactly on the
    boundary. The crossing-parity test alone can land an on-boundary point
    on either side; closed-region predicates (touching counts) need this."""
    return (points_in_polygon(points, verts, n)
            | points_on_polygon_boundary(points, verts, n))


def representative_points(verts: np.ndarray, nverts: np.ndarray) -> np.ndarray:
    """One guaranteed-interior point per simple polygon. [P,V,2]/[P] -> [P,2].

    O'Rourke's diagonal construction: let b be the extreme vertex along a
    generic direction (a convex-hull vertex; a generic direction avoids the
    flat axis-aligned runs map-border clipping produces) with ring
    neighbours a and c. If no other vertex lies in the closed triangle
    (a,b,c), its centroid is interior; otherwise the midpoint of b and the
    in-triangle vertex farthest from line (a,c) is the midpoint of a polygon
    diagonal, hence interior. Unlike a raw vertex — which may sit
    (numerically) on another polygon's boundary — the result is bounded away
    from this polygon's boundary, so crossing-parity tests classify it
    robustly. Degenerate rings (< 3 vertices, collinear (a,b,c), or a
    crossing-parity self-check failure) fall back to the first vertex.
    """
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    P, V, _ = verts.shape
    if P == 0:
        return np.zeros((0, 2), np.float64)
    idx = np.arange(V)[None, :]
    valid = idx < nverts[:, None]
    rows = np.arange(P)
    key = np.where(valid,
                   verts[..., 0] + 0.5609840165894135 * verts[..., 1], np.inf)
    b = np.argmin(key, axis=1)
    n = np.maximum(nverts, 1)
    a = (b - 1) % n
    c = (b + 1) % n
    pa, pb, pc = verts[rows, a], verts[rows, b], verts[rows, c]
    s = _orient(pa[:, 0], pa[:, 1], pb[:, 0], pb[:, 1], pc[:, 0], pc[:, 1])
    sgn = np.where(s >= 0, 1.0, -1.0)[:, None]
    wx, wy = verts[..., 0], verts[..., 1]

    def tri(p, q):
        return _orient(p[:, None, 0], p[:, None, 1],
                       q[:, None, 0], q[:, None, 1], wx, wy)

    in_tri = ((sgn * tri(pa, pb) >= 0) & (sgn * tri(pb, pc) >= 0)
              & (sgn * tri(pc, pa) >= 0) & valid
              & (idx != a[:, None]) & (idx != b[:, None]) & (idx != c[:, None]))
    dist = np.where(in_tri, np.abs(tri(pa, pc)), -1.0)
    q = np.argmax(dist, axis=1)
    pq = verts[rows, q]
    has_q = dist[rows, q] > 0
    rep = np.where(has_q[:, None], (pb + pq) / 2.0, (pa + pb + pc) / 3.0)
    ok = (nverts >= 3) & (s != 0)
    # self-check: near-degenerate rings (e.g. zero-area clipped slivers) can
    # defeat the construction; verify by parity against the own polygon
    ok &= points_in_polygons_batch(rep[:, None, :], verts, nverts)[:, 0]
    return np.where(ok[:, None], rep, pb)


def points_in_polygons_batch(
    points: np.ndarray, verts: np.ndarray, nverts: np.ndarray
) -> np.ndarray:
    """PiP for per-polygon points. points: [P,M,2]; polygons padded [P,V,2].

    Returns [P,M] bool. Fully vectorized (one pass, no Python loop) — this is
    the TPU-adapted "batched PiP" used by one-step intervalization.
    """
    points = np.asarray(points, dtype=np.float64)
    starts, ends, mask = polygon_edges(verts, nverts)
    x, y = points[..., 0][:, :, None], points[..., 1][:, :, None]   # [P,M,1]
    x0, y0 = starts[..., 0][:, None, :], starts[..., 1][:, None, :]  # [P,1,V]
    x1, y1 = ends[..., 0][:, None, :], ends[..., 1][:, None, :]
    cond = (y0 <= y) != (y1 <= y)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    cross = cond & (xint > x) & mask[:, None, :]
    return (np.sum(cross, axis=2) % 2) == 1


def points_in_polygon_rows(
    points: np.ndarray, poly_of_point: np.ndarray,
    verts: np.ndarray, nverts: np.ndarray, chunk_elems: int = 1 << 22,
) -> np.ndarray:
    """Crossing-number test where every point tests against its OWN polygon.

    points: [M,2]; poly_of_point: [M] indices into the padded polygon arrays
    ([P,V,2] / [P]). Returns [M] bool. This is the flat form the batched
    one-step construction uses: gap-head cells of many polygons classified in
    one pass (DESIGN.md §6). Row-identical to :func:`points_in_polygon`.
    """
    points = np.asarray(points, dtype=np.float64)
    poly_of_point = np.asarray(poly_of_point, np.int64)
    starts, ends, mask = polygon_edges(verts, nverts)
    M = len(points)
    V = starts.shape[1]
    out = np.zeros(M, dtype=bool)
    step = max(1, int(chunk_elems // max(1, V)))
    for i0 in range(0, M, step):
        sl = slice(i0, min(M, i0 + step))
        p = poly_of_point[sl]
        x = points[sl, 0][:, None]
        y = points[sl, 1][:, None]
        x0, y0 = starts[p, :, 0], starts[p, :, 1]
        x1, y1 = ends[p, :, 0], ends[p, :, 1]
        cond = (y0 <= y) != (y1 <= y)
        with np.errstate(divide="ignore", invalid="ignore"):
            t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
        xint = x0 + t * (x1 - x0)
        cross = cond & (xint > x) & mask[p]
        out[sl] = (np.sum(cross, axis=1) % 2) == 1
    return out


_JNP_PIP_JIT = None


def _pip_rows_jnp_impl(points, starts, ends, mask, poly_of_point):
    import jax.numpy as jnp
    x = points[:, 0][:, None]
    y = points[:, 1][:, None]
    x0, y0 = starts[poly_of_point, :, 0], starts[poly_of_point, :, 1]
    x1, y1 = ends[poly_of_point, :, 0], ends[poly_of_point, :, 1]
    cond = (y0 <= y) != (y1 <= y)
    t = (y - y0) / jnp.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    cross = cond & (xint > x) & mask[poly_of_point]
    return (jnp.sum(cross, axis=1) % 2) == 1


def points_in_polygon_rows_jnp(points, poly_of_point, verts, nverts) -> np.ndarray:
    """jnp twin of :func:`points_in_polygon_rows` (float64 under enable_x64;
    the crossing test is exact comparisons, so results are identical)."""
    global _JNP_PIP_JIT
    import jax
    from jax.experimental import enable_x64
    starts, ends, mask = polygon_edges(verts, nverts)
    with enable_x64():
        if _JNP_PIP_JIT is None:
            _JNP_PIP_JIT = jax.jit(_pip_rows_jnp_impl)
        out = _JNP_PIP_JIT(np.asarray(points, np.float64), starts, ends, mask,
                           np.asarray(poly_of_point, np.int64))
        return np.asarray(out)


def _orient(ax, ay, bx, by, cx, cy):
    """Signed orientation of triangle (a,b,c): >0 ccw, <0 cw, 0 collinear."""
    return (bx - ax) * (cy - ay) - (by - ay) * (cx - ax)


def segments_intersect(a0, a1, b0, b1) -> np.ndarray:
    """Proper/improper segment intersection test, broadcastable.

    a0,a1,b0,b1: [...,2]. Returns bool array of the broadcast shape.
    Handles collinear-overlap via on-segment checks.
    """
    a0 = np.asarray(a0, np.float64); a1 = np.asarray(a1, np.float64)
    b0 = np.asarray(b0, np.float64); b1 = np.asarray(b1, np.float64)
    d1 = _orient(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a0[..., 0], a0[..., 1])
    d2 = _orient(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a1[..., 0], a1[..., 1])
    d3 = _orient(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b0[..., 0], b0[..., 1])
    d4 = _orient(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b1[..., 0], b1[..., 1])
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0)) \
        & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0)

    def on_seg(px, py, qx, qy, rx, ry):
        # r collinear with pq assumed; is r within the pq bounding box?
        return (
            (np.minimum(px, qx) <= rx) & (rx <= np.maximum(px, qx))
            & (np.minimum(py, qy) <= ry) & (ry <= np.maximum(py, qy))
        )

    touch = (
        ((d1 == 0) & on_seg(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a0[..., 0], a0[..., 1]))
        | ((d2 == 0) & on_seg(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1], a1[..., 0], a1[..., 1]))
        | ((d3 == 0) & on_seg(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b0[..., 0], b0[..., 1]))
        | ((d4 == 0) & on_seg(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1], b1[..., 0], b1[..., 1]))
    )
    return proper | touch


def polygons_intersect(
    verts_a: np.ndarray, na: int, verts_b: np.ndarray, nb: int
) -> bool:
    """Exact polygon-polygon intersection (the refinement oracle).

    True iff boundaries cross, or one polygon contains the other.
    """
    va = np.asarray(verts_a, np.float64)[: int(na)]
    vb = np.asarray(verts_b, np.float64)[: int(nb)]
    a0 = va; a1 = np.roll(va, -1, axis=0)
    b0 = vb; b1 = np.roll(vb, -1, axis=0)
    hit = segments_intersect(
        a0[:, None, :], a1[:, None, :], b0[None, :, :], b1[None, :, :]
    )
    if bool(hit.any()):
        return True
    # containment: representative interior points, closed-region classified.
    # A raw first vertex can sit (numerically) on the other boundary, where
    # crossing parity may land either side — a false negative on touching
    # containment.
    ra = representative_points(va[None], np.asarray([len(va)]))[0]
    rb = representative_points(vb[None], np.asarray([len(vb)]))[0]
    if bool(points_in_polygon_closed(ra[None], vb)[0]):
        return True
    if bool(points_in_polygon_closed(rb[None], va)[0]):
        return True
    return False


def polygon_within(verts_a: np.ndarray, na: int, verts_b: np.ndarray, nb: int) -> bool:
    """Exact 'a within b' (a's area subset of b's). Boundary-touching counts
    as within (closed-region semantics), matching the paper's within joins."""
    va = np.asarray(verts_a, np.float64)[: int(na)]
    vb = np.asarray(verts_b, np.float64)[: int(nb)]
    # every vertex of a inside (or on) b — exact on-boundary classification;
    # the previous nudge-toward-centroid fallback was unsound for concave
    # containers (the centroid may be outside; the nudge direction wrong)
    if not points_in_polygon_closed(va, vb).all():
        return False
    # ... and no proper boundary crossing
    a0 = va; a1 = np.roll(va, -1, axis=0)
    b0 = vb; b1 = np.roll(vb, -1, axis=0)
    d1 = _orient(b0[None, :, 0], b0[None, :, 1], b1[None, :, 0], b1[None, :, 1], a0[:, None, 0], a0[:, None, 1])
    d2 = _orient(b0[None, :, 0], b0[None, :, 1], b1[None, :, 0], b1[None, :, 1], a1[:, None, 0], a1[:, None, 1])
    d3 = _orient(a0[:, None, 0], a0[:, None, 1], a1[:, None, 0], a1[:, None, 1], b0[None, :, 0], b0[None, :, 1])
    d4 = _orient(a0[:, None, 0], a0[:, None, 1], a1[:, None, 0], a1[:, None, 1], b1[None, :, 0], b1[None, :, 1])
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0)) \
        & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0)
    return not bool(proper.any())


def polygon_area(verts: np.ndarray, n: int | None = None) -> float:
    """Shoelace area (absolute)."""
    v = np.asarray(verts, np.float64)
    if n is not None:
        v = v[: int(n)]
    x, y = v[:, 0], v[:, 1]
    return float(abs(np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y)) / 2.0)


def clip_polygon_to_box(verts: np.ndarray, box: tuple[float, float, float, float]) -> np.ndarray:
    """Sutherland–Hodgman clip of a polygon to an axis-aligned box.

    Host-side helper for RA/RI construction (coverage-fraction labeling).
    Returns the clipped ring [K,2] (possibly empty).
    """
    xmin, ymin, xmax, ymax = box

    def clip_half(poly, inside, intersect):
        out = []
        k = len(poly)
        for i in range(k):
            cur, nxt = poly[i], poly[(i + 1) % k]
            cin, nin = inside(cur), inside(nxt)
            if cin:
                out.append(cur)
                if not nin:
                    out.append(intersect(cur, nxt))
            elif nin:
                out.append(intersect(cur, nxt))
        return out

    def ix_x(c, n, x):
        t = (x - c[0]) / (n[0] - c[0])
        return (x, c[1] + t * (n[1] - c[1]))

    def ix_y(c, n, y):
        t = (y - c[1]) / (n[1] - c[1])
        return (c[0] + t * (n[0] - c[0]), y)

    # y-planes first: the batched construction pass shares the two y-clips
    # across every cell of a grid row (same band), so the sequential
    # reference must clip in the same order to stay bit-identical.
    poly = [tuple(p) for p in np.asarray(verts, np.float64)]
    poly = clip_half(poly, lambda p: p[1] >= ymin, lambda c, n: ix_y(c, n, ymin))
    if poly:
        poly = clip_half(poly, lambda p: p[1] <= ymax, lambda c, n: ix_y(c, n, ymax))
    if poly:
        poly = clip_half(poly, lambda p: p[0] >= xmin, lambda c, n: ix_x(c, n, xmin))
    if poly:
        poly = clip_half(poly, lambda p: p[0] <= xmax, lambda c, n: ix_x(c, n, xmax))
    return np.asarray(poly, np.float64).reshape(-1, 2)


# ---------------------------------------------------------------------------
# Batched box clipping (DESIGN.md §6): one padded Sutherland–Hodgman pass over
# all (cell x edge) pairs of a construction batch. Row k clips ring k to box
# k; the four half-plane passes and the shoelace use exactly the formulas of
# the sequential clip_polygon_to_box/polygon_area pair, so per-row results
# match the per-cell reference loop.
# ---------------------------------------------------------------------------

# clip sequence: (coordinate axis, box column, keep-greater-or-equal);
# y-planes first — see clip_polygon_to_box
_CLIP_PASSES = ((1, 1, True), (1, 3, False), (0, 0, True), (0, 2, False))


def _clip_halfplane_batch(pts, cnt, axis, bound, keep_ge):
    """One half-plane Sutherland–Hodgman pass over K padded rings.

    pts [K,V,2], cnt [K], bound [K] (per-row clip line). Returns
    (out [K,Vout,2], new_cnt [K]); each input vertex emits at most itself
    plus one intersection (a non-convex ring may cross the line many times,
    so Vout can exceed V+1 — it is sized to the actual max emission).
    """
    K, V, _ = pts.shape
    if V == 0:
        return np.zeros((K, 1, 2), np.float64), np.zeros(K, np.int64)
    idx = np.arange(V)[None, :]
    valid = idx < cnt[:, None]
    rows = np.broadcast_to(np.arange(K)[:, None], (K, V))
    # ring successor: roll, then rewrite each ring's wrap slot (cnt-1 -> 0)
    nxt_pts = np.roll(pts, -1, axis=1)
    nxt_pts[np.arange(K), np.maximum(cnt - 1, 0)] = pts[:, 0]
    c = pts[..., axis]
    n_ = nxt_pts[..., axis]
    b = bound[:, None]
    cin = (c >= b) if keep_ge else (c <= b)
    nin = (n_ >= b) if keep_ge else (n_ <= b)
    emit_cur = cin & valid
    emit_ix = (cin != nin) & valid
    n_emit = np.add(emit_cur, emit_ix, dtype=np.int32)
    pos = np.cumsum(n_emit, axis=1, dtype=np.int32) - n_emit  # excl. prefix
    new_cnt = n_emit.sum(axis=1).astype(np.int64)
    Vout = max(1, int(new_cnt.max()) if K else 1)
    out = np.zeros((K, Vout, 2), np.float64)
    out[rows[emit_cur], pos[emit_cur]] = pts[emit_cur]
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (b - c) / np.where(n_ == c, 1.0, n_ - c)
    ix = np.empty((K, V, 2), np.float64)
    ix[..., axis] = np.broadcast_to(b, (K, V))
    ix[..., 1 - axis] = pts[..., 1 - axis] + t * (nxt_pts[..., 1 - axis]
                                                 - pts[..., 1 - axis])
    pos_ix = pos + emit_cur
    out[rows[emit_ix], pos_ix[emit_ix]] = ix[emit_ix]
    return out, new_cnt


def _ring_areas(pts, cnt):
    """Absolute shoelace area of K padded rings (padding contributes 0)."""
    K, V, _ = pts.shape
    idx = np.arange(V)[None, :]
    valid = idx < cnt[:, None]
    nxt_pts = np.roll(pts, -1, axis=1)
    nxt_pts[np.arange(K), np.maximum(cnt - 1, 0)] = pts[:, 0]
    terms = pts[..., 0] * nxt_pts[..., 1] - nxt_pts[..., 0] * pts[..., 1]
    return np.abs(np.where(valid, terms, 0.0).sum(axis=1)) / 2.0


def box_clip_areas(verts, nverts, boxes) -> np.ndarray:
    """Area of (ring ∩ axis-aligned box) for K independent rows at once.

    verts [K,V,2] padded rings, nverts [K], boxes [K,4] (xmin,ymin,xmax,ymax).
    Returns [K] float64 absolute areas; rows whose clipped ring degenerates
    (< 3 vertices) report 0, matching the sequential reference.
    """
    pts = np.asarray(verts, np.float64)
    cnt = np.asarray(nverts, np.int64)
    boxes = np.asarray(boxes, np.float64)
    for axis, col, keep_ge in _CLIP_PASSES:
        pts, cnt = _clip_halfplane_batch(pts, cnt, axis, boxes[:, col], keep_ge)
    return np.where(cnt >= 3, _ring_areas(pts, cnt), 0.0)


_JNP_CLIP_JIT = None


def _box_clip_areas_jnp_impl(pts, cnt, boxes):
    import jax
    import jax.numpy as jnp

    def halfplane(pts, cnt, axis, bound, keep_ge):
        K, V = pts.shape[0], pts.shape[1]
        idx = jnp.arange(V)[None, :]
        valid = idx < cnt[:, None]
        rows = jnp.broadcast_to(jnp.arange(K)[:, None], (K, V))
        nxt = jnp.where(valid, (idx + 1) % jnp.maximum(cnt[:, None], 1), 0)
        nxt_pts = jnp.take_along_axis(
            pts, jnp.broadcast_to(nxt[..., None], (K, V, 2)), axis=1)
        c = pts[..., axis]
        n_ = nxt_pts[..., axis]
        b = bound[:, None]
        cin = (c >= b) if keep_ge else (c <= b)
        nin = (n_ >= b) if keep_ge else (n_ <= b)
        emit_cur = cin & valid
        emit_ix = (cin != nin) & valid
        n_emit = emit_cur.astype(jnp.int32) + emit_ix.astype(jnp.int32)
        pos = jnp.cumsum(n_emit, axis=1) - n_emit
        # static worst case: every vertex emits itself + one intersection
        # (non-convex rings can cross the line many times)
        dump = 2 * V                               # masked writes land here
        out = jnp.zeros((K, 2 * V + 1, 2), pts.dtype)
        out = out.at[rows, jnp.where(emit_cur, pos, dump)].set(pts)
        t = (b - c) / jnp.where(n_ == c, 1.0, n_ - c)
        # barrier keeps XLA from fusing mul+add into an FMA, which would
        # round vertices 1 ulp off the numpy path
        step = jax.lax.optimization_barrier(
            t * (nxt_pts[..., 1 - axis] - pts[..., 1 - axis]))
        other = pts[..., 1 - axis] + step
        bb = jnp.broadcast_to(b, (K, V))
        ix = (jnp.stack([bb, other], -1) if axis == 0
              else jnp.stack([other, bb], -1))
        out = out.at[rows, jnp.where(emit_ix, pos + emit_cur, dump)].set(ix)
        return out[:, : 2 * V], n_emit.sum(axis=1)

    for axis, col, keep_ge in _CLIP_PASSES:
        pts, cnt = halfplane(pts, cnt, axis, boxes[:, col], keep_ge)
    return pts, cnt


def box_clip_areas_jnp(verts, nverts, boxes) -> np.ndarray:
    """jnp twin of :func:`box_clip_areas` (float64 under enable_x64).

    The four half-plane passes run on device; the shoelace runs on host via
    :func:`_ring_areas` over the same trimmed width so the reduction order
    matches the numpy path. Caveat: XLA CPU fast-math may round individual
    intersection vertices 1 ulp differently (despite the FMA barrier), so
    coverage fractions can differ at the ~1e-16 level — a class flip needs a
    fraction within ulps of a threshold, which general-position data does
    not produce. The 'numpy' backend is the bit-identical reference.
    """
    global _JNP_CLIP_JIT
    import jax
    from jax.experimental import enable_x64
    with enable_x64():
        if _JNP_CLIP_JIT is None:
            _JNP_CLIP_JIT = jax.jit(_box_clip_areas_jnp_impl)
        pts, cnt = _JNP_CLIP_JIT(np.asarray(verts, np.float64),
                                 np.asarray(nverts, np.int64),
                                 np.asarray(boxes, np.float64))
    pts = np.asarray(pts)
    cnt = np.asarray(cnt, np.int64)
    W = max(1, int(cnt.max()) if len(cnt) else 1)
    return np.where(cnt >= 3, _ring_areas(pts[:, :W], cnt), 0.0)


def box_clip_areas_rows(verts, nverts, poly_of_row, boxes,
                        backend: str = "numpy",
                        chunk_elems: int = 1 << 22) -> np.ndarray:
    """Row-bucketed driver over the batched clip: row k clips polygon
    ``poly_of_row[k]`` (padded [P,V,2]/[P]) to ``boxes[k]``.

    The numpy path shares work across a construction batch: all cells of one
    grid row of one polygon carry the exact same (ymin, ymax), so the two
    y-plane passes run once per unique *band* and only the two x-plane
    passes run per cell — identical results (same pass order as
    :func:`clip_polygon_to_box`), a fraction of the work. Buckets by
    power-of-two vertex-count class bound padding waste; chunks bound the
    padded working set below ``chunk_elems``.
    """
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    poly_of_row = np.asarray(poly_of_row, np.int64)
    boxes = np.asarray(boxes, np.float64)
    K = len(poly_of_row)
    out = np.zeros(K, np.float64)
    if K == 0:
        return out

    if backend == "jnp":
        # generic per-row device pass (same pass order => same results); the
        # static 2x-per-clip padding wants smaller chunks
        nv = nverts[poly_of_row]
        for sel in size_buckets(nv, min(chunk_elems, 1 << 18)):
            Vb = int(nv[sel].max())
            out[sel] = box_clip_areas_jnp(
                verts[:, :Vb][poly_of_row[sel]], nv[sel], boxes[sel])
        return out

    # --- unique (polygon, ymin, ymax) bands ---------------------------------
    bandkey = np.stack([poly_of_row.astype(np.float64),
                        boxes[:, 1], boxes[:, 3]], axis=1)
    uniq, band_of_row = np.unique(bandkey, axis=0, return_inverse=True)
    band_of_row = band_of_row.ravel()
    band_poly = uniq[:, 0].astype(np.int64)
    B = len(uniq)

    # y-passes once per band, bucketed by polygon vertex class
    nvb = nverts[band_poly]
    chunks = []                       # (band sel, pts, cnt)
    for sel in size_buckets(nvb, chunk_elems):
        Vb = int(nvb[sel].max())
        pts = verts[:, :Vb][band_poly[sel]]
        cnt = nvb[sel]
        for axis, col, keep_ge in _CLIP_PASSES[:2]:
            bound = uniq[sel, 1] if col == 1 else uniq[sel, 2]
            pts, cnt = _clip_halfplane_batch(pts, cnt, axis, bound, keep_ge)
        chunks.append((sel, pts, cnt))

    # assemble the padded band-ring store
    band_cnt = np.zeros(B, np.int64)
    for sel, _, cnt in chunks:
        band_cnt[sel] = cnt
    W = max(1, int(band_cnt.max()))
    band_pts = np.zeros((B, W, 2), np.float64)
    for sel, pts, _ in chunks:
        band_pts[sel, : pts.shape[1]] = pts[:, :W]

    # x-passes per cell row, bucketed by band-ring size class (rows whose
    # band clipped away entirely are skipped by the bucketing and stay 0)
    cntr = band_cnt[band_of_row]
    for sel in size_buckets(cntr, chunk_elems):
        Wb = int(cntr[sel].max())
        pts = band_pts[:, :Wb][band_of_row[sel]]
        cnt = cntr[sel]
        for axis, col, keep_ge in _CLIP_PASSES[2:]:
            pts, cnt = _clip_halfplane_batch(pts, cnt, axis,
                                             boxes[sel, col], keep_ge)
        out[sel] = np.where(cnt >= 3, _ring_areas(pts, cnt), 0.0)
    return out
