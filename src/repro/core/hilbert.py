"""Vectorized Hilbert-curve cell ordering.

The paper orders the cells of the global ``2^N x 2^N`` grid (N=16) along the
Hilbert curve so that sets of intersected cells compress into few intervals.
Cell ids live in ``[0, 2^(2N))`` — for N=16 that is the full uint32 range.

TPU note: int32 is the native integer type on the TPU VPU, and Pallas/TPU
comparisons are cheapest on int32. We therefore keep ids in uint32 on host and
provide an order-preserving bijection into *biased int32* (XOR with 2^31) for
the on-device interval arrays: ``u32 ids  a < b  <=>  biased(a) < biased(b)``.

Both numpy (host/preprocessing) and jnp (device) implementations of the
standard iterative xy<->d algorithm are provided; loops run a fixed N times
and are fully vectorized across cells.
"""
from __future__ import annotations

import numpy as np

try:  # jnp is optional at import time for pure-host tooling
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None

__all__ = [
    "xy2d", "d2xy", "xy2d_jnp", "d2xy_jnp",
    "u32_to_biased_i32", "biased_i32_to_u32",
]


def xy2d(n_order: int, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Hilbert index of cells (x, y) on a 2^n_order grid. Vectorized.

    x, y: integer arrays (any shape) in [0, 2^n_order). Returns uint64 for
    headroom on host (values fit uint32 for n_order <= 16).
    """
    x = np.asarray(x, dtype=np.uint64).copy()
    y = np.asarray(y, dtype=np.uint64).copy()
    d = np.zeros_like(x, dtype=np.uint64)
    s = np.uint64(1) << np.uint64(n_order - 1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.uint64)
        ry = ((y & s) > 0).astype(np.uint64)
        d += s * s * ((np.uint64(3) * rx) ^ ry)
        # rotate quadrant
        flip = ry == 0
        swapmask = flip & (rx == 1)
        x_f = np.where(swapmask, s - np.uint64(1) - x, x)
        y_f = np.where(swapmask, s - np.uint64(1) - y, y)
        x, y = np.where(flip, y_f, x_f), np.where(flip, x_f, y_f)
        s >>= np.uint64(1)
    return d


def d2xy(n_order: int, d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`xy2d`. d: integer array. Returns (x, y) uint64."""
    d = np.asarray(d, dtype=np.uint64)
    t = d.copy()
    x = np.zeros_like(d, dtype=np.uint64)
    y = np.zeros_like(d, dtype=np.uint64)
    s = np.uint64(1)
    side = np.uint64(1) << np.uint64(n_order)
    while s < side:
        rx = (t // np.uint64(2)) & np.uint64(1)
        ry = (t ^ rx) & np.uint64(1)
        # rotate
        flip = ry == 0
        swapmask = flip & (rx == 1)
        x_f = np.where(swapmask, s - np.uint64(1) - x, x)
        y_f = np.where(swapmask, s - np.uint64(1) - y, y)
        x, y = np.where(flip, y_f, x_f), np.where(flip, x_f, y_f)
        x += s * rx
        y += s * ry
        t //= np.uint64(4)
        s <<= np.uint64(1)
    return x, y


def xy2d_jnp(n_order: int, x, y):
    """jnp version of :func:`xy2d`; returns uint32 (n_order <= 16)."""
    assert jnp is not None, "jax not available"
    x = x.astype(jnp.uint32)
    y = y.astype(jnp.uint32)
    d = jnp.zeros_like(x, dtype=jnp.uint32)
    for k in range(n_order - 1, -1, -1):
        s = jnp.uint32(1 << k)
        rx = ((x & s) > 0).astype(jnp.uint32)
        ry = ((y & s) > 0).astype(jnp.uint32)
        d = d + (s * s) * ((jnp.uint32(3) * rx) ^ ry)
        flip = ry == 0
        swapmask = flip & (rx == 1)
        x_f = jnp.where(swapmask, s - 1 - x, x)
        y_f = jnp.where(swapmask, s - 1 - y, y)
        x, y = jnp.where(flip, y_f, x_f), jnp.where(flip, x_f, y_f)
    return d


def d2xy_jnp(n_order: int, d):
    """jnp inverse; d uint32 -> (x, y) uint32."""
    assert jnp is not None, "jax not available"
    t = d.astype(jnp.uint32)
    x = jnp.zeros_like(t)
    y = jnp.zeros_like(t)
    for k in range(n_order):
        s = jnp.uint32(1 << k)
        rx = (t >> 1) & 1
        ry = (t ^ rx) & 1
        flip = ry == 0
        swapmask = flip & (rx == 1)
        x_f = jnp.where(swapmask, s - 1 - x, x)
        y_f = jnp.where(swapmask, s - 1 - y, y)
        x, y = jnp.where(flip, y_f, x_f), jnp.where(flip, x_f, y_f)
        x = x + s * rx
        y = y + s * ry
        t = t >> 2
    return x, y


def u32_to_biased_i32(u: np.ndarray) -> np.ndarray:
    """Order-preserving uint32 -> int32 (XOR 2^31). Host-side."""
    u = np.ascontiguousarray(np.asarray(u).astype(np.uint32))
    return (u ^ np.uint32(0x80000000)).view(np.int32)


def biased_i32_to_u32(i: np.ndarray) -> np.ndarray:
    """Inverse of :func:`u32_to_biased_i32`."""
    return (np.asarray(i, dtype=np.int32).view(np.uint32) ^ np.uint32(0x80000000))
