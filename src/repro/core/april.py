"""APRIL approximation store (§4): per-polygon A- and F-interval lists.

Host storage is CSR-style: one flat [sum_I, 2] uint64 interval array plus
[P+1] offsets, per list kind. Device batches are packed on demand
(:func:`pack_pairs` in ``join.py``) into padded int32 *biased* arrays with an
inclusive-last representation (`end - 1`), which keeps every endpoint inside
int32 — the TPU-native integer — even for N=16 where half-open ends reach
2^32 (see ``hilbert.u32_to_biased_i32``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import intervalize, rasterize
from .rasterize import Extent, GLOBAL_EXTENT

__all__ = ["AprilStore", "build_april", "build_april_polygon"]


@dataclass
class AprilStore:
    """APRIL approximations for one dataset."""
    n_order: int
    extent: Extent
    a_off: np.ndarray    # [P+1] int64
    a_ints: np.ndarray   # [sum_Ia, 2] uint64
    f_off: np.ndarray    # [P+1] int64
    f_ints: np.ndarray   # [sum_If, 2] uint64

    def __len__(self) -> int:
        return len(self.a_off) - 1

    def a_list(self, i: int) -> np.ndarray:
        return self.a_ints[self.a_off[i]: self.a_off[i + 1]]

    def f_list(self, i: int) -> np.ndarray:
        return self.f_ints[self.f_off[i]: self.f_off[i + 1]]

    def num_intervals(self) -> tuple[int, int]:
        return len(self.a_ints), len(self.f_ints)

    def size_bytes(self) -> int:
        """Uncompressed size: every endpoint is a 32-bit unsigned int (paper
        §3.2/N=16 choice), plus the offset tables."""
        return 4 * 2 * (len(self.a_ints) + len(self.f_ints)) \
            + 8 * (len(self.a_off) + len(self.f_off))


def build_april_polygon(
    verts: np.ndarray, n: int, n_order: int,
    extent: Extent = GLOBAL_EXTENT, method: str = "batched",
) -> tuple[np.ndarray, np.ndarray]:
    """(A-list, F-list) for one polygon. ``method``: 'batched' | 'pips' |
    'neighbors' (one-step, §6.2) or 'scanline' | 'floodfill' (§6.1)."""
    if method in ("batched", "pips", "neighbors"):
        return intervalize.onestep(verts, n, n_order, extent, method=method)
    partial = rasterize.dda_partial_cells(verts, n, n_order, extent)
    if method == "scanline":
        full = rasterize.scanline_full_cells(verts, n, partial, n_order, extent)
    elif method == "floodfill":
        full = rasterize.floodfill_classify(verts, n, partial, n_order, extent)
    else:
        raise ValueError(f"unknown construction method {method!r}")
    return intervalize.april_from_cells(partial, full, n_order)


def build_april(
    dataset, n_order: int, extent: Extent = GLOBAL_EXTENT,
    method: str = "batched", backend: str = "numpy",
) -> AprilStore:
    """Build the APRIL store for a PolygonDataset.

    ``backend``: 'numpy' | 'jnp' run the dataset-level batched construction
    (one multi-polygon DDA + one PiP pass over all gap heads, DESIGN.md §6);
    'sequential' keeps the per-polygon reference loop. Non-'batched'
    ``method`` variants (pips / neighbors / scanline / floodfill) are
    inherently per-polygon and always take the sequential path.
    """
    if method == "batched" and backend != "sequential":
        a_off, a_ints, f_off, f_ints = intervalize.onestep_multi(
            dataset.verts, dataset.nverts, n_order, extent, backend=backend)
        return AprilStore(n_order=n_order, extent=extent,
                          a_off=a_off, a_ints=a_ints,
                          f_off=f_off, f_ints=f_ints)
    a_off = [0]; f_off = [0]
    a_chunks = []; f_chunks = []
    for i in range(len(dataset)):
        a, f = build_april_polygon(
            dataset.verts[i], int(dataset.nverts[i]), n_order, extent, method)
        a_chunks.append(a); f_chunks.append(f)
        a_off.append(a_off[-1] + len(a))
        f_off.append(f_off[-1] + len(f))
    cat = lambda chunks: (np.concatenate(chunks, axis=0)
                          if chunks else np.zeros((0, 2), np.uint64))
    return AprilStore(
        n_order=n_order, extent=extent,
        a_off=np.asarray(a_off, np.int64), a_ints=cat(a_chunks),
        f_off=np.asarray(f_off, np.int64), f_ints=cat(f_chunks),
    )
