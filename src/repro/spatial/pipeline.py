"""Deprecation shims over the `JoinPlan` session API (DESIGN.md §2).

The original entry points — ``spatial_intersection_join(method=...)`` and
the within/linestring/selection variants — are kept as thin wrappers so
existing call sites continue to work. New code should use::

    from repro.spatial import JoinPlan
    plan = JoinPlan(R, S, filter="ri", filter_backend="jnp", n_order=10)
    plan.build()
    results, stats = plan.execute("intersects")

with pluggable intermediate filters (the registry in ``spatial.filters``):

    'none'    no intermediate step (refine everything)
    'april'   APRIL A/F interval lists (Algorithm 2)          [this paper]
    'april-c' APRIL over VByte-compressed lists (§5.1)        [this paper]
    'ri'      Raster Intervals with bitstring codes (Alg. 1)  [this paper]
    'ra'      Zimbrao & de Souza raster approximation [58]
    '5cch'    Brinkhoff 5-corner + convex hull [9]

Every filter evaluates whole candidate batches for every predicate
(intersects / within / linestring / selection); statistics keep the shape
of the paper's Tables 5/13/16/17 and Fig. 13. All four pipeline stages
are dataset-batched behind backend knobs forwarded to ``JoinPlan`` —
``mbr_backend`` (candidate generation, DESIGN.md §8), ``filter_backend``
(the bucketed filter joins, §9; ``use_jnp`` is its legacy spelling),
``build_backend`` via build options (§6), and ``refine_backend`` (§7);
see the README "Pipeline stages & backends" table. ``pipeline_mode``
(DESIGN.md §12) selects staged (host stage boundaries, default) or fused
(device-resident chain, one end-of-chain sync) execution; ``plan_mode``
(DESIGN.md §13) selects static knobs (default) or the sample-based
adaptive planner that picks method/granularity/order per workload.
"""
from __future__ import annotations

import numpy as np

from ..core.april import AprilStore
from ..core.compress import compress_april
from .plan import JoinPlan, JoinStats

__all__ = ["JoinStats", "spatial_intersection_join", "spatial_within_join",
           "polygon_linestring_join", "selection_queries",
           "tiled_spatial_join"]


def _plan(R, S, method, n_order, *, filter_backend="numpy",
          refine_backend="numpy", mbr_backend="numpy", mbr_grid=None,
          max_ra_cells=None, order=None, r_kind="polygon",
          pipeline_mode="staged", plan_mode="static"):
    build_opts = {}
    filter_opts = {}
    if method == "ra" and max_ra_cells is not None:
        build_opts["max_cells"] = max_ra_cells
    if order is not None and method in ("april", "april-c"):
        filter_opts["order"] = order
    return JoinPlan(R, S, filter=method, filter_backend=filter_backend,
                    refine_backend=refine_backend, mbr_backend=mbr_backend,
                    n_order=n_order, mbr_grid=mbr_grid, r_kind=r_kind,
                    pipeline_mode=pipeline_mode, plan_mode=plan_mode,
                    build_opts=build_opts, filter_opts=filter_opts)


def _adopt(method: str, store):
    """Adapt legacy prebuilt stores: APRIL-C call sites used to pass raw
    AprilStores and compress inside the pipeline."""
    if store is not None and method == "april-c" \
            and isinstance(store, AprilStore):
        return compress_april(store)
    return store


def spatial_intersection_join(
    R, S, method: str = "april", n_order: int = 10,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
    use_jnp: bool = False, max_ra_cells: int = 750,
    prebuilt: tuple | None = None, mbr_grid: int | None = None,
    refine_backend: str = "numpy", mbr_backend: str = "numpy",
    filter_backend: str | None = None, pipeline_mode: str = "staged",
    plan_mode: str = "static",
) -> tuple[np.ndarray, JoinStats]:
    """Deprecated shim: run the full pipeline; returns (pairs [K,2], stats).

    Prefer ``JoinPlan(R, S, filter=method).build().execute("intersects")``.
    ``filter_backend`` overrides the legacy ``use_jnp`` switch;
    ``plan_mode="adaptive"`` lets the §13 planner override method/order.
    """
    plan = _plan(R, S, method, n_order,
                 filter_backend=filter_backend
                 or ("jnp" if use_jnp else "numpy"),
                 refine_backend=refine_backend, mbr_backend=mbr_backend,
                 mbr_grid=mbr_grid, max_ra_cells=max_ra_cells, order=order,
                 pipeline_mode=pipeline_mode, plan_mode=plan_mode)
    if prebuilt is not None:
        pr, ps = prebuilt
        plan.build(prebuilt=(_adopt(method, pr), _adopt(method, ps)))
    return plan.execute("intersects")


def tiled_spatial_join(
    r_chunks, s_chunks, predicate: str = "intersects",
    method: str = "april", n_order: int = 10,
    tile_budget: int | None = None, balance: str = "cost",
    ckpt_dir: str | None = None, resume: bool = True,
    filter_backend: str = "numpy", refine_backend: str = "numpy",
    mbr_backend: str = "numpy", pipeline_mode: str = "staged",
    plan_mode: str = "static", **scaleout_opts,
) -> tuple[np.ndarray, JoinStats]:
    """Pipeline-flavored front door to the out-of-core tiled driver
    (DESIGN.md §14): same knob names as the shims above, plus the
    partitioner's ``tile_budget`` (resident bytes per tile) / ``balance``
    and the checkpoint pair ``ckpt_dir`` / ``resume`` (rerun with
    ``resume=True`` to continue at the first unfinished tile). Inputs are
    chunk iterators or in-memory datasets (auto-chunked); result pairs are
    global ids, set-identical to the in-memory shims for every method x
    predicate. Thin forwarder to
    :func:`~repro.spatial.scaleout.tiled_join`."""
    from .scaleout import SCALEOUT_DEFAULTS, tiled_join
    if tile_budget is not None:
        scaleout_opts["tile_budget"] = tile_budget
    scaleout_opts.setdefault("tile_budget", SCALEOUT_DEFAULTS["tile_budget"])
    return tiled_join(r_chunks, s_chunks, predicate=predicate,
                      method=method, n_order=n_order,
                      filter_backend=filter_backend,
                      refine_backend=refine_backend,
                      mbr_backend=mbr_backend, pipeline_mode=pipeline_mode,
                      plan_mode=plan_mode, ckpt_dir=ckpt_dir, resume=resume,
                      balance=balance, **scaleout_opts)


def spatial_within_join(
    R, S, method: str = "april", n_order: int = 10,
    prebuilt: tuple | None = None, refine_backend: str = "numpy",
    mbr_backend: str = "numpy", filter_backend: str = "numpy",
    pipeline_mode: str = "staged", plan_mode: str = "static",
) -> tuple[np.ndarray, JoinStats]:
    """Deprecated shim: within join (§4.3.2), pairs (r, s) with r within s."""
    plan = _plan(R, S, method, n_order, filter_backend=filter_backend,
                 refine_backend=refine_backend, mbr_backend=mbr_backend,
                 pipeline_mode=pipeline_mode, plan_mode=plan_mode)
    if prebuilt is not None:
        plan.build(prebuilt=tuple(_adopt(method, p) for p in prebuilt))
    return plan.execute("within")


def polygon_linestring_join(
    S, L, method: str = "april", n_order: int = 10,
    prebuilt=None, refine_backend: str = "numpy",
    mbr_backend: str = "numpy", filter_backend: str = "numpy",
    pipeline_mode: str = "staged", plan_mode: str = "static",
) -> tuple[np.ndarray, JoinStats]:
    """Deprecated shim: polygon x linestring join (§4.3.3), pairs are
    (line, poly). ``prebuilt`` is the polygon-side store."""
    plan = _plan(L, S, method, n_order, r_kind="line",
                 filter_backend=filter_backend,
                 refine_backend=refine_backend, mbr_backend=mbr_backend,
                 pipeline_mode=pipeline_mode, plan_mode=plan_mode)
    if prebuilt is not None:
        plan.build(prebuilt=(None, _adopt(method, prebuilt)))
    return plan.execute("linestring")


def selection_queries(
    data, queries, method: str = "april", n_order: int = 10, prebuilt=None,
    refine_backend: str = "numpy", mbr_backend: str = "numpy",
    filter_backend: str = "numpy", pipeline_mode: str = "staged",
    plan_mode: str = "static",
) -> tuple[list[np.ndarray], JoinStats]:
    """Deprecated shim: polygonal range queries (§4.3.1). Returns, per query
    polygon, the data polygons intersecting it. ``prebuilt`` is the
    data-side store."""
    plan = _plan(data, queries, method, n_order,
                 filter_backend=filter_backend,
                 refine_backend=refine_backend, mbr_backend=mbr_backend,
                 pipeline_mode=pipeline_mode, plan_mode=plan_mode)
    if prebuilt is not None:
        plan.build(prebuilt=(_adopt(method, prebuilt), None))
    pairs, stats = plan.execute("selection")
    results = [pairs[pairs[:, 1] == q, 0] for q in range(len(queries))]
    return results, stats
