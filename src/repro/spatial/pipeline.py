"""End-to-end spatial join pipeline: MBR filter -> intermediate filter ->
refinement (paper Fig. 1), with pluggable intermediate filters:

    'none'    no intermediate step (refine everything)
    'april'   APRIL A/F interval lists (Algorithm 2)          [this paper]
    'april-c' APRIL over VByte-compressed lists (§5.1)        [this paper]
    'ri'      Raster Intervals with bitstring codes (Alg. 1)  [this paper]
    'ra'      Zimbrao & de Souza raster approximation [58]
    '5cch'    Brinkhoff 5-corner + convex hull [9]

Returns full statistics (true hit/neg/indecisive %, per-stage wall times) —
the shape of the paper's Tables 5/13/16/17 and Fig. 13.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..baselines import fivec_ch, ra as ra_mod
from ..core import compress, join, rasterize, ri as ri_mod
from ..core.april import build_april
from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from . import refine
from .mbr_join import mbr_intersect_mask, mbr_join as _mbr_join

__all__ = ["JoinStats", "spatial_intersection_join", "spatial_within_join",
           "polygon_linestring_join", "selection_queries"]


@dataclass
class JoinStats:
    method: str
    n_candidates: int = 0
    n_true_hits: int = 0
    n_true_negs: int = 0
    n_indecisive: int = 0
    n_results: int = 0
    t_mbr: float = 0.0
    t_filter: float = 0.0
    t_refine: float = 0.0
    t_build: float = 0.0
    approx_bytes: int = 0
    extra: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        return self.t_mbr + self.t_filter + self.t_refine

    def rates(self) -> tuple[float, float, float]:
        n = max(1, self.n_candidates)
        return (self.n_true_hits / n, self.n_true_negs / n,
                self.n_indecisive / n)

    def row(self) -> str:
        h, g, i = self.rates()
        return (f"{self.method:8s} hits={h:6.2%} negs={g:6.2%} indec={i:6.2%} "
                f"mbr={self.t_mbr:.3f}s filter={self.t_filter:.3f}s "
                f"refine={self.t_refine:.3f}s total={self.t_total:.3f}s "
                f"results={self.n_results}")


def _apply_verdicts(stats: JoinStats, verdicts: np.ndarray):
    stats.n_true_hits = int(np.sum(verdicts == TRUE_HIT))
    stats.n_true_negs = int(np.sum(verdicts == TRUE_NEG))
    stats.n_indecisive = int(np.sum(verdicts == INDECISIVE))


def spatial_intersection_join(
    R, S, method: str = "april", n_order: int = 10,
    order: tuple[str, ...] = ("AA", "AF", "FA"),
    use_jnp: bool = False, max_ra_cells: int = 750,
    prebuilt: tuple | None = None, mbr_grid: int = 32,
) -> tuple[np.ndarray, JoinStats]:
    """Run the full pipeline; returns (result pairs [K,2], JoinStats)."""
    stats = JoinStats(method=method)

    t0 = time.perf_counter()
    pairs = _mbr_join(R.mbrs, S.mbrs, grid=mbr_grid)
    stats.t_mbr = time.perf_counter() - t0
    stats.n_candidates = len(pairs)
    if len(pairs) == 0:
        return np.zeros((0, 2), np.int64), stats

    # ---- build approximations (preprocessing; timed separately) ----
    t0 = time.perf_counter()
    if prebuilt is not None:
        built = prebuilt
    elif method in ("april", "april-c"):
        built = (build_april(R, n_order), build_april(S, n_order))
    elif method == "ri":
        built = (ri_mod.build_ri(R, n_order, encoding="R"),
                 ri_mod.build_ri(S, n_order, encoding="S"))
    elif method == "ra":
        built = (ra_mod.build_ra(R, max_cells=max_ra_cells),
                 ra_mod.build_ra(S, max_cells=max_ra_cells))
    elif method == "5cch":
        built = (fivec_ch.build_5cch(R), fivec_ch.build_5cch(S))
    else:
        built = (None, None)
    stats.t_build = time.perf_counter() - t0

    # ---- intermediate filter ----
    t0 = time.perf_counter()
    if method == "none":
        verdicts = np.full(len(pairs), INDECISIVE, np.int8)
    elif method == "april":
        ar, as_ = built
        stats.approx_bytes = ar.size_bytes() + as_.size_bytes()
        verdicts = join.april_filter_batch(ar, as_, pairs, order=order,
                                           use_jnp=use_jnp)
    elif method == "april-c":
        ar, as_ = built
        bufs_r = _compress_store(ar)
        bufs_s = _compress_store(as_)
        stats.approx_bytes = _bufs_bytes(bufs_r) + _bufs_bytes(bufs_s)
        t0 = time.perf_counter()   # exclude compression from filter time
        verdicts = np.asarray([
            compress.april_verdict_compressed(
                bufs_r[0][i], bufs_r[1][i], bufs_s[0][j], bufs_s[1][j])
            for i, j in pairs], np.int8)
    elif method == "ri":
        rir, ris = built
        stats.approx_bytes = rir.size_bytes() + ris.size_bytes()
        verdicts = np.asarray([
            ri_mod.ri_verdict_pair(rir, int(i), ris, int(j))
            for i, j in pairs], np.int8)
    elif method == "ra":
        rar, ras = built
        stats.approx_bytes = rar.size_bytes() + ras.size_bytes()
        verdicts = np.asarray([
            ra_mod.ra_verdict_pair(rar, int(i), ras, int(j))
            for i, j in pairs], np.int8)
    elif method == "5cch":
        cr, cs = built
        stats.approx_bytes = cr.size_bytes() + cs.size_bytes()
        verdicts = np.asarray([
            fivec_ch.fivecch_verdict_pair(cr, int(i), cs, int(j))
            for i, j in pairs], np.int8)
    else:
        raise ValueError(f"unknown method {method!r}")
    stats.t_filter = time.perf_counter() - t0
    _apply_verdicts(stats, verdicts)

    # ---- refinement ----
    t0 = time.perf_counter()
    indec = pairs[verdicts == INDECISIVE]
    ref = refine.refine_pairs(R, S, indec) if len(indec) else np.zeros(0, bool)
    stats.t_refine = time.perf_counter() - t0

    results = np.concatenate([
        pairs[verdicts == TRUE_HIT], indec[ref]], axis=0) \
        if len(pairs) else np.zeros((0, 2), np.int64)
    stats.n_results = len(results)
    return results, stats


def _compress_store(store):
    a_bufs = [compress.compress_intervals(store.a_list(i)) for i in range(len(store))]
    f_bufs = [compress.compress_intervals(store.f_list(i)) for i in range(len(store))]
    return a_bufs, f_bufs


def _bufs_bytes(bufs):
    return sum(len(b) for b, _ in bufs[0]) + sum(len(b) for b, _ in bufs[1])


def spatial_within_join(
    R, S, method: str = "april", n_order: int = 10,
    prebuilt: tuple | None = None,
) -> tuple[np.ndarray, JoinStats]:
    """Within join (§4.3.2): pairs (r, s) with r within s."""
    stats = JoinStats(method=method)
    t0 = time.perf_counter()
    # filter step for within: MBR(r) within MBR(s)
    mr, ms = R.mbrs, S.mbrs
    inside = ((mr[:, None, 0] >= ms[None, :, 0]) & (mr[:, None, 1] >= ms[None, :, 1])
              & (mr[:, None, 2] <= ms[None, :, 2]) & (mr[:, None, 3] <= ms[None, :, 3]))
    pairs = np.stack(np.nonzero(inside), axis=1).astype(np.int64)
    stats.t_mbr = time.perf_counter() - t0
    stats.n_candidates = len(pairs)
    if len(pairs) == 0:
        return np.zeros((0, 2), np.int64), stats

    t0 = time.perf_counter()
    built = prebuilt or (build_april(R, n_order), build_april(S, n_order))
    stats.t_build = time.perf_counter() - t0

    t0 = time.perf_counter()
    if method == "none":
        verdicts = np.full(len(pairs), INDECISIVE, np.int8)
    else:
        ar, as_ = built
        verdicts = np.asarray([
            join.within_verdict_pair(ar.a_list(int(i)), ar.f_list(int(i)),
                                     as_.a_list(int(j)), as_.f_list(int(j)))
            for i, j in pairs], np.int8)
    stats.t_filter = time.perf_counter() - t0
    _apply_verdicts(stats, verdicts)

    t0 = time.perf_counter()
    indec = pairs[verdicts == INDECISIVE]
    ref = refine.refine_within_pairs(R, S, indec) if len(indec) else np.zeros(0, bool)
    stats.t_refine = time.perf_counter() - t0
    results = np.concatenate([pairs[verdicts == TRUE_HIT], indec[ref]], axis=0)
    stats.n_results = len(results)
    return results, stats


def polygon_linestring_join(
    S, L, method: str = "april", n_order: int = 10,
    prebuilt=None,
) -> tuple[np.ndarray, JoinStats]:
    """Polygon x linestring intersection join (§4.3.3). Pairs are (line, poly)."""
    stats = JoinStats(method=method)
    t0 = time.perf_counter()
    import repro.core.geometry as geo
    lm = geo.polygon_mbrs(L.verts, L.nverts)
    pairs = []
    hit = mbr_intersect_mask(lm, S.mbrs)
    pairs = np.stack(np.nonzero(hit), axis=1).astype(np.int64)
    stats.t_mbr = time.perf_counter() - t0
    stats.n_candidates = len(pairs)
    if len(pairs) == 0:
        return np.zeros((0, 2), np.int64), stats

    t0 = time.perf_counter()
    store = prebuilt or build_april(S, n_order)
    line_ids = [
        rasterize.cells_to_hilbert(
            rasterize.dda_partial_cells(L.verts[i], int(L.nverts[i]), n_order,
                                        closed=False), n_order)
        for i in range(len(L))]
    stats.t_build = time.perf_counter() - t0

    t0 = time.perf_counter()
    if method == "none":
        verdicts = np.full(len(pairs), INDECISIVE, np.int8)
    else:
        verdicts = np.asarray([
            join.linestring_verdict_pair(store.a_list(int(j)),
                                         store.f_list(int(j)), line_ids[int(i)])
            for i, j in pairs], np.int8)
    stats.t_filter = time.perf_counter() - t0
    _apply_verdicts(stats, verdicts)

    t0 = time.perf_counter()
    indec = pairs[verdicts == INDECISIVE]
    ref = refine.refine_line_poly_pairs(L, S, indec) if len(indec) else np.zeros(0, bool)
    stats.t_refine = time.perf_counter() - t0
    results = np.concatenate([pairs[verdicts == TRUE_HIT], indec[ref]], axis=0)
    stats.n_results = len(results)
    return results, stats


def selection_queries(
    data, queries, method: str = "april", n_order: int = 10, prebuilt=None,
) -> tuple[list[np.ndarray], JoinStats]:
    """Polygonal range queries (§4.3.1): for each query polygon, the data
    polygons intersecting it. ``queries`` is a PolygonDataset."""
    stats = JoinStats(method=method)
    t0 = time.perf_counter()
    store = prebuilt or (build_april(data, n_order) if method != "none" else None)
    stats.t_build = time.perf_counter() - t0

    from ..core.april import build_april_polygon
    results = []
    all_verdicts = []
    pair_list = []
    t_mbr = t_filter = 0.0
    for q in range(len(queries)):
        t0 = time.perf_counter()
        qv = queries.verts[q]; qn = int(queries.nverts[q])
        qm = queries.mbrs[q]
        cand = np.nonzero(
            (data.mbrs[:, 0] <= qm[2]) & (qm[0] <= data.mbrs[:, 2])
            & (data.mbrs[:, 1] <= qm[3]) & (qm[1] <= data.mbrs[:, 3]))[0]
        t_mbr += time.perf_counter() - t0

        t0 = time.perf_counter()
        if method == "none":
            v = np.full(len(cand), INDECISIVE, np.int8)
        else:
            qa, qf = build_april_polygon(qv, qn, n_order)
            v = np.asarray([
                join.april_verdict_pair(store.a_list(int(i)), store.f_list(int(i)),
                                        qa, qf) for i in cand], np.int8)
        t_filter += time.perf_counter() - t0
        all_verdicts.append(v)
        pair_list.append(cand)
    stats.t_mbr = t_mbr
    stats.t_filter = t_filter

    t0 = time.perf_counter()
    for q, (cand, v) in enumerate(zip(pair_list, all_verdicts)):
        indec = cand[v == INDECISIVE]
        if len(indec):
            ref = np.asarray([
                refine.refine_pair(data, int(i), queries, q) for i in indec], bool)
        else:
            ref = np.zeros(0, bool)
        results.append(np.concatenate([cand[v == TRUE_HIT], indec[ref]]))
    stats.t_refine = time.perf_counter() - t0

    verd = np.concatenate(all_verdicts) if all_verdicts else np.zeros(0, np.int8)
    stats.n_candidates = len(verd)
    _apply_verdicts(stats, verd)
    stats.n_results = sum(len(r) for r in results)
    return results, stats
