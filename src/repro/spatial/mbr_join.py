"""Filter step: MBR join (paper §2, using the partition-bucket approach of
[49] with reference-point duplicate elimination [13]).

Vectorized grid-hash join: MBRs are bucketed into a coarse uniform grid; each
bucket cross-tests its R x S members; a qualifying pair is emitted only from
the bucket that contains the bottom-left corner of the pair's common MBR, so
the output is duplicate-free without sorting.
"""
from __future__ import annotations

import numpy as np

__all__ = ["mbr_join", "mbr_intersect_mask"]


def mbr_intersect_mask(mr: np.ndarray, ms: np.ndarray) -> np.ndarray:
    """Pairwise MBR intersection for [N,4] x [M,4] -> [N,M] bool."""
    return ((mr[:, None, 0] <= ms[None, :, 2]) & (ms[None, :, 0] <= mr[:, None, 2])
            & (mr[:, None, 1] <= ms[None, :, 3]) & (ms[None, :, 1] <= mr[:, None, 3]))


def _bucket_ids(mbrs: np.ndarray, k: int):
    """Bucket range [x0,x1] x [y0,y1] (inclusive) per MBR on a k x k grid."""
    lo = np.clip((mbrs[:, :2] * k).astype(np.int64), 0, k - 1)
    hi = np.clip((mbrs[:, 2:] * k).astype(np.int64), 0, k - 1)
    return lo, hi


def mbr_join(mbrs_r: np.ndarray, mbrs_s: np.ndarray, grid: int = 32) -> np.ndarray:
    """All (r, s) index pairs with intersecting MBRs. Returns [N,2] int64."""
    mbrs_r = np.asarray(mbrs_r, np.float64)
    mbrs_s = np.asarray(mbrs_s, np.float64)
    lo_r, hi_r = _bucket_ids(mbrs_r, grid)
    lo_s, hi_s = _bucket_ids(mbrs_s, grid)

    # expand each object into its covered buckets
    def expand(lo, hi):
        obj, bx, by = [], [], []
        for i in range(len(lo)):
            xs = np.arange(lo[i, 0], hi[i, 0] + 1)
            ys = np.arange(lo[i, 1], hi[i, 1] + 1)
            X, Y = np.meshgrid(xs, ys, indexing="ij")
            cnt = X.size
            obj.append(np.full(cnt, i, np.int64))
            bx.append(X.ravel()); by.append(Y.ravel())
        if not obj:
            z = np.zeros(0, np.int64)
            return z, z
        return (np.concatenate(obj),
                np.concatenate(bx) * grid + np.concatenate(by))

    obj_r, buck_r = expand(lo_r, hi_r)
    obj_s, buck_s = expand(lo_s, hi_s)

    order_r = np.argsort(buck_r, kind="stable")
    order_s = np.argsort(buck_s, kind="stable")
    obj_r, buck_r = obj_r[order_r], buck_r[order_r]
    obj_s, buck_s = obj_s[order_s], buck_s[order_s]

    pairs = []
    # walk common buckets
    ur, idx_r = np.unique(buck_r, return_index=True)
    us, idx_s = np.unique(buck_s, return_index=True)
    common, ir, is_ = np.intersect1d(ur, us, return_indices=True)
    bounds_r = np.append(idx_r, len(buck_r))
    bounds_s = np.append(idx_s, len(buck_s))
    for c, a, b in zip(common, ir, is_):
        rs = obj_r[bounds_r[a]: bounds_r[a + 1]]
        ss = obj_s[bounds_s[b]: bounds_s[b + 1]]
        mr = mbrs_r[rs]; ms = mbrs_s[ss]
        hit = mbr_intersect_mask(mr, ms)
        # reference point: bottom-left of the common MBR must be in bucket c
        rx = np.maximum(mr[:, None, 0], ms[None, :, 0])
        ry = np.maximum(mr[:, None, 1], ms[None, :, 1])
        bx = np.clip((rx * grid).astype(np.int64), 0, grid - 1)
        by = np.clip((ry * grid).astype(np.int64), 0, grid - 1)
        owner = (bx * grid + by) == c
        ii, jj = np.nonzero(hit & owner)
        if len(ii):
            pairs.append(np.stack([rs[ii], ss[jj]], axis=1))
    if not pairs:
        return np.zeros((0, 2), np.int64)
    return np.concatenate(pairs, axis=0)
