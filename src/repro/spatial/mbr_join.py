"""Candidate generation: the batched partitioned MBR join (paper §2,
DESIGN.md §8).

First of the four pipeline stages (MBR filter -> intermediate filter ->
construction-backed verdicts -> refinement): produce every (r, s) pair
whose MBRs intersect, duplicate-free, without materializing the dense
[N, M] cross test. The algorithm is the partition-bucket approach of
Tsitsigkos & Mamoulis [49] with reference-point duplicate elimination
[13]: MBRs are hashed into a coarse uniform grid over the *joint data
extent*, co-bucketed pairs are cross-tested, and a qualifying pair is
emitted only from the bucket containing the bottom-left corner of the
pair's common MBR.

Batching contract (the ``mbr_backend`` knob on
:class:`~repro.spatial.plan.JoinPlan`, mirroring ``build_backend`` /
``refine_backend``):

* ``sequential`` — the per-object expansion loop and per-bucket cross-test
  walk (the pre-batching reference, order-identical to it); every batched
  backend must produce the identical pair *set*.
* ``numpy`` — fully vectorized: bucket expansion via repeat/cumsum offset
  arithmetic, a sort-merge join over the two flat (object, bucket) tables,
  and one vectorized intersection + reference-point ownership mask over
  the co-bucket cross-product rows. No per-object or per-bucket Python.
* ``jnp`` — the same candidate rows evaluated on device: the mask pass
  (MBR gathers, interval tests, integer ownership test) is jit-compiled
  over padded row batches. ``spatial.distributed.distributed_mbr_join``
  shards the identical mask pass over the mesh 'data' axis.

The grid granularity adapts to the data (Kipf et al., *Adaptive Geospatial
Joins*): :func:`adaptive_grid` picks the finest power-of-two grid whose
bucket-expansion stays within a constant factor of the object count, so
cross-tests shrink as far as linear-size bucket tables allow. A fixed
grid remains available (``mbr_grid`` on ``JoinPlan``). Bucketing
normalizes by the joint extent of both datasets — raw coordinates are
*not* assumed to lie in the unit square.

The reference-point bucket is computed from the per-object integer cell
ranges (``floor`` and ``clip`` are monotone, so the common MBR's cell is
exactly the elementwise max of the two low cells) — bucketing and
ownership can never disagree through float rounding, on any backend.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "MBR_BACKENDS", "mbr_join", "mbr_intersect_mask", "adaptive_grid",
    "joint_extent", "bucket_ranges", "expand_buckets", "candidate_rows",
    "pair_mask_body", "pair_mask_lane_jnp", "MBRIndex",
]

MBR_BACKENDS = ("numpy", "jnp", "sequential")

#: bucket-entry budget per object for the adaptive grid (expansion stays
#: within this factor of the object count)
_ENTRY_BUDGET = 8
_MAX_GRID = 1024


def _check_backend(backend: str) -> None:
    if backend not in MBR_BACKENDS:
        raise ValueError(f"unknown mbr backend {backend!r}; "
                         f"expected one of {MBR_BACKENDS}")


def _resolve_grid(grid, mbrs_r, mbrs_s, extent) -> int:
    """Validate an explicit grid (``>= 1``) or pick one adaptively."""
    if grid is None:
        return adaptive_grid(mbrs_r, mbrs_s, extent)
    if int(grid) < 1:
        raise ValueError(f"mbr grid must be >= 1 or None (adaptive), "
                         f"got {grid!r}")
    return int(grid)


def _pad_rows_pow2(xs: list[np.ndarray], multiple: int = 1
                   ) -> tuple[list[np.ndarray], int]:
    """Zero-pad equal-length arrays (along axis 0) to the next power of two
    (then up to ``multiple``) so jitted consumers recompile logarithmically
    in the row count; returns (padded arrays, original length)."""
    n = len(xs[0])
    p2 = 1 << int(np.ceil(np.log2(max(n, 1))))
    pad = max(multiple, ((p2 + multiple - 1) // multiple) * multiple)
    return [x if len(x) == pad else
            np.concatenate([x, np.zeros((pad - n,) + x.shape[1:], x.dtype)])
            for x in xs], n


def _prepare(mbrs_r: np.ndarray, mbrs_s: np.ndarray, grid: int | None):
    """Shared host preamble of every ``mbr_join`` entry point: coerce,
    guard empties, resolve the joint extent and grid. Returns
    (mbrs_r, mbrs_s, k, extent), with ``k = 0`` signalling an empty join —
    keeping host and mesh paths pair-set-identical by construction."""
    mbrs_r = np.asarray(mbrs_r, np.float64).reshape(-1, 4)
    mbrs_s = np.asarray(mbrs_s, np.float64).reshape(-1, 4)
    extent = joint_extent(mbrs_r, mbrs_s)
    # resolve even when a side is empty: an invalid explicit grid must
    # raise regardless of which partition it is first wired through
    k = _resolve_grid(grid, mbrs_r, mbrs_s, extent)
    if len(mbrs_r) == 0 or len(mbrs_s) == 0:
        return mbrs_r, mbrs_s, 0, extent
    return mbrs_r, mbrs_s, k, extent


def mbr_intersect_mask(mr: np.ndarray, ms: np.ndarray) -> np.ndarray:
    """Pairwise MBR intersection for [N,4] x [M,4] -> [N,M] bool.

    The brute-force oracle: every ``mbr_join`` backend must return exactly
    its nonzero set (asserted by ``tests/test_mbr_join.py``).
    """
    return ((mr[:, None, 0] <= ms[None, :, 2]) & (ms[None, :, 0] <= mr[:, None, 2])
            & (mr[:, None, 1] <= ms[None, :, 3]) & (ms[None, :, 1] <= mr[:, None, 3]))


# ---------------------------------------------------------------------------
# Grid selection and bucketing
# ---------------------------------------------------------------------------

def joint_extent(mbrs_r: np.ndarray, mbrs_s: np.ndarray
                 ) -> tuple[float, float, float]:
    """(x0, y0, span) of the square window covering both datasets' MBRs.

    ``span`` is the larger side, floored at a tiny positive value so that
    degenerate (single-point) inputs still bucket without dividing by zero.
    """
    allm = np.concatenate([mbrs_r.reshape(-1, 4), mbrs_s.reshape(-1, 4)])
    if len(allm) == 0:
        return 0.0, 0.0, 1.0
    x0 = float(allm[:, 0].min())
    y0 = float(allm[:, 1].min())
    span = max(float(allm[:, 2].max()) - x0, float(allm[:, 3].max()) - y0)
    return x0, y0, max(span, np.finfo(np.float64).tiny)


def adaptive_grid(mbrs_r: np.ndarray, mbrs_s: np.ndarray,
                  extent: tuple[float, float, float] | None = None) -> int:
    """Grid granularity from MBR-extent statistics (Kipf-style adaptivity).

    Picks the finest power-of-two ``k`` (up to 1024) whose total bucket
    expansion ``sum_i (w_i*k + 1)(h_i*k + 1)`` stays within ``_ENTRY_BUDGET``
    entries per object: finer grids mean smaller buckets (fewer cross-test
    rows), while the budget keeps the expanded tables linear in the input,
    so neither side of the hash join can degenerate — large objects push
    ``k`` down, many small objects allow it up.
    """
    mbrs_r = np.asarray(mbrs_r, np.float64).reshape(-1, 4)
    mbrs_s = np.asarray(mbrs_s, np.float64).reshape(-1, 4)
    n = len(mbrs_r) + len(mbrs_s)
    if n == 0:
        return 1
    span = (extent or joint_extent(mbrs_r, mbrs_s))[2]
    allm = np.concatenate([mbrs_r, mbrs_s])
    w = (allm[:, 2] - allm[:, 0]) / span
    h = (allm[:, 3] - allm[:, 1]) / span
    ks = 2 ** np.arange(0, int(np.log2(_MAX_GRID)) + 1)
    entries = ((w[:, None] * ks + 1.0) * (h[:, None] * ks + 1.0)).sum(axis=0)
    ok = np.nonzero(entries <= _ENTRY_BUDGET * n)[0]
    return int(ks[ok[-1]]) if len(ok) else 1


def bucket_ranges(mbrs: np.ndarray, k: int,
                  extent: tuple[float, float, float]) -> tuple[np.ndarray, np.ndarray]:
    """Inclusive cell range [x0,x1] x [y0,y1] per MBR on the k x k grid.

    Coordinates are normalized by the joint data ``extent`` before
    bucketing — MBRs far outside the unit square spread over the grid
    instead of all clamping into the border cells (the pre-§8 bug that
    degenerated translated/scaled workloads to one quadratic cross-test).
    """
    x0, y0, span = extent
    scaled = (mbrs.reshape(-1, 4) - [x0, y0, x0, y0]) / span * k
    lo = np.clip(np.floor(scaled[:, :2]).astype(np.int64), 0, k - 1)
    hi = np.clip(np.floor(scaled[:, 2:]).astype(np.int64), 0, k - 1)
    return lo, hi


# ---------------------------------------------------------------------------
# Batched core: vectorized expansion + sort-merge bucket join
# ---------------------------------------------------------------------------

def expand_buckets(lo: np.ndarray, hi: np.ndarray, k: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Flat (object, bucket) table for inclusive cell ranges; vectorized.

    Row-major bucket ids ``x * k + y``; per-object cell offsets come from
    repeat/cumsum arithmetic — no Python loop over objects.
    """
    lo = lo.reshape(-1, 2)
    hi = hi.reshape(-1, 2)
    nx = hi[:, 0] - lo[:, 0] + 1
    ny = hi[:, 1] - lo[:, 1] + 1
    cnt = nx * ny
    total = int(cnt.sum())
    if total == 0:
        z = np.zeros(0, np.int64)
        return z, z
    obj = np.repeat(np.arange(len(lo), dtype=np.int64), cnt)
    start = np.cumsum(cnt) - cnt
    off = np.arange(total, dtype=np.int64) - start[obj]
    oy = off % ny[obj]
    ox = off // ny[obj]
    return obj, (lo[obj, 0] + ox) * k + (lo[obj, 1] + oy)


def _cross_rows(obj_r: np.ndarray, buck_r: np.ndarray,
                obj_s: np.ndarray, buck_s: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cartesian co-bucket rows of two *bucket-sorted* (object, bucket)
    tables: ``(ri, si, own)`` with ``own`` the shared bucket id. The single
    definition of the sort-merge tail, shared between the one-shot
    :func:`candidate_rows` and the warm :class:`MBRIndex` probe path."""
    ur, start_r, cnt_r = np.unique(buck_r, return_index=True,
                                   return_counts=True)
    us, start_s, cnt_s = np.unique(buck_s, return_index=True,
                                   return_counts=True)
    common, ir, is_ = np.intersect1d(ur, us, assume_unique=True,
                                     return_indices=True)
    cr = cnt_r[ir]
    cs = cnt_s[is_]
    m = cr * cs
    total = int(m.sum())
    if total == 0:
        z = np.zeros(0, np.int64)
        return z, z, z
    grp = np.repeat(np.arange(len(common), dtype=np.int64), m)
    off = np.arange(total, dtype=np.int64) - (np.cumsum(m) - m)[grp]
    a = off // cs[grp]
    b = off % cs[grp]
    ri = obj_r[start_r[ir][grp] + a]
    si = obj_s[start_s[is_][grp] + b]
    return ri, si, common[grp]


def candidate_rows(mbrs_r: np.ndarray, mbrs_s: np.ndarray, k: int,
                   extent: tuple[float, float, float]
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray, np.ndarray]:
    """Co-bucket cross-product rows of the grid-hash join.

    Returns ``(ri, si, own_x, own_y, lo_r, lo_s)``: for every bucket shared
    by both sides, the cartesian rows of its R x S members (``ri``/``si``
    index the original datasets; ``own_x``/``own_y`` are the shared
    bucket's cell). A row is a join result iff the MBRs intersect *and*
    ``(max(lo_r[ri], lo_s[si]) == (own_x, own_y))`` — the reference-point
    ownership test, evaluated by the caller's backend of choice (host
    numpy, device jnp, or sharded over the mesh in
    ``distributed.distributed_mbr_join``).
    """
    lo_r, hi_r = bucket_ranges(mbrs_r, k, extent)
    lo_s, hi_s = bucket_ranges(mbrs_s, k, extent)
    obj_r, buck_r = expand_buckets(lo_r, hi_r, k)
    obj_s, buck_s = expand_buckets(lo_s, hi_s, k)

    order_r = np.argsort(buck_r, kind="stable")
    order_s = np.argsort(buck_s, kind="stable")
    obj_r, buck_r = obj_r[order_r], buck_r[order_r]
    obj_s, buck_s = obj_s[order_s], buck_s[order_s]

    ri, si, own = _cross_rows(obj_r, buck_r, obj_s, buck_s)
    if len(ri) == 0:
        z = np.zeros(0, np.int64)
        return z, z, z, z, lo_r, lo_s
    return ri, si, own // k, own % k, lo_r, lo_s


def pair_mask_body(xp, mbrs_r, mbrs_s, lo_r, lo_s, ri, si, own_x, own_y):
    """Intersection + reference-point ownership mask over candidate rows.

    The single definition of the pair test, generic over the array module
    (``numpy`` or ``jax.numpy``) — every backend, including the mesh step
    in ``spatial.distributed``, evaluates this body, so the test can never
    diverge between backends whose contract is pair-set identity.
    """
    a = mbrs_r[ri]
    b = mbrs_s[si]
    hit = ((a[:, 0] <= b[:, 2]) & (b[:, 0] <= a[:, 2])
           & (a[:, 1] <= b[:, 3]) & (b[:, 1] <= a[:, 3]))
    owner = ((xp.maximum(lo_r[ri, 0], lo_s[si, 0]) == own_x)
             & (xp.maximum(lo_r[ri, 1], lo_s[si, 1]) == own_y))
    return hit & owner


def _pair_mask_np(mbrs_r, mbrs_s, lo_r, lo_s, ri, si, own_x, own_y):
    return pair_mask_body(np, mbrs_r, mbrs_s, lo_r, lo_s, ri, si,
                          own_x, own_y)


_JNP_MASK = None


def pair_mask_lane_jnp(mbrs_r, mbrs_s, lo_r, lo_s, ri, si, own_x, own_y):
    """Device-resident pair mask: (lane [Npad] device bool, n).

    The same mask pass jit-compiled on device (f64 under ``enable_x64`` —
    without it JAX would silently round coordinates to f32 and merge
    nearby MBR borders), rows padded to powers of two so recompilation
    stays logarithmic in the row count. The lane never visits the host —
    the fused chain (DESIGN.md §12) consumes it directly as the
    CandidateSet ``valid`` lane; padding rows are already False via the
    jit's ``valid`` operand. ``lane[:n]`` are the real rows.
    """
    global _JNP_MASK
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    if _JNP_MASK is None:
        def mask(mr, ms, lor, los, ri, si, ox, oy, valid):
            return pair_mask_body(jnp, mr, ms, lor, los, ri, si,
                                  ox, oy) & valid
        _JNP_MASK = jax.jit(mask)

    # the replicated tables pad too: their exact shapes would otherwise
    # retrigger a compile for every distinct dataset size (padded table
    # rows are only gathered by padded candidate rows, masked by `valid`)
    (mbrs_r, lo_r), _ = _pad_rows_pow2([mbrs_r, lo_r])
    (mbrs_s, lo_s), _ = _pad_rows_pow2([mbrs_s, lo_s])
    (ri, si, own_x, own_y, valid), n = _pad_rows_pow2(
        [ri, si, own_x, own_y, np.ones(len(ri), bool)])
    with enable_x64():
        out = _JNP_MASK(mbrs_r, mbrs_s, lo_r, lo_s, ri, si,
                        own_x, own_y, valid)
    return out, n


def _pair_mask_jnp(mbrs_r, mbrs_s, lo_r, lo_s, ri, si, own_x, own_y):
    """Staged-mode wrapper: compute the device lane, then materialize it
    through the chain's sanctioned sync point (``fused.to_host``)."""
    from .fused import to_host
    out, n = pair_mask_lane_jnp(mbrs_r, mbrs_s, lo_r, lo_s, ri, si,
                                own_x, own_y)
    return to_host(out)[:n]


# ---------------------------------------------------------------------------
# Sequential reference (the pre-batching per-object / per-bucket walk)
# ---------------------------------------------------------------------------

def _mbr_join_sequential(mbrs_r, mbrs_s, k, extent) -> np.ndarray:
    """Order-identical reference: per-object expansion loop, per-bucket
    cross test. Every batched backend must emit the identical pair set."""
    lo_r, hi_r = bucket_ranges(mbrs_r, k, extent)
    lo_s, hi_s = bucket_ranges(mbrs_s, k, extent)

    def expand(lo, hi):
        obj, bx, by = [], [], []
        for i in range(len(lo)):
            xs = np.arange(lo[i, 0], hi[i, 0] + 1)
            ys = np.arange(lo[i, 1], hi[i, 1] + 1)
            X, Y = np.meshgrid(xs, ys, indexing="ij")
            obj.append(np.full(X.size, i, np.int64))
            bx.append(X.ravel()); by.append(Y.ravel())
        if not obj:
            z = np.zeros(0, np.int64)
            return z, z
        return (np.concatenate(obj),
                np.concatenate(bx) * k + np.concatenate(by))

    obj_r, buck_r = expand(lo_r, hi_r)
    obj_s, buck_s = expand(lo_s, hi_s)

    order_r = np.argsort(buck_r, kind="stable")
    order_s = np.argsort(buck_s, kind="stable")
    obj_r, buck_r = obj_r[order_r], buck_r[order_r]
    obj_s, buck_s = obj_s[order_s], buck_s[order_s]

    pairs = []
    ur, idx_r = np.unique(buck_r, return_index=True)
    us, idx_s = np.unique(buck_s, return_index=True)
    common, ir, is_ = np.intersect1d(ur, us, return_indices=True)
    bounds_r = np.append(idx_r, len(buck_r))
    bounds_s = np.append(idx_s, len(buck_s))
    for c, a, b in zip(common, ir, is_):
        rs = obj_r[bounds_r[a]: bounds_r[a + 1]]
        ss = obj_s[bounds_s[b]: bounds_s[b + 1]]
        hit = mbr_intersect_mask(mbrs_r[rs], mbrs_s[ss])
        bx = np.maximum(lo_r[rs, None, 0], lo_s[None, ss, 0])
        by = np.maximum(lo_r[rs, None, 1], lo_s[None, ss, 1])
        owner = (bx * k + by) == c
        ii, jj = np.nonzero(hit & owner)
        if len(ii):
            pairs.append(np.stack([rs[ii], ss[jj]], axis=1))
    if not pairs:
        return np.zeros((0, 2), np.int64)
    return np.concatenate(pairs, axis=0)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def mbr_join(mbrs_r: np.ndarray, mbrs_s: np.ndarray,
             grid: int | None = None, backend: str = "numpy") -> np.ndarray:
    """All (r, s) index pairs with intersecting MBRs. Returns [N,2] int64.

    ``grid=None`` (the default) picks the granularity adaptively from the
    MBR-extent statistics (:func:`adaptive_grid`); an explicit ``grid``
    pins it. ``backend`` selects the execution path (``MBR_BACKENDS``) —
    the pair set is identical for every backend and every grid.
    """
    _check_backend(backend)
    mbrs_r, mbrs_s, k, extent = _prepare(mbrs_r, mbrs_s, grid)
    if k == 0:
        return np.zeros((0, 2), np.int64)
    if backend == "sequential":
        return _mbr_join_sequential(mbrs_r, mbrs_s, k, extent)
    ri, si, own_x, own_y, lo_r, lo_s = candidate_rows(mbrs_r, mbrs_s, k,
                                                      extent)
    if len(ri) == 0:
        return np.zeros((0, 2), np.int64)
    mask_fn = _pair_mask_jnp if backend == "jnp" else _pair_mask_np
    keep = mask_fn(mbrs_r, mbrs_s, lo_r, lo_s, ri, si, own_x, own_y)
    return np.stack([ri[keep], si[keep]], axis=1)


# ---------------------------------------------------------------------------
# Warm index: build the R-side bucket table once, probe many times
# ---------------------------------------------------------------------------

class MBRIndex:
    """Grid-hash bucket table over one dataset's MBRs, built once and
    probed by many query batches (the serving path of DESIGN.md §10).

    A probe reuses the sorted (object, bucket) table instead of
    re-expanding and re-sorting the indexed side per join. The pair *set*
    is grid- and extent-invariant (``floor`` and ``clip`` are monotone, so
    the reference-point ownership cell — the elementwise max of the two
    clipped low cells — is covered by both objects' clipped cell ranges
    even when a query MBR lies outside the index extent), hence
    ``probe(q)`` equals ``mbr_join(self.mbrs, q)`` as a set for any grid.

    ``insert`` / ``delete`` splice only the affected buckets' entries
    (``stats["entries_touched"]`` counts them) — with the grid and extent
    pinned at construction, a patched index is array-identical to one
    freshly built over the patched MBRs with the same ``grid``/``extent``.
    """

    def __init__(self, mbrs: np.ndarray, grid: int | None = None,
                 extent: tuple[float, float, float] | None = None):
        self.mbrs = np.asarray(mbrs, np.float64).reshape(-1, 4).copy()
        self.extent = extent or joint_extent(self.mbrs, self.mbrs)
        self.k = _resolve_grid(grid, self.mbrs, self.mbrs, self.extent)
        self.lo, hi = bucket_ranges(self.mbrs, self.k, self.extent)
        obj, buck = expand_buckets(self.lo, hi, self.k)
        order = np.argsort(buck, kind="stable")
        self._obj, self._buck = obj[order], buck[order]
        self.stats = {"inserts": 0, "deletes": 0, "probes": 0,
                      "entries_touched": 0}

    @property
    def n_entries(self) -> int:
        return len(self._buck)

    def probe(self, mbrs_q: np.ndarray, backend: str = "numpy"
              ) -> np.ndarray:
        """All (indexed, query) pairs with intersecting MBRs, [N,2] int64 —
        pair-set-identical to ``mbr_join(self.mbrs, mbrs_q, backend)``."""
        _check_backend(backend)
        self.stats["probes"] += 1
        mbrs_q = np.asarray(mbrs_q, np.float64).reshape(-1, 4)
        if len(self.mbrs) == 0 or len(mbrs_q) == 0:
            return np.zeros((0, 2), np.int64)
        if backend == "sequential":
            return _mbr_join_sequential(self.mbrs, mbrs_q, self.k,
                                        self.extent)
        lo_q, hi_q = bucket_ranges(mbrs_q, self.k, self.extent)
        obj_q, buck_q = expand_buckets(lo_q, hi_q, self.k)
        order = np.argsort(buck_q, kind="stable")
        obj_q, buck_q = obj_q[order], buck_q[order]
        ri, si, own = _cross_rows(self._obj, self._buck, obj_q, buck_q)
        if len(ri) == 0:
            return np.zeros((0, 2), np.int64)
        mask_fn = _pair_mask_jnp if backend == "jnp" else _pair_mask_np
        keep = mask_fn(self.mbrs, mbrs_q, self.lo, lo_q, ri, si,
                       own // self.k, own % self.k)
        return np.stack([ri[keep], si[keep]], axis=1)

    def insert(self, mbr: np.ndarray) -> int:
        """Add one MBR; returns its index id. Only the new object's
        buckets gain entries (spliced at each bucket run's end, matching
        the obj-ascending order of a fresh build)."""
        mbr = np.asarray(mbr, np.float64).reshape(1, 4)
        new_id = len(self.mbrs)
        self.mbrs = np.concatenate([self.mbrs, mbr])
        lo, hi = bucket_ranges(mbr, self.k, self.extent)
        self.lo = np.concatenate([self.lo, lo])
        obj, buck = expand_buckets(lo, hi, self.k)
        pos = np.searchsorted(self._buck, buck, side="right")
        self._obj = np.insert(self._obj, pos, new_id)
        self._buck = np.insert(self._buck, pos, buck)
        self.stats["inserts"] += 1
        self.stats["entries_touched"] += len(buck)
        return new_id

    def delete(self, idx: int) -> None:
        """Remove the MBR at ``idx``; later ids shift down by one (the
        renumbering a fresh build over the remaining MBRs would use)."""
        if not 0 <= idx < len(self.mbrs):
            raise IndexError(f"MBRIndex.delete: id {idx} out of range "
                             f"[0, {len(self.mbrs)})")
        keep = self._obj != idx
        self.stats["entries_touched"] += int((~keep).sum())
        self._obj = self._obj[keep] - (self._obj[keep] > idx)
        self._buck = self._buck[keep]
        self.mbrs = np.delete(self.mbrs, idx, axis=0)
        self.lo = np.delete(self.lo, idx, axis=0)
        self.stats["deletes"] += 1
