"""The fused device-resident pipeline (DESIGN.md §12).

``JoinPlan(pipeline_mode="fused")`` runs MBR -> intermediate filter ->
refinement as ONE dispatch chain: every stage consumes and produces a
:class:`CandidateSet` — a host-known pair frame plus device-resident status
lanes — and stage boundaries compact on device through
``kernels.compact.compact_mask`` instead of the staged mode's
materialize-compact-reupload round trips. Nothing returns to the host until
the single sanctioned :func:`to_host` gather at the end of the chain, which
also drives the one permitted host round trip: f64 re-refinement of the
FMA-borderline pairs the device refinement flagged uncertain.

Contract with the staged mode (the reference): identical result pairs, in
identical order, for every filter method, predicate, and backend — asserted
by tests/test_fused_pipeline.py. The staged per-stage backends remain the
references; fused changes *where* stage boundaries live, never verdicts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG

__all__ = [
    "PIPELINE_MODES", "check_pipeline_mode", "to_host",
    "CandidateSet", "Stage", "StagePlan", "build_stage_plan",
    "execute_fused",
]

#: execution modes of JoinPlan (DESIGN.md §12): 'staged' materializes each
#: stage's survivors on host (the reference), 'fused' keeps the chain
#: device-resident with one end-of-chain sync
PIPELINE_MODES = ("staged", "fused")


def check_pipeline_mode(mode: str) -> None:
    if mode not in PIPELINE_MODES:
        raise ValueError(f"unknown pipeline_mode {mode!r}; "
                         f"expected one of {PIPELINE_MODES}")


def to_host(*vals):
    """The chain's single sanctioned device -> host materialization.

    Every lane of the finished chain gathers in ONE ``jax.device_get`` —
    the lexical choke point the HS001 static pass holds the fused pipeline
    to (staged reference paths route their per-stage pulls through here
    too, so intent stays visible). Returns numpy arrays, one per operand.
    """
    import jax
    got = jax.device_get(list(vals))  # analyze: ignore[HS001] the one sanctioned sync (DESIGN.md §12)
    return got[0] if len(vals) == 1 else tuple(got)


# ---------------------------------------------------------------------------
# The stage contract
# ---------------------------------------------------------------------------

@dataclass
class CandidateSet:
    """The device-resident currency of the fused chain.

    The pair *frame* ``(ri, si)`` is host-known metadata — it comes out of
    grid-hash preprocessing over host MBR tables, so holding it costs no
    device sync. Everything data-dependent lives in device lanes over that
    frame: ``valid`` (MBR mask + ownership; ``None`` means the frame is
    pre-filtered on host and all rows are real), ``status`` (the int8
    trichotomy, already masked — invalid rows are TRUE_NEG), ``hit`` /
    ``unc`` (refined verdicts and FMA-borderline flags). Stages consume and
    produce CandidateSets; no stage materializes a lane.
    """
    ri: np.ndarray                 # [N] int64 host frame, R indices
    si: np.ndarray                 # [N] int64 host frame, S indices
    valid: object | None = None    # [N] device bool (None = all valid)
    status: object | None = None   # [N] device int8 trichotomy
    hit: object | None = None      # [N] device bool refined verdicts
    unc: object | None = None      # [N] device bool FMA-borderline

    def __len__(self) -> int:
        return len(self.ri)


@dataclass
class Stage:
    """One link of the chain; ``name`` keys the JoinStats wall-time field
    (``t_mbr`` / ``t_filter`` / ``t_refine``)."""
    name: str
    fn: Callable


class StagePlan:
    """An ordered CandidateSet -> CandidateSet chain, dispatched back to
    back with no intermediate host syncs.

    Per-stage wall times record *dispatch* cost only — JAX dispatch is
    asynchronous, so the device work of the whole chain surfaces in the
    end-of-chain gather, reported as ``t_sync``.
    """

    def __init__(self, stages: list[Stage]):
        self.stages = list(stages)

    def run(self, cs: CandidateSet | None = None, stats=None) -> CandidateSet:
        for st in self.stages:
            t0 = time.perf_counter()
            cs = st.fn(cs)
            if stats is not None:
                field = "t_" + st.name
                setattr(stats, field,
                        getattr(stats, field, 0.0)
                        + time.perf_counter() - t0)
        return cs


def _empty_cs():
    import jax.numpy as jnp
    z = np.zeros(0, np.int64)
    return CandidateSet(ri=z, si=z, valid=None,
                        status=jnp.zeros(0, jnp.int8),
                        hit=jnp.zeros(0, bool), unc=jnp.zeros(0, bool))


# ---------------------------------------------------------------------------
# Stage builders
# ---------------------------------------------------------------------------

def build_stage_plan(plan, predicate: str) -> StagePlan:
    """The three-stage fused chain for one JoinPlan execution.

    * ``mbr`` — host grid-hash preprocessing producing the pair frame; with
      ``mbr_backend='jnp'`` the intersection + ownership mask stays a
      device ``valid`` lane (``pair_mask_lane_jnp``), the within MBR
      containment restriction folded in. A warm ``mbr_index`` or a host
      backend yields a pre-filtered frame (pure host work — no sync).
    * ``filter`` — the method's ``status_lane`` over the frame, masked so
      invalid rows read TRUE_NEG.
    * ``refine`` — on-device compaction of the INDECISIVE lane
      (``compact_mask``) + chunked packed refinement
      (``fused_refine_lanes``), scattered back to frame lanes.
    """
    import jax.numpy as jnp

    def mbr_stage(_):
        from .mbr_join import _prepare, candidate_rows, pair_mask_lane_jnp
        R, S = plan.R, plan.S
        if plan.mbr_index is not None or plan.mbr_backend != "jnp":
            pairs = plan.candidates(predicate)
            if len(pairs) == 0:
                return _empty_cs()
            return CandidateSet(ri=pairs[:, 0], si=pairs[:, 1])
        mbrs_r, mbrs_s, k, extent = _prepare(R.mbrs, S.mbrs, plan.mbr_grid)
        if k == 0:
            return _empty_cs()
        ri, si, own_x, own_y, lo_r, lo_s = candidate_rows(
            mbrs_r, mbrs_s, k, extent)
        if len(ri) == 0:
            return _empty_cs()
        lane, n = pair_mask_lane_jnp(mbrs_r, mbrs_s, lo_r, lo_s,
                                     ri, si, own_x, own_y)
        valid = lane[:n]
        if predicate == "within":
            # the stricter containment restriction of JoinPlan.candidates,
            # evaluated on the host MBR tables and folded into the lane
            mr, ms = mbrs_r[ri], mbrs_s[si]
            inside = ((mr[:, 0] >= ms[:, 0]) & (mr[:, 1] >= ms[:, 1])
                      & (mr[:, 2] <= ms[:, 2]) & (mr[:, 3] <= ms[:, 3]))
            valid = valid & jnp.asarray(inside)
        return CandidateSet(ri=ri, si=si, valid=valid)

    def filter_stage(cs):
        if len(cs) == 0:
            return cs
        lane = plan.filter.status_lane(
            plan.approx_r, plan.approx_s, cs.ri, cs.si,
            predicate=predicate, backend=plan.filter_backend,
            **plan.filter_opts)
        if cs.valid is not None:
            lane = jnp.where(cs.valid, lane, jnp.int8(TRUE_NEG))
        cs.status = lane
        return cs

    def refine_stage(cs):
        from . import refine as RF
        if len(cs) == 0:
            return cs
        from ..kernels.compact import compact_mask
        cb = "pallas" if plan.refine_backend == "pallas" else "jnp"
        perm, count = compact_mask(cs.status == INDECISIVE, backend=cb)
        ri_dev = jnp.asarray(np.asarray(cs.ri, np.int32))
        si_dev = jnp.asarray(np.asarray(cs.si, np.int32))
        res, unc, perm_p = RF.fused_refine_lanes(
            plan.R, plan.S, ri_dev, si_dev, perm, count, predicate)
        N = len(cs)
        hit_ref = jnp.zeros(N, bool).at[perm_p].set(res, mode="drop")
        cs.hit = (cs.status == TRUE_HIT) | hit_ref
        cs.unc = jnp.zeros(N, bool).at[perm_p].set(unc, mode="drop")
        return cs

    return StagePlan([Stage("mbr", mbr_stage),
                      Stage("filter", filter_stage),
                      Stage("refine", refine_stage)])


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

def execute_fused(plan, predicate: str, stats):
    """Run the fused chain; returns (result pairs [K,2] int64, stats).

    Result rows reproduce the staged ordering exactly: TRUE_HIT pairs in
    frame order, then refined-true INDECISIVE pairs in frame order.
    ``stats.t_sync`` times the end-of-chain gather plus the f64 host
    escalation of FMA-borderline pairs (the one permitted round trip);
    the per-stage times are dispatch-only.
    """
    from . import refine as RF
    sp = build_stage_plan(plan, predicate)
    cs = sp.run(stats=stats)

    t0 = time.perf_counter()
    if len(cs) == 0:
        stats.t_sync = time.perf_counter() - t0
        return np.zeros((0, 2), np.int64), stats
    frame = np.stack([np.asarray(cs.ri, np.int64),
                      np.asarray(cs.si, np.int64)], axis=1)
    lanes = (cs.status, cs.hit, cs.unc)
    if cs.valid is not None:
        lanes += (cs.valid,)
    got = to_host(*lanes)
    status_h, hit_h, unc_h = got[0], np.array(got[1]), got[2]
    valid_h = got[3] if cs.valid is not None else np.ones(len(cs), bool)
    if unc_h.any():
        # f64 escalation of the FMA-borderline pairs — identical to the
        # staged jnp refine backend's per-bucket escalation set
        esc = frame[unc_h]
        hit_h[unc_h] = RF.refine(plan.R, plan.S, esc, predicate=predicate,
                                 backend="numpy")
    stats.t_sync = time.perf_counter() - t0

    stats.n_candidates = int(valid_h.sum())
    stats.n_true_hits = int(np.sum((status_h == TRUE_HIT) & valid_h))
    stats.n_true_negs = int(np.sum((status_h == TRUE_NEG) & valid_h))
    stats.n_indecisive = int(np.sum((status_h == INDECISIVE) & valid_h))
    indec = status_h == INDECISIVE
    results = np.concatenate([frame[status_h == TRUE_HIT],
                              frame[indec & hit_h]], axis=0)
    stats.n_results = len(results)
    return results, stats
