"""APRIL and APRIL-C intermediate filters (paper §4, §5.1).

The batched paths run the three interval joins (AA/AF/FA) as masked
vectorized passes (`core.join.april_filter_batch`) on numpy or jnp device
arrays; APRIL additionally has a mesh-sharded path (spatial/distributed.py).
APRIL-C stores VByte-compressed lists; its per-pair reference streams
(join-while-decompress, §5.1) while its batched path decompresses the
objects of the batch on host first (DESIGN.md §3) and reuses the APRIL
vectorized joins — verdicts are identical either way.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...core import compress, join, rasterize
from ...core.april import build_april
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["LineCellStore", "build_line_cells", "AprilFilter",
           "AprilCompressedFilter"]

_DEFAULT_ORDER = ("AA", "AF", "FA")


@dataclass
class LineCellStore:
    """CSR store of sorted Partial cell ids per linestring (§4.3.3): the
    approximation of an open chain is its cell-id set, joined as unit
    intervals."""
    n_order: int
    off: np.ndarray     # [P+1] int64
    ids: np.ndarray     # [sum_K] uint64, sorted per row

    def __len__(self) -> int:
        return len(self.off) - 1

    def cell_ids(self, i: int) -> np.ndarray:
        return self.ids[self.off[i]: self.off[i + 1]]

    def size_bytes(self) -> int:
        return 4 * len(self.ids) + 8 * len(self.off)


def build_line_cells(dataset, n_order: int,
                     extent: Extent = GLOBAL_EXTENT,
                     backend: str = "numpy") -> LineCellStore:
    if backend == "sequential":
        off = [0]
        chunks = []
        for i in range(len(dataset)):
            cells = rasterize.dda_partial_cells(
                dataset.verts[i], int(dataset.nverts[i]), n_order, extent,
                closed=False)
            ids = np.sort(rasterize.cells_to_hilbert(cells, n_order))
            chunks.append(ids)
            off.append(off[-1] + len(ids))
        ids = np.concatenate(chunks) if chunks else np.zeros(0, np.uint64)
        return LineCellStore(n_order=n_order, off=np.asarray(off, np.int64),
                             ids=ids)
    P = len(dataset)
    off, cells = rasterize.dda_partial_cells_multi(
        dataset.verts, dataset.nverts, n_order, extent, closed=False)
    ids = rasterize.xy2d(n_order, cells[:, 0], cells[:, 1])
    pid = np.repeat(np.arange(P), np.diff(off))
    shift = np.uint64(1) << np.uint64(2 * n_order)
    order = np.argsort(pid.astype(np.uint64) * shift + ids)
    return LineCellStore(n_order=n_order, off=off, ids=ids[order])


@register_filter("april")
class AprilFilter(IntermediateFilter):

    supports_mesh = True

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", method: str = "batched",
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        if kind == "line":
            store = build_line_cells(dataset, n_order, extent,
                                     backend=build_backend)
        else:
            store = build_april(dataset, n_order, extent, method,
                                backend=build_backend)
        return Approximation(filter=self.name, store=store, n_order=n_order,
                             extent=extent, kind=kind)

    # both sides as AprilStores (APRIL-C overrides to decompress the batch)
    def _stores(self, approx_r, approx_s, pairs):
        return approx_r.store, approx_s.store, pairs

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 order: tuple[str, ...] = _DEFAULT_ORDER, **opts
                 ) -> np.ndarray:
        self._check(predicate, backend)
        e = self._empty(pairs)
        if e is not None:
            return e
        use_jnp = backend in ("jnp", "pallas")
        if predicate == "linestring":
            line: LineCellStore = approx_r.store
            _, store_s, pairs = self._stores(approx_r, approx_s, pairs)
            return join.linestring_filter_batch(
                store_s, line.off, line.ids, pairs, use_jnp=use_jnp)
        store_r, store_s, pairs = self._stores(approx_r, approx_s, pairs)
        if predicate == "within":
            return join.within_filter_batch(store_r, store_s, pairs,
                                            use_jnp=use_jnp)
        return join.april_filter_batch(store_r, store_s, pairs, order=order,
                                       use_jnp=use_jnp)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     order: tuple[str, ...] = _DEFAULT_ORDER, **opts) -> int:
        sr, ss = approx_r.store, approx_s.store
        if predicate == "linestring":
            return join.linestring_verdict_pair(ss.a_list(j), ss.f_list(j),
                                                sr.cell_ids(i))
        if predicate == "within":
            return join.within_verdict_pair(sr.a_list(i), sr.f_list(i),
                                            ss.a_list(j), ss.f_list(j))
        return join.april_verdict_pair(sr.a_list(i), sr.f_list(i),
                                       ss.a_list(j), ss.f_list(j), order=order)

    def verdicts_mesh(self, approx_r, approx_s, pairs, *, mesh=None, **opts):
        from ..distributed import (bucket_pairs, distributed_april_filter,
                                   make_join_mesh)
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        mesh = mesh or make_join_mesh()
        n_dev = int(np.prod(list(mesh.shape.values())))
        # fail safe: a slot the scatter never writes (e.g. a duplicated
        # pair) gets refined rather than dropped as a certified negative
        from ...core.join import INDECISIVE
        verdicts = np.full(len(pairs), INDECISIVE, np.int8)
        counts = {"true_neg": 0, "true_hit": 0, "indecisive": 0}
        # vectorized scatter of bucketed results back to batch order
        keys = (pairs[:, 0] << 32) | pairs[:, 1]
        order = np.argsort(keys)
        sorted_keys = keys[order]
        for packed in bucket_pairs(approx_r.store, approx_s.store, pairs,
                                   n_devices=n_dev):
            verd, c = distributed_april_filter(packed, mesh)
            for k in counts:
                counts[k] += c[k]
            pidx = packed.pair_idx[packed.valid]
            vkeys = (pidx[:, 0] << 32) | pidx[:, 1]
            verdicts[order[np.searchsorted(sorted_keys, vkeys)]] = \
                verd[packed.valid]
        return verdicts, counts


@register_filter("april-c")
class AprilCompressedFilter(AprilFilter):

    supports_mesh = False

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", method: str = "batched",
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        if kind == "line":
            # the line side has no interval lists to compress; reuse the
            # uncompressed cell-id store
            store = build_line_cells(dataset, n_order, extent,
                                     backend=build_backend)
        else:
            store = compress.compress_april(
                build_april(dataset, n_order, extent, method,
                            backend=build_backend))
        return Approximation(filter=self.name, store=store, n_order=n_order,
                             extent=extent, kind=kind)

    def _stores(self, approx_r, approx_s, pairs):
        """Host-decompress the objects touched by the batch (DESIGN.md §3)
        and renumber the pairs into the temporary stores."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        new_pairs = pairs.copy()
        store_r = approx_r.store
        if isinstance(store_r, compress.CompressedAprilStore):
            uniq, inv = np.unique(pairs[:, 0], return_inverse=True)
            store_r = store_r.decompress(uniq)
            new_pairs[:, 0] = inv
        store_s = approx_s.store
        if isinstance(store_s, compress.CompressedAprilStore):
            uniq, inv = np.unique(pairs[:, 1], return_inverse=True)
            store_s = store_s.decompress(uniq)
            new_pairs[:, 1] = inv
        return store_r, store_s, new_pairs

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     order: tuple[str, ...] = _DEFAULT_ORDER, **opts) -> int:
        sr, ss = approx_r.store, approx_s.store
        if predicate in ("intersects", "selection"):
            # streaming join-while-decompress (§5.1)
            return compress.april_verdict_compressed(
                sr.a_bufs[i], sr.f_bufs[i], ss.a_bufs[j], ss.f_bufs[j])
        return super()._verdict_one(approx_r, approx_s, i, j,
                                    predicate=predicate, order=order, **opts)
