"""APRIL and APRIL-C intermediate filters (paper §4, §5.1).

The batched paths run the staged trichotomy of the bucketed filter-join
subsystem (DESIGN.md §9, ``core.join``): the cheap AA-join evaluates the
whole batch, the expensive AF/FA (or containment) joins only the compacted
AA survivors. Interval lists are wrapped once per Approximation into
device-ready :class:`~repro.core.join.IntervalLists` (cached in ``meta``,
reused across ``JoinPlan`` calls); APRIL additionally has a mesh-sharded
path (spatial/distributed.py). APRIL-C stores VByte-compressed lists; its
per-pair reference streams (join-while-decompress, §5.1) while its batched
path *bounds* decode work: one vectorized VByte pass decodes the A lists of
the batch's objects, and F lists decode only for objects in AA-surviving
rows — verdicts are identical either way.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...core import compress, join, rasterize
from ...core.april import build_april
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["LineCellStore", "build_line_cells", "AprilFilter",
           "AprilCompressedFilter"]

_DEFAULT_ORDER = ("AA", "AF", "FA")


@dataclass
class LineCellStore:
    """CSR store of sorted Partial cell ids per linestring (§4.3.3): the
    approximation of an open chain is its cell-id set, joined as unit
    intervals."""
    n_order: int
    off: np.ndarray     # [P+1] int64
    ids: np.ndarray     # [sum_K] uint64, sorted per row

    def __len__(self) -> int:
        return len(self.off) - 1

    def cell_ids(self, i: int) -> np.ndarray:
        return self.ids[self.off[i]: self.off[i + 1]]

    def size_bytes(self) -> int:
        return 4 * len(self.ids) + 8 * len(self.off)


def build_line_cells(dataset, n_order: int,
                     extent: Extent = GLOBAL_EXTENT,
                     backend: str = "numpy") -> LineCellStore:
    if backend == "sequential":
        off = [0]
        chunks = []
        for i in range(len(dataset)):
            cells = rasterize.dda_partial_cells(
                dataset.verts[i], int(dataset.nverts[i]), n_order, extent,
                closed=False)
            ids = np.sort(rasterize.cells_to_hilbert(cells, n_order))
            chunks.append(ids)
            off.append(off[-1] + len(ids))
        ids = np.concatenate(chunks) if chunks else np.zeros(0, np.uint64)
        return LineCellStore(n_order=n_order, off=np.asarray(off, np.int64),
                             ids=ids)
    P = len(dataset)
    off, cells = rasterize.dda_partial_cells_multi(
        dataset.verts, dataset.nverts, n_order, extent, closed=False)
    ids = rasterize.xy2d(n_order, cells[:, 0], cells[:, 1])
    pid = np.repeat(np.arange(P), np.diff(off))
    shift = np.uint64(1) << np.uint64(2 * n_order)
    order = np.argsort(pid.astype(np.uint64) * shift + ids)
    return LineCellStore(n_order=n_order, off=off, ids=ids[order])


@register_filter("april")
class AprilFilter(IntermediateFilter):

    supports_mesh = True

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", method: str = "batched",
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        if kind == "line":
            store = build_line_cells(dataset, n_order, extent,
                                     backend=build_backend)
        else:
            store = build_april(dataset, n_order, extent, method,
                                backend=build_backend)
        return Approximation(filter=self.name, store=store, n_order=n_order,
                             extent=extent, kind=kind,
                             meta={"build_opts": {"method": method}})

    # -- incremental maintenance (row splice on the CSR interval lists) -----
    def _store_append(self, approx, one) -> None:
        store, o = approx.store, one.store
        cache = approx.meta.get("interval_lists", {})
        if isinstance(store, LineCellStore):
            store.off, store.ids = join.csr_append_row(store.off, store.ids,
                                                       o.ids)
            if "line" in cache:
                row = join.IntervalLists.from_unit_cells(o.off, o.ids)
                cache["line"].append_row(row.starts, row.lasts)
        else:
            store.a_off, store.a_ints = join.csr_append_row(
                store.a_off, store.a_ints, o.a_ints)
            store.f_off, store.f_ints = join.csr_append_row(
                store.f_off, store.f_ints, o.f_ints)
            # splice the device-ready lists in place instead of rebuilding
            # them: the biased-int32 conversion is elementwise, so a patched
            # cache equals one freshly wrapped from the patched store
            for kind, off, ints in (("A", o.a_off, o.a_ints),
                                    ("F", o.f_off, o.f_ints)):
                if kind in cache:
                    row = join.IntervalLists.from_intervals(off, ints)
                    cache[kind].append_row(row.starts, row.lasts)
        if hasattr(store, "_interval_lists_cache"):
            del store._interval_lists_cache

    def _store_delete(self, approx, idx: int) -> None:
        store = approx.store
        cache = approx.meta.get("interval_lists", {})
        if isinstance(store, LineCellStore):
            store.off, store.ids = join.csr_delete_row(store.off, store.ids,
                                                       idx)
            if "line" in cache:
                cache["line"].delete_row(idx)
        else:
            store.a_off, store.a_ints = join.csr_delete_row(
                store.a_off, store.a_ints, idx)
            store.f_off, store.f_ints = join.csr_delete_row(
                store.f_off, store.f_ints, idx)
            for kind in ("A", "F"):
                if kind in cache:
                    cache[kind].delete_row(idx)
        if hasattr(store, "_interval_lists_cache"):
            del store._interval_lists_cache

    # device-ready interval lists, built once per Approximation and reused
    # across JoinPlan calls (APRIL-C overrides with the bounded batch decode)
    @staticmethod
    def _lists(approx, kind: str) -> join.IntervalLists:
        cache = approx.meta.setdefault("interval_lists", {})
        if kind not in cache:
            store = approx.store
            if kind == "line":
                cache[kind] = join.IntervalLists.from_unit_cells(store.off,
                                                                 store.ids)
            else:
                off = store.a_off if kind == "A" else store.f_off
                ints = store.a_ints if kind == "A" else store.f_ints
                cache[kind] = join.IntervalLists.from_intervals(off, ints)
        return cache[kind]

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 order: tuple[str, ...] = _DEFAULT_ORDER, **opts
                 ) -> np.ndarray:
        self._check(predicate, backend)
        if backend == "sequential":
            return self.verdicts_seq(approx_r, approx_s, pairs,
                                     predicate=predicate, order=order, **opts)
        e = self._empty(pairs)
        if e is not None:
            return e
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        ri, si = pairs[:, 0], pairs[:, 1]
        if predicate == "linestring":
            return join.linestring_trichotomy_rows(
                self._lists(approx_r, "line"), self._lists(approx_s, "A"),
                self._lists(approx_s, "F"), ri, si, backend=backend)
        if predicate == "within":
            return join.within_trichotomy_rows(
                self._lists(approx_r, "A"), self._lists(approx_s, "A"),
                self._lists(approx_s, "F"), ri, si, backend=backend)
        return join.april_trichotomy_rows(
            self._lists(approx_r, "A"), self._lists(approx_r, "F"),
            self._lists(approx_s, "A"), self._lists(approx_s, "F"),
            ri, si, backend=backend, order=order)

    def status_lane(self, approx_r, approx_s, ri, si, *,
                    predicate: str = "intersects", backend: str = "numpy",
                    order: tuple[str, ...] = _DEFAULT_ORDER, **opts):
        """Device-computed status lane (DESIGN.md §12).

        The interval-list slabs are device-resident, so the full trichotomy
        evaluates on device via ``join.fused_status_rows`` — no host verdict
        round trip. The sequential backend and degenerate intersects orders
        (the reference leaves AA survivors INDECISIVE) keep the uploaded
        host lane so fused == staged row for row.
        """
        self._check(predicate, backend)
        if backend == "sequential" or (
                predicate in ("intersects", "selection")
                and set(order) != set(_DEFAULT_ORDER)):
            return super().status_lane(approx_r, approx_s, ri, si,
                                       predicate=predicate, backend=backend,
                                       order=order, **opts)
        if predicate == "linestring":
            return join.fused_status_rows(
                "linestring", self._lists(approx_r, "line"), None,
                self._lists(approx_s, "A"), self._lists(approx_s, "F"),
                ri, si)
        if predicate == "within":
            return join.fused_status_rows(
                "within", self._lists(approx_r, "A"), None,
                self._lists(approx_s, "A"), self._lists(approx_s, "F"),
                ri, si)
        return join.fused_status_rows(
            "intersects", self._lists(approx_r, "A"),
            self._lists(approx_r, "F"), self._lists(approx_s, "A"),
            self._lists(approx_s, "F"), ri, si)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     order: tuple[str, ...] = _DEFAULT_ORDER, **opts) -> int:
        sr, ss = approx_r.store, approx_s.store
        if predicate == "linestring":
            return join.linestring_verdict_pair(ss.a_list(j), ss.f_list(j),
                                                sr.cell_ids(i))
        if predicate == "within":
            return join.within_verdict_pair(sr.a_list(i), sr.f_list(i),
                                            ss.a_list(j), ss.f_list(j))
        return join.april_verdict_pair(sr.a_list(i), sr.f_list(i),
                                       ss.a_list(j), ss.f_list(j), order=order)

    def verdicts_mesh(self, approx_r, approx_s, pairs, *, mesh=None, **opts):
        from ..distributed import (bucket_pairs, distributed_april_filter,
                                   make_join_mesh)
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        mesh = mesh or make_join_mesh()
        n_dev = int(np.prod(list(mesh.shape.values())))
        # fail safe: a slot the scatter never writes (e.g. a duplicated
        # pair) gets refined rather than dropped as a certified negative
        from ...core.join import INDECISIVE
        verdicts = np.full(len(pairs), INDECISIVE, np.int8)
        counts = {"true_neg": 0, "true_hit": 0, "indecisive": 0}
        # vectorized scatter of bucketed results back to batch order
        keys = (pairs[:, 0] << 32) | pairs[:, 1]
        order = np.argsort(keys)
        sorted_keys = keys[order]
        for packed in bucket_pairs(approx_r.store, approx_s.store, pairs,
                                   n_devices=n_dev):
            verd, c = distributed_april_filter(packed, mesh)
            for k in counts:
                counts[k] += c[k]
            pidx = packed.pair_idx[packed.valid]
            vkeys = (pidx[:, 0] << 32) | pidx[:, 1]
            verdicts[order[np.searchsorted(sorted_keys, vkeys)]] = \
                verd[packed.valid]
        return verdicts, counts


@register_filter("april-c")
class AprilCompressedFilter(AprilFilter):

    supports_mesh = False

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", method: str = "batched",
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        if kind == "line":
            # the line side has no interval lists to compress; reuse the
            # uncompressed cell-id store
            store = build_line_cells(dataset, n_order, extent,
                                     backend=build_backend)
        else:
            store = compress.compress_april(
                build_april(dataset, n_order, extent, method,
                            backend=build_backend))
        return Approximation(filter=self.name, store=store, n_order=n_order,
                             extent=extent, kind=kind,
                             meta={"build_opts": {"method": method}})

    # VByte buffers are per-object python lists: splice is a list op; the
    # line kind reuses the uncompressed CSR path of AprilFilter
    def _store_append(self, approx, one) -> None:
        store = approx.store
        if isinstance(store, compress.CompressedAprilStore):
            store.a_bufs.append(one.store.a_bufs[0])
            store.f_bufs.append(one.store.f_bufs[0])
            self._drop_derived(approx)
        else:
            super()._store_append(approx, one)

    def _store_delete(self, approx, idx: int) -> None:
        store = approx.store
        if isinstance(store, compress.CompressedAprilStore):
            del store.a_bufs[idx]
            del store.f_bufs[idx]
            self._drop_derived(approx)
        else:
            super()._store_delete(approx, idx)

    # -- bounded batch decode (DESIGN.md §9) --------------------------------
    # A lists decode once for the batch's unique objects (the AA-join needs
    # them all); F lists decode per stage, for exactly the unique objects of
    # the AA-surviving rows — a batch full of sure negatives decodes no F
    # bytes at all.

    def _a_side(self, approx, col: np.ndarray):
        """(IntervalLists, rows) for one A-list side, decoded for the batch."""
        store = approx.store
        if not isinstance(store, compress.CompressedAprilStore):
            return self._lists(approx, "A"), col
        uniq, rows = np.unique(col, return_inverse=True)
        off, ints = store.decompress_lists(uniq, "A")
        return join.IntervalLists.from_intervals(off, ints), rows

    def _f_side(self, approx, col_sel: np.ndarray):
        """(IntervalLists, rows) for one F-list side, decoded for the
        survivor rows only."""
        store = approx.store
        if not isinstance(store, compress.CompressedAprilStore):
            return self._lists(approx, "F"), col_sel
        uniq, rows = np.unique(col_sel, return_inverse=True)
        off, ints = store.decompress_lists(uniq, "F")
        return join.IntervalLists.from_intervals(off, ints), rows

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 order: tuple[str, ...] = _DEFAULT_ORDER, **opts
                 ) -> np.ndarray:
        self._check(predicate, backend)
        if backend == "sequential":
            return self.verdicts_seq(approx_r, approx_s, pairs,
                                     predicate=predicate, order=order, **opts)
        if predicate in ("intersects", "selection") and "AA" not in order:
            raise ValueError("order must include 'AA'")
        e = self._empty(pairs)
        if e is not None:
            return e
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        ri, si = pairs[:, 0], pairs[:, 1]
        overlap = join._overlap_fn(backend)
        if predicate == "linestring":
            # the line side is an uncompressed cell-id store
            C = self._lists(approx_r, "line")
            Ya, ya_rows = self._a_side(approx_s, si)
            aa = overlap(C, ri, Ya, ya_rows)
        else:
            Xa, xa_rows = self._a_side(approx_r, ri)
            Ya, ya_rows = self._a_side(approx_s, si)
            aa = overlap(Xa, xa_rows, Ya, ya_rows)
        verdicts = np.where(aa, join.INDECISIVE, join.TRUE_NEG).astype(np.int8)
        sel = np.nonzero(aa)[0]
        if len(sel) == 0:
            return verdicts
        if predicate == "linestring":
            Yf, yf_rows = self._f_side(approx_s, si[sel])
            fhit = overlap(C, ri[sel], Yf, yf_rows)
            verdicts[sel[fhit]] = join.TRUE_HIT
            return verdicts
        if predicate == "within":
            Yf, yf_rows = self._f_side(approx_s, si[sel])
            contain = join.contain_rows_jnp if backend in ("jnp", "pallas") \
                else join.contain_rows_np
            cont = contain(Xa, xa_rows[sel], Yf, yf_rows)
            verdicts[sel[cont]] = join.TRUE_HIT
            return verdicts
        # degenerate orders leave AA survivors INDECISIVE, like the reference
        for step in [s for s in order if s != "AA"]:
            if len(sel) == 0:
                break
            if step == "AF":
                Yf, yf_rows = self._f_side(approx_s, si[sel])
                hit = overlap(Xa, xa_rows[sel], Yf, yf_rows)
            else:
                Xf, xf_rows = self._f_side(approx_r, ri[sel])
                hit = overlap(Xf, xf_rows, Ya, ya_rows[sel])
            verdicts[sel[hit]] = join.TRUE_HIT
            sel = sel[~hit]
        return verdicts

    def status_lane(self, approx_r, approx_s, ri, si, *,
                    predicate: str = "intersects", backend: str = "numpy",
                    order: tuple[str, ...] = _DEFAULT_ORDER, **opts):
        # the bounded batch decode is survivor-driven host logic (np.unique
        # over AA survivors), so the fused lane is the uploaded host verdicts
        return IntermediateFilter.status_lane(
            self, approx_r, approx_s, ri, si, predicate=predicate,
            backend=backend, order=order, **opts)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     order: tuple[str, ...] = _DEFAULT_ORDER, **opts) -> int:
        sr, ss = approx_r.store, approx_s.store
        if predicate in ("intersects", "selection"):
            # streaming join-while-decompress (§5.1)
            return compress.april_verdict_compressed(
                sr.a_bufs[i], sr.f_bufs[i], ss.a_bufs[j], ss.f_bufs[j])
        return super()._verdict_one(approx_r, approx_s, i, j,
                                    predicate=predicate, order=order, **opts)
