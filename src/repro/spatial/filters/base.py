"""The `IntermediateFilter` protocol + registry (DESIGN.md §2).

The paper's pipeline is MBR filter -> *intermediate filter* -> refinement
(Fig. 1). This module makes the intermediate step a first-class, pluggable
abstraction:

* :class:`Approximation` — a built, reusable, sizeable store for one dataset
  (what used to be the ad-hoc ``prebuilt: tuple | None``).
* :class:`IntermediateFilter` — ``build(dataset, *, n_order, extent, ...)``
  produces an Approximation; ``verdicts(approx_r, approx_s, pairs, *,
  predicate, backend)`` classifies a whole candidate batch into the paper's
  trichotomy (TRUE_NEG / TRUE_HIT / INDECISIVE) in one vectorized pass.
  ``verdicts_seq`` is the faithful per-pair reference the batched path must
  be verdict-identical to (asserted by tests/test_filter_protocol.py).
* a name-based registry — :func:`register_filter` / :func:`get_filter` —
  backing ``none / april / april-c / ri / ra / 5cch``.

Predicates: ``intersects`` | ``within`` | ``linestring`` | ``selection``.
``selection`` (polygonal range queries, §4.3.1) is the intersects test with
query polygons as the S side; ``linestring`` (§4.3.3) expects the R side
built with ``kind='line'``.

Backends (``filter_backend`` on :class:`~repro.spatial.plan.JoinPlan`,
DESIGN.md §9): ``numpy`` (host, default), ``jnp`` (bucketed device
batches), ``pallas`` (TPU kernels where available), ``sequential`` (the
faithful per-pair reference loop — every filter dispatches it to
``verdicts_seq``). Filters without a device path for a given predicate
fall back to their vectorized numpy path — backend choice never changes
verdicts.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field

import numpy as np

from ...core.join import INDECISIVE
from ...core.join import FILTER_BACKENDS as _FILTER_BACKENDS
from ...core.rasterize import Extent, GLOBAL_EXTENT

__all__ = [
    "PREDICATES", "BACKENDS", "FILTER_BACKENDS", "BUILD_BACKENDS",
    "Approximation", "IntermediateFilter",
    "register_filter", "unregister_filter", "get_filter", "available_filters",
]

PREDICATES = ("intersects", "within", "linestring", "selection")
#: verdict-stage execution paths (DESIGN.md §9, the single source of truth
#: in core.join); 'sequential' is the per-pair reference loop, dispatched
#: to ``verdicts_seq`` by every filter
FILTER_BACKENDS = _FILTER_BACKENDS
BACKENDS = FILTER_BACKENDS   # historical alias
#: construction backends (DESIGN.md §6): 'numpy'/'jnp' run the batched
#: dataset-level build; 'sequential' is the per-object reference loop every
#: batched build must be store-identical to.
BUILD_BACKENDS = ("numpy", "jnp", "sequential")


@dataclass
class Approximation:
    """A built intermediate-filter store for one dataset.

    ``store`` is filter-specific (AprilStore, RIStore, RAStore, FiveCCH,
    CompressedAprilStore, or None for the 'none' filter); ``kind`` records
    what was approximated ('polygon' or 'line'); ``meta`` holds reusable
    caches (e.g. RA upscale pyramids) that survive across ``verdicts`` calls
    and predicates.
    """
    filter: str
    store: object
    n_order: int | None = None
    extent: Extent | None = None
    kind: str = "polygon"
    meta: dict = field(default_factory=dict)

    def size_bytes(self) -> int:
        return int(self.store.size_bytes()) if self.store is not None else 0

    def __len__(self) -> int:
        return len(self.store) if self.store is not None else 0


class IntermediateFilter(abc.ABC):
    """One intermediate filter method (paper §2-§5)."""

    name: str = "?"
    #: filters with a mesh-sharded device path (see spatial/distributed.py)
    supports_mesh: bool = False

    # -- preprocessing ------------------------------------------------------
    @abc.abstractmethod
    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", **opts) -> Approximation:
        """Build the approximation store for ``dataset``.

        ``kind``: 'polygon' or 'line' (open chains, §4.3.3). ``side`` is a
        hint ('r'/'s') for filters whose encoding differs per join side (RI).
        Every built-in filter accepts ``build_backend`` (one of
        ``BUILD_BACKENDS``): 'numpy' (default) / 'jnp' run the batched
        dataset-level construction, 'sequential' the per-object reference.
        """

    # -- filtering ----------------------------------------------------------
    @abc.abstractmethod
    def verdicts(self, approx_r: Approximation, approx_s: Approximation,
                 pairs: np.ndarray, *, predicate: str = "intersects",
                 backend: str = "numpy", **opts) -> np.ndarray:
        """Batched verdicts [N] int8 for candidate ``pairs`` [N, 2]."""

    def verdicts_seq(self, approx_r: Approximation, approx_s: Approximation,
                     pairs: np.ndarray, *, predicate: str = "intersects",
                     **opts) -> np.ndarray:
        """Faithful per-pair reference loop (the paper's algorithms).

        Subclasses override :meth:`_verdict_one`; this loop is the semantic
        contract the batched path is tested against.
        """
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        self._check(predicate, "numpy")
        return np.asarray(
            [self._verdict_one(approx_r, approx_s, int(i), int(j),
                               predicate=predicate, **opts)
             for i, j in pairs], np.int8)

    def _verdict_one(self, approx_r, approx_s, i: int, j: int, *,
                     predicate: str, **opts) -> int:
        raise NotImplementedError

    def status_lane(self, approx_r: Approximation, approx_s: Approximation,
                    ri: np.ndarray, si: np.ndarray, *,
                    predicate: str = "intersects", backend: str = "numpy",
                    **opts):
        """Device int8 status lane [N] over the fused chain's pair frame
        (DESIGN.md §12).

        ``ri``/``si`` are the host-known candidate frame — grid-hash
        preprocessing artifacts, so consuming them costs no device sync.
        The default computes the batched host :meth:`verdicts` over the
        frame and uploads the result; filters whose stores are
        device-resident (APRIL, none) override with a lane computed on
        device, keeping the chain free of intermediate host pulls. Verdicts
        must be row-identical to :meth:`verdicts` for every backend.
        """
        import jax.numpy as jnp
        ri = np.asarray(ri, np.int64)
        si = np.asarray(si, np.int64)
        if len(ri) == 0:
            return jnp.zeros(0, jnp.int8)
        verd = self.verdicts(approx_r, approx_s, np.stack([ri, si], axis=1),
                             predicate=predicate, backend=backend, **opts)
        return jnp.asarray(verd)

    # -- incremental maintenance (DESIGN.md §10) ----------------------------
    def patch_insert(self, approx: Approximation, dataset_one) -> None:
        """Append the approximation of ``dataset_one``'s single object to
        ``approx`` in place (the new object gets id ``len(approx)``).

        The one-object store comes from this filter's own :meth:`build`
        under the ``build_opts`` recorded in ``approx.meta`` at build time;
        construction is per-object independent (the batched build is
        store-identical to the sequential per-object reference), so a
        patched store equals a fresh rebuild over the extended dataset.
        """
        if len(dataset_one) != 1:
            raise ValueError(f"patch_insert expects a 1-object dataset, "
                             f"got {len(dataset_one)}")
        opts = dict(approx.meta.get("build_opts", {}))
        one = self.build(
            dataset_one,
            n_order=approx.n_order if approx.n_order is not None else 10,
            extent=approx.extent if approx.extent is not None
            else GLOBAL_EXTENT, kind=approx.kind, **opts)
        self._store_append(approx, one)

    def patch_delete(self, approx: Approximation, idx: int) -> None:
        """Splice object ``idx`` out of ``approx`` in place; later ids
        shift down by one (the numbering a fresh rebuild would use)."""
        if not 0 <= int(idx) < len(approx):
            raise IndexError(f"patch_delete: id {idx} out of range "
                             f"[0, {len(approx)})")
        self._store_delete(approx, int(idx))

    def _store_append(self, approx: Approximation,
                      one: Approximation) -> None:
        raise NotImplementedError(
            f"filter {self.name!r} has no incremental maintenance path")

    def _store_delete(self, approx: Approximation, idx: int) -> None:
        raise NotImplementedError(
            f"filter {self.name!r} has no incremental maintenance path")

    @staticmethod
    def _drop_derived(approx: Approximation) -> None:
        """Drop per-object derived caches that a row splice invalidates
        (meta caches are index-keyed; ``core.join`` attaches a raw-store
        interval-list cache)."""
        for key in ("interval_lists", "pyramid"):
            approx.meta.pop(key, None)
        store = approx.store
        if store is not None and hasattr(store, "_interval_lists_cache"):
            del store._interval_lists_cache

    # -- optional mesh path (overridden by filters with a device kernel) ----
    def verdicts_mesh(self, approx_r, approx_s, pairs, *, mesh=None,
                      **opts) -> tuple[np.ndarray, dict]:
        raise NotImplementedError(
            f"filter {self.name!r} has no mesh-sharded path")

    # -- helpers ------------------------------------------------------------
    @staticmethod
    def _check_build_backend(build_backend: str) -> None:
        if build_backend not in BUILD_BACKENDS:
            raise ValueError(f"unknown build_backend {build_backend!r}; "
                             f"expected one of {BUILD_BACKENDS}")

    @staticmethod
    def _check(predicate: str, backend: str) -> None:
        if predicate not in PREDICATES:
            raise ValueError(f"unknown predicate {predicate!r}; "
                             f"expected one of {PREDICATES}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {BACKENDS}")

    @staticmethod
    def _empty(pairs: np.ndarray) -> np.ndarray | None:
        pairs = np.asarray(pairs)
        if pairs.size == 0:
            return np.zeros(0, np.int8)
        return None

    @staticmethod
    def _all_indecisive(pairs: np.ndarray) -> np.ndarray:
        n = len(np.asarray(pairs).reshape(-1, 2))
        return np.full(n, INDECISIVE, np.int8)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type[IntermediateFilter]] = {}


def register_filter(name: str, cls: type[IntermediateFilter] | None = None):
    """Register a filter class under ``name``. Usable as a decorator::

        @register_filter("april")
        class AprilFilter(IntermediateFilter): ...
    """
    def _do(c):
        c.name = name
        _REGISTRY[name] = c
        return c
    return _do(cls) if cls is not None else _do


def unregister_filter(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_filter(name: str | IntermediateFilter) -> IntermediateFilter:
    """Look up a registered filter by name; instances pass through."""
    if isinstance(name, IntermediateFilter):
        return name
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(
            f"unknown intermediate filter {name!r}; "
            f"available: {sorted(_REGISTRY)}") from None


def available_filters() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
