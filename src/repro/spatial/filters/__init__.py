"""Pluggable intermediate filters (DESIGN.md §2).

Importing this package registers the six built-in filters:
``none / april / april-c / ri / ra / 5cch``.
"""
from .base import (  # noqa: F401
    BACKENDS, FILTER_BACKENDS, PREDICATES, Approximation,
    IntermediateFilter,
    available_filters, get_filter, register_filter, unregister_filter,
)
from .none_filter import NoneFilter  # noqa: F401
from .april_filter import AprilCompressedFilter, AprilFilter  # noqa: F401
from .ri_filter import RIFilter  # noqa: F401
from .ra_filter import RAFilter  # noqa: F401
from .fivecch_filter import FiveCCHFilter  # noqa: F401
