"""RA (Zimbrao & de Souza raster approximation) intermediate filter (§2).

The batched path memoizes per-object upscale pyramids in the Approximation's
``meta`` (they survive across calls and predicates) and evaluates the
overlay + Table-1 lookup of every candidate pair as one padded vectorized
gather (``baselines.ra.ra_filter_batch``).

Fused pipeline (DESIGN.md §12): the pyramid overlay is a host-memoized
gather, so RA keeps the inherited host ``status_lane`` — one verdict upload
per batch, then the chain stays device-resident.
"""
from __future__ import annotations

import numpy as np

from ...baselines import ra
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["RAFilter"]


@register_filter("ra")
class RAFilter(IntermediateFilter):

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", max_cells: int = 750,
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        # n_order is unused: RA grids are per-object, sized by max_cells
        if kind == "line":
            store = ra.build_ra_lines(dataset, max_cells=max_cells,
                                      backend=build_backend)
        else:
            store = ra.build_ra(dataset, max_cells=max_cells,
                                backend=build_backend)
        return Approximation(filter=self.name, store=store, n_order=None,
                             extent=extent, kind=kind,
                             meta={"build_opts": {"max_cells": max_cells}})

    # -- incremental maintenance: per-object grid patch ---------------------
    # RA grids are fit per object from its own MBR (omega is a fixed unit),
    # so one object's (k, origin, shape, cells) rows splice independently;
    # the index-keyed pyramid memo is dropped by _drop_derived.
    def _store_append(self, approx, one) -> None:
        store, o = approx.store, one.store
        store.k = np.concatenate([store.k, o.k])
        store.origin = np.concatenate([store.origin, o.origin])
        store.shape = np.concatenate([store.shape, o.shape])
        store.cells.append(o.cells[0])
        self._drop_derived(approx)

    def _store_delete(self, approx, idx: int) -> None:
        store = approx.store
        store.k = np.delete(store.k, idx)
        store.origin = np.delete(store.origin, idx, axis=0)
        store.shape = np.delete(store.shape, idx, axis=0)
        del store.cells[idx]
        self._drop_derived(approx)

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 **opts) -> np.ndarray:
        self._check(predicate, backend)
        if backend == "sequential":
            return self.verdicts_seq(approx_r, approx_s, pairs,
                                     predicate=predicate, **opts)
        e = self._empty(pairs)
        if e is not None:
            return e
        cache_r = approx_r.meta.setdefault("pyramid", {})
        cache_s = approx_s.meta.setdefault("pyramid", {})
        if predicate == "within":
            return ra.ra_within_batch(approx_r.store, approx_s.store, pairs,
                                      cache_r=cache_r, cache_s=cache_s)
        return ra.ra_filter_batch(approx_r.store, approx_s.store, pairs,
                                  cache_r=cache_r, cache_s=cache_s)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     **opts) -> int:
        if predicate == "within":
            return ra.ra_within_verdict_pair(approx_r.store, i,
                                             approx_s.store, j)
        return ra.ra_verdict_pair(approx_r.store, i, approx_s.store, j)
