"""5C+CH (Brinkhoff) intermediate filter (§2).

Conservative-only: certifies TRUE negatives, never hits — for every
predicate (disjoint approximations rule out intersection, containment, and
line crossing alike). The batched path runs the separating-axis tests as
padded einsum passes over the whole candidate batch.

Fused pipeline (DESIGN.md §12): the hull stores are host ragged arrays, so
5C+CH keeps the inherited host ``status_lane`` — one verdict upload per
batch, then the chain stays device-resident.
"""
from __future__ import annotations

import numpy as np

from ...baselines import fivec_ch
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["FiveCCHFilter"]


@register_filter("5cch")
class FiveCCHFilter(IntermediateFilter):

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", build_backend: str = "numpy", **opts
              ) -> Approximation:
        self._check_build_backend(build_backend)
        # n_order is unused: 5C+CH is raster-free
        if kind == "line":
            store = fivec_ch.build_5cch_lines(dataset, backend=build_backend)
        else:
            store = fivec_ch.build_5cch(dataset, backend=build_backend)
        return Approximation(filter=self.name, store=store, n_order=None,
                             extent=extent, kind=kind)

    # -- incremental maintenance: pentagon row + hull CSR splice ------------
    def _store_append(self, approx, one) -> None:
        from ...core.join import csr_append_row
        store, o = approx.store, one.store
        store.pent = np.concatenate([store.pent, o.pent])
        store.hull_off, store.hull_pts = csr_append_row(
            store.hull_off, store.hull_pts, o.hull_pts)

    def _store_delete(self, approx, idx: int) -> None:
        from ...core.join import csr_delete_row
        store = approx.store
        store.pent = np.delete(store.pent, idx, axis=0)
        store.hull_off, store.hull_pts = csr_delete_row(
            store.hull_off, store.hull_pts, idx)

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 **opts) -> np.ndarray:
        self._check(predicate, backend)
        if backend == "sequential":
            return self.verdicts_seq(approx_r, approx_s, pairs,
                                     predicate=predicate, **opts)
        e = self._empty(pairs)
        if e is not None:
            return e
        return fivec_ch.fivecch_filter_batch(approx_r.store, approx_s.store,
                                             pairs)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     **opts) -> int:
        if predicate == "within":
            return fivec_ch.fivecch_within_verdict_pair(approx_r.store, i,
                                                        approx_s.store, j)
        return fivec_ch.fivecch_verdict_pair(approx_r.store, i,
                                             approx_s.store, j)
