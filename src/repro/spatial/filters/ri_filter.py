"""RI (Raster Intervals) intermediate filter (paper §3).

Verdict batches run the vectorized fragment sweep in ``core.ri``: candidate
pairs expand to overlapping-interval fragments, whose 3-bit code runs are
ANDed either on host (numpy bit pass) or as packed uint32 words through the
Pallas ``kernels/ri_and`` ALIGNEDAND kernel (backend 'jnp'/'pallas').

Fused pipeline (DESIGN.md §12): the fragment expansion is survivor-driven
host logic, so RI keeps the inherited host ``status_lane`` — its verdicts
upload once per batch and the chain stays device-resident from there.
"""
from __future__ import annotations

import numpy as np

from ...core import ri
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["RIFilter"]


@register_filter("ri")
class RIFilter(IntermediateFilter):

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", encoding: str | None = None,
              build_backend: str = "numpy", **opts) -> Approximation:
        self._check_build_backend(build_backend)
        # opposite per-side encodings skip the XOR re-encoding in the join
        # (§3.3); same-encoding pairs stay correct via the XOR mask.
        enc = encoding or ("R" if side == "r" else "S")
        if kind == "line":
            store = ri.build_ri_lines(dataset, n_order, extent, enc,
                                      backend=build_backend)
        else:
            store = ri.build_ri(dataset, n_order, extent, enc,
                                backend=build_backend)
        return Approximation(filter=self.name, store=store, n_order=n_order,
                             extent=extent, kind=kind,
                             meta={"build_opts": {"encoding": enc}})

    # -- incremental maintenance: interval-row splice + bit-segment rebase --
    def _store_append(self, approx, one) -> None:
        from ...core.join import csr_append_row
        store, o = approx.store, one.store
        # per-interval bit offsets are absolute: rebase the appended
        # object's segment past the existing bitstream
        store.bit_off = np.concatenate(
            [store.bit_off, o.bit_off[1:] + store.bit_off[-1]])
        store.bits = np.concatenate([store.bits, o.bits])
        store.off, store.ints = csr_append_row(store.off, store.ints, o.ints)

    def _store_delete(self, approx, idx: int) -> None:
        from ...core.join import csr_delete_row
        store = approx.store
        lo, hi = int(store.off[idx]), int(store.off[idx + 1])
        b_lo, b_hi = int(store.bit_off[lo]), int(store.bit_off[hi])
        store.bits = np.concatenate([store.bits[:b_lo], store.bits[b_hi:]])
        store.bit_off = np.concatenate(
            [store.bit_off[:lo], store.bit_off[hi:] - (b_hi - b_lo)])
        store.off, store.ints = csr_delete_row(store.off, store.ints, idx)

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 **opts) -> np.ndarray:
        self._check(predicate, backend)
        if backend == "sequential":
            return self.verdicts_seq(approx_r, approx_s, pairs,
                                     predicate=predicate, **opts)
        e = self._empty(pairs)
        if e is not None:
            return e
        if predicate == "within":
            return ri.ri_within_batch(approx_r.store, approx_s.store, pairs)
        # intersects / selection / linestring share Algorithm 1: a line cell
        # is Weak, so a non-zero AND still certifies the hit
        return ri.ri_filter_batch(approx_r.store, approx_s.store, pairs,
                                  backend=backend)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate,
                     **opts) -> int:
        if predicate == "within":
            return ri.ri_within_verdict_pair(approx_r.store, i,
                                             approx_s.store, j)
        return ri.ri_verdict_pair(approx_r.store, i, approx_s.store, j)
