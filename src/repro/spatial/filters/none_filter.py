"""The 'none' filter: no intermediate step — every MBR candidate is
forwarded to refinement (the paper's baseline column)."""
from __future__ import annotations

import numpy as np

from ...core.join import INDECISIVE
from ...core.rasterize import Extent, GLOBAL_EXTENT
from .base import Approximation, IntermediateFilter, register_filter

__all__ = ["NoneFilter"]


@register_filter("none")
class NoneFilter(IntermediateFilter):

    def build(self, dataset, *, n_order: int = 10,
              extent: Extent = GLOBAL_EXTENT, kind: str = "polygon",
              side: str = "r", **opts) -> Approximation:
        # nothing to build — and nothing is (spatial_within_join used to
        # waste t_build constructing APRIL stores it never consulted)
        return Approximation(filter=self.name, store=None, n_order=n_order,
                             extent=extent, kind=kind)

    def verdicts(self, approx_r, approx_s, pairs, *,
                 predicate: str = "intersects", backend: str = "numpy",
                 **opts) -> np.ndarray:
        self._check(predicate, backend)
        # every backend (sequential included) forwards everything
        return self._all_indecisive(pairs)

    def _verdict_one(self, approx_r, approx_s, i, j, *, predicate, **opts):
        return INDECISIVE

    def status_lane(self, approx_r, approx_s, ri, si, *,
                    predicate: str = "intersects", backend: str = "numpy",
                    **opts):
        # constant lane, minted directly on device — no host round trip
        self._check(predicate, backend)
        import jax.numpy as jnp
        return jnp.full(len(np.asarray(ri)), INDECISIVE, jnp.int8)

    # nothing is stored, so maintenance is a no-op (ids are tracked by the
    # dataset handle, not the store)
    def patch_insert(self, approx, dataset_one) -> None:
        if len(dataset_one) != 1:
            raise ValueError(f"patch_insert expects a 1-object dataset, "
                             f"got {len(dataset_one)}")

    def patch_delete(self, approx, idx: int) -> None:
        pass
