"""Distributed spatial-join execution (shard_map over the device mesh).

The join is partition-parallel (paper §5.2 + DESIGN.md §4), and every
pipeline stage has a mesh-sharded batched path:

* **Candidate generation** (:func:`distributed_mbr_join`, DESIGN.md §8):
  the host builds the flat co-bucket cross-product rows of the grid-hash
  MBR join; the rows shard across the mesh 'data' axis, each device
  evaluates its shard's intersection + reference-point ownership mask,
  qualifying counts psum-reduce on device, and the gathered mask emits
  the duplicate-free pair list on host.
* **Filtering** (:func:`distributed_filter`, §3/§4/§9): candidate pairs
  pack into padded, *bucketed* batches (bucketing by interval-list width
  bounds padding waste and is the primary load-balance/straggler lever)
  and dispatch with ``shard_map``; each device runs the three interval
  joins as one fused, branch-free vectorized pass. Filters that declare
  ``supports_mesh`` (APRIL) ship packed batches through the mesh kernel;
  every other registered filter runs its bucketed batched ``verdicts`` on
  the selected ``filter_backend`` — the launcher works for all of
  ``none/april/april-c/ri/ra/5cch``. Counts are psum-reduced; verdicts
  stay sharded for refinement.
* **Refinement** (:func:`distributed_refine`, §7): indecisive pairs refine
  sharded in vertex-count-bucketed chunks, guard-band-uncertain pairs
  escalating to the host, so verdicts equal the sequential oracle.

The same step functions lower on the production meshes (16x16 and 2x16x16)
— exercised by ``launch/dryrun.py --arch april_join``.

Batching contract: every entry point here is candidate-batched — it takes
``[N, 2]`` pair-index arrays (plus the padded interval/vertex operand
arrays packed from them) and dispatches whole shards; nothing loops
per pair on the host. Partitions are the outer unit of work: the
launcher (``launch/spatial_join.py``) and the §14 tiled driver
(``spatial/scaleout.py``) call these per partition, each with its own
approximations and candidate frame.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # stable alias, jax >= 0.6
    shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG, pack_lists
from .fused import to_host

__all__ = [
    "PackedPairs", "pack_pair_batch", "bucket_pairs",
    "april_filter_kernel_jnp", "distributed_april_filter",
    "distributed_filter", "distributed_fused_join", "distributed_mbr_join",
    "distributed_refine", "make_join_mesh",
]

I32_MAX = np.int32(np.iinfo(np.int32).max)


@dataclass
class PackedPairs:
    """Padded device batch for N candidate pairs (biased-int32 inclusive)."""
    ra_s: np.ndarray; ra_l: np.ndarray; ra_n: np.ndarray   # A(r)
    rf_s: np.ndarray; rf_l: np.ndarray; rf_n: np.ndarray   # F(r)
    sa_s: np.ndarray; sa_l: np.ndarray; sa_n: np.ndarray   # A(s)
    sf_s: np.ndarray; sf_l: np.ndarray; sf_n: np.ndarray   # F(s)
    pair_idx: np.ndarray                                   # [B,2] original ids
    valid: np.ndarray                                      # [B] bool

    def __len__(self):
        return len(self.valid)

    def arrays(self) -> dict:
        return {k: getattr(self, k) for k in (
            "ra_s", "ra_l", "ra_n", "rf_s", "rf_l", "rf_n",
            "sa_s", "sa_l", "sa_n", "sf_s", "sf_l", "sf_n")}


def pack_pair_batch(store_r, store_s, pairs: np.ndarray,
                    pad_batch_to: int = 1, pad_width_to: int = 8) -> PackedPairs:
    """Pack a ``[N, 2]`` candidate-pair batch into the padded device arrays
    of :class:`PackedPairs` (DESIGN.md §9): batch padded to a multiple of
    ``pad_batch_to`` (the device count, so shards divide evenly), interval
    widths to a multiple of ``pad_width_to``. One vectorized gather per
    list kind — no per-pair host loop."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    B = len(pairs)
    Bp = max(pad_batch_to, ((B + pad_batch_to - 1) // pad_batch_to) * pad_batch_to)

    def pad_rows(x, fill):
        if len(x) == Bp:
            return x
        pad = np.full((Bp - len(x),) + x.shape[1:], fill, x.dtype)
        return np.concatenate([x, pad], axis=0)

    def mk(store, idx, kind):
        s, l, n = pack_lists(store, idx, kind, pad_to=pad_width_to)
        w = ((s.shape[1] + pad_width_to - 1) // pad_width_to) * pad_width_to
        if s.shape[1] < w:
            extra = np.full((s.shape[0], w - s.shape[1]), I32_MAX, np.int32)
            s = np.concatenate([s, extra], axis=1)
            l = np.concatenate([l, extra], axis=1)
        return pad_rows(s, I32_MAX), pad_rows(l, I32_MAX), pad_rows(n, 0)

    ra = mk(store_r, pairs[:, 0], "A")
    rf = mk(store_r, pairs[:, 0], "F")
    sa = mk(store_s, pairs[:, 1], "A")
    sf = mk(store_s, pairs[:, 1], "F")
    valid = pad_rows(np.ones(B, bool), False)
    pidx = pad_rows(pairs, -1)
    return PackedPairs(*ra, *rf, *sa, *sf, pair_idx=pidx, valid=valid)


def bucket_pairs(store_r, store_s, pairs: np.ndarray, n_devices: int = 1,
                 max_width: int = 512) -> list[PackedPairs]:
    """Split a ``[N, 2]`` pair batch into power-of-two interval-width
    buckets and pack each (DESIGN.md §9): width-bucketing bounds padding
    waste and is the primary load-balance/straggler lever of the sharded
    filter stage."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return []
    wa = store_r.a_off[pairs[:, 0] + 1] - store_r.a_off[pairs[:, 0]]
    wb = store_s.a_off[pairs[:, 1] + 1] - store_s.a_off[pairs[:, 1]]
    width = np.maximum(np.maximum(wa, wb), 1)
    buckets: dict[int, list[int]] = {}
    for k, w in enumerate(width):
        b = 1 << int(np.ceil(np.log2(min(int(w), max_width))))
        buckets.setdefault(max(b, 8), []).append(k)
    return [
        pack_pair_batch(store_r, store_s, pairs[idx], pad_batch_to=n_devices,
                        pad_width_to=bw)
        for bw, idx in sorted(buckets.items())
    ]


# ---------------------------------------------------------------------------
# Device kernel (pure jnp; the Pallas version lives in kernels/interval_join)
# ---------------------------------------------------------------------------

def _overlap_rows(xs, xl, nx, ys, yl, ny):
    """Branch-free batched interval overlap (biased-int32, inclusive-last)."""
    I = xs.shape[-1]
    idx = jax.vmap(lambda ylr, xsr: jnp.searchsorted(ylr, xsr, side="left"))(yl, xs)
    ok = idx < ny[:, None]
    jj = jnp.minimum(idx, jnp.maximum(ny - 1, 0)[:, None])
    ys_at = jnp.take_along_axis(ys, jj, axis=1)
    valid_x = jnp.arange(I, dtype=jnp.int32)[None, :] < nx[:, None]
    return jnp.any(valid_x & ok & (ys_at <= xl), axis=-1)


def april_filter_kernel_jnp(batch: dict) -> jnp.ndarray:
    """Fused AA/AF/FA filter for a packed batch -> verdicts [B] int8
    (DESIGN.md §9; the Pallas twin lives in ``kernels/interval_join``).

    All three joins are evaluated for every pair (branch-free); the verdict
    select reproduces Algorithm 2's decision tree. Batched: the input is
    the :meth:`PackedPairs.arrays` dict, one row per candidate pair.
    """
    aa = _overlap_rows(batch["ra_s"], batch["ra_l"], batch["ra_n"],
                       batch["sa_s"], batch["sa_l"], batch["sa_n"])
    af = _overlap_rows(batch["ra_s"], batch["ra_l"], batch["ra_n"],
                       batch["sf_s"], batch["sf_l"], batch["sf_n"])
    fa = _overlap_rows(batch["rf_s"], batch["rf_l"], batch["rf_n"],
                       batch["sa_s"], batch["sa_l"], batch["sa_n"])
    return jnp.where(~aa, TRUE_NEG,
                     jnp.where(af | fa, TRUE_HIT, INDECISIVE)).astype(jnp.int8)


def make_join_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ``Mesh`` over the first ``n_devices`` local devices (all by
    default), axis name 'data' — the batch-sharding axis every
    ``distributed_*`` step and the §14 tiled driver shard over."""
    devs = jax.devices()
    n = n_devices or len(devs)
    return Mesh(np.asarray(devs[:n]), ("data",))


def distributed_april_filter(packed: PackedPairs, mesh: Mesh | None = None):
    """Run the APRIL filter kernel on one packed batch, sharded over the
    mesh 'data' axis (DESIGN.md §9).

    Returns (verdicts [B] np.int8, counts dict) — counts are psum-reduced on
    device (one scalar per verdict class crosses the network, not the batch).
    """
    mesh = mesh or make_join_mesh()
    batch = packed.arrays()
    valid = packed.valid

    @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P("data"), P()))
    def step(b, v):
        verd = april_filter_kernel_jnp(b)
        verd = jnp.where(v, verd, jnp.int8(-1))
        counts = jnp.stack([
            jnp.sum((verd == TRUE_NEG)), jnp.sum((verd == TRUE_HIT)),
            jnp.sum((verd == INDECISIVE))])
        counts = jax.lax.psum(counts, "data")
        return verd, counts

    verd, counts = jax.jit(step)(
        {k: jnp.asarray(a) for k, a in batch.items()}, jnp.asarray(valid))
    verd, counts = to_host(verd, counts)
    return (verd,
            {"true_neg": int(counts[0]), "true_hit": int(counts[1]),
             "indecisive": int(counts[2])})


def distributed_filter(filt, approx_r, approx_s, pairs: np.ndarray,
                       mesh: Mesh | None = None, backend: str = "numpy",
                       predicate: str = "intersects",
                       filter_backend: str | None = None):
    """Filter a candidate batch through any registered intermediate filter.

    Mesh-capable filters (``filt.supports_mesh``) run sharded across the
    device mesh on the ``jnp``/``pallas`` filter backends; the rest run
    their bucketed batched ``verdicts`` on the selected backend
    (``sequential`` runs the per-pair reference loop). ``filter_backend``
    is the canonical knob name, ``backend`` its historical alias. Returns
    (verdicts [N] np.int8, counts dict).
    """
    from .filters import get_filter
    filt = get_filter(filt)
    backend = filter_backend or backend
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    # the mesh kernel evaluates the intersects trichotomy only; other
    # predicates run the filter's batched host path
    if (filt.supports_mesh and backend in ("jnp", "pallas")
            and predicate == "intersects"):
        return filt.verdicts_mesh(approx_r, approx_s, pairs, mesh=mesh)
    verd = filt.verdicts(approx_r, approx_s, pairs, predicate=predicate,
                         backend=backend)
    counts = {"true_neg": int(np.sum(verd == TRUE_NEG)),
              "true_hit": int(np.sum(verd == TRUE_HIT)),
              "indecisive": int(np.sum(verd == INDECISIVE))}
    return verd, counts


# ---------------------------------------------------------------------------
# Sharded candidate generation (DESIGN.md §8): bucket cross-product rows
# shard across the mesh; the gathered ownership mask emits the pair list
# ---------------------------------------------------------------------------

_MBR_STEP_CACHE: dict = {}


def _mbr_shard_step(mesh):
    if mesh in _MBR_STEP_CACHE:
        return _MBR_STEP_CACHE[mesh]
    specs = (P(), P(), P(), P()) + tuple(P("data") for _ in range(5))

    from .mbr_join import pair_mask_body

    @partial(shard_map, mesh=mesh, in_specs=specs, out_specs=(P("data"), P()))
    def step(mr, ms, lor, los, ri, si, ox, oy, v):
        keep = pair_mask_body(jnp, mr, ms, lor, los, ri, si, ox, oy) & v
        return keep, jax.lax.psum(jnp.sum(keep), "data")

    _MBR_STEP_CACHE[mesh] = jax.jit(step)
    return _MBR_STEP_CACHE[mesh]


def distributed_mbr_join(mbrs_r: np.ndarray, mbrs_s: np.ndarray,
                         grid: int | None = None, mesh: Mesh | None = None):
    """MBR candidate generation sharded over the mesh 'data' axis.

    The host runs the cheap O(N) stages of the §8 grid-hash join (bucket
    expansion, sort-merge over the bucket tables); the O(candidates)
    cross-product rows are padded to the device count and sharded, each
    device evaluates its shard's intersection + reference-point ownership
    mask against the replicated MBR/cell tables (f64 under ``enable_x64``),
    and the qualifying count psum-reduces on device. The gathered mask
    emits the pair list on host — identical to ``mbr_join`` on every
    backend. Returns (pairs [K,2] int64, counts dict).
    """
    from .mbr_join import _pad_rows_pow2, _prepare, candidate_rows
    from jax.experimental import enable_x64

    mbrs_r, mbrs_s, k, extent = _prepare(mbrs_r, mbrs_s, grid)
    if k == 0:
        return np.zeros((0, 2), np.int64), {"mbr_candidates": 0,
                                            "mbr_pairs": 0}
    ri, si, own_x, own_y, lo_r, lo_s = candidate_rows(mbrs_r, mbrs_s, k,
                                                      extent)
    if len(ri) == 0:
        return np.zeros((0, 2), np.int64), {"mbr_candidates": 0,
                                            "mbr_pairs": 0}
    mesh = mesh or make_join_mesh()
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    # replicated tables pad to powers of two as well, so the shard step
    # compiles O(log) times across partition-sized inputs, not per shape
    (mbrs_r, lo_r), _ = _pad_rows_pow2([mbrs_r, lo_r])
    (mbrs_s, lo_s), _ = _pad_rows_pow2([mbrs_s, lo_s])
    (pri, psi, pox, poy, valid), n = _pad_rows_pow2(
        [ri, si, own_x, own_y, np.ones(len(ri), bool)], multiple=n_dev)
    step = _mbr_shard_step(mesh)
    with enable_x64():
        keep, count = step(*[jnp.asarray(a) for a in (
            mbrs_r, mbrs_s, lo_r, lo_s, pri, psi, pox, poy, valid)])
    keep_h, count_h = to_host(keep, count)
    keep_h = keep_h[:n]
    pairs = np.stack([ri[keep_h], si[keep_h]], axis=1)
    return pairs, {"mbr_candidates": int(n), "mbr_pairs": int(count_h)}


# ---------------------------------------------------------------------------
# Sharded refinement (DESIGN.md §7): the indecisive remainder stays sharded
# ---------------------------------------------------------------------------

_REFINE_STEP_CACHE: dict = {}


def _refine_shard_step(body, mesh, n_args):
    key = (body, mesh, n_args)
    if key in _REFINE_STEP_CACHE:
        return _REFINE_STEP_CACHE[key]
    specs = tuple(P("data") for _ in range(n_args)) + (P("data"),)

    @partial(shard_map, mesh=mesh, in_specs=specs,
             out_specs=(P("data"), P("data"), P()))
    def step(*xs):
        *geom, v = xs
        res, unc = body(*geom)
        res = res & v
        unc = unc & v
        return res, unc, jax.lax.psum(jnp.sum(res & ~unc), "data")

    _REFINE_STEP_CACHE[key] = jax.jit(step)
    return _REFINE_STEP_CACHE[key]


def distributed_refine(R, S, pairs: np.ndarray,
                       predicate: str = "intersects",
                       mesh: Mesh | None = None):
    """Refine indecisive candidate pairs sharded over the mesh 'data' axis.

    Pairs are processed in vertex-count-bucketed chunks (the padded
    [N, Er, Es] working set stays bounded, as on the host backends); each
    device runs the batched jnp refinement core (f64 under ``enable_x64``)
    on its shard, and the count of device-decided hits is psum-reduced on
    device (one scalar per chunk crosses the network). Pairs whose sign
    evaluations fall inside the FMA guard band come back uncertain and are
    re-run on host, so the final verdicts are identical to the host
    backends. Returns (results [N] bool, counts dict).
    """
    from . import refine as refine_mod
    from jax.experimental import enable_x64

    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, bool), {"refined_true": 0}
    mesh = mesh or make_join_mesh()
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    intersectsish = predicate not in ("within", "linestring")
    body = (refine_mod._within_impl_jnp if predicate == "within"
            else refine_mod._line_impl_jnp if predicate == "linestring"
            else refine_mod._intersects_impl_jnp)
    if intersectsish:
        rep_r = refine_mod._reps(R, pairs[:, 0])
        rep_s = refine_mod._reps(S, pairs[:, 1])

    out = np.zeros(N, bool)
    n_true = 0
    for sel, p, vr, nr, vs, ns in refine_mod.iter_pair_chunks(R, S, pairs):
        Bp = max(n_dev, ((len(p) + n_dev - 1) // n_dev) * n_dev)

        def pad(x, fill=0):
            if len(x) == Bp:
                return x
            ext = np.full((Bp - len(x),) + x.shape[1:], fill, x.dtype)
            return np.concatenate([x, ext], axis=0)

        args = [pad(vr), pad(nr), pad(vs), pad(ns)]
        if intersectsish:
            args += [pad(rep_r[sel]), pad(rep_s[sel])]
        valid = pad(np.ones(len(p), bool), False)

        step = _refine_shard_step(body, mesh, len(args))
        with enable_x64():
            res, unc, count = step(*[jnp.asarray(a) for a in args],
                                   jnp.asarray(valid))
        res_h, unc_h, count_h = to_host(res, unc, count)
        res_h = res_h[: len(p)].copy()
        unc_h = unc_h[: len(p)]
        n_true += int(count_h)
        if unc_h.any():    # guard-band pairs: exact host re-check
            res_h[unc_h] = refine_mod.refine(R, S, p[unc_h],
                                             predicate=predicate,
                                             backend="numpy")
            n_true += int(res_h[unc_h].sum())
        out[sel] = res_h
    return out, {"refined_true": n_true}


# ---------------------------------------------------------------------------
# Fused sharded chain (DESIGN.md §12): MBR mask + APRIL trichotomy + exact
# refinement of every shard row under ONE shard_map
# ---------------------------------------------------------------------------

_FUSED_STEP_CACHE: dict = {}


def _fused_shard_step(mesh, with_filter: bool = True):
    """The one-dispatch chain, compiled per (mesh, filter-on/off).

    ``with_filter=False`` is the per-shard plan's skip-filter variant
    (DESIGN.md §13): no interval batch enters the step — every valid row
    is INDECISIVE and refines, so tiny candidate sets avoid the packing
    and kernel work entirely while staying inside one ``shard_map``.
    """
    key = (mesh, with_filter)
    if key in _FUSED_STEP_CACHE:
        return _FUSED_STEP_CACHE[key]
    from . import refine as refine_mod
    from .mbr_join import pair_mask_body

    # replicated MBR/cell tables, then the sharded per-row operands
    specs = ((P(),) * 4
             + (P("data"),) * 5      # ri, si, own_x, own_y, valid
             + ((P("data"),) if with_filter else ())  # packed batch pytree
             + (P("data"),) * 6)     # vr, nr, rep_r, vs, ns, rep_s

    def _finish(v, verd, vr, nr, rpr, vs, ns, rps):
        res, unc = refine_mod._intersects_impl_jnp(vr, nr, vs, ns, rpr, rps)
        indec = v & (verd == INDECISIVE)
        hit = (verd == TRUE_HIT) | (indec & res)
        unc = unc & indec
        counts = jax.lax.psum(jnp.stack([
            jnp.sum(v), jnp.sum(v & (verd == TRUE_NEG)),
            jnp.sum(verd == TRUE_HIT), jnp.sum(indec)]), "data")
        return verd, hit, unc, counts

    if with_filter:
        @partial(shard_map, mesh=mesh, in_specs=specs,
                 out_specs=(P("data"), P("data"), P("data"), P()))
        def step(mr, ms, lor, los, ri, si, ox, oy, vrow, batch,
                 vr, nr, rpr, vs, ns, rps):
            v = pair_mask_body(jnp, mr, ms, lor, los, ri, si, ox, oy) & vrow
            verd = april_filter_kernel_jnp(batch)
            verd = jnp.where(v, verd, jnp.int8(TRUE_NEG))
            return _finish(v, verd, vr, nr, rpr, vs, ns, rps)
    else:
        @partial(shard_map, mesh=mesh, in_specs=specs,
                 out_specs=(P("data"), P("data"), P("data"), P()))
        def step(mr, ms, lor, los, ri, si, ox, oy, vrow,
                 vr, nr, rpr, vs, ns, rps):
            v = pair_mask_body(jnp, mr, ms, lor, los, ri, si, ox, oy) & vrow
            verd = jnp.where(v, jnp.int8(INDECISIVE), jnp.int8(TRUE_NEG))
            return _finish(v, verd, vr, nr, rpr, vs, ns, rps)

    _FUSED_STEP_CACHE[key] = jax.jit(step)
    return _FUSED_STEP_CACHE[key]


def distributed_fused_join(R, S, approx_r, approx_s,
                           grid: int | None = None, mesh: Mesh | None = None,
                           plan: "PlanChoice | None" = None):
    """The intersects join as ONE sharded dispatch (DESIGN.md §12).

    The host runs the cheap grid-hash preprocessing; every candidate row
    then flows through MBR mask -> APRIL trichotomy -> exact refinement
    inside a single ``shard_map`` step, counts psum-reduce on device, and
    the lanes come back in one :func:`~repro.spatial.fused.to_host` gather
    (plus the sanctioned f64 escalation of guard-band pairs). Refinement is
    branch-free — every shard row refines, masked by its verdict — so this
    trades redundant FLOPs for zero intermediate syncs; the staged
    ``distributed_*`` steps remain the large-batch references. Pair *set*
    (order-insensitive) equals the staged chain. APRIL stores over polygon
    sides only.

    ``plan`` carries this shard's :class:`~repro.spatial.planner.PlanChoice`
    (DESIGN.md §13): a skip-filter plan drops the interval batch from the
    step — no packing, no kernel, every valid row refines — still as one
    ``shard_map`` dispatch (``approx_r``/``approx_s`` may then be ``None``).
    The join order a plan carries is irrelevant here: the branch-free
    kernel evaluates all three joins at once. Returns
    (pairs [K,2] int64, counts dict).
    """
    from .mbr_join import _pad_rows_pow2, _prepare, candidate_rows
    from . import refine as refine_mod
    from jax.experimental import enable_x64

    empty = np.zeros((0, 2), np.int64)
    zero = {"mbr_pairs": 0, "true_neg": 0, "true_hit": 0, "indecisive": 0}
    mbrs_r, mbrs_s, k, extent = _prepare(R.mbrs, S.mbrs, grid)
    if k == 0:
        return empty, zero
    ri, si, own_x, own_y, lo_r, lo_s = candidate_rows(mbrs_r, mbrs_s, k,
                                                      extent)
    if len(ri) == 0:
        return empty, zero
    mesh = mesh or make_join_mesh()
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    (mbrs_r, lo_r), _ = _pad_rows_pow2([mbrs_r, lo_r])
    (mbrs_s, lo_s), _ = _pad_rows_pow2([mbrs_s, lo_s])
    (pri, psi, pox, poy, vrow), n = _pad_rows_pow2(
        [ri, si, own_x, own_y, np.ones(len(ri), bool)], multiple=n_dev)
    frame = np.stack([pri, psi], axis=1)
    with_filter = not (plan is not None
                       and (plan.skip_filter or plan.method == "none"))
    if with_filter:
        packed = pack_pair_batch(approx_r.store, approx_s.store,
                                 frame, pad_batch_to=n_dev)
        batch = {key: jnp.asarray(a) for key, a in packed.arrays().items()}
    vr = np.asarray(R.verts, np.float64)[pri]
    vs = np.asarray(S.verts, np.float64)[psi]
    nr = np.asarray(R.nverts, np.int32)[pri]
    ns = np.asarray(S.nverts, np.int32)[psi]
    rpr = refine_mod._reps(R, pri)
    rps = refine_mod._reps(S, psi)

    step = _fused_shard_step(mesh, with_filter)
    with enable_x64():
        head = [jnp.asarray(a) for a in (mbrs_r, mbrs_s, lo_r, lo_s,
                                         pri, psi, pox, poy, vrow)]
        tail = [jnp.asarray(a) for a in (vr, nr, rpr, vs, ns, rps)]
        if with_filter:
            verd, hit, unc, counts = step(*head, batch, *tail)
        else:
            verd, hit, unc, counts = step(*head, *tail)
    verd, hit, unc, counts = to_host(verd, hit, unc, counts)
    hit, unc = hit[:n].copy(), unc[:n]
    if unc.any():          # sanctioned f64 escalation of guard-band rows
        esc = frame[:n][unc]
        hit[unc] = (verd[:n][unc] == TRUE_HIT) | refine_mod.refine(
            R, S, esc, predicate="intersects", backend="numpy")
    pairs = frame[:n][hit]
    return pairs, {"mbr_pairs": int(counts[0]), "true_neg": int(counts[1]),
                   "true_hit": int(counts[2]), "indecisive": int(counts[3])}
