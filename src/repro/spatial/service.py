"""`JoinService`: the online spatial-join serving layer (DESIGN.md §10).

The paper's approximations are built **once** in preprocessing and
amortized across many joins; this module exercises that contract as a
system. A long-lived service owns, per registered dataset:

* the polygon arrays themselves (a mutable handle — ``insert`` / ``delete``
  patch them in place),
* a warm :class:`~repro.spatial.mbr_join.MBRIndex` (the R-side bucket
  table of the §8 grid-hash join, built once and probed per batch),
* warm :class:`~repro.spatial.filters.base.Approximation` stores behind a
  byte-budgeted LRU :class:`~repro.spatial.store_cache.StoreCache` — the
  CSR ``IntervalLists`` device uploads ride along in ``meta`` and are
  reused across requests.

In front sits a micro-batching request queue: concurrent ``selection`` /
``window`` / ``intersects`` / ``within`` queries accumulate for a
configurable window, are grouped by (dataset, predicate, method, n_order),
and each group executes as ONE batched
:class:`~repro.spatial.plan.JoinPlan` pass — the query polygons of every
request in the group become one S-side dataset, and the result pairs
scatter back per request. Batching is an execution detail: the verdicts
equal the per-request sequential runs (asserted by
``benchmarks/service_throughput.py --smoke``).

Incremental maintenance keeps warm state warm: a mutation appends to the
dataset handle's log, patches the arrays and the MBR index immediately,
and cached stores replay their pending log suffix lazily on next use via
the filter's ``patch_insert`` / ``patch_delete`` (row splices — a patched
store is identical to a fresh rebuild). ``save_checkpoint`` persists host
copies of the datasets and interval-CSR stores plus each store's synced
position in the mutation log through
:class:`~repro.runtime.checkpoint.CheckpointManager`; restore re-creates
the stores and replays what they missed.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.april import AprilStore
from ..core.ri import RIStore
from ..core.rasterize import Extent, GLOBAL_EXTENT
from ..datagen.synthetic import PolygonDataset
from .filters import get_filter
from .mbr_join import MBRIndex
from .plan import JoinPlan
from .planner import PlanChoice, check_plan_mode
from .store_cache import StoreCache, DEFAULT_BUDGET

__all__ = ["JoinService", "JoinTicket", "SERVICE_PREDICATES"]

#: request predicates; 'window' is a rectangle query executed as
#: 'selection' with the rectangle's 4-corner polygon
SERVICE_PREDICATES = ("selection", "window", "intersects", "within")


def _pad_verts(verts: np.ndarray, vmax: int) -> np.ndarray:
    """Zero-pad [P, V, 2] along V (padding is masked by ``nverts``
    everywhere downstream)."""
    if verts.shape[1] == vmax:
        return verts
    pad = np.zeros((verts.shape[0], vmax - verts.shape[1], 2), np.float64)
    return np.concatenate([verts, pad], axis=1)


def _one_polygon_dataset(verts: np.ndarray) -> PolygonDataset:
    verts = np.asarray(verts, np.float64).reshape(-1, 2)
    return PolygonDataset(name="_patch", verts=verts[None],
                          nverts=np.array([len(verts)], np.int64))


@dataclass
class JoinTicket:
    """Handle returned by :meth:`JoinService.submit`; resolved at drain.

    ``pairs`` is [K, 2] int64 — (data object id, local query index) for the
    request's query polygons; ``stats`` is the executed group's
    ``JoinStats.to_dict()`` envelope (shared by every request in the
    micro-batch); ``latency`` is submit-to-resolution seconds.
    """
    dataset_id: str
    predicate: str
    pairs: np.ndarray | None = None
    stats: dict | None = None
    latency: float | None = None
    done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: float | None = None) -> "JoinTicket":
        if not self.done.wait(timeout):
            raise TimeoutError("join request not resolved "
                               f"(dataset={self.dataset_id!r})")
        return self


@dataclass
class _Request:
    ticket: JoinTicket
    exec_predicate: str
    method: str
    n_order: int
    verts: np.ndarray        # [Q, V, 2]
    nverts: np.ndarray       # [Q]
    t_submit: float = 0.0


class _DatasetHandle:
    """One registered dataset: mutable arrays + warm MBR index + the
    mutation log cached stores sync against."""

    def __init__(self, dataset: PolygonDataset, extent: Extent):
        self.dataset = dataset
        self.extent = extent
        self.log: list[tuple] = []      # ("insert", verts[V,2]) | ("delete", id)
        self._index: MBRIndex | None = None

    @property
    def seq(self) -> int:
        return len(self.log)

    @property
    def index(self) -> MBRIndex:
        if self._index is None:
            self._index = MBRIndex(self.dataset.mbrs)
        return self._index

    def insert(self, verts: np.ndarray) -> int:
        verts = np.asarray(verts, np.float64).reshape(-1, 2)
        ds = self.dataset
        vmax = max(ds.verts.shape[1], len(verts))
        row = _pad_verts(verts[None], vmax)
        self.dataset = PolygonDataset(
            name=ds.name, verts=np.concatenate(
                [_pad_verts(ds.verts, vmax), row]),
            nverts=np.append(ds.nverts, len(verts)))
        new_id = len(self.dataset) - 1
        if self._index is not None:
            self._index.insert(self.dataset.mbrs[new_id])
        self.log.append(("insert", verts))
        return new_id

    def delete(self, obj_id: int) -> None:
        ds = self.dataset
        if not 0 <= obj_id < len(ds):
            raise IndexError(f"delete: object id {obj_id} out of range "
                             f"[0, {len(ds)})")
        self.dataset = PolygonDataset(
            name=ds.name, verts=np.delete(ds.verts, obj_id, axis=0),
            nverts=np.delete(ds.nverts, obj_id))
        if self._index is not None:
            self._index.delete(obj_id)
        self.log.append(("delete", int(obj_id)))


class JoinService:
    """Long-lived spatial-join server over warm device-resident stores.

    ``window_s`` is the micro-batch accumulation window of the background
    worker (:meth:`start`); without a worker, call :meth:`drain` to execute
    everything pending synchronously (what tests and benchmarks do).
    Backend knobs mirror :class:`~repro.spatial.plan.JoinPlan` and apply to
    every batched pass.

    ``plan_mode="adaptive"`` (DESIGN.md §13) replaces the static
    method/n_order of each request group with the sample-based planner's
    pick, computed against the group's actual query batch and cached per
    (dataset, predicate, method, n_order) group key. The cached choice is
    invalidated once ``patch_insert``/``patch_delete`` drift — mutations
    applied since planning — reaches ``replan_after``; build cost is
    amortized in the cost model (warm stores serve many batches), which
    ``plan_opts`` can override. ``stats["replans"]`` counts planner runs.
    """

    def __init__(self, *, cache_bytes: int = DEFAULT_BUDGET,
                 window_s: float = 0.002, method: str = "april",
                 n_order: int = 10, filter_backend: str = "numpy",
                 refine_backend: str = "numpy", mbr_backend: str = "numpy",
                 pipeline_mode: str = "staged", plan_mode: str = "static",
                 plan_opts: dict | None = None, replan_after: int = 16):
        from .fused import check_pipeline_mode
        check_pipeline_mode(pipeline_mode)
        check_plan_mode(plan_mode)
        self.cache = StoreCache(cache_bytes)
        self.window_s = float(window_s)
        self.method = method
        self.n_order = int(n_order)
        self.filter_backend = filter_backend
        self.refine_backend = refine_backend
        self.mbr_backend = mbr_backend
        self.pipeline_mode = pipeline_mode
        self.plan_mode = plan_mode
        self.plan_opts = dict(plan_opts or {})
        self.replan_after = int(replan_after)
        # group key -> (PlanChoice, mutation seq at planning time);
        # guarded by _lock (planning itself is serialized by _exec_lock)
        self._plans: dict[tuple, tuple[PlanChoice, int]] = {}
        self.datasets: dict[str, _DatasetHandle] = {}
        self._pending: list[_Request] = []
        # guards the request queue, stats, latencies and worker lifecycle
        self._lock = threading.Lock()
        # serializes store/index/dataset access between the micro-batch
        # worker and mutating callers (mutations are cheap splices; queries
        # inside a batch still run fully vectorized).  Reentrant: _run_group
        # holds it across _handle/warm_store, which take it themselves when
        # called directly.  Order: _exec_lock outer, _lock inner — never
        # acquire _exec_lock while holding _lock.
        self._exec_lock = threading.RLock()
        self._have_work = threading.Event()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        self._latencies: list[float] = []
        # cumulative per-stage device-time breakdown across executed groups
        # (JoinStats.stage_times of every batch, summed)
        self._stage_times: dict[str, float] = {}
        self.stats = {"requests": 0, "batches": 0, "batched_requests": 0,
                      "inserts": 0, "deletes": 0, "replans": 0}

    # -- datasets and mutations ---------------------------------------------

    def register_dataset(self, dataset_id: str, dataset: PolygonDataset,
                         extent: Extent = GLOBAL_EXTENT) -> None:
        with self._exec_lock:
            if dataset_id in self.datasets:
                raise ValueError(
                    f"dataset {dataset_id!r} already registered")
            self.datasets[dataset_id] = _DatasetHandle(dataset, extent)

    def dataset(self, dataset_id: str) -> PolygonDataset:
        return self._handle(dataset_id).dataset

    def _handle(self, dataset_id: str) -> _DatasetHandle:
        with self._exec_lock:
            try:
                return self.datasets[dataset_id]
            except KeyError:
                raise KeyError(
                    f"unknown dataset {dataset_id!r}; registered: "
                    f"{sorted(self.datasets)}") from None

    def insert(self, dataset_id: str, verts: np.ndarray) -> int:
        """Add one polygon; returns its object id. Warm stores are patched
        lazily (each replays the mutation log suffix it has not seen on its
        next use) — nothing is rebuilt."""
        with self._exec_lock:
            new_id = self._handle(dataset_id).insert(verts)
        with self._lock:
            self.stats["inserts"] += 1
        return new_id

    def delete(self, dataset_id: str, obj_id: int) -> None:
        """Remove one polygon; later ids shift down by one (rebuild
        numbering)."""
        with self._exec_lock:
            self._handle(dataset_id).delete(obj_id)
        with self._lock:
            self.stats["deletes"] += 1

    # -- warm store access --------------------------------------------------

    def warm_store(self, dataset_id: str, method: str | None = None,
                   n_order: int | None = None):
        """The cached Approximation for (dataset, method, n_order), built
        on miss and brought current with the mutation log on hit."""
        method = method or self.method
        n_order = self.n_order if n_order is None else int(n_order)
        with self._exec_lock:
            handle = self._handle(dataset_id)
            key = (dataset_id, method, n_order)
            approx = self.cache.get(key)
            filt = get_filter(method)
            if approx is None:
                approx = filt.build(handle.dataset, n_order=n_order,
                                    extent=handle.extent, kind="polygon",
                                    side="r")
                approx.meta["mutation_seq"] = handle.seq
                self.cache.put(key, approx)
                return approx
            seq = approx.meta.get("mutation_seq", 0)
            if seq < handle.seq:
                for op in handle.log[seq:]:
                    if op[0] == "insert":
                        filt.patch_insert(approx,
                                          _one_polygon_dataset(op[1]))
                    else:
                        filt.patch_delete(approx, op[1])
                approx.meta["mutation_seq"] = handle.seq
                self.cache.resize(key)
            return approx

    # -- the request queue --------------------------------------------------

    def submit(self, dataset_id: str, predicate: str, query,
               nverts: np.ndarray | None = None, *,
               method: str | None = None,
               n_order: int | None = None) -> JoinTicket:
        """Enqueue one query; returns a :class:`JoinTicket`.

        ``query``: a polygon [V, 2] (``selection`` / ``intersects`` /
        ``within``), a rectangle ``(x0, y0, x1, y1)`` (``window``), or a
        padded batch [Q, V, 2] with ``nverts`` [Q].
        """
        if predicate not in SERVICE_PREDICATES:
            raise ValueError(f"unknown predicate {predicate!r}; expected "
                             f"one of {SERVICE_PREDICATES}")
        self._handle(dataset_id)
        if predicate == "window":
            x0, y0, x1, y1 = (float(v) for v in np.asarray(query).ravel())
            query = np.array([[x0, y0], [x1, y0], [x1, y1], [x0, y1]])
        query = np.asarray(query, np.float64)
        if query.ndim == 2:
            query = query[None]
        if nverts is None:
            nverts = np.full(len(query), query.shape[1], np.int64)
        exec_predicate = {"window": "selection"}.get(predicate, predicate)
        ticket = JoinTicket(dataset_id=dataset_id, predicate=predicate)
        req = _Request(ticket=ticket, exec_predicate=exec_predicate,
                       method=method or self.method,
                       n_order=self.n_order if n_order is None
                       else int(n_order),
                       verts=query, nverts=np.asarray(nverts, np.int64),
                       t_submit=time.perf_counter())
        with self._lock:
            self._pending.append(req)
            self.stats["requests"] += 1
        self._have_work.set()
        return ticket

    def drain(self) -> int:
        """Execute everything pending: one batched JoinPlan pass per
        (dataset, predicate, method, n_order) group. Returns the number of
        requests resolved."""
        with self._lock:
            batch, self._pending = self._pending, []
            self._have_work.clear()
        if not batch:
            return 0
        groups: dict[tuple, list[_Request]] = {}
        for req in batch:
            key = (req.ticket.dataset_id, req.exec_predicate, req.method,
                   req.n_order)
            groups.setdefault(key, []).append(req)
        for (did, predicate, method, n_order), reqs in groups.items():
            self._run_group(did, predicate, method, n_order, reqs)
        with self._lock:
            self.stats["batches"] += len(groups)
            self.stats["batched_requests"] += len(batch)
        return len(batch)

    def _plan_for(self, handle, dataset_id: str, predicate: str,
                  method: str, n_order: int, queries) -> PlanChoice:
        """The group's cached PlanChoice, recomputed once mutation drift
        (log entries since planning) reaches ``replan_after``. Callers hold
        ``_exec_lock``. Build cost is amortized 16x by default: warm stores
        serve many micro-batches (``plan_opts`` overrides)."""
        pkey = (dataset_id, predicate, method, n_order)
        with self._lock:
            cached = self._plans.get(pkey)
        if cached is not None and handle.seq - cached[1] < self.replan_after:
            return cached[0]
        opts = {"amortize_build": 16.0}
        opts.update(self.plan_opts)
        probe = JoinPlan(handle.dataset, queries, filter="april",
                         n_order=n_order, extent=handle.extent,
                         mbr_backend=self.mbr_backend,
                         mbr_index=handle.index,
                         plan_mode="adaptive", plan_opts=opts)
        choice = probe.plan(predicate)
        with self._lock:
            self._plans[pkey] = (choice, handle.seq)
            self.stats["replans"] += 1
        return choice

    def _run_group(self, dataset_id: str, predicate: str, method: str,
                   n_order: int, reqs: list[_Request]) -> None:
        with self._exec_lock:
            handle = self._handle(dataset_id)
            vmax = max(r.verts.shape[1] for r in reqs)
            q_verts = np.concatenate(
                [_pad_verts(r.verts, vmax) for r in reqs])
            q_nverts = np.concatenate([r.nverts for r in reqs])
            queries = PolygonDataset(name="_queries", verts=q_verts,
                                     nverts=q_nverts)
            if self.plan_mode == "adaptive":
                # the planner's pick overrides the request's method/n_order;
                # its warm store lands in the same LRU, so several chosen
                # configs stay resident side by side
                choice = self._plan_for(handle, dataset_id, predicate,
                                        method, n_order, queries)
                approx = self.warm_store(dataset_id, choice.method,
                                         choice.n_order)
                plan = JoinPlan(handle.dataset, queries,
                                filter=choice.method,
                                n_order=choice.n_order, extent=handle.extent,
                                filter_backend=self.filter_backend,
                                refine_backend=self.refine_backend,
                                mbr_backend=self.mbr_backend,
                                mbr_index=handle.index,
                                pipeline_mode=self.pipeline_mode,
                                plan_mode="adaptive", plan_choice=choice)
            else:
                approx = self.warm_store(dataset_id, method, n_order)
                plan = JoinPlan(handle.dataset, queries, filter=method,
                                n_order=n_order, extent=handle.extent,
                                filter_backend=self.filter_backend,
                                refine_backend=self.refine_backend,
                                mbr_backend=self.mbr_backend,
                                mbr_index=handle.index,
                                pipeline_mode=self.pipeline_mode)
            plan.build(prebuilt=(approx, None))
            pairs, stats = plan.execute(predicate)
            stats.extra["batched_requests"] = len(reqs)
            stats.extra["cache"] = dict(self.cache.stats)
        with self._lock:
            for key, dt in stats.stage_times().items():
                self._stage_times[key] = self._stage_times.get(key, 0.0) + dt
        envelope = stats.to_dict()
        # scatter: each request owns a contiguous run of query indices
        offs = np.cumsum([0] + [len(r.nverts) for r in reqs])
        order = np.argsort(pairs[:, 1], kind="stable")
        pairs = pairs[order]
        bounds = np.searchsorted(pairs[:, 1], offs)
        now = time.perf_counter()
        for i, req in enumerate(reqs):
            mine = pairs[bounds[i]: bounds[i + 1]].copy()
            mine[:, 1] -= offs[i]
            t = req.ticket
            t.pairs, t.stats = mine, envelope
            t.latency = now - req.t_submit
            with self._lock:
                self._latencies.append(t.latency)
            t.done.set()

    # -- background micro-batching worker -----------------------------------

    def start(self) -> None:
        """Run the micro-batch loop in a daemon thread: wait for the first
        pending request, accumulate for ``window_s``, drain."""

        def loop():
            while not self._stop.is_set():
                if not self._have_work.wait(timeout=0.05):
                    continue
                time.sleep(self.window_s)
                self.drain()

        with self._lock:
            if self._worker is not None:
                return
            self._stop.clear()
            self._worker = threading.Thread(target=loop, daemon=True)
            self._worker.start()

    def stop(self) -> None:
        with self._lock:
            worker, self._worker = self._worker, None
        if worker is None:
            return
        self._stop.set()
        # join outside _lock: the worker's drain() takes _lock itself
        worker.join()
        self.drain()

    # -- accounting ---------------------------------------------------------

    def latency_stats(self) -> dict:
        """p50/p99 submit-to-resolution latency over resolved requests,
        plus the cumulative per-stage device-time breakdown
        (``t_mbr``/``t_filter``/``t_refine``/``t_sync``) of the executed
        batches."""
        with self._lock:
            lat = np.asarray(self._latencies, np.float64)
            stages = dict(self._stage_times)
        if len(lat) == 0:
            return {"n": 0, "p50_s": 0.0, "p99_s": 0.0, "mean_s": 0.0,
                    "stage_times": stages}
        return {"n": int(len(lat)),
                "p50_s": float(np.percentile(lat, 50)),
                "p99_s": float(np.percentile(lat, 99)),
                "mean_s": float(lat.mean()),
                "stage_times": stages}

    # -- checkpointing ------------------------------------------------------

    def save_checkpoint(self, manager, step: int) -> None:
        """Persist datasets, interval-CSR stores (APRIL/RI) and the
        mutation log through a
        :class:`~repro.runtime.checkpoint.CheckpointManager`.

        Stores whose arrays are not flat-checkpointable (RA ragged grids,
        APRIL-C byte buffers, 5C+CH is cheap to rebuild) are rebuilt on
        first use after restore; each persisted store records the log
        position it is synced to, so restore replays exactly the mutations
        it missed.
        """
        tree: dict = {}
        extra: dict = {"datasets": {}, "stores": [],
                       "service": {"method": self.method,
                                   "n_order": self.n_order}}
        with self._exec_lock:
            for did, h in self.datasets.items():
                tree[f"ds/{did}/verts"] = h.dataset.verts
                tree[f"ds/{did}/nverts"] = h.dataset.nverts
                extra["datasets"][did] = {
                    "name": h.dataset.name,
                    "extent": [h.extent.x0, h.extent.y0, h.extent.side],
                    "log": [["insert", v.tolist()] if op == "insert"
                            else ["delete", v] for op, v in h.log],
                }
            for (did, method, n_order), approx in self.cache.items():
                store = approx.store
                if isinstance(store, AprilStore):
                    leaves = {"a_off": store.a_off, "a_ints": store.a_ints,
                              "f_off": store.f_off, "f_ints": store.f_ints}
                elif isinstance(store, RIStore):
                    leaves = {"off": store.off, "ints": store.ints,
                              "bit_off": store.bit_off, "bits": store.bits}
                else:
                    continue
                rec = {"dataset_id": did, "method": method,
                       "n_order": n_order,
                       "seq": int(approx.meta.get("mutation_seq", 0)),
                       "build_opts": dict(approx.meta.get("build_opts", {}))}
                if isinstance(store, RIStore):
                    rec["encoding"] = store.encoding
                extra["stores"].append(rec)
                for name, arr in leaves.items():
                    tree[f"store/{did}/{method}/{n_order}/{name}"] = arr
        manager.save(step, tree, extra=extra, block=True)

    @classmethod
    def restore_checkpoint(cls, manager, step: int | None = None,
                           **service_opts) -> "JoinService | None":
        """Rebuild a service from a checkpoint written by
        :meth:`save_checkpoint`; returns ``None`` when no step exists."""
        res = manager.restore(step)
        if res is None:
            return None
        _, flat, extra = res
        svc = cls(method=extra["service"]["method"],
                  n_order=extra["service"]["n_order"], **service_opts)
        for did, meta in extra["datasets"].items():
            ds = PolygonDataset(name=meta["name"],
                                verts=flat[f"ds/{did}/verts"],
                                nverts=flat[f"ds/{did}/nverts"])
            svc.register_dataset(did, ds, extent=Extent(*meta["extent"]))
            h = svc.datasets[did]
            h.log = [("insert", np.asarray(v, np.float64)) if op == "insert"
                     else ("delete", int(v))
                     for op, v in meta["log"]]
        for rec in extra["stores"]:
            did, method, n_order = (rec["dataset_id"], rec["method"],
                                    rec["n_order"])
            h = svc.datasets[did]
            pre = f"store/{did}/{method}/{n_order}"
            if method == "ri":
                store = RIStore(n_order=n_order, extent=h.extent,
                                encoding=rec["encoding"],
                                off=flat[f"{pre}/off"],
                                ints=flat[f"{pre}/ints"],
                                bit_off=flat[f"{pre}/bit_off"],
                                bits=flat[f"{pre}/bits"])
            else:
                store = AprilStore(n_order=n_order, extent=h.extent,
                                   a_off=flat[f"{pre}/a_off"],
                                   a_ints=flat[f"{pre}/a_ints"],
                                   f_off=flat[f"{pre}/f_off"],
                                   f_ints=flat[f"{pre}/f_ints"])
            from .filters import Approximation
            approx = Approximation(
                filter=method, store=store, n_order=n_order, extent=h.extent,
                kind="polygon",
                meta={"build_opts": rec["build_opts"],
                      "mutation_seq": rec["seq"]})
            svc.cache.put((did, method, n_order), approx)
        return svc
