"""Refinement: exact geometry tests for indecisive candidate pairs (the
final stage of the paper's §2 pipeline, dominating end-to-end join cost).

The batched refinement subsystem (DESIGN.md §7), mirroring the batched
candidate generation (§8), filtering (§3), and construction (§6) passes. All three refinement
variants — polygon x polygon ``intersects`` (also serving ``selection``),
``within``, and linestring x polygon — have dataset-level batched
formulations over vertex-count **bucketed** pair batches: pairs group by the
power-of-two class of their Er x Es orientation-tile size (the same
padding-waste lever as the §4 interval-width bucketing), so padding waste
stays <= 2x and the [N, Er, Es] working set stays bounded.

Backends (``refine_backend`` in :class:`~repro.spatial.plan.JoinPlan`):

* ``sequential`` — the per-pair f64 reference loop over the
  :mod:`repro.core.geometry` oracles (``refine_*_seq``); every batched
  backend must be verdict-identical to it.
* ``numpy`` — vectorized host pass with the CMBR optimization of
  Aghajarian et al. [2]: only edges overlapping the pair's common MBR take
  part in the segment sweep (mask-based pruning, exact — a crossing or
  touch point lies in both MBRs, so no contributing edge is ever pruned).
  Containment resolves branch-free via representative interior points
  classified with closed-region PiP (no per-pair Python fallback loop).
* ``jnp`` — the same pass jit-compiled on device under ``enable_x64``.
  XLA contracts mul+add chains into FMAs (below the HLO level, so
  ``optimization_barrier`` cannot stop it), which can flip near-zero
  orientation signs vs strict IEEE; every sign test therefore carries a
  guard band, and pairs with any borderline evaluation re-run on host —
  final verdicts are identical to the sequential oracle.
* ``pallas`` — the edge x edge orientation sweep runs through
  ``kernels/refine`` in f32 with a relative guard band: definite verdicts
  are taken from the device, near-degenerate pairs come back *uncertain*
  and are re-checked on host at f64, so definite verdicts never contradict
  the f64 oracle.
"""
from __future__ import annotations

import numpy as np

from ..core import geometry
from ..core.geometry import polygon_edges, segments_intersect, size_buckets

__all__ = [
    "REFINE_BACKENDS", "refine", "refine_pair",
    "refine_pairs", "refine_within_pairs", "refine_line_poly_pairs",
    "refine_pairs_seq", "refine_within_pairs_seq",
    "refine_line_poly_pairs_seq", "iter_pair_chunks",
]

REFINE_BACKENDS = ("numpy", "jnp", "pallas", "sequential")

#: bound on the padded [N, Er, Es] orientation working set per bucket chunk
_CHUNK_ELEMS = 1 << 20


def _check_backend(backend: str) -> None:
    if backend not in REFINE_BACKENDS:
        raise ValueError(f"unknown refine backend {backend!r}; "
                         f"expected one of {REFINE_BACKENDS}")


# ---------------------------------------------------------------------------
# Sequential per-pair references (the verdict oracle)
# ---------------------------------------------------------------------------

def refine_pair(R, i: int, S, j: int) -> bool:
    return geometry.polygons_intersect(R.verts[i], R.nverts[i],
                                       S.verts[j], S.nverts[j])


def refine_pairs_seq(R, S, pairs: np.ndarray) -> np.ndarray:
    """Per-pair f64 reference for exact polygon intersection, [N,2] -> [N]."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    return np.asarray([
        geometry.polygons_intersect(R.verts[i], R.nverts[i],
                                    S.verts[j], S.nverts[j])
        for i, j in pairs], bool)


def refine_within_pairs_seq(R, S, pairs: np.ndarray) -> np.ndarray:
    """Per-pair f64 reference for exact 'r within s', [N,2] -> [N]."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    return np.asarray([
        geometry.polygon_within(R.verts[i], R.nverts[i],
                                S.verts[j], S.nverts[j])
        for i, j in pairs], bool)


def refine_line_poly_pairs_seq(L, S, pairs: np.ndarray) -> np.ndarray:
    """Per-pair f64 reference for linestring x polygon intersection."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    out = np.zeros(len(pairs), bool)
    for k, (li, pj) in enumerate(pairs):
        line = L.verts[li, : L.nverts[li]]
        poly = S.verts[pj, : S.nverts[pj]]
        a0, a1 = line[:-1], line[1:]
        b0 = poly
        b1 = np.roll(poly, -1, axis=0)
        crossed = bool(segments_intersect(
            a0[:, None, :], a1[:, None, :],
            b0[None, :, :], b1[None, :, :]).any())
        out[k] = crossed or bool(
            geometry.points_in_polygon_closed(line[:1], poly)[0])
    return out


# ---------------------------------------------------------------------------
# Shared batched pieces
# ---------------------------------------------------------------------------

def _chain_edges(verts: np.ndarray, nverts: np.ndarray):
    """Open-chain edges: (starts [N,V-1,2], ends, mask). Edge i runs vertex
    i -> i+1; the ring-closing edge of :func:`polygon_edges` is absent."""
    starts = verts[:, :-1]
    ends = verts[:, 1:]
    mask = np.arange(verts.shape[1] - 1)[None, :] < (nverts[:, None] - 1)
    return starts, ends, mask


def _cmbr_mask(mr: np.ndarray, ms: np.ndarray, e0, e1):
    """Edges overlapping the pair's common MBR (inclusive — exact pruning)."""
    cm = np.stack([np.maximum(mr[:, 0], ms[:, 0]),
                   np.maximum(mr[:, 1], ms[:, 1]),
                   np.minimum(mr[:, 2], ms[:, 2]),
                   np.minimum(mr[:, 3], ms[:, 3])], axis=1)     # [N,4]
    lo = np.minimum(e0, e1)                                     # [N,V,2]
    hi = np.maximum(e0, e1)
    return ((lo[..., 0] <= cm[:, None, 2]) & (hi[..., 0] >= cm[:, None, 0])
            & (lo[..., 1] <= cm[:, None, 3]) & (hi[..., 1] >= cm[:, None, 1]))


def _pip_batch_np(points, pmask, b0, b1, bm):
    """Closed-region PiP of per-pair point sets against per-pair polygons.

    points [N,M,2] (pmask [N,M]) vs polygon edges [N,V,...]. Returns
    (inside_or_on [N,M]) with masked points reported True (vacuous)."""
    x = points[..., 0][:, :, None]                              # [N,M,1]
    y = points[..., 1][:, :, None]
    x0, y0 = b0[..., 0][:, None, :], b0[..., 1][:, None, :]     # [N,1,V]
    x1, y1 = b1[..., 0][:, None, :], b1[..., 1][:, None, :]
    m = bm[:, None, :]
    cond = (y0 <= y) != (y1 <= y)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = (y - y0) / np.where(y1 == y0, 1.0, y1 - y0)
    xint = x0 + t * (x1 - x0)
    inside = (np.sum(cond & (xint > x) & m, axis=2) % 2) == 1
    d = (x1 - x0) * (y - y0) - (y1 - y0) * (x - x0)
    onb = ((d == 0)
           & (np.minimum(x0, x1) <= x) & (x <= np.maximum(x0, x1))
           & (np.minimum(y0, y1) <= y) & (y <= np.maximum(y0, y1)) & m)
    return inside | onb.any(axis=2) | ~pmask


def _reps(D, idx: np.ndarray) -> np.ndarray:
    """Representative interior points for the selected polygons, [K,2]."""
    ui, inv = np.unique(np.asarray(idx, np.int64), return_inverse=True)
    return geometry.representative_points(D.verts[ui], D.nverts[ui])[inv]


def _compact_edges(e0, e1, mask):
    """Left-pack the masked-in edges of each row: [N,V,2] -> [N,K,2] with
    K = max kept per row. Pruned edges cannot contribute a crossing (the
    CMBR test is inclusive and exact), so sweeping the compacted arrays is
    result-identical while shrinking the [N, Er, Es] orientation tile by
    the prune rate on both axes. Low prune rates (< 1/4 of the padded
    width) skip the gather — the sweep saves less than the repacking
    costs."""
    K = max(1, int(mask.sum(axis=1).max()))
    if K >= mask.shape[1] * 3 // 4:
        return e0, e1, mask
    order = np.argsort(~mask, axis=1, kind="stable")
    take = order[:, :K]
    return (np.take_along_axis(e0, take[..., None], axis=1),
            np.take_along_axis(e1, take[..., None], axis=1),
            np.take_along_axis(mask, take, axis=1))


def _proper_cross_np(a0, a1, am, b0, b1, bm) -> np.ndarray:
    """Any *proper* (transversal, all orientations nonzero) edge crossing."""
    d1 = geometry._orient(b0[:, None, :, 0], b0[:, None, :, 1],
                          b1[:, None, :, 0], b1[:, None, :, 1],
                          a0[:, :, None, 0], a0[:, :, None, 1])
    d2 = geometry._orient(b0[:, None, :, 0], b0[:, None, :, 1],
                          b1[:, None, :, 0], b1[:, None, :, 1],
                          a1[:, :, None, 0], a1[:, :, None, 1])
    d3 = geometry._orient(a0[:, :, None, 0], a0[:, :, None, 1],
                          a1[:, :, None, 0], a1[:, :, None, 1],
                          b0[:, None, :, 0], b0[:, None, :, 1])
    d4 = geometry._orient(a0[:, :, None, 0], a0[:, :, None, 1],
                          a1[:, :, None, 0], a1[:, :, None, 1],
                          b1[:, None, :, 0], b1[:, None, :, 1])
    proper = (((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0))
              & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0))
    return (proper & am[:, :, None] & bm[:, None, :]).any(axis=(1, 2))


# ---------------------------------------------------------------------------
# numpy batched cores (one vertex-count bucket at a time)
# ---------------------------------------------------------------------------

def _sweep_pruned(a0, a1, am, b0, b1, bm, mr, ms,
                  use_cmbr: bool) -> np.ndarray:
    """Any-segment-crossing per row, with CMBR pruning: rows where either
    side loses all its edges cannot cross (exact — every crossing or touch
    point lies in both MBRs), and the survivors sweep compacted tiles."""
    if not use_cmbr:
        hit = segments_intersect(a0[:, :, None, :], a1[:, :, None, :],
                                 b0[:, None, :, :], b1[:, None, :, :])
        return (hit & am[:, :, None] & bm[:, None, :]).any(axis=(1, 2))
    ams = am & _cmbr_mask(mr, ms, a0, a1)
    bms = bm & _cmbr_mask(mr, ms, b0, b1)
    crossed = np.zeros(len(a0), bool)
    live = ams.any(axis=1) & bms.any(axis=1)
    if live.any():
        a0c, a1c, amc = _compact_edges(a0[live], a1[live], ams[live])
        b0c, b1c, bmc = _compact_edges(b0[live], b1[live], bms[live])
        hit = segments_intersect(a0c[:, :, None, :], a1c[:, :, None, :],
                                 b0c[:, None, :, :], b1c[:, None, :, :])
        crossed[live] = (hit & amc[:, :, None]
                         & bmc[:, None, :]).any(axis=(1, 2))
    return crossed


def _intersects_batch_np(vr, nr, vs, ns, rep_r, rep_s, mr, ms,
                         use_cmbr: bool) -> np.ndarray:
    a0, a1, am = polygon_edges(vr, nr)
    b0, b1, bm = polygon_edges(vs, ns)
    crossed = _sweep_pruned(a0, a1, am, b0, b1, bm, mr, ms, use_cmbr)
    # containment (no crossing): representative point of either side inside
    # the closed other — sound unconditionally, complete when not crossed;
    # PiP parity needs the full (unpruned) edge set
    ones = np.ones((len(vr), 1), bool)
    in_s = _pip_batch_np(rep_r[:, None, :], ones, b0, b1, bm)[:, 0]
    in_r = _pip_batch_np(rep_s[:, None, :], ones, a0, a1, am)[:, 0]
    return crossed | in_s | in_r


def _within_batch_np(vr, nr, vs, ns, mr, ms, use_cmbr: bool) -> np.ndarray:
    """Staged 'r within s': exact MBR vertex prefilter -> closed PiP of the
    surviving rows -> proper-crossing sweep of the all-inside rows only.
    Each stage is exact, so the staging never changes verdicts — it only
    skips tensor work the sequential reference short-circuits past."""
    N = len(vr)
    out = np.zeros(N, bool)
    pmask = np.arange(vr.shape[1])[None, :] < nr[:, None]
    x, y = vr[..., 0], vr[..., 1]
    inmbr = (((x >= ms[:, None, 0]) & (x <= ms[:, None, 2])
              & (y >= ms[:, None, 1]) & (y <= ms[:, None, 3])) | ~pmask)
    cand = inmbr.all(axis=1)          # a vertex outside MBR(s) decides False
    if not cand.any():
        return out
    b0, b1, bm = polygon_edges(vs[cand], ns[cand])
    all_in = _pip_batch_np(vr[cand], pmask[cand], b0, b1, bm).all(axis=1)
    if not all_in.any():
        return out
    keep = np.nonzero(cand)[0][all_in]
    a0, a1, am = polygon_edges(vr[keep], nr[keep])
    b0, b1, bm = b0[all_in], b1[all_in], bm[all_in]
    if use_cmbr:
        a0, a1, am = _compact_edges(
            a0, a1, am & _cmbr_mask(mr[keep], ms[keep], a0, a1))
        b0, b1, bm = _compact_edges(
            b0, b1, bm & _cmbr_mask(mr[keep], ms[keep], b0, b1))
    out[keep] = ~_proper_cross_np(a0, a1, am, b0, b1, bm)
    return out


def _line_batch_np(vl, nl, vs, ns, mr, ms, use_cmbr: bool) -> np.ndarray:
    a0, a1, am = _chain_edges(vl, nl)
    b0, b1, bm = polygon_edges(vs, ns)
    head_in = _pip_batch_np(vl[:, :1], np.ones((len(vl), 1), bool),
                            b0, b1, bm)[:, 0]
    crossed = _sweep_pruned(a0, a1, am, b0, b1, bm, mr, ms, use_cmbr)
    return crossed | head_in


# ---------------------------------------------------------------------------
# jnp cores (device twins of the numpy cores). XLA contracts mul+add chains
# into FMAs below the HLO level (optimization_barrier does not stop it), so
# near-zero orientation/parity signs can differ from the strict-IEEE numpy
# path. Every sign-critical comparison therefore carries a guard band: pairs
# with any borderline evaluation come back *uncertain* and re-run on host,
# making the final jnp verdicts identical to the sequential oracle.
# ---------------------------------------------------------------------------

#: relative guard half-width for jit'd f64 sign tests — a few hundred ulps,
#: far above any FMA-contraction delta, far below general-position margins
_EPS_GUARD = 2.0 ** -44


def _orient_unc_jnp(ax, ay, bx, by, cx, cy):
    """(orientation, borderline) — borderline flags magnitudes within the
    FMA guard band of zero, where the jit'd sign may disagree with numpy.
    When either product is exactly zero the fused evaluation is provably
    identical to strict IEEE (the fma reduces to a single rounding of the
    other term), so axis-aligned geometry — whose orientations vanish
    through exact zeros — is exempt and does not escalate."""
    import jax.numpy as jnp
    p1 = (bx - ax) * (cy - ay)
    p2 = (by - ay) * (cx - ax)
    d = p1 - p2
    unc = ((jnp.abs(d) <= _EPS_GUARD * (jnp.abs(p1) + jnp.abs(p2)))
           & (p1 != 0) & (p2 != 0))
    return d, unc


def _edges_jnp(verts, nverts):
    import jax.numpy as jnp
    V = verts.shape[1]
    idx = jnp.arange(V)[None, :]
    valid = idx < nverts[:, None]
    nxt = jnp.where(valid, (idx + 1) % jnp.maximum(nverts[:, None], 1), 0)
    starts = jnp.where(valid[..., None], verts, verts[:, :1, :])
    ends = jnp.take_along_axis(
        verts, jnp.broadcast_to(nxt[..., None], nxt.shape + (2,)), axis=1)
    ends = jnp.where(valid[..., None], ends, verts[:, :1, :])
    return starts, ends, valid


def _chain_edges_jnp(verts, nverts):
    import jax.numpy as jnp
    mask = jnp.arange(verts.shape[1] - 1)[None, :] < (nverts[:, None] - 1)
    return verts[:, :-1], verts[:, 1:], mask


def _quad_orients_jnp(a0, a1, b0, b1):
    d1, u1 = _orient_unc_jnp(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1],
                             a0[..., 0], a0[..., 1])
    d2, u2 = _orient_unc_jnp(b0[..., 0], b0[..., 1], b1[..., 0], b1[..., 1],
                             a1[..., 0], a1[..., 1])
    d3, u3 = _orient_unc_jnp(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1],
                             b0[..., 0], b0[..., 1])
    d4, u4 = _orient_unc_jnp(a0[..., 0], a0[..., 1], a1[..., 0], a1[..., 1],
                             b1[..., 0], b1[..., 1])
    return (d1, d2, d3, d4), (u1 | u2 | u3 | u4)


def _segments_intersect_jnp(a0, a1, b0, b1):
    """(hit, borderline) — broadcastable segment intersection + guard."""
    import jax.numpy as jnp
    (d1, d2, d3, d4), unc = _quad_orients_jnp(a0, a1, b0, b1)
    proper = (((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0))
              & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0))

    def on_seg(p0, p1, r):
        return ((jnp.minimum(p0[..., 0], p1[..., 0]) <= r[..., 0])
                & (r[..., 0] <= jnp.maximum(p0[..., 0], p1[..., 0]))
                & (jnp.minimum(p0[..., 1], p1[..., 1]) <= r[..., 1])
                & (r[..., 1] <= jnp.maximum(p0[..., 1], p1[..., 1])))

    touch = (((d1 == 0) & on_seg(b0, b1, a0))
             | ((d2 == 0) & on_seg(b0, b1, a1))
             | ((d3 == 0) & on_seg(a0, a1, b0))
             | ((d4 == 0) & on_seg(a0, a1, b1)))
    return proper | touch, unc


def _pip_batch_jnp(points, pmask, b0, b1, bm):
    """(inside_or_on [N,M], borderline [N,M]) closed-region PiP + guard."""
    import jax.numpy as jnp
    x = points[..., 0][:, :, None]
    y = points[..., 1][:, :, None]
    x0, y0 = b0[..., 0][:, None, :], b0[..., 1][:, None, :]
    x1, y1 = b1[..., 0][:, None, :], b1[..., 1][:, None, :]
    m = bm[:, None, :]
    cond = (y0 <= y) != (y1 <= y)
    step = ((y - y0) / jnp.where(y1 == y0, 1.0, y1 - y0)) * (x1 - x0)
    xint = x0 + step
    # step == 0 exactly (e.g. vertical edges) makes the fused add exact
    near = ((jnp.abs(xint - x)
             <= _EPS_GUARD * (jnp.abs(x0) + jnp.abs(step) + jnp.abs(x)))
            & (step != 0))
    inside = (jnp.sum(cond & (xint > x) & m, axis=2) % 2) == 1
    d, du = _orient_unc_jnp(x0, y0, x1, y1, x, y)
    inbox = ((jnp.minimum(x0, x1) <= x) & (x <= jnp.maximum(x0, x1))
             & (jnp.minimum(y0, y1) <= y) & (y <= jnp.maximum(y0, y1)) & m)
    onb = (d == 0) & inbox
    unc = ((cond & near & m) | (du & inbox)).any(axis=2) & pmask
    return inside | onb.any(axis=2) | ~pmask, unc


def _intersects_impl_jnp(vr, nr, vs, ns, rep_r, rep_s):
    """Pure-jnp batched intersects core (also the shard_map step body).

    Returns (verdicts [N], uncertain [N]) — uncertain pairs had a borderline
    sign evaluation and must be re-run on host."""
    import jax.numpy as jnp
    a0, a1, am = _edges_jnp(vr, nr)
    b0, b1, bm = _edges_jnp(vs, ns)
    hit, hunc = _segments_intersect_jnp(a0[:, :, None, :], a1[:, :, None, :],
                                        b0[:, None, :, :], b1[:, None, :, :])
    pair_mask = am[:, :, None] & bm[:, None, :]
    crossed = (hit & pair_mask).any(axis=(1, 2))
    ones = jnp.ones((vr.shape[0], 1), bool)
    in_s, u1 = _pip_batch_jnp(rep_r[:, None, :], ones, b0, b1, bm)
    in_r, u2 = _pip_batch_jnp(rep_s[:, None, :], ones, a0, a1, am)
    unc = (hunc & pair_mask).any(axis=(1, 2)) | u1[:, 0] | u2[:, 0]
    # a True reached through a non-borderline element holds on host too —
    # no need to escalate, whatever else is borderline
    definite_true = ((hit & ~hunc & pair_mask).any(axis=(1, 2))
                     | (in_s[:, 0] & ~u1[:, 0]) | (in_r[:, 0] & ~u2[:, 0]))
    return crossed | in_s[:, 0] | in_r[:, 0], unc & ~definite_true


def _within_impl_jnp(vr, nr, vs, ns):
    """(verdicts [N], uncertain [N]) batched 'r within s' on device."""
    import jax.numpy as jnp
    a0, a1, am = _edges_jnp(vr, nr)
    b0, b1, bm = _edges_jnp(vs, ns)
    pmask = jnp.arange(vr.shape[1])[None, :] < nr[:, None]
    in_b, pip_unc = _pip_batch_jnp(vr, pmask, b0, b1, bm)
    all_in = in_b.all(axis=1)
    (d1, d2, d3, d4), ounc = _quad_orients_jnp(
        a0[:, :, None, :], a1[:, :, None, :],
        b0[:, None, :, :], b1[:, None, :, :])
    proper = (((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0))
              & (d1 != 0) & (d2 != 0) & (d3 != 0) & (d4 != 0))
    pair_mask = am[:, :, None] & bm[:, None, :]
    proper = (proper & pair_mask).any(axis=(1, 2))
    # a certainly-not-all-inside pair is False whatever the sweep says
    pu = pip_unc.any(axis=1)
    unc = pu | (all_in & (ounc & pair_mask).any(axis=(1, 2)))
    return all_in & ~proper, unc


def _line_impl_jnp(vl, nl, vs, ns):
    """(verdicts [N], uncertain [N]) linestring x polygon on device."""
    import jax.numpy as jnp
    a0, a1, am = _chain_edges_jnp(vl, nl)
    b0, b1, bm = _edges_jnp(vs, ns)
    hit, hunc = _segments_intersect_jnp(a0[:, :, None, :], a1[:, :, None, :],
                                        b0[:, None, :, :], b1[:, None, :, :])
    pair_mask = am[:, :, None] & bm[:, None, :]
    crossed = (hit & pair_mask).any(axis=(1, 2))
    ones = jnp.ones((vl.shape[0], 1), bool)
    head_in, hu = _pip_batch_jnp(vl[:, :1], ones, b0, b1, bm)
    unc = (hunc & pair_mask).any(axis=(1, 2)) | hu[:, 0]
    definite_true = ((hit & ~hunc & pair_mask).any(axis=(1, 2))
                     | (head_in[:, 0] & ~hu[:, 0]))
    return crossed | head_in[:, 0], unc & ~definite_true


_JNP_REFINE_JIT: dict | None = None


def _refine_jnp(kind: str, *arrays) -> tuple[np.ndarray, np.ndarray]:
    """Run a jit'd device core; returns (verdicts, uncertain) as numpy."""
    global _JNP_REFINE_JIT
    import jax
    from jax.experimental import enable_x64
    with enable_x64():
        if _JNP_REFINE_JIT is None:
            _JNP_REFINE_JIT = {
                "intersects": jax.jit(_intersects_impl_jnp),
                "within": jax.jit(_within_impl_jnp),
                "line": jax.jit(_line_impl_jnp),
            }
        res, unc = _JNP_REFINE_JIT[kind](*arrays)
        return np.array(res), np.asarray(unc)     # res: writable copy


# ---------------------------------------------------------------------------
# pallas: f32 device sweep + f64 host escalation of uncertain pairs
# ---------------------------------------------------------------------------

def _pallas_sweep(a0, a1, am, b0, b1, bm):
    import jax
    from ..kernels.refine import batch_edges_intersect
    interpret = jax.default_backend() != "tpu"
    hit, unc = batch_edges_intersect(a0, a1, am, b0, b1, bm,
                                     interpret=interpret)
    return np.asarray(hit), np.asarray(unc)


# ---------------------------------------------------------------------------
# Bucketed public drivers
# ---------------------------------------------------------------------------

def _bucketed(nvr: np.ndarray, nvs: np.ndarray, fn) -> np.ndarray:
    """Run ``fn(sel, Va, Vb) -> bool[len(sel)]`` over power-of-two buckets of
    the per-pair Er x Es tile size (padding waste <= 2x in the product)."""
    out = np.zeros(len(nvr), bool)
    sizes = np.maximum(nvr, 1) * np.maximum(nvs, 1)
    for sel in size_buckets(sizes, _CHUNK_ELEMS):
        Va = int(nvr[sel].max())
        Vb = int(nvs[sel].max())
        out[sel] = fn(sel, Va, Vb)
    return out


def iter_pair_chunks(R, S, pairs: np.ndarray):
    """Yield (sel, p, vr, nr, vs, ns) vertex-count-bucketed pair chunks —
    the one bucketing contract shared by the host drivers here and the
    sharded driver in :mod:`repro.spatial.distributed`."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    nvr = R.nverts[pairs[:, 0]]
    nvs = S.nverts[pairs[:, 1]]
    sizes = np.maximum(nvr, 1) * np.maximum(nvs, 1)
    for sel in size_buckets(sizes, _CHUNK_ELEMS):
        p = pairs[sel]
        Va = int(nvr[sel].max())
        Vb = int(nvs[sel].max())
        yield (sel, p, R.verts[:, :Va][p[:, 0]], nvr[sel],
               S.verts[:, :Vb][p[:, 1]], nvs[sel])


def refine_pairs(R, S, pairs: np.ndarray, use_cmbr: bool = True,
                 backend: str = "numpy") -> np.ndarray:
    """Exact intersection for candidate pairs [N,2] -> [N] bool, batched over
    vertex-count buckets on the selected backend."""
    _check_backend(backend)
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    if backend == "sequential":
        return refine_pairs_seq(R, S, pairs)
    nvr = R.nverts[pairs[:, 0]]
    nvs = S.nverts[pairs[:, 1]]
    rep_r = _reps(R, pairs[:, 0])
    rep_s = _reps(S, pairs[:, 1])

    def run(sel, Va, Vb):
        p = pairs[sel]
        vr = R.verts[:, :Va][p[:, 0]]
        vs = S.verts[:, :Vb][p[:, 1]]
        nr, ns = nvr[sel], nvs[sel]
        if backend == "jnp":
            res, unc = _refine_jnp("intersects", vr, nr, vs, ns,
                                   rep_r[sel], rep_s[sel])
            if unc.any():   # borderline signs: re-run on host (strict IEEE)
                res[unc] = _intersects_batch_np(
                    vr[unc], nr[unc], vs[unc], ns[unc],
                    rep_r[sel][unc], rep_s[sel][unc],
                    R.mbrs[p[unc, 0]], S.mbrs[p[unc, 1]], use_cmbr)
            return res
        if backend == "pallas":
            return _refine_pallas_intersects(
                R, S, p, vr, nr, vs, ns, rep_r[sel], rep_s[sel], use_cmbr)
        return _intersects_batch_np(vr, nr, vs, ns, rep_r[sel], rep_s[sel],
                                    R.mbrs[p[:, 0]], S.mbrs[p[:, 1]],
                                    use_cmbr)

    return _bucketed(nvr, nvs, run)


def _refine_pallas_intersects(R, S, p, vr, nr, vs, ns, rep_r, rep_s,
                              use_cmbr) -> np.ndarray:
    a0, a1, am = polygon_edges(vr, nr)
    b0, b1, bm = polygon_edges(vs, ns)
    ams, bms = am, bm
    if use_cmbr:
        ams = am & _cmbr_mask(R.mbrs[p[:, 0]], S.mbrs[p[:, 1]], a0, a1)
        bms = bm & _cmbr_mask(R.mbrs[p[:, 0]], S.mbrs[p[:, 1]], b0, b1)
    hit, unc = _pallas_sweep(a0, a1, ams, b0, b1, bms)
    out = hit & ~unc
    # no definite crossing: containment via host closed-PiP of the reps
    rest = ~hit & ~unc
    if rest.any():
        ones = np.ones((int(rest.sum()), 1), bool)
        in_s = _pip_batch_np(rep_r[rest][:, None, :], ones,
                             b0[rest], b1[rest], bm[rest])[:, 0]
        in_r = _pip_batch_np(rep_s[rest][:, None, :], ones,
                             a0[rest], a1[rest], am[rest])[:, 0]
        out[rest] = in_s | in_r
    # guard band tripped: full f64 re-check on host
    if unc.any():
        out[unc] = refine_pairs(R, S, p[unc], use_cmbr=use_cmbr,
                                backend="numpy")
    return out


def refine_within_pairs(R, S, pairs: np.ndarray,
                        backend: str = "numpy") -> np.ndarray:
    """Exact 'r within s' for candidate pairs [N,2] -> [N] bool, batched."""
    _check_backend(backend)
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    if backend == "sequential":
        return refine_within_pairs_seq(R, S, pairs)
    nvr = R.nverts[pairs[:, 0]]
    nvs = S.nverts[pairs[:, 1]]

    def run(sel, Va, Vb):
        p = pairs[sel]
        vr = R.verts[:, :Va][p[:, 0]]
        vs = S.verts[:, :Vb][p[:, 1]]
        nr, ns = nvr[sel], nvs[sel]
        if backend == "jnp":
            res, unc = _refine_jnp("within", vr, nr, vs, ns)
            if unc.any():
                res[unc] = _within_batch_np(
                    vr[unc], nr[unc], vs[unc], ns[unc],
                    R.mbrs[p[unc, 0]], S.mbrs[p[unc, 1]], True)
            return res
        if backend == "pallas":
            a0, a1, am = polygon_edges(vr, nr)
            b0, b1, bm = polygon_edges(vs, ns)
            hit, unc = _pallas_sweep(a0, a1, am, b0, b1, bm)
            out = np.zeros(len(p), bool)       # definite crossing: not within
            todo = ~hit | unc
            if todo.any():
                out[todo] = _within_batch_np(
                    vr[todo], nr[todo], vs[todo], ns[todo],
                    R.mbrs[p[todo, 0]], S.mbrs[p[todo, 1]], True)
            return out
        return _within_batch_np(vr, nr, vs, ns, R.mbrs[p[:, 0]],
                                S.mbrs[p[:, 1]], True)

    return _bucketed(nvr, nvs, run)


def refine_line_poly_pairs(L, S, pairs: np.ndarray,
                           backend: str = "numpy") -> np.ndarray:
    """Exact linestring x polygon intersection for [N,2] (line, poly) pairs,
    batched over vertex-count buckets on the selected backend."""
    _check_backend(backend)
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    if backend == "sequential":
        return refine_line_poly_pairs_seq(L, S, pairs)
    nvl = L.nverts[pairs[:, 0]]
    nvs = S.nverts[pairs[:, 1]]

    def run(sel, Va, Vb):
        p = pairs[sel]
        vl = L.verts[:, :Va][p[:, 0]]
        vs = S.verts[:, :Vb][p[:, 1]]
        nl, ns = nvl[sel], nvs[sel]
        if backend == "jnp":
            res, unc = _refine_jnp("line", vl, nl, vs, ns)
            if unc.any():
                res[unc] = _line_batch_np(
                    vl[unc], nl[unc], vs[unc], ns[unc],
                    L.mbrs[p[unc, 0]], S.mbrs[p[unc, 1]], True)
            return res
        if backend == "pallas":
            a0, a1, am = _chain_edges(vl, nl)
            b0, b1, bm = polygon_edges(vs, ns)
            hit, unc = _pallas_sweep(a0, a1, am, b0, b1, bm)
            out = hit & ~unc
            rest = ~hit & ~unc
            if rest.any():
                out[rest] = _pip_batch_np(
                    vl[rest][:, :1], np.ones((int(rest.sum()), 1), bool),
                    b0[rest], b1[rest], bm[rest])[:, 0]
            if unc.any():
                out[unc] = _line_batch_np(
                    vl[unc], nl[unc], vs[unc], ns[unc],
                    L.mbrs[p[unc, 0]], S.mbrs[p[unc, 1]], False)
            return out
        return _line_batch_np(vl, nl, vs, ns, L.mbrs[p[:, 0]],
                              S.mbrs[p[:, 1]], True)

    return _bucketed(nvl, nvs, run)


def refine(R, S, pairs: np.ndarray, predicate: str = "intersects",
           backend: str = "numpy") -> np.ndarray:
    """Predicate dispatcher: one entry point for all refinement variants.

    ``selection`` shares the intersects refinement (query polygons as S)."""
    if predicate == "within":
        return refine_within_pairs(R, S, pairs, backend=backend)
    if predicate == "linestring":
        return refine_line_poly_pairs(R, S, pairs, backend=backend)
    if predicate not in ("intersects", "selection"):
        raise ValueError(f"unknown predicate {predicate!r}; expected one of "
                         "('intersects', 'within', 'linestring', "
                         "'selection')")
    return refine_pairs(R, S, pairs, backend=backend)


# ---------------------------------------------------------------------------
# Fused-chain device refinement (DESIGN.md §12)
# ---------------------------------------------------------------------------

def device_geometry(D, kind: str = "polygon") -> dict:
    """f64 device copies of a dataset's padded vertex tensors, plus (for
    polygons) representative interior points for every object.

    Uploaded once per dataset and cached on the handle (the
    ``_interval_lists_cache`` idiom of ``core.join``), so fused chains and
    warm service groups gather by index instead of re-packing host slabs
    per query. The cache keys on the identity of the ``verts`` array —
    incremental dataset patches swap the array and naturally invalidate.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    key = (id(D.verts), kind)
    cached = getattr(D, "_device_geom", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    with enable_x64():
        geom = {
            "verts": jnp.asarray(np.asarray(D.verts, np.float64)),
            "nverts": jnp.asarray(np.asarray(D.nverts, np.int32)),
        }
        if kind != "line":
            reps = geometry.representative_points(D.verts, D.nverts)
            geom["reps"] = jnp.asarray(np.asarray(reps, np.float64))
    try:
        D._device_geom = (key, geom)
    except AttributeError:      # slotted handle: still correct, just colder
        pass
    return geom


_FUSED_REFINE_FNS: dict = {}
#: unroll bound for the chunked packed-prefix loop (compile-time lever)
_MAX_REFINE_CHUNKS = 32


def _fused_refine_fn(kind: str, C: int):
    """jit'd chunked refinement of a front-packed pair prefix.

    The packed frame is walked in static chunks of ``C``; a chunk whose
    start lies past the device survivor count is skipped with
    ``jax.lax.cond`` — XLA executes only the taken branch, so the work
    scales with the (data-dependent) survivor count without the count ever
    visiting the host.
    """
    import jax
    import jax.numpy as jnp

    if (kind, C) in _FUSED_REFINE_FNS:
        return _FUSED_REFINE_FNS[(kind, C)]

    def run(vr_all, nr_all, rep_r, vs_all, ns_all, rep_s, ri, si,
            perm, count):
        Np = perm.shape[0]
        res = jnp.zeros(Np, bool)
        unc = jnp.zeros(Np, bool)
        for c0 in range(0, Np, C):
            idx = perm[c0:c0 + C]
            take = (c0 + jnp.arange(C)) < count

            def live(_):
                rr = ri[idx]
                ss = si[idx]
                vr, nr = vr_all[rr], nr_all[rr]
                vs, ns = vs_all[ss], ns_all[ss]
                if kind == "intersects":
                    v, u = _intersects_impl_jnp(vr, nr, vs, ns,
                                                rep_r[rr], rep_s[ss])
                elif kind == "within":
                    v, u = _within_impl_jnp(vr, nr, vs, ns)
                else:
                    v, u = _line_impl_jnp(vr, nr, vs, ns)
                return v & take, u & take

            def dead(_):
                return jnp.zeros(C, bool), jnp.zeros(C, bool)

            v, u = jax.lax.cond(c0 < count, live, dead, 0)
            res = res.at[c0:c0 + C].set(v)
            unc = unc.at[c0:c0 + C].set(u)
        return res, unc

    _FUSED_REFINE_FNS[(kind, C)] = jax.jit(run)
    return _FUSED_REFINE_FNS[(kind, C)]


def fused_refine_lanes(R, S, ri_dev, si_dev, perm, count,
                       predicate: str = "intersects"):
    """Device (res, unc) lanes over a front-packed indecisive prefix.

    ``perm``/``count`` come from ``kernels.compact.compact_mask`` over the
    INDECISIVE status lane; ``ri_dev``/``si_dev`` are the device pair frame.
    Returns [Np] bool lanes in the *packed* frame (``Np`` = ``len(perm)``
    padded up to the chunk size, padding entries False); scatter back
    through ``perm``. ``unc`` marks FMA-borderline pairs for the single
    end-of-chain host escalation — identical to the staged jnp backend's
    per-bucket escalation set.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    kind = {"intersects": "intersects", "selection": "intersects",
            "within": "within", "linestring": "line"}[predicate]
    geom_r = device_geometry(R, kind="line" if kind == "line" else "polygon")
    geom_s = device_geometry(S)
    N = perm.shape[0]
    if N == 0:
        return jnp.zeros(0, bool), jnp.zeros(0, bool), perm
    # chunk size: bounded [C, Er, Es] tile, bounded unroll
    Va = int(np.asarray(R.nverts).max(initial=1))
    Vb = int(np.asarray(S.nverts).max(initial=1))
    by_mem = max(8, _CHUNK_ELEMS // max(1, Va * Vb))
    by_unroll = -(-N // _MAX_REFINE_CHUNKS)
    C = 1 << int(np.ceil(np.log2(max(by_mem, by_unroll, 1))))
    Np = -(-N // C) * C
    # pad the permutation with out-of-frame indices: the scatter back into
    # candidate-frame lanes drops them (mode='drop')
    perm_p = jnp.concatenate(
        [perm, jnp.full(Np - N, N, jnp.int32)]) if Np != N else perm
    with enable_x64():
        fn = _fused_refine_fn(kind, C)
        res, unc = fn(geom_r["verts"], geom_r["nverts"],
                      geom_r.get("reps"), geom_s["verts"],
                      geom_s["nverts"], geom_s.get("reps"),
                      ri_dev, si_dev, perm_p, count)
    return res, unc, perm_p
