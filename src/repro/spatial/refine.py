"""Refinement step: exact geometry tests for indecisive candidate pairs.

Batched, vectorized implementation with the CMBR optimization of
Aghajarian et al. [2]: only edges overlapping the pair's common MBR take part
in the segment-intersection test (mask-based pruning — TPU-friendly, no
compaction). Containment falls back to PiP tests of one representative
vertex per side. ``kernels/refine`` provides the Pallas version of the
edge x edge orientation pass; this module is the numpy/jnp reference used by
the end-to-end pipeline.
"""
from __future__ import annotations

import numpy as np

from ..core import geometry

__all__ = ["refine_pairs", "refine_pair", "refine_within_pairs",
           "refine_line_poly_pairs"]


def refine_pair(R, i: int, S, j: int) -> bool:
    return geometry.polygons_intersect(R.verts[i], R.nverts[i],
                                       S.verts[j], S.nverts[j])


def _edges(verts, nverts, idx):
    """Padded edge arrays for the selected polygons: [B, V, 2, 2] + mask."""
    v = verts[idx]
    n = nverts[idx]
    B, V, _ = v.shape
    starts, ends, mask = geometry.polygon_edges(v, n)
    return starts, ends, mask


def refine_pairs(R, S, pairs: np.ndarray, use_cmbr: bool = True) -> np.ndarray:
    """Exact intersection for candidate pairs [N,2] -> [N] bool, vectorized
    over pairs with edge padding (batch the MXU-shaped orientation tests).
    Chunks the pair axis to bound the [N, Er, Es] working set."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    if len(pairs) == 0:
        return np.zeros(0, bool)
    va = R.verts.shape[1]
    vb = S.verts.shape[1]
    chunk = max(1, int(2e7 // max(1, va * vb)))
    if len(pairs) > chunk:
        return np.concatenate([
            refine_pairs(R, S, pairs[k: k + chunk], use_cmbr)
            for k in range(0, len(pairs), chunk)])
    a0, a1, am = _edges(R.verts, R.nverts, pairs[:, 0])
    b0, b1, bm = _edges(S.verts, S.nverts, pairs[:, 1])

    if use_cmbr:
        mr = R.mbrs[pairs[:, 0]]
        ms = S.mbrs[pairs[:, 1]]
        cm = np.stack([np.maximum(mr[:, 0], ms[:, 0]),
                       np.maximum(mr[:, 1], ms[:, 1]),
                       np.minimum(mr[:, 2], ms[:, 2]),
                       np.minimum(mr[:, 3], ms[:, 3])], axis=1)  # [N,4]

        def edge_in_cmbr(e0, e1):
            lo = np.minimum(e0, e1)   # [N,V,2]
            hi = np.maximum(e0, e1)
            return ((lo[..., 0] <= cm[:, None, 2]) & (hi[..., 0] >= cm[:, None, 0])
                    & (lo[..., 1] <= cm[:, None, 3]) & (hi[..., 1] >= cm[:, None, 1]))

        am = am & edge_in_cmbr(a0, a1)
        bm = bm & edge_in_cmbr(b0, b1)

    hit = geometry.segments_intersect(
        a0[:, :, None, :], a1[:, :, None, :], b0[:, None, :, :], b1[:, None, :, :])
    hit &= am[:, :, None] & bm[:, None, :]
    out = hit.any(axis=(1, 2))

    # containment for pairs with no boundary crossing
    rest = np.nonzero(~out)[0]
    for k in rest:
        i, j = pairs[k]
        va = R.verts[i, : R.nverts[i]]
        vb = S.verts[j, : S.nverts[j]]
        out[k] = bool(geometry.points_in_polygon(va[:1], vb)[0]
                      or geometry.points_in_polygon(vb[:1], va)[0])
    return out


def refine_within_pairs(R, S, pairs: np.ndarray) -> np.ndarray:
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    return np.asarray([
        geometry.polygon_within(R.verts[i], R.nverts[i], S.verts[j], S.nverts[j])
        for i, j in pairs], bool)


def refine_line_poly_pairs(L, S, pairs: np.ndarray) -> np.ndarray:
    """Exact linestring x polygon intersection for [N,2] (line, poly) pairs."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    out = np.zeros(len(pairs), bool)
    for k, (li, pj) in enumerate(pairs):
        line = L.verts[li, : L.nverts[li]]
        poly = S.verts[pj, : S.nverts[pj]]
        a0, a1 = line[:-1], line[1:]
        b0 = poly; b1 = np.roll(poly, -1, axis=0)
        crossed = bool(geometry.segments_intersect(
            a0[:, None, :], a1[:, None, :], b0[None, :, :], b1[None, :, :]).any())
        out[k] = crossed or bool(geometry.points_in_polygon(line[:1], poly)[0])
    return out
