"""Cost-based adaptive query planner (DESIGN.md §13).

The repo has five filter methods, granularity (``n_order``), four AA/AF/FA
join orders, and two pipeline modes — but until this module nothing
*chose* among them. ``choose_plan`` samples a small slice of the MBR
candidate pairs, runs the cheap trichotomy on probe APRIL stores built
over just the sampled objects, and estimates — in machine-independent
work units — what every static configuration would cost on the full
candidate set. The argmin becomes the :class:`PlanChoice` that
``JoinPlan(plan_mode="adaptive")`` executes.

Cost model (work unit = one interval comparison of the two-pointer merge
join, paper Algorithm 2):

* **filter** — per-pair early-exit comparisons under the candidate join
  order, averaged over the sample and scaled to the candidate count.
  Order semantics mirror :func:`repro.core.join.april_verdict_pair`:
  an AA miss or an AF/FA hit stops the pair.
* **refine** — ``C_REFINE`` per vertex product (an edge-pair orientation
  test costs about one comparison), charged to the pairs the sample says
  stay INDECISIVE; the ``none`` "skip the intermediate filter" config
  charges it to every candidate.
* **build** — ``C_BUILD`` per interval constructed (DDA + scanline work),
  extrapolated from the probe store's mean intervals per sampled object.
  ``amortize_build`` divides this term for build-once/query-forever
  deployments (the service replans with amortization > 1).
* **decode** — APRIL-C only: following the Decode-Work Law (PAPERS.md),
  decompression cost is bounded by the interval volume actually touched —
  ``C_DECODE`` per A-interval of the batch plus, at the AA-survivor rate,
  per F-interval. (A per-pair upper bound of the per-unique-object decode;
  :func:`measured_work` charges the exact unique-object quantity.)

Sampling is seeded (``numpy.random.default_rng(seed)``) so planning is a
pure function of its inputs: same datasets, candidates, and options →
same :class:`PlanChoice`, which the property tests assert. The estimate
of the chosen plan is never worse than the best static estimate *by
construction* — the chooser is an argmin over the same estimator.

Tiny candidate sets skip everything: below ``skip_filter_below`` pairs
the planner returns the ``none`` config without building probe stores
(refining a handful of pairs is cheaper than any preprocessing).

Planning itself is cost-bounded so the overhead amortizes even on small
workloads: the effective sample is ``min(sample_size, n_cand // 16)``
(floor 8), the requested granularity is always probed, and each extra
granularity is probed only while cumulative probe work plus its predicted
cost (×4 per +2 orders — the F-interval area scaling) stays within
``probe_budget`` of the cheapest full-join estimate seen so far. Skipped
granularities simply drop out of the costed sweep; ``est["n_orders"]``
records what was actually probed.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from ..core.rasterize import Extent, GLOBAL_EXTENT

__all__ = [
    "PLAN_MODES", "PLANNER_METHODS", "ORDER_CHOICES", "PLAN_DEFAULTS",
    "PlanChoice", "ProfileCache", "check_plan_mode", "choose_plan",
    "static_configs", "measured_work",
]

#: ``JoinPlan(plan_mode=...)``: ``static`` executes the constructor knobs
#: verbatim; ``adaptive`` runs :func:`choose_plan` on the first execute.
PLAN_MODES = ("static", "adaptive")

#: methods the cost model can price. The exotic filters (ri/ra/5cch) stay
#: static-only: their work is not interval-comparison shaped.
PLANNER_METHODS = ("none", "april", "april-c")

#: the Table-7 join-order sweep (paper §7.2.2); the first is the default.
ORDER_CHOICES = (("AA", "AF", "FA"), ("AA", "FA", "AF"),
                 ("AF", "FA", "AA"), ("FA", "AF", "AA"))

PLAN_DEFAULTS: dict = {
    "sample_size": 64,        # candidate pairs profiled
    "seed": 0,                # rng seed -> deterministic planning
    "methods": PLANNER_METHODS,
    "n_orders": None,         # default: {n-2, n, n+2} clamped to [4, 14]
    "orders": ORDER_CHOICES,
    "skip_filter_below": 32,  # candidates below this -> straight to refine
    "fuse_above": 1024,       # candidates above this -> pipeline_mode fused
    "c_refine": 1.0,          # work units per refinement vertex product
    "c_build": 2.0,           # work units per interval constructed
    "c_decode": 0.25,         # work units per interval decoded (APRIL-C)
    "amortize_build": 1.0,    # divide build cost (store reuse across joins)
    "probe_budget": 0.15,     # cap plan_work at this fraction of the join
}

#: APRIL-C construction overhead over plain APRIL (delta+varint encode).
_COMPRESS_BUILD_FACTOR = 1.25


def check_plan_mode(mode: str) -> None:
    if mode not in PLAN_MODES:
        raise ValueError(f"unknown plan_mode {mode!r}; "
                         f"expected one of {PLAN_MODES}")


@dataclass
class PlanChoice:
    """One executable configuration: what the planner picked (or one point
    of the static sweep). JSON-safe via :meth:`to_dict`/:meth:`from_dict`
    so it rides inside ``JoinStats.extra`` and the service envelope."""

    method: str = "april"
    n_order: int = 10
    order: tuple = ORDER_CHOICES[0]
    pipeline_mode: str = "staged"
    skip_filter: bool = False
    predicate: str = "intersects"
    #: planner evidence: sample size/seed, per-config cost table, rates,
    #: the chosen total, and the planning work itself (``plan_work``).
    est: dict = field(default_factory=dict)

    def key(self) -> str:
        """Stable id of the config point (the cost-table key)."""
        if self.method == "none":
            return "none"
        return f"{self.method}/n{self.n_order}/{'-'.join(self.order)}"

    def to_dict(self) -> dict:
        return {"method": self.method, "n_order": int(self.n_order),
                "order": list(self.order),
                "pipeline_mode": self.pipeline_mode,
                "skip_filter": bool(self.skip_filter),
                "predicate": self.predicate, "est": dict(self.est)}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanChoice":
        return cls(method=d["method"], n_order=int(d["n_order"]),
                   order=tuple(d["order"]),
                   pipeline_mode=d.get("pipeline_mode", "staged"),
                   skip_filter=bool(d.get("skip_filter", False)),
                   predicate=d.get("predicate", "intersects"),
                   est=dict(d.get("est", {})))


class ProfileCache:
    """Shares planner choices between partitions of similar candidate
    density (the §13 follow-on used by the launcher's adaptive path and
    the §14 tiled driver).

    Per-partition adaptive planning re-samples and re-probes every
    partition, but partitions with the same workload *shape* — similar
    candidate volume and candidate density (candidates per MBR
    cross-pair) — land on the same :class:`PlanChoice` anyway. The cache
    keys a partition by ``predicate`` plus the **quantized log2** of its
    candidate count and density (``density_tol_log2`` buckets, default one
    octave): the first partition in a bucket pays for
    :func:`choose_plan`, the rest adopt its choice via
    ``JoinPlan._apply_choice`` without building probe stores.

    Reused choices are heuristic, not argmin-exact, for the adopting
    partition — verdicts are unaffected (plans change execution, never
    results; the exact refinement stage decides every pair). Single-thread
    use only (the launcher and tiled driver plan sequentially); the
    service's replan cache remains separate.
    """

    def __init__(self, density_tol_log2: float = 1.0):
        self.density_tol_log2 = float(density_tol_log2)
        self._cache: dict[tuple, PlanChoice] = {}
        self.stats = {"hits": 0, "misses": 0}

    def key(self, predicate: str, n_r: int, n_s: int,
            n_cand: int) -> tuple:
        """Quantized workload-shape bucket of one partition."""
        tol = max(self.density_tol_log2, 1e-9)
        size = round(np.log2(n_cand + 1.0) / tol)
        dens = n_cand / max(1.0, float(n_r) * float(n_s))
        return (predicate, size, round(np.log2(dens + 1e-12) / tol))

    def get(self, key: tuple) -> PlanChoice | None:
        choice = self._cache.get(key)
        self.stats["hits" if choice is not None else "misses"] += 1
        return choice

    def put(self, key: tuple, choice: PlanChoice) -> None:
        self._cache[key] = choice

    def __len__(self) -> int:
        return len(self._cache)


# ---------------------------------------------------------------------------
# Work counters (machine-independent; shared by planner, bench, and tests)
# ---------------------------------------------------------------------------

def _count_join(X, Y) -> tuple[int, bool]:
    """(comparisons, overlap?) of the early-exit two-pointer merge join —
    the counting twin of :func:`repro.core.join.interval_join_pair`."""
    i = j = n = 0
    nx, ny = len(X), len(Y)
    while i < nx and j < ny:
        n += 1
        if X[i][0] < Y[j][1] and Y[j][0] < X[i][1]:
            return n, True
        if X[i][1] <= Y[j][1]:
            i += 1
        else:
            j += 1
    return n, False


def _count_containment(X, F) -> tuple[int, bool]:
    """Counting twin of :func:`repro.core.join.containment_join_pair`."""
    j = n = 0
    nf = len(F)
    ok = bool(len(X))
    for xs, xe in X:
        while j < nf and F[j][1] < xe:
            n += 1
            j += 1
        n += 1
        if j >= nf or not (F[j][0] <= xs and xe <= F[j][1]):
            ok = False
            break
    return n, ok


def _cells_as_intervals(ids: np.ndarray) -> np.ndarray:
    ids = np.asarray(ids, np.uint64)
    if not len(ids):
        return np.zeros((0, 2), np.uint64)
    return np.stack([ids, ids + np.uint64(1)], axis=1)


def _store_ints(store) -> int:
    """Interval (or partial-cell) count a store holds — the build-work and
    decode-work base quantity."""
    if hasattr(store, "a_ints"):
        return len(store.a_ints) + len(store.f_ints)
    return len(store.ids)        # LineCellStore


def _lists(store, i: int, kind: str):
    """(A, F) interval lists of object ``i``; line stores expose their
    partial cells as unit intervals in the A slot (no Full list)."""
    if kind == "line":
        cells = _cells_as_intervals(store.ids[store.off[i]:store.off[i + 1]])
        return cells, cells[:0]
    return store.a_list(i), store.f_list(i)


def _pair_record(Ar, Fr, As_, Fs, refine_unit: float,
                 predicate: str) -> dict:
    """Profile one pair: per-join comparison counts, hit flags, verdict,
    and list lengths — everything any join order's work simulation needs."""
    rec = {"refine": refine_unit,
           "lens": (len(Ar), len(Fr), len(As_), len(Fs))}
    if predicate == "linestring":
        # R is the line side: its cells sit in Ar; polygon lists are As/Fs.
        rec["aa"], aa_hit = _count_join(As_, Ar)
        rec["af"], af_hit = (_count_join(Fs, Ar) if aa_hit else (0, False))
        rec["aa_hit"], rec["af_hit"] = aa_hit, af_hit
        rec["verdict"] = (TRUE_NEG if not aa_hit
                          else TRUE_HIT if af_hit else INDECISIVE)
        return rec
    if predicate == "within":
        rec["aa"], aa_hit = _count_join(Ar, As_)
        rec["cont"], cont = (_count_containment(Ar, Fs) if aa_hit
                             else (0, False))
        rec["aa_hit"] = aa_hit
        rec["verdict"] = (TRUE_NEG if not aa_hit
                          else TRUE_HIT if cont else INDECISIVE)
        return rec
    rec["aa"], rec["aa_hit"] = _count_join(Ar, As_)
    rec["af"], rec["af_hit"] = _count_join(Ar, Fs)
    rec["fa"], rec["fa_hit"] = _count_join(Fr, As_)
    if not rec["aa_hit"]:
        rec["verdict"] = TRUE_NEG
    elif rec["af_hit"] or rec["fa_hit"]:
        rec["verdict"] = TRUE_HIT
    else:
        rec["verdict"] = INDECISIVE
    return rec


def _order_work(rec: dict, order: tuple, predicate: str) -> int:
    """Early-exit comparisons one pair costs under ``order`` — the
    simulation twin of :func:`repro.core.join.april_verdict_pair`."""
    if predicate == "within":
        return rec["aa"] + rec.get("cont", 0)
    if predicate == "linestring":
        return rec["aa"] + (rec["af"] if rec["aa_hit"] else 0)
    w = 0
    for step in order:
        k = step.lower()
        w += rec[k]
        if step == "AA" and not rec["aa_hit"]:
            break
        if step != "AA" and rec[k + "_hit"]:
            break
    return w


def _record_work(rec: dict, predicate: str) -> int:
    """Comparisons spent *profiling* the pair (all joins computed)."""
    if predicate == "within":
        return rec["aa"] + rec.get("cont", 0)
    if predicate == "linestring":
        return rec["aa"] + rec["af"]
    return rec["aa"] + rec["af"] + rec["fa"]


# ---------------------------------------------------------------------------
# Sample profiling
# ---------------------------------------------------------------------------

def _subset(ds_, idx: np.ndarray):
    """Sub-dataset of the unique sampled objects (probe-store input)."""
    from ..datagen.synthetic import PolygonDataset
    return PolygonDataset(name=f"{ds_.name}#probe", verts=ds_.verts[idx],
                          nverts=ds_.nverts[idx])


def _profile(R, S, sample: np.ndarray, n: int, predicate: str,
             extent: Extent, r_kind: str) -> dict:
    """Build probe APRIL stores over the unique sampled objects at
    granularity ``n`` and record per-pair join work."""
    from .filters import get_filter
    ur = np.unique(sample[:, 0])
    us = np.unique(sample[:, 1])
    filt = get_filter("april")
    ax_r = filt.build(_subset(R, ur), n_order=n, extent=extent, kind=r_kind)
    ax_s = filt.build(_subset(S, us), n_order=n, extent=extent,
                      kind="polygon")
    loc_r = {int(g): k for k, g in enumerate(ur)}
    loc_s = {int(g): k for k, g in enumerate(us)}
    recs = []
    for gi, gj in sample:
        Ar, Fr = _lists(ax_r.store, loc_r[int(gi)], r_kind)
        As_, Fs = _lists(ax_s.store, loc_s[int(gj)], "polygon")
        recs.append(_pair_record(
            Ar, Fr, As_, Fs,
            float(R.nverts[gi]) * float(S.nverts[gj]), predicate))
    ints_r = _store_ints(ax_r.store)
    ints_s = _store_ints(ax_s.store)
    return {
        "recs": recs,
        "mean_ints_r": ints_r / max(1, len(ur)),
        "mean_ints_s": ints_s / max(1, len(us)),
        "probe_work": (sum(_record_work(r, predicate) for r in recs)
                       + ints_r + ints_s),
    }


# ---------------------------------------------------------------------------
# Cost model + chooser
# ---------------------------------------------------------------------------

def static_configs(predicate: str, methods: tuple, n_orders: list,
                   orders: tuple, n_order_req: int) -> list:
    """The static configuration space the planner prices (and the sweep
    space of ``benchmarks/adaptive_order.py``). Join orders only vary for
    the three-join predicates; within/linestring have a fixed order."""
    cfgs = []
    if "none" in methods:
        cfgs.append(PlanChoice(method="none", n_order=n_order_req,
                               order=ORDER_CHOICES[0], skip_filter=True,
                               predicate=predicate))
    sweep = orders if predicate in ("intersects", "selection") \
        else (ORDER_CHOICES[0],)
    for meth in methods:
        if meth == "none":
            continue
        for n in n_orders:
            for order in sweep:
                cfgs.append(PlanChoice(method=meth, n_order=int(n),
                                       order=tuple(order),
                                       predicate=predicate))
    return cfgs


def _config_cost(cfg: PlanChoice, profiles: dict, n_cand: int,
                 len_r: int, len_s: int, mean_refine_all: float,
                 o: dict) -> dict:
    if cfg.method == "none":
        refine = o["c_refine"] * n_cand * mean_refine_all
        return {"build": 0.0, "filter": 0.0, "decode": 0.0,
                "refine": refine, "total": refine}
    prof = profiles[cfg.n_order]
    recs = prof["recs"]
    m = max(1, len(recs))
    filter_w = n_cand * sum(
        _order_work(r, cfg.order, cfg.predicate) for r in recs) / m
    refine_w = o["c_refine"] * n_cand * sum(
        r["refine"] for r in recs if r["verdict"] == INDECISIVE) / m
    build_w = o["c_build"] * (prof["mean_ints_r"] * len_r
                              + prof["mean_ints_s"] * len_s)
    build_w /= max(1e-9, o["amortize_build"])
    decode_w = 0.0
    if cfg.method == "april-c":
        build_w *= _COMPRESS_BUILD_FACTOR
        mean_a = sum(r["lens"][0] + r["lens"][2] for r in recs) / m
        mean_f = sum(r["lens"][1] + r["lens"][3] for r in recs) / m
        aa_rate = sum(1 for r in recs if r["aa_hit"]) / m
        decode_w = o["c_decode"] * n_cand * (mean_a + aa_rate * mean_f)
    total = build_w + filter_w + refine_w + decode_w
    return {"build": build_w, "filter": filter_w, "refine": refine_w,
            "decode": decode_w, "total": total}


def _rates(recs: list) -> dict:
    m = max(1, len(recs))
    return {"hit": sum(1 for r in recs if r["verdict"] == TRUE_HIT) / m,
            "neg": sum(1 for r in recs if r["verdict"] == TRUE_NEG) / m,
            "indec": sum(
                1 for r in recs if r["verdict"] == INDECISIVE) / m}


def choose_plan(R, S, pairs: np.ndarray, *, predicate: str = "intersects",
                n_order: int = 10, extent: Extent = GLOBAL_EXTENT,
                r_kind: str = "polygon", **opts) -> PlanChoice:
    """Pick the cheapest configuration for this workload (module docstring
    has the cost model). Deterministic: seeded sampling, stable-key
    tiebreak on equal costs."""
    unknown = set(opts) - set(PLAN_DEFAULTS)
    if unknown:
        raise TypeError(f"unknown plan option(s) {sorted(unknown)}; "
                        f"expected a subset of {sorted(PLAN_DEFAULTS)}")
    o = dict(PLAN_DEFAULTS)
    o.update(opts)
    methods = tuple(o["methods"])
    bad = set(methods) - set(PLANNER_METHODS)
    if bad:
        raise ValueError(f"planner cannot cost method(s) {sorted(bad)}; "
                         f"supported: {PLANNER_METHODS}")
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    n_cand = len(pairs)

    if n_cand < o["skip_filter_below"]:
        # Too few candidates to amortize ANY preprocessing: straight to
        # refinement, no probe builds, no sampling.
        return PlanChoice(
            method="none", n_order=n_order, order=ORDER_CHOICES[0],
            pipeline_mode="staged", skip_filter=True, predicate=predicate,
            est={"n_candidates": n_cand, "sample_size": 0,
                 "seed": o["seed"], "skip_rule": True, "costs": {},
                 "total": 0.0, "plan_work": 0.0})

    rng = np.random.default_rng(o["seed"])
    # probe at most 1/16th of the candidates (floor 8): on small workloads
    # a full-size sample would cost a sizeable fraction of the join itself
    m = min(int(o["sample_size"]), max(8, n_cand // 16), n_cand)
    sample = pairs[np.sort(rng.choice(n_cand, size=m, replace=False))]

    n_orders = o["n_orders"]
    if n_orders is None:
        n_orders = sorted({max(4, n_order - 2), n_order,
                           min(14, n_order + 2)})
    n_orders = [int(n) for n in n_orders]

    profiles: dict = {}
    plan_work = 0.0

    def _est_ref() -> float:
        # cheapest full-join estimate over the granularities probed so
        # far — the yardstick the probe budget is measured against
        mra = (sum(r["refine"] for r in profiles[probe_seq[0]]["recs"])
               / max(1, m))
        best = None
        for cfg in static_configs(predicate, methods, sorted(profiles),
                                  o["orders"], n_order):
            c = _config_cost(cfg, profiles, n_cand, len(R), len(S), mra, o)
            best = c["total"] if best is None else min(best, c["total"])
        return best if best is not None else 0.0

    # The requested granularity is always probed; alternates (cheapest
    # first) only while planning stays within probe_budget of the
    # predicted join cost. A finer/coarser probe's cost is predicted at
    # x4 per +2 orders — the F-interval area scaling.
    probe_seq = ([n_order] if n_order in n_orders else []) \
        + sorted(n for n in n_orders if n != n_order)
    for n in probe_seq:
        if profiles:
            base = min(profiles, key=lambda p: abs(p - n))
            predicted = profiles[base]["probe_work"] * 4.0 ** ((n - base) / 2)
            if plan_work + predicted > o["probe_budget"] * _est_ref():
                continue
        profiles[n] = _profile(R, S, sample, n, predicate, extent, r_kind)
        plan_work += profiles[n]["probe_work"]

    n_orders = sorted(profiles)
    any_recs = profiles[n_orders[0]]["recs"]
    mean_refine_all = sum(r["refine"] for r in any_recs) / max(1, m)

    costs = {}
    parts = {}
    for cfg in static_configs(predicate, methods, n_orders, o["orders"],
                              n_order):
        c = _config_cost(cfg, profiles, n_cand, len(R), len(S),
                         mean_refine_all, o)
        costs[cfg.key()] = c["total"]
        parts[cfg.key()] = (cfg, c)
    best_key = min(costs, key=lambda k: (costs[k], k))
    best, best_cost = parts[best_key]

    pipeline_mode = ("fused" if best.method != "none"
                     and n_cand >= o["fuse_above"] else "staged")
    est = {
        "n_candidates": n_cand, "sample_size": m, "seed": o["seed"],
        "n_orders": list(n_orders),
        "rates": _rates(profiles[best.n_order]["recs"])
        if best.method != "none" else _rates(any_recs),
        "costs": {k: round(v, 3) for k, v in costs.items()},
        "best_static": best_key, "total": best_cost["total"],
        "components": {k: round(v, 3) for k, v in best_cost.items()},
        "plan_work": plan_work,
    }
    return PlanChoice(method=best.method, n_order=best.n_order,
                      order=tuple(best.order), pipeline_mode=pipeline_mode,
                      skip_filter=best.method == "none",
                      predicate=predicate, est=est)


# ---------------------------------------------------------------------------
# Ground truth for the bench: work a config ACTUALLY performs
# ---------------------------------------------------------------------------

def measured_work(R, S, pairs: np.ndarray, cfg: PlanChoice, *,
                  extent: Extent = GLOBAL_EXTENT, r_kind: str = "polygon",
                  store_bank: dict | None = None, **opts) -> dict:
    """Deterministic work units a static config spends on the FULL
    candidate set: early-exit interval comparisons, build work per
    interval constructed, refinement work per vertex product, and — for
    APRIL-C — the exact unique-object decode quantity (A-intervals of the
    batch plus F-intervals of the AA survivors). Shares the cost-model
    constants with :func:`choose_plan` so estimated and measured totals
    are commensurable; ``store_bank`` caches full builds across configs
    keyed by ``(r_kind, n_order)``."""
    from .filters import get_filter
    o = dict(PLAN_DEFAULTS)
    o.update(opts)
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    predicate = cfg.predicate
    if cfg.method == "none" or cfg.skip_filter:
        refine = o["c_refine"] * float(np.sum(
            R.nverts[pairs[:, 0]].astype(np.float64)
            * S.nverts[pairs[:, 1]]))
        return {"build": 0.0, "filter": 0.0, "decode": 0.0,
                "refine": refine, "total": refine}

    key = (r_kind, cfg.n_order)
    if store_bank is not None and key in store_bank:
        ax_r, ax_s = store_bank[key]
    else:
        filt = get_filter("april")
        ax_r = filt.build(R, n_order=cfg.n_order, extent=extent,
                          kind=r_kind)
        ax_s = filt.build(S, n_order=cfg.n_order, extent=extent,
                          kind="polygon")
        if store_bank is not None:
            store_bank[key] = (ax_r, ax_s)

    build_w = o["c_build"] * (_store_ints(ax_r.store)
                              + _store_ints(ax_s.store))
    build_w /= max(1e-9, o["amortize_build"])
    if cfg.method == "april-c":
        build_w *= _COMPRESS_BUILD_FACTOR

    filter_w = 0
    refine_w = 0.0
    aa_survivors: set[tuple[str, int]] = set()
    for gi, gj in pairs:
        Ar, Fr = _lists(ax_r.store, int(gi), r_kind)
        As_, Fs = _lists(ax_s.store, int(gj), "polygon")
        rec = _pair_record(Ar, Fr, As_, Fs,
                           float(R.nverts[gi]) * float(S.nverts[gj]),
                           predicate)
        filter_w += _order_work(rec, cfg.order, predicate)
        if rec["verdict"] == INDECISIVE:
            refine_w += o["c_refine"] * rec["refine"]
        if rec["aa_hit"]:
            aa_survivors.add(("r", int(gi)))
            aa_survivors.add(("s", int(gj)))

    decode_w = 0.0
    if cfg.method == "april-c":
        stores = {"r": (ax_r.store, r_kind), "s": (ax_s.store, "polygon")}
        for side, uniq in (("r", np.unique(pairs[:, 0])),
                           ("s", np.unique(pairs[:, 1]))):
            store, kind = stores[side]
            for g in uniq:
                A, F = _lists(store, int(g), kind)
                decode_w += len(A)
                if (side, int(g)) in aa_survivors:
                    decode_w += len(F)
        decode_w *= o["c_decode"]

    total = build_w + filter_w + refine_w + decode_w
    return {"build": build_w, "filter": float(filter_w),
            "decode": decode_w, "refine": refine_w, "total": total}
