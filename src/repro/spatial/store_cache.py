"""LRU cache of warm approximation stores for the join service
(DESIGN.md §10).

The paper's contract is *build once, query forever*: approximations are
preprocessing artifacts amortized across many joins. :class:`StoreCache`
holds built :class:`~repro.spatial.filters.base.Approximation`\\ s — with
their device-resident ``IntervalLists`` caches riding along in ``meta`` —
keyed by ``(dataset_id, filter_method, n_order)`` under a byte budget.
Least-recently-used stores are evicted when the budget is exceeded;
:attr:`stats` tracks hits / misses / evictions / resident bytes so the
service can report cache efficiency per traffic trace.

The cache is internally thread-safe: the service's micro-batch worker and
mutating caller threads hit it concurrently, so every method holds
``self._lock`` (reentrant — ``put``/``pop`` call ``_drop`` under it).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from .filters import Approximation

__all__ = ["StoreCache"]

#: default byte budget: plenty for the synthetic datasets, small enough
#: that a launcher flag can force eviction traffic in benchmarks
DEFAULT_BUDGET = 256 << 20


class StoreCache:
    """Byte-budgeted LRU of built approximation stores.

    Keys are ``(dataset_id, filter_method, n_order)`` tuples; values are
    :class:`Approximation`. ``get`` refreshes recency; ``put`` evicts from
    the LRU end until the new entry fits. A single store larger than the
    whole budget is still admitted (the service must be able to run) but
    evicts everything else.
    """

    def __init__(self, budget_bytes: int = DEFAULT_BUDGET):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self._entries: OrderedDict[tuple, Approximation] = OrderedDict()
        self._bytes: dict[tuple, int] = {}
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "resident_bytes": 0, "puts": 0}
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    def get(self, key: tuple) -> Approximation | None:
        with self._lock:
            approx = self._entries.get(key)
            if approx is None:
                self.stats["misses"] += 1
                return None
            self._entries.move_to_end(key)
            self.stats["hits"] += 1
            return approx

    def put(self, key: tuple, approx: Approximation) -> None:
        with self._lock:
            if key in self._entries:
                self._drop(key)
            size = approx.size_bytes()
            while self._entries and \
                    self.stats["resident_bytes"] + size > self.budget_bytes:
                old_key, _ = self._entries.popitem(last=False)
                self.stats["resident_bytes"] -= self._bytes.pop(old_key)
                self.stats["evictions"] += 1
            self._entries[key] = approx
            self._bytes[key] = size
            self.stats["resident_bytes"] += size
            self.stats["puts"] += 1

    def resize(self, key: tuple) -> None:
        """Re-measure one entry after an in-place store patch."""
        with self._lock:
            if key in self._entries:
                size = self._entries[key].size_bytes()
                self.stats["resident_bytes"] += size - self._bytes[key]
                self._bytes[key] = size

    def pop(self, key: tuple) -> Approximation | None:
        with self._lock:
            approx = self._entries.get(key)
            if approx is not None:
                self._drop(key)
            return approx

    def _drop(self, key: tuple) -> None:
        with self._lock:
            del self._entries[key]
            self.stats["resident_bytes"] -= self._bytes.pop(key)

    def items(self):
        """(key, approx) pairs, least-recently-used first."""
        with self._lock:
            return list(self._entries.items())

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes.clear()
            self.stats["resident_bytes"] = 0
