"""`JoinPlan`: the session API of the spatial-join pipeline (DESIGN.md §2).

Separates *preprocessing* from *execution*:

    plan = JoinPlan(R, S, filter="ri", filter_backend="numpy", n_order=9)
    plan.build()                               # approximations, reusable
    hits, stats = plan.execute("intersects")   # batched filter + refinement
    within, st2 = plan.execute("within")       # same approximations, free

Every execution runs the paper's stages dataset-batched end to end — MBR
candidate generation (one partitioned grid-hash join, §8) -> intermediate
filter (one batched ``verdicts`` call, §3) -> refinement of the indecisive
remainder (one bucketed exact-geometry pass, §7) — and returns
:class:`JoinStats` with per-stage wall times, the shape of the paper's
Tables 5/13/16/17 and Fig. 13. Each stage's execution path is a backend
knob (``mbr_backend`` / ``filter_backend`` / ``refine_backend``, plus
``build_opts["build_backend"]`` for construction, §6); backends change
execution, never results.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field, fields

import numpy as np

from ..core.join import (INDECISIVE, TRUE_HIT, TRUE_NEG,
                         check_filter_backend)
from ..core.rasterize import Extent, GLOBAL_EXTENT
from . import refine
from .filters import Approximation, IntermediateFilter, get_filter
from .fused import PIPELINE_MODES, check_pipeline_mode, execute_fused
from .mbr_join import _check_backend as _check_mbr_backend
from .mbr_join import mbr_join
from .planner import PLAN_MODES, PlanChoice, check_plan_mode, choose_plan

__all__ = ["JoinStats", "JoinPlan", "PIPELINE_MODES", "PLAN_MODES"]


@dataclass
class JoinStats:
    method: str
    predicate: str = "intersects"
    backend: str = "numpy"             # historical alias of filter_backend
    filter_backend: str = "numpy"
    refine_backend: str = "numpy"
    mbr_backend: str = "numpy"
    n_candidates: int = 0
    n_true_hits: int = 0
    n_true_negs: int = 0
    n_indecisive: int = 0
    n_results: int = 0
    pipeline_mode: str = "staged"
    #: how the executed configuration was chosen (DESIGN.md §13):
    #: ``static`` = constructor knobs verbatim, ``adaptive`` = planner pick
    #: (the chosen :class:`~repro.spatial.planner.PlanChoice` rides in
    #: ``extra["plan"]``)
    plan_mode: str = "static"
    #: §14 tiled scale-out only: number of memory-budgeted tiles the run
    #: was packed into (0 = in-memory join, no tiling)
    tiles: int = 0
    t_mbr: float = 0.0
    t_filter: float = 0.0
    t_refine: float = 0.0
    #: fused mode only: the end-of-chain gather + f64 escalation (staged
    #: stage times include their own syncs, so this stays 0.0 there)
    t_sync: float = 0.0
    t_build: float = 0.0
    #: §14 tiled scale-out only: wall time of the streaming partitioner
    #: (spill + statistics + skew split + tile packing)
    t_partition: float = 0.0
    approx_bytes: int = 0
    extra: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        return self.t_mbr + self.t_filter + self.t_refine + self.t_sync

    def stage_times(self) -> dict:
        """Per-stage device-time breakdown (the serving latency report):
        JSON-safe, round-trips through to_dict/from_dict."""
        return {"t_mbr": float(self.t_mbr), "t_filter": float(self.t_filter),
                "t_refine": float(self.t_refine),
                "t_sync": float(self.t_sync),
                "t_partition": float(self.t_partition),
                "t_total": float(self.t_total)}

    def rates(self) -> tuple[float, float, float]:
        n = max(1, self.n_candidates)
        return (self.n_true_hits / n, self.n_true_negs / n,
                self.n_indecisive / n)

    def row(self) -> str:
        h, g, i = self.rates()
        sync = (f"sync={self.t_sync:.3f}s "
                if self.pipeline_mode == "fused" else "")
        if self.tiles:
            sync += f"tiles={self.tiles} part={self.t_partition:.3f}s "
        return (f"{self.method:8s} hits={h:6.2%} negs={g:6.2%} indec={i:6.2%} "
                f"mbr={self.t_mbr:.3f}s[{self.mbr_backend}] "
                f"filter={self.t_filter:.3f}s[{self.filter_backend}] "
                f"refine={self.t_refine:.3f}s[{self.refine_backend}] "
                f"{sync}total={self.t_total:.3f}s results={self.n_results}")

    def to_dict(self) -> dict:
        """JSON-safe dict of every field (the service response envelope);
        ``t_build`` rides along — warm-vs-cold build time is the headline
        serving metric. Round-trips through :meth:`from_dict`."""
        out = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, (np.integer, np.floating)):
                v = v.item()
            out[f.name] = dict(v) if f.name == "extra" else v
        out["t_total"] = self.t_total
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "JoinStats":
        names = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def _apply_verdicts(stats: JoinStats, verdicts: np.ndarray) -> None:
    stats.n_true_hits = int(np.sum(verdicts == TRUE_HIT))
    stats.n_true_negs = int(np.sum(verdicts == TRUE_NEG))
    stats.n_indecisive = int(np.sum(verdicts == INDECISIVE))


class JoinPlan:
    """A reusable two-dataset join session over one intermediate filter.

    ``filter`` is a registry name (``none/april/april-c/ri/ra/5cch``) or an
    :class:`IntermediateFilter` instance; ``filter_backend`` selects the
    verdict execution path of the intermediate-filter stage (``numpy`` |
    ``jnp`` | ``pallas`` | ``sequential``, DESIGN.md §9 — ``sequential``
    is the faithful per-pair reference every batched backend is
    verdict-identical to; ``backend`` is its historical alias, deprecated —
    passing it emits a ``DeprecationWarning``).
    ``r_kind``/``s_kind``
    mark a side as 'line' (open chains) for the linestring predicate.
    ``refine_backend`` selects the execution path of the final exact-geometry
    stage (``numpy`` | ``jnp`` | ``pallas`` | ``sequential``, DESIGN.md §7) —
    every backend is verdict-identical to the sequential per-pair reference.
    ``mbr_backend`` selects the execution path of candidate generation
    (``numpy`` | ``jnp`` | ``sequential``, DESIGN.md §8); ``mbr_grid`` pins
    the bucket granularity (default: adaptive from MBR-extent statistics) —
    neither changes the candidate pair set. ``build_opts`` go to
    ``filter.build`` (e.g. ``build_backend``, ``max_cells`` for RA,
    ``method`` for APRIL construction); ``filter_opts`` go to every
    ``filter.verdicts`` call (e.g. ``order`` for APRIL).
    ``pipeline_mode`` selects where stage boundaries live (DESIGN.md §12):
    ``staged`` (default) materializes each stage's survivors on host;
    ``fused`` chains the stages device-resident with one end-of-chain sync
    — result pairs and their order are identical either way.
    ``plan_mode`` selects who picks the configuration (DESIGN.md §13):
    ``static`` (default) executes the knobs above verbatim; ``adaptive``
    runs the sample-based cost planner on the first :meth:`execute` (or an
    explicit :meth:`plan` call) and adopts its choice of filter method,
    ``n_order``, join order, and pipeline mode. ``plan_opts`` tune the
    planner (see :data:`~repro.spatial.planner.PLAN_DEFAULTS`);
    ``plan_choice`` injects a pre-computed choice (per-shard plans, the
    service's replan cache) instead of re-sampling.
    """

    def __init__(self, R, S, *, filter: str | IntermediateFilter = "april",
                 filter_backend: str | None = None,
                 backend: str | None = None, refine_backend: str = "numpy",
                 mbr_backend: str = "numpy", n_order: int = 10,
                 extent: Extent = GLOBAL_EXTENT, r_kind: str = "polygon",
                 s_kind: str = "polygon", mbr_grid: int | None = None,
                 mbr_index: "MBRIndex | None" = None,
                 pipeline_mode: str = "staged",
                 plan_mode: str = "static",
                 plan_opts: dict | None = None,
                 plan_choice: PlanChoice | None = None,
                 build_opts: dict | None = None,
                 filter_opts: dict | None = None):
        if (filter_backend is not None and backend is not None
                and filter_backend != backend):
            raise ValueError("pass filter_backend or its alias backend, "
                             f"not both ({filter_backend!r} vs {backend!r})")
        if backend is not None:
            warnings.warn(
                "JoinPlan(backend=...) is a deprecated alias; "
                "pass filter_backend=... instead (alias removed after "
                "2026-12-01)",
                DeprecationWarning, stacklevel=2)
        filter_backend = filter_backend or backend or "numpy"
        check_filter_backend(filter_backend)
        refine._check_backend(refine_backend)
        _check_mbr_backend(mbr_backend)
        check_pipeline_mode(pipeline_mode)
        check_plan_mode(plan_mode)
        if plan_choice is not None and plan_mode != "adaptive":
            raise ValueError("plan_choice requires plan_mode='adaptive' "
                             f"(got plan_mode={plan_mode!r})")
        self.R = R
        self.S = S
        self.filter = get_filter(filter)
        self.filter_backend = filter_backend
        self.backend = filter_backend      # historical alias
        self.refine_backend = refine_backend
        self.mbr_backend = mbr_backend
        self.n_order = n_order
        self.extent = extent
        self.r_kind = r_kind
        self.s_kind = s_kind
        self.mbr_grid = mbr_grid
        self.mbr_index = mbr_index
        self.pipeline_mode = pipeline_mode
        self.plan_mode = plan_mode
        self.plan_opts = dict(plan_opts or {})
        self.plan_choice: PlanChoice | None = None
        self.build_opts = dict(build_opts or {})
        self.filter_opts = dict(filter_opts or {})
        self.approx_r: Approximation | None = None
        self.approx_s: Approximation | None = None
        self._t_build = 0.0
        self._t_plan = 0.0
        self.last_stats: JoinStats | None = None
        if plan_choice is not None:
            self._apply_choice(plan_choice)

    # -- preprocessing ------------------------------------------------------

    def _wrap(self, store, kind: str) -> Approximation:
        if isinstance(store, Approximation):
            return store
        return Approximation(filter=self.filter.name, store=store,
                             n_order=self.n_order, extent=self.extent,
                             kind=kind)

    def build(self, prebuilt: tuple | None = None) -> "JoinPlan":
        """Build (or adopt) both approximations; idempotent.

        ``prebuilt`` may supply an (approx_r, approx_s) tuple — raw stores
        are wrapped — with ``None`` entries meaning "build this side".
        """
        pre_r = pre_s = None
        if prebuilt is not None:
            pre_r, pre_s = prebuilt
        t0 = time.perf_counter()
        if self.approx_r is None:
            self.approx_r = (self._wrap(pre_r, self.r_kind)
                             if pre_r is not None else
                             self.filter.build(
                                 self.R, n_order=self.n_order,
                                 extent=self.extent, kind=self.r_kind,
                                 side="r", **self.build_opts))
        if self.approx_s is None:
            self.approx_s = (self._wrap(pre_s, self.s_kind)
                             if pre_s is not None else
                             self.filter.build(
                                 self.S, n_order=self.n_order,
                                 extent=self.extent, kind=self.s_kind,
                                 side="s", **self.build_opts))
        self._t_build += time.perf_counter() - t0
        return self

    # -- adaptive planning (DESIGN.md §13) ----------------------------------

    def _apply_choice(self, choice: PlanChoice) -> None:
        """Adopt a planner choice: swap filter/granularity/order/pipeline.
        Built approximations are invalidated when the store shape changes
        (a prebuilt store for the chosen config can still be adopted via
        :meth:`build`'s ``prebuilt``)."""
        if (choice.method != self.filter.name
                or int(choice.n_order) != self.n_order):
            self.approx_r = self.approx_s = None
        self.filter = get_filter(choice.method)
        self.n_order = int(choice.n_order)
        self.pipeline_mode = choice.pipeline_mode
        if (choice.method in ("april", "april-c")
                and choice.predicate in ("intersects", "selection")):
            self.filter_opts["order"] = tuple(choice.order)
        else:
            self.filter_opts.pop("order", None)
        self.plan_choice = choice

    def plan(self, predicate: str = "intersects",
             pairs: np.ndarray | None = None) -> PlanChoice:
        """Run the sample-based planner for ``predicate`` and apply its
        choice (``plan_mode='adaptive'`` only). Called lazily by the first
        :meth:`execute`; call explicitly to re-plan (e.g. after the
        workload drifts). ``pairs`` may supply the candidate set when the
        caller already generated it (the launcher's
        :class:`~repro.spatial.planner.ProfileCache` path keys on the
        candidate count before deciding whether to plan at all) — it must
        equal :meth:`candidates` (``predicate``) output. Deterministic for
        fixed inputs and ``plan_opts['seed']``."""
        if self.plan_mode != "adaptive":
            raise ValueError("plan() requires JoinPlan(plan_mode="
                             f"'adaptive'), got {self.plan_mode!r}")
        t0 = time.perf_counter()
        if pairs is None:
            pairs = self.candidates(predicate)
        choice = choose_plan(self.R, self.S, pairs, predicate=predicate,
                             n_order=self.n_order, extent=self.extent,
                             r_kind=self.r_kind, **self.plan_opts)
        self._t_plan = time.perf_counter() - t0
        self._apply_choice(choice)
        return choice

    # -- candidate generation (the MBR filter, per predicate) ---------------

    def candidates(self, predicate: str = "intersects") -> np.ndarray:
        """Candidate pairs through the §8 grid-hash join (``mbr_backend``).

        No predicate materializes the dense [N, M] cross test: ``within``
        needs MBR *containment*, but containment implies intersection, so
        the (stricter) containment test runs on just the hash join's
        candidate rows.

        A warm :class:`~repro.spatial.mbr_join.MBRIndex` over R
        (``mbr_index``) replaces the per-call expansion + sort of the R
        side with a probe against its prebuilt bucket table — the pair set
        is identical either way (grid/extent invariance).
        """
        R, S = self.R, self.S
        if self.mbr_index is not None:
            pairs = self.mbr_index.probe(S.mbrs, backend=self.mbr_backend)
        else:
            pairs = mbr_join(R.mbrs, S.mbrs, grid=self.mbr_grid,
                             backend=self.mbr_backend)
        if predicate == "within":
            mr = R.mbrs[pairs[:, 0]]
            ms = S.mbrs[pairs[:, 1]]
            inside = ((mr[:, 0] >= ms[:, 0]) & (mr[:, 1] >= ms[:, 1])
                      & (mr[:, 2] <= ms[:, 2]) & (mr[:, 3] <= ms[:, 3]))
            return pairs[inside]
        return pairs

    # -- execution ----------------------------------------------------------

    def _refine(self, predicate: str, pairs: np.ndarray) -> np.ndarray:
        if len(pairs) == 0:
            return np.zeros(0, bool)
        return refine.refine(self.R, self.S, pairs, predicate=predicate,
                             backend=self.refine_backend)

    def execute(self, predicate: str = "intersects",
                ) -> tuple[np.ndarray, JoinStats]:
        """Run MBR -> filter -> refine; returns (result pairs [K,2], stats).

        For ``selection``, result rows are (data index, query index) — see
        :func:`repro.spatial.pipeline.selection_queries` for the per-query
        grouping wrapper.
        """
        if predicate == "linestring" and self.r_kind != "line":
            raise ValueError("predicate 'linestring' needs JoinPlan(..., "
                             "r_kind='line') with the chains as R")
        if predicate != "linestring" and self.r_kind == "line":
            raise ValueError(
                f"predicate {predicate!r} needs polygon approximations, but "
                "this plan was built with r_kind='line'")
        if self.plan_mode == "adaptive" and self.plan_choice is None:
            self.plan(predicate)
        if self.approx_r is None or self.approx_s is None:
            self.build()
        stats = JoinStats(method=self.filter.name, predicate=predicate,
                          backend=self.filter_backend,
                          filter_backend=self.filter_backend,
                          refine_backend=self.refine_backend,
                          mbr_backend=self.mbr_backend,
                          pipeline_mode=self.pipeline_mode,
                          plan_mode=self.plan_mode)
        if self.plan_choice is not None:
            stats.extra["plan"] = self.plan_choice.to_dict()
            stats.extra["t_plan"] = self._t_plan
        stats.t_build = self._t_build
        stats.approx_bytes = (self.approx_r.size_bytes()
                              + self.approx_s.size_bytes())

        if self.pipeline_mode == "fused":
            results, stats = execute_fused(self, predicate, stats)
            self.last_stats = stats
            return results, stats

        t0 = time.perf_counter()
        pairs = self.candidates(predicate)
        stats.t_mbr = time.perf_counter() - t0
        stats.n_candidates = len(pairs)
        if len(pairs) == 0:
            self.last_stats = stats
            return np.zeros((0, 2), np.int64), stats

        t0 = time.perf_counter()
        verdicts = self.filter.verdicts(
            self.approx_r, self.approx_s, pairs, predicate=predicate,
            backend=self.filter_backend, **self.filter_opts)
        stats.t_filter = time.perf_counter() - t0
        _apply_verdicts(stats, verdicts)

        t0 = time.perf_counter()
        indec = pairs[verdicts == INDECISIVE]
        ref = self._refine(predicate, indec)
        stats.t_refine = time.perf_counter() - t0

        results = np.concatenate([pairs[verdicts == TRUE_HIT], indec[ref]],
                                 axis=0)
        stats.n_results = len(results)
        self.last_stats = stats
        return results, stats
