"""Out-of-core tiled scale-out driver (DESIGN.md §14).

Everything before this module assumes both datasets — and every
approximation built over them — fit in one host's memory. This module
joins datasets that don't, in four streaming phases:

1. **Streaming partition & spill.** Each input side arrives as an
   *iterator of chunks* (:func:`repro.datagen.iter_dataset_chunks`, or any
   :class:`~repro.datagen.PolygonDataset` sequence). Every chunk is
   assigned to the §5.2 uniform grid partitions its MBRs intersect
   (vectorized :func:`~repro.core.partition.tile_hits`) and appended to a
   per-partition on-disk spill; host memory stays O(chunk), the spill
   holds the replicated partitions. Per partition the pass accumulates the
   statistics the cost model needs: object counts, the MBR hull (the
   partition's raster extent), a D×D rect-coverage histogram of the MBRs
   (the same co-bucket quantity the §8 grid-hash join enumerates), and a
   deterministic bottom-k hash sample of whole objects.

2. **Cost estimation** (:func:`estimate_partition`). Per-partition work is
   priced in the PR 9 planner's machine-independent work units
   (DESIGN.md §13): probe APRIL stores built over the sampled objects give
   interval-count statistics (build cost, merge-join comparison bounds),
   the MBR-density histograms give the expected candidate count, and the
   sampled pair records give the filter comparisons + INDECISIVE rate +
   refinement vertex products. ``cost = c_build·intervals +
   candidates·filter_cmp + c_refine·candidates·indec_rate·vertex_product``.

3. **Skew split & tile packing** (:func:`plan_scaleout`). Partitions whose
   estimated cost exceeds ``split_factor`` × the median split into their
   2x2 quadrants (:func:`~repro.core.partition.quadrants`), re-spilling
   only the hot partition's objects; children recompute statistics and may
   split again up to ``max_split_depth``. Splitting a hot partition also
   *shrinks its raster extent*, so its children filter at a finer
   effective resolution — less exact-refinement work, the measured win in
   ``BENCH_scaleout.json``. The surviving partitions pack
   first-fit-decreasing by estimated resident bytes into **tiles** bounded
   by ``tile_budget`` — a tile is the unit of device/host residency.
   ``balance="static"`` disables the split and packs in partition order
   (the comparison baseline). All of it is deterministic: seeded hash
   samples, stable orders, no wall-clock feedback.

4. **Streaming join** (:func:`tiled_join`). Tiles execute in order; within
   a tile each partition loads its spilled arrays, builds approximations
   *for that tile only*, and runs the staged or fused pipeline — under the
   adaptive planner when ``plan_mode="adaptive"`` (per-partition
   :class:`~repro.spatial.planner.PlanChoice`, shared between
   similar-density partitions through a
   :class:`~repro.spatial.planner.ProfileCache`), and through
   :func:`~repro.spatial.distributed.distributed_fused_join` (one
   ``shard_map`` dispatch, counts psum-reduced on device) when a mesh is
   supplied. Cross-partition duplicates drop by the reference-point rule
   over the final (non-uniform) tile cover
   (:func:`~repro.core.partition.owner_tiles`); local ids map back to
   global ids from the spill. After every tile the completed-tile manifest
   checkpoints through :class:`~repro.runtime.checkpoint.CheckpointManager`
   — a killed run restarts at the first unfinished tile and produces the
   identical verdict set (fingerprint-guarded, see tests/test_scaleout.py).

Verdicts are identical to the in-memory ``JoinPlan`` reference for every
filter method × predicate: partitioning, splitting, packing, and resume
are execution details — the exact refinement stage decides every result.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
import zlib
from dataclasses import dataclass, field

import numpy as np

from ..core.partition import owner_tiles, quadrants, square_extent, tile_hits
from ..core.rasterize import Extent
from ..datagen.synthetic import PolygonDataset
from .plan import JoinPlan, JoinStats
from .planner import (ORDER_CHOICES, PLAN_DEFAULTS, ProfileCache,
                      _lists, _order_work, _pair_record, _store_ints)

__all__ = ["SCALEOUT_DEFAULTS", "BALANCE_MODES", "TilePartition", "TilePlan",
           "check_balance", "estimate_partition", "plan_scaleout",
           "tiled_join"]

#: ``balance="cost"`` splits skewed partitions and packs tiles
#: first-fit-decreasing by estimated bytes; ``"static"`` keeps the uniform
#: grid and packs in partition order (the BENCH_scaleout baseline).
BALANCE_MODES = ("cost", "static")

SCALEOUT_DEFAULTS: dict = {
    "parts_per_dim": 2,       # base uniform grid (parts_per_dim^2 tiles)
    "tile_budget": 64 << 20,  # resident bytes per tile
    "balance": "cost",        # cost | static
    "split_factor": 4.0,      # split while cost > factor * median
    "max_split_depth": 2,     # quadtree depth below the base grid
    "min_split_objs": 64,     # don't split partitions smaller than this
    "sample_size": 32,        # bottom-k objects probed per side
    "max_probe_pairs": 64,    # sampled pair records per partition
    "density_grid": 8,        # D of the D x D MBR-density histogram
    "seed": 0,                # salts the bottom-k hash sample
}

_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


def check_balance(balance: str) -> None:
    if balance not in BALANCE_MODES:
        raise ValueError(f"unknown balance {balance!r}; "
                         f"expected one of {BALANCE_MODES}")


def _as_chunks(src, chunk_size: int = 65536):
    """Normalize a chunk source: a PolygonDataset slices into chunk views;
    any iterable of datasets streams through unchanged."""
    if isinstance(src, PolygonDataset):
        def gen():
            for start in range(0, len(src), chunk_size):
                sl = slice(start, start + chunk_size)
                yield PolygonDataset(name=src.name, verts=src.verts[sl],
                                     nverts=src.nverts[sl])
        return gen()
    return iter(src)


# ---------------------------------------------------------------------------
# Phase 1: streaming partition + spill
# ---------------------------------------------------------------------------

class _SideSpill:
    """On-disk chunk store of one partition's objects on one side.

    ``append`` writes one npz per incoming chunk slice (global ids, padded
    vertices, vertex counts, MBRs); ``load`` concatenates them padded to
    the partition-wide Vmax. Host memory during the spill pass stays
    O(chunk); a ``load`` materializes one partition-side only — bounded by
    the tile budget the packer enforced.
    """

    def __init__(self, root: str, side: str, pid: int):
        self.dir = os.path.join(root, side, f"part_{pid}")
        os.makedirs(self.dir, exist_ok=True)
        self.n = 0
        self.n_chunks = 0
        self.vmax = 0

    def append(self, gid, verts, nverts, mbrs) -> None:
        np.savez(os.path.join(self.dir, f"chunk_{self.n_chunks:06d}.npz"),
                 gid=gid, verts=verts, nverts=nverts, mbrs=mbrs)
        self.n += len(gid)
        self.n_chunks += 1
        self.vmax = max(self.vmax, int(verts.shape[1]))

    def iter_chunks(self):
        for ci in range(self.n_chunks):
            with np.load(os.path.join(self.dir,
                                      f"chunk_{ci:06d}.npz")) as z:
                yield {k: z[k] for k in ("gid", "verts", "nverts", "mbrs")}

    def load(self):
        """(gid [N], verts [N,Vmax,2], nverts [N], mbrs [N,4]) or Nones."""
        if self.n == 0:
            return (np.zeros(0, np.int64), np.zeros((0, 0, 2)),
                    np.zeros(0, np.int64), np.zeros((0, 4)))
        gids, verts, nvs, mbrs = [], [], [], []
        for ch in self.iter_chunks():
            v = ch["verts"]
            if v.shape[1] < self.vmax:
                v = np.pad(v, ((0, 0), (0, self.vmax - v.shape[1]), (0, 0)))
            gids.append(ch["gid"])
            verts.append(v)
            nvs.append(ch["nverts"])
            mbrs.append(ch["mbrs"])
        return (np.concatenate(gids), np.concatenate(verts, axis=0),
                np.concatenate(nvs), np.concatenate(mbrs, axis=0))

    def remove(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)


class _SideStats:
    """Streaming per-(partition, side) statistics: count, MBR hull, the
    D x D rect-coverage histogram (difference-array adds, cumsum on
    finalize), and a deterministic bottom-k hash sample of objects."""

    def __init__(self, tile, k: int, D: int, salt: int):
        self.tile = tile
        self.D = D
        self.k = k
        self.salt = np.uint64(salt)
        self.n = 0
        self.vmax = 0
        self.lo = np.array([np.inf, np.inf])
        self.hi = np.array([-np.inf, -np.inf])
        self._diff = np.zeros((D + 1, D + 1))
        self.sample: list[tuple] = []   # (key, verts_row, nv, mbr)

    def update(self, gid, verts, nverts, mbrs) -> None:
        self.n += len(gid)
        self.vmax = max(self.vmax, int(verts.shape[1]))
        self.lo = np.minimum(self.lo, mbrs[:, :2].min(axis=0))
        self.hi = np.maximum(self.hi, mbrs[:, 2:].max(axis=0))
        xmin, ymin, xmax, ymax = self.tile
        D = self.D
        sx = max(xmax - xmin, 1e-12) / D
        sy = max(ymax - ymin, 1e-12) / D
        x0 = np.clip(((mbrs[:, 0] - xmin) / sx).astype(np.int64), 0, D - 1)
        x1 = np.clip(((mbrs[:, 2] - xmin) / sx).astype(np.int64), 0, D - 1)
        y0 = np.clip(((mbrs[:, 1] - ymin) / sy).astype(np.int64), 0, D - 1)
        y1 = np.clip(((mbrs[:, 3] - ymin) / sy).astype(np.int64), 0, D - 1)
        np.add.at(self._diff, (x0, y0), 1.0)
        np.add.at(self._diff, (x1 + 1, y0), -1.0)
        np.add.at(self._diff, (x0, y1 + 1), -1.0)
        np.add.at(self._diff, (x1 + 1, y1 + 1), 1.0)
        # bottom-k hash sample: chunk-order independent, no rng state
        keys = ((gid.astype(np.uint64) + np.uint64(1)) * _HASH_MULT
                ^ self.salt)
        take = np.argsort(keys, kind="stable")[: self.k]
        merged = self.sample + [
            (int(keys[i]), verts[i], int(nverts[i]), mbrs[i]) for i in take]
        merged.sort(key=lambda t: t[0])
        self.sample = merged[: self.k]

    @property
    def hist(self) -> np.ndarray:
        return np.cumsum(np.cumsum(self._diff, axis=0),
                         axis=1)[: self.D, : self.D]

    def sample_dataset(self, name: str) -> PolygonDataset | None:
        if not self.sample:
            return None
        vmax = max(v.shape[0] for _, v, _, _ in self.sample)
        verts = np.zeros((len(self.sample), vmax, 2))
        nvs = np.zeros(len(self.sample), np.int64)
        for i, (_, v, nv, _) in enumerate(self.sample):
            verts[i, : v.shape[0]] = v
            nvs[i] = nv
        return PolygonDataset(name=name, verts=verts, nverts=nvs)


@dataclass
class TilePartition:
    """One partition of the (possibly skew-split) cover: its tile rect,
    raster extent (§5.2 square hull of member MBRs), per-side object
    counts, split depth, and the cost-model estimate (work units +
    resident bytes)."""
    pid: int
    tile: tuple
    extent: Extent | None
    n_r: int = 0
    n_s: int = 0
    depth: int = 0
    est: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"pid": self.pid, "tile": [float(v) for v in self.tile],
                "n_r": self.n_r, "n_s": self.n_s, "depth": self.depth,
                "est": {k: (round(v, 3) if isinstance(v, float) else v)
                        for k, v in self.est.items()}}


@dataclass
class TilePlan:
    """The partitioner's output: the final partition cover and its packing
    into memory-budgeted tiles. ``tiles[t]`` lists indices into ``parts``;
    :meth:`cover` is the [P,4] rect array the reference-point ownership
    rule (:func:`~repro.core.partition.owner_tiles`) dedups against."""
    parts: list[TilePartition]
    tiles: list[list[int]]
    tile_budget: int
    balance: str
    est: dict = field(default_factory=dict)

    def cover(self) -> np.ndarray:
        return np.asarray([p.tile for p in self.parts], np.float64)

    def to_dict(self) -> dict:
        return {"balance": self.balance,
                "tile_budget": int(self.tile_budget),
                "parts": [p.to_dict() for p in self.parts],
                "tiles": [list(t) for t in self.tiles],
                "est": dict(self.est)}


# ---------------------------------------------------------------------------
# Phase 2: the cost model (PR 9 work units over streaming statistics)
# ---------------------------------------------------------------------------

def estimate_partition(st_r: _SideStats, st_s: _SideStats, extent: Extent,
                       n_order: int, predicate: str, r_kind: str,
                       max_probe_pairs: int = 64) -> dict:
    """Price one partition in the §13 planner's work units.

    Probe APRIL stores over the bottom-k samples give
    ``mean_ints_{r,s}`` (interval-count statistics → build cost and
    merge-join comparison bounds); the MBR-density histograms give
    ``est_cand`` (the co-bucket candidate quantity of the §8 grid-hash
    join); sampled pair records (:func:`~repro.spatial.planner` counting
    twins) give the mean early-exit filter comparisons, the INDECISIVE
    rate, and the mean refinement vertex product. Returns the est dict
    (work-unit components, total ``cost``, and resident ``bytes``).
    """
    from .filters import get_filter

    o = PLAN_DEFAULTS
    est_cand = float((st_r.hist * st_s.hist).sum())
    ds_r = st_r.sample_dataset("probe_r")
    ds_s = st_s.sample_dataset("probe_s")
    mean_ints_r = mean_ints_s = 0.0
    mean_cmp = 0.0
    indec_rate = 0.0
    mean_vp = 0.0
    if ds_r is not None and ds_s is not None:
        filt = get_filter("april")
        ax_r = filt.build(ds_r, n_order=n_order, extent=extent, kind=r_kind)
        ax_s = filt.build(ds_s, n_order=n_order, extent=extent)
        mean_ints_r = _store_ints(ax_r.store) / len(ds_r)
        mean_ints_s = _store_ints(ax_s.store) / len(ds_s)
        mr = np.asarray([m for _, _, _, m in st_r.sample])
        ms = np.asarray([m for _, _, _, m in st_s.sample])
        cand = [(i, j) for i in range(len(mr)) for j in range(len(ms))
                if (mr[i, 0] < ms[j, 2] and mr[i, 2] > ms[j, 0]
                    and mr[i, 1] < ms[j, 3] and mr[i, 3] > ms[j, 1])]
        cand = cand[:max_probe_pairs]
        if cand:
            recs = []
            for i, j in cand:
                Ar, Fr = _lists(ax_r.store, i, r_kind)
                As_, Fs = _lists(ax_s.store, j, "polygon")
                recs.append(_pair_record(
                    Ar, Fr, As_, Fs,
                    float(ds_r.nverts[i]) * float(ds_s.nverts[j]),
                    predicate))
            m = len(recs)
            mean_cmp = sum(_order_work(r, ORDER_CHOICES[0], predicate)
                           for r in recs) / m
            from ..core.join import INDECISIVE
            indec = [r for r in recs if r["verdict"] == INDECISIVE]
            indec_rate = len(indec) / m
            mean_vp = (sum(r["refine"] for r in indec) / len(indec)
                       if indec else 0.0)

    build_w = o["c_build"] * (mean_ints_r * st_r.n + mean_ints_s * st_s.n)
    filter_w = est_cand * mean_cmp
    refine_w = o["c_refine"] * est_cand * indec_rate * mean_vp
    size = (st_r.n * st_r.vmax * 16 + st_s.n * st_s.vmax * 16
            + 8 * (mean_ints_r * st_r.n + mean_ints_s * st_s.n)
            + 32 * est_cand)
    return {"est_cand": est_cand, "mean_ints_r": mean_ints_r,
            "mean_ints_s": mean_ints_s, "mean_cmp": mean_cmp,
            "indec_rate": indec_rate, "mean_vp": mean_vp,
            "build": build_w, "filter": filter_w, "refine": refine_w,
            "cost": build_w + filter_w + refine_w, "bytes": float(size)}


# ---------------------------------------------------------------------------
# Phase 3: skew split + tile packing
# ---------------------------------------------------------------------------

class _SpillStore:
    """All partition spills + statistics of one scale-out run."""

    def __init__(self, root: str, D: int, k: int, seed: int):
        self.root = root
        self.D = D
        self.k = k
        self.seed = seed
        self.spills: dict[tuple[str, int], _SideSpill] = {}
        self.stats: dict[tuple[str, int], _SideStats] = {}

    def side(self, side: str, pid: int, tile) -> tuple[_SideSpill,
                                                       _SideStats]:
        key = (side, pid)
        if key not in self.spills:
            self.spills[key] = _SideSpill(self.root, side, pid)
            salt = zlib.crc32(f"{pid}:{side}:{self.seed}".encode())
            self.stats[key] = _SideStats(tile, self.k, self.D, salt)
        return self.spills[key], self.stats[key]

    def add(self, side: str, pid: int, tile, gid, verts, nverts,
            mbrs) -> None:
        hit = tile_hits(mbrs, tile)
        if not hit.any():
            return
        spill, st = self.side(side, pid, tile)
        spill.append(gid[hit], verts[hit], nverts[hit], mbrs[hit])
        st.update(gid[hit], verts[hit], nverts[hit], mbrs[hit])

    def drop(self, pid: int) -> None:
        for side in ("r", "s"):
            sp = self.spills.pop((side, pid), None)
            if sp is not None:
                sp.remove()
            self.stats.pop((side, pid), None)


def _spill_side(store: _SpillStore, side: str, chunks, parts) -> int:
    """Stream one side's chunks into every base partition spill; returns
    the total object count (global ids are chunk offsets + local index)."""
    offset = 0
    for chunk in chunks:
        gid = offset + np.arange(len(chunk), dtype=np.int64)
        for p in parts:
            store.add(side, p.pid, p.tile, gid, chunk.verts, chunk.nverts,
                      chunk.mbrs)
        offset += len(chunk)
    return offset


def _finish_partition(store: _SpillStore, part: TilePartition,
                      n_order: int, predicate: str, r_kind: str,
                      max_probe_pairs: int) -> None:
    """Fill a partition's extent + cost estimate from its side stats."""
    st_r = store.stats.get(("r", part.pid))
    st_s = store.stats.get(("s", part.pid))
    part.n_r = st_r.n if st_r else 0
    part.n_s = st_s.n if st_s else 0
    boxes = []
    for st in (st_r, st_s):
        if st is not None and st.n:
            boxes.append(np.concatenate([st.lo, st.hi]))
    part.extent = square_extent(
        np.asarray(boxes).reshape(-1, 4), part.tile)
    if st_r is None or st_s is None or not (st_r.n and st_s.n):
        part.est = {"cost": 0.0, "bytes": 0.0, "est_cand": 0.0}
        return
    part.est = estimate_partition(st_r, st_s, part.extent, n_order,
                                  predicate, r_kind, max_probe_pairs)


def _split_partition(store: _SpillStore, part: TilePartition,
                     next_pid: int, n_order: int, predicate: str,
                     r_kind: str, max_probe_pairs: int
                     ) -> list[TilePartition]:
    """Re-spill one hot partition into its 2x2 quadrant children (reads the
    parent spill chunk-by-chunk — O(chunk) host memory) and price them."""
    children = [TilePartition(pid=next_pid + q, tile=rect, extent=None,
                              depth=part.depth + 1)
                for q, rect in enumerate(quadrants(part.tile))]
    for side in ("r", "s"):
        parent = store.spills.get((side, part.pid))
        if parent is None:
            continue
        for ch in parent.iter_chunks():
            for c in children:
                store.add(side, c.pid, c.tile, ch["gid"], ch["verts"],
                          ch["nverts"], ch["mbrs"])
    store.drop(part.pid)
    for c in children:
        _finish_partition(store, c, n_order, predicate, r_kind,
                          max_probe_pairs)
    return children


def plan_scaleout(r_chunks, s_chunks, *, spill_dir: str,
                  n_order: int = 8, predicate: str = "intersects",
                  r_kind: str = "polygon", **opts
                  ) -> tuple[TilePlan, _SpillStore, tuple[int, int]]:
    """Phases 1-3: spill both streams, price the partitions, split skew,
    pack tiles. Returns (plan, spill store, (n_r_total, n_s_total)).
    Deterministic for fixed inputs and options — asserted by
    tests/test_scaleout.py. Host memory stays O(chunk) + O(samples).
    """
    unknown = set(opts) - set(SCALEOUT_DEFAULTS)
    if unknown:
        raise TypeError(f"unknown scaleout option(s) {sorted(unknown)}; "
                        f"expected a subset of {sorted(SCALEOUT_DEFAULTS)}")
    o = dict(SCALEOUT_DEFAULTS)
    o.update(opts)
    check_balance(o["balance"])
    k = int(o["parts_per_dim"])
    store = _SpillStore(spill_dir, int(o["density_grid"]),
                        int(o["sample_size"]), int(o["seed"]))
    parts = []
    pid = 0
    for ty in range(k):
        for tx in range(k):
            parts.append(TilePartition(
                pid=pid, tile=(tx / k, ty / k, (tx + 1) / k, (ty + 1) / k),
                extent=None))
            pid += 1
    n_r = _spill_side(store, "r", _as_chunks(r_chunks), parts)
    n_s = _spill_side(store, "s", _as_chunks(s_chunks), parts)
    for p in parts:
        _finish_partition(store, p, n_order, predicate, r_kind,
                          int(o["max_probe_pairs"]))

    n_splits = 0
    if o["balance"] == "cost":
        base_costs = sorted(p.est["cost"] for p in parts)
        median = base_costs[len(base_costs) // 2] if base_costs else 0.0
        threshold = float(o["split_factor"]) * max(median, 1e-9)
        work = list(parts)
        final: list[TilePartition] = []
        while work:
            p = work.pop(0)
            if (median > 0 and p.est["cost"] > threshold
                    and p.n_r + p.n_s >= int(o["min_split_objs"])
                    and p.depth < int(o["max_split_depth"])):
                children = _split_partition(
                    store, p, pid, n_order, predicate, r_kind,
                    int(o["max_probe_pairs"]))
                pid += len(children)
                n_splits += 1
                work = children + work      # children may split again
            else:
                final.append(p)
        parts = sorted(final, key=lambda p: p.pid)

    # pack partitions into memory-budgeted tiles
    budget = int(o["tile_budget"])
    idx = list(range(len(parts)))
    if o["balance"] == "cost":
        idx.sort(key=lambda i: (-parts[i].est["bytes"], parts[i].pid))
    tiles: list[list[int]] = []
    loads: list[float] = []
    for i in idx:
        b = parts[i].est["bytes"]
        placed = False
        if o["balance"] == "cost":
            for t in range(len(tiles)):
                if loads[t] + b <= budget:
                    tiles[t].append(i)
                    loads[t] += b
                    placed = True
                    break
        elif tiles and loads[-1] + b <= budget:
            tiles[-1].append(i)       # static: order-preserving fill
            loads[-1] += b
            placed = True
        if not placed:
            tiles.append([i])         # oversized partitions ride alone
            loads.append(b)
    for t in tiles:
        t.sort()
    plan = TilePlan(parts=parts, tiles=tiles, tile_budget=budget,
                    balance=o["balance"],
                    est={"n_splits": n_splits,
                         "total_cost": round(sum(p.est["cost"]
                                                 for p in parts), 3),
                         "total_bytes": round(sum(p.est["bytes"]
                                                  for p in parts), 1),
                         "tile_loads": [round(x, 1) for x in loads]})
    return plan, store, (n_r, n_s)


# ---------------------------------------------------------------------------
# Phase 4: the streaming join driver
# ---------------------------------------------------------------------------

def _fingerprint(plan: TilePlan, n_r: int, n_s: int, predicate: str,
                 method: str, n_order: int, r_kind: str) -> int:
    """Identity of a run's work plan: a resumed checkpoint is honored only
    when the tile plan AND the join configuration both match."""
    doc = {"plan": plan.to_dict(), "n_r": n_r, "n_s": n_s,
           "predicate": predicate, "method": method, "n_order": n_order,
           "r_kind": r_kind}
    return zlib.crc32(json.dumps(doc, sort_keys=True).encode())


_COUNT_KEYS = ("n_candidates", "n_true_hits", "n_true_negs", "n_indecisive",
               "n_results")
_TIME_KEYS = ("t_mbr", "t_filter", "t_refine", "t_sync", "t_build")


def _execute_partition(Rp, Sp, part: TilePartition, *, predicate, method,
                       n_order, filter_backend, refine_backend, mbr_backend,
                       pipeline_mode, plan_mode, plan_opts, profile_cache,
                       mesh, r_kind, totals: dict) -> np.ndarray:
    """Join one partition's local datasets; returns LOCAL result pairs
    (ownership not yet applied). Accumulates counters/times into
    ``totals``. A mesh routes april/none intersects plans through the
    one-dispatch sharded chain (DESIGN.md §12/§13)."""
    plan_kw = dict(filter=method, n_order=n_order, extent=part.extent,
                   filter_backend=filter_backend,
                   refine_backend=refine_backend, mbr_backend=mbr_backend,
                   r_kind=r_kind)
    choice = None
    if plan_mode == "adaptive":
        jp = JoinPlan(Rp, Sp, plan_mode="adaptive",
                      plan_opts=dict(plan_opts or {}), **plan_kw)
        cand = jp.candidates(predicate)
        key = None
        if profile_cache is not None:
            key = profile_cache.key(predicate, len(Rp), len(Sp), len(cand))
            choice = profile_cache.get(key)
        if choice is None:
            choice = jp.plan(predicate, pairs=cand)
            if profile_cache is not None:
                profile_cache.put(key, choice)
        else:
            jp._apply_choice(choice)
    else:
        jp = JoinPlan(Rp, Sp, pipeline_mode=pipeline_mode, **plan_kw)

    effective_mode = jp.pipeline_mode
    if (mesh is not None and predicate == "intersects"
            and effective_mode == "fused"
            and jp.filter.name in ("april", "none")):
        from .distributed import distributed_fused_join
        t0 = time.perf_counter()
        if choice is not None and (choice.skip_filter
                                   or choice.method == "none"):
            ar = as_ = None
        else:
            jp.build()
            ar, as_ = jp.approx_r, jp.approx_s
        pairs, counts = distributed_fused_join(Rp, Sp, ar, as_, mesh=mesh,
                                               plan=choice)
        totals["t_filter"] += time.perf_counter() - t0
        totals["t_build"] += jp._t_build
        totals["n_candidates"] += int(counts.get("mbr_pairs", 0))
        totals["n_true_hits"] += int(counts.get("true_hit", 0))
        totals["n_true_negs"] += int(counts.get("true_neg", 0))
        totals["n_indecisive"] += int(counts.get("indecisive", 0))
        return pairs

    pairs, st = jp.execute(predicate)
    for kk in _COUNT_KEYS:
        totals[kk] += getattr(st, kk)
    for kk in _TIME_KEYS:
        totals[kk] += getattr(st, kk)
    return pairs


def tiled_join(r_chunks, s_chunks, *, predicate: str = "intersects",
               method: str = "april", n_order: int = 8,
               filter_backend: str = "numpy", refine_backend: str = "numpy",
               mbr_backend: str = "numpy", pipeline_mode: str = "staged",
               plan_mode: str = "static", plan_opts: dict | None = None,
               r_kind: str = "polygon", mesh=None,
               spill_dir: str | None = None, ckpt_dir: str | None = None,
               resume: bool = True, stop_after_tiles: int | None = None,
               profile_cache: ProfileCache | None = None,
               **opts) -> tuple[np.ndarray, JoinStats]:
    """The out-of-core tiled join (DESIGN.md §14, module docstring has the
    protocol). ``r_chunks``/``s_chunks`` stream in as chunk iterators (or
    in-memory datasets, auto-chunked); result pairs are GLOBAL ids,
    set-identical to the in-memory ``JoinPlan`` reference.

    ``**opts`` are the :data:`SCALEOUT_DEFAULTS` partitioner knobs
    (``tile_budget``, ``balance``, ``split_factor``, ...). ``ckpt_dir``
    enables the completed-tile manifest: every finished tile checkpoints,
    and a rerun with ``resume=True`` (the default) skips straight to the
    first unfinished tile — fingerprint-guarded, so a changed workload or
    configuration starts fresh. ``stop_after_tiles`` ends the run early
    after N tiles (the kill-and-resume test hook); the partial run's
    stats carry ``extra["interrupted"] = True``.

    Returns ``(pairs [K,2] int64, JoinStats)`` with the §14 additions:
    ``t_partition`` (spill + statistics + split + pack wall time) and
    ``tiles`` (tile count), plus ``extra["tile_plan"]`` evidence.
    """
    own_spill = spill_dir is None
    if own_spill:
        spill_dir = tempfile.mkdtemp(prefix="scaleout_spill_")
    try:
        t0 = time.perf_counter()
        plan, store, (n_r, n_s) = plan_scaleout(
            r_chunks, s_chunks, spill_dir=spill_dir, n_order=n_order,
            predicate=predicate, r_kind=r_kind, **opts)
        t_partition = time.perf_counter() - t0

        fp = _fingerprint(plan, n_r, n_s, predicate, method, n_order,
                          r_kind)
        mgr = None
        done: dict[int, np.ndarray] = {}
        tile_counts: dict[str, dict] = {}
        if ckpt_dir is not None:
            from ..runtime.checkpoint import CheckpointManager
            mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)
            restored = mgr.restore() if resume else None
            if restored is not None:
                _, flat, extra = restored
                if extra.get("fingerprint") == fp:
                    done = {int(k.split("_")[1]): v for k, v in flat.items()
                            if k.startswith("tile_")}
                    tile_counts = dict(extra.get("tile_counts", {}))

        totals = {kk: 0 for kk in _COUNT_KEYS}
        totals.update({kk: 0.0 for kk in _TIME_KEYS})
        for ti_key, c in tile_counts.items():
            if int(ti_key) in done:
                for kk, v in c.items():
                    totals[kk] += v
        cover = plan.cover()
        n_resumed = len(done)
        interrupted = False

        for ti, tile in enumerate(plan.tiles):
            if ti in done:
                continue
            if stop_after_tiles is not None and \
                    len(done) - n_resumed >= stop_after_tiles:
                interrupted = True
                break
            before = dict(totals)
            tile_pairs = []
            for part_i in tile:
                part = plan.parts[part_i]
                if part.n_r == 0 or part.n_s == 0:
                    continue
                gid_r, verts_r, nv_r, mbrs_r = \
                    store.spills[("r", part.pid)].load()
                gid_s, verts_s, nv_s, mbrs_s = \
                    store.spills[("s", part.pid)].load()
                Rp = PolygonDataset(name="r", verts=verts_r, nverts=nv_r)
                Sp = PolygonDataset(name="s", verts=verts_s, nverts=nv_s)
                local = _execute_partition(
                    Rp, Sp, part, predicate=predicate, method=method,
                    n_order=n_order, filter_backend=filter_backend,
                    refine_backend=refine_backend, mbr_backend=mbr_backend,
                    pipeline_mode=pipeline_mode, plan_mode=plan_mode,
                    plan_opts=plan_opts, profile_cache=profile_cache,
                    mesh=mesh, r_kind=r_kind, totals=totals)
                if len(local) == 0:
                    continue
                own = owner_tiles(cover, mbrs_r[local[:, 0]],
                                  mbrs_s[local[:, 1]]) == part_i
                local = local[own]
                tile_pairs.append(np.stack(
                    [gid_r[local[:, 0]], gid_s[local[:, 1]]], axis=1))
            done[ti] = (np.concatenate(tile_pairs, axis=0) if tile_pairs
                        else np.zeros((0, 2), np.int64))
            tile_counts[str(ti)] = {
                kk: totals[kk] - before[kk]
                for kk in (*_COUNT_KEYS, *_TIME_KEYS)}
            if mgr is not None:
                mgr.save(len(done),
                         {f"tile_{k}": v for k, v in done.items()},
                         extra={"fingerprint": fp,
                                "tile_counts": tile_counts,
                                "tile_plan": plan.to_dict()})

        pairs = (np.concatenate([done[t] for t in sorted(done)], axis=0)
                 if done else np.zeros((0, 2), np.int64))
        stats = JoinStats(method=method, predicate=predicate,
                          filter_backend=filter_backend,
                          backend=filter_backend,
                          refine_backend=refine_backend,
                          mbr_backend=mbr_backend,
                          pipeline_mode=pipeline_mode, plan_mode=plan_mode,
                          tiles=len(plan.tiles), t_partition=t_partition)
        for kk in _COUNT_KEYS:
            setattr(stats, kk, int(totals[kk]))
        for kk in _TIME_KEYS:
            setattr(stats, kk, float(totals[kk]))
        stats.n_results = int(len(pairs))
        stats.extra["tile_plan"] = plan.est | {
            "balance": plan.balance, "n_parts": len(plan.parts),
            "n_tiles": len(plan.tiles)}
        stats.extra["resumed_tiles"] = n_resumed
        if interrupted:
            stats.extra["interrupted"] = True
        if profile_cache is not None:
            stats.extra["profile_cache"] = dict(profile_cache.stats)
        return pairs, stats
    finally:
        if own_spill:
            shutil.rmtree(spill_dir, ignore_errors=True)
