from .mbr_join import MBR_BACKENDS, MBRIndex, adaptive_grid, mbr_join  # noqa: F401,E501
from .filters import (  # noqa: F401
    Approximation, FILTER_BACKENDS, IntermediateFilter, available_filters,
    get_filter, register_filter,
)
from .plan import PIPELINE_MODES, JoinPlan, JoinStats  # noqa: F401
from .planner import (  # noqa: F401
    PLAN_MODES, PlanChoice, ProfileCache, check_plan_mode, choose_plan,
)
from .refine import REFINE_BACKENDS  # noqa: F401
from .pipeline import (  # noqa: F401
    spatial_intersection_join, spatial_within_join,
    polygon_linestring_join, selection_queries, tiled_spatial_join,
)
from .scaleout import (  # noqa: F401
    BALANCE_MODES, SCALEOUT_DEFAULTS, TilePartition, TilePlan,
    plan_scaleout, tiled_join,
)
from .store_cache import StoreCache  # noqa: F401
from .service import JoinService, JoinTicket, SERVICE_PREDICATES  # noqa: F401
