from .mbr_join import mbr_join  # noqa: F401
from .pipeline import (  # noqa: F401
    JoinStats, spatial_intersection_join, spatial_within_join,
    polygon_linestring_join, selection_queries,
)
