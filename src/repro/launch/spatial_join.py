"""Distributed spatial-join launcher — the paper's system as a service run.

  PYTHONPATH=src python -m repro.launch.spatial_join --r T1 --s T2 \
      --n-order 8 --parts 2 --method ri --filter-backend numpy \
      --ckpt-dir /tmp/join_ckpt

Orchestration (DESIGN.md §4): partition the map (§5.2) -> per-partition
approximations through the `IntermediateFilter` registry (any of
none/april/april-c/ri/ra/5cch) -> MBR join per partition -> batched filter
verdicts, mesh-sharded for mesh-capable filters (APRIL) or host-batched for
the rest -> batched refinement of the indecisive remainder. Fault tolerance:
per-partition results checkpoint through CheckpointManager, so a killed run
resumes at partition granularity; the WorkQueue re-leases partitions whose
workers stall (straggler mitigation).

``--plan-mode adaptive`` plans per partition (DESIGN.md §13), sharing
planner choices between partitions of similar candidate density through a
:class:`~repro.spatial.planner.ProfileCache` — only the first partition of
each density bucket pays for sampling.

``--tile-budget BYTES`` switches to the out-of-core tiled driver
(DESIGN.md §14, "Scaling beyond one device" in README.md): datasets stream
in as generated chunks, the cost-balanced partitioner packs them into
memory-budgeted tiles (``--balance static`` keeps the uniform grid), and
every finished tile checkpoints to ``--ckpt-dir`` — rerun with ``--resume``
to continue a killed run at the first unfinished tile.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import partition as partition_mod
from ..core.join import INDECISIVE, TRUE_HIT
from ..datagen import PolygonDataset, make_dataset
from ..runtime.checkpoint import CheckpointManager
from ..runtime.elastic import WorkQueue
from ..spatial import refine
from ..spatial.distributed import (distributed_filter, distributed_fused_join,
                                   distributed_mbr_join, distributed_refine,
                                   make_join_mesh)
from ..spatial.filters import get_filter
from ..spatial.fused import check_pipeline_mode
from ..spatial.mbr_join import mbr_join
from ..spatial.plan import JoinPlan
from ..spatial.planner import ProfileCache, check_plan_mode


def join_partition(R, S, approx_r, approx_s, parting, pidx, mesh, filt,
                   backend: str = "jnp", refine_backend: str = "numpy",
                   mbr_backend: str = "numpy", pipeline_mode: str = "staged",
                   plan_mode: str = "static", n_order: int = 8,
                   profile_cache=None):
    """Filter + refine all candidate pairs owned by partition ``pidx``.

    ``mbr_backend='jnp'`` generates the partition's candidates sharded over
    the mesh (DESIGN.md §8, bucket cross-product rows sharded, pair lists
    gathered); other values run the host grid-hash join.
    ``refine_backend='jnp'`` refines the indecisive remainder sharded over
    the mesh (verdicts stay sharded end-to-end, DESIGN.md §7); other
    backends run the batched host refinement.
    ``pipeline_mode='fused'`` (APRIL only) runs the partition's whole
    MBR -> filter -> refine chain as one sharded dispatch
    (:func:`~repro.spatial.distributed.distributed_fused_join`) with the
    cross-partition ownership dedup applied to the joined pairs — the
    result set is identical to the staged chain; per-partition counts
    then cover the partition's full candidate frame.

    ``plan_mode='adaptive'`` (DESIGN.md §13) gives each partition its own
    plan: the sample-based planner runs on the partition's candidates, and
    an april/none choice executes under ONE ``shard_map`` step via
    :func:`~repro.spatial.distributed.distributed_fused_join` with the
    per-shard plan (skip-filter plans drop the interval kernel entirely);
    other choices run the partition's batched host path. Prebuilt
    partition stores are reused when the choice matches their
    method/granularity, rebuilt locally otherwise. A ``profile_cache``
    (:class:`~repro.spatial.planner.ProfileCache`) shares planner choices
    between partitions of similar candidate density — a cache hit adopts
    the cached :class:`~repro.spatial.planner.PlanChoice` instead of
    re-sampling this partition."""
    part = parting.partitions[pidx]
    ridx = part.obj_idx[R.name]
    sidx = part.obj_idx[S.name]
    ar, as_ = approx_r[pidx], approx_s[pidx]
    if len(ridx) == 0 or len(sidx) == 0:
        return np.zeros((0, 2), np.int64), {}

    if plan_mode == "adaptive":
        Rp = PolygonDataset(name=R.name, verts=R.verts[ridx],
                            nverts=R.nverts[ridx])
        Sp = PolygonDataset(name=S.name, verts=S.verts[sidx],
                            nverts=S.nverts[sidx])
        probe = JoinPlan(Rp, Sp, filter="april", n_order=n_order,
                         refine_backend=refine_backend
                         if refine_backend != "jnp" else "numpy",
                         plan_mode="adaptive")
        choice = key = None
        if profile_cache is not None:
            cand = probe.candidates("intersects")
            key = profile_cache.key("intersects", len(Rp), len(Sp),
                                    len(cand))
            choice = profile_cache.get(key)
            if choice is not None:
                probe._apply_choice(choice)
            else:
                choice = probe.plan("intersects", pairs=cand)
                profile_cache.put(key, choice)
        else:
            choice = probe.plan("intersects")
        if choice.method in ("april", "none"):
            if choice.skip_filter:
                ar2 = as2 = None
            elif (filt.name == "april" and choice.n_order == n_order
                    and ar is not None and as_ is not None):
                ar2, as2 = ar, as_
            else:
                april = get_filter("april")
                ar2 = april.build(Rp, n_order=choice.n_order, side="r")
                as2 = april.build(Sp, n_order=choice.n_order, side="s")
            local_pairs, counts = distributed_fused_join(
                Rp, Sp, ar2, as2, mesh=mesh, plan=choice)
        else:
            local_pairs, st = probe.execute("intersects")
            counts = {"true_neg": st.n_true_negs,
                      "true_hit": st.n_true_hits,
                      "indecisive": st.n_indecisive}
        counts = dict(counts)
        counts["plan"] = choice.key()
        if len(local_pairs) == 0:
            return np.zeros((0, 2), np.int64), counts
        own = partition_mod.reference_partitions(
            parting.parts_per_dim, R.mbrs[ridx[local_pairs[:, 0]]],
            S.mbrs[sidx[local_pairs[:, 1]]]) == pidx
        local_pairs = local_pairs[own]
        out = np.stack([ridx[local_pairs[:, 0]], sidx[local_pairs[:, 1]]],
                       axis=1)
        return out, counts

    if filt.name != "none" and (ar is None or as_ is None):
        return np.zeros((0, 2), np.int64), {}

    if pipeline_mode == "fused":
        if filt.name != "april":
            raise ValueError("pipeline_mode='fused' in the distributed "
                             "launcher needs --method april (the sharded "
                             f"fused chain), got {filt.name!r}")
        Rp = PolygonDataset(name=R.name, verts=R.verts[ridx],
                            nverts=R.nverts[ridx])
        Sp = PolygonDataset(name=S.name, verts=S.verts[sidx],
                            nverts=S.nverts[sidx])
        local_pairs, counts = distributed_fused_join(Rp, Sp, ar, as_,
                                                     mesh=mesh)
        if len(local_pairs) == 0:
            return np.zeros((0, 2), np.int64), counts
        own = partition_mod.reference_partitions(
            parting.parts_per_dim, R.mbrs[ridx[local_pairs[:, 0]]],
            S.mbrs[sidx[local_pairs[:, 1]]]) == pidx
        local_pairs = local_pairs[own]
        out = np.stack([ridx[local_pairs[:, 0]], sidx[local_pairs[:, 1]]],
                       axis=1)
        return out, counts

    if mbr_backend == "jnp":
        local_pairs, _ = distributed_mbr_join(R.mbrs[ridx], S.mbrs[sidx],
                                              mesh=mesh)
    else:
        local_pairs = mbr_join(R.mbrs[ridx], S.mbrs[sidx],
                               backend=mbr_backend)
    if len(local_pairs) == 0:
        return np.zeros((0, 2), np.int64), {}
    # ownership: reference point must fall inside this partition's tile
    own = partition_mod.reference_partitions(
        parting.parts_per_dim, R.mbrs[ridx[local_pairs[:, 0]]],
        S.mbrs[sidx[local_pairs[:, 1]]]) == pidx
    local_pairs = local_pairs[own]
    if len(local_pairs) == 0:
        return np.zeros((0, 2), np.int64), {}

    verd, counts = distributed_filter(filt, ar, as_, local_pairs, mesh=mesh,
                                      backend=backend)
    results = []
    hits = local_pairs[verd == TRUE_HIT]
    indec = local_pairs[verd == INDECISIVE]
    if len(indec):
        glob = np.stack([ridx[indec[:, 0]], sidx[indec[:, 1]]], axis=1)
        if refine_backend == "jnp":
            ref, rcounts = distributed_refine(R, S, glob, mesh=mesh)
            counts = {**counts, **rcounts}
        else:
            ref = refine.refine_pairs(R, S, glob, backend=refine_backend)
            counts = {**counts, "refined_true": int(ref.sum())}
        results.append(glob[ref])
    if len(hits):
        results.append(np.stack([ridx[hits[:, 0]], sidx[hits[:, 1]]], axis=1))
    out = (np.concatenate(results, axis=0) if results
           else np.zeros((0, 2), np.int64))
    return out, counts


def run_join(r_name="T1", s_name="T2", n_order=8, parts=2, ckpt_dir=None,
             seed=0, count_r=None, count_s=None, mesh=None, method="april",
             backend="jnp", refine_backend="numpy", mbr_backend="numpy",
             build_backend="numpy", pipeline_mode="staged",
             plan_mode="static"):
    check_pipeline_mode(pipeline_mode)
    check_plan_mode(plan_mode)
    filt = get_filter(method)
    R = make_dataset(r_name, seed=seed, count=count_r)
    S = make_dataset(s_name, seed=seed + 1, count=count_s)
    mesh = mesh or make_join_mesh()
    profile_cache = ProfileCache() if plan_mode == "adaptive" else None

    t0 = time.perf_counter()
    parting = partition_mod.partition_space([R, S], parts_per_dim=parts)
    if plan_mode == "adaptive":
        # no global prebuild: every partition's planner decides its own
        # method/granularity and builds (or skips) stores locally
        approx_r = [None] * len(parting)
        approx_s = [None] * len(parting)
    else:
        approx_r = parting.build_approx(filt, R, n_order, side="r",
                                        build_backend=build_backend)
        approx_s = parting.build_approx(filt, S, n_order, side="s",
                                        build_backend=build_backend)
    t_build = time.perf_counter() - t0

    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    done: dict[int, np.ndarray] = {}
    if mgr is not None:
        restored = mgr.restore()
        if restored is not None:
            _, flat, extra = restored
            done = {int(k.split("_")[1]): v for k, v in flat.items()
                    if k.startswith("part_")}
            print(f"[resume] {len(done)} partitions already joined")

    queue = WorkQueue([p for p in range(len(parting)) if p not in done],
                      lease_seconds=600)
    totals = {"true_neg": 0, "true_hit": 0, "indecisive": 0,
              "refined_true": 0}
    t0 = time.perf_counter()
    while not queue.finished:
        p = queue.acquire()
        if p is None:
            break
        res, counts = join_partition(R, S, approx_r, approx_s, parting, p,
                                     mesh, filt, backend=backend,
                                     refine_backend=refine_backend,
                                     mbr_backend=mbr_backend,
                                     pipeline_mode=pipeline_mode,
                                     plan_mode=plan_mode, n_order=n_order,
                                     profile_cache=profile_cache)
        done[p] = res
        for k in totals:
            totals[k] += counts.get(k, 0)
        queue.complete(p)
        if mgr is not None:
            mgr.save(len(done), {f"part_{k}": v for k, v in done.items()})
    t_join = time.perf_counter() - t0
    if mgr is not None:
        mgr.wait()

    results = np.concatenate([v for v in done.values() if len(v)], axis=0) \
        if any(len(v) for v in done.values()) else np.zeros((0, 2), np.int64)
    cache_note = (f"  plan cache {profile_cache.stats}"
                  if profile_cache is not None else "")
    print(f"build {t_build:.2f}s  join {t_join:.2f}s  "
          f"results {len(results)}  filter counts {totals}{cache_note}")
    return results, totals


def run_tiled_join(r_name="T1", s_name="T2", *, tile_budget: int,
                   n_order=8, balance="cost", ckpt_dir=None, resume=True,
                   seed=0, count_r=None, count_s=None, chunk_size=65536,
                   mesh=None, method="april", backend="numpy",
                   refine_backend="numpy", mbr_backend="numpy",
                   pipeline_mode="staged", plan_mode="static"):
    """Out-of-core tiled scale-out run (DESIGN.md §14): both datasets
    stream in as generated chunks (never materialized whole), the
    cost-balanced partitioner packs them into ``tile_budget``-byte tiles,
    and :func:`~repro.spatial.scaleout.tiled_join` drives the per-tile
    joins — checkpointing every finished tile to ``ckpt_dir`` so a rerun
    with ``resume=True`` continues at the first unfinished tile. The
    summary line surfaces the §14 stats additions (``tiles``,
    ``t_partition``) next to the per-stage times."""
    from ..datagen import iter_dataset_chunks
    from ..spatial.planner import ProfileCache
    from ..spatial.scaleout import tiled_join

    check_pipeline_mode(pipeline_mode)
    check_plan_mode(plan_mode)
    profile_cache = ProfileCache() if plan_mode == "adaptive" else None
    pairs, stats = tiled_join(
        iter_dataset_chunks(r_name, seed=seed, count=count_r,
                            chunk_size=chunk_size),
        iter_dataset_chunks(s_name, seed=seed + 1, count=count_s,
                            chunk_size=chunk_size),
        method=method, n_order=n_order, filter_backend=backend,
        refine_backend=refine_backend, mbr_backend=mbr_backend,
        pipeline_mode=pipeline_mode, plan_mode=plan_mode, mesh=mesh,
        ckpt_dir=ckpt_dir, resume=resume, profile_cache=profile_cache,
        tile_budget=tile_budget, balance=balance, seed=seed)
    resumed = stats.extra.get("resumed_tiles", 0)
    print(f"tiles {stats.tiles} ({resumed} resumed)  "
          f"partition {stats.t_partition:.2f}s  build {stats.t_build:.2f}s  "
          f"results {len(pairs)}")
    print(stats.row())
    return pairs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", default="T1")
    ap.add_argument("--s", default="T2")
    ap.add_argument("--n-order", type=int, default=8)
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--count-r", type=int, default=None)
    ap.add_argument("--count-s", type=int, default=None)
    ap.add_argument("--method", default="april",
                    help="intermediate filter: none/april/april-c/ri/ra/5cch")
    ap.add_argument("--filter-backend", default=None,
                    help="filter_backend: numpy/jnp/pallas/sequential "
                         "(jnp/pallas run mesh-capable filters sharded "
                         "over the mesh; default: --backend)")
    ap.add_argument("--backend", default="jnp",
                    help="historical alias of --filter-backend")
    ap.add_argument("--refine-backend", default="numpy",
                    help="refinement backend: numpy/jnp/pallas/sequential "
                         "(jnp refines sharded over the mesh)")
    ap.add_argument("--mbr-backend", default="numpy",
                    help="candidate-generation backend: numpy/jnp/sequential "
                         "(jnp generates candidates sharded over the mesh)")
    ap.add_argument("--build-backend", default="numpy",
                    help="store-build backend: numpy/jnp (threaded to every "
                         "per-partition filter build via build_opts)")
    ap.add_argument("--pipeline-mode", default="staged",
                    help="staged (host stage boundaries, default) or fused "
                         "(whole partition chain as one sharded dispatch, "
                         "DESIGN.md §12; APRIL only)")
    ap.add_argument("--plan-mode", default="static",
                    help="static (use the knobs above verbatim, default) or "
                         "adaptive (per-partition sample-based planner "
                         "picks method/granularity/order, DESIGN.md §13)")
    ap.add_argument("--tile-budget", type=int, default=None,
                    help="resident bytes per tile; switches to the "
                         "out-of-core tiled driver (DESIGN.md §14): "
                         "datasets stream in chunked, partitions pack into "
                         "memory-budgeted tiles, finished tiles checkpoint "
                         "to --ckpt-dir")
    ap.add_argument("--balance", default="cost",
                    help="tiled driver only: 'cost' (skew-split + "
                         "cost-balanced packing, default) or 'static' "
                         "(uniform grid, partition-order packing)")
    ap.add_argument("--resume", action="store_true",
                    help="tiled driver only: resume from the --ckpt-dir "
                         "completed-tile manifest (skips straight to the "
                         "first unfinished tile; a changed workload or "
                         "config starts fresh)")
    ap.add_argument("--chunk-size", type=int, default=65536,
                    help="tiled driver only: generated objects per "
                         "streamed chunk")
    args = ap.parse_args()
    if args.tile_budget is not None:
        run_tiled_join(args.r, args.s, tile_budget=args.tile_budget,
                       n_order=args.n_order, balance=args.balance,
                       ckpt_dir=args.ckpt_dir, resume=args.resume,
                       count_r=args.count_r, count_s=args.count_s,
                       chunk_size=args.chunk_size, method=args.method,
                       backend=args.filter_backend or "numpy",
                       refine_backend=args.refine_backend,
                       mbr_backend=args.mbr_backend,
                       pipeline_mode=args.pipeline_mode,
                       plan_mode=args.plan_mode)
        return
    run_join(args.r, args.s, n_order=args.n_order, parts=args.parts,
             ckpt_dir=args.ckpt_dir, count_r=args.count_r,
             count_s=args.count_s, method=args.method,
             backend=args.filter_backend or args.backend,
             refine_backend=args.refine_backend,
             mbr_backend=args.mbr_backend,
             build_backend=args.build_backend,
             pipeline_mode=args.pipeline_mode, plan_mode=args.plan_mode)


if __name__ == "__main__":
    main()
