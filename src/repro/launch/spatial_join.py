"""Distributed spatial-join launcher — the paper's system as a service run.

  PYTHONPATH=src python -m repro.launch.spatial_join --r T1 --s T2 \
      --n-order 8 --parts 2 --ckpt-dir /tmp/join_ckpt

Orchestration (DESIGN.md §4): partition the map (§5.2) -> per-partition
APRIL stores -> MBR join per partition -> bucketed pair batches -> sharded
APRIL filter across the device mesh -> batched refinement of the indecisive
remainder. Fault tolerance: per-partition results checkpoint through
CheckpointManager, so a killed run resumes at partition granularity; the
WorkQueue re-leases partitions whose workers stall (straggler mitigation).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import partition as partition_mod
from ..core.april import build_april
from ..core.join import INDECISIVE, TRUE_HIT
from ..datagen import make_dataset
from ..runtime.checkpoint import CheckpointManager
from ..runtime.elastic import WorkQueue
from ..spatial import refine
from ..spatial.distributed import (bucket_pairs, distributed_april_filter,
                                   make_join_mesh)
from ..spatial.mbr_join import mbr_join


def join_partition(R, S, stores_r, stores_s, parting, pidx, mesh):
    """Filter + refine all candidate pairs owned by partition ``pidx``."""
    part = parting.partitions[pidx]
    ridx = part.obj_idx[R.name]
    sidx = part.obj_idx[S.name]
    sr, ss = stores_r[pidx], stores_s[pidx]
    if sr is None or ss is None or len(ridx) == 0 or len(sidx) == 0:
        return np.zeros((0, 2), np.int64), {}

    local_pairs = mbr_join(R.mbrs[ridx], S.mbrs[sidx])
    if len(local_pairs) == 0:
        return np.zeros((0, 2), np.int64), {}
    # ownership: reference point must fall inside this partition's tile
    own = np.asarray([
        partition_mod.reference_partition(
            parting.parts_per_dim, R.mbrs[ridx[i]], S.mbrs[sidx[j]]) == pidx
        for i, j in local_pairs])
    local_pairs = local_pairs[own]
    if len(local_pairs) == 0:
        return np.zeros((0, 2), np.int64), {}

    results = []
    counts = {"true_neg": 0, "true_hit": 0, "indecisive": 0}
    n_dev = int(np.prod(list(mesh.shape.values())))
    for packed in bucket_pairs(sr, ss, local_pairs, n_devices=n_dev):
        verd, c = distributed_april_filter(packed, mesh)
        for k in counts:
            counts[k] += c[k]
        valid = packed.valid
        hits = packed.pair_idx[valid & (verd == TRUE_HIT)]
        indec = packed.pair_idx[valid & (verd == INDECISIVE)]
        if len(indec):
            glob = np.stack([ridx[indec[:, 0]], sidx[indec[:, 1]]], axis=1)
            ref = refine.refine_pairs(R, S, glob)
            results.append(glob[ref])
        if len(hits):
            results.append(np.stack([ridx[hits[:, 0]], sidx[hits[:, 1]]],
                                    axis=1))
    out = (np.concatenate(results, axis=0) if results
           else np.zeros((0, 2), np.int64))
    return out, counts


def run_join(r_name="T1", s_name="T2", n_order=8, parts=2, ckpt_dir=None,
             seed=0, count_r=None, count_s=None, mesh=None):
    R = make_dataset(r_name, seed=seed, count=count_r)
    S = make_dataset(s_name, seed=seed + 1, count=count_s)
    mesh = mesh or make_join_mesh()

    t0 = time.perf_counter()
    parting = partition_mod.partition_space([R, S], parts_per_dim=parts)
    stores_r = parting.build_april(R, n_order)
    stores_s = parting.build_april(S, n_order)
    t_build = time.perf_counter() - t0

    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    done: dict[int, np.ndarray] = {}
    if mgr is not None:
        restored = mgr.restore()
        if restored is not None:
            _, flat, extra = restored
            done = {int(k.split("_")[1]): v for k, v in flat.items()
                    if k.startswith("part_")}
            print(f"[resume] {len(done)} partitions already joined")

    queue = WorkQueue([p for p in range(len(parting)) if p not in done],
                      lease_seconds=600)
    totals = {"true_neg": 0, "true_hit": 0, "indecisive": 0}
    t0 = time.perf_counter()
    while not queue.finished:
        p = queue.acquire()
        if p is None:
            break
        res, counts = join_partition(R, S, stores_r, stores_s, parting, p, mesh)
        done[p] = res
        for k in totals:
            totals[k] += counts.get(k, 0)
        queue.complete(p)
        if mgr is not None:
            mgr.save(len(done), {f"part_{k}": v for k, v in done.items()})
    t_join = time.perf_counter() - t0
    if mgr is not None:
        mgr.wait()

    results = np.concatenate([v for v in done.values() if len(v)], axis=0) \
        if any(len(v) for v in done.values()) else np.zeros((0, 2), np.int64)
    print(f"build {t_build:.2f}s  join {t_join:.2f}s  "
          f"results {len(results)}  filter counts {totals}")
    return results, totals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", default="T1")
    ap.add_argument("--s", default="T2")
    ap.add_argument("--n-order", type=int, default=8)
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--count-r", type=int, default=None)
    ap.add_argument("--count-s", type=int, default=None)
    args = ap.parse_args()
    run_join(args.r, args.s, n_order=args.n_order, parts=args.parts,
             ckpt_dir=args.ckpt_dir, count_r=args.count_r,
             count_s=args.count_s)


if __name__ == "__main__":
    main()
