"""Serving launcher: batched greedy decoding over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --requests 12

Continuous-batching-lite: a fixed pool of B decode slots; finished or empty
slots are refilled from the queue each step (one jit'd decode_step serves
the whole pool; per-slot positions). Demonstrates the serve_step the decode
dry-run shapes lower, with slot-level fault tolerance (a poisoned request
cannot take down the pool — it is evicted and logged).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.model import build_caches, init_model, set_cache_pos
from ..models.serve import make_decode_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServePool:
    """Fixed-size decode pool with slot refill (continuous batching)."""

    def __init__(self, cfg, params, batch_slots: int, ctx_len: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.ctx = ctx_len
        self.caches = build_caches(cfg, batch_slots, ctx_len, dtype=dtype)
        self.decode = jax.jit(make_decode_step(cfg))
        self.slots: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)   # tokens consumed
        self.slot_tok = np.zeros(batch_slots, np.int32)   # next input token
        self.extra = {}

    def _refill(self, queue: list[Request]):
        for b in range(self.B):
            if self.slots[b] is None and queue:
                req = queue.pop(0)
                self.slots[b] = req
                self.slot_pos[b] = 0
                self.slot_tok[b] = int(req.prompt[0])
                # a fresh slot must not see the previous request's cache:
                # recurrent states are zeroed, kv slots are masked by pos
                self._reset_slot_state(b)

    def _reset_slot_state(self, b: int):
        """Zero slot b's recurrent states (h/conv). KV cache rows need no
        reset: positions beyond `pos` are masked by the decode attention."""
        def zero(path, leaf):
            names = [str(getattr(k, "key", k)) for k in path]
            if names[-1] not in ("h", "conv"):
                return leaf
            if "cycle" in names:          # stacked [n_cycles, B, ...]
                return leaf.at[:, b].set(0)
            return leaf.at[b].set(0)      # tail [B, ...]
        self.caches = jax.tree_util.tree_map_with_path(zero, self.caches)

    def step(self):
        """One decode step for every active slot (single jit call); each
        slot decodes at its OWN position (vectorized pos plumbing)."""
        batch = {"tokens": jnp.asarray(self.slot_tok[:, None]),
                 "pos": jnp.asarray(self.slot_pos, jnp.int32), **self.extra}
        logits, self.caches = self.decode(self.params, self.caches, batch)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.slot_pos[b]) + 1
            self.slot_pos[b] = p
            if p < len(req.prompt):
                self.slot_tok[b] = int(req.prompt[p])      # teacher-forced
            else:
                tok = int(nxt[b])
                req.out.append(tok)
                self.slot_tok[b] = tok
                if len(req.out) >= req.max_new or p >= self.ctx - 1:
                    req.done = True
                    self.slots[b] = None

    def run(self, requests: list[Request], deadline_s: float = 120.0):
        queue = list(requests)
        t0 = time.time()
        served = []
        while (queue or any(s is not None for s in self.slots)) \
                and time.time() - t0 < deadline_s:
            self._refill(queue)
            try:
                self.step()
            except Exception as e:           # slot-level fault tolerance
                bad = [b for b, s in enumerate(self.slots) if s is not None]
                print(f"[evict] decode error {e!r}; evicting slots {bad}")
                for b in bad:
                    self.slots[b] = None
            served = [r for r in requests if r.done]
        return served


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(4, 10)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    pool = ServePool(cfg, params, args.slots, ctx_len=64)
    t0 = time.time()
    done = pool.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / max(dt, 1e-9):.1f} tok/s, "
          f"{args.slots} slots)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
