"""Training launcher: end-to-end driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised here (and tested in tests/test_fault_tolerance.py):
  * synthetic-corpus data pipeline with a deterministic, checkpointable
    cursor (restart-safe: byte-identical batch sequence after resume);
  * CheckpointManager auto-resume (params + optimizer + data cursor);
  * --fail-at-step N injects a crash to demonstrate restart;
  * straggler detection via StragglerMonitor;
  * mesh-sharded execution when more than one device is present.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..models.model import init_model
from ..models.sharding import (data_axes, make_activation_hook,
                               named_sharding_tree, opt_state_specs,
                               param_specs)
from ..models.train import make_train_step
from ..optim.adamw import adamw_init
from ..runtime.checkpoint import CheckpointManager
from ..runtime.elastic import StragglerMonitor


class SyntheticCorpus:
    """Deterministic token stream with a restorable cursor."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.cursor = 0

    def next_batch(self, cfg=None):
        rng = np.random.default_rng((self.seed, self.cursor))
        # learnable structure: noisy affine next-token rule (a model that
        # trains must drive the loss well below log(vocab))
        B, S, V = self.batch, self.seq, self.vocab
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, S)) < 0.1
        rand = rng.integers(0, V, (B, S))
        for t in range(S):
            nxt = (toks[:, t] * 31 + 17) % V
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        self.cursor += 1
        out = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg is not None and cfg.encoder is not None:
            fr = rng.normal(size=(self.batch, cfg.encoder.n_frames,
                                  cfg.d_model)) * 0.02
            out["frames"] = jnp.asarray(fr, jnp.float32)
        elif cfg is not None and cfg.n_patch_tokens:
            pt = rng.normal(size=(self.batch, cfg.n_patch_tokens,
                                  cfg.d_model)) * 0.02
            out["patches"] = jnp.asarray(pt, jnp.float32)
        return out

    def state(self):
        return {"cursor": np.asarray(self.cursor)}

    def load_state(self, st):
        self.cursor = int(st["cursor"])


def train_loop(arch: str, *, smoke=True, steps=20, batch=4, seq=64,
               ckpt_dir=None, ckpt_every=10, fail_at_step=None, lr=1e-3,
               mesh=None, log_every=5, remat="dots"):
    cfg = get_config(arch, smoke=smoke)
    data = SyntheticCorpus(cfg.vocab, batch, seq)

    params = init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt = adamw_init(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_tree({"params": params, "opt": opt,
                                     "data": data.state()})
        if restored is not None:
            start_step, tree, _ = restored
            params, opt = tree["params"], tree["opt"]
            data.load_state(tree["data"])
            print(f"[resume] restored checkpoint at step {start_step}")

    hook = None
    if mesh is not None:
        hook = make_activation_hook(mesh, sequence_parallel=False)
        ns_p = named_sharding_tree(mesh, param_specs(params, mesh))
        ns_o = named_sharding_tree(mesh, opt_state_specs(params, mesh))
        params = jax.device_put(params, ns_p)
        opt = jax.device_put(opt, ns_o)

    step_fn = jax.jit(make_train_step(cfg, lr=lr, remat_policy=remat,
                                      activation_hook=hook))
    mon = StragglerMonitor()
    losses = []
    try:
        for step in range(start_step, steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            b = data.next_batch(cfg)
            if mesh is not None:
                d = data_axes(mesh)
                d = d if len(d) > 1 else d[0]
                b = {k: jax.device_put(v, NamedSharding(
                    mesh, P(*((d,) + (None,) * (v.ndim - 1)))))
                    for k, v in b.items()}
            mon.start()
            params, opt, metrics = step_fn(params, opt, b)
            loss = float(metrics["loss"])
            slow = mon.stop()
            losses.append(loss)
            if step % log_every == 0 or slow:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"{'[straggler]' if slow else ''}")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt,
                                    "data": data.state()})
    finally:
        # flush any in-flight async checkpoint, even on a crash — the last
        # committed checkpoint must be durable before the process exits
        if mgr is not None:
            mgr.wait()
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt, "data": data.state()},
                 block=True)
        mgr.wait()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    t0 = time.time()
    _, _, losses = train_loop(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at_step=args.fail_at_step, lr=args.lr)
    print(f"done in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
