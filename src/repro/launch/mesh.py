"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int, n_model: int):
    """Small mesh for tests on forced-host-device backends."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
