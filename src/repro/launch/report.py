"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(dir_: str) -> list[dict]:
    out = []
    for fn in sorted(os.listdir(dir_)):
        if fn.endswith(".json"):
            out.append(json.load(open(os.path.join(dir_, fn))))
    return out


def fmt_t(x: float) -> str:
    return f"{x * 1e3:.2f}ms" if x < 10 else f"{x:.2f}s"


def roofline_table(cells: list[dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
            "MODEL/HLO | roofline frac | mem/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped: {c['skipped'][:40]}… | — | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_t(c['t_compute'])} | "
            f"{fmt_t(c['t_memory'])} | {fmt_t(c['t_collective'])} | "
            f"{c['bottleneck']} | {c['useful_flops_ratio']:.2f} | "
            f"{c['roofline_fraction']:.3f} | "
            f"{c['memory_per_chip_bytes'] / 2**30:.1f}GiB |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compile | FLOPs/chip | bytes/chip | "
            "coll bytes/chip | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"skip | — | — | — | {c['skipped'][:45]} |")
            continue
        coll = ",".join(f"{k.split('-')[-1][:4]}:{v / 2**20:.0f}M"
                        for k, v in sorted(c.get("coll_breakdown", {}).items()))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{c.get('compile_seconds', 0):.0f}s | "
            f"{c['flops_per_chip']:.2e} | {c['bytes_per_chip']:.2e} | "
            f"{c['coll_bytes_per_chip']:.2e} | {coll} |")
    return "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    cells = load(d)
    print("## Dry-run (all cells)\n")
    print(dryrun_table(cells))
    print("\n## Roofline — single pod (16x16)\n")
    print(roofline_table(cells, "16x16"))
    print("\n## Roofline — multi-pod (2x16x16)\n")
    print(roofline_table(cells, "2x16x16"))


if __name__ == "__main__":
    main()
