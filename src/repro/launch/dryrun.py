import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against abstract inputs (ShapeDtypeStruct — no allocation), then
extract memory_analysis / cost_analysis / collective bytes for §Roofline.

MUST be run as its own process (the XLA_FLAGS line above pins the 512
placeholder devices before jax initializes). Results land as one JSON per
cell under --out, so the sweep is resumable (crashed/killed runs keep
completed cells).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --arch april_join --mesh multi   # paper system
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config, input_specs, shape_skip_reason
from ..models.model import init_model
from ..models.serve import make_decode_step, make_prefill_step
from ..models.sharding import (cache_specs, data_axes, make_activation_hook,
                               named_sharding_tree, opt_state_specs,
                               param_specs)
from ..models.train import make_train_step
from ..optim.adamw import adamw_init
from .mesh import make_production_mesh
from .roofline import RooflineReport, collective_bytes, model_flops

JOIN_SHAPES = {  # paper-system cells: (n_pairs, intervals_per_list)
    "join_256k": (262144, 64),
    "join_1m": (1048576, 32),
}


def _batch_sharding(mesh, specs, cfg):
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    d = daxes if len(daxes) > 1 else daxes[0]
    out = {}
    for k, v in specs.items():
        if k == "caches":
            out[k] = named_sharding_tree(mesh, cache_specs(v, mesh))
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif v.ndim in (2, 3):
            b = d if v.shape[0] % dsize == 0 else None
            out[k] = NamedSharding(mesh, P(*((b,) + (None,) * (v.ndim - 1))))
        else:
            out[k] = NamedSharding(mesh, P())
    return out


def lower_model_cell(arch: str, shape_name: str, multi_pod: bool,
                     sequence_parallel: bool = True, remat: str = "dots",
                     donate: bool = True, probe_cycles: int | None = None,
                     probe_enc_layers: int | None = None,
                     probe_tail: bool = False, cfg=None,
                     zero1_grads: bool = False, sp_prefill: bool = False,
                     replicate_params: bool = False,
                     microbatch: int | None = None,
                     moe_groups: int | None = None):
    """Returns (lowered, cfg, mesh, mode).

    probe_cycles/probe_enc_layers: truncate+unroll the layer loops — used by
    the FLOP-correction probes (XLA cost analysis counts while bodies once;
    see analyze())."""
    import dataclasses
    cfg = cfg or get_config(arch)
    if moe_groups and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=moe_groups))
    unroll = probe_cycles is not None
    if probe_cycles is not None:
        tail = len(cfg.tail_kinds) if probe_tail else 0
        cfg = dataclasses.replace(
            cfg, n_layers=probe_cycles * cfg.pattern_period + tail)
    if probe_enc_layers is not None and cfg.encoder is not None:
        cfg = dataclasses.replace(
            cfg, encoder=dataclasses.replace(cfg.encoder,
                                             n_layers=probe_enc_layers))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = SHAPES[shape_name][2]
    specs = input_specs(cfg, shape_name)

    params_shape = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16))
    if replicate_params:
        # context-parallel serving for small models: weights replicated,
        # BOTH mesh axes shard data/sequence (no TP collectives)
        ns_params = jax.tree.map(
            lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), params_shape)
    else:
        ns_params = named_sharding_tree(mesh, param_specs(params_shape, mesh))
    sp_on = (mode == "train" and sequence_parallel) or \
        (mode == "prefill" and sp_prefill)
    hook = make_activation_hook(mesh, sequence_parallel=sp_on,
                                decode=(mode == "decode"))
    bshard = _batch_sharding(mesh, specs, cfg)

    with mesh:
        if mode == "train":
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            ns_opt = named_sharding_tree(mesh, opt_state_specs(params_shape, mesh))
            step = make_train_step(
                cfg, remat_policy=remat, activation_hook=hook, unroll=unroll,
                grad_shardings=(ns_opt["m"] if zero1_grads else None),
                microbatch=microbatch)
            jitted = jax.jit(
                step,
                in_shardings=(ns_params, ns_opt,
                              {k: bshard[k] for k in specs}),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_shape, opt_shape, specs)
        elif mode == "prefill":
            step = make_prefill_step(cfg, activation_hook=hook, unroll=unroll)
            jitted = jax.jit(step, in_shardings=(ns_params,
                                                 {k: bshard[k] for k in specs}))
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            step = make_decode_step(cfg, activation_hook=hook, unroll=unroll)
            caches_shape = specs.pop("caches")
            ns_caches = bshard.pop("caches")
            jitted = jax.jit(
                step,
                in_shardings=(ns_params, ns_caches,
                              {k: bshard[k] for k in specs}),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_shape, caches_shape, specs)
    return lowered, cfg, mesh, mode


def lower_join_cell(shape_name: str, multi_pod: bool):
    """Lower the paper's distributed APRIL filter on the production mesh."""
    from ..spatial.distributed import april_filter_kernel_jnp
    mesh = make_production_mesh(multi_pod=multi_pod)
    d = data_axes(mesh)
    d = d if len(d) > 1 else d[0]
    B, I = JOIN_SHAPES[shape_name]
    sds = jax.ShapeDtypeStruct
    batch = {k: sds((B, I), jnp.int32)
             for k in ("ra_s", "ra_l", "rf_s", "rf_l",
                       "sa_s", "sa_l", "sf_s", "sf_l")}
    batch.update({k: sds((B,), jnp.int32)
                  for k in ("ra_n", "rf_n", "sa_n", "sf_n")})
    shard = {k: NamedSharding(mesh, P(d) if v.ndim == 1 else P(d, None))
             for k, v in batch.items()}

    def step(b):
        verd = april_filter_kernel_jnp(b)
        counts = jnp.stack([jnp.sum(verd == 0), jnp.sum(verd == 1),
                            jnp.sum(verd == 2)])
        return verd, counts

    with mesh:
        lowered = jax.jit(step, in_shardings=(shard,)).lower(batch)
    return lowered, mesh


def _cell_metrics(compiled) -> dict:
    """(flops, bytes, per-kind collective bytes) of one compiled module."""
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _combine(u1: dict, u2: dict, n: int) -> dict:
    """total = U1 + (n-1)(U2-U1), per metric (clamped at U1)."""
    out = {"flops": max(u1["flops"], u1["flops"] + (n - 1) * (u2["flops"] - u1["flops"])),
           "bytes": max(u1["bytes"], u1["bytes"] + (n - 1) * (u2["bytes"] - u1["bytes"]))}
    coll = {}
    for k in set(u1["coll"]) | set(u2["coll"]):
        a, b = u1["coll"].get(k, 0), u2["coll"].get(k, 0)
        coll[k] = max(a, a + (n - 1) * (b - a))
    out["coll"] = coll
    return out


def probe_metrics(arch, shape_name, multi_pod, cfg=None, **kw) -> dict:
    """Loop-corrected per-chip metrics via unrolled 1/2-cycle probes.

    XLA's HloCostAnalysis counts while-loop bodies ONCE, so the scanned
    full-model lower under-reports FLOPs/bytes/collectives by ~n_cycles.
    Probes with truncated, unrolled stacks give the per-cycle body cost:
    total = U1 + (n_cycles-1)(U2-U1), and likewise for the encoder loop.
    """
    import dataclasses
    base = cfg or get_config(arch)
    kw.pop("probe_cycles", None)

    def probe(d, e, tail=False):
        lowered, pcfg, mesh, mode = lower_model_cell(
            arch, shape_name, multi_pod, probe_cycles=d,
            probe_enc_layers=e, probe_tail=tail, donate=False, cfg=cfg, **kw)
        return _cell_metrics(lowered.compile())

    has_enc = base.encoder is not None
    u11 = probe(1, 1 if has_enc else None)
    u21 = probe(2, 1 if has_enc else None)
    total = _combine(u11, u21, base.n_cycles)
    if base.tail_kinds:
        u1t = probe(1, 1 if has_enc else None, tail=True)
        total = {
            "flops": total["flops"] + (u1t["flops"] - u11["flops"]),
            "bytes": total["bytes"] + (u1t["bytes"] - u11["bytes"]),
            "coll": {k: total["coll"].get(k, 0)
                     + (u1t["coll"].get(k, 0) - u11["coll"].get(k, 0))
                     for k in set(total["coll"]) | set(u1t["coll"])},
        }
    if has_enc:
        u12 = probe(1, 2)
        enc_body = _combine(u11, u12, base.encoder.n_layers)
        # add the encoder's extra (n_enc - 1) bodies on top
        total = {
            "flops": total["flops"] + (enc_body["flops"] - u11["flops"]),
            "bytes": total["bytes"] + (enc_body["bytes"] - u11["bytes"]),
            "coll": {k: total["coll"].get(k, 0)
                     + (enc_body["coll"].get(k, 0) - u11["coll"].get(k, 0))
                     for k in set(total["coll"]) | set(enc_body["coll"])},
        }
    return total


def analyze(lowered, *, arch, shape_name, mesh, cfg=None,
            corrected: dict | None = None) -> dict:
    compiled_t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - compiled_t0

    try:
        mem = compiled.memory_analysis()
        mem_bytes = sum(
            int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"))
        # alias'd (donated) bytes are double-counted in arg+output
        mem_bytes -= int(getattr(mem, "alias_size_in_bytes", 0) or 0) * 2
        mem_detail = {k: int(getattr(mem, k, 0) or 0) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes")}
    except Exception as e:  # pragma: no cover
        mem_bytes, mem_detail = 0, {"error": str(e)}

    raw = _cell_metrics(compiled)
    if corrected is not None:
        flops, bytes_accessed, coll = (corrected["flops"],
                                       corrected["bytes"], corrected["coll"])
    else:
        flops, bytes_accessed, coll = raw["flops"], raw["bytes"], raw["coll"]
    n_chips = int(np.prod(list(mesh.shape.values())))

    mf = model_flops(cfg, shape_name, SHAPES) if cfg is not None else 0.0
    report = RooflineReport(
        arch=arch, shape=shape_name,
        mesh="x".join(str(v) for v in mesh.shape.values()),
        chips=n_chips, flops_per_chip=flops, bytes_per_chip=bytes_accessed,
        coll_bytes_per_chip=float(sum(coll.values())), coll_breakdown=coll,
        model_flops_global=mf, memory_per_chip_bytes=float(mem_bytes),
        compile_seconds=compile_s)
    out = report.to_dict()
    out["memory_detail"] = mem_detail
    out["hlo_collective_ops"] = {k: v for k, v in coll.items()}
    out["raw_scan_metrics"] = raw
    return out


def run_cell(arch, shape_name, multi_pod, out_dir, q_chunk=None, tag="",
             **kw):
    mesh_tag = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_tag}{tag}.json")
    if q_chunk is not None and arch != "april_join":
        import dataclasses
        kw["cfg"] = dataclasses.replace(get_config(arch),
                                        attn_q_chunk=q_chunk)
    if os.path.exists(path):
        print(f"[skip-done] {path}")
        return json.load(open(path))

    if arch == "april_join":
        t0 = time.time()
        lowered, mesh = lower_join_cell(shape_name, multi_pod)
        res = analyze(lowered, arch=arch, shape_name=shape_name, mesh=mesh)
    else:
        cfg = get_config(arch)
        reason = shape_skip_reason(cfg, shape_name)
        if reason:
            res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "skipped": reason}
            json.dump(res, open(path, "w"), indent=1)
            print(f"[skip] {arch} {shape_name} {mesh_tag}: {reason}")
            return res
        t0 = time.time()
        lowered, cfg, mesh, mode = lower_model_cell(
            arch, shape_name, multi_pod, **kw)
        corrected = probe_metrics(arch, shape_name, multi_pod, **kw)
        res = analyze(lowered, arch=arch, shape_name=shape_name, mesh=mesh,
                      cfg=cfg, corrected=corrected)
    res["lower_seconds"] = time.time() - t0 - res.get("compile_seconds", 0)
    json.dump(res, open(path, "w"), indent=1)
    print(f"[ok] {arch} {shape_name} {mesh_tag}: "
          f"flops/chip={res.get('flops_per_chip', 0):.3e} "
          f"coll/chip={res.get('coll_bytes_per_chip', 0):.3e} "
          f"mem/chip={res.get('memory_per_chip_bytes', 0) / 2**30:.2f}GiB "
          f"bottleneck={res.get('bottleneck')} "
          f"compile={res.get('compile_seconds', 0):.1f}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel activation sharding")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--q-chunk", type=int, default=None,
                    help="query-chunked attention (A-interval banding)")
    ap.add_argument("--zero1-grads", action="store_true",
                    help="constrain grads to ZeRO-1 shard layout (RS+AG)")
    ap.add_argument("--sp-prefill", action="store_true",
                    help="sequence-parallel activations in prefill too")
    ap.add_argument("--replicate-params", action="store_true",
                    help="context-parallel serving: replicated weights")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="gradient-accumulation splits per train step")
    ap.add_argument("--moe-groups", type=int, default=None,
                    help="grouped 2D MoE dispatch (set = data-axis size)")
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (hillclimb variants)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.arch == "april_join":
        cells = [("april_join", s) for s in
                 ([args.shape] if args.shape else list(JOIN_SHAPES))]
    elif args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape_name, mp, args.out,
                         sequence_parallel=not args.no_sp, remat=args.remat,
                         q_chunk=args.q_chunk, tag=args.tag,
                         zero1_grads=args.zero1_grads,
                         sp_prefill=args.sp_prefill,
                         replicate_params=args.replicate_params,
                         microbatch=args.microbatch,
                         moe_groups=args.moe_groups)
            except Exception as e:
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"[FAIL] {arch} {shape_name} "
                      f"{'multi' if mp else 'single'}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
