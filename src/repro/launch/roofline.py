"""Roofline term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_chip / HBM_bandwidth
    collective term = collective_bytes_per_chip / ICI_link_bandwidth

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition
module). Collective bytes are parsed from the post-SPMD HLO text: the summed
output sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-chip shapes). Hardware model: TPU v5e-like —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip effective)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def shape_bytes(text: str) -> int:
    """Total bytes of all tensor shapes appearing in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip bytes moved by each collective kind (output-shape sized)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + shape_bytes(shape_txt)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops_global: float = 0.0
    memory_per_chip_bytes: float = 0.0
    compile_seconds: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/dispatch waste meter."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (max of the terms)."""
        t_useful = (self.model_flops_global / self.chips) / PEAK_FLOPS
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_step if t_step else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape_name: str, shapes: dict) -> float:
    """MODEL_FLOPS: 6·N·D for training (N = active params), 2·N·D for a
    forward/prefill pass, 2·N per decoded token."""
    seq, batch, mode = shapes[shape_name]
    n_active = cfg.active_param_count()
    if mode == "train":
        return 6.0 * n_active * seq * batch
    if mode == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch     # decode: one token per sequence
