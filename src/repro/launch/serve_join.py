"""Online spatial-join serving launcher (DESIGN.md §10).

  PYTHONPATH=src python -m repro.launch.serve_join --queries 200

Stands up a long-lived :class:`~repro.spatial.service.JoinService` — warm
device-resident approximation stores behind the LRU store cache, warm MBR
bucket index, micro-batching worker — and drives a seeded simulated
traffic trace into it: a mix of ``selection`` / ``window`` /
``intersects`` / ``within`` queries whose polygons are drawn from a second
synthetic layer over the same map, interleaved with ``insert`` / ``delete``
mutations that exercise the incremental store patches. Reports sustained
queries/sec, p50/p99 latency with the per-stage device-time breakdown
(``t_mbr``/``t_filter``/``t_refine``/``t_sync``), and cache hit/eviction
stats; ``--pipeline-mode fused`` routes every micro-batched group through
the device-resident fused chain (DESIGN.md §12); ``--plan-mode adaptive``
lets the sample-based planner pick each group's method/granularity
(DESIGN.md §13, replanning on mutation drift); ``--ckpt-dir``
periodically persists the stores + mutation log through
:class:`~repro.runtime.checkpoint.CheckpointManager` (and resumes from the
latest step on restart).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..datagen import make_dataset
from ..runtime.checkpoint import CheckpointManager
from ..spatial import JoinService
from ..spatial.filters import available_filters

_PREDICATE_MIX = ("selection", "selection", "window", "intersects", "within")


def make_trace(rng: np.random.Generator, queries, n_requests: int):
    """Seeded request trace: (predicate, query payload) tuples."""
    trace = []
    for _ in range(n_requests):
        pred = _PREDICATE_MIX[rng.integers(len(_PREDICATE_MIX))]
        if pred == "window":
            c = rng.uniform(0.1, 0.9, 2)
            w = rng.uniform(0.02, 0.2, 2)
            payload = (c[0] - w[0], c[1] - w[1], c[0] + w[0], c[1] + w[1])
        else:
            qi = int(rng.integers(len(queries)))
            payload = queries.verts[qi, : queries.nverts[qi]]
        trace.append((pred, payload))
    return trace


def run_serve(dataset: str = "T1", count: int | None = 300,
              query_layer: str = "T2", n_queries: int = 60,
              n_requests: int = 100, method: str = "april",
              n_order: int = 8, filter_backend: str = "numpy",
              mbr_backend: str = "numpy", refine_backend: str = "numpy",
              pipeline_mode: str = "staged", plan_mode: str = "static",
              window_ms: float = 2.0, cache_mb: float = 256.0,
              mutate_every: int = 25, ckpt_dir: str | None = None,
              ckpt_every: int = 50, seed: int = 0,
              background: bool = True) -> dict:
    """Drive ``n_requests`` trace requests through a warm service; returns
    the report dict (queries/sec, latency, cache + service stats)."""
    rng = np.random.default_rng(seed)
    D = make_dataset(dataset, seed=seed, count=count)
    Q = make_dataset(query_layer, seed=seed + 1, count=n_queries)

    svc = None
    mgr = None
    if ckpt_dir is not None:
        mgr = CheckpointManager(ckpt_dir, async_save=False)
        svc = JoinService.restore_checkpoint(
            mgr, window_s=window_ms / 1e3,
            cache_bytes=int(cache_mb * (1 << 20)),
            filter_backend=filter_backend, mbr_backend=mbr_backend,
            refine_backend=refine_backend, pipeline_mode=pipeline_mode,
            plan_mode=plan_mode)
    if svc is None:
        svc = JoinService(method=method, n_order=n_order,
                          window_s=window_ms / 1e3,
                          cache_bytes=int(cache_mb * (1 << 20)),
                          filter_backend=filter_backend,
                          mbr_backend=mbr_backend,
                          refine_backend=refine_backend,
                          pipeline_mode=pipeline_mode, plan_mode=plan_mode)
        svc.register_dataset(dataset, D)

    trace = make_trace(rng, Q, n_requests)
    if background:
        svc.start()
    t0 = time.perf_counter()
    tickets = []
    step = 0
    for i, (pred, payload) in enumerate(trace):
        tickets.append(svc.submit(dataset, pred, payload))
        if mutate_every and (i + 1) % mutate_every == 0:
            # grow-and-shrink: the dataset size stays roughly constant
            qi = int(rng.integers(len(Q)))
            svc.insert(dataset, Q.verts[qi, : Q.nverts[qi]])
            svc.delete(dataset, int(rng.integers(len(svc.dataset(dataset)))))
        if mgr is not None and (i + 1) % ckpt_every == 0:
            step += 1
            svc.save_checkpoint(mgr, step)
        if not background and len(svc._pending) >= 8:
            svc.drain()
    if background:
        svc.stop()
    else:
        svc.drain()
    for t in tickets:
        t.wait(timeout=60.0)
    elapsed = time.perf_counter() - t0
    if mgr is not None:
        step += 1
        svc.save_checkpoint(mgr, step)

    report = {
        "dataset": dataset, "method": method, "n_order": n_order,
        "pipeline_mode": pipeline_mode, "plan_mode": plan_mode,
        "n_requests": n_requests, "elapsed_s": elapsed,
        "queries_per_s": n_requests / max(elapsed, 1e-9),
        "latency": svc.latency_stats(),
        "cache": dict(svc.cache.stats),
        "service": dict(svc.stats),
        "results_total": int(sum(len(t.pairs) for t in tickets)),
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="T1")
    ap.add_argument("--count", type=int, default=300)
    ap.add_argument("--query-layer", default="T2")
    ap.add_argument("--n-queries", type=int, default=60)
    ap.add_argument("--queries", type=int, default=100,
                    help="requests in the simulated traffic trace")
    ap.add_argument("--method", default="april",
                    choices=available_filters())
    ap.add_argument("--n-order", type=int, default=8)
    ap.add_argument("--filter-backend", default="numpy",
                    help="verdict-stage execution path for every batch")
    ap.add_argument("--mbr-backend", default="numpy",
                    help="candidate-generation execution path")
    ap.add_argument("--refine-backend", default="numpy",
                    help="refinement-stage execution path")
    ap.add_argument("--pipeline-mode", default="staged",
                    help="staged (default) or fused: run each micro-batched "
                         "group as one device-resident dispatch chain "
                         "(DESIGN.md §12)")
    ap.add_argument("--plan-mode", default="static",
                    help="static (default) or adaptive: the sample-based "
                         "planner picks each request group's filter "
                         "method/granularity, replanning once mutation "
                         "drift passes the threshold (DESIGN.md §13)")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="micro-batch accumulation window")
    ap.add_argument("--cache-mb", type=float, default=256.0,
                    help="store-cache byte budget (MiB)")
    ap.add_argument("--mutate-every", type=int, default=25,
                    help="insert+delete every N requests (0 disables)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    report = run_serve(
        dataset=args.dataset, count=args.count,
        query_layer=args.query_layer, n_queries=args.n_queries,
        n_requests=args.queries, method=args.method, n_order=args.n_order,
        filter_backend=args.filter_backend, mbr_backend=args.mbr_backend,
        refine_backend=args.refine_backend,
        pipeline_mode=args.pipeline_mode, plan_mode=args.plan_mode,
        window_ms=args.window_ms,
        cache_mb=args.cache_mb, mutate_every=args.mutate_every,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, seed=args.seed)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
