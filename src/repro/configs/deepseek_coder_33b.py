"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19200, vocab=32256, block_pattern=("attn",),
    rope_theta=100000.0, act="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, block_pattern=("attn",), act="swiglu",
)
