"""gemma2-2b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab=256000,
    block_pattern=("local", "attn"), local_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, act="geglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-2b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, block_pattern=("local", "attn"), local_window=16,
    attn_softcap=50.0, logit_softcap=30.0, act="geglu", tie_embeddings=True,
)
