"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
    d_ff=1536, vocab=49152, block_pattern=("attn",), act="swiglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="smollm-135m-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_head=16,
    d_ff=96, vocab=512, block_pattern=("attn",), act="swiglu",
    tie_embeddings=True,
)
