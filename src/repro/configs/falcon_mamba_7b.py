"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free
[arXiv:2410.05355; unverified]."""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_head=64,
    d_ff=0, vocab=65024, block_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    supports_long_context=True,
)

SMOKE_CONFIG = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_head=16,
    d_ff=0, vocab=512, block_pattern=("ssm",),
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    supports_long_context=True,
)
