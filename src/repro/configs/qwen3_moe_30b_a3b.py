"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab=151936, block_pattern=("attn",), act="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=512, block_pattern=("attn",), act="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
)
