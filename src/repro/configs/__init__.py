"""Architecture registry: the 10 assigned configs + the paper's own spatial
join 'architecture' (``april_join``), selectable via --arch <id>."""
from __future__ import annotations

from . import (deepseek_coder_33b, falcon_mamba_7b, gemma2_2b,
               granite_moe_1b_a400m, llama32_vision_11b, qwen3_moe_30b_a3b,
               qwen15_4b, recurrentgemma_2b, smollm_135m, whisper_small)
from .shapes import SHAPES, input_specs, shape_skip_reason  # noqa: F401

ARCHS = {
    "deepseek-coder-33b": deepseek_coder_33b,
    "gemma2-2b": gemma2_2b,
    "qwen1.5-4b": qwen15_4b,
    "smollm-135m": smollm_135m,
    "recurrentgemma-2b": recurrentgemma_2b,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "falcon-mamba-7b": falcon_mamba_7b,
    "whisper-small": whisper_small,
}


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG
