"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155, block_pattern=("attn",), act="swiglu",
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=512, block_pattern=("attn",), act="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
    tie_embeddings=True,
)
