"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

The audio conv frontend is a STUB per the assignment: input_specs() feeds
precomputed frame embeddings [B, 1500, d_model] to the encoder; every
decoder layer cross-attends the encoder output.
"""
from ..models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=51865, block_pattern=("xattn",), act="gelu",
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-small-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512, block_pattern=("xattn",), act="gelu",
    encoder=EncoderConfig(n_layers=2, n_frames=32),
)
