"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_head=128,
    d_ff=6912, vocab=151936, block_pattern=("attn",),
    qkv_bias=True, act="swiglu",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512, block_pattern=("attn",), qkv_bias=True, act="swiglu",
)
