"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2
[arXiv:2402.19427; hf].

26 layers follow Griffin's (R, R, A) blocks: eight scanned (R, R, A)
cycles plus an unscanned (R, R) tail — exactly the released model's layout
(18 recurrent : 8 local-attention layers).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab=256000,
    block_pattern=("rglru", "rglru", "local"), local_window=2048,
    act="geglu", tie_embeddings=True, supports_long_context=True,
)

# n_layers=5 = one scanned cycle + a 2-layer tail: exercises the tail path
SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab=512, block_pattern=("rglru", "rglru", "local"),
    local_window=16, act="geglu", tie_embeddings=True,
    supports_long_context=True,
)
