"""Assigned input shapes and abstract input specs (ShapeDtypeStruct only —
no device allocation; the dry-run lowers against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import build_caches

# name -> (seq_len, global_batch, mode)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    """Why a (arch, shape) cell is skipped, or None if it runs."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: 500k context is quadratic "
                "(run only for SSM/hybrid per assignment)")
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str, dtype=jnp.bfloat16) -> dict:
    """Abstract model inputs for one (arch, shape) cell.

    train: {'tokens', 'labels'} (+ 'frames'/'patches' stubs);
    prefill: {'tokens'} (+ ctx stubs);
    decode: {'tokens' [B,1], 'pos' scalar, 'caches' tree} (+ ctx stubs).
    """
    seq, batch, mode = SHAPES[shape]
    out: dict = {}
    if mode in ("train", "prefill"):
        out["tokens"] = _sds((batch, seq), jnp.int32)
        if mode == "train":
            out["labels"] = _sds((batch, seq), jnp.int32)
    else:
        out["tokens"] = _sds((batch, 1), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
        out["caches"] = jax.eval_shape(
            lambda: build_caches(cfg, batch, seq, dtype=dtype))
    # modality frontends are stubs: precomputed embeddings
    if cfg.encoder is not None:
        out["frames"] = _sds((batch, cfg.encoder.n_frames, cfg.d_model), dtype)
    elif cfg.n_patch_tokens:
        out["patches"] = _sds((batch, cfg.n_patch_tokens, cfg.d_model), dtype)
    return out
