"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40 decoder layers with a cross-attention layer every 5th position; the
vision tower is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, n_patch_tokens, d_model].
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=128256,
    block_pattern=("attn", "attn", "attn", "xattn", "attn"),
    rope_theta=500000.0, act="swiglu", n_patch_tokens=1600,
)

SMOKE_CONFIG = ModelConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
    block_pattern=("attn", "attn", "attn", "xattn", "attn"),
    act="swiglu", n_patch_tokens=16,
)
