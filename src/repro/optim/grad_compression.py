"""Int8 error-feedback gradient compression (1-bit-Adam-family trick).

Data-parallel gradient all-reduces dominate cross-pod ICI traffic at scale.
Quantizing gradients to int8 with a *shared* per-tensor scale + error
feedback (residual carried to the next step) cuts DP all-reduce payloads
2-4x with no convergence loss in practice.

Protocol (inside a shard_map over the DP axis):
  1. s = pmax(max|g + residual|) / 127      (one scalar all-reduce)
  2. q = clip(round((g + residual) / s))    (int8 wire payload)
  3. residual' = (g + residual) - q * s     (error feedback, local)
  4. sum = psum(q) * s                      (int8 per hop on a ring)

The shared scale makes the reduction exact over the quantized values —
summing payloads quantized with per-shard scales is NOT (that bug is what
test_grad_compression_shard_map guards)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree",
           "compressed_psum_ef"]


def quantize_int8(g, scale=None):
    scale = scale if scale is not None else \
        jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residuals):
    """Local error-feedback compress (no collectives): returns
    (quantized tree, scales, new residuals)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        return q, s, gf - dequantize_int8(q, s)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    qs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([x[0] for x in qs]),
            treedef.unflatten([x[1] for x in qs]),
            treedef.unflatten([x[2] for x in qs]))


def compressed_psum_ef(grads, residuals, axis: str):
    """Shared-scale int8 all-reduce with error feedback, for use inside a
    shard_map over the DP ``axis``. Returns (summed f32 tree, new residuals).
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        local_max = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30)
        s = jax.lax.pmax(local_max, axis) / 127.0
        q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * s
        total = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * s
        return total, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
