"""AdamW with f32 moments (ZeRO-1 sharding applied via opt_state_specs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
