"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr=3e-4, warmup=100, total=10_000,
                    min_ratio=0.1):
    t = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, t / warmup)
    prog = jnp.clip((t - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
