from .synthetic import (  # noqa: F401
    PolygonDataset, make_dataset, make_linestrings, DATASET_SPECS
)
