from .synthetic import (  # noqa: F401
    PolygonDataset, make_dataset, make_linestrings, iter_dataset_chunks,
    make_chunked_dataset, DATASET_SPECS
)
