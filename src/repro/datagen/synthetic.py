"""Reproducible synthetic polygon / linestring datasets.

The paper evaluates on TIGER (T1 landmarks, T2 water, T3 counties, T9 states,
T10 zip codes) and OSM continent extracts. Those files are not
redistributable in this container, so we generate seeded synthetic datasets
whose *statistics* mirror Table 4 / Table 14: cardinality ratios, average
vertex counts, and average MBR-area ratios. Polygons are star-shaped (radial)
rings — simple, non-self-intersecting, hole-free, matching the paper's data
cleaning (§7.1 removes multi-polygons, self-intersections, holes).

All geometry lives in the unit square [0,1]^2 (the "map").
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

from ..core import geometry

__all__ = ["PolygonDataset", "make_dataset", "make_linestrings", "DATASET_SPECS"]


@dataclass
class PolygonDataset:
    """Padded polygon collection."""
    name: str
    verts: np.ndarray        # [P, Vmax, 2] float64
    nverts: np.ndarray       # [P] int64
    mbrs: np.ndarray = field(init=False)  # [P, 4]

    def __post_init__(self):
        self.mbrs = geometry.polygon_mbrs(self.verts, self.nverts)

    def __len__(self) -> int:
        return len(self.nverts)

    def polygon(self, i: int) -> np.ndarray:
        return self.verts[i, : self.nverts[i]]


# name -> (count, avg_vertices, avg_radius, radius_jitter)
# Radii are in map units; avg MBR area ~ (2r)^2 tracks the paper's relative
# object-size ordering: T2 < T1 < T10 < T3 < T9 (Table 14).
DATASET_SPECS: dict[str, tuple[int, int, float, float]] = {
    "T1":  (1200, 24, 0.0045, 0.5),    # landmarks: medium-small
    "T2":  (4000, 30, 0.0022, 0.5),    # water: many small simple
    "T3":  (64, 220, 0.085, 0.35),     # counties: few large complex
    "T9":  (12, 380, 0.28, 0.25),      # states: very few, huge
    "T10": (300, 90, 0.030, 0.4),      # zip codes
    "O5":  (1500, 40, 0.0065, 0.5),    # OSM lakes-like
    "O6":  (2500, 36, 0.0050, 0.5),    # OSM parks-like
}


def _star_polygon(rng: np.random.Generator, center, radius, nv, jitter):
    """Simple star-shaped ring: sorted angles + jittered radii."""
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=nv))
    # Avoid near-duplicate angles (degenerate edges)
    angles += np.linspace(0, 1e-4, nv)
    radii = radius * (1.0 + jitter * rng.uniform(-1.0, 1.0, size=nv))
    radii = np.maximum(radii, 0.15 * radius)
    pts = np.stack([
        center[0] + radii * np.cos(angles),
        center[1] + radii * np.sin(angles),
    ], axis=1)
    return np.clip(pts, 1e-6, 1.0 - 1e-6)


def make_dataset(
    name: str, seed: int = 0, count: int | None = None,
    avg_vertices: int | None = None, avg_radius: float | None = None,
    map_seed: int = 0,
) -> PolygonDataset:
    """Build a seeded dataset. ``name`` picks a spec from DATASET_SPECS
    (unknown names get default medium stats); overrides are optional.

    ``map_seed`` fixes the *geography* (cluster centers) independently of the
    dataset, so different layers built over the same map co-locate and joins
    between them produce realistic candidate densities — as with the paper's
    TIGER/OSM layers that all cover the same region.
    """
    spec = DATASET_SPECS.get(name, (1000, 30, 0.005, 0.5))
    cnt = count if count is not None else spec[0]
    nv_avg = avg_vertices if avg_vertices is not None else spec[1]
    rad = avg_radius if avg_radius is not None else spec[2]
    jitter = spec[3]
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))

    nvs = np.clip(
        rng.poisson(nv_avg, size=cnt), 4, None
    ).astype(np.int64)
    vmax = int(nvs.max())
    verts = np.zeros((cnt, vmax, 2), dtype=np.float64)
    # Shared cluster centers: realistic spatial skew + cross-layer overlap.
    map_rng = np.random.default_rng(map_seed)
    n_clusters = 16
    cl_centers = map_rng.uniform(0.1, 0.9, size=(n_clusters, 2))
    cl_idx = rng.integers(0, n_clusters, size=cnt)
    for i in range(cnt):
        r = rad * np.exp(rng.normal(0.0, 0.45))
        spread = max(0.008, 2.5 * rad)
        c = np.clip(cl_centers[cl_idx[i]] + rng.normal(0, spread, 2),
                    r + 1e-4, 1 - r - 1e-4)
        pts = _star_polygon(rng, c, r, int(nvs[i]), jitter)
        verts[i, : nvs[i]] = pts
    return PolygonDataset(name=name, verts=verts, nverts=nvs)


def make_linestrings(
    name: str = "T8", seed: int = 0, count: int = 2000, avg_vertices: int = 20,
    step: float = 0.004,
) -> PolygonDataset:
    """Random-walk linestrings (roads/rivers-like). Reuses PolygonDataset
    storage; rings are NOT closed — callers must treat these as open chains."""
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    nvs = np.clip(rng.poisson(avg_vertices, size=count), 2, None).astype(np.int64)
    vmax = int(nvs.max())
    verts = np.zeros((count, vmax, 2), dtype=np.float64)
    for i in range(count):
        start = rng.uniform(0.05, 0.95, size=2)
        heading = rng.uniform(0, 2 * np.pi)
        pts = [start]
        for _ in range(int(nvs[i]) - 1):
            heading += rng.normal(0, 0.6)
            nxt = pts[-1] + step * np.array([np.cos(heading), np.sin(heading)])
            pts.append(np.clip(nxt, 1e-6, 1 - 1e-6))
        verts[i, : nvs[i]] = np.asarray(pts)
    return PolygonDataset(name=name, verts=verts, nverts=nvs)
