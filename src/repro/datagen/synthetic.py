"""Reproducible synthetic polygon / linestring datasets.

The paper evaluates on TIGER (T1 landmarks, T2 water, T3 counties, T9 states,
T10 zip codes) and OSM continent extracts. Those files are not
redistributable in this container, so we generate seeded synthetic datasets
whose *statistics* mirror Table 4 / Table 14: cardinality ratios, average
vertex counts, and average MBR-area ratios. Polygons are star-shaped (radial)
rings — simple, non-self-intersecting, hole-free, matching the paper's data
cleaning (§7.1 removes multi-polygons, self-intersections, holes).

All geometry lives in the unit square [0,1]^2 (the "map").

Two generation scales (both seeded, both deterministic):

* :func:`make_dataset` — the original per-polygon loop; fine up to a few
  thousand objects (tests, benches at paper scale).
* :func:`iter_dataset_chunks` — the out-of-core generator behind the §14
  tiled scale-out driver: polygons are produced in fixed-size **chunks**,
  each chunk built by ONE vectorized pass (no per-polygon Python loop), so
  multi-million-polygon workloads stream through bounded host memory.
  Chunk ``ci`` is a pure function of ``(name, seed, ci)`` — chunks can be
  regenerated independently and in any order, which is what the streaming
  partitioner and the checkpoint-resume path rely on.
  :func:`make_chunked_dataset` concatenates the chunks into one in-memory
  dataset — the identity reference the tiled driver is tested against.

Batching contract (DESIGN.md §6/§14): every entry point returns (or yields)
:class:`PolygonDataset` — padded ``[P, Vmax, 2]`` vertex arrays with a
``nverts`` mask and precomputed MBRs — the dataset-batched input shape of
every pipeline stage.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from ..core import geometry

__all__ = ["PolygonDataset", "make_dataset", "make_linestrings",
           "iter_dataset_chunks", "make_chunked_dataset", "DATASET_SPECS"]


@dataclass
class PolygonDataset:
    """Padded polygon collection."""
    name: str
    verts: np.ndarray        # [P, Vmax, 2] float64
    nverts: np.ndarray       # [P] int64
    mbrs: np.ndarray = field(init=False)  # [P, 4]

    def __post_init__(self):
        self.mbrs = geometry.polygon_mbrs(self.verts, self.nverts)

    def __len__(self) -> int:
        return len(self.nverts)

    def polygon(self, i: int) -> np.ndarray:
        return self.verts[i, : self.nverts[i]]


# name -> (count, avg_vertices, avg_radius, radius_jitter)
# Radii are in map units; avg MBR area ~ (2r)^2 tracks the paper's relative
# object-size ordering: T2 < T1 < T10 < T3 < T9 (Table 14).
DATASET_SPECS: dict[str, tuple[int, int, float, float]] = {
    "T1":  (1200, 24, 0.0045, 0.5),    # landmarks: medium-small
    "T2":  (4000, 30, 0.0022, 0.5),    # water: many small simple
    "T3":  (64, 220, 0.085, 0.35),     # counties: few large complex
    "T9":  (12, 380, 0.28, 0.25),      # states: very few, huge
    "T10": (300, 90, 0.030, 0.4),      # zip codes
    "O5":  (1500, 40, 0.0065, 0.5),    # OSM lakes-like
    "O6":  (2500, 36, 0.0050, 0.5),    # OSM parks-like
}


def _star_polygon(rng: np.random.Generator, center, radius, nv, jitter):
    """Simple star-shaped ring: sorted angles + jittered radii."""
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=nv))
    # Avoid near-duplicate angles (degenerate edges)
    angles += np.linspace(0, 1e-4, nv)
    radii = radius * (1.0 + jitter * rng.uniform(-1.0, 1.0, size=nv))
    radii = np.maximum(radii, 0.15 * radius)
    pts = np.stack([
        center[0] + radii * np.cos(angles),
        center[1] + radii * np.sin(angles),
    ], axis=1)
    return np.clip(pts, 1e-6, 1.0 - 1e-6)


def make_dataset(
    name: str, seed: int = 0, count: int | None = None,
    avg_vertices: int | None = None, avg_radius: float | None = None,
    map_seed: int = 0,
) -> PolygonDataset:
    """Build a seeded dataset. ``name`` picks a spec from DATASET_SPECS
    (unknown names get default medium stats); overrides are optional.

    ``map_seed`` fixes the *geography* (cluster centers) independently of the
    dataset, so different layers built over the same map co-locate and joins
    between them produce realistic candidate densities — as with the paper's
    TIGER/OSM layers that all cover the same region.
    """
    spec = DATASET_SPECS.get(name, (1000, 30, 0.005, 0.5))
    cnt = count if count is not None else spec[0]
    nv_avg = avg_vertices if avg_vertices is not None else spec[1]
    rad = avg_radius if avg_radius is not None else spec[2]
    jitter = spec[3]
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))

    nvs = np.clip(
        rng.poisson(nv_avg, size=cnt), 4, None
    ).astype(np.int64)
    vmax = int(nvs.max())
    verts = np.zeros((cnt, vmax, 2), dtype=np.float64)
    # Shared cluster centers: realistic spatial skew + cross-layer overlap.
    map_rng = np.random.default_rng(map_seed)
    n_clusters = 16
    cl_centers = map_rng.uniform(0.1, 0.9, size=(n_clusters, 2))
    cl_idx = rng.integers(0, n_clusters, size=cnt)
    for i in range(cnt):
        r = rad * np.exp(rng.normal(0.0, 0.45))
        spread = max(0.008, 2.5 * rad)
        c = np.clip(cl_centers[cl_idx[i]] + rng.normal(0, spread, 2),
                    r + 1e-4, 1 - r - 1e-4)
        pts = _star_polygon(rng, c, r, int(nvs[i]), jitter)
        verts[i, : nvs[i]] = pts
    return PolygonDataset(name=name, verts=verts, nverts=nvs)


def _star_polygons_chunk(rng: np.random.Generator, centers: np.ndarray,
                         radii: np.ndarray, nvs: np.ndarray,
                         jitter: float) -> np.ndarray:
    """One vectorized pass over a whole chunk of star polygons: sorted
    jittered angles + jittered radii, padding rows zeroed. The chunk twin
    of :func:`_star_polygon` (same construction, batched RNG draws — chunk
    streams are seeded independently of the per-polygon loop)."""
    n, vmax = len(nvs), int(nvs.max())
    mask = np.arange(vmax)[None, :] < nvs[:, None]
    angles = rng.uniform(0.0, 2 * np.pi, size=(n, vmax))
    # padding sorts to the row tail (inf), then drops out via the mask
    angles = np.sort(np.where(mask, angles, np.inf), axis=1)
    angles = np.where(mask, angles, 0.0)
    angles += np.linspace(0, 1e-4, vmax)[None, :]   # no degenerate edges
    rad = radii[:, None] * (1.0 + jitter * rng.uniform(-1.0, 1.0,
                                                       size=(n, vmax)))
    rad = np.maximum(rad, 0.15 * radii[:, None])
    pts = centers[:, None, :] + np.stack(
        [rad * np.cos(angles), rad * np.sin(angles)], axis=-1)
    pts = np.clip(pts, 1e-6, 1.0 - 1e-6)
    return np.where(mask[..., None], pts, 0.0)


def iter_dataset_chunks(
    name: str, seed: int = 0, count: int | None = None,
    chunk_size: int = 65536, avg_vertices: int | None = None,
    avg_radius: float | None = None, map_seed: int = 0,
) -> Iterator[PolygonDataset]:
    """Stream a dataset as fixed-size chunks (the §14 out-of-core source).

    Chunk ``ci`` is generated by one vectorized pass from an rng seeded on
    ``(name, seed, ci)`` — deterministic, order-independent, and O(chunk)
    host memory regardless of ``count``, so multi-million-polygon workloads
    never materialize in full. Statistics (cluster skew, vertex counts,
    radius distribution) match :func:`make_dataset`'s spec table; the
    *stream* is its own seeded universe, not a re-chunking of the
    per-polygon loop. ``make_chunked_dataset`` is the in-memory
    concatenation used as the tiled driver's identity reference.
    """
    spec = DATASET_SPECS.get(name, (1000, 30, 0.005, 0.5))
    cnt = count if count is not None else spec[0]
    nv_avg = avg_vertices if avg_vertices is not None else spec[1]
    rad = avg_radius if avg_radius is not None else spec[2]
    jitter = spec[3]
    map_rng = np.random.default_rng(map_seed)
    n_clusters = 16
    cl_centers = map_rng.uniform(0.1, 0.9, size=(n_clusters, 2))

    for ci, start in enumerate(range(0, cnt, chunk_size)):
        m = min(chunk_size, cnt - start)
        rng = np.random.default_rng(
            zlib.crc32(f"{name}:{seed}:chunk:{ci}".encode()))
        nvs = np.clip(rng.poisson(nv_avg, size=m), 4, None).astype(np.int64)
        radii = rad * np.exp(rng.normal(0.0, 0.45, size=m))
        spread = max(0.008, 2.5 * rad)
        cl_idx = rng.integers(0, n_clusters, size=m)
        centers = cl_centers[cl_idx] + rng.normal(0, spread, size=(m, 2))
        centers = np.clip(centers, radii[:, None] + 1e-4,
                          1.0 - radii[:, None] - 1e-4)
        verts = _star_polygons_chunk(rng, centers, radii, nvs, jitter)
        yield PolygonDataset(name=name, verts=verts, nverts=nvs)


def make_chunked_dataset(
    name: str, seed: int = 0, count: int | None = None,
    chunk_size: int = 65536, avg_vertices: int | None = None,
    avg_radius: float | None = None, map_seed: int = 0,
) -> PolygonDataset:
    """Concatenate :func:`iter_dataset_chunks` into one in-memory dataset
    (padded to the global Vmax). Object ``i`` here carries the same global
    id ``i`` the streaming driver assigns (chunk start + local index) — the
    identity reference for the tiled scale-out tests."""
    chunks = list(iter_dataset_chunks(
        name, seed=seed, count=count, chunk_size=chunk_size,
        avg_vertices=avg_vertices, avg_radius=avg_radius,
        map_seed=map_seed))
    vmax = max(int(c.verts.shape[1]) for c in chunks)
    verts = np.concatenate([
        np.pad(c.verts, ((0, 0), (0, vmax - c.verts.shape[1]), (0, 0)))
        for c in chunks], axis=0)
    nvs = np.concatenate([c.nverts for c in chunks])
    return PolygonDataset(name=name, verts=verts, nverts=nvs)


def make_linestrings(
    name: str = "T8", seed: int = 0, count: int = 2000, avg_vertices: int = 20,
    step: float = 0.004,
) -> PolygonDataset:
    """Random-walk linestrings (roads/rivers-like). Reuses PolygonDataset
    storage; rings are NOT closed — callers must treat these as open chains."""
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    nvs = np.clip(rng.poisson(avg_vertices, size=count), 2, None).astype(np.int64)
    vmax = int(nvs.max())
    verts = np.zeros((count, vmax, 2), dtype=np.float64)
    for i in range(count):
        start = rng.uniform(0.05, 0.95, size=2)
        heading = rng.uniform(0, 2 * np.pi)
        pts = [start]
        for _ in range(int(nvs[i]) - 1):
            heading += rng.normal(0, 0.6)
            nxt = pts[-1] + step * np.array([np.cos(heading), np.sin(heading)])
            pts.append(np.clip(nxt, 1e-6, 1 - 1e-6))
        verts[i, : nvs[i]] = np.asarray(pts)
    return PolygonDataset(name=name, verts=verts, nverts=nvs)
