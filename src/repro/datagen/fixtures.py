"""Boundary-touch regression geometry (ISSUE 3), shared by tests and the
CI refinement smoke so the float literals cannot drift apart.

``SNAPPED_TRI`` / ``SNAPPED_HOST``: a triangle whose first vertex was
snapped onto a diagonal edge of the host polygon (found by exact-rational
search) — the segment sweep sees no crossing and the old first-vertex
crossing-parity fallback classified the snapped vertex outside, a false
negative on touching containment; the exact truth on the stored floats is
True.

``CSHAPE`` / ``CSHAPE_INNER``: a concave C-shaped container whose vertex
centroid lies in the cavity, and an inner triangle with one vertex exactly
on the container boundary — the old nudge-toward-centroid within fallback
pushed the vertex out of the polygon, a false negative on touching within.
"""
from __future__ import annotations

import numpy as np

__all__ = ["SNAPPED_TRI", "SNAPPED_HOST", "CSHAPE", "CSHAPE_INNER"]

SNAPPED_TRI = np.array([
    [0.52826315, 0.22223645],
    [0.53367238, 0.30697867],
    [0.50589603, 0.30415236],
])

SNAPPED_HOST = np.array([
    [0.876275, 0.5392158],
    [0.84509312, 0.59085845],
    [0.47389812, 0.7088683],
    [0.14926845, 0.4013808],
    [0.33066059, 0.36583674],
    [0.45614802, 0.16149059],
    [0.59354244, 0.27722416],
    [0.81183718, 0.30959406],
])

CSHAPE = np.array([
    [0., 0.], [10., 0.], [10., 2.], [2., 2.],
    [2., 8.], [10., 8.], [10., 10.], [0., 10.],
])

CSHAPE_INNER = np.array([[6., 2.], [7., .5], [5., .5]])
