"""jit'd public wrapper: pads to kernel tile multiples and dispatches."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .interval_join import april_trichotomy_pallas, interval_overlap_pallas

I32_MAX = np.iinfo(np.int32).max


def _pad_axis(a, axis, mult, fill):
    size = a.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(a, pad, constant_values=fill)


@partial(jax.jit, static_argnames=("interpret", "block_b", "block_j"))
def batch_interval_overlap(xs, xl, nx, ys, yl, ny, *, interpret: bool = False,
                           block_b: int = 8, block_j: int = 128):
    """Overlap verdicts [B] bool for padded interval batches (any I/J/B)."""
    xs = _pad_axis(jnp.asarray(xs, jnp.int32), 1, 128, I32_MAX)
    xl = _pad_axis(jnp.asarray(xl, jnp.int32), 1, 128, I32_MAX)
    ys = _pad_axis(jnp.asarray(ys, jnp.int32), 1, block_j, I32_MAX)
    yl = _pad_axis(jnp.asarray(yl, jnp.int32), 1, block_j, I32_MAX)
    B = xs.shape[0]
    xs = _pad_axis(xs, 0, block_b, I32_MAX)
    xl = _pad_axis(xl, 0, block_b, I32_MAX)
    ys = _pad_axis(ys, 0, block_b, I32_MAX)
    yl = _pad_axis(yl, 0, block_b, I32_MAX)
    nx = _pad_axis(jnp.asarray(nx, jnp.int32), 0, block_b, 0)
    ny = _pad_axis(jnp.asarray(ny, jnp.int32), 0, block_b, 0)
    out = interval_overlap_pallas(xs, xl, nx, ys, yl, ny,
                                  block_b=block_b, block_j=block_j,
                                  interpret=interpret)
    return out[:B]


@partial(jax.jit, static_argnames=("interpret", "block_b"))
def _trichotomy_jit(nra, nrf, nsa, nsf, mats, *, interpret, block_b):
    padded = []
    for s, l in mats:
        padded.append((_pad_axis(_pad_axis(jnp.asarray(s, jnp.int32), 1, 128,
                                           I32_MAX), 0, block_b, I32_MAX),
                       _pad_axis(_pad_axis(jnp.asarray(l, jnp.int32), 1, 128,
                                           I32_MAX), 0, block_b, I32_MAX)))
    counts = [_pad_axis(jnp.asarray(n, jnp.int32), 0, block_b, 0)
              for n in (nra, nrf, nsa, nsf)]
    flat = [a for pair in padded for a in pair]
    return april_trichotomy_pallas(*counts, *flat, block_b=block_b,
                                   interpret=interpret)


def batch_april_trichotomy(ras, ral, nra, rfs, rfl, nrf,
                           sas, sal, nsa, sfs, sfl, nsf, *,
                           interpret: bool = False,
                           block_b: int = 8) -> np.ndarray:
    """Fused three-join verdicts [B] int8 for padded A/F batches (any
    widths/B; pads to kernel tile multiples and dispatches)."""
    B = ras.shape[0]
    out = _trichotomy_jit(nra, nrf, nsa, nsf,
                          ((ras, ral), (rfs, rfl), (sas, sal), (sfs, sfl)),
                          interpret=interpret, block_b=block_b)
    return np.asarray(out[:B]).astype(np.int8)
