"""jit'd public wrapper: pads to kernel tile multiples and dispatches."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .interval_join import interval_overlap_pallas

I32_MAX = np.iinfo(np.int32).max


def _pad_axis(a, axis, mult, fill):
    size = a.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(a, pad, constant_values=fill)


@partial(jax.jit, static_argnames=("interpret", "block_b", "block_j"))
def batch_interval_overlap(xs, xl, nx, ys, yl, ny, *, interpret: bool = False,
                           block_b: int = 8, block_j: int = 128):
    """Overlap verdicts [B] bool for padded interval batches (any I/J/B)."""
    xs = _pad_axis(jnp.asarray(xs, jnp.int32), 1, 128, I32_MAX)
    xl = _pad_axis(jnp.asarray(xl, jnp.int32), 1, 128, I32_MAX)
    ys = _pad_axis(jnp.asarray(ys, jnp.int32), 1, block_j, I32_MAX)
    yl = _pad_axis(jnp.asarray(yl, jnp.int32), 1, block_j, I32_MAX)
    B = xs.shape[0]
    xs = _pad_axis(xs, 0, block_b, I32_MAX)
    xl = _pad_axis(xl, 0, block_b, I32_MAX)
    ys = _pad_axis(ys, 0, block_b, I32_MAX)
    yl = _pad_axis(yl, 0, block_b, I32_MAX)
    nx = _pad_axis(jnp.asarray(nx, jnp.int32), 0, block_b, 0)
    ny = _pad_axis(jnp.asarray(ny, jnp.int32), 0, block_b, 0)
    out = interval_overlap_pallas(xs, xl, nx, ys, yl, ny,
                                  block_b=block_b, block_j=block_j,
                                  interpret=interpret)
    return out[:B]
