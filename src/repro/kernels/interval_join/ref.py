"""Pure-jnp oracle for the interval-overlap kernel."""
from __future__ import annotations

import jax.numpy as jnp


def interval_overlap_ref(xs, xl, nx, ys, yl, ny):
    """Same contract as interval_overlap_pallas, dense jnp evaluation."""
    B, I = xs.shape
    J = ys.shape[1]
    ovl = (ys[:, None, :] <= xl[:, :, None]) & (xs[:, :, None] <= yl[:, None, :])
    ii = jnp.arange(I, dtype=jnp.int32)[None, :, None]
    jj = jnp.arange(J, dtype=jnp.int32)[None, None, :]
    valid = (ii < nx[:, None, None]) & (jj < ny[:, None, None])
    return jnp.any(ovl & valid, axis=(1, 2))


def april_trichotomy_ref(nra, nrf, nsa, nsf, ras, ral, rfs, rfl,
                         sas, sal, sfs, sfl):
    """Same contract as april_trichotomy_pallas, dense jnp evaluation."""
    aa = interval_overlap_ref(ras, ral, nra, sas, sal, nsa)
    af = interval_overlap_ref(ras, ral, nra, sfs, sfl, nsf)
    fa = interval_overlap_ref(rfs, rfl, nrf, sas, sal, nsa)
    return jnp.where(~aa, 0, jnp.where(af | fa, 1, 2)).astype(jnp.int32)
