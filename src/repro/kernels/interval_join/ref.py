"""Pure-jnp oracle for the interval-overlap kernel."""
from __future__ import annotations

import jax.numpy as jnp


def interval_overlap_ref(xs, xl, nx, ys, yl, ny):
    """Same contract as interval_overlap_pallas, dense jnp evaluation."""
    B, I = xs.shape
    J = ys.shape[1]
    ovl = (ys[:, None, :] <= xl[:, :, None]) & (xs[:, :, None] <= yl[:, None, :])
    ii = jnp.arange(I, dtype=jnp.int32)[None, :, None]
    jj = jnp.arange(J, dtype=jnp.int32)[None, None, :]
    valid = (ii < nx[:, None, None]) & (jj < ny[:, None, None])
    return jnp.any(ovl & valid, axis=(1, 2))
