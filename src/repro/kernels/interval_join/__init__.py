from .ops import batch_interval_overlap  # noqa: F401
