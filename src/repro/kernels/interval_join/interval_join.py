"""Pallas TPU kernel: batched sorted-interval-list overlap join.

The paper's intermediate filter reduces to "do two sorted disjoint interval
lists share a point?" per candidate pair (AA/AF/FA joins). On CPU this is a
branchy two-pointer merge; on TPU we evaluate the overlap predicate for all
(i, j) interval pairs of a tile at once on the VPU — lists are short (tens of
intervals), so the O(I*J) lane-parallel pass beats any serial walk and needs
no gather/scatter.

Tiling: grid (B/BB, J/JB); each program holds BB pair-rows of X intervals
([BB, I]) and a JB-wide slab of Y intervals in VMEM, materializes the
[BB, I, JB] predicate, reduces over (I, JB), and ORs into the [BB] output.
Endpoints are biased-int32, inclusive-last (see core/april.py); X rows are
masked by their true interval counts, Y slabs by theirs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["interval_overlap_pallas", "april_trichotomy_pallas"]

TRUE_NEG, TRUE_HIT, INDECISIVE = 0, 1, 2   # mirrors core.join


def _kernel(nx_ref, ny_ref, xs_ref, xl_ref, ys_ref, yl_ref, out_ref, *, jb_size):
    jb = pl.program_id(1)
    xs = xs_ref[...]            # [BB, I]
    xl = xl_ref[...]
    ys = ys_ref[...]            # [BB, JB]
    yl = yl_ref[...]
    nx = nx_ref[...]            # [BB]
    ny = ny_ref[...]

    BB, I = xs.shape
    JB = ys.shape[1]
    # overlap(i, j) = ys[j] <= xl[i] and xs[i] <= yl[j]
    ovl = (ys[:, None, :] <= xl[:, :, None]) & (xs[:, :, None] <= yl[:, None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (BB, I, JB), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (BB, I, JB), 2) + jb * jb_size
    valid = (ii < nx[:, None, None]) & (jj < ny[:, None, None])
    any_hit = jnp.any(ovl & valid, axis=(1, 2))

    @pl.when(jb == 0)
    def _():
        out_ref[...] = any_hit

    @pl.when(jb != 0)
    def _():
        out_ref[...] = out_ref[...] | any_hit


def interval_overlap_pallas(
    xs, xl, nx, ys, yl, ny, *, block_b: int = 8, block_j: int = 128,
    interpret: bool = False,
):
    """[B] bool: does pair b's X list overlap its Y list?

    xs/xl: [B, I] int32 (biased, inclusive-last, padded with INT32_MAX);
    ys/yl: [B, J]; nx/ny: [B] int32 true counts.
    """
    B, I = xs.shape
    J = ys.shape[1]
    assert B % block_b == 0 and J % block_j == 0, (B, J, block_b, block_j)
    grid = (B // block_b, J // block_j)

    return pl.pallas_call(
        partial(_kernel, jb_size=block_j),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda b, j: (b,)),            # nx
            pl.BlockSpec((block_b,), lambda b, j: (b,)),            # ny
            pl.BlockSpec((block_b, I), lambda b, j: (b, 0)),        # xs
            pl.BlockSpec((block_b, I), lambda b, j: (b, 0)),        # xl
            pl.BlockSpec((block_b, block_j), lambda b, j: (b, j)),  # ys
            pl.BlockSpec((block_b, block_j), lambda b, j: (b, j)),  # yl
        ],
        out_specs=pl.BlockSpec((block_b,), lambda b, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.bool_),
        interpret=interpret,
    )(nx, ny, xs, xl, ys, yl)


def _any_overlap(xs, xl, nx, ys, yl, ny):
    """[BB] bool: lane-parallel overlap reduction of one pair of list slabs
    (the [BB, I, J] predicate materialized in VMEM, masked by true counts)."""
    BB, I = xs.shape
    J = ys.shape[1]
    ovl = (ys[:, None, :] <= xl[:, :, None]) & (xs[:, :, None] <= yl[:, None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (BB, I, J), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (BB, I, J), 2)
    valid = (ii < nx[:, None, None]) & (jj < ny[:, None, None])
    return jnp.any(ovl & valid, axis=(1, 2))


def _trichotomy_kernel(nra_ref, nrf_ref, nsa_ref, nsf_ref,
                       ras_ref, ral_ref, rfs_ref, rfl_ref,
                       sas_ref, sal_ref, sfs_ref, sfl_ref, out_ref):
    """Fused APRIL trichotomy (Algorithm 2): AA + AF + FA joins and the
    verdict select in ONE pass over the block — a bucketed batch needs a
    single kernel launch instead of three overlap launches."""
    nra = nra_ref[...]; nrf = nrf_ref[...]
    nsa = nsa_ref[...]; nsf = nsf_ref[...]
    aa = _any_overlap(ras_ref[...], ral_ref[...], nra,
                      sas_ref[...], sal_ref[...], nsa)
    af = _any_overlap(ras_ref[...], ral_ref[...], nra,
                      sfs_ref[...], sfl_ref[...], nsf)
    fa = _any_overlap(rfs_ref[...], rfl_ref[...], nrf,
                      sas_ref[...], sal_ref[...], nsa)
    out_ref[...] = jnp.where(
        ~aa, TRUE_NEG,
        jnp.where(af | fa, TRUE_HIT, INDECISIVE)).astype(jnp.int32)


def april_trichotomy_pallas(
    nra, nrf, nsa, nsf, ras, ral, rfs, rfl, sas, sal, sfs, sfl, *,
    block_b: int = 8, interpret: bool = False,
):
    """[B] int32 verdicts (TRUE_NEG / TRUE_HIT / INDECISIVE) per pair row.

    ras/ral: [B, Ia] A(r); rfs/rfl: [B, If] F(r); sas/sal: [B, Ja] A(s);
    sfs/sfl: [B, Jf] F(s) — biased int32, inclusive-last, INT32_MAX padded;
    n*: [B] int32 true counts. Width bounding is the caller's bucketing job
    (core.join buckets by power-of-two list width, DESIGN.md §9).
    """
    B, Ia = ras.shape
    If = rfs.shape[1]
    Ja = sas.shape[1]
    Jf = sfs.shape[1]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)

    def vec(_):
        return pl.BlockSpec((block_b,), lambda b: (b,))

    def mat(w):
        return pl.BlockSpec((block_b, w), lambda b: (b, 0))

    return pl.pallas_call(
        _trichotomy_kernel,
        grid=grid,
        in_specs=[vec(0), vec(0), vec(0), vec(0),
                  mat(Ia), mat(Ia), mat(If), mat(If),
                  mat(Ja), mat(Ja), mat(Jf), mat(Jf)],
        out_specs=pl.BlockSpec((block_b,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(nra, nrf, nsa, nsf, ras, ral, rfs, rfl, sas, sal, sfs, sfl)
