"""Pallas TPU kernel: RI ALIGNEDAND (paper §3.3) on packed uint32 words.

The paper aligns two interval bitstrings byte-by-byte with carry-over and
ANDs them, early-exiting on the first non-zero byte. Byte loops are scalar
poison on TPU; here each grid program aligns one fragment pair with
*vectorized 32-bit funnel shifts* over the whole word vector (roll + shift),
applies the optional XOR re-encoding mask (same-encoding joins) and the tail
mask, and reduces with a single any().

Codes are packed LSB-first: stream bit ``3c+t`` is bit ``(3c+t) % 32`` of
word ``(3c+t) // 32`` (t = position inside the cell's 3-bit code). Fragments
start on cell boundaries, so the XOR mask's phase is always 0 and the mask
word pattern (period lcm(3,32) = 3 words) is passed in precomputed.

TPU note: one fragment pair per grid step keeps the shifts scalar-uniform
(per-row funnel shifts would need lane gathers). Fragment words W is tiny
(3·cells/32), so the batch axis is the throughput axis — on real hardware
multiple pairs pipeline through the sequential grid with negligible VMEM
pressure, and the hot path of APRIL never calls this kernel (RI only).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["aligned_and_pallas"]


def _funnel_align(words, off_bits, W):
    """Extract W words starting at bit offset ``off_bits`` from ``words``."""
    off_w = off_bits // 32
    sh = (off_bits % 32).astype(jnp.uint32)
    cur = jnp.roll(words, -off_w)
    nxt = jnp.roll(words, -(off_w + 1))
    hi_sh = (jnp.uint32(32) - sh) % jnp.uint32(32)
    shifted = (cur >> sh) | jnp.where(sh == 0, jnp.uint32(0), nxt << hi_sh)
    return shifted


def _kernel(meta_ref, x_ref, y_ref, mask_ref, out_ref):
    # meta row: [1,4] int32 = (x_off_bits, y_off_bits, n_bits, xor_y)
    x_off = meta_ref[0, 0]
    y_off = meta_ref[0, 1]
    n_bits = meta_ref[0, 2]
    xor_y = meta_ref[0, 3]

    xw = x_ref[0]             # [W] uint32
    yw = y_ref[0]
    mask = mask_ref[...]      # [W] uint32 repeating XOR pattern (phase 0)
    W = xw.shape[0]

    ax = _funnel_align(xw, x_off, W)
    ay = _funnel_align(yw, y_off, W)
    ay = jnp.where(xor_y != 0, ay ^ mask, ay)

    # tail mask: word k keeps bits [0, clamp(n_bits - 32k, 0, 32))
    k = jax.lax.broadcasted_iota(jnp.int32, (W,), 0)
    rem = jnp.clip(n_bits - 32 * k, 0, 32)
    full = rem >= 32
    tail = (jnp.uint32(1) << rem.astype(jnp.uint32)) - jnp.uint32(1)
    keep = jnp.where(full, jnp.uint32(0xFFFFFFFF), tail)

    out_ref[0, 0] = jnp.any((ax & ay & keep) != 0)


def aligned_and_pallas(x_words, y_words, meta, mask_words, *,
                       interpret: bool = False):
    """[B] bool. x_words/y_words: [B, W] uint32; meta: [B, 4] int32
    (x_off_bits, y_off_bits, n_bits, xor_y); mask_words: [W] uint32."""
    B, W = x_words.shape
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda b: (b, 0)),
            pl.BlockSpec((1, W), lambda b: (b, 0)),
            pl.BlockSpec((1, W), lambda b: (b, 0)),
            pl.BlockSpec((W,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.bool_),
        interpret=interpret,
    )(meta, x_words, y_words, mask_words)[:, 0]
