from .ops import batch_aligned_and  # noqa: F401
