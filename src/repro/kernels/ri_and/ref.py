"""Pure-jnp oracle for ALIGNEDAND: expand words to bit vectors and compare."""
from __future__ import annotations

import jax.numpy as jnp


def _to_bits(words, n_bits_total):
    """[.., W] uint32 -> [.., 32W] bool, LSB-first per word."""
    b = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., :, None] >> b[None, :]) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], -1)[..., :n_bits_total]


def aligned_and_ref(x_words, y_words, meta, mask_words):
    """Same contract as aligned_and_pallas (meta: [B,4] int32)."""
    B, W = x_words.shape
    nb = 32 * W
    xb = _to_bits(x_words, nb)           # [B, nb]
    yb = _to_bits(y_words, nb)
    mb = _to_bits(mask_words[None, :], nb)[0]
    pos = jnp.arange(nb)
    out = []
    for i in range(B):
        xo, yo, n, xy = (int(meta[i, 0]), int(meta[i, 1]),
                         int(meta[i, 2]), int(meta[i, 3]))
        ax = jnp.roll(xb[i], -xo)
        ay = jnp.roll(yb[i], -yo)
        if xy:
            ay = ay ^ mb
        keep = pos < n
        out.append(jnp.any((ax & ay & keep.astype(ax.dtype)) != 0))
    return jnp.stack(out)
