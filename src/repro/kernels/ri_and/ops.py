"""Wrapper: packs host RI bit fragments into word batches and dispatches.

Also provides :func:`pack_bits_u32` / :func:`xor_mask_words` used by tests
and by the RI device pipeline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .ri_and import aligned_and_pallas


def pack_bits_u32(bits: np.ndarray, W: int) -> np.ndarray:
    """[n] 0/1 -> [W] uint32 words, LSB-first within each word."""
    out = np.zeros(W, np.uint32)
    n = min(len(bits), 32 * W)
    idx = np.arange(n)
    np.add.at(out, idx // 32,
              (bits[:n].astype(np.uint32) << (idx % 32).astype(np.uint32)))
    return out


def xor_mask_words(W: int, pattern=(1, 1, 0)) -> np.ndarray:
    """Repeating 3-bit XOR mask (phase 0) packed into W uint32 words."""
    bits = np.tile(np.asarray(pattern, np.uint8), (32 * W + 2) // 3)[: 32 * W]
    return pack_bits_u32(bits, W)


@partial(jax.jit, static_argnames=("interpret",))
def batch_aligned_and(x_words, y_words, meta, mask_words, *, interpret=False):
    return aligned_and_pallas(
        jnp.asarray(x_words, jnp.uint32), jnp.asarray(y_words, jnp.uint32),
        jnp.asarray(meta, jnp.int32), jnp.asarray(mask_words, jnp.uint32),
        interpret=interpret)
