"""Pallas TPU kernel: APRIL-interval block-sparse flash attention.

The beyond-paper bridge (DESIGN.md §5): APRIL classifies raster cells as
Full / Partial / Empty and stores each class as sorted interval lists along a
locality-preserving order. A block-sparse attention mask has exactly this
structure on the (q_block x kv_block) grid:

    Empty   block: no query attends any key        -> skip entirely
    Full    block: every query attends every key   -> compute, NO mask applied
    Partial block: boundary of the mask            -> compute + apply mask

Per q-block row the kernel receives an A-interval ``[a_lo, a_hi)`` (blocks to
visit) and an F-interval ``[f_lo, f_hi)`` (mask-free sub-run) — for causal and
local-window masks the Partial blocks are exactly the boundary runs flanking
the F-run, mirroring the paper's A/F-list split. Scalar-prefetched interval
tables steer the grid; masked-out KV blocks cost no FLOPs or VMEM traffic.

Flash-attention online softmax accumulates in f32 VMEM scratch; the KV axis
is the innermost grid dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["april_attention_pallas"]

NEG_INF = -1e30


def _kernel(iv_ref,                       # scalar prefetch: [nq, 4] int32
            q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr,
            *, scale, block_q, block_kv, mask_kind, window, softcap):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    a_lo = iv_ref[qi, 0]
    f_lo = iv_ref[qi, 1]
    f_hi = iv_ref[qi, 2]
    a_hi = iv_ref[qi, 3]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    visit = (ki >= a_lo) & (ki < a_hi)

    @pl.when(visit)
    def _block():
        q = q_ref[0]                       # [bq, D]
        k = k_ref[0]                       # [bkv, D]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bkv]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        partial_blk = (ki < f_lo) | (ki >= f_hi)

        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kpos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        if mask_kind == "causal":
            allowed = kpos <= qpos
        elif mask_kind == "local":
            allowed = (kpos <= qpos) & (kpos > qpos - window)
        else:  # 'full' — A/F intervals already encode everything
            allowed = jnp.ones((block_q, block_kv), bool)
        # Full blocks skip the mask entirely (the APRIL F-run property)
        s = jnp.where(partial_blk & ~allowed, NEG_INF, s)

        m_prev = m_scr[...]                # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)             # [bq, bkv]
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_scr[...]
        out = acc_scr[...] / jnp.where(l == 0, 1.0, l)
        o_ref[0] = out.astype(o_ref.dtype)


def april_attention_pallas(
    q, k, v, intervals, *, scale=None, block_q=128, block_kv=128,
    mask_kind="causal", window=0, softcap=None, interpret=False,
):
    """q: [BH, Sq, D]; k/v: [BH, Skv, D]; intervals: [nq_blocks, 4] int32
    rows (a_lo, f_lo, f_hi, a_hi) in kv-block units. Returns [BH, Sq, D]."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq = Sq // block_q
    nk = Skv // block_kv
    scale = scale if scale is not None else (1.0 / D ** 0.5)

    grid = (BH, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        mask_kind=mask_kind, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, D), lambda b, qi, ki, iv: (b, qi, 0)),
                pl.BlockSpec((1, block_kv, D), lambda b, qi, ki, iv: (b, ki, 0)),
                pl.BlockSpec((1, block_kv, D), lambda b, qi, ki, iv: (b, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, D),
                                   lambda b, qi, ki, iv: (b, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        interpret=interpret,
    )(intervals, q, k, v)
