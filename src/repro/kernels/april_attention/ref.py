"""Pure-jnp oracle: dense attention with the equivalent mask."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def dense_mask(Sq: int, Skv: int, mask_kind: str, window: int = 0):
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    if mask_kind == "causal":
        return kpos <= qpos
    if mask_kind == "local":
        return (kpos <= qpos) & (kpos > qpos - window)
    return jnp.ones((Sq, Skv), bool)


def april_attention_ref(q, k, v, *, scale=None, mask_kind="causal",
                        window=0, softcap=None):
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else (1.0 / D ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = dense_mask(Sq, Skv, mask_kind, window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    row_any = mask.any(axis=1)[None, :, None]
    out = jnp.where(row_any, out, 0.0)
    return out.astype(q.dtype)
