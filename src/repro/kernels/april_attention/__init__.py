from .ops import april_attention, build_block_intervals  # noqa: F401
