"""Wrapper: builds the per-q-block A/F interval tables (the APRIL structure
of the mask) and dispatches the kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .april_attention import april_attention_pallas


def build_block_intervals(Sq: int, Skv: int, block_q: int, block_kv: int,
                          mask_kind: str, window: int = 0) -> np.ndarray:
    """[nq, 4] int32 rows (a_lo, f_lo, f_hi, a_hi) in kv-block units.

    Exactly the APRIL construction on the (q_block x kv_block) raster:
    a 'cell' (block) is Full iff every (q, k) position it covers is allowed,
    Partial iff some are, Empty otherwise. For causal/local masks the three
    classes form contiguous runs per row, so one A- and one F-interval
    suffice (the general case would carry lists, as in the paper).
    """
    nq = Sq // block_q
    nk = Skv // block_kv
    out = np.zeros((nq, 4), np.int32)
    for qi in range(nq):
        q_lo = qi * block_q
        q_hi = q_lo + block_q - 1         # inclusive
        if mask_kind == "causal":
            lo_pos, hi_pos = 0, q_hi
            full_lo_pos, full_hi_pos = 0, q_lo  # kpos <= q_lo - 1 + 1
        elif mask_kind == "local":
            lo_pos = max(0, q_lo - window + 1)
            hi_pos = q_hi
            full_lo_pos = max(0, q_hi - window + 1)
            full_hi_pos = q_lo
        else:  # full attention
            lo_pos, hi_pos = 0, Skv - 1
            full_lo_pos, full_hi_pos = 0, Skv
        a_lo = lo_pos // block_kv
        a_hi = min(nk, hi_pos // block_kv + 1)
        # Full blocks: fully contained in [full_lo_pos, full_hi_pos)
        f_lo = (full_lo_pos + block_kv - 1) // block_kv
        f_hi = max(f_lo, full_hi_pos // block_kv)
        f_lo = max(f_lo, a_lo)
        f_hi = min(f_hi, a_hi)
        if f_hi <= f_lo:
            f_lo = f_hi = a_lo            # empty F-run
        out[qi] = (a_lo, f_lo, f_hi, a_hi)
    return out


@partial(jax.jit, static_argnames=(
    "block_q", "block_kv", "mask_kind", "window", "softcap", "interpret", "scale"))
def april_attention(q, k, v, *, scale=None, block_q=128, block_kv=128,
                    mask_kind="causal", window=0, softcap=None,
                    interpret=False):
    """Block-interval attention. q: [BH, Sq, D]; k/v: [BH, Skv, D]."""
    Sq, Skv = q.shape[1], k.shape[1]
    iv = jnp.asarray(build_block_intervals(
        Sq, Skv, block_q, block_kv, mask_kind, window))
    return april_attention_pallas(
        q, k, v, iv, scale=scale, block_q=block_q, block_kv=block_kv,
        mask_kind=mask_kind, window=window, softcap=softcap,
        interpret=interpret)
