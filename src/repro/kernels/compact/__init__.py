from .ops import compact_mask  # noqa: F401
