"""Pallas TPU kernel: blocked exclusive prefix sum of a survivor mask.

The fused pipeline (DESIGN.md §12) front-packs stage survivors *on device*
between the filter trichotomy and refinement — the staged path's
``np.nonzero`` compact-and-reupload is exactly the host sync the chain must
not pay. The scatter destinations of a stable compaction are an exclusive
prefix sum of the mask; this kernel computes it blocked over [BR, 128]
tiles with the running carry held in SMEM across the (sequential on TPU)
grid, so lanes of any length scan in one launch.

Layout: the [N] mask arrives reshaped [R, 128] (int32 0/1, zero-padded);
each grid step scans an [BR, 128] row block in row-major order — in-row
exclusive cumsum plus row-exclusive block offsets plus the carry — and
bumps the carry by the block's population count. The [1] total output is
revisited by every step; the last step leaves the full count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["exclusive_scan_pallas"]

#: rows per grid step; with the 128-lane minor dim this is the int32 min tile
BLOCK_ROWS = 8
LANES = 128


def _scan_kernel(m_ref, excl_ref, total_ref, carry_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _():
        carry_ref[0] = 0

    m = m_ref[...]                              # [BR, 128] int32 0/1
    rows = jnp.sum(m, axis=1)                   # [BR] per-row populations
    base = jnp.cumsum(rows) - rows              # row-exclusive offsets
    inrow = jnp.cumsum(m, axis=1) - m           # in-row exclusive cumsum
    excl_ref[...] = carry_ref[0] + base[:, None] + inrow
    carry_ref[0] = carry_ref[0] + jnp.sum(rows)
    total_ref[0] = carry_ref[0]


def exclusive_scan_pallas(m2d, *, interpret: bool = False):
    """Row-major exclusive prefix sum of an [R, 128] int32 0/1 mask.

    Returns (excl [R, 128] int32, total [1] int32); R must be a multiple of
    ``BLOCK_ROWS``. The grid walks row blocks sequentially, threading the
    running count through an SMEM scratch cell.
    """
    R, L = m2d.shape
    assert L == LANES and R % BLOCK_ROWS == 0, (R, L)
    grid = (R // BLOCK_ROWS,)
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, LANES), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(m2d)
