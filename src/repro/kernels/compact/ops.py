"""jit'd public wrapper for the on-device compaction primitive.

``compact_mask`` is the stage-boundary operator of the fused pipeline
(DESIGN.md §12): it turns a device bool lane into a stable front-pack
permutation plus a device survivor count, so the next stage can gather the
compacted prefix without the mask ever visiting the host.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .compact import BLOCK_ROWS, LANES, exclusive_scan_pallas

__all__ = ["compact_mask"]

#: one pallas launch scans the whole lane from VMEM; longer lanes take the
#: (identical) cumsum path rather than a multi-pass tiling
_PALLAS_MAX = 1 << 21


@partial(jax.jit, static_argnames=("backend", "interpret"))
def _compact_impl(mask, *, backend: str, interpret: bool):
    N = mask.shape[0]
    m = mask.astype(jnp.int32)
    if backend == "pallas":
        tile = BLOCK_ROWS * LANES
        Np = -(-N // tile) * tile
        m2d = jnp.pad(m, (0, Np - N)).reshape(-1, LANES)
        excl2d, total = exclusive_scan_pallas(m2d, interpret=interpret)
        excl = excl2d.reshape(-1)[:N]
        k = total[0]
    else:
        c = jnp.cumsum(m)
        excl = c - m
        k = c[-1]
    i = jnp.arange(N, dtype=jnp.int32)
    # selected rows pack to [0, k) in order; unselected to [k, N) in order —
    # dest is a permutation, so the scatter is collision-free
    dest = jnp.where(m > 0, excl, k + (i - excl))
    perm = jnp.zeros(N, jnp.int32).at[dest].set(i)
    return perm, k.astype(jnp.int32)


def compact_mask(mask, *, backend: str = "jnp", interpret: bool | None = None):
    """Stable front-pack of a device bool lane: (perm [N] int32, count []).

    ``perm[:count]`` are the True indices ascending, ``perm[count:]`` the
    False indices ascending — gathering ``lane[perm]`` front-packs stage
    survivors entirely on device; ``count`` stays a device scalar (the
    fused chain never reads it on host). ``backend='pallas'`` runs the
    blocked SMEM-carry scan kernel (interpret mode off-TPU); ``'jnp'`` the
    plain cumsum. Both are bit-identical to ``ref.compact_mask_ref``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if mask.shape[0] == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros((), jnp.int32)
    if backend == "pallas" and mask.shape[0] > _PALLAS_MAX:
        backend = "jnp"
    return _compact_impl(mask, backend=backend, interpret=interpret)
