"""Pure-jnp oracle for the compaction kernel."""
from __future__ import annotations

import jax.numpy as jnp


def compact_mask_ref(mask):
    """Same contract as compact_mask: (perm [N] int32, count [] int32).

    Stable argsort of the negated mask — True rows first, each side in
    ascending index order — is the definitional front-pack permutation.
    """
    perm = jnp.argsort(~mask.astype(bool), stable=True).astype(jnp.int32)
    return perm, jnp.sum(mask, dtype=jnp.int32)
